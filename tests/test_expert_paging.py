"""Expert-paged MoE serving tests (DESIGN.md §15).

* Token identity: the expert-paged engine emits bit-identical streams
  to the resident-weight engine on every traffic mix — full residency,
  tight budgets with LRU eviction churn, restricted and unrestricted
  footprints — because the admitted-footprint router mask is applied
  pre-top_k in BOTH engines and the paged FFN reconstructs the exact
  dense weight stack from CLS_EXPERT pages.
* Read-only shared-page protocol under routing churn: a randomized
  load/admit/release/evict trace over expert-shaped CLS_EXPERT traffic
  replayed against the sequential :class:`repro.core.refpool.
  RefClassedPool` witness — identical grants, exact conformance,
  per-class conservation, and a non-negative §4.2 margin after
  eviction storms.
* Zero silent drops: the forward meters MoE capacity overflow
  (``moe_dropped_tokens`` rides the class-0 counter block) and the
  serving smokes assert it stays 0 at serving capacity factors.
* Observability: expert hit/miss/prefetch counters ride the expert
  class's ``_c2`` device-counter block through the step's one sync;
  ``expert_hit_rate`` exports through snapshot() and render_prom().
* Admission safety: the in-step miss row is an invariant 0 (residency
  is guaranteed before dispatch), unservable footprints reject as
  ``too_large``, and the engine stays leak-free after drain +
  ``flush_experts``.
"""

import dataclasses
import random

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import models
from repro.configs import get_config, smoke_config
from repro.core import classed_pool, refpool
from repro.core.classed_pool import ClassSpec
from repro.models.moe import moe_apply
from repro.models.transformer import EXPERT_PPE, expert_layer_slots
from repro.serving.engine import Request, ServingEngine
from repro.serving.telemetry import parse_prom


@pytest.fixture(scope="module")
def moe_setup():
    cfg = smoke_config(get_config("mixtral-8x7b"))
    # serving capacity factor: C clamps to top_k * tokens so the
    # expert-parallel dispatch drops nothing — the zero-drop invariant
    # the satellite meters guard (both engines use the same cf, so
    # identity is unaffected)
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=64.0))
    params = models.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _drive(cfg, params, footprints, expert_paging, budget=None,
           n_req=6, max_new=5, **kw):
    eng = ServingEngine(cfg, params, dp=1, b_local=2, max_len=64,
                        prefix_sharing=False, mesh=None,
                        expert_paging=expert_paging,
                        expert_budget=budget, **kw)
    rng = np.random.RandomState(0)
    reqs = []
    for rid in range(n_req):
        prompt = list(rng.randint(1, cfg.vocab - 1, 6))
        r = Request(rid, prompt=prompt, max_new_tokens=max_new,
                    experts=footprints(rid))
        reqs.append(r)
        eng.submit(r)
    eng.run(max_steps=500)
    return eng, reqs


# ========================================================= token identity


def test_paged_vs_resident_token_identity(moe_setup):
    """Full-residency paged serving vs the resident engine on a mixed
    footprint trace: bit-identical tokens, zero dropped tokens, zero
    in-step misses (admission preloads every footprint), leak-free
    after drain + flush."""
    cfg, params = moe_setup
    fp = lambda rid: [(0, 1), None, (2, 3)][rid % 3]
    e0, r0 = _drive(cfg, params, fp, expert_paging=False)
    e1, r1 = _drive(cfg, params, fp, expert_paging=True)
    assert all(r.done for r in r0) and all(r.done for r in r1)
    assert [r.out_tokens for r in r0] == [r.out_tokens for r in r1]
    # satellite: the capacity-drop meter rode class 0's counter block
    # and stayed 0 — no silent token drops in either engine
    for eng in (e0, e1):
        assert int(eng.telemetry.shard["moe_dropped_tokens"].sum()) == 0
    # the miss row is an invariant detector: admission guarantees
    # residency, so a routed-to non-resident page is a bug
    assert int(e1.telemetry.shard["expert_miss_pages_c2"].sum()) == 0
    assert int(e1.telemetry.shard["expert_hit_pages_c2"].sum()) > 0
    # the in-scan gathers are metered as prefetch (overlapped loads)
    assert int(e1.telemetry.shard["expert_prefetch_pages_c2"].sum()) > 0
    assert e1.telemetry.expert_hit_rate() is not None
    assert e1.telemetry.never_dry_margin_min() >= 0
    e1.flush_experts()
    assert e1.leak_free()


def test_tight_budget_eviction_churn_identity(moe_setup):
    """A budget of one 2-expert footprint forces LRU churn between
    disjoint footprints: evictions happen, the resident peak respects
    the budget exactly, admission defers on the expert dimension, and
    the token streams stay identical to the resident engine."""
    cfg, params = moe_setup
    fp = lambda rid: (0, 1) if rid % 2 == 0 else (2, 3)
    budget = EXPERT_PPE * expert_layer_slots(cfg) * 2   # one footprint
    e0, r0 = _drive(cfg, params, fp, expert_paging=False)
    e1, r1 = _drive(cfg, params, fp, expert_paging=True, budget=budget)
    assert all(r.done for r in r1)
    assert [r.out_tokens for r in r0] == [r.out_tokens for r in r1]
    assert e1.stats["expert_evictions"] > 0
    assert e1.stats["expert_pages_resident_peak"] <= budget
    assert e1.scheduler.stats["defer_experts"] > 0
    assert int(e1.telemetry.shard["expert_miss_pages_c2"].sum()) == 0
    assert int(e1.telemetry.shard["moe_dropped_tokens"].sum()) == 0
    assert e1.telemetry.never_dry_margin_min() >= 0
    # per-class conservation on the live pool
    for c in range(e1.n_classes):
        free = np.asarray(classed_pool.free_per_shard(e1.state.pool, c))
        live = np.asarray(classed_pool.live_per_shard(e1.state.pool, c))
        nb = e1.state.pool.classes[c].shared.free_ids.shape[-1]
        assert int(free[0] + live[0]) == nb
    e1.flush_experts()
    assert e1.leak_free()


def test_unservable_footprint_rejected_too_large(moe_setup):
    """A footprint whose full-stack load exceeds the per-shard budget
    on an EMPTY shard can never be admitted — typed too_large
    rejection at submit, not a wedged queue."""
    cfg, params = moe_setup
    budget = EXPERT_PPE * expert_layer_slots(cfg) * 2
    eng = ServingEngine(cfg, params, dp=1, b_local=2, max_len=64,
                        prefix_sharing=False, mesh=None,
                        expert_paging=True, expert_budget=budget)
    big = Request(0, prompt=[3, 4, 5], max_new_tokens=3, experts=None)
    adm = eng.submit(big)
    assert not adm.accepted and adm.reason == "too_large"
    assert big.rejected == "too_large"
    ok = Request(1, prompt=[3, 4, 5], max_new_tokens=3, experts=(1, 2))
    assert eng.submit(ok).accepted
    eng.run(max_steps=200)
    assert ok.done
    eng.flush_experts()
    assert eng.leak_free()


# ===================================== shared-page protocol under churn


DP = 2
ESPEC = ClassSpec(page_size=64, num_blocks=30, num_lanes=2, ell=2)
SPECS = (ClassSpec(page_size=8, num_blocks=24, num_lanes=2, ell=2),
         ESPEC)
ECLS = 1        # the expert-like read-only class in this mini vector


def test_expert_refcount_churn_vs_witness():
    """Randomized routing-churn trace of the §15 residency protocol —
    bulk shared-stack loads (expert load), addref per admission,
    free_shared per release and per eviction — replayed in lockstep
    against the sequential RefClassedPool witness: identical grants,
    exact final-state conformance, conservation after every op, and a
    never-dry pool after eviction storms."""
    rng = random.Random(11)
    pool = classed_pool.create_dp(DP, SPECS)
    refs = refpool.create_classed_dp(DP, SPECS)
    # ledger[d]: expert -> (pages, batch_refs)
    ledger = [dict() for _ in range(DP)]
    next_eid = 0

    def conservation():
        for c, spec in enumerate(SPECS):
            free = np.asarray(classed_pool.free_per_shard(pool, c))
            live = np.asarray(classed_pool.live_per_shard(pool, c))
            for d in range(DP):
                assert free[d] + live[d] == spec.num_blocks

    for step in range(250):
        op = rng.choice(["load", "admit", "admit", "release", "release",
                         "evict", "evict_storm"])
        d = rng.randrange(DP)
        if op == "load":
            if sum(len(e[0]) for e in ledger[d].values()) + EXPERT_PPE \
                    > ESPEC.num_blocks - 3 * ESPEC.ell * ESPEC.num_lanes:
                continue        # admission respects the budget (§15)
            counts = np.zeros((DP, ESPEC.num_lanes), np.int32)
            counts[d, 0] = EXPERT_PPE
            pool, ids = classed_pool.alloc_from_shared_dp(
                pool, ECLS, jnp.asarray(counts), EXPERT_PPE)
            got = np.asarray(ids)
            for s in range(DP):
                ref_rows = refs[s].alloc_from_shared(
                    ECLS, counts[s], EXPERT_PPE)
                flat = [b for row in ref_rows for b in row]
                want = [int(x) for x in got[s].reshape(-1) if x >= 0]
                assert want == flat, f"shard {s}: load grant diverged"
            pages = [int(x) for x in got[d, 0] if x >= 0]
            assert len(pages) == EXPERT_PPE
            ledger[d][next_eid] = (pages, 0)
            next_eid += 1
        elif op == "admit" and ledger[d]:
            eid = rng.choice(list(ledger[d]))
            pages, b = ledger[d][eid]
            rows = np.full((DP, EXPERT_PPE), -1, np.int32)
            rows[d] = pages
            pool = classed_pool.addref_dp(pool, ECLS, jnp.asarray(rows))
            for s in range(DP):
                refs[s].addref(ECLS, [int(x) for x in rows[s]])
            ledger[d][eid] = (pages, b + 1)
        elif op == "release":
            hot = [e for e, (_, b) in ledger[d].items() if b > 0]
            if not hot:
                continue
            eid = rng.choice(hot)
            pages, b = ledger[d][eid]
            rows = np.full((DP, EXPERT_PPE), -1, np.int32)
            rows[d] = pages
            pool = classed_pool.free_shared_dp(pool, ECLS,
                                               jnp.asarray(rows))
            for s in range(DP):
                refs[s].free_shared(ECLS, [int(x) for x in rows[s]])
            ledger[d][eid] = (pages, b - 1)
        elif op in ("evict", "evict_storm"):
            # unpin-shaped eviction of COLD experts only; a storm
            # evicts every cold expert on the shard at once
            cold = [e for e, (_, b) in ledger[d].items() if b == 0]
            if not cold:
                continue
            victims = cold if op == "evict_storm" else [rng.choice(cold)]
            for eid in victims:
                pages, _ = ledger[d].pop(eid)
                rows = np.full((DP, EXPERT_PPE), -1, np.int32)
                rows[d] = pages
                pool = classed_pool.free_shared_dp(pool, ECLS,
                                                   jnp.asarray(rows))
                for s in range(DP):
                    refs[s].free_shared(ECLS, [int(x) for x in rows[s]])
        conservation()

    for d in range(DP):
        msg = refpool.conforms_classed(refs[d], pool, d)
        assert msg is None, f"shard {d}: {msg}"
    # after the storms, one rebalance restocks every lane to >= ell:
    # the class never went dry because churn respected the §15 budget
    pool = classed_pool.rebalance_dp(pool)
    for c in range(len(SPECS)):
        hp = pool.classes[c]
        margin = (np.asarray(hp.private_top).min()
                  - SPECS[c].ell)
        assert margin >= 0, f"class {c} ran a lane dry"


# =========================================================== drop meter


def test_moe_apply_meters_dropped_tokens(moe_setup):
    """The dispatch meter counts exactly the valid assignments dropped
    by capacity overflow: 0 at serving capacity factors, > 0 when the
    expert capacity C is squeezed below the routed load."""
    cfg, _ = moe_setup
    d, E, k = cfg.d_model, cfg.moe.num_experts, cfg.moe.top_k
    rng = np.random.RandomState(3)
    x = jnp.asarray(rng.randn(16, 1, d).astype(np.float32))  # decode shape
    key = jax.random.PRNGKey(1)
    ffn = {
        "router": jax.random.normal(key, (d, E)) * 0.1,
        "w_gate": jax.random.normal(key, (E, d, cfg.d_ff)) * 0.05,
        "w_up": jax.random.normal(key, (E, d, cfg.d_ff)) * 0.05,
        "w_down": jax.random.normal(key, (E, cfg.d_ff, d)) * 0.05,
    }
    _, dropped, routed = moe_apply(cfg, ffn, x, metered=True)
    assert int(dropped.sum()) == 0, "serving cf must never drop tokens"
    assert int(routed.sum()) == 16 * k
    cfg_t = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=0.25))
    _, dropped_t, routed_t = moe_apply(cfg_t, ffn, x, metered=True)
    assert int(dropped_t.sum()) > 0, "squeezed capacity must meter drops"
    # conservation: every valid assignment is either kept or metered
    assert int(routed_t.sum()) + int(dropped_t.sum()) == 16 * k


# ======================================================== observability


def test_expert_counters_in_snapshot_and_prom(moe_setup):
    cfg, params = moe_setup
    fp = lambda rid: (0, 1) if rid % 2 == 0 else (2, 3)
    budget = EXPERT_PPE * expert_layer_slots(cfg) * 2
    eng, reqs = _drive(cfg, params, fp, expert_paging=True,
                       budget=budget)
    assert all(r.done for r in reqs)
    snap = eng.telemetry.snapshot()
    assert snap["expert_hit_rate"] is not None
    assert snap["counters"]["expert_load_pages"] > 0
    assert snap["counters"]["expert_pages_resident_peak"] == budget
    # the expert page meters ride the class-2 rows of the one-sync
    # counter block — per-shard sums land under the _c2 keys
    assert "expert_hit_pages_c2" in snap["per_shard"]
    assert sum(snap["per_shard"]["expert_hit_pages_c2"]) > 0
    assert sum(snap["per_shard"]["expert_miss_pages_c2"]) == 0
    text = eng.telemetry.render_prom()
    prom = parse_prom(text)
    assert prom["repro_expert_hit_rate"][()] >= 0
    assert prom["repro_expert_load_pages"][()] > 0
    assert sum(prom["repro_expert_hit_pages_c2"].values()) > 0
    assert prom["repro_moe_dropped_tokens"][(("shard", "0"),)] == 0
    eng.flush_experts()
    assert eng.leak_free()


@pytest.mark.skipif(len(jax.devices()) < 2, reason="needs >= 2 devices")
def test_one_collective_per_step_expert_paged(moe_setup):
    """The expert-paged serve variant on the dp-mesh plane compiles
    exactly one collective — expert gathers, footprint masking, and
    the §15 meter rows all ride inside the existing status
    all_gather."""
    cfg, params = moe_setup
    eng = ServingEngine(cfg, params, dp=2, b_local=2, max_len=64,
                        prefix_sharing=False, expert_paging=True)
    assert eng.mesh is not None
    hlo = eng._serve_variants[(False, False)].lower(
        eng.params, eng.state, eng.last_tok, eng.out_count, eng.budget,
        eng.temps, eng.topks, eng.seeds,
        jnp.zeros((2, 2, eng.chunk), jnp.int32),
        jnp.zeros((2, 2), jnp.int32),
        jnp.zeros((2, 2), bool), jnp.zeros((2, 2), bool),
        eng.expert_mask,
    ).compile().as_text()
    n_gather = hlo.count("all-gather(") + hlo.count("all-gather-start(")
    n_other = sum(hlo.count(c) for c in
                  ("all-reduce(", "all-reduce-start(", "all-to-all(",
                   "collective-permute(", "collective-permute-start("))
    assert n_gather == 1, f"expected exactly one all_gather: {n_gather}"
    assert n_other == 0, f"unexpected extra collectives: {n_other}"


def test_one_sync_per_step_expert_paged(moe_setup):
    """Expert paging adds no device->host syncs to the serve loop: one
    ``np.asarray`` per step, exactly like the dense engine (loads and
    refcount traffic are jitted dispatches, never reads)."""
    cfg, params = moe_setup
    budget = EXPERT_PPE * expert_layer_slots(cfg) * 2   # one footprint
    eng = ServingEngine(cfg, params, dp=1, b_local=2, max_len=64,
                        prefix_sharing=False, mesh=None,
                        expert_paging=True, expert_budget=budget)
    rng = np.random.RandomState(5)
    for rid in range(4):
        # short streams + a one-footprint budget: slots turn over
        # INSIDE the patched window, so release (bulk free_shared),
        # re-admission (addref), eviction AND reload traffic all run
        # under the sync counter
        eng.submit(Request(rid, prompt=list(rng.randint(1, 255, 6)),
                           max_new_tokens=2,
                           experts=(0, 1) if rid % 2 else (2, 3)))
    eng.step()                       # admission + first loads

    import repro.serving.engine as engine_mod
    syncs = []
    real_asarray = np.asarray

    class CountingNp:
        def __getattr__(self, name):
            return getattr(np, name)

        @staticmethod
        def asarray(x, *a, **kw):
            if isinstance(x, jax.Array):
                syncs.append(x.shape)
            return real_asarray(x, *a, **kw)

    loads_before = eng.stats["expert_load_pages"]
    steps_before = eng.stats["steps"]
    orig = engine_mod.np
    engine_mod.np = CountingNp()
    try:
        for _ in range(5):           # may drain early: idle fast-path
            eng.step()               # steps skip the dispatch AND sync
    finally:
        engine_mod.np = orig
    served = eng.stats["steps"] - steps_before
    assert served >= 3, "window too short to cover slot turnover"
    assert len(syncs) == served, f"1 sync per served step: {syncs}"
    assert eng.stats["expert_load_pages"] > loads_before, (
        "the patched window never exercised the expert load path")


# ====================================================== fault tolerance


def test_recover_inplace_reloads_experts(moe_setup):
    """In-place recovery reclaims every CLS_EXPERT page (tables NULL,
    ledger cleared) and the requeued requests re-admit with fresh
    loads — the engine drains token-identically and leak-free."""
    cfg, params = moe_setup
    fp = lambda rid: (0, 1) if rid % 2 == 0 else (2, 3)
    e0, r0 = _drive(cfg, params, fp, expert_paging=False)
    eng = ServingEngine(cfg, params, dp=1, b_local=2, max_len=64,
                        prefix_sharing=False, mesh=None,
                        expert_paging=True)
    rng = np.random.RandomState(0)
    reqs = []
    for rid in range(6):
        prompt = list(rng.randint(1, cfg.vocab - 1, 6))
        r = Request(rid, prompt=prompt, max_new_tokens=5,
                    experts=fp(rid))
        reqs.append(r)
        eng.submit(r)
    for _ in range(3):
        eng.step()
    loads_before = eng.stats["expert_load_pages"]
    assert loads_before > 0
    eng._recover_inplace()
    assert eng.expert_ledger.resident_count() == 0
    for tab in eng.state.expert_tables.values():
        assert int(jnp.max(tab)) < 0, "recovery left a mapped expert"
    assert bool(jnp.all(eng.expert_mask)), "recovery left a stale mask"
    eng.run(max_steps=500)
    assert all(r.done for r in reqs)
    # resumed streams are the streams the unpreempted run produced
    assert [r.out_tokens for r in reqs] == [r.out_tokens for r in r0]
    assert eng.stats["expert_load_pages"] > loads_before, "reloaded"
    eng.flush_experts()
    assert eng.leak_free()
