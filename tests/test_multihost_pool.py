"""Multi-host pool test plane: shard_map conformance + cross-shard safety.

The tentpole deliverable of the mesh lift (DESIGN.md §9), in four
layers, all runnable on CPU — under
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` the pool ops and
the serving engine run shard_mapped over a real ("dp",) device mesh
(CI's mesh-8 job); on a single device the same tests cover the vmap
semantics, which must be bit-identical.

1. **Differential conformance**: one randomized op trace replayed
   through the jax ``HierPool`` (shard_mapped when a mesh exists) and
   through the host-side sequential reference model
   (:mod:`repro.core.refpool`, the P-SIM sequential witness) — grant
   ids and final pool state must match exactly per shard, hence
   identical grant/free multisets per shard.
2. **Cross-shard adversarial storms**: per-shard lanes and per-shard
   rebalancers interleaved instruction-by-instruction (torn
   drain/refill windows straddling other shards' ops), histories
   checked with the sharded linearizability extensions
   (``split_history_by_shard`` + cross-shard theft) plus per-shard
   conservation; crash variants included.
3. **Engine property storms** (seeded, via the hypothesis shim):
   admission -> prefill -> preempt -> release traffic on a dp=4 engine,
   asserting per-shard page conservation, the §4.2 never-dry invariant
   per lane, and token-identity vs the single-device (dp=1) run of the
   same trace.
4. **Mesh plumbing**: the engine builds the mesh, shards its state over
   it, and still performs exactly one device->host sync per step.
"""

import random

import numpy as np
import jax
import jax.numpy as jnp
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:              # container image lacks hypothesis
    from _hypothesis_fallback import given, settings, st

from jax.sharding import NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro import models
from repro.configs import get_config, smoke_config
from repro.core import (Scheduler, SimContext, check_cross_shard_frees,
                        check_sharded_batch_history, hier_pool, refpool,
                        split_history_by_shard)
from repro.core.sim import OpRecord
from repro.launch.mesh import make_dp_mesh
from repro.models.transformer import pool_ell
from repro.serving.engine import Request, ServingEngine
from repro.serving.sched import SchedConfig

DP, LANES, ELL, KMAX, BLOCKS = 4, 3, 2, 3, 64


def _pool_ops(mesh, pool):
    """Jitted DP pool ops — shard_mapped over the mesh when one exists,
    plain jit (vmap semantics) otherwise.  Same call signatures."""
    specs = jax.tree.map(lambda _: P("dp"), pool)

    def w(fn, out_specs):
        if mesh is None:
            return jax.jit(fn)
        return jax.jit(shard_map(fn, mesh=mesh, in_specs=(specs, P("dp")),
                                 out_specs=out_specs, check_rep=False))

    def reb(p, _):
        return hier_pool.rebalance_dp(p), _

    return {
        "alloc": w(hier_pool.alloc_dp, (specs, P("dp"))),
        "alloc_n": w(lambda p, c: hier_pool.alloc_n_dp(p, c, KMAX),
                     (specs, P("dp"))),
        "alloc_shared": w(
            lambda p, c: hier_pool.alloc_from_shared_dp(p, c, KMAX),
            (specs, P("dp"))),
        "addref": w(hier_pool.addref_dp, specs),
        "free_n": w(hier_pool.free_n_dp, specs),
        "free_shared": w(hier_pool.free_shared_dp, specs),
        "rebalance": w(reb, (specs, P("dp"))),
    }


# module-level lazy context (NOT a pytest fixture: the hypothesis
# fallback shim's @given wrapper hides the test signature, so fixtures
# cannot be injected into property tests — plain helpers work in both)
_POOL_CTX = None


def _get_pool_ctx():
    global _POOL_CTX
    if _POOL_CTX is None:
        mesh = make_dp_mesh(DP)
        pool = hier_pool.create_dp(DP, BLOCKS, LANES, ELL)
        if mesh is not None:
            pool = jax.device_put(
                pool,
                jax.tree.map(lambda _: NamedSharding(mesh, P("dp")), pool))
        _POOL_CTX = (mesh, pool, _pool_ops(mesh, pool))
    return _POOL_CTX


# ===================================================== 1. conformance

class TestDifferentialConformance:
    """One trace, three executors: jax (shard_map or vmap) vs the
    host-side sequential reference — identical grants, identical final
    stacks/refcounts per shard."""

    @settings(max_examples=8, deadline=None)
    @given(seed=st.integers(0, 9999))
    def test_trace_conforms_per_shard(self, seed):
        mesh, pool0, ops = _get_pool_ctx()
        rng = random.Random(seed)
        pool = pool0
        refs = refpool.create_dp(DP, BLOCKS, LANES, ELL)
        # per-shard bookkeeping for building valid frees: blocks the
        # user holds (one base ref) and blocks carrying an extra ref
        held = [[] for _ in range(DP)]
        extra = [[] for _ in range(DP)]
        jax_grants = [[] for _ in range(DP)]    # grant multiset (device)
        ref_grants = [[] for _ in range(DP)]    # grant multiset (spec)
        frees = [[] for _ in range(DP)]         # free multiset (shared)

        def pad(row, k):
            return row + [-1] * (k - len(row))

        for step in range(40):
            op = rng.choice(["alloc", "alloc_n", "alloc_shared", "addref",
                             "free_n", "free_n", "free_shared",
                             "rebalance"])
            if op == "alloc":
                want = np.asarray(
                    [[rng.random() < 0.7 for _ in range(LANES)]
                     for _ in range(DP)])
                pool, ids = ops["alloc"](pool, jnp.asarray(want))
                got = np.asarray(ids)
                for d in range(DP):
                    ref_ids = refs[d].alloc(want[d])
                    assert got[d].tolist() == ref_ids, (
                        f"seed {seed} step {step} shard {d}: alloc")
                    jax_grants[d] += [int(b) for b in got[d] if b >= 0]
                    valid = [b for b in ref_ids if b >= 0]
                    held[d] += valid
                    ref_grants[d] += valid
            elif op == "alloc_n":
                counts = np.asarray(
                    [[rng.randint(0, KMAX) for _ in range(LANES)]
                     for _ in range(DP)], np.int32)
                pool, ids = ops["alloc_n"](pool, jnp.asarray(counts))
                got = np.asarray(ids)
                for d in range(DP):
                    ref_rows = refs[d].alloc_n(counts[d], KMAX)
                    jax_grants[d] += [int(b) for b in got[d].ravel()
                                      if b >= 0]
                    for ln in range(LANES):
                        assert got[d, ln].tolist() == pad(ref_rows[ln],
                                                          KMAX), (
                            f"seed {seed} step {step} shard {d}: alloc_n")
                        held[d] += ref_rows[ln]
                        ref_grants[d] += ref_rows[ln]
            elif op == "alloc_shared":
                counts = np.asarray(
                    [[rng.randint(0, 2) for _ in range(LANES)]
                     for _ in range(DP)], np.int32)
                pool, ids = ops["alloc_shared"](pool, jnp.asarray(counts))
                got = np.asarray(ids)
                for d in range(DP):
                    ref_rows = refs[d].alloc_from_shared(counts[d], KMAX)
                    jax_grants[d] += [int(b) for b in got[d].ravel()
                                      if b >= 0]
                    for ln in range(LANES):
                        assert got[d, ln].tolist() == pad(ref_rows[ln],
                                                          KMAX), (
                            f"seed {seed} step {step} shard {d}: bulk")
                        held[d] += ref_rows[ln]
                        ref_grants[d] += ref_rows[ln]
            elif op == "addref":
                rows = []
                for d in range(DP):
                    picks = ([rng.choice(held[d])] if held[d]
                             and rng.random() < 0.8 else [])
                    extra[d] += picks
                    refs[d].addref(pad(picks, 1))
                    rows.append(pad(picks, 1))
                pool = ops["addref"](pool, jnp.asarray(rows, jnp.int32))
            elif op == "free_n":
                rows_dp = []
                for d in range(DP):
                    rows = [[] for _ in range(LANES)]
                    k = rng.randint(0, min(3, len(held[d])))
                    for _ in range(k):
                        b = held[d].pop(rng.randrange(len(held[d])))
                        rows[rng.randrange(LANES)].append(b)
                        frees[d].append(b)
                    rows_dp.append([pad(r, KMAX) for r in rows])
                pool = ops["free_n"](pool, jnp.asarray(rows_dp, jnp.int32))
                for d in range(DP):
                    refs[d].free_n(rows_dp[d])
            elif op == "free_shared":
                rows = []
                for d in range(DP):
                    picks = []
                    if extra[d] and rng.random() < 0.8:
                        picks.append(extra[d].pop())
                        frees[d].append(picks[-1])
                    rows.append(pad(picks, 1))
                pool = ops["free_shared"](pool, jnp.asarray(rows, jnp.int32))
                for d in range(DP):
                    refs[d].free_shared(rows[d])
            else:
                pool, _ = ops["rebalance"](pool, jnp.zeros((DP, 1),
                                                           jnp.int32))
                for d in range(DP):
                    refs[d].rebalance()

            # shard-resolved conservation at every step
            free_s = np.asarray(hier_pool.free_per_shard(pool))
            live_s = np.asarray(hier_pool.live_per_shard(pool))
            for d in range(DP):
                assert free_s[d] + live_s[d] == BLOCKS, (
                    f"seed {seed} step {step} shard {d}: conservation")

        # identical grant/free multisets per shard: frees are the same
        # trace input on both sides by construction, grants compared
        # here as whole multisets (and per-op exactly, above), and the
        # exact final-state conformance closes the loop
        sh = jax.tree.map(np.asarray, pool)
        for d in range(DP):
            assert sorted(jax_grants[d]) == sorted(ref_grants[d]), (
                f"seed {seed} shard {d}: grant multisets diverge")
            msg = refpool.conforms(
                refs[d], sh.shared.free_ids[d], sh.shared.top[d],
                sh.private_ids[d], sh.private_top[d],
                sh.shared.refcount[d])
            assert msg is None, f"seed {seed} shard {d}: {msg}"
            assert len(frees[d]) <= len(ref_grants[d]) + len(extra[d])

    def test_shard_map_matches_vmap_exactly(self):
        """When a mesh exists, the shard_mapped ops and the plain vmap
        ops must produce bit-identical pools and grants for the same
        trace — the mesh changes placement, never results."""
        mesh, pool0, ops = _get_pool_ctx()
        if mesh is None:
            pytest.skip("needs >= 4 devices (mesh-8 CI job)")
        vops = _pool_ops(None, pool0)
        p_a = pool0
        p_b = jax.device_put(pool0,
                             jax.devices()[0])     # single-device copy
        rng = random.Random(123)
        for _ in range(12):
            counts = jnp.asarray(
                [[rng.randint(0, KMAX) for _ in range(LANES)]
                 for _ in range(DP)], jnp.int32)
            p_a, ids_a = ops["alloc_n"](p_a, counts)
            p_b, ids_b = vops["alloc_n"](p_b, counts)
            assert np.array_equal(np.asarray(ids_a), np.asarray(ids_b))
            p_a = ops["free_n"](p_a, ids_a)
            p_b = vops["free_n"](p_b, ids_b)
            p_a, _ = ops["rebalance"](p_a, jnp.zeros((DP, 1), jnp.int32))
            p_b, _ = vops["rebalance"](p_b, jnp.zeros((DP, 1), jnp.int32))
        for leaf_a, leaf_b in zip(jax.tree.leaves(p_a), jax.tree.leaves(p_b)):
            assert np.array_equal(np.asarray(leaf_a), np.asarray(leaf_b))


# ============================================= 2. cross-shard storms

class TestCrossShardStorms:
    """Adversarial interleavings across shard-local pools: lanes and a
    torn-rebalance program per shard, scheduled instruction-by-
    instruction so one shard's drain/refill window straddles other
    shards' ops.  Histories carry meta["shard"] and must pass the
    sharded checks; per-shard conservation holds even under crashes."""

    N_SHARDS = 3

    def _storm(self, seed, crash_rebalancer=None, crash_lane=None):
        S, L, ell, kmax = self.N_SHARDS, 2, 3, 3
        pools = {d: hier_pool.create(num_blocks=48, num_lanes=L, ell=ell)
                 for d in range(S)}
        held = {(d, ln): [] for d in range(S) for ln in range(L)}
        ctx = SimContext(S * (L + 1), seed=seed)
        sched = Scheduler(seed=seed)

        def lane_program(d, ln, pid):
            rng = random.Random(seed * 101 + pid)
            mine = held[(d, ln)]
            for _ in range(20):
                yield
                if not mine or rng.random() < 0.55:
                    want = rng.randint(1, kmax)
                    counts = np.zeros(L, np.int32)
                    counts[ln] = want
                    rec = ctx.begin_op(pid, "alloc_n", arg=want)
                    rec.meta["shard"] = d
                    rec.invoke_step = sched.steps
                    yield
                    pool, ids = hier_pool.alloc_n(
                        pools[d], jnp.asarray(counts), kmax)
                    pools[d] = pool
                    got = [int(i) for i in np.asarray(ids)[ln] if i >= 0]
                    mine.extend(got)
                    yield
                    ctx.end_op(rec, result=got)
                    rec.response_step = sched.steps
                else:
                    k = rng.randint(1, min(len(mine), kmax))
                    back = mine[-k:]
                    ids = np.full((L, kmax), -1, np.int32)
                    ids[ln, :k] = back
                    rec = ctx.begin_op(pid, "free_n", arg=back)
                    rec.meta["shard"] = d
                    rec.invoke_step = sched.steps
                    yield
                    pools[d] = hier_pool.free_n(pools[d], jnp.asarray(ids))
                    del mine[-k:]
                    yield
                    ctx.end_op(rec)
                    rec.response_step = sched.steps

        def rebalancer(d, pid):
            for _ in range(30):
                yield
                pools[d] = hier_pool.rebalance_drain(pools[d])
                yield          # torn window: other SHARDS run here too
                pools[d] = hier_pool.rebalance_refill(pools[d])

        pid = 0
        reb_pids = {}
        for d in range(S):
            for ln in range(L):
                sched.add(pid, lane_program(d, ln, pid))
                pid += 1
            reb_pids[d] = pid
            sched.add(pid, rebalancer(d, pid))
            pid += 1
        crash_at = {}
        if crash_rebalancer is not None:
            d, at = crash_rebalancer
            crash_at[reb_pids[d]] = at
        if crash_lane is not None:
            crash_at[crash_lane] = 150
        sched.run("bursty", crash_at=crash_at)

        errs = check_sharded_batch_history(ctx.history)
        assert errs == [], errs
        by_shard = split_history_by_shard(ctx.history)
        assert set(by_shard) <= set(range(S))
        for d in range(S):
            live = sum(len(held[(d, ln)]) for ln in range(L))
            free = int(hier_pool.total_free(pools[d]))
            assert free + live == 48, (
                f"shard {d}: blocks lost or duplicated")
            assert int(hier_pool.num_live(pools[d])) == live

    def test_interleaved_rebalance_across_shards(self):
        for seed in (0, 1, 2):
            self._storm(seed)

    def test_crash_mid_rebalance_one_shard(self):
        """One shard's rebalancer dies inside its torn window while the
        other shards keep trading: only that shard's drained batch is
        parked on its own shared stack; every shard conserves."""
        self._storm(seed=4, crash_rebalancer=(1, 120))

    def test_crash_lane_holding_blocks(self):
        self._storm(seed=6, crash_lane=2)

    def test_checker_catches_cross_shard_theft(self):
        """Self-test: a block granted on shard 0 but freed through
        shard 1's history is flagged as theft by the checker (and the
        same history with the right shard tag passes)."""
        def hist(free_shard):
            a = OpRecord(opid=0, pid=0, name="alloc_n", arg=2,
                         invoke_step=0, result=[5, 6], response_step=1)
            a.meta["shard"] = 0
            f = OpRecord(opid=1, pid=1, name="free_n", arg=[5, 6],
                         invoke_step=2, result=None, response_step=3)
            f.meta["shard"] = free_shard
            return [a, f]

        errs = check_cross_shard_frees(hist(free_shard=1))
        assert len(errs) == 2 and all("theft" in e for e in errs), errs
        assert check_cross_shard_frees(hist(free_shard=0)) == []
        # ...and a same-id grant on ANOTHER shard is not a false theft
        h = hist(free_shard=0)
        b = OpRecord(opid=2, pid=2, name="alloc_n", arg=2, invoke_step=0,
                     result=[5, 6], response_step=1)
        b.meta["shard"] = 1
        errs = check_sharded_batch_history(h + [b])
        assert errs == [], errs


# ========================================== 3. engine property storms

_ENGINE_CTX = None


def _get_engine_setup():
    global _ENGINE_CTX
    if _ENGINE_CTX is None:
        cfg = smoke_config(get_config("olmo-1b"))
        params = models.init_params(cfg, jax.random.PRNGKey(0))
        _ENGINE_CTX = (cfg, params)
    return _ENGINE_CTX


@pytest.fixture(scope="module")
def engine_setup():
    return _get_engine_setup()


_STORM_ENGINES = None


def _get_storm_engines():
    """One dp=4 (mesh when available) engine + one dp=1 reference,
    reused across property examples — each example drains to idle and
    proves zero occupancy, so reuse is itself a conservation check
    (and it amortizes step compilation across the seeded examples)."""
    global _STORM_ENGINES
    if _STORM_ENGINES is None:
        cfg, params = _get_engine_setup()
        mk = lambda dp, bl: ServingEngine(
            cfg, params, dp=dp, b_local=bl, max_len=64, chunk_size=8,
            sched=SchedConfig(pin_pages=6))
        _STORM_ENGINES = (cfg, mk(4, 2), mk(1, 2))
    return _STORM_ENGINES


def _storm_requests(cfg, rng, n):
    hot = list(rng.randint(1, 255, 16))           # 2 whole pages of 8
    reqs = []
    for i in range(n):
        if rng.random() < 0.6:
            prompt = hot + list(rng.randint(1, 255, rng.randint(1, 6)))
        else:
            prompt = list(rng.randint(1, 255, rng.randint(2, 20)))
        slo = ("interactive" if rng.random() < 0.25 else
               "batch" if rng.random() < 0.3 else "standard")
        reqs.append((prompt, int(rng.randint(1, 5)), slo))
    return reqs


def _drive(eng, reqs, rid0, check=None):
    out = []
    rs = [Request(rid0 + i, prompt=list(p), max_new_tokens=mn, slo=slo)
          for i, (p, mn, slo) in enumerate(reqs)]
    # staggered submission: half up front, the rest trickling in while
    # the batch is busy (admission under pressure)
    for r in rs[:len(rs) // 2]:
        eng.submit(r)
    backlog = rs[len(rs) // 2:]
    for step in range(400):
        if backlog and step % 2 == 0:
            eng.submit(backlog.pop(0))
        if not backlog and eng.idle():
            break
        eng.step()
        if check is not None:
            check(eng)
    assert all(r.done for r in rs), "storm did not drain"
    for r in rs:
        out.append(r.out_tokens)
    eng.flush_pins()
    assert eng.page_occupancy() == 0.0, "pages leaked after drain+flush"
    return out


class TestEngineMeshStorms:
    @settings(max_examples=4, deadline=None)
    @given(seed=st.integers(0, 999))
    def test_storm_conservation_never_dry_token_identity(self, seed):
        """Admission/prefill/preempt/release storms on the dp=4 plane:
        per-shard conservation + §4.2 never-dry after every step, and
        the emitted streams are identical to the dp=1 run of the same
        trace (placement, sharing, pinning, and the mesh are all
        output-invisible)."""
        cfg, eng4, eng1 = _get_storm_engines()
        rng = np.random.RandomState(seed)
        reqs = _storm_requests(cfg, rng, 10)
        ell = pool_ell(cfg, chunk=8)
        pages_local = eng4.pages_local

        def invariants(eng):
            kv = eng.state.pool.classes[0]
            free_s = np.asarray(hier_pool.free_per_shard(kv))
            live_s = np.asarray(hier_pool.live_per_shard(kv))
            assert np.all(free_s + live_s == pages_local), (
                f"seed {seed}: per-shard conservation broken "
                f"(free={free_s.tolist()} live={live_s.tolist()})")
            tops = np.asarray(kv.private_top)
            assert tops.min() >= ell, (
                f"seed {seed}: a lane ran dry (min={tops.min()}, "
                f"ell={ell}) — §4.2 violated")

        out4 = _drive(eng4, reqs, rid0=seed * 1000, check=invariants)
        out1 = _drive(eng1, reqs, rid0=seed * 1000)
        assert out4 == out1, (
            f"seed {seed}: mesh run diverged from single-device run")

    def test_preemption_storm_on_mesh_token_identical(self, engine_setup):
        """Tight per-shard budget + interactive arrivals mid-flight:
        standard work is preempted and resumed across the mesh with
        identical output streams, and the budget ledger matches the
        device truth when the dust settles."""
        cfg, params = engine_setup
        eng = ServingEngine(cfg, params, dp=2, b_local=2, max_len=64,
                            chunk_size=8,
                            sched=SchedConfig(page_budget=6))
        rng = np.random.RandomState(7)
        std = [Request(i, prompt=list(rng.randint(1, 255, 18)),
                       max_new_tokens=6) for i in range(4)]
        for r in std:
            eng.submit(r)
        for _ in range(2):
            eng.step()
        inter = [Request(10 + i, prompt=list(rng.randint(1, 255, 10)),
                         max_new_tokens=4, slo="interactive")
                 for i in range(2)]
        for r in inter:
            eng.submit(r)
        eng.run(max_steps=400)
        assert all(r.done for r in std + inter)
        assert eng.stats["preemptions"] >= 1, "storm never preempted"
        assert eng.page_occupancy() == 0.0
        assert eng.scheduler.committed == [0] * eng.dp

        ref = ServingEngine(cfg, params, dp=1, b_local=2, max_len=64,
                            chunk_size=8)
        refs = [Request(100 + i, prompt=list(r.prompt),
                        max_new_tokens=r.max_new_tokens)
                for i, r in enumerate(std + inter)]
        for r in refs:
            ref.submit(r)
        ref.run(max_steps=400)
        assert [r.out_tokens for r in std + inter] == \
            [r.out_tokens for r in refs], "preemption changed tokens"


# ================================================== 4. mesh plumbing

class TestMeshPlumbing:
    def test_engine_builds_mesh_and_shards_state(self, engine_setup):
        cfg, params = engine_setup
        eng = ServingEngine(cfg, params, dp=2, b_local=2, max_len=48)
        if len(jax.devices()) < 2:
            assert eng.mesh is None, "mesh without enough devices"
            return
        assert eng.mesh is not None and eng.mesh.axis_names == ("dp",)
        for leaf in jax.tree.leaves(eng.state):
            s = leaf.sharding
            assert isinstance(s, NamedSharding) and "dp" in str(s.spec), (
                f"unsharded serving leaf: {leaf.shape} {s}")

    @pytest.mark.skipif(len(jax.devices()) < 4, reason="mesh-8 CI job")
    def test_one_sync_per_step_under_mesh(self, engine_setup):
        """The shard_map lift must not add device->host traffic: steady
        state is still exactly one packed-status sync per step, now
        carrying every shard's row (the all_gather ran on device)."""
        cfg, params = engine_setup
        eng = ServingEngine(cfg, params, dp=4, b_local=2, max_len=64,
                            chunk_size=8)
        assert eng.mesh is not None
        for i in range(4):
            eng.submit(Request(i, prompt=[3, 5, 7], max_new_tokens=8))
        eng.step()
        assert all(not p for p in eng.pending_tokens.values())

        import repro.serving.engine as engine_mod
        syncs = []
        real_asarray = np.asarray

        class CountingNp:
            def __getattr__(self, name):
                return getattr(np, name)

            @staticmethod
            def asarray(x, *a, **kw):
                if isinstance(x, jax.Array):
                    syncs.append(x.shape)
                return real_asarray(x, *a, **kw)

        orig = engine_mod.np
        engine_mod.np = CountingNp()
        try:
            for _ in range(3):
                eng.step()
        finally:
            engine_mod.np = orig
        assert len(syncs) == 3, f"expected 1 sync/step, saw {syncs}"
        from repro.serving.telemetry import N_CTR
        assert all(s == (4 + N_CTR, 4, 2) for s in syncs)
        eng.run(max_steps=200)
        assert eng.page_occupancy() == 0.0
