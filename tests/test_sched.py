"""Traffic-aware frontend: admission, backpressure, preemption, pins.

Covers the DESIGN.md §8 subsystem at three levels:

* scheduler — SLO-class admission order, reject/defer backpressure with
  reasons, per-shard page-budget accounting;
* engine — preempt-then-readmit token identity (greedy AND sampled:
  the (seed, out_count) noise keying makes preemption invisible),
  pinned-prefix refcount conservation under mixed finish orders, LRU
  eviction under the pin budget, and the idle fast-path;
* sim — an adversarial scheduler storm that preempts a victim lane
  mid-rebalance (inside the torn drain/refill window), checked with
  the extended preemption-aware linearizability test.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import models
from repro.configs import get_config, smoke_config
from repro.core import block_pool, hier_pool
from repro.serving.engine import Request, ServingEngine
from repro.serving.sched import SchedConfig


@pytest.fixture(scope="module")
def engine_setup():
    cfg = smoke_config(get_config("olmo-1b"))
    params = models.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _conserved(eng):
    total = eng.pages_local * eng.dp
    kv = eng.state.pool.classes[0]
    free = int(hier_pool.total_free(kv))
    live = int(hier_pool.num_live(kv))
    assert free + live == total, "pages lost or duplicated"
    # the low-water query agrees with the pool-wide free count
    per_shard = np.asarray(hier_pool.free_per_shard(kv))
    assert per_shard.shape == (eng.dp,) and per_shard.sum() == free
    return live


# ------------------------------------------------------------- scheduler

class TestAdmissionPolicy:
    def test_priority_classes_admit_before_fifo(self, engine_setup):
        """A later-submitted interactive request is admitted before the
        earlier standard ones (strict priority across classes, FIFO
        within a class)."""
        cfg, params = engine_setup
        eng = ServingEngine(cfg, params, dp=1, b_local=1, max_len=64,
                            sched=SchedConfig(preemption=False))
        r0 = Request(0, prompt=[2, 3], max_new_tokens=3)
        r1 = Request(1, prompt=[4, 5], max_new_tokens=3)
        r2 = Request(2, prompt=[6, 7], max_new_tokens=3, slo="interactive")
        for r in (r0, r1, r2):
            assert eng.submit(r).accepted
        eng.run(max_steps=200)
        assert all(r.done for r in (r0, r1, r2))
        # r2 jumped both standard requests; r0 before r1 (FIFO in class)
        assert r2._seq < r0._seq < r1._seq, "priority order violated"

    def test_reject_queue_full_and_too_large(self, engine_setup):
        cfg, params = engine_setup
        eng = ServingEngine(cfg, params, dp=1, b_local=1, max_len=64,
                            sched=SchedConfig(max_queue=2, page_budget=4))
        # too_large: worst case 30 prompt + 8 new = 5 pages > budget 4
        big = Request(9, prompt=[3] * 30, max_new_tokens=8)
        a = eng.submit(big)
        assert not a.accepted and a.reason == "too_large"
        assert big.rejected == "too_large"
        oks = [eng.submit(Request(i, prompt=[2, 3], max_new_tokens=2))
               for i in range(3)]
        assert [o.accepted for o in oks] == [True, True, False]
        assert oks[2].reason == "queue_full"
        eng.run(max_steps=300)          # rejected requests never spin run()
        assert eng.stats["admitted"] == 2
        assert eng.scheduler.stats["rejected"] == 2
        assert eng.page_occupancy() == 0.0

    def test_page_budget_defers_despite_free_slot(self, engine_setup):
        """Two free slots but a 6-page budget: the second request (4
        worst-case pages each) must wait for the first to release its
        commitment, and the deferral is recorded with reason=pages."""
        cfg, params = engine_setup
        eng = ServingEngine(cfg, params, dp=1, b_local=2, max_len=64,
                            chunk_size=16,
                            sched=SchedConfig(page_budget=6,
                                              preemption=False))
        ra = Request(0, prompt=[2] * 20, max_new_tokens=6)   # 4 pages
        rb = Request(1, prompt=[3] * 20, max_new_tokens=6)
        eng.submit(ra)
        eng.submit(rb)
        eng.step()
        assert ra.slot is not None and rb.slot is None
        assert eng.scheduler.stats["defer_pages"] > 0
        eng.run(max_steps=300)
        assert ra.done and rb.done
        assert eng.page_occupancy() == 0.0


# ------------------------------------------------------------ preemption

class TestPreemption:
    def test_preempt_then_readmit_token_identity(self, engine_setup):
        """A preempted request (greedy and sampled) finishes with
        exactly the tokens of an unpreempted run: readmission re-feeds
        prompt + generated tokens, resumes out_count at the preemption
        point, and the sampler keys noise by (seed, position)."""
        cfg, params = engine_setup

        def mk_reqs():
            return (Request(0, prompt=[2, 3, 4], max_new_tokens=10,
                            slo="batch"),
                    Request(1, prompt=[8, 9, 10], max_new_tokens=10,
                            slo="batch", temperature=0.9, top_k=8, seed=7),
                    Request(2, prompt=[5, 6, 7], max_new_tokens=4,
                            slo="interactive"))

        # constrained: 2 slots, both batch requests mid-generation when
        # the interactive one arrives and preempts one of them
        g, s, it = mk_reqs()
        eng = ServingEngine(cfg, params, dp=1, b_local=2, max_len=64,
                            chunk_size=8)
        eng.submit(g)
        eng.submit(s)
        eng.step(); eng.step(); eng.step()
        eng.submit(it)
        eng.run(max_steps=300)
        assert all(r.done for r in (g, s, it))
        assert eng.stats["preemptions"] >= 1
        assert g.preemptions + s.preemptions >= 1

        # unconstrained reference: 3 slots, nothing preempted
        g2, s2, it2 = mk_reqs()
        ref = ServingEngine(cfg, params, dp=1, b_local=3, max_len=64,
                            chunk_size=8)
        ref.submit(g2)
        ref.submit(s2)
        ref.step(); ref.step(); ref.step()
        ref.submit(it2)
        ref.run(max_steps=300)
        assert ref.stats["preemptions"] == 0
        assert g.out_tokens == g2.out_tokens, "greedy victim diverged"
        assert s.out_tokens == s2.out_tokens, "sampled victim diverged"
        assert it.out_tokens == it2.out_tokens
        assert eng.page_occupancy() == 0.0
        _conserved(eng)

    def test_preemption_on_page_pressure(self, engine_setup):
        """Free slot available but no page headroom: the scheduler
        preempts the lower-priority holder rather than deferring the
        interactive head."""
        cfg, params = engine_setup
        eng = ServingEngine(cfg, params, dp=1, b_local=2, max_len=64,
                            chunk_size=16,
                            sched=SchedConfig(page_budget=6))
        rb = Request(0, prompt=[2] * 20, max_new_tokens=8)    # 4 pages
        eng.submit(rb)
        eng.step()
        ri = Request(1, prompt=[4] * 20, max_new_tokens=4,    # 3 pages
                     slo="interactive")
        eng.submit(ri)
        eng.run(max_steps=300)
        assert rb.done and ri.done
        assert rb.preemptions >= 1
        assert eng.page_occupancy() == 0.0
        _conserved(eng)

    def test_readmission_estimate_stable_under_tight_budget(self, engine_setup):
        """Regression: the worst-case estimate must not grow with
        tokens generated before a preemption (max_new is the TOTAL
        budget) — a victim that exactly fit the page budget must fit
        again on readmission instead of wedging the queue."""
        cfg, params = engine_setup
        eng = ServingEngine(cfg, params, dp=1, b_local=2, max_len=64,
                            chunk_size=16,
                            sched=SchedConfig(page_budget=4))
        rb = Request(0, prompt=[2] * 20, max_new_tokens=8)  # exactly 4 pages
        eng.submit(rb)
        for _ in range(7):
            eng.step()
        assert 5 <= len(rb.out_tokens) < 8
        ri = Request(1, prompt=[4, 5], max_new_tokens=2, slo="interactive")
        eng.submit(ri)              # blocked on pages → rb is preempted
        eng.run(max_steps=300)
        assert rb.preemptions >= 1
        assert rb.done and ri.done
        assert len(rb.out_tokens) == 8
        assert eng.page_occupancy() == 0.0
        _conserved(eng)

    def test_preempt_mid_prefill_resumes_cleanly(self, engine_setup):
        """Preemption before the victim emitted anything: the whole
        prompt is re-fed and outputs match an undisturbed run."""
        cfg, params = engine_setup
        prompt = list(range(2, 26))                           # 24 tokens
        ref = ServingEngine(cfg, params, dp=1, b_local=2, max_len=64,
                            chunk_size=8)
        r_ref = Request(0, prompt=list(prompt), max_new_tokens=4)
        ref.submit(r_ref)
        ref.run(max_steps=100)

        eng = ServingEngine(cfg, params, dp=1, b_local=1, max_len=64,
                            chunk_size=8)
        victim = Request(0, prompt=list(prompt), max_new_tokens=4,
                         slo="batch")
        eng.submit(victim)
        eng.step()                       # one 8-token chunk in KV
        hi = Request(1, prompt=[3, 5], max_new_tokens=2, slo="interactive")
        eng.submit(hi)
        eng.run(max_steps=300)
        assert victim.done and hi.done and victim.preemptions == 1
        assert victim.out_tokens == r_ref.out_tokens
        assert eng.page_occupancy() == 0.0


# ---------------------------------------------------------------- pinning

class TestPinnedPrefixes:
    def test_refcount_accounting_mixed_finish_orders(self, engine_setup):
        """Two sharers of a hot prefix finish in either order; the
        cache-owned references keep exactly the hot whole pages alive
        (refcount 1 each, deduplicated across the two pins), a
        re-arrival hits the pin, and a flush returns the pool to
        exactly empty — conservation at every stage."""
        cfg, params = engine_setup                            # psz = 8
        rng = np.random.RandomState(2)
        hot = list(rng.randint(1, 255, 16))                   # 2 pages
        for first_longer in (False, True):
            eng = ServingEngine(cfg, params, dp=1, b_local=2, max_len=64,
                                chunk_size=16,
                                sched=SchedConfig(pin_pages=8))
            na, nb = (6, 3) if first_longer else (3, 6)
            ra = Request(0, prompt=hot + [90, 91], max_new_tokens=na)
            rb = Request(1, prompt=hot + [77, 78, 79], max_new_tokens=nb)
            eng.submit(ra)
            eng.step(); eng.step()       # A prefilled → hot pages pinned
            eng.submit(rb)
            eng.run(max_steps=200)
            assert ra.done and rb.done
            # both requests pinned the same 2 whole pages: exact dedup
            assert eng.pinned_pages() == 2
            assert eng.pages_in_use() == 2, "only the pin survives drain"
            live = _conserved(eng)
            assert live == 2
            rc = np.asarray(eng.state.pool.classes[0].shared.refcount[0])
            assert (rc == 1).sum() == 2 and (rc >= 2).sum() == 0
            # the pin row's own view agrees (cache-owner refcounts)
            shard_pool = jax.tree.map(lambda a: a[0],
                                      eng.state.pool.classes[0].shared)
            row_rc = np.asarray(block_pool.refcounts_of(
                shard_pool, eng.pin_tables[0].reshape(-1)))
            assert (row_rc == 1).sum() == 2

            # re-arrival after the donors died: served from the pin
            rc2 = Request(2, prompt=hot + [50, 51], max_new_tokens=3)
            eng.submit(rc2)
            eng.run(max_steps=100)
            assert rc2.done
            assert eng.stats["pin_hit_reqs"] == 1
            assert eng.stats["pin_hit_tokens"] == 16
            assert eng.flush_pins() >= 1
            assert eng.page_occupancy() == 0.0
            assert int(hier_pool.num_live(eng.state.pool.classes[0])) == 0

    def test_pin_engages_for_single_token_requests(self, engine_setup):
        """Regression: a request that finishes on its prompt-completion
        step (max_new=1) releases its pages inside that very jitted
        step — the pin must be taken at feed-build time, before
        dispatch, or short-generation workloads never populate the
        cache despite a granted budget."""
        cfg, params = engine_setup                       # psz = 8
        rng = np.random.RandomState(5)
        hot = list(rng.randint(1, 255, 16))              # 2 whole pages
        eng = ServingEngine(cfg, params, dp=1, b_local=2, max_len=64,
                            chunk_size=8,
                            sched=SchedConfig(pin_pages=8))
        r0 = Request(0, prompt=hot + [3, 4], max_new_tokens=1)
        eng.submit(r0)
        eng.run(max_steps=50)
        assert r0.done and len(r0.out_tokens) == 1
        assert eng.pinned_pages() == 2, "same-step finisher did not pin"
        r1 = Request(1, prompt=hot + [5, 6], max_new_tokens=1)
        eng.submit(r1)
        eng.run(max_steps=50)
        assert r1.done
        assert eng.stats["pin_hit_reqs"] == 1
        _conserved(eng)
        eng.flush_pins()
        assert eng.page_occupancy() == 0.0

    def test_lru_eviction_under_pin_budget(self, engine_setup):
        """Three distinct 2-page prefixes against a 4-page pin budget:
        the least-recently-used pin is evicted, pages conserved."""
        cfg, params = engine_setup
        rng = np.random.RandomState(3)
        pA, pB, pC = (list(rng.randint(1, 255, 16)) for _ in range(3))
        eng = ServingEngine(cfg, params, dp=1, b_local=2, max_len=64,
                            chunk_size=16,
                            sched=SchedConfig(pin_pages=4))
        for i, p in enumerate((pA, pB)):
            r = Request(i, prompt=p + [60 + i], max_new_tokens=2)
            eng.submit(r)
            eng.run(max_steps=100)
        assert eng.pinned_pages() == 4                  # A and B pinned
        # touch A (pin hit), then pin C: B is now LRU and must go
        r = Request(7, prompt=pA + [99], max_new_tokens=2)
        eng.submit(r)
        eng.run(max_steps=100)
        r = Request(8, prompt=pC + [98], max_new_tokens=2)
        eng.submit(r)
        eng.run(max_steps=100)
        assert eng.pinned_pages() == 4
        assert eng.scheduler.stats["pins_evicted"] == 1
        assert eng.pins.lookup(0, tuple(pB)) is None, "LRU should be B"
        assert eng.pins.lookup(0, tuple(pA)) is not None
        assert eng.pins.lookup(0, tuple(pC)) is not None
        _conserved(eng)
        eng.flush_pins()
        assert eng.page_occupancy() == 0.0

    def test_idle_fast_path_skips_device_steps(self, engine_setup):
        """An engine with nothing to do must not dispatch the jitted
        step: step() reports idle, run() exits immediately."""
        cfg, params = engine_setup
        eng = ServingEngine(cfg, params, dp=1, b_local=2, max_len=48)
        assert eng.idle()
        for _ in range(3):
            assert eng.step() is False
        assert eng.stats["steps"] == 0
        assert eng.stats["idle_steps"] == 3
        eng.run(max_steps=10_000)                    # returns instantly
        assert eng.stats["steps"] == 0
        r = Request(0, prompt=[2, 3], max_new_tokens=2)
        eng.submit(r)
        assert not eng.idle()
        eng.run(max_steps=100)
        assert r.done
        steps = eng.stats["steps"]
        eng.run(max_steps=10_000)                    # drained → instant
        assert eng.stats["steps"] == steps


# ------------------------------------------------- sim-level storm checks

class TestPreemptionStorm:
    """Adversarial scheduler storm over the device pool with a
    preemptor that fires inside the torn rebalance window (between
    drain and refill), checked with the preemption-aware
    linearizability extension."""

    def _storm(self, seed):
        import random
        from repro.core import (Scheduler, SimContext,
                                check_preemption_history)
        L, ell, kmax = 3, 4, 4
        st = {"pool": hier_pool.create(num_blocks=96, num_lanes=L, ell=ell),
              "held": {lane: [] for lane in range(L)},
              "torn": False, "mid_reb_preempts": 0}
        total0 = int(hier_pool.total_free(st["pool"]))
        ctx = SimContext(L + 2, seed=seed)
        sched = Scheduler(seed=seed)

        def lane_program(lane):
            rng = random.Random(seed * 31 + lane)
            held = st["held"][lane]
            for _ in range(30):
                yield
                if not held or rng.random() < 0.55:
                    want = rng.randint(1, kmax)
                    counts = np.zeros(L, np.int32)
                    counts[lane] = want
                    rec = ctx.begin_op(lane, "alloc_n", arg=want)
                    rec.invoke_step = sched.steps
                    yield
                    pool, ids = hier_pool.alloc_n(
                        st["pool"], jnp.asarray(counts), kmax)
                    st["pool"] = pool
                    got = [int(i) for i in np.asarray(ids)[lane] if i >= 0]
                    held.extend(got)
                    yield
                    ctx.end_op(rec, result=got)
                    rec.response_step = sched.steps
                else:
                    k = rng.randint(1, min(len(held), kmax))
                    back = held[-k:]
                    ids = np.full((L, kmax), -1, np.int32)
                    ids[lane, :k] = back
                    rec = ctx.begin_op(lane, "free_n", arg=back)
                    rec.invoke_step = sched.steps
                    yield
                    st["pool"] = hier_pool.free_n(st["pool"],
                                                  jnp.asarray(ids))
                    del held[-k:]
                    yield
                    ctx.end_op(rec)
                    rec.response_step = sched.steps

        def rebalancer(pid):
            for _ in range(40):
                yield
                st["pool"] = hier_pool.rebalance_drain(st["pool"])
                st["torn"] = True
                yield              # <-- the torn window preemptions hit
                st["pool"] = hier_pool.rebalance_refill(st["pool"])
                st["torn"] = False

        def preemptor(pid):
            rng = random.Random(seed * 77 + 5)
            for _ in range(60):
                yield
                if not st["torn"]:
                    continue
                victim = rng.randrange(L)
                # like the engine: only preempt between the victim's
                # ops, never mid-allocation
                if ctx.current_op[victim] is not None:
                    continue
                held = st["held"][victim]
                if not held:
                    continue
                rec = ctx.begin_op(pid, "preempt", arg=victim)
                rec.invoke_step = sched.steps
                yield
                # release + response are atomic (the engine's preempt is
                # host-sequential): the victim cannot slip an op between
                # the forced free and the preempt's linearization point
                ids = np.full((L, len(held)), -1, np.int32)
                ids[victim, :] = held
                st["pool"] = hier_pool.free_n(st["pool"],
                                              jnp.asarray(ids))
                released = list(held)
                held.clear()
                st["mid_reb_preempts"] += int(st["torn"])
                ctx.end_op(rec, result=released)
                rec.response_step = sched.steps

        for lane in range(L):
            sched.add(lane, lane_program(lane))
        sched.add(L, rebalancer(L))
        sched.add(L + 1, preemptor(L + 1))
        sched.run("bursty")

        errs = check_preemption_history(ctx.history)
        assert errs == [], errs
        live = sum(len(h) for h in st["held"].values())
        assert int(hier_pool.total_free(st["pool"])) + live == total0, (
            "blocks lost or duplicated across preemptions")
        assert int(hier_pool.num_live(st["pool"])) == live
        return st["mid_reb_preempts"]

    def test_preempts_mid_rebalance_conserve_and_linearize(self):
        mid = sum(self._storm(seed) for seed in (0, 1, 2, 3))
        assert mid >= 1, "no preemption landed in the torn window"

    def test_checker_catches_leaky_preempt(self):
        """The extended checker must flag a preempt that under-reports
        the victim's holdings (a page leak) and one that releases a
        block the victim never held."""
        from repro.core import check_preemption_history
        from repro.core.sim import OpRecord

        def op(opid, pid, name, arg, res, t0, t1):
            return OpRecord(opid=opid, pid=pid, name=name, arg=arg,
                            invoke_step=t0, result=res, response_step=t1)

        leak = [op(0, 0, "alloc_n", 2, [5, 6], 0, 1),
                op(1, 1, "preempt", 0, [5], 2, 3)]       # 6 retained
        errs = check_preemption_history(leak)
        assert any("retained" in e for e in errs)

        theft = [op(0, 0, "alloc_n", 1, [5], 0, 1),
                 op(1, 2, "alloc_n", 1, [6], 0, 1),
                 op(2, 1, "preempt", 0, [5, 6], 2, 3)]   # 6 is lane 2's
        errs = check_preemption_history(theft)
        assert any("not held" in e for e in errs)

        clean = [op(0, 0, "alloc_n", 2, [5, 6], 0, 1),
                 op(1, 1, "preempt", 0, [5, 6], 2, 3),
                 op(2, 0, "alloc_n", 2, [5, 6], 4, 5)]   # readmit reuses
        assert check_preemption_history(clean) == []
