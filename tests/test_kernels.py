"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs jnp oracle."""

import numpy as np
import jax.numpy as jnp
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:              # container image lacks hypothesis
    from _hypothesis_fallback import given, settings, st

from repro.kernels.paged_attention.kernel import (paged_attention,
                                                  paged_attention_chunk)
from repro.kernels.paged_attention.ref import (paged_attention_chunk_ref,
                                               paged_attention_ref)
from repro.kernels.flash_attention.kernel import flash_attention
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.ssd_scan.kernel import ssd_scan
from repro.kernels.ssd_scan.ref import ssd_scan_ref
from repro.kernels.rg_lru.kernel import rg_lru
from repro.kernels.rg_lru.ref import rg_lru_ref


def _tol(dt):
    return 1e-4 if dt == jnp.float32 else 6e-2


class TestPagedAttention:
    @pytest.mark.parametrize("B,H,KH,hd,psz,maxp,P,dt", [
        (4, 8, 2, 128, 16, 6, 64, jnp.float32),
        (2, 4, 4, 64, 8, 4, 32, jnp.float32),
        (3, 8, 1, 128, 32, 3, 16, jnp.bfloat16),
        (1, 16, 8, 64, 16, 5, 48, jnp.float32),
    ])
    def test_vs_ref(self, B, H, KH, hd, psz, maxp, P, dt):
        rng = np.random.RandomState(hash((B, H, KH)) % 2**31)
        q = jnp.asarray(rng.randn(B, H, hd), dt)
        kp = jnp.asarray(rng.randn(P, psz, KH, hd), dt)
        vp = jnp.asarray(rng.randn(P, psz, KH, hd), dt)
        lens = jnp.asarray(rng.randint(1, maxp * psz, B), jnp.int32)
        table = np.full((B, maxp), -1, np.int32)
        used = set()
        for b in range(B):
            for i in range(int(np.ceil(float(lens[b]) / psz))):
                pid = rng.randint(0, P)
                while pid in used:
                    pid = rng.randint(0, P)
                used.add(pid)
                table[b, i] = pid
        table = jnp.asarray(table)
        ref = paged_attention_ref(q, kp, vp, table, lens)
        out = paged_attention(q, kp, vp, table, lens, interpret=True)
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(ref, np.float32),
            atol=_tol(dt), rtol=_tol(dt))

    @settings(max_examples=8, deadline=None)
    @given(seed=st.integers(0, 2**16), psz=st.sampled_from([8, 16]),
           maxp=st.integers(2, 5))
    def test_property_random_tables(self, seed, psz, maxp):
        rng = np.random.RandomState(seed)
        B, H, KH, hd, P = 2, 4, 2, 64, 24
        q = jnp.asarray(rng.randn(B, H, hd), jnp.float32)
        kp = jnp.asarray(rng.randn(P, psz, KH, hd), jnp.float32)
        vp = jnp.asarray(rng.randn(P, psz, KH, hd), jnp.float32)
        lens = jnp.asarray(rng.randint(1, maxp * psz, B), jnp.int32)
        table = np.full((B, maxp), -1, np.int32)
        avail = list(range(P))
        rng.shuffle(avail)
        for b in range(B):
            for i in range(int(np.ceil(float(lens[b]) / psz))):
                table[b, i] = avail.pop()
        ref = paged_attention_ref(q, kp, vp, jnp.asarray(table), lens)
        out = paged_attention(q, kp, vp, jnp.asarray(table), lens,
                              interpret=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=1e-4, rtol=1e-4)


class TestPagedAttentionChunk:
    def _case(self, rng, B, T, H, KH, hd, psz, maxp, P, dt,
              ragged_base=True):
        hi = max((maxp - 1) * psz - T, 1)
        base = rng.randint(0, hi, B).astype(np.int32) if ragged_base \
            else np.zeros(B, np.int32)
        q = jnp.asarray(rng.randn(B, T, H, hd), dt)
        kp = jnp.asarray(rng.randn(P, psz, KH, hd), dt)
        vp = jnp.asarray(rng.randn(P, psz, KH, hd), dt)
        table = np.full((B, maxp), -1, np.int32)
        avail = list(range(P))
        rng.shuffle(avail)
        for b in range(B):
            for i in range(int(np.ceil((base[b] + T) / psz))):
                table[b, i] = avail.pop()
        return q, kp, vp, jnp.asarray(table), jnp.asarray(base)

    @pytest.mark.parametrize("B,T,H,KH,hd,psz,maxp,P,dt", [
        (3, 4, 8, 2, 64, 8, 5, 32, jnp.float32),
        (2, 8, 4, 4, 64, 8, 4, 32, jnp.float32),
        (2, 5, 8, 1, 128, 16, 3, 16, jnp.bfloat16),
        (1, 16, 16, 8, 64, 16, 4, 48, jnp.float32),
    ])
    def test_vs_ref(self, B, T, H, KH, hd, psz, maxp, P, dt):
        rng = np.random.RandomState(hash((B, T, H, KH)) % 2**31)
        q, kp, vp, table, base = self._case(rng, B, T, H, KH, hd, psz,
                                            maxp, P, dt)
        ref = paged_attention_chunk_ref(q, kp, vp, table, base)
        out = paged_attention_chunk(q, kp, vp, table, base, interpret=True)
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(ref, np.float32),
            atol=_tol(dt), rtol=_tol(dt))

    def test_t1_matches_decode_kernel(self):
        """The chunk kernel at T=1 equals the single-token decode path
        (query at position base attends to base + 1 tokens)."""
        rng = np.random.RandomState(11)
        B, H, KH, hd, psz, maxp, P = 3, 8, 2, 64, 8, 4, 24
        q, kp, vp, table, base = self._case(rng, B, 1, H, KH, hd, psz,
                                            maxp, P, jnp.float32)
        out = paged_attention_chunk(q, kp, vp, table, base, interpret=True)
        ref = paged_attention_ref(q[:, 0], kp, vp, table, base + 1)
        np.testing.assert_allclose(np.asarray(out[:, 0]), np.asarray(ref),
                                   atol=1e-4, rtol=1e-4)

    def test_causal_within_chunk(self):
        """Row t must ignore chunk tokens written at positions > base+t:
        scrambling the future tokens' K/V leaves earlier rows unchanged."""
        rng = np.random.RandomState(12)
        B, T, H, KH, hd, psz, maxp, P = 1, 6, 4, 2, 64, 8, 3, 12
        q, kp, vp, table, base = self._case(rng, B, T, H, KH, hd, psz,
                                            maxp, P, jnp.float32,
                                            ragged_base=False)
        out1 = paged_attention_chunk_ref(q, kp, vp, table, base)
        # scramble K/V at absolute positions >= base + tcut
        tcut = 3
        kp2, vp2 = np.asarray(kp).copy(), np.asarray(vp).copy()
        tbl = np.asarray(table)
        for t in range(tcut, T):
            pos = int(base[0]) + t
            pid = tbl[0, pos // psz]
            kp2[pid, pos % psz] = 99.0
            vp2[pid, pos % psz] = -99.0
        out2 = paged_attention_chunk_ref(q, jnp.asarray(kp2),
                                         jnp.asarray(vp2), table, base)
        np.testing.assert_allclose(np.asarray(out1[:, :tcut]),
                                   np.asarray(out2[:, :tcut]),
                                   atol=1e-6, rtol=1e-6)
        assert not np.allclose(np.asarray(out1[:, tcut:]),
                               np.asarray(out2[:, tcut:]))

    def test_all_masked_row_outputs_zeros(self):
        """An idle slot (page table all -1, the engine runs every batch
        slot) must output exact zeros from kernel and ref alike — not a
        mean of the clamped fallback page's V."""
        rng = np.random.RandomState(13)
        B, T, H, KH, hd, psz, maxp, P = 2, 4, 4, 2, 64, 8, 3, 12
        q, kp, vp, table, base = self._case(rng, B, T, H, KH, hd, psz,
                                            maxp, P, jnp.float32)
        table = table.at[1].set(-1)          # slot 1: nothing resident
        base = base.at[1].set(0)
        ref = paged_attention_chunk_ref(q, kp, vp, table, base)
        out = paged_attention_chunk(q, kp, vp, table, base, interpret=True)
        assert np.all(np.asarray(ref[1]) == 0.0)
        assert np.all(np.asarray(out[1]) == 0.0)
        np.testing.assert_allclose(np.asarray(out[0]), np.asarray(ref[0]),
                                   atol=1e-4, rtol=1e-4)

    @settings(max_examples=8, deadline=None)
    @given(seed=st.integers(0, 2**16), psz=st.sampled_from([8, 16]),
           T=st.integers(1, 8))
    def test_property_random_chunks(self, seed, psz, T):
        rng = np.random.RandomState(seed)
        B, H, KH, hd, maxp, P = 2, 4, 2, 64, 4, 24
        q, kp, vp, table, base = self._case(rng, B, T, H, KH, hd, psz,
                                            maxp, P, jnp.float32)
        ref = paged_attention_chunk_ref(q, kp, vp, table, base)
        out = paged_attention_chunk(q, kp, vp, table, base, interpret=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=1e-4, rtol=1e-4)


class TestFlashAttention:
    @pytest.mark.parametrize("B,H,S,hd,bq,bk,dt", [
        (2, 3, 512, 64, 256, 256, jnp.float32),
        (1, 2, 256, 128, 128, 64, jnp.float32),
        (2, 2, 256, 128, 128, 128, jnp.bfloat16),
        (1, 1, 128, 64, 64, 128, jnp.float32),
    ])
    def test_vs_ref_causal(self, B, H, S, hd, bq, bk, dt):
        rng = np.random.RandomState(0)
        q = jnp.asarray(rng.randn(B, H, S, hd), dt)
        k = jnp.asarray(rng.randn(B, H, S, hd), dt)
        v = jnp.asarray(rng.randn(B, H, S, hd), dt)
        ref = flash_attention_ref(q, k, v, causal=True)
        out = flash_attention(q, k, v, causal=True, block_q=bq, block_k=bk,
                              interpret=True)
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(ref, np.float32),
            atol=_tol(dt), rtol=_tol(dt))

    def test_bidirectional(self):
        rng = np.random.RandomState(1)
        q = jnp.asarray(rng.randn(1, 2, 256, 64), jnp.float32)
        k = jnp.asarray(rng.randn(1, 2, 256, 64), jnp.float32)
        v = jnp.asarray(rng.randn(1, 2, 256, 64), jnp.float32)
        ref = flash_attention_ref(q, k, v, causal=False)
        out = flash_attention(q, k, v, causal=False, block_q=128,
                              block_k=128, interpret=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=1e-4, rtol=1e-4)


class TestSSDScan:
    @pytest.mark.parametrize("B,S,H,P,N,chunk", [
        (2, 256, 4, 64, 32, 64),
        (1, 128, 2, 32, 64, 128),
        (1, 128, 1, 64, 128, 32),
    ])
    def test_vs_sequential_recurrence(self, B, S, H, P, N, chunk):
        rng = np.random.RandomState(2)
        x = jnp.asarray(rng.randn(B, S, H, P) * 0.5, jnp.float32)
        dt = jnp.asarray(rng.rand(B, S, H) * 0.5 + 0.1, jnp.float32)
        A = jnp.asarray(-np.abs(rng.randn(H)) * 0.5, jnp.float32)
        Bm = jnp.asarray(rng.randn(B, S, N) * 0.3, jnp.float32)
        Cm = jnp.asarray(rng.randn(B, S, N) * 0.3, jnp.float32)
        D = jnp.asarray(rng.randn(H) * 0.1, jnp.float32)
        yr, hr = ssd_scan_ref(x, dt, A, Bm, Cm, D)
        yk, hk = ssd_scan(x, dt, A, Bm, Cm, D, chunk=chunk, interpret=True)
        np.testing.assert_allclose(np.asarray(yk), np.asarray(yr),
                                   atol=2e-3, rtol=2e-3)
        np.testing.assert_allclose(np.asarray(hk.transpose(0, 1, 3, 2)),
                                   np.asarray(hr), atol=2e-3, rtol=2e-3)

    def test_matches_model_ssd_chunked(self):
        """The model-layer chunked SSD and the kernel agree."""
        from repro.models.ssm import ssd_chunked
        rng = np.random.RandomState(3)
        B, S, H, P, N = 1, 128, 2, 32, 16
        x = jnp.asarray(rng.randn(B, S, H, P) * 0.5, jnp.float32)
        dt = jnp.asarray(rng.rand(B, S, H) * 0.5 + 0.1, jnp.float32)
        A = jnp.asarray(-np.abs(rng.randn(H)) * 0.5, jnp.float32)
        Bm = jnp.asarray(rng.randn(B, S, N) * 0.3, jnp.float32)
        Cm = jnp.asarray(rng.randn(B, S, N) * 0.3, jnp.float32)
        D = jnp.asarray(rng.randn(H) * 0.1, jnp.float32)
        ym, hm = ssd_chunked(x, dt, A, Bm, Cm, D, chunk=64)
        yk, hk = ssd_scan(x, dt, A, Bm, Cm, D, chunk=64, interpret=True)
        np.testing.assert_allclose(np.asarray(yk), np.asarray(ym),
                                   atol=2e-3, rtol=2e-3)
        np.testing.assert_allclose(
            np.asarray(hk.transpose(0, 1, 3, 2)), np.asarray(hm),
            atol=2e-3, rtol=2e-3)


class TestRGLRU:
    @pytest.mark.parametrize("B,S,d", [(2, 256, 256), (1, 128, 512),
                                       (3, 128, 128)])
    def test_vs_sequential(self, B, S, d):
        rng = np.random.RandomState(4)
        a = jnp.asarray(rng.rand(B, S, d) * 0.9, jnp.float32)
        b = jnp.asarray(rng.randn(B, S, d) * 0.5, jnp.float32)
        hr, hfr = rg_lru_ref(a, b)
        hk, hfk = rg_lru(a, b, interpret=True)
        np.testing.assert_allclose(np.asarray(hk), np.asarray(hr),
                                   atol=1e-4, rtol=1e-4)
        np.testing.assert_allclose(np.asarray(hfk), np.asarray(hfr),
                                   atol=1e-4, rtol=1e-4)

    def test_initial_state(self):
        rng = np.random.RandomState(5)
        B, S, d = 2, 128, 256
        a = jnp.asarray(rng.rand(B, S, d) * 0.9, jnp.float32)
        b = jnp.asarray(rng.randn(B, S, d) * 0.5, jnp.float32)
        h0 = jnp.asarray(rng.randn(B, d), jnp.float32)
        hr, _ = rg_lru_ref(a, b, h0)
        hk, _ = rg_lru(a, b, h0, interpret=True)
        np.testing.assert_allclose(np.asarray(hk), np.asarray(hr),
                                   atol=1e-4, rtol=1e-4)
