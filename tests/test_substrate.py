"""Substrate tests: data, checkpoint, fault tolerance, elastic, compression."""

import numpy as np
import jax.numpy as jnp

from repro.checkpoint.ckpt import Checkpointer
from repro.data.pipeline import DataConfig, Prefetcher, TokenStream
from repro.parallel import compress
from repro.parallel.partition import param_specs
from repro.runtime.elastic import plan_for
from repro.runtime.fault import FailureInjector, FaultTolerantLoop


class TestData:
    def test_deterministic_across_restarts(self):
        cfg = DataConfig(vocab=1000, seq_len=32, global_batch=4, seed=7)
        s1, s2 = TokenStream(cfg), TokenStream(cfg)
        for step in (0, 5, 17):
            np.testing.assert_array_equal(
                s1.batch_at(step)["tokens"], s2.batch_at(step)["tokens"])

    def test_host_sharding_partitions_batch(self):
        full = TokenStream(DataConfig(1000, 32, 8, seed=3))
        parts = [TokenStream(DataConfig(1000, 32, 8, seed=3,
                                        n_hosts=4, host_id=h))
                 for h in range(4)]
        got = np.concatenate([p.batch_at(2)["tokens"] for p in parts])
        np.testing.assert_array_equal(got, full.batch_at(2)["tokens"])

    def test_labels_shifted(self):
        s = TokenStream(DataConfig(1000, 16, 2))
        b = s.batch_at(0)
        assert b["tokens"].shape == (2, 16) and b["labels"].shape == (2, 16)

    def test_prefetcher(self):
        s = TokenStream(DataConfig(100, 8, 2))
        p = Prefetcher(s)
        np.testing.assert_array_equal(p.get()["tokens"],
                                      s.batch_at(0)["tokens"])
        np.testing.assert_array_equal(p.get()["tokens"],
                                      s.batch_at(1)["tokens"])


class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        ck = Checkpointer(tmp_path)
        state = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
                 "b": {"c": jnp.ones((4,), jnp.bfloat16)}}
        ck.save(3, state)
        assert ck.latest_step() == 3
        got = ck.restore(3, state)
        np.testing.assert_array_equal(np.asarray(got["a"]),
                                      np.asarray(state["a"]))
        assert got["b"]["c"].dtype == jnp.bfloat16

    def test_tuple_and_namedtuple_state(self, tmp_path):
        from repro.optim import adamw
        params = {"w": jnp.ones((3, 3))}
        opt = adamw.init(params)
        ck = Checkpointer(tmp_path)
        ck.save(0, (params, opt))
        p2, o2 = ck.restore(0, (params, opt))
        assert int(o2.step) == 0
        np.testing.assert_array_equal(np.asarray(p2["w"]),
                                      np.asarray(params["w"]))

    def test_incomplete_checkpoint_ignored(self, tmp_path):
        ck = Checkpointer(tmp_path)
        ck.save(1, {"a": jnp.zeros(2)})
        (tmp_path / "step_00000009").mkdir()   # no INDEX.json
        assert ck.latest_step() == 1

    def test_gc_keeps_recent(self, tmp_path):
        ck = Checkpointer(tmp_path, keep=2)
        for s in range(5):
            ck.save(s, {"a": jnp.zeros(2)})
        steps = sorted(p.name for p in tmp_path.glob("step_*"))
        assert len(steps) == 2 and steps[-1] == "step_00000004"

    def test_async_save(self, tmp_path):
        ck = Checkpointer(tmp_path)
        ck.save(7, {"a": jnp.arange(10)}, async_=True)
        ck.wait()
        assert ck.latest_step() == 7


class TestFaultTolerance:
    def _loop(self, tmp_path, injector=None, n=20, save_every=5):
        trace = []

        def step_fn(state, batch):
            return {"x": state["x"] + batch}

        def batch_fn(step):
            trace.append(step)
            return jnp.float32(step)

        loop = FaultTolerantLoop(step_fn, batch_fn, Checkpointer(tmp_path),
                                 save_every=save_every, injector=injector)
        out = loop.run({"x": jnp.float32(0)}, n)
        return out, loop, trace

    def test_no_failure(self, tmp_path):
        out, loop, _ = self._loop(tmp_path)
        assert float(out["x"]) == sum(range(20))
        assert loop.stats.restarts == 0

    def test_restart_resumes_correctly(self, tmp_path):
        inj = FailureInjector(fail_at={13: RuntimeError("boom")})
        out, loop, _ = self._loop(tmp_path, inj)
        # the state after recovery must be EXACTLY the no-failure result
        assert float(out["x"]) == sum(range(20))
        assert loop.stats.restarts == 1

    def test_multiple_failures(self, tmp_path):
        inj = FailureInjector(fail_at={7: RuntimeError("a"),
                                       12: RuntimeError("b"),
                                       18: RuntimeError("c")})
        out, loop, _ = self._loop(tmp_path, inj)
        assert float(out["x"]) == sum(range(20))
        assert loop.stats.restarts == 3

    def test_straggler_watchdog(self, tmp_path):
        inj = FailureInjector(slow_at={15: 0.15})
        hits = []
        loop = FaultTolerantLoop(
            lambda s, b: s, lambda i: None, Checkpointer(tmp_path),
            save_every=100, injector=inj, straggler_factor=3.0,
            on_straggler=lambda step, dt: hits.append(step))
        loop.run({"x": jnp.float32(0)}, 20)
        assert loop.stats.straggler_steps >= 1 and 15 in hits


class TestElastic:
    def test_full_mesh(self):
        p = plan_for(256, model_parallel=16, full_data_parallel=16)
        assert p.mesh_shape == (16, 16) and p.grad_accum == 1

    def test_lost_devices_keep_model_axis(self):
        p = plan_for(192, model_parallel=16, full_data_parallel=16)
        assert p.mesh_shape == (12, 16)
        assert p.grad_accum == 2   # 16/12 -> ceil = 2 keeps global batch

    def test_odd_counts_shrink_model_axis(self):
        p = plan_for(24, model_parallel=16, full_data_parallel=16)
        assert p.mesh_shape[1] in (8, 4, 2, 1)
        assert p.mesh_shape[0] * p.mesh_shape[1] == 24

    def test_multi_pod(self):
        p = plan_for(512, model_parallel=16, full_data_parallel=16, pods=2)
        assert p.mesh_shape == (2, 16, 16)


class TestCompression:
    def test_roundtrip_accuracy(self):
        rng = np.random.RandomState(0)
        g = jnp.asarray(rng.randn(64, 64) * 1e-3, jnp.float32)
        q, s = compress.quantize(g)
        back = compress.dequantize(q, s)
        assert float(jnp.max(jnp.abs(back - g))) <= float(s) * 0.51

    def test_error_feedback_preserves_sum(self):
        """EF: the *accumulated* applied gradient converges to the truth."""
        rng = np.random.RandomState(1)
        grads = {"w": jnp.asarray(rng.randn(32, 32), jnp.float32)}
        ef = compress.init_error_feedback(grads)
        applied = jnp.zeros((32, 32))
        for _ in range(30):
            out, ef = compress.compressed_grads(grads, ef)
            applied = applied + out["w"]
        target = grads["w"] * 30
        rel = float(jnp.linalg.norm(applied - target) /
                    jnp.linalg.norm(target))
        assert rel < 0.01, rel

    def test_payload_is_int8(self):
        grads = {"w": jnp.ones((8, 8), jnp.float32)}
        ef = compress.init_error_feedback(grads)
        qs, ss, _ = compress.compress_tree(grads, ef)
        assert qs["w"].dtype == jnp.int8


class TestPartitionRules:
    def test_divisibility_guard(self):
        import jax as j
        from repro.models.layers import ParamDef
        try:                                  # jax >= 0.5 signature
            mesh = j.sharding.AbstractMesh((1, 2), ("data", "model"))
        except TypeError:                     # jax 0.4.x: (name, size) pairs
            mesh = j.sharding.AbstractMesh((("data", 1), ("model", 2)))
        # 6 heads not divisible by 2 -> replicated... 6 % 2 == 0 -> sharded
        d = ParamDef((8, 6, 4), ("embed", "heads", "head_dim"))
        spec = param_specs({"w": d}, mesh)["w"]
        assert spec[1] == "model"
        d2 = ParamDef((8, 5, 4), ("embed", "heads", "head_dim"))
        spec2 = param_specs({"w": d2}, mesh)["w"]
        assert spec2[1] is None   # 5 % 2 != 0 -> replicated
