"""Size-classed allocation plane tests (DESIGN.md §14) and the PR's
allocator accounting / sizing bugfix regressions.

* Differential conformance: a randomized MULTI-CLASS op storm (torn
  per-class rebalance windows included) replayed through the jax
  :mod:`repro.core.classed_pool` and the sequential classed witness
  (:class:`repro.core.refpool.RefClassedPool`) — identical grants,
  identical metered spills, identical final stacks per class per shard;
  the recorded history passes the class-resolved linearizability
  checkers.
* Crash/reconcile mid-storm: the classed ``audit_and_reconcile``
  rebuilds every class, the witness is re-anchored to the (deterministic)
  reconciled state, and the storm continues conformant.
* Serving token identity: a paged-only model served with
  ``size_classes=2`` emits bit-identical tokens and class-0 counters to
  the single-class engine.
* §4.2 sizing regression: a pool that passes ``create``'s
  one-batch-per-lane assert but lacks the pool-wide ``3*ell*L`` slack
  demonstrably runs a lane dry; ``validate_plan`` rejects it at plan
  time (and admits it only under ``degraded_ok``).
* Reconcile recount narrowing regression: a pathologically shared page
  (more keeping rows than int16 can count) clamps to the dtype max with
  a report entry instead of silently wrapping negative ("free").
"""

import random

import numpy as np
import jax
import jax.numpy as jnp
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                                    # pragma: no cover
    import sys
    sys.path.insert(0, "tests")
    from _hypothesis_fallback import given, settings, st

from repro import models
from repro.configs import get_config, smoke_config
from repro.core import classed_pool, hier_pool, refpool
from repro.core.classed_pool import CLS_KV, CLS_STATE, ClassSpec
from repro.core.linearizability import (check_classed_batch_history,
                                        check_cross_class_frees,
                                        split_history_by_class)
from repro.core.sim import OpRecord
from repro.serving.engine import Request, ServingEngine
from repro.serving.telemetry import (CTR_ALLOC, CTR_FREED, N_CTR, ctr_key)

DP = 2
# three deliberately different classes: coarse (KV-like), fine
# (bounded-state), and the read-only expert-weight class (§15) — the
# storm drives all three, so every op mix, torn window, and crash/
# reconcile below exercises the expert class too
SPECS = (ClassSpec(page_size=8, num_blocks=48, num_lanes=3, ell=2),
         ClassSpec(page_size=2, num_blocks=30, num_lanes=3, ell=2),
         ClassSpec(page_size=64, num_blocks=36, num_lanes=3, ell=2))
LANES, KMAX = 3, 3


def _pad(row, k):
    return row + [-1] * (k - len(row))


class ClassedStorm:
    """Drives one randomized multi-class trace through the jax classed
    pool and the sequential witness in lockstep, asserting grant/spill
    identity per op and recording a class/shard-tagged history for the
    class-resolved linearizability checkers."""

    def __init__(self, rng, pool=None, refs=None):
        self.rng = rng
        self.pool = pool if pool is not None \
            else classed_pool.create_dp(DP, SPECS)
        self.refs = refs if refs is not None \
            else refpool.create_classed_dp(DP, SPECS)
        self.held = [[[] for _ in range(DP)] for _ in SPECS]
        self.extra = [[[] for _ in range(DP)] for _ in SPECS]
        self.torn = []                       # classes drained, not refilled
        self.history = []
        self._opid = 0
        self._step = 0

    # ---------------------------------------------------------- history
    def _rec(self, name, cls, shard, arg=None, result=None):
        self._opid += 1
        self._step += 1
        self.history.append(OpRecord(
            opid=self._opid, pid=shard, name=name, arg=arg,
            invoke_step=self._step, result=result,
            response_step=self._step,
            meta={"cls": cls, "shard": shard}))

    # ------------------------------------------------------------- ops
    def run(self, steps):
        for _ in range(steps):
            cls = self.rng.randrange(len(SPECS))
            op = self.rng.choice(["alloc", "alloc_n", "alloc_shared",
                                  "addref", "free_n", "free_n",
                                  "free_shared", "rebalance", "torn"])
            getattr(self, "_op_" + op)(cls)
            self._check_conservation()

    def _op_alloc(self, cls):
        want = np.asarray([[self.rng.random() < 0.7
                            for _ in range(LANES)] for _ in range(DP)])
        self.pool, ids = classed_pool.alloc_n_dp(
            self.pool, cls, jnp.asarray(want, jnp.int32), 1)
        got = np.asarray(ids)
        for d in range(DP):
            ref_rows = self.refs[d].alloc_n(cls, want[d].astype(int), 1)
            grants = []
            for ln in range(LANES):
                assert got[d, ln].tolist() == _pad(ref_rows[ln], 1), (
                    f"cls {cls} shard {d}: alloc diverged")
                self.held[cls][d] += ref_rows[ln]
                grants += ref_rows[ln]
            self._rec("alloc_n", cls, d, result=grants)

    def _op_alloc_n(self, cls):
        counts = np.asarray([[self.rng.randint(0, KMAX)
                              for _ in range(LANES)] for _ in range(DP)],
                            np.int32)
        self.pool, ids = classed_pool.alloc_n_dp(
            self.pool, cls, jnp.asarray(counts), KMAX)
        got = np.asarray(ids)
        for d in range(DP):
            ref_rows = self.refs[d].alloc_n(cls, counts[d], KMAX)
            grants = []
            for ln in range(LANES):
                assert got[d, ln].tolist() == _pad(ref_rows[ln], KMAX), (
                    f"cls {cls} shard {d}: alloc_n diverged")
                self.held[cls][d] += ref_rows[ln]
                grants += ref_rows[ln]
            self._rec("alloc_n", cls, d, result=grants)

    def _op_alloc_shared(self, cls):
        counts = np.asarray([[self.rng.randint(0, 2)
                              for _ in range(LANES)] for _ in range(DP)],
                            np.int32)
        self.pool, ids = classed_pool.alloc_from_shared_dp(
            self.pool, cls, jnp.asarray(counts), KMAX)
        got = np.asarray(ids)
        for d in range(DP):
            ref_rows = self.refs[d].alloc_from_shared(cls, counts[d], KMAX)
            grants = []
            for ln in range(LANES):
                assert got[d, ln].tolist() == _pad(ref_rows[ln], KMAX), (
                    f"cls {cls} shard {d}: shared alloc diverged")
                self.held[cls][d] += ref_rows[ln]
                grants += ref_rows[ln]
            self._rec("alloc_n", cls, d, result=grants)

    def _op_addref(self, cls):
        rows = []
        for d in range(DP):
            picks = ([self.rng.choice(self.held[cls][d])]
                     if self.held[cls][d] and self.rng.random() < 0.8
                     else [])
            self.extra[cls][d] += picks
            self.refs[d].addref(cls, _pad(picks, 1))
            rows.append(_pad(picks, 1))
        self.pool = classed_pool.addref_dp(
            self.pool, cls, jnp.asarray(rows, jnp.int32))

    def _op_free_n(self, cls):
        rows_dp = []
        freed = [[] for _ in range(DP)]
        for d in range(DP):
            rows = [[] for _ in range(LANES)]
            k = self.rng.randint(0, min(3, len(self.held[cls][d])))
            for _ in range(k):
                b = self.held[cls][d].pop(
                    self.rng.randrange(len(self.held[cls][d])))
                rows[self.rng.randrange(LANES)].append(b)
                freed[d].append(b)
            rows_dp.append([_pad(r, KMAX) for r in rows])
        self.pool, spilled = classed_pool.free_n_metered_dp(
            self.pool, cls, jnp.asarray(rows_dp, jnp.int32))
        sp = np.asarray(spilled)
        for d in range(DP):
            ref_spill = self.refs[d].free_n(cls, rows_dp[d])
            assert int(sp[d]) == ref_spill, (
                f"cls {cls} shard {d}: metered spill {int(sp[d])} != "
                f"witness {ref_spill}")
            self._rec("free_n", cls, d, arg=freed[d])

    def _op_free_shared(self, cls):
        rows = []
        freed = [[] for _ in range(DP)]
        for d in range(DP):
            picks = []
            if self.extra[cls][d] and self.rng.random() < 0.8:
                picks.append(self.extra[cls][d].pop())
            rows.append(_pad(picks, 1))
            freed[d] = picks
        self.pool = classed_pool.free_shared_dp(
            self.pool, cls, jnp.asarray(rows, jnp.int32))
        for d in range(DP):
            self.refs[d].free_shared(cls, rows[d])
            # extra-ref drop, not a live release: not a history "free"

    def _op_rebalance(self, cls):
        # close any torn window first (refill what was drained), then a
        # full all-class rebalance — the serve step's fused form
        if self.torn:
            c = self.torn.pop()
            self.pool = classed_pool.rebalance_refill_dp(self.pool, c)
            for d in range(DP):
                self.refs[d].rebalance_refill(c)
        self.pool = classed_pool.rebalance_dp(self.pool)
        for d in range(DP):
            self.refs[d].rebalance()

    def _op_torn(self, cls):
        # torn per-class window: drain ONE class and leave it un-refilled
        # for a while (chaos.py plants exactly this before a host crash)
        if cls in self.torn:
            return
        self.pool = classed_pool.rebalance_drain_dp(self.pool, cls)
        for d in range(DP):
            self.refs[d].rebalance_drain(cls)
        self.torn.append(cls)

    # ------------------------------------------------------ invariants
    def _check_conservation(self):
        for c, spec in enumerate(SPECS):
            free_s = np.asarray(classed_pool.free_per_shard(self.pool, c))
            live_s = np.asarray(classed_pool.live_per_shard(self.pool, c))
            for d in range(DP):
                assert free_s[d] + live_s[d] == spec.num_blocks, (
                    f"class {c} shard {d}: conservation broke")

    def check_conformance(self):
        for d in range(DP):
            msg = refpool.conforms_classed(self.refs[d], self.pool, d)
            assert msg is None, f"shard {d}: {msg}"


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 9999))
def test_classed_storm_conforms_and_linearizes(seed):
    storm = ClassedStorm(random.Random(seed))
    for _ in range(4):
        storm.run(15)
        storm.check_conformance()
    # the class-resolved checkers accept the whole tagged history
    assert check_classed_batch_history(storm.history) == []
    by_cls = split_history_by_class(storm.history)
    assert set(by_cls) <= {0, 1, 2}


def test_classed_storm_crash_reconcile_then_conforms():
    """Mid-storm crash: reconcile every class from kept page-table rows,
    re-anchor the witness to the (deterministic) reconciled state, and
    the storm continues in exact conformance."""
    rng = random.Random(7)
    storm = ClassedStorm(rng)
    storm.run(30)

    # the crash keeps a random subset of held blocks per class per shard
    keep, orphans = [], 0
    for c in range(len(SPECS)):
        width = max(1, max(len(storm.held[c][d]) for d in range(DP)))
        tab = np.full((DP, width), -1, np.int32)
        for d in range(DP):
            kept = [b for b in storm.held[c][d] if rng.random() < 0.5]
            # blocks with extra refs must be kept once per reference to
            # reproduce their refcount; keep it simple: drop extras too
            kept = [b for b in kept if b not in storm.extra[c][d]]
            dropped = [b for b in set(storm.held[c][d]) - set(kept)]
            orphans += len(set(dropped))
            tab[d, :len(kept)] = kept
            storm.held[c][d] = list(kept)
            storm.extra[c][d] = []
        keep.append(tab)

    pool, report = classed_pool.audit_and_reconcile(
        storm.pool, keep_tables=tuple(keep))
    assert report["conserved"]
    assert report["never_dry"]
    assert report["reclaimed"] >= orphans          # extras reclaim too
    assert len(report["classes"]) == len(SPECS)

    # re-anchor the witness: reconcile is deterministic (ascending free
    # ids, ell per lane, remainder reversed on the shared stack)
    sh = jax.tree.map(np.asarray, pool)
    refs = refpool.create_classed_dp(DP, SPECS)
    for d in range(DP):
        for c, rc in enumerate(refs[d].classes):
            hp = sh.classes[c]
            top = int(hp.shared.top[d])
            rc.shared = [int(x) for x in hp.shared.free_ids[d][:top]]
            rc.lanes = [
                [int(x) for x in hp.private_ids[d][i][:int(t)]]
                for i, t in enumerate(hp.private_top[d])]
            rc.refcount = [int(x) for x in hp.shared.refcount[d]]
    storm.pool, storm.refs, storm.torn = pool, refs, []
    storm.check_conformance()
    storm.run(30)
    storm.check_conformance()


def test_checker_flags_cross_class_theft():
    """A grant in class 0 freed through class 1's allocator is flagged
    by the class-resolved checkers (and invisible to a per-class-only
    split — the exact reason the cross-class pass exists)."""
    h = [
        OpRecord(opid=1, pid=0, name="alloc_n", arg=None, result=[5],
                 invoke_step=1, response_step=2,
                 meta={"cls": 0, "shard": 0}),
        OpRecord(opid=2, pid=0, name="free_n", arg=[5], result=None,
                 invoke_step=3, response_step=4,
                 meta={"cls": 1, "shard": 0}),
    ]
    errs = check_cross_class_frees(h)
    assert errs and "cross-class theft" in errs[0]
    assert check_classed_batch_history(h) != []
    # the same free in its own class is clean
    h[1].meta["cls"] = 0
    assert check_cross_class_frees(h) == []
    assert check_classed_batch_history(h) == []
    # the expert class (cls 2) is covered by the same pass: a KV grant
    # freed through CLS_EXPERT's allocator is theft too
    h[1].meta["cls"] = 2
    errs = check_cross_class_frees(h)
    assert errs and "cross-class theft" in errs[0]


# ==================================================== serving identity


@pytest.fixture(scope="module")
def engine_setup():
    cfg = smoke_config(get_config("olmo-1b"))
    params = models.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _drive(eng, prompts):
    reqs = [Request(i, prompt=list(p), max_new_tokens=5)
            for i, p in enumerate(prompts)]
    for r in reqs:
        eng.submit(r)
    eng.run(max_steps=400)
    assert all(r.done for r in reqs)
    return [r.out_tokens for r in reqs]


def test_paged_only_token_identity_single_vs_two_class(engine_setup):
    """A paged-only model (state_blocks_per_slot == 0) served under
    ``size_classes=2`` is bit-identical to the single-class engine:
    same tokens, same class-0 device counters, zero class-1 traffic —
    the class axis is pure plumbing until a consumer routes to it."""
    cfg, params = engine_setup
    rng = np.random.RandomState(11)
    prompts = [list(rng.randint(1, 255, rng.randint(4, 14)))
               for _ in range(8)]

    eng1 = ServingEngine(cfg, params, dp=2, b_local=2, max_len=64,
                         prefix_sharing=False)
    out1 = _drive(eng1, prompts)
    eng2 = ServingEngine(cfg, params, dp=2, b_local=2, max_len=64,
                         prefix_sharing=False, size_classes=2)
    assert eng2.n_classes == 2
    out2 = _drive(eng2, prompts)

    assert out1 == out2, "size classes changed tokens on a paged model"
    for row in (CTR_ALLOC, CTR_FREED):
        np.testing.assert_array_equal(
            eng1.telemetry.shard[ctr_key(row, 0)],
            eng2.telemetry.shard[ctr_key(row, 0)],
            err_msg=f"class-0 counter row {row} diverged")
    # class 1 exists but nothing routed to it on a paged-only model
    assert eng2.telemetry.shard[ctr_key(CTR_ALLOC, 1)].sum() == 0
    assert int(np.asarray(
        classed_pool.live_per_shard(eng2.state.pool, CLS_STATE)).sum()) == 0
    assert eng1.page_occupancy() == 0.0 and eng2.page_occupancy() == 0.0


def test_two_class_counter_block_shape(engine_setup):
    """The packed status grows exactly one extra N_CTR block per class
    and the telemetry facade accounts both classes."""
    cfg, params = engine_setup
    eng = ServingEngine(cfg, params, dp=1, b_local=2, max_len=64,
                        size_classes=2)
    rng = np.random.RandomState(12)
    eng.submit(Request(0, prompt=list(rng.randint(1, 255, 6)),
                       max_new_tokens=4))
    eng.run(max_steps=100)
    assert eng.telemetry.n_classes == 2
    assert eng.telemetry.last_block.shape == (2 * N_CTR, 1)
    assert ctr_key(CTR_ALLOC, 1) == "alloc_pages_c1"
    assert ctr_key(CTR_ALLOC, 0) == "alloc_pages"


# ========================================== §4.2 plan validation (bugfix)


def test_validate_plan_catches_tight_config():
    """num_blocks = 6, lanes = 2, ell = 2 passes ``create``'s
    one-batch-per-lane assert, yet with max_live = 4 the §4.2 slack
    3*ell*L = 12 is unavailable — a lane demonstrably runs dry between
    rebalances.  ``validate_plan`` rejects the plan; ``degraded_ok``
    admits it flagged."""
    with pytest.raises(ValueError, match="run dry"):
        hier_pool.validate_plan(6, 2, 2, max_live=4)
    assert hier_pool.validate_plan(6, 2, 2, max_live=4,
                                   degraded_ok=True) is False
    assert hier_pool.validate_plan(4 + 12, 2, 2, max_live=4) is True

    # the dry lane is real, not theoretical: drain lane 0 twice with
    # max_live=4 held and the refill has nothing to grant
    pool = hier_pool.create(6, 2, 2)          # passes create's assert
    pool, ids = hier_pool.alloc_n(pool, jnp.asarray([2, 0], jnp.int32), 2)
    assert (np.asarray(ids)[0] >= 0).all()
    pool = hier_pool.rebalance(pool)          # refills lane 0 from shared
    pool, ids = hier_pool.alloc_n(pool, jnp.asarray([2, 0], jnp.int32), 2)
    assert (np.asarray(ids)[0] >= 0).all()    # max_live = 4 reached
    pool = hier_pool.rebalance(pool)          # shared is empty: no refill
    tops = np.asarray(pool.private_top)
    ell = hier_pool.lane_ell(pool)
    assert tops[0] < ell, "lane should have run dry (the §4.2 violation)"
    pool, ids = hier_pool.alloc_n(pool, jnp.asarray([1, 0], jnp.int32), 1)
    assert int(np.asarray(ids)[0, 0]) == -1, (
        "dry lane granted — expected a NULL grant on the hot path")
    # free blocks exist (lane 1 holds 2): the failure is distribution,
    # exactly what the plan-time slack requirement prevents
    assert int(hier_pool.total_free(pool)) > 0


def test_classed_validate_specs_names_failing_class():
    ok = classed_pool.validate_specs(
        SPECS, max_live=[30, 12, 16], degraded_ok=False)
    assert ok == (True, True, True)
    with pytest.raises(ValueError, match="class 1"):
        classed_pool.validate_specs(SPECS, max_live=[30, 29, 16])
    flags = classed_pool.validate_specs(SPECS, max_live=[30, 29, 16],
                                        degraded_ok=True)
    assert flags == (True, False, True)


def test_engine_validates_pool_plan(engine_setup):
    """The serving engine runs the §4.2 plan validation over its whole
    class vector at construction and records full provisioning; the
    sizing rule (`pool_class_specs`) always passes it by construction,
    so the check is the guard rail for future sizing changes."""
    cfg, params = engine_setup
    eng = ServingEngine(cfg, params, dp=1, b_local=2, max_len=64)
    assert eng.pool_provisioned == (True,) * eng.n_classes
    eng2 = ServingEngine(cfg, params, dp=1, b_local=2, max_len=64,
                         size_classes=2, degraded_pool_ok=True)
    assert eng2.pool_provisioned == (True,) * eng2.n_classes


# ==================================== reconcile int16 narrowing (bugfix)


def test_reconcile_clamps_pathological_refcount():
    """> int16-max keeping rows reference one page: the int64 recount
    must clamp at the dtype max (page stays live, reported) instead of
    silently wrapping negative on the narrow (page turns 'free' and
    gets double-granted)."""
    pool = hier_pool.create(8, 2, 2)
    cap = np.iinfo(np.asarray(pool.shared.refcount).dtype).max
    rows = np.zeros((cap + 5, 1), np.int32)        # all reference block 0
    new_pool, report = hier_pool.audit_and_reconcile(pool, keep_tables=rows)
    assert report["conserved"]
    assert report["clamped"] == 1
    assert report["shards"][0]["clamped"] == [0]
    rc = np.asarray(new_pool.shared.refcount)
    assert rc[0] == cap, "clamp must pin to the dtype max"
    assert rc[0] > 0, "the pathologically shared page must stay live"
    # conservation: block 0 live, the other 7 free
    assert int(hier_pool.num_live(new_pool)) == 1
    assert int(hier_pool.total_free(new_pool)) == 7

    # classed merge surfaces the clamp count too
    cpool = classed_pool.create(
        (ClassSpec(8, 8, 2, 2), ClassSpec(2, 8, 2, 2)))
    _, rep = classed_pool.audit_and_reconcile(
        cpool, keep_tables=(rows, None))
    assert rep["clamped"] == 1
    assert rep["classes"][0]["clamped"] == 1
    assert rep["classes"][1]["clamped"] == 0
