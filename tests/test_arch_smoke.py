"""Per-architecture smoke tests (deliverable f).

For each of the 10 assigned architectures: instantiate the REDUCED
same-family config (configs.smoke_config), run one forward/train step on
CPU asserting output shapes + no NaNs, and check the serving path
(prefill -> paged/ring/recurrent decode) reproduces the one-shot forward
logits exactly.  The FULL configs are exercised by the dry-run only.
"""

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import models
from repro.configs import get_config, list_archs, smoke_config
from repro.configs.base import MoEConfig
from repro.models.decode_init import empty_decode_state, load_prefill
from repro.models.layers import logits_apply
from repro.optim import adamw

ARCHS = list_archs()


def _batch(cfg, B, S, rng):
    batch = {
        "tokens": jnp.asarray(rng.randint(1, cfg.vocab, (B, S)), jnp.int32),
        "labels": jnp.asarray(rng.randint(1, cfg.vocab, (B, S)), jnp.int32),
    }
    if cfg.arch_kind == "vlm":
        batch["img_embeds"] = jnp.asarray(
            rng.randn(B, cfg.img_tokens, cfg.d_model), jnp.float32)
    if cfg.arch_kind == "encdec":
        batch["enc_embeds"] = jnp.asarray(
            rng.randn(B, cfg.enc_len, cfg.d_model), jnp.float32)
    return batch


def test_all_archs_registered():
    assert len(ARCHS) == 10


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_and_train_step(arch):
    cfg = smoke_config(get_config(arch))
    rng = np.random.RandomState(0)
    params = models.init_params(cfg, jax.random.PRNGKey(0))
    B, S = 2, 32
    batch = _batch(cfg, B, S, rng)

    # forward: shapes + finite
    x = models.forward_train(cfg, params, batch["tokens"], extra=batch,
                             remat=False)
    S_out = S + (cfg.img_tokens if cfg.arch_kind == "vlm" else 0)
    assert x.shape == (B, S_out, cfg.d_model)
    assert bool(jnp.all(jnp.isfinite(x.astype(jnp.float32))))

    # one full train step: loss finite, params updated, no NaNs
    opt = adamw.init(params)
    ocfg = adamw.AdamWConfig(warmup_steps=2, decay_steps=10)

    loss, grads = jax.value_and_grad(
        lambda p: models.loss_fn(cfg, p, batch))(params)
    assert bool(jnp.isfinite(loss))
    gn = adamw.global_norm(grads)
    assert bool(jnp.isfinite(gn)), f"{arch}: NaN gradients"
    new_params, _, _ = adamw.apply(ocfg, opt, grads, params)
    for leaf in jax.tree.leaves(new_params):
        assert bool(jnp.all(jnp.isfinite(leaf.astype(jnp.float32))))


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_consistency(arch):
    """Paged/ring/recurrent decode == one-shot forward, per arch."""
    cfg = smoke_config(get_config(arch))
    if cfg.moe:   # no-drop capacity so the comparison is exact
        cfg = dataclasses.replace(cfg, moe=MoEConfig(
            cfg.moe.num_experts, cfg.moe.top_k,
            float(cfg.moe.num_experts)))
    rng = np.random.RandomState(1)
    params = models.init_params(cfg, jax.random.PRNGKey(1))
    B, T0, T1, dp, bl = 4, 16, 4, 2, 2
    toks = jnp.asarray(rng.randint(1, cfg.vocab, (B, T0 + T1)), jnp.int32)
    extra = {k: v for k, v in _batch(cfg, B, T0, rng).items()
             if k not in ("tokens", "labels")}

    x_full = models.forward_train(cfg, params, toks, extra=extra, remat=False)
    if cfg.arch_kind == "vlm":
        x_full = x_full[:, cfg.img_tokens:]
    logits_full = logits_apply(cfg, params["embed"], x_full)

    batch = dict(extra)
    batch["tokens"] = toks[:, :T0]
    logits_p, caches = models.prefill(cfg, params, batch)
    plen = T0 + (cfg.img_tokens if cfg.arch_kind == "vlm" else 0)
    state = empty_decode_state(cfg, dp, bl, max_len=64)
    state = load_prefill(cfg, state, caches, plen)

    errs = [float(jnp.max(jnp.abs(logits_p - logits_full[:, T0 - 1])))]
    for t in range(T1 - 1):
        tok = toks[:, T0 + t].reshape(dp, bl)
        logits_d, state = models.decode_step(cfg, params, tok, state)
        ref = logits_full[:, T0 + t].reshape(dp, bl, -1)
        errs.append(float(jnp.max(jnp.abs(logits_d - ref))))
    assert max(errs) < 2e-3, f"{arch}: decode diverges {max(errs):.2e}"


@pytest.mark.parametrize("arch", ARCHS)
def test_param_counts_sane(arch):
    cfg = get_config(arch)
    n = models.count_params(cfg)
    na = models.count_active_params(cfg)
    assert n > 0 and 0 < na <= n
    if cfg.moe is None:
        assert n == na


def test_long_context_support_flags():
    """Sub-quadratic rule (DESIGN.md): SSM/hybrid/windowed run long_500k."""
    expected_long = {"mamba2-370m", "recurrentgemma-2b", "gemma3-27b",
                     "mixtral-8x7b"}
    got = {a for a in ARCHS if get_config(a).supports_long}
    assert got == expected_long
