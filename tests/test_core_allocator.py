"""Tests for the faithful allocator (Result 1 of the paper).

Validates, empirically, every property of Result 1:
  1. references are plain block indices (pointers)      — by construction
  2. O(1) worst-case time per operation                 — step-count bound
  3. at most m - Theta(p^2) live blocks                 — capacity test
  4. Theta(p^2) extra space for metadata                — space test
  5. single-word read/write/CAS (LL/SC via DISC'20)     — by construction
plus linearizability, wait-freedom under crashes, and robustness to
user writes into live blocks.
"""

import random

import pytest
try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:              # container image lacks hypothesis
    from _hypothesis_fallback import given, settings, st

from repro.core import (
    SimContext, WaitFreeAllocator, Scheduler, closed_loop,
    check_alloc_history, PoolExhausted,
)

POLICIES = ("random", "bursty", "round_robin", "stall_one")


def run_workload(p, policy="random", seed=0, n_ops=150, phased_bursts=False,
                 crash_at=None, **alloc_kw):
    ctx = SimContext(p, seed=seed)
    alloc = WaitFreeAllocator(ctx, shared_batches=4 * p, **alloc_kw)
    sched = Scheduler(seed=seed)
    for pid in range(p):
        if phased_bursts:
            sched.add(pid, _phased(pid, alloc, random.Random(seed * 97 + pid)))
        else:
            sched.add(pid, closed_loop(pid, alloc, n_ops,
                                       random.Random(seed * 97 + pid)))
    sched.run(policy, crash_at=crash_at)
    return ctx, alloc, sched


def _phased(pid, alloc, rng, phases=4):
    held = []
    burst = alloc.ell * 3
    for ph in range(phases):
        if ph % 2 == 0:
            for _ in range(burst):
                b = yield from alloc.allocate(pid)
                for w in range(alloc.mem.k):
                    alloc.mem.words[b][w] = 0xDEADBEEF  # user scribble
                held.append(b)
        else:
            rng.shuffle(held)
            while held:
                yield from alloc.free(pid, held.pop())
    while held:
        yield from alloc.free(pid, held.pop())


@pytest.mark.parametrize("p", [2, 3, 4, 8])
@pytest.mark.parametrize("policy", POLICIES)
def test_safety_under_schedules(p, policy):
    ctx, alloc, _ = run_workload(p, policy, seed=11, phased_bursts=True)
    alloc.check_num_batches_invariant()
    assert ctx.violations == []
    assert check_alloc_history(ctx.history) == []


@pytest.mark.parametrize("p", [2, 4, 8, 16])
def test_constant_time_bound(p):
    """Result 1.2: worst-case steps per op is a constant independent of p."""
    worst = 0
    for policy in POLICIES:
        ctx, alloc, _ = run_workload(p, policy, seed=5, phased_bursts=True)
        assert ctx.violations == []
        worst = max(worst, max(op.steps for op in ctx.history if op.completed))
    # DEAMORT_C(48) + private-op drain + op logic; see allocator.py.
    assert worst <= 70, f"p={p}: worst op took {worst} steps"


def test_step_bound_independent_of_p():
    results = {}
    for p in (2, 16):
        worst = 0
        for policy in POLICIES:
            ctx, _, _ = run_workload(p, policy, seed=5, phased_bursts=True)
            worst = max(worst, max(op.steps for op in ctx.history if op.completed))
        results[p] = worst
    assert results[16] <= results[2] + 12, results


@pytest.mark.parametrize("p", [2, 4, 8])
def test_delayed_ops_complete_within_p_user_ops(p):
    ctx, alloc, _ = run_workload(p, "random", seed=3, phased_bursts=True)
    assert alloc.delayed_started == alloc.delayed_completed + (
        sum(1 for pool in alloc.pools if pool.delayed is not None))
    assert alloc.max_delayed_slices <= p, (
        f"a shared op needed {alloc.max_delayed_slices} > p={p} user ops")


@pytest.mark.parametrize("p", [2, 4, 8])
def test_live_capacity(p):
    """Result 1.3: at least m - Theta(p^2) blocks can be live at once."""
    ctx = SimContext(p, seed=0)
    alloc = WaitFreeAllocator(ctx, shared_batches=6 * p, allow_os_growth=False)
    m = alloc.mem.m
    sched = Scheduler(seed=0)
    got = []

    def greedy(pid):
        try:
            while True:
                b = yield from alloc.allocate(pid)
                got.append(b)
        except PoolExhausted:
            return

    # one process drains everything it can reach
    sched.add(0, greedy(0))
    try:
        sched.run("round_robin")
    except PoolExhausted:
        pass
    # Unreachable: other processes' private pools (<= 2.5*ell each) plus
    # our own residual metadata-held blocks — all Theta(p^2) with ell=4p.
    live = len(got)
    assert live >= m - 11 * p * p - 8 * p, (
        f"p={p}: only {live} of {m} blocks allocatable")
    assert len(set(got)) == live  # all distinct


@pytest.mark.parametrize("p", [2, 4, 8, 16, 32])
def test_space_overhead_quadratic(p):
    """Result 1.4: internal metadata is Theta(p^2) words."""
    ctx = SimContext(p, seed=0)
    alloc = WaitFreeAllocator(ctx, shared_batches=4 * p)
    words = alloc.metadata_words()
    # LLSC (p^2) + psim pool (2(p+1)(2p+1)) + announces/toggles + locals
    assert words <= 12 * p * p + 40 * p + 60, f"p={p}: {words} words"
    assert words >= p * p  # genuinely quadratic components present


def test_crash_wait_freedom():
    """Crashed processes cannot block others (wait-freedom)."""
    p = 6
    ctx = SimContext(p, seed=9)
    alloc = WaitFreeAllocator(ctx, shared_batches=4 * p)
    sched = Scheduler(seed=9)
    for pid in range(p):
        sched.add(pid, _phased(pid, alloc, random.Random(pid)))
    # crash half the processes at staggered points mid-execution
    sched.run("random", crash_at={0: 500, 1: 1500, 2: 2500})
    assert ctx.violations == []
    assert check_alloc_history(ctx.history) == []
    # survivors finished their whole programs
    for pid in (3, 4, 5):
        assert sched.done[pid]
    # and their ops all stayed O(1)
    for op in ctx.history:
        if op.pid in (3, 4, 5) and op.completed:
            assert op.steps <= 70


def test_user_scribble_cannot_corrupt():
    """The allocator never trusts words of live blocks (paper section 1)."""
    ctx, alloc, _ = run_workload(4, "bursty", seed=21, phased_bursts=True)
    assert ctx.violations == []
    assert check_alloc_history(ctx.history) == []


def test_os_growth_when_exhausted():
    p = 2
    ctx = SimContext(p, seed=0)
    alloc = WaitFreeAllocator(ctx, shared_batches=1, allow_os_growth=True)
    sched = Scheduler(seed=0)
    n_target = alloc.mem.m + 3 * alloc.ell   # force growth

    def greedy(pid, n):
        for _ in range(n):
            yield from alloc.allocate(pid)

    sched.add(0, greedy(0, n_target // 2))
    sched.add(1, greedy(1, n_target // 2))
    sched.run("random")
    assert alloc.os_requests > 0
    assert ctx.violations == []


@settings(max_examples=15, deadline=None)
@given(
    p=st.integers(min_value=2, max_value=6),
    seed=st.integers(min_value=0, max_value=2**16),
    max_held=st.integers(min_value=1, max_value=48),
)
def test_property_random_schedules(p, seed, max_held):
    """Hypothesis: no schedule/workload mix violates safety or O(1)."""
    ctx = SimContext(p, seed=seed)
    alloc = WaitFreeAllocator(ctx, shared_batches=4 * p)
    sched = Scheduler(seed=seed)
    for pid in range(p):
        sched.add(pid, closed_loop(pid, alloc, 120,
                                   random.Random(seed + pid), max_held=max_held))
    sched.run("random")
    alloc.check_num_batches_invariant()
    assert ctx.violations == []
    assert check_alloc_history(ctx.history) == []
    assert max(op.steps for op in ctx.history if op.completed) <= 70
