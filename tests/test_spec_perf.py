"""Making speculation pay (DESIGN.md §12): the page-grouped verify-
attention kernel vs its jnp oracle, the chunked-vocab argmax projection,
the n-gram drafter, and accept-rate-gated drafting — including mid-
request on->off->on gating flips that must stay token-identical for
greedy AND sampled decode with a leak-free speculative history.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import models
from repro.configs import get_config, smoke_config
from repro.core.linearizability import check_speculative_history
from repro.core.sim import OpRecord
from repro.kernels.verify_attention import (build_verify_schedule,
                                            verify_attention_ref)
from repro.kernels.verify_attention.kernel import (
    verify_attention as verify_attention_kernel)
from repro.models.layers import logits_apply, logits_argmax_chunked
from repro.serving.engine import Request, ServingEngine
from repro.serving.prefix_cache import SpeculationStore


@pytest.fixture(scope="module")
def engine_setup():
    cfg = smoke_config(get_config("olmo-1b"))
    params = models.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


# ================================ 1. verify-attention kernel vs oracle

def _spec_tables(rng, B, T, psz, maxp, P, overlap: str):
    """Block tables + base lens shaped like a verify step: B draft
    lanes mid-generation, sharing 0 / some / all of their prefix pages
    (the refcounted sharing `share_prefix_step` produces)."""
    base = rng.randint(psz, (maxp - 1) * psz - T, size=B).astype(np.int32)
    tbl = np.full((B, maxp), -1, np.int32)
    shared = rng.choice(P, size=maxp, replace=False)
    for b in range(B):
        npages = int(np.ceil((int(base[b]) + T) / psz))
        for i in range(npages):
            if overlap == "all" or (overlap == "prefix" and i < 2):
                tbl[b, i] = shared[i]
            else:
                tbl[b, i] = int(rng.randint(0, P))
    return jnp.asarray(tbl), jnp.asarray(base)


class TestVerifyAttentionKernel:
    @pytest.mark.parametrize("B,T,H,KH,hd,psz,maxp,P,overlap", [
        (4, 5, 4, 2, 32, 8, 6, 64, "prefix"),     # draft_len 4
        (8, 3, 4, 2, 32, 8, 6, 64, "all"),        # draft_len 2, hot pages
        (2, 2, 4, 4, 16, 4, 8, 32, "none"),       # draft_len 1, no GQA
        (6, 5, 8, 2, 16, 16, 4, 48, "prefix"),    # big pages, GQA 4
        (3, 4, 4, 1, 32, 8, 6, 32, "all"),        # single kv head
    ])
    def test_vs_ref_sweep(self, B, T, H, KH, hd, psz, maxp, P, overlap):
        rng = np.random.RandomState(hash((B, T, psz, overlap)) % 2 ** 31)
        q = jnp.asarray(rng.randn(B, T, H, hd), jnp.float32)
        kp = jnp.asarray(rng.randn(P, psz, KH, hd), jnp.float32)
        vp = jnp.asarray(rng.randn(P, psz, KH, hd), jnp.float32)
        tbl, base = _spec_tables(rng, B, T, psz, maxp, P, overlap)
        ref = verify_attention_ref(q, kp, vp, tbl, base)
        out = verify_attention_kernel(q, kp, vp, tbl, base, interpret=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=1e-4, rtol=1e-4)

    def test_matches_chunk_attention_path(self, engine_setup):
        """verify_attention is bit-for-bit the math of the non-spec
        chunk path's oracle — the schedule may not change a single
        output element."""
        from repro.kernels.paged_attention.ref import (
            paged_attention_chunk_ref)
        rng = np.random.RandomState(11)
        q = jnp.asarray(rng.randn(3, 4, 4, 16), jnp.float32)
        kp = jnp.asarray(rng.randn(24, 8, 2, 16), jnp.float32)
        vp = jnp.asarray(rng.randn(24, 8, 2, 16), jnp.float32)
        tbl, base = _spec_tables(rng, 3, 4, 8, 5, 24, "prefix")
        a = verify_attention_ref(q, kp, vp, tbl, base)
        b = paged_attention_chunk_ref(q, kp, vp, tbl, base)
        assert np.array_equal(np.asarray(a), np.asarray(b))

    def test_schedule_invariants(self):
        """The sorted schedule covers every resident in-window (lane,
        slot) exactly once, groups equal pages into single contiguous
        runs (the one-DMA-per-hot-page property), and parks dead items
        at the tail."""
        rng = np.random.RandomState(7)
        B, T, psz, maxp, P = 6, 5, 8, 6, 32
        tbl, base = _spec_tables(rng, B, T, psz, maxp, P, "prefix")
        pages, lanes, slots = map(np.asarray,
                                  build_verify_schedule(tbl, base, T, psz))
        assert pages.shape == (B * maxp,)
        live = pages >= 0
        # dead items strictly at the tail
        assert not np.any(live[np.argmax(~live):]) or np.all(live)
        # sorted ascending -> every page id is one contiguous run
        lp = pages[live]
        assert np.all(np.diff(lp) >= 0)
        runs = 1 + int(np.sum(np.diff(lp) != 0))
        assert runs == len(np.unique(lp))
        # exact coverage: each resident in-window table entry once
        tbl_np, base_np = np.asarray(tbl), np.asarray(base)
        want = {(b, i) for b in range(B) for i in range(maxp)
                if tbl_np[b, i] >= 0 and i * psz <= base_np[b] + T - 1}
        got = list(zip(lanes[live].tolist(), slots[live].tolist()))
        assert len(got) == len(set(got)) == len(want)
        assert set(got) == want
        for b, i in want:
            j = got.index((b, i))
            assert pages[live][j] == tbl_np[b, i]

    def test_shared_pages_fewer_runs_than_visits(self):
        """With every lane reading the same pages, the live region
        collapses to one run per unique page: B visits per page, one
        potential DMA."""
        rng = np.random.RandomState(9)
        B, T, psz, maxp, P = 8, 4, 8, 4, 16
        tbl, base = _spec_tables(rng, B, T, psz, maxp, P, "all")
        pages, _, _ = map(np.asarray,
                          build_verify_schedule(tbl, base, T, psz))
        lp = pages[pages >= 0]
        runs = 1 + int(np.sum(np.diff(lp) != 0))
        assert runs == len(np.unique(lp)) < len(lp)


# ======================================= 2. chunked-vocab projection

class TestChunkedArgmax:
    def test_matches_full_projection(self, engine_setup):
        cfg, params = engine_setup
        rng = np.random.RandomState(2)
        x = jnp.asarray(rng.randn(2, 3, 5, cfg.d_model), jnp.float32)
        full = jnp.argmax(logits_apply(cfg, params["embed"], x), axis=-1)
        for chunk in (16, 100, 256, 1024):   # vocab 256: split/odd/exact/1
            got = logits_argmax_chunked(cfg, params["embed"], x, chunk=chunk)
            assert np.array_equal(np.asarray(got), np.asarray(full)), chunk

    def test_tie_break_is_first_max(self):
        cfg = smoke_config(get_config("olmo-1b"))
        d = cfg.d_model
        # lm_head with duplicated columns -> exact logit ties
        w = np.zeros((d, 8), np.float32)
        w[:, 2] = 1.0
        w[:, 5] = 1.0          # same column: tie between ids 2 and 5
        params = {"lm_head": jnp.asarray(w)}
        x = jnp.ones((3, d), jnp.float32)
        got = logits_argmax_chunked(cfg, params, x, chunk=3)
        assert np.all(np.asarray(got) == 2), "chunked argmax broke the " \
            "first-max tie-break jnp.argmax guarantees"


# ============================================== 3. n-gram drafting

class TestNgramDrafter:
    def test_exact_replay_still_wins(self):
        st = SpeculationStore(page_size=4)
        key = (1, 2, 3, 4)
        st.record(key, (10, 11, 12, 13, 14))
        assert st.draft(key, (10, 11), 3) == [12, 13, 14]

    def test_ngram_fallback_extends_beyond_replay(self):
        """A suffix no stream starts with still drafts when its last
        tokens appear mid-stream — the drafter follows the n-gram."""
        st = SpeculationStore(page_size=4, ngram=3)
        key = (1, 2, 3, 4)
        st.record(key, (10, 11, 12, 13, 14, 15))
        # suffix (99, 12, 13) matches no stream prefix, but (12, 13)
        # ... actually (99, 12, 13)[-3:] has no occurrence; g=2 matches
        assert st.draft(key, (99, 12, 13), 2) == [14, 15]

    def test_ngram_prefers_longest_gram(self):
        st = SpeculationStore(page_size=4, ngram=3)
        key = (1, 2, 3, 4)
        st.record(key, (7, 8, 9, 100, 8, 9, 200))
        # g=2 tail (8, 9): rightmost occurrence predicts 200, and the
        # rightmost match wins within a stream
        assert st.draft(key, (50, 8, 9), 1) == [200]

    def test_no_history_no_draft(self):
        st = SpeculationStore(page_size=4)
        assert st.draft((1, 2, 3, 4), (9,), 4) == []

    def test_accept_ewma(self):
        st = SpeculationStore(page_size=4, ewma_alpha=0.5)
        key = (1, 2, 3, 4)
        assert st.accept_rate(key) is None
        st.observe(key, 4, 4)
        assert st.accept_rate(key) == 1.0
        st.observe(key, 4, 0)
        assert st.accept_rate(key) == 0.5
        st.observe(key, 0, 0)            # no drafts -> no update
        assert st.accept_rate(key) == 0.5

    def test_ewma_survives_state_roundtrip(self):
        st = SpeculationStore(page_size=4)
        key = (1, 2, 3, 4)
        st.record(key, (5, 6, 7))
        st.observe(key, 4, 2)
        st2 = SpeculationStore(page_size=4)
        st2.load_state(st.to_state())
        assert st2.accept_rate(key) == st.accept_rate(key)
        assert st2.to_state() == st.to_state()


# ======================================= 4. accept-rate-gated drafting

class _FrozenCosts(dict):
    """Cost model pinned for deterministic gating tests: the engine's
    per-step recorder writes are ignored."""

    def __setitem__(self, k, v):
        pass


def _pin_costs(eng, ratios):
    """Install a frozen measured cost model: width-1 decode costs 1.0,
    width-(k+1) spec steps cost ``ratios[k]``."""
    costs = _FrozenCosts({(1, False): 1.0})
    for k, r in ratios.items():
        dict.__setitem__(costs, (k + 1, True), float(r))
    eng._step_cost = costs


class TestBreakEvenGate:
    def _engine(self, engine_setup, **kw):
        cfg, params = engine_setup
        return ServingEngine(cfg, params, dp=1, b_local=2, max_len=64,
                             speculate=True, draft_len=4, **kw)

    def test_unmeasured_prefix_drafts_full(self, engine_setup):
        eng = self._engine(engine_setup)
        assert eng._gate_k(("k",), 4) == 4

    def test_draft_len_shrinks_before_disabling(self, engine_setup):
        """The gate walks k down as the accept EWMA drops: measured
        costs make k=4 uneconomical before k=1 is."""
        eng = self._engine(engine_setup)
        key = ("k",)
        # fallback linear model (slope 0.25): ratio(k) = 1 + k/4.
        # expected tokens 1 + a + ... + a^k vs ratio:
        #   a=0.9 -> k=4 (4.10 >= 2.00); a=0.5 -> k=3 (1.875 >= 1.75
        #   but 1.9375 < 2.0 at k=4); a=0.2 -> 0 (1.2 < 1.25)
        for a, want in [(0.9, 4), (0.5, 3), (0.2, 0)]:
            eng.spec_store._accept[key] = a
            assert eng._gate_k(key, 4) == want, (a, want)

    def test_measured_costs_override_fallback(self, engine_setup):
        eng = self._engine(engine_setup)
        key = ("k",)
        eng.spec_store._accept[key] = 0.6
        # cheap verify lane (kernel + slimming did their job): k=4
        # costs only 1.3 decode steps -> even a=0.6 clears it
        _pin_costs(eng, {4: 1.3})
        assert eng._gate_k(key, 4) == 4
        # expensive verify lane: a=0.6 yields 2.12 expected tokens < 3
        _pin_costs(eng, {4: 3.0})
        assert eng._gate_k(key, 4) < 4

    def test_gate_off_passes_through(self, engine_setup):
        eng = self._engine(engine_setup, spec_gate=False)
        eng.spec_store._accept[("k",)] = 0.0
        assert eng._gate_k(("k",), 4) == 4


# ================================ 5. mid-request gating flips (on->off->on)

class TestGatingFlipIdentity:
    """A request whose prefix's accept-rate EWMA toggles speculation
    on->off->on must stay token-identical: the fold_in(seed, out_count)
    stream admits no skipped or reused key indices at either flip."""

    def _reference(self, cfg, params, prompt, max_new, sampled):
        eng = ServingEngine(cfg, params, dp=1, b_local=1, max_len=64)
        kw = dict(temperature=0.9, top_k=12, seed=7) if sampled else {}
        r = Request(0, prompt=list(prompt), max_new_tokens=max_new, **kw)
        eng.submit(r)
        eng.run(max_steps=300)
        assert r.done
        return r.out_tokens

    @pytest.mark.parametrize("sampled", [False, True],
                             ids=["greedy", "sampled"])
    def test_flip_on_off_on_token_identity(self, engine_setup, sampled):
        cfg, params = engine_setup
        rng = np.random.RandomState(21)
        prompt = list(rng.randint(1, 255, 16))
        max_new = 24
        ref = self._reference(cfg, params, prompt, max_new, sampled)

        # record the TRUE continuation so on-phase drafts accept, then
        # flip the EWMA: on (1.0) -> off (0.0) -> on (1.0)
        eng = ServingEngine(cfg, params, dp=1, b_local=1, max_len=64,
                            speculate=True, draft_len=4)
        _pin_costs(eng, {1: 1.25, 2: 1.5, 3: 1.75, 4: 2.0})
        key = eng.spec_store.key_of(prompt)
        eng.spec_store.record(key, tuple(prompt[len(key):]) + tuple(ref))
        kw = dict(temperature=0.9, top_k=12, seed=7) if sampled else {}
        r = Request(0, prompt=list(prompt), max_new_tokens=max_new, **kw)
        eng.submit(r)
        # timeline: steps 0-1 prefill the 16-token prompt, spec lanes
        # run from step 2 (full accepts: ~5 tokens/step), the off
        # window covers steps 4-5 (width-1 decode), then spec resumes
        phase_lanes = []
        steps = 0
        flips = {4: 0.0, 6: 1.0}
        while not eng.idle() and steps < 300:
            if steps in flips:
                eng.spec_store._accept[key] = flips[steps]
                phase_lanes.append(eng.stats["spec_lanes"])
            eng.step()
            steps += 1
        assert r.done
        assert r.out_tokens == ref, (
            "gating flip changed the token stream — a key index was "
            "skipped or reused at the flip boundary")
        # the flip really happened: lanes fired before the off-flip,
        # none during the off window, and again after the on-flip
        assert phase_lanes[0] > 0, "no spec lane before the off-flip"
        assert eng.stats["spec_lanes"] > phase_lanes[1], \
            "no spec lane after the on-flip"
        assert eng.stats["spec_gate_skips"] > 0, "off window never gated"
        assert eng.page_occupancy() == 0.0

    def test_flip_preserves_page_conservation(self, engine_setup):
        """Every step across both flip boundaries conserves pages and
        keeps §4.2 never-dry (the rollback plane is gating-oblivious)."""
        from repro.core import hier_pool
        cfg, params = engine_setup
        rng = np.random.RandomState(22)
        prompt = list(rng.randint(1, 255, 16))
        eng = ServingEngine(cfg, params, dp=1, b_local=1, max_len=64,
                            speculate=True, draft_len=4)
        _pin_costs(eng, {1: 1.25, 2: 1.5, 3: 1.75, 4: 2.0})
        ell = hier_pool.lane_ell(eng.state.pool.classes[0])
        key = eng.spec_store.key_of(prompt)
        eng.spec_store.record(key, tuple(prompt[len(key):])
                              + tuple(range(40, 60)))
        r = Request(0, prompt=list(prompt), max_new_tokens=10)
        eng.submit(r)
        flips = {3: 0.0, 6: 1.0}
        steps = 0
        while not eng.idle() and steps < 300:
            if steps in flips:
                eng.spec_store._accept[key] = flips[steps]
            eng.step()
            kv = eng.state.pool.classes[0]
            free_s = np.asarray(hier_pool.free_per_shard(kv))
            live_s = np.asarray(hier_pool.live_per_shard(kv))
            assert np.all(free_s + live_s == eng.pages_local)
            assert np.asarray(kv.private_top).min() >= ell
            steps += 1
        assert r.done
        assert eng.page_occupancy() == 0.0


# =================== 6. speculative-history checker across a gate flip

def _op(opid, name, pid=0, arg=None, result=None, t0=0, t1=1, meta=None):
    rec = OpRecord(opid=opid, pid=pid, name=name, arg=arg,
                   invoke_step=t0, result=result, response_step=t1)
    rec.meta.update(meta or {})
    return rec


class TestCheckerAcrossFlip:
    def test_flip_history_leak_free(self):
        """Spec episodes before and after a gated-off window (plain
        allocs in between) verify clean — the checker does not require
        episodes to be contiguous."""
        hist = [
            _op(1, "alloc_n", result=[4, 5, 6],
                meta={"spec": "e0", "shard": 0}),
            _op(2, "spec_rollback", arg=[5, 6], t0=2, t1=3,
                meta={"spec": "e0", "shard": 0, "kept": [4]}),
            # gate off: plain non-speculative allocation traffic
            _op(3, "alloc_n", result=[7], t0=4, t1=5),
            _op(4, "alloc_n", result=[8], t0=6, t1=7),
            # gate back on: a new episode on the same lane
            _op(5, "alloc_n", result=[9, 10], t0=8, t1=9,
                meta={"spec": "e1", "shard": 0}),
            _op(6, "spec_rollback", arg=[10], t0=10, t1=11,
                meta={"spec": "e1", "shard": 0, "kept": [9]}),
        ]
        assert check_speculative_history(hist) == []

    def test_flip_history_still_catches_leak(self):
        """The off-window must not mask a leak in the episode after the
        on-flip."""
        hist = [
            _op(1, "alloc_n", result=[4, 5, 6],
                meta={"spec": "e0", "shard": 0}),
            _op(2, "spec_rollback", arg=[5, 6], t0=2, t1=3,
                meta={"spec": "e0", "shard": 0, "kept": [4]}),
            _op(3, "alloc_n", result=[7], t0=4, t1=5),
            _op(4, "alloc_n", result=[9, 10, 11], t0=6, t1=7,
                meta={"spec": "e1", "shard": 0}),
            _op(5, "spec_rollback", arg=[10], t0=8, t1=9,
                meta={"spec": "e1", "shard": 0, "kept": [9]}),
        ]
        errs = check_speculative_history(hist)
        assert any("leak" in e and "11" in e for e in errs), errs


# ========================= 7. drafts riding mixed prompt/decode steps

class TestMixedStepDrafts:
    def test_mixed_step_token_identity(self, engine_setup):
        """A decode slot drafts while another slot is mid-prefill (the
        slimmed projection made that affordable); outputs match the
        non-speculative run of the same staggered schedule."""
        cfg, params = engine_setup
        rng = np.random.RandomState(23)
        p0 = list(rng.randint(1, 255, 16))
        p1 = list(rng.randint(1, 255, 24))

        def run(speculate):
            eng = ServingEngine(cfg, params, dp=1, b_local=2, max_len=64,
                                chunk_size=4, speculate=speculate,
                                draft_len=3)
            if speculate:
                key = eng.spec_store.key_of(p0)
                eng.spec_store.record(
                    key, tuple(p0[len(key):]) + tuple(range(30, 50)))
            r0 = Request(0, prompt=list(p0), max_new_tokens=8)
            r1 = Request(1, prompt=list(p1), max_new_tokens=4)
            eng.submit(r0)
            # r0 prefills (and starts decoding) alone, then r1's long
            # prompt arrives: r0's decode rides r1's prefill steps
            for _ in range(6):
                eng.step()
            eng.submit(r1)
            eng.run(max_steps=300)
            assert r0.done and r1.done
            return [r0.out_tokens, r1.out_tokens], eng

        ref, _ = run(speculate=False)
        out, eng = run(speculate=True)
        assert out == ref, "a draft riding a prefill step changed tokens"
        assert eng.stats["spec_mixed_steps"] > 0, (
            "no draft ever rode a mixed prompt/decode step — the "
            "slimmed spec variant never exercised its prefill branch")
        assert eng.page_occupancy() == 0.0
