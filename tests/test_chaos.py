"""Fault-tolerant serving (DESIGN.md §11).

Covers the chaos plane bottom-up:

* ckpt — atomic saves: a kill mid-save never tears the previous
  complete checkpoint (the warm-restart substrate);
* watchdog — straggler/timeout detection against a rolling median;
* linearizability — ``check_recovery_history`` flags leaks (orphaned
  blocks never reconciled) AND double frees (reclaiming a live
  holder's pages), mirroring ``check_preemption_history``'s style;
* hier_pool — ``audit_and_reconcile`` rebuilds free stacks, lane
  tops, and refcounts from page tables/pin rows alone, proving
  conservation and the §4.2 never-dry refill even from torn
  mid-rebalance state;
* engine — host crashes at EVERY step phase boundary (including the
  torn drain/refill window) recover token-identically for greedy and
  sampled lanes with zero leaked pages; poisoned requests retry with
  backoff then fail typed; deadlines expire queued and running work;
  shard loss degrades to survivors; a transient step error triggers
  exception-safe in-place recovery with pool conservation intact;
* warm restart — pins + speculation streams + queued requests survive
  an engine restart through the checkpoint sidecar, so the restarted
  engine re-pins without re-prefilling.
"""

import json

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import models
from repro.configs import get_config, smoke_config
from repro.checkpoint.ckpt import Checkpointer
from repro.core import hier_pool
from repro.core.linearizability import check_recovery_history
from repro.core.sim import OpRecord
from repro.runtime.fault import StepWatchdog
from repro.runtime.elastic import plan_serving_for
from repro.serving import chaos
from repro.serving.engine import Request, ServingEngine
from repro.serving.sched import FAILURE_REASONS, SchedConfig


@pytest.fixture(scope="module")
def engine_setup():
    cfg = smoke_config(get_config("olmo-1b"))
    params = models.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _conserved(eng):
    total = eng.pages_local * eng.dp
    free = int(hier_pool.total_free(eng.state.pool.classes[0]))
    live = int(hier_pool.num_live(eng.state.pool.classes[0]))
    assert free + live == total, "pages lost or duplicated"


def _mk_reqs(n=4, max_new=6):
    """Greedy and sampled lanes in one batch: rid 0, 2 greedy; 1, 3
    sampled — one run checks identity for both decode modes."""
    return [Request(rid=i, prompt=[2 + i, 3, 5, 7 + i],
                    max_new_tokens=max_new,
                    temperature=0.8 if i % 2 else 0.0, seed=100 + i)
            for i in range(n)]


@pytest.fixture(scope="module")
def ref_outputs(engine_setup):
    """Unfaulted reference outputs for the _mk_reqs trace."""
    cfg, params = engine_setup
    reqs = _mk_reqs()
    eng = ServingEngine(cfg, params, dp=1, b_local=4)
    for r in reqs:
        eng.submit(r)
    eng.run(max_steps=300)
    assert all(r.done for r in reqs)
    return {r.rid: list(r.out_tokens) for r in reqs}


# ---------------------------------------------------------------- ckpt
class TestAtomicCheckpoint:
    def test_kill_mid_save_keeps_previous_checkpoint(self, tmp_path,
                                                     monkeypatch):
        """A crash mid-serialization — after bytes hit the temp file —
        must leave the previous complete snapshot restorable and
        ``latest_step`` pointing at it."""
        from repro.checkpoint import ckpt as ckpt_mod
        state = {"w": jnp.arange(8, dtype=jnp.float32)}
        c = Checkpointer(str(tmp_path), keep=3)
        c.save(1, state, aux={"pins": [1, 2]})
        assert c.latest_step() == 1

        real_savez = np.savez

        def dying_savez(f, **kw):
            f.write(b"torn garbage")          # partial bytes on disk
            raise chaos.HostCrash("killed mid-save")

        monkeypatch.setattr(ckpt_mod.np, "savez", dying_savez)
        with pytest.raises(chaos.HostCrash):
            c.save(2, {"w": jnp.ones(8)}, aux={"pins": []})
        monkeypatch.setattr(ckpt_mod.np, "savez", real_savez)

        # step 2 has no INDEX -> invisible; step 1 intact
        assert c.latest_step() == 1
        got = c.restore(1, {"w": jnp.zeros(8, jnp.float32)})
        np.testing.assert_array_equal(np.asarray(got["w"]), np.arange(8))
        assert c.restore_aux(1) == {"pins": [1, 2]}

    def test_overwrite_same_step_is_atomic(self, tmp_path, monkeypatch):
        """Re-saving an existing step dies mid-write: the OLD npz for
        that step must still load (write-temp-then-rename)."""
        from repro.checkpoint import ckpt as ckpt_mod
        c = Checkpointer(str(tmp_path), keep=3)
        c.save(1, {"w": jnp.full(4, 7.0)})

        def dying_savez(f, **kw):
            f.write(b"x")
            raise RuntimeError("killed")

        monkeypatch.setattr(ckpt_mod.np, "savez", dying_savez)
        with pytest.raises(RuntimeError):
            c.save(1, {"w": jnp.zeros(4)})
        got = c.restore(1, {"w": jnp.zeros(4, jnp.float32)})
        np.testing.assert_array_equal(np.asarray(got["w"]), np.full(4, 7.0))


# ------------------------------------------------------------ watchdog
class TestStepWatchdog:
    def test_straggler_against_rolling_median(self):
        wd = StepWatchdog(straggler_factor=3.0, min_samples=8)
        for i in range(10):
            assert wd.observe(i, 0.01) is None
        assert wd.observe(10, 0.05) == "straggler"
        assert wd.observe(11, 0.011) is None

    def test_timeout_outranks_straggler(self):
        wd = StepWatchdog(straggler_factor=3.0, timeout_s=0.5,
                          min_samples=4)
        for i in range(6):
            wd.observe(i, 0.01)
        assert wd.observe(6, 0.6) == "timeout"

    def test_needs_min_samples(self):
        wd = StepWatchdog(min_samples=8)
        assert wd.observe(0, 10.0) is None


# ----------------------------------------------------- history checker
def _ops(*specs):
    out = []
    for i, (pid, name, arg, inv, resp, result) in enumerate(specs):
        out.append(OpRecord(opid=i, pid=pid, name=name, arg=arg,
                            invoke_step=inv, response_step=resp,
                            result=result))
    return out


class TestRecoveryHistoryChecker:
    def test_clean_crash_reconcile(self):
        h = _ops((0, "allocate", None, 0, 1, 5),
                 (1, "crash", [0], 2, 3, None),
                 (2, "reconcile", [5], 4, 5, None))
        assert check_recovery_history(h) == []

    def test_leak_detected(self):
        h = _ops((0, "allocate", None, 0, 1, 5),
                 (1, "crash", [0], 2, 3, None),
                 (2, "reconcile", [], 4, 5, None))
        errs = check_recovery_history(h)
        assert any("leaked" in e for e in errs)

    def test_double_free_detected(self):
        # pid 1 still holds block 7 when the reconcile reclaims it
        h = _ops((0, "allocate", None, 0, 1, 5),
                 (1, "allocate", None, 1, 2, 7),
                 (2, "crash", [0], 3, 4, None),
                 (3, "reconcile", [5, 7], 5, 6, None))
        errs = check_recovery_history(h)
        assert any("double free" in e for e in errs)

    def test_orphans_never_reconciled(self):
        h = _ops((0, "allocate", None, 0, 1, 5),
                 (1, "crash", [0], 2, 3, None))
        errs = check_recovery_history(h)
        assert any("never reclaimed" in e for e in errs)


# --------------------------------------------------- pool reconcile
class TestAuditAndReconcile:
    def _torn_pool_with_tables(self, dp=2, m=16, lanes=2, ell=3):
        pool = hier_pool.create_dp(dp, m, lanes, ell)
        # allocate 3 pages on each shard's lane 0
        counts = jnp.zeros((dp, lanes), jnp.int32).at[:, 0].set(3)
        pool, ids = hier_pool.alloc_n_dp(pool, counts, 3)
        tables = np.full((dp, lanes, 4), -1, np.int64)
        tables[:, 0, :3] = np.asarray(ids)[:, 0, :3]
        # tear the allocator mid-rebalance: drained, never refilled
        pool = hier_pool.rebalance_drain_dp(pool)
        return pool, tables

    def test_torn_pool_reconciles_conserved_and_never_dry(self):
        pool, tables = self._torn_pool_with_tables()
        new, report = hier_pool.audit_and_reconcile(pool,
                                                    keep_tables=tables)
        assert report["conserved"] and report["never_dry"]
        for s in report["shards"]:
            assert s["free"] + s["live"] == s["capacity"]
            assert s["live"] == 3
        ell = hier_pool.lane_ell(new)
        assert bool(jnp.all(new.private_top == ell))

    def test_dead_rows_reclaimed_pins_kept(self):
        pool, tables = self._torn_pool_with_tables()
        pins = tables[:, :1, :]          # keep lane-0 rows as "pins"
        dead = np.full_like(tables, -1)  # every slot row dead
        new, report = hier_pool.audit_and_reconcile(
            pool, keep_tables=dead, pin_tables=pins)
        assert report["reclaimed"] == 0          # pins still hold them
        assert int(hier_pool.num_live(new)) == 6
        new2, report2 = hier_pool.audit_and_reconcile(
            pool, keep_tables=dead, pin_tables=None)
        assert report2["reclaimed"] == 6         # nobody holds them
        assert int(hier_pool.num_live(new2)) == 0

    def test_resurrection_shields_double_free(self):
        pool, tables = self._torn_pool_with_tables()
        # simulate a torn mirror that already dropped the refcounts
        zeroed = pool._replace(shared=pool.shared._replace(
            refcount=jnp.zeros_like(pool.shared.refcount)))
        new, report = hier_pool.audit_and_reconcile(zeroed,
                                                    keep_tables=tables)
        assert report["resurrected"] == 6
        assert report["conserved"] and report["never_dry"]


# -------------------------------------------------- crash recovery e2e
CRASH_CASES = [("pre_tick", False), ("post_admission", False),
               ("feed", False), ("dispatched", True),
               ("post_sync", True), ("post_step", False)]


class TestCrashRecovery:
    @pytest.mark.parametrize("phase,torn", CRASH_CASES,
                             ids=[f"{p}{'-torn' if t else ''}"
                                  for p, t in CRASH_CASES])
    def test_crash_recovers_token_identical(self, engine_setup,
                                            ref_outputs, phase, torn):
        cfg, params = engine_setup
        journal = chaos.ServingJournal()
        injector = chaos.ServingFailureInjector(
            [chaos.Fault(step=3, phase=phase, kind="crash", torn=torn)])

        def build():
            return ServingEngine(cfg, params, dp=1, b_local=4,
                                 journal=journal, injector=injector)

        eng = build()
        for r in _mk_reqs():
            eng.submit(r)
        with pytest.raises(chaos.HostCrash):
            eng.run(max_steps=300)
        eng2, report = chaos.recover_engine(build, eng, journal)
        assert report["conserved"] and report["never_dry"]
        eng2.run(max_steps=300)
        out = journal.outputs()
        assert journal.finished() == set(ref_outputs)
        for rid, toks in ref_outputs.items():
            assert out[rid] == toks, f"rid {rid} diverged after {phase}"
        assert eng2.leak_free()
        _conserved(eng2)

    def test_journal_jsonl_roundtrip(self, engine_setup, tmp_path):
        cfg, params = engine_setup
        path = tmp_path / "journal.jsonl"
        journal = chaos.ServingJournal(path=str(path))
        eng = ServingEngine(cfg, params, dp=1, b_local=4, journal=journal)
        reqs = _mk_reqs(n=2)
        for r in reqs:
            eng.submit(r)
        eng.run(max_steps=300)
        journal.close()
        replay = chaos.ServingJournal.load(str(path))
        assert replay.finished() == {0, 1}
        assert replay.outputs()[0] == list(reqs[0].out_tokens)
        assert not replay.in_flight()
        # every line is valid JSON (the offline-analysis contract)
        for line in path.read_text().splitlines():
            json.loads(line)


# --------------------------------------------- typed failures/deadlines
class TestHardening:
    def test_poison_retries_then_terminal(self, engine_setup):
        cfg, params = engine_setup
        injector = chaos.ServingFailureInjector(
            [chaos.Fault(step=1, phase="feed", kind="poison", rid=1),
             chaos.Fault(step=3, phase="feed", kind="poison", rid=1),
             chaos.Fault(step=6, phase="feed", kind="poison", rid=1)])
        eng = ServingEngine(cfg, params, dp=1, b_local=4,
                            injector=injector,
                            sched=SchedConfig(retry_limit=1,
                                              retry_backoff=1))
        reqs = _mk_reqs(n=3)
        for r in reqs:
            eng.submit(r)
        eng.run(max_steps=300)
        assert reqs[1].rejected == "poisoned"
        assert "poisoned" in FAILURE_REASONS
        assert reqs[1].retries == 1
        assert eng.stats["retries"] == 1 and eng.stats["failed"] == 1
        assert reqs[0].done and reqs[2].done     # everyone else fine
        _conserved(eng)
        assert eng.leak_free()

    def test_deadline_expires_queued_and_running(self, engine_setup):
        cfg, params = engine_setup
        clock = [0.0]
        eng = ServingEngine(cfg, params, dp=1, b_local=4,
                            clock=lambda: clock[0])
        # 4 slots: rid 0-3 admit and run; rid 4 queues
        reqs = [Request(rid=i, prompt=[2 + i, 3, 5], max_new_tokens=20,
                        deadline_s=10.0) for i in range(5)]
        for r in reqs:
            eng.submit(r)
        assert all(r.deadline_at == 10.0 for r in reqs)
        for _ in range(2):
            eng.step()
        clock[0] = 11.0                          # everyone expires
        eng.run(max_steps=300)
        assert all(r.rejected == "deadline" for r in reqs if not r.done)
        assert any(r.rejected == "deadline" for r in reqs)
        assert eng.stats["deadline_expired"] >= 1
        _conserved(eng)
        assert eng.leak_free()

    def test_deadline_survives_crash_recovery(self, engine_setup):
        cfg, params = engine_setup
        clock = [0.0]
        journal = chaos.ServingJournal()
        injector = chaos.ServingFailureInjector(
            [chaos.Fault(step=2, phase="post_sync", kind="crash")])

        def build():
            return ServingEngine(cfg, params, dp=1, b_local=4,
                                 journal=journal, injector=injector,
                                 clock=lambda: clock[0])

        eng = build()
        eng.submit(Request(rid=0, prompt=[2, 3, 5], max_new_tokens=20,
                           deadline_s=10.0))
        with pytest.raises(chaos.HostCrash):
            eng.run(max_steps=300)
        eng2, report = chaos.recover_engine(build, eng, journal)
        # the requeued request carries the ORIGINAL absolute deadline
        assert [r.deadline_at for r in report["requests"]] == [10.0]
        clock[0] = 11.0
        eng2.run(max_steps=300)
        assert not journal.in_flight()
        assert report["requests"][0].rejected == "deadline"
        assert eng2.stats["deadline_expired"] == 1
        assert eng2.leak_free()

    def test_step_error_recovers_in_place_conserved(self, engine_setup):
        cfg, params = engine_setup
        injector = chaos.ServingFailureInjector(
            [chaos.Fault(step=2, phase="post_sync", kind="error")])
        eng = ServingEngine(cfg, params, dp=1, b_local=4,
                            injector=injector, max_restarts=2)
        reqs = _mk_reqs()
        for r in reqs:
            eng.submit(r)
        eng.run(max_steps=300)                   # error absorbed
        assert eng.stats["recoveries"] == 1
        assert all(r.done for r in reqs)
        assert any(r.preemptions >= 1 for r in reqs)  # requeued + resumed
        _conserved(eng)
        assert eng.leak_free()

    def test_step_error_past_budget_raises_conserved(self, engine_setup):
        cfg, params = engine_setup
        injector = chaos.ServingFailureInjector(
            [chaos.Fault(step=2, phase="post_sync", kind="error"),
             chaos.Fault(step=3, phase="post_sync", kind="error")])
        eng = ServingEngine(cfg, params, dp=1, b_local=4,
                            injector=injector, max_restarts=1)
        for r in _mk_reqs():
            eng.submit(r)
        with pytest.raises(chaos.StepError):
            eng.run(max_steps=300)
        # recovery ran BEFORE the re-raise: conservation holds
        _conserved(eng)
        assert eng.leak_free()


# ------------------------------------------------------------ shard loss
class TestShardLoss:
    def test_lost_shard_evacuates_and_degrades(self, engine_setup):
        cfg, params = engine_setup
        injector = chaos.ServingFailureInjector(
            [chaos.Fault(step=3, phase="post_admission",
                         kind="shard_loss", shard=1)])
        eng = ServingEngine(cfg, params, dp=2, b_local=2,
                            injector=injector)
        reqs = _mk_reqs(n=6)
        for r in reqs:
            eng.submit(r)
        eng.run(max_steps=400)
        assert eng.lost_shards == {1}
        assert eng.stats["shards_lost"] == 1
        done = [r for r in reqs if r.done]
        shed = [r for r in reqs if r.rejected]
        assert len(done) + len(shed) == len(reqs)
        assert done, "no request survived shard loss"
        # survivors leak-free; the dead shard's pages left the
        # accounting with the shard (no release targets dead hardware)
        assert eng.leak_free()
        # no free slot maps to the dead shard anymore
        assert all(s // eng.bl != 1 for s in eng._free_slots)

    def test_plan_serving_for_sheds_over_capacity(self):
        plan = plan_serving_for(4, {2}, page_budget=10, backlog_pages=35)
        assert plan.surviving == (0, 1, 3)
        assert plan.capacity_pages == 30 and plan.shed_pages == 5
        full = plan_serving_for(4, set(), page_budget=10, backlog_pages=35)
        assert full.shed_pages == 0 and "full mesh" in full.note


# ----------------------------------------------------------- warm restart
class TestWarmRestart:
    def _hot_reqs(self, hot, base=0):
        return [Request(rid=base + i, prompt=hot + [11 + i, 13],
                        max_new_tokens=4) for i in range(3)]

    def test_pins_and_speculation_survive_restart(self, engine_setup,
                                                  tmp_path):
        cfg, params = engine_setup
        hot = list(range(2, 18))                 # 2 pages of 8

        def fresh():
            return ServingEngine(cfg, params, dp=1, b_local=4,
                                 speculate=True, draft_len=4,
                                 sched=SchedConfig(pin_pages=8))

        warmup = fresh()
        for r in self._hot_reqs(hot):
            warmup.submit(r)
        warmup.run(max_steps=300)
        assert warmup.pinned_pages() > 0
        ckptr = Checkpointer(str(tmp_path), keep=1)
        warmup.save_warm(ckptr, step=1)

        # cold: a fresh engine re-prefills the hot prefix from scratch
        cold = fresh()
        cold_reqs = self._hot_reqs(hot, base=100)
        for r in cold_reqs:
            cold.submit(r)
        cold.run(max_steps=300)

        # warm: restored pins serve the hot prefix without re-prefill
        warm = fresh()
        step = warm.restore_warm(ckptr)
        assert step == 1
        assert warm.pinned_pages() == warmup.pinned_pages()
        assert warm.spec_store.to_state() == warmup.spec_store.to_state()
        warm_reqs = self._hot_reqs(hot, base=200)
        for r in warm_reqs:
            warm.submit(r)
        warm.run(max_steps=300)

        assert warm.stats["pin_hit_reqs"] > 0, "restored pins unused"
        assert (warm.stats["prompt_tokens"]
                < cold.stats["prompt_tokens"]), "warm restart re-prefilled"
        # identity: restart is invisible to outputs
        assert ([r.out_tokens for r in warm_reqs]
                == [r.out_tokens for r in cold_reqs])
        warm.flush_pins()
        _conserved(warm)
        assert warm.leak_free()

    def test_queued_requests_survive_restart(self, engine_setup,
                                             tmp_path):
        cfg, params = engine_setup
        eng = ServingEngine(cfg, params, dp=1, b_local=4)
        queued = [Request(rid=i, prompt=[3 + i, 5, 7], max_new_tokens=3,
                          deadline_s=0.0) for i in range(2)]
        for r in queued:
            eng.submit(r)                        # never stepped: all queued
        ckptr = Checkpointer(str(tmp_path), keep=1)
        eng.save_warm(ckptr, step=1)

        eng2 = ServingEngine(cfg, params, dp=1, b_local=4)
        eng2.restore_warm(ckptr)
        assert eng2.scheduler.backlog() == 2
        eng2.run(max_steps=300)
        assert eng2.stats["tokens_out"] > 0
        assert eng2.leak_free()
