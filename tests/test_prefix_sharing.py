"""Prefix sharing: refcounted pages, COW partial pages, engine behavior.

Covers the DESIGN.md §7 protocol at two levels:

* core — ``kv_cache.share_prefix`` (table mapping + addref + COW copy)
  and the pool's refcount conservation under mixed-order release;
* serving — the engine's trie-driven sharing: exact page accounting for
  two requests with a common prefix, token-identical outputs vs the
  unshared path, and the >= 2x pages-in-use reduction on a hot-prefix
  workload (the bench's pool-churn scenario in miniature).
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import models
from repro.configs import get_config, smoke_config
from repro.core import block_pool, hier_pool, kv_cache
from repro.serving.engine import Request, ServingEngine
from repro.serving.prefix_cache import PrefixCache


@pytest.fixture(scope="module")
def engine_setup():
    cfg = smoke_config(get_config("olmo-1b"))
    params = models.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _pool_invariants(pool, total_pages):
    """free + live == total, and every stacked block has refcount 0."""
    free = int(hier_pool.total_free(pool))
    live = int(hier_pool.num_live(pool))
    assert free + live == total_pages, "pages lost or duplicated"
    return free, live


# --------------------------------------------------------------- core level

class TestKVCacheSharePrefix:
    def _mk(self):
        return kv_cache.create(num_pages=32, page_size=4, kv_heads=2,
                               head_dim=8, max_seqs=3, max_pages_per_seq=8,
                               dtype=jnp.float32)

    def _fill(self, cache, seq_mask, toks):
        """Append toks[t] (distinct per position) to masked seqs."""
        for t in range(toks):
            k = jnp.full((3, 2, 8), float(t + 1))
            cache, ok = kv_cache.append(cache, k, k, jnp.asarray(seq_mask))
            assert bool(jnp.all(jnp.asarray(ok)[np.asarray(seq_mask)]))
        return cache

    def test_share_maps_tables_and_refcounts(self):
        cache = self._mk()
        cache = self._fill(cache, [True, False, False], 10)   # 3 pages (psz 4)
        used0 = 32 - int(cache.pool.top)
        assert used0 == 3
        cache, ok = kv_cache.share_prefix(cache, dst=1, src=0,
                                          n_tokens=jnp.int32(10))
        assert bool(ok)
        # 2 full pages shared (same physical ids), 1 COW copy of page 2
        t0 = np.asarray(cache.page_tables[0])
        t1 = np.asarray(cache.page_tables[1])
        assert t1[0] == t0[0] and t1[1] == t0[1]
        assert t1[2] != t0[2] and t1[2] >= 0, "partial page must be COW'd"
        assert int(cache.seq_lens[1]) == 10
        rc = np.asarray(cache.pool.refcount)
        assert rc[t0[0]] == 2 and rc[t0[1]] == 2       # shared
        assert rc[t0[2]] == 1 and rc[t1[2]] == 1       # private
        assert 32 - int(cache.pool.top) == 4           # 3 + 1 COW page
        # COW copy holds the donor's partial-page content
        np.testing.assert_array_equal(
            np.asarray(cache.k_pages[t1[2]]), np.asarray(cache.k_pages[t0[2]]))

    def test_mixed_order_release_conserves(self):
        for first in (0, 1):                     # donor-first and sharer-first
            cache = self._mk()
            cache = self._fill(cache, [True, False, False], 10)
            cache, ok = kv_cache.share_prefix(cache, dst=1, src=0,
                                              n_tokens=jnp.int32(10))
            assert bool(ok)
            mask = np.zeros(3, bool)
            mask[first] = True
            cache = kv_cache.release(cache, jnp.asarray(mask))
            rc = np.asarray(cache.pool.refcount)
            # shared pages still live through the survivor's references
            assert (rc == 1).sum() == 3 and (rc >= 2).sum() == 0
            assert 32 - int(cache.pool.top) == 3
            mask = np.zeros(3, bool)
            mask[1 - first] = True
            cache = kv_cache.release(cache, jnp.asarray(mask))
            assert int(cache.pool.top) == 32, "pages leaked"
            assert int(block_pool.num_live(cache.pool)) == 0

    def test_share_denied_changes_nothing(self):
        cache = self._mk()
        cache = self._fill(cache, [True, False, False], 10)
        drained = cache._replace(pool=cache.pool._replace(top=jnp.int32(0)))
        shared, ok = kv_cache.share_prefix(drained, dst=1, src=0,
                                           n_tokens=jnp.int32(10))
        assert not bool(ok)                       # COW page unavailable
        assert int(shared.seq_lens[1]) == 0
        assert np.all(np.asarray(shared.page_tables[1]) == -1)
        assert np.array_equal(np.asarray(shared.pool.refcount),
                              np.asarray(drained.pool.refcount))


# ------------------------------------------------------------ host trie

class TestPrefixCacheTrie:
    def test_match_page_granular_with_partial_extension(self):
        pc = PrefixCache(page_size=4)
        pc.insert(0, 0, list(range(100, 118)))            # 18 tokens
        pc.update_progress(0, 18)
        q = list(range(100, 114)) + [7, 7, 7, 7]          # lcp = 14
        m = pc.match(q)
        assert m is not None and m.slot == 0 and m.shard == 0
        assert m.n_tokens == 14                           # 3 pages + 2 extra
        # capped by the donor's completed length
        pc2 = PrefixCache(page_size=4)
        pc2.insert(0, 0, list(range(100, 118)))
        pc2.update_progress(0, 9)
        assert pc2.match(q).n_tokens == 9
        # never the whole query (last token must be fed normally)
        assert pc.match(list(range(100, 114))).n_tokens == 13

    def test_remove_prunes_and_survivor_still_donates(self):
        pc = PrefixCache(page_size=4)
        pc.insert(0, 0, [1, 2, 3, 4, 5, 6, 7, 8, 9])
        pc.update_progress(0, 9)
        pc.insert(1, 0, [1, 2, 3, 4, 5, 6, 7, 8, 42])
        pc.update_progress(1, 9)
        pc.remove(0)
        m = pc.match([1, 2, 3, 4, 5, 6, 7, 8, 77, 78])
        assert m is not None and m.slot == 1 and m.n_tokens == 8
        pc.remove(1)
        assert pc.match([1, 2, 3, 4, 5, 6, 7, 8, 77]) is None
        assert pc.live_slots() == 0

    def test_no_cross_shard_match(self):
        pc = PrefixCache(page_size=4)
        pc.insert(0, 1, [1, 2, 3, 4, 5, 6, 7, 8])
        pc.update_progress(0, 8)
        m = pc.match([1, 2, 3, 4, 5, 6, 7, 99])
        assert m.shard == 1                      # engine must place there

    def test_shard_restricted_match_rejects_exact_foreign_key(self):
        """Regression (DESIGN.md §9): a shard-restricted lookup must
        reject a donor on another shard EVEN ON AN EXACT TOKEN MATCH —
        page ids never alias across shards, so returning it would let
        the engine map foreign page ids into a local table."""
        pc = PrefixCache(page_size=4)
        toks = [1, 2, 3, 4, 5, 6, 7, 8]
        pc.insert(0, 1, list(toks))
        pc.update_progress(0, 8)
        assert pc.match(toks + [9], shard=1).slot == 0
        assert pc.match(toks + [9], shard=0) is None, (
            "exact-key donor on shard 1 leaked into a shard-0 lookup")
        assert pc.match(toks + [9], shard=7) is None   # no such shard
        # identical prompt inserted on shard 0 too: each shard's lookup
        # now resolves to its OWN donor
        pc.insert(5, 0, list(toks))
        pc.update_progress(5, 8)
        m0, m1 = pc.match(toks + [9], shard=0), pc.match(toks + [9], shard=1)
        assert (m0.slot, m0.shard) == (5, 0)
        assert (m1.slot, m1.shard) == (0, 1)


# ------------------------------------------------------------ engine level

class TestEnginePrefixSharing:
    def test_exact_page_accounting_and_mixed_order_release(self, engine_setup):
        """Two requests with a common prefix occupy shared + distinct
        pages — exact counts, then refcount conservation as they finish
        in donor-first order."""
        cfg, params = engine_setup                       # psz = 8
        psz = cfg.page_size
        eng = ServingEngine(cfg, params, dp=1, b_local=2, max_len=64,
                            chunk_size=16)
        total = eng.state.pool.classes[0].shared.free_ids.shape[1]
        pa = list(range(2, 22))                          # 20 tokens
        ra = Request(0, prompt=list(pa), max_new_tokens=3)
        eng.submit(ra)
        eng.step(); eng.step()                           # prefill 16 + 4
        assert eng.pages_in_use() == 3                   # ceil(20/8)
        _pool_invariants(eng.state.pool.classes[0], total)

        pb = pa[:18] + [200, 201, 202, 203, 204, 205]    # lcp 18 = 2p + 2
        rb = Request(1, prompt=list(pb), max_new_tokens=3)
        eng.submit(rb)
        eng.step()    # admits B: 2 shared pages + 1 COW; feeds B's tail
        assert eng.stats["prefix_shared_reqs"] == 1
        assert eng.stats["prefix_shared_tokens"] == 18
        # A: 3 pages; B: 2 shared (not recounted) + 1 COW = 4 total
        assert eng.pages_in_use() == 4
        rc = np.asarray(eng.state.pool.classes[0].shared.refcount)
        assert (rc == 2).sum() == 2 and (rc == 1).sum() == 2
        _pool_invariants(eng.state.pool.classes[0], total)

        eng.run(max_steps=50)                            # A finishes first
        assert ra.done and rb.done
        assert eng.pages_in_use() == 0 and eng.page_occupancy() == 0.0
        assert int(hier_pool.num_live(eng.state.pool.classes[0])) == 0
        _pool_invariants(eng.state.pool.classes[0], total)

    def test_cow_divergence_keeps_donor_intact(self, engine_setup):
        """The sharer's divergent tokens go to its private COW page; the
        donor's outputs are bit-identical to a solo run."""
        cfg, params = engine_setup
        pa = list(range(3, 23))                          # 20 tokens

        def run_solo():
            eng = ServingEngine(cfg, params, dp=1, b_local=2, max_len=64,
                                chunk_size=16)
            r = Request(0, prompt=list(pa), max_new_tokens=6)
            eng.submit(r)
            eng.run(max_steps=60)
            return r.out_tokens

        solo = run_solo()
        eng = ServingEngine(cfg, params, dp=1, b_local=2, max_len=64,
                            chunk_size=16)
        ra = Request(0, prompt=list(pa), max_new_tokens=6)
        eng.submit(ra)
        eng.step(); eng.step()
        pb = pa[:18] + [230, 231, 232, 233]
        rb = Request(1, prompt=list(pb), max_new_tokens=6)
        eng.submit(rb)
        eng.run(max_steps=60)
        assert ra.done and rb.done
        assert eng.stats["prefix_shared_reqs"] == 1
        assert ra.out_tokens == solo, "sharer's appends corrupted the donor"
        assert rb.out_tokens != solo or pb == pa         # truly divergent
        assert eng.page_occupancy() == 0.0

    def test_hot_prefix_halves_pages_with_identical_tokens(self, engine_setup):
        """90%-shared-prefix workload: >= 2x fewer pages-in-use (mean
        over steps), token-identical outputs vs the unshared path."""
        cfg, params = engine_setup
        rng = np.random.RandomState(0)
        hot = list(rng.randint(1, 255, 68))              # 8.5 pages of 8
        prompts = [hot + list(rng.randint(1, 255, 6)) for _ in range(12)]

        def run(share):
            eng = ServingEngine(cfg, params, dp=1, b_local=6, max_len=96,
                                chunk_size=16, prefix_sharing=share)
            reqs = [Request(0, prompt=list(prompts[0]), max_new_tokens=8)]
            eng.submit(reqs[0])
            for _ in range(5):                           # donor prefills
                eng.step()
            for i, p in enumerate(prompts[1:], 1):
                r = Request(i, prompt=list(p), max_new_tokens=8)
                reqs.append(r)
                eng.submit(r)
            eng.run(max_steps=500)
            assert all(r.done for r in reqs)
            assert eng.page_occupancy() == 0.0
            return [r.out_tokens for r in reqs], eng

        out_u, eng_u = run(False)
        out_s, eng_s = run(True)
        assert out_s == out_u, "prefix sharing changed emitted tokens"
        assert eng_s.stats["prefix_shared_reqs"] >= 10
        ratio = eng_u.pages_mean() / max(eng_s.pages_mean(), 1e-9)
        assert ratio >= 2.0, (
            f"pages-in-use only improved {ratio:.2f}x "
            f"({eng_u.pages_mean():.1f} -> {eng_s.pages_mean():.1f})")

    def test_long_prompt_suffix_after_share_is_never_denied(self, engine_setup):
        """Regression (review finding): the COW page must come from the
        SHARED pool, not the slot's lane — taking it from the lane left
        the first post-share chunk (which may need a full ell pages)
        short, and a denied chunk silently dropped prompt tokens while
        the host advanced.  Repro: short shared prefix, long remaining
        prompt (first chunk needs 2 pages with ell=2)."""
        cfg, params = engine_setup                       # psz=8
        rng = np.random.RandomState(3)
        hot = list(rng.randint(1, 255, 20))              # 2.5 pages shared
        prompts = [hot + list(rng.randint(1, 255, 20)) for _ in range(4)]

        def run(share):
            eng = ServingEngine(cfg, params, dp=1, b_local=2, max_len=96,
                                chunk_size=16, prefix_sharing=share)
            reqs = [Request(0, prompt=list(prompts[0]), max_new_tokens=6)]
            eng.submit(reqs[0])
            for _ in range(4):
                eng.step()                               # donor prefills
            for i, p in enumerate(prompts[1:], 1):
                r = Request(i, prompt=list(p), max_new_tokens=6)
                reqs.append(r)
                eng.submit(r)
            eng.run(max_steps=200)
            assert all(r.done for r in reqs)
            return [r.out_tokens for r in reqs], eng

        out_u, _ = run(False)
        out_s, eng_s = run(True)
        assert eng_s.stats["prefix_shared_reqs"] >= 3
        assert out_s == out_u, (
            "post-share chunk was denied pages (lane raided for COW)")
        assert eng_s.page_occupancy() == 0.0

    def test_pinned_prefix_survives_idle_gap(self, engine_setup):
        """DESIGN.md §8: with a pin budget, a hot prefix outlives its
        last request — a second wave arriving after a full drain
        re-shares it from the cache-owned pages instead of re-prefilling
        (measured as fewer prompt tokens fed), with identical outputs;
        with pinning off, the drain kills the prefix and the full
        prefill cost comes back."""
        cfg, params = engine_setup                       # psz = 8
        from repro.serving.sched import SchedConfig
        rng = np.random.RandomState(11)
        hot = list(rng.randint(1, 255, 32))              # 4 whole pages
        waves = [[hot + list(rng.randint(1, 255, 4)) for _ in range(3)]
                 for _ in range(2)]

        def run(pin_pages):
            eng = ServingEngine(cfg, params, dp=1, b_local=3, max_len=96,
                                chunk_size=16,
                                sched=SchedConfig(pin_pages=pin_pages))
            outs = []
            for w, wave in enumerate(waves):
                reqs = [Request(w * 10 + i, prompt=list(p),
                                max_new_tokens=4)
                        for i, p in enumerate(wave)]
                for r in reqs:
                    eng.submit(r)
                eng.run(max_steps=300)                   # drain to idle
                assert all(r.done for r in reqs)
                outs.append([r.out_tokens for r in reqs])
            return outs, eng

        out_pin, eng_pin = run(pin_pages=8)
        out_raw, eng_raw = run(pin_pages=0)
        assert out_pin == out_raw, "pinning changed emitted tokens"
        # wave 2 re-shared the hot pages from the pin across the drain
        assert eng_pin.stats["pin_hit_reqs"] >= 1
        saved = (eng_raw.stats["prompt_tokens"]
                 - eng_pin.stats["prompt_tokens"])
        assert saved >= len(hot) - cfg.page_size, (
            f"pinning saved only {saved} prompt tokens")
        # drain leaves exactly the pinned pages; flush reclaims all
        assert eng_pin.pages_in_use() == eng_pin.pinned_pages() > 0
        eng_pin.flush_pins()
        assert eng_pin.page_occupancy() == 0.0
        assert eng_raw.page_occupancy() == 0.0

    def test_identical_prompts_on_two_shards_share_shard_locally(
            self, engine_setup):
        """Regression (DESIGN.md §9): the same hot prompt lands on both
        shards; every share must use a donor on the request's OWN shard
        — an exact-key donor on the other shard is rejected (the
        engine's cross-shard assert would trip), and a request placed
        on a donor-less shard admits unshared rather than aliasing
        foreign page ids.  Outputs match the unshared run throughout."""
        cfg, params = engine_setup                       # psz = 8
        rng = np.random.RandomState(21)
        hot = list(rng.randint(1, 255, 20))              # 2.5 pages

        def mk():
            return ServingEngine(cfg, params, dp=2, b_local=2, max_len=64,
                                 chunk_size=16)

        eng = mk()
        ra = Request(0, prompt=list(hot), max_new_tokens=12)
        eng.submit(ra)
        eng.step(); eng.step()                           # A's prompt in KV
        shard_a = ra.slot // eng.bl

        rb = Request(1, prompt=list(hot), max_new_tokens=12)
        eng.submit(rb)
        eng.step()
        assert eng.stats["prefix_shared_reqs"] == 1
        assert rb.slot // eng.bl == shard_a, (
            "B must be placed next to its only donor")

        # shard_a is now full: C lands on the other shard, where the
        # exact-key donors are unreachable — it must admit UNSHARED
        rc = Request(2, prompt=list(hot), max_new_tokens=4)
        eng.submit(rc)
        eng.step()
        assert rc.slot // eng.bl == 1 - shard_a
        assert eng.stats["prefix_shared_reqs"] == 1, (
            "cross-shard donor was used for an exact-key match")
        eng.step()                                       # C's pages resident

        # D: donors now exist on BOTH shards; only shard 1-shard_a has
        # a free slot, so D must share from C there, shard-locally
        rd = Request(3, prompt=list(hot), max_new_tokens=4)
        eng.submit(rd)
        eng.step()
        assert rd.slot // eng.bl == 1 - shard_a
        assert eng.stats["prefix_shared_reqs"] == 2
        eng.run(max_steps=200)
        assert all(r.done for r in (ra, rb, rc, rd))
        assert eng.page_occupancy() == 0.0

        ref = mk()
        ref_reqs = [Request(10 + i, prompt=list(hot), max_new_tokens=mn)
                    for i, mn in enumerate((12, 12, 4, 4))]
        ref.prefix_cache = None                          # unshared baseline
        for r in ref_reqs:
            ref.submit(r)
        ref.run(max_steps=200)
        assert [r.out_tokens for r in (ra, rb, rc, rd)] == \
            [r.out_tokens for r in ref_reqs]

    def test_sharing_disabled_for_non_paged_archs(self):
        """Ring / recurrent layers cannot share prefixes (their state at
        the match point no longer exists) — the engine must auto-disable
        rather than corrupt outputs."""
        cfg = smoke_config(get_config("recurrentgemma-2b"))
        params = models.init_params(cfg, jax.random.PRNGKey(0))
        eng = ServingEngine(cfg, params, dp=1, b_local=2, max_len=64)
        assert eng.prefix_cache is None
