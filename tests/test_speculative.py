"""Speculative decode on shared prefixes + SLO-aware chunk sizing
(DESIGN.md §10): token identity, key-stream determinism, whole-page
rollback accounting, the speculative-episode checker (and its
self-tests), and the torn-rebalance draft-rejection storm.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import models
from repro.configs import get_config, smoke_config
from repro.core import hier_pool, kv_cache
from repro.core.linearizability import check_speculative_history
from repro.core.sim import OpRecord
from repro.serving.engine import Request, ServingEngine
from repro.serving.sched import SchedConfig


@pytest.fixture(scope="module")
def engine_setup():
    cfg = smoke_config(get_config("olmo-1b"))
    params = models.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _greedy_reference(cfg, params, prompts, max_new=6, dp=1, b_local=2):
    eng = ServingEngine(cfg, params, dp=dp, b_local=b_local, max_len=64)
    reqs = [Request(i, prompt=list(p), max_new_tokens=max_new)
            for i, p in enumerate(prompts)]
    for r in reqs:
        eng.submit(r)
    eng.run(max_steps=500)
    assert all(r.done for r in reqs)
    return [r.out_tokens for r in reqs]


# ================================================= 1. engine identity

class TestSpeculativeIdentity:
    def test_greedy_token_identity_and_invariants(self, engine_setup):
        """Hot-prefix repeat traffic with speculation on: outputs are
        bit-identical to the non-speculative run of the same trace,
        drafts are actually accepted (the feature fired), and after
        EVERY verify/rollback step each shard conserves pages
        (free + live == pages_local) and keeps §4.2's never-dry
        min(private_top) >= ell."""
        cfg, params = engine_setup
        rng = np.random.RandomState(0)
        hot = list(rng.randint(1, 255, 16))          # 2 pages of 8
        prompts = [list(hot) for _ in range(6)] + \
                  [list(rng.randint(1, 255, 10)) for _ in range(3)]
        ref = _greedy_reference(cfg, params, prompts, dp=2)

        eng = ServingEngine(cfg, params, dp=2, b_local=2, max_len=64,
                            speculate=True, draft_len=4)
        ell = hier_pool.lane_ell(eng.state.pool.classes[0])
        reqs = [Request(i, prompt=list(p), max_new_tokens=6)
                for i, p in enumerate(prompts)]
        for r in reqs:
            eng.submit(r)
        for _ in range(400):
            if eng.idle():
                break
            eng.step()
            kv = eng.state.pool.classes[0]
            free_s = np.asarray(hier_pool.free_per_shard(kv))
            live_s = np.asarray(hier_pool.live_per_shard(kv))
            assert np.all(free_s + live_s == eng.pages_local), (
                f"per-shard conservation broken after a step "
                f"(free={free_s.tolist()} live={live_s.tolist()})")
            tops = np.asarray(kv.private_top)
            assert tops.min() >= ell, (
                f"a lane ran dry after a verify/rollback step "
                f"(min={tops.min()}, ell={ell}) — §4.2 violated")
        assert all(r.done for r in reqs)
        assert [r.out_tokens for r in reqs] == ref, \
            "speculation changed greedy output"
        assert eng.stats["spec_accepted"] > 0, "no draft ever accepted"
        assert eng.page_occupancy() == 0.0

    def test_rejected_drafts_roll_back_pages(self, engine_setup):
        """A continuation that matches one real token then diverges:
        every draft is rejected, the whole-page over-allocation is
        rolled back (counted), and output still equals the
        non-speculative stream."""
        cfg, params = engine_setup
        rng = np.random.RandomState(1)
        # 23-token prompt: decode sits mid-page, so a rejected 4-draft
        # lane over-allocates a page that must come back
        prompt = list(rng.randint(1, 255, 23))
        ref = _greedy_reference(cfg, params, [prompt], dp=1, b_local=1)[0]

        eng = ServingEngine(cfg, params, dp=1, b_local=1, max_len=64,
                            speculate=True, draft_len=4)
        key = eng.spec_store.key_of(prompt)
        tail = tuple(prompt[len(key):])
        garbage = tuple((t + 101) % (cfg.vocab - 2) + 1 for t in ref)
        eng.spec_store.record(key, tail + (ref[0],) + garbage)
        r = Request(0, prompt=list(prompt), max_new_tokens=6)
        eng.submit(r)
        eng.run(max_steps=300)
        assert r.done and r.out_tokens == ref
        assert eng.stats["spec_drafted"] > 0
        assert eng.stats["spec_accepted"] == 0
        assert eng.stats["spec_pages_rolled_back"] > 0
        assert eng.stats["accept_hist"].get(0, 0) >= 1
        assert eng.page_occupancy() == 0.0


# ====================================== 2. sampled-key determinism

class TestSampledSpecDeterminism:
    """The fold_in(seed, out_count) key stream must be exactly the
    one-token-at-a-time stream: position i of a draft lane draws key
    out_count + i, acceptance consumes keys in order, rollback never
    skips one."""

    def _sampled_run(self, cfg, params, prompt, seed=7, speculate=False,
                     cont=None):
        eng = ServingEngine(cfg, params, dp=1, b_local=1, max_len=64,
                            speculate=speculate, draft_len=4)
        if cont is not None:
            key = eng.spec_store.key_of(prompt)
            eng.spec_store.record(key, tuple(prompt[len(key):]) + cont)
        r = Request(0, prompt=list(prompt), max_new_tokens=6,
                    temperature=0.9, top_k=12, seed=seed)
        eng.submit(r)
        eng.run(max_steps=300)
        assert r.done
        return r.out_tokens, eng

    def test_all_rejected_drafts_bit_identical(self, engine_setup):
        cfg, params = engine_setup
        rng = np.random.RandomState(3)
        prompt = list(rng.randint(1, 255, 16))
        ref, _ = self._sampled_run(cfg, params, prompt)
        # drafts: the real first sampled token (so the lane fires) then
        # off-vocab-shifted garbage -> every draft rejected
        garbage = tuple((t + 77) % (cfg.vocab - 2) + 1 for t in ref[1:])
        out, eng = self._sampled_run(cfg, params, prompt, speculate=True,
                                     cont=(ref[0],) + garbage)
        assert eng.stats["spec_drafted"] > 0
        assert eng.stats["spec_accepted"] == 0
        assert out == ref, ("all-rejected speculative sampling must be "
                            "bit-identical to non-speculative decode")

    def test_partial_accept_never_skips_keys(self, engine_setup):
        """Continuation = the true sampled stream's first 3 tokens, then
        garbage: the lane accepts a partial prefix, and the resumed key
        indices continue exactly where the accepted stream stopped —
        the full output still equals the non-speculative stream."""
        cfg, params = engine_setup
        rng = np.random.RandomState(4)
        prompt = list(rng.randint(1, 255, 16))
        ref, _ = self._sampled_run(cfg, params, prompt)
        cont = tuple(ref[:3]) + ((ref[3] + 55) % (cfg.vocab - 2) + 1,
                                 (ref[4] + 55) % (cfg.vocab - 2) + 1)
        out, eng = self._sampled_run(cfg, params, prompt, speculate=True,
                                     cont=cont)
        assert eng.stats["spec_accepted"] > 0, "no partial accept fired"
        assert eng.stats["spec_accepted"] < eng.stats["spec_drafted"]
        assert out == ref, ("partial accept skipped or reused a sampling "
                            "key — keyed stream diverged")

    def test_full_accept_matches_sampled_stream(self, engine_setup):
        """Recording the true sampled continuation makes every draft an
        accept and the output is still the same stream."""
        cfg, params = engine_setup
        rng = np.random.RandomState(5)
        prompt = list(rng.randint(1, 255, 16))
        ref, _ = self._sampled_run(cfg, params, prompt)
        out, eng = self._sampled_run(cfg, params, prompt, speculate=True,
                                     cont=tuple(ref))
        assert eng.stats["spec_accepted"] > 0
        assert out == ref
        assert eng.stats["steps"] > 0


# =========================================== 3. SLO-aware chunk sizing

class TestChunkBuckets:
    def test_prefill_shrinks_when_interactive_waits(self, engine_setup):
        """With buckets configured, batch-class prefill runs full-width
        until interactive work arrives, then shrinks to the smallest
        bucket — and the emitted tokens are identical to the fixed-chunk
        run (lane width is output-invisible)."""
        cfg, params = engine_setup
        rng = np.random.RandomState(6)
        std = [list(rng.randint(1, 255, 28)) for _ in range(2)]
        inter = list(rng.randint(1, 255, 6))

        def run(buckets):
            eng = ServingEngine(cfg, params, dp=1, b_local=2, max_len=64,
                                chunk_size=16,
                                sched=SchedConfig(chunk_buckets=buckets))
            reqs = [Request(i, prompt=list(p), max_new_tokens=4,
                            slo="batch") for i, p in enumerate(std)]
            for r in reqs:
                eng.submit(r)
            eng.step()                       # full-width prefill step
            ri = Request(9, prompt=list(inter), max_new_tokens=4,
                         slo="interactive")
            eng.submit(ri)
            eng.run(max_steps=300)
            assert all(r.done for r in reqs + [ri])
            assert eng.page_occupancy() == 0.0
            return [r.out_tokens for r in reqs + [ri]], eng

        out_fixed, eng_fixed = run(())
        out_adapt, eng_adapt = run((1, 4))
        assert out_adapt == out_fixed, "chunk sizing changed tokens"
        hist = eng_adapt.stats["chunk_hist"]
        assert hist.get(16), "full-width prefill never ran"
        assert hist.get(1) or hist.get(4), (
            f"prefill never shrank for the waiting interactive class "
            f"(lane hist {hist})")
        assert set(eng_fixed.stats["chunk_hist"]) <= {1, 16}

    def test_pick_chunk_policy(self, engine_setup):
        """Unit: no latency pressure -> full chunk; interactive queued
        or decoding over lower-priority prefill -> smallest bucket."""
        cfg, params = engine_setup
        eng = ServingEngine(cfg, params, dp=1, b_local=2, max_len=64,
                            chunk_size=16,
                            sched=SchedConfig(chunk_buckets=(4, 8)))
        sched = eng.scheduler
        assert sched.buckets(16) == (4, 8, 16)
        assert sched.pick_chunk(eng, 16) == 16          # idle queue
        # a queued interactive head + lower-priority prefill -> shrink
        eng.submit(Request(0, prompt=[1] * 24, max_new_tokens=2,
                           slo="batch"))
        eng.step()
        assert eng.pending_tokens, "prefill should still be pending"
        eng.scheduler.queues["interactive"].append(
            Request(1, prompt=[2, 3], max_new_tokens=2,
                    slo="interactive"))
        assert sched.pick_chunk(eng, 16) == 4
        eng.scheduler.queues["interactive"].clear()
        assert sched.pick_chunk(eng, 16) == 16


# ------------------------------------------------- pin-gate regression

def test_pin_waits_for_final_whole_page_chunk(engine_setup):
    """Regression (review finding): the feed-build `_fed` update must
    land AFTER the feed-time pin gate.  A 3-whole-page prompt whose
    final page arrives in the last chunk must pin on the post-status
    path (after the step wrote the page) — pinning at feed build would
    capture a NULL table entry, and the pin could never donate."""
    cfg, params = engine_setup                      # page_size = 8
    rng = np.random.RandomState(8)
    prompt = list(rng.randint(1, 255, 24))          # exactly 3 pages
    eng = ServingEngine(cfg, params, dp=1, b_local=2, max_len=64,
                        chunk_size=8,
                        sched=SchedConfig(pin_pages=8))
    r0 = Request(0, prompt=list(prompt), max_new_tokens=3)
    eng.submit(r0)
    eng.run(max_steps=100)
    assert r0.done
    assert eng.stats["pins_created"] == 1
    pin = next(iter(eng.pins.entries.values()))
    assert pin["pages"] == 3
    row = np.asarray(eng.pin_tables)[pin["shard"], pin["row"]]
    assert (row[:3] >= 0).all(), f"pin row holds NULL pages: {row[:4]}"
    # the pin must actually donate to an identical follow-up
    r1 = Request(1, prompt=list(prompt), max_new_tokens=3)
    eng.submit(r1)
    eng.run(max_steps=100)
    assert r1.done and r1.out_tokens == r0.out_tokens
    assert eng.stats["pin_hit_reqs"] == 1, "pinned prefix never donated"
    eng.flush_pins()
    assert eng.page_occupancy() == 0.0


# ============================================ 4. cache-level rollback

def test_kv_cache_rollback_frees_empty_pages():
    """kv_cache.rollback un-appends a tail: pages left holding no token
    return to the pool (shared pages just drop a reference), the
    partial surviving page stays mapped, and conservation holds."""
    cache = kv_cache.create(num_pages=32, page_size=4, kv_heads=1,
                            head_dim=8, max_seqs=2, max_pages_per_seq=6)
    k = jnp.ones((2, 10, 1, 8))
    v = jnp.ones((2, 10, 1, 8))
    lens = jnp.asarray([10, 7], jnp.int32)
    cache, ok = kv_cache.append_chunk(cache, k, v, lens)
    assert bool(ok.all())
    free0 = int(cache.pool.top)
    # seq0: 10 -> 5 tokens (pages 3 -> 2: one page freed);
    # seq1: 7 -> 7 (no-op)
    cache = kv_cache.rollback(cache, jnp.asarray([5, 0], jnp.int32))
    assert [int(x) for x in cache.seq_lens] == [5, 7]
    assert int(cache.pool.top) == free0 + 1
    assert int(cache.page_tables[0, 2]) == -1, "emptied page still mapped"
    assert int(cache.page_tables[0, 1]) >= 0, "partial page unmapped"
    # the surviving prefix still reads back intact
    kk, vv, valid = kv_cache.gather_kv(cache, 0, 8)
    assert int(valid.sum()) == 5
    # conservation: free + live == num_pages
    live = int(kv_cache.block_pool.num_live(cache.pool))
    assert int(cache.pool.top) + live == 32


# ======================================= 5. episode checker self-tests

def _op(opid, name, pid=0, arg=None, result=None, t0=0, t1=1, meta=None):
    rec = OpRecord(opid=opid, pid=pid, name=name, arg=arg,
                   invoke_step=t0, result=result, response_step=t1)
    rec.meta.update(meta or {})
    return rec


class TestSpeculativeChecker:
    def test_clean_episode_passes(self):
        hist = [
            _op(1, "alloc_n", result=[4, 5, 6],
                meta={"spec": "e0", "shard": 1}),
            _op(2, "spec_rollback", arg=[5, 6], t0=2, t1=3,
                meta={"spec": "e0", "shard": 1, "kept": [4]}),
        ]
        assert check_speculative_history(hist) == []

    def test_full_accept_needs_no_rollback(self):
        hist = [_op(1, "alloc_n", result=[7, 8],
                    meta={"spec": "e1", "shard": 0, "kept": [7, 8]})]
        assert check_speculative_history(hist) == []

    def test_leak_detected(self):
        hist = [
            _op(1, "alloc_n", result=[4, 5, 6],
                meta={"spec": "e0", "shard": 0}),
            _op(2, "spec_rollback", arg=[5], t0=2, t1=3,
                meta={"spec": "e0", "shard": 0, "kept": [4]}),
        ]
        errs = check_speculative_history(hist)
        assert any("leak" in e and "[6]" in e for e in errs), errs

    def test_theft_detected(self):
        # rollback frees a page the episode kept
        hist = [
            _op(1, "alloc_n", result=[4, 5],
                meta={"spec": "e0", "shard": 0}),
            _op(2, "spec_rollback", arg=[4, 5], t0=2, t1=3,
                meta={"spec": "e0", "shard": 0, "kept": [4]}),
        ]
        errs = check_speculative_history(hist)
        assert any("theft" in e and "[4]" in e for e in errs), errs

    def test_foreign_page_theft_detected(self):
        # rollback frees a page granted to ANOTHER lane (never to this
        # episode): episode theft + cross-lane free-while-available
        hist = [
            _op(1, "alloc_n", pid=1, result=[9]),
            _op(2, "alloc_n", result=[4, 5],
                meta={"spec": "e0", "shard": 0}, t0=1, t1=2),
            _op(3, "spec_rollback", arg=[5, 9], t0=3, t1=4,
                meta={"spec": "e0", "shard": 0, "kept": [4]}),
        ]
        errs = check_speculative_history(hist)
        assert any("theft" in e and "9" in e for e in errs), errs

    def test_kept_not_granted_detected(self):
        hist = [
            _op(1, "alloc_n", result=[4],
                meta={"spec": "e0", "shard": 0}),
            _op(2, "spec_rollback", arg=[4], t0=2, t1=3,
                meta={"spec": "e0", "shard": 0, "kept": [12]}),
        ]
        errs = check_speculative_history(hist)
        assert any("never granted" in e for e in errs), errs

    def test_cross_shard_rollback_detected(self):
        hist = [
            _op(1, "alloc_n", result=[4, 5],
                meta={"spec": "e0", "shard": 0}),
            _op(2, "spec_rollback", arg=[5], t0=2, t1=3,
                meta={"spec": "e0", "shard": 1, "kept": [4]}),
        ]
        errs = check_speculative_history(hist)
        assert any("span shards" in e for e in errs), errs
        assert any("cross-shard theft" in e for e in errs), errs


# ====================================== 6. torn-rebalance draft storm

class TestSpecRollbackStorm:
    """Adversarial storm: draft lanes over-allocate, verify, and roll
    back WHILE the rebalancer sits inside its torn drain/refill window —
    the §4.2 worst case the draft-page ownership rules must survive
    (DESIGN.md §10)."""

    def _storm(self, seed, leak_lane=None):
        import random
        from repro.core import Scheduler, SimContext
        L, ell, kmax = 3, 4, 4
        st = {"pool": hier_pool.create(num_blocks=96, num_lanes=L, ell=ell),
              "held": {lane: [] for lane in range(L)}}
        total0 = int(hier_pool.total_free(st["pool"]))
        ctx = SimContext(L + 1, seed=seed)
        sched = Scheduler(seed=seed)
        eid = [0]

        def lane_program(lane):
            rng = random.Random(seed * 17 + lane)
            held = st["held"][lane]
            for _ in range(20):
                yield                                 # scheduling point
                # --- speculative episode: over-allocate a draft lane
                want = rng.randint(1, kmax)
                counts = np.zeros(L, np.int32)
                counts[lane] = want
                ep = f"s{seed}-{eid[0]}"
                eid[0] += 1
                rec = ctx.begin_op(lane, "alloc_n", arg=want)
                rec.meta.update(spec=ep, shard=0)
                rec.invoke_step = sched.steps
                yield
                pool, ids = hier_pool.alloc_n(
                    st["pool"], jnp.asarray(counts), kmax)
                st["pool"] = pool
                got = [int(i) for i in np.asarray(ids)[lane] if i >= 0]
                yield
                ctx.end_op(rec, result=got)
                rec.response_step = sched.steps
                if not got:
                    continue
                # --- verify: accept a prefix, reject the rest; the
                # rollback happens INSIDE whatever rebalance window the
                # scheduler has the rebalancer parked in
                a = rng.randint(0, len(got))
                kept, rejected = got[:a], got[a:]
                if leak_lane == lane and rejected:
                    rejected = rejected[:-1]        # bug injection: leak
                held.extend(kept)
                back = np.full((L, kmax), -1, np.int32)
                back[lane, :len(rejected)] = rejected
                rec = ctx.begin_op(lane, "spec_rollback", arg=rejected)
                rec.meta.update(spec=ep, shard=0, kept=kept)
                rec.invoke_step = sched.steps
                yield
                st["pool"] = hier_pool.free_n(st["pool"],
                                              jnp.asarray(back))
                yield
                ctx.end_op(rec)
                rec.response_step = sched.steps
                # occasionally release committed pages (normal free)
                if held and rng.random() < 0.4:
                    k = rng.randint(1, min(len(held), kmax))
                    rel = held[-k:]
                    ids = np.full((L, kmax), -1, np.int32)
                    ids[lane, :k] = rel
                    rec = ctx.begin_op(lane, "free_n", arg=rel)
                    rec.meta.update(shard=0)
                    rec.invoke_step = sched.steps
                    yield
                    st["pool"] = hier_pool.free_n(st["pool"],
                                                  jnp.asarray(ids))
                    del held[-k:]
                    yield
                    ctx.end_op(rec)
                    rec.response_step = sched.steps

        def rebalancer(pid):
            for _ in range(60):
                yield
                st["pool"] = hier_pool.rebalance_drain(st["pool"])
                yield              # <-- torn window: rollbacks land here
                st["pool"] = hier_pool.rebalance_refill(st["pool"])

        for lane in range(L):
            sched.add(lane, lane_program(lane))
        sched.add(L, rebalancer(L))
        sched.run("bursty")

        errs = check_speculative_history(ctx.history)
        live = sum(len(h) for h in st["held"].values())
        if leak_lane is None:
            assert errs == [], errs
            assert int(hier_pool.total_free(st["pool"])) + live == total0
            assert int(hier_pool.num_live(st["pool"])) == live
        return errs

    def test_storm_rollbacks_in_torn_window_conserve(self):
        for seed in (0, 1, 2):
            self._storm(seed)

    def test_storm_checker_catches_injected_leak(self):
        errs = self._storm(3, leak_lane=1)
        assert any("leak" in e for e in errs), (
            "injected rejected-draft leak went undetected")


# ============================================= 7. mesh: one sync, one
# collective per speculative step (dp=4 — the mesh-8 CI job)

@pytest.mark.skipif(len(jax.devices()) < 4, reason="mesh-8 CI job")
def test_speculative_step_one_sync_one_collective(engine_setup):
    """On the dp=4 shard_map plane a draft+verify+rollback step still
    performs exactly ONE device->host sync (the packed status, now
    carrying up to draft_len+1 tokens per slot) and compiles exactly
    ONE collective (the status all_gather)."""
    cfg, params = engine_setup
    rng = np.random.RandomState(9)
    prompt = list(rng.randint(1, 255, 16))
    ref = _greedy_reference(cfg, params, [prompt], max_new=8,
                            dp=1, b_local=1)[0]

    eng = ServingEngine(cfg, params, dp=4, b_local=2, max_len=64,
                        speculate=True, draft_len=3)
    assert eng.mesh is not None
    key = eng.spec_store.key_of(prompt)
    eng.spec_store.record(key, tuple(prompt[len(key):]) + tuple(ref))
    for i in range(4):
        eng.submit(Request(i, prompt=list(prompt), max_new_tokens=8))
    eng.step()                            # admission + first prefill chunk
    while any(eng.pending_tokens.get(s) for s in eng.active):
        eng.step()

    import repro.serving.engine as engine_mod
    syncs = []
    real_asarray = np.asarray

    class CountingNp:
        def __getattr__(self, name):
            return getattr(np, name)

        @staticmethod
        def asarray(x, *a, **kw):
            if isinstance(x, jax.Array):
                syncs.append(x.shape)
            return real_asarray(x, *a, **kw)

    orig = engine_mod.np
    engine_mod.np = CountingNp()
    try:
        steps0 = eng.stats["steps"]
        for _ in range(2):
            eng.step()
    finally:
        engine_mod.np = orig
    assert eng.stats["steps"] == steps0 + 2
    assert len(syncs) == 2, f"expected 1 sync/step, saw {syncs}"
    # width-4 draft lanes: status is [spec_T + 3 + N_CTR, DP, Bl]
    from repro.serving.telemetry import N_CTR
    assert all(s == (eng._spec_T + 3 + N_CTR, 4, 2) for s in syncs), syncs
    assert eng.stats["spec_lanes"] > 0, "steps were not speculative"

    # exactly one collective in the compiled speculative step
    hlo = eng._serve_variants[(False, True)].lower(
        eng.params, eng.state, eng.last_tok, eng.out_count, eng.budget,
        eng.temps, eng.topks, eng.seeds,
        jnp.zeros((4, 2, eng._spec_T), jnp.int32),
        jnp.zeros((4, 2), jnp.int32),
        jnp.zeros((4, 2), bool), jnp.zeros((4, 2), bool),
        eng.expert_mask,
    ).compile().as_text()
    n_gather = hlo.count("all-gather(") + hlo.count("all-gather-start(")
    n_other = sum(hlo.count(c) for c in
                  ("all-reduce(", "all-reduce-start(", "all-to-all(",
                   "collective-permute(", "collective-permute-start("))
    assert n_gather == 1, f"expected exactly one all_gather, HLO has {n_gather}"
    assert n_other == 0, "unexpected extra collectives in the step"

    eng.run(max_steps=300)
    assert eng.page_occupancy() == 0.0
