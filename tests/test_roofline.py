"""Unit tests for the HLO-walking roofline cost model."""

import textwrap

import pytest

from repro.launch import roofline as rf

HLO = textwrap.dedent("""
    HloModule test

    %fused_dus (p0: f32[10,128,128], p1: f32[1,128,128], p2: s32[]) -> f32[10,128,128] {
      %p0 = f32[10,128,128]{2,1,0} parameter(0)
      %p1 = f32[1,128,128]{2,1,0} parameter(1)
      %p2 = s32[] parameter(2)
      %c0 = s32[] constant(0)
      ROOT %dus = f32[10,128,128]{2,1,0} dynamic-update-slice(%p0, %p1, %p2, %c0, %c0)
    }

    %body (arg: (s32[], f32[64,64], f32[8,64,64])) -> (s32[], f32[64,64], f32[8,64,64]) {
      %arg = (s32[], f32[64,64]{1,0}, f32[8,64,64]{2,1,0}) parameter(0)
      %i = s32[] get-tuple-element(%arg), index=0
      %x = f32[64,64]{1,0} get-tuple-element(%arg), index=1
      %ws = f32[8,64,64]{2,1,0} get-tuple-element(%arg), index=2
      %c0 = s32[] constant(0)
      %w = f32[1,64,64]{2,1,0} dynamic-slice(%ws, %i, %c0, %c0), dynamic_slice_sizes={1,64,64}
      %wb = f32[64,64]{1,0} bitcast(%w)
      %dot = f32[64,64]{1,0} dot(%x, %wb), lhs_contracting_dims={1}, rhs_contracting_dims={0}
      %ar = f32[64,64]{1,0} all-reduce(%dot), replica_groups={}, to_apply=%add_comp
      %c1 = s32[] constant(1)
      %ip = s32[] add(%i, %c1)
      ROOT %t = (s32[], f32[64,64]{1,0}, f32[8,64,64]{2,1,0}) tuple(%ip, %ar, %ws)
    }

    %cond (arg: (s32[], f32[64,64], f32[8,64,64])) -> pred[] {
      %arg = (s32[], f32[64,64]{1,0}, f32[8,64,64]{2,1,0}) parameter(0)
      %i = s32[] get-tuple-element(%arg), index=0
      %n = s32[] constant(8)
      ROOT %lt = pred[] compare(%i, %n), direction=LT
    }

    %add_comp (a: f32[], b: f32[]) -> f32[] {
      %a = f32[] parameter(0)
      %b = f32[] parameter(1)
      ROOT %s = f32[] add(%a, %b)
    }

    ENTRY %main (x: f32[64,64], ws: f32[8,64,64], big: f32[10,128,128], upd: f32[1,128,128]) -> f32[64,64] {
      %x = f32[64,64]{1,0} parameter(0)
      %ws = f32[8,64,64]{2,1,0} parameter(1)
      %big = f32[10,128,128]{2,1,0} parameter(2)
      %upd = f32[1,128,128]{2,1,0} parameter(3)
      %c0 = s32[] constant(0)
      %f = f32[10,128,128]{2,1,0} fusion(%big, %upd, %c0), kind=kLoop, calls=%fused_dus
      %init = (s32[], f32[64,64]{1,0}, f32[8,64,64]{2,1,0}) tuple(%c0, %x, %ws)
      %loop = (s32[], f32[64,64]{1,0}, f32[8,64,64]{2,1,0}) while(%init), condition=%cond, body=%body
      ROOT %out = f32[64,64]{1,0} get-tuple-element(%loop), index=1
    }
""")


class TestParser:
    def test_computations_split(self):
        comps = rf.parse_hlo(HLO)
        assert {"fused_dus", "body", "cond", "add_comp", "main"} <= set(comps)

    def test_while_trip_count_multiplier(self):
        comps = rf.parse_hlo(HLO)
        mult = rf._call_multipliers(comps)
        assert mult["body"] == 8.0         # constant(8) in the condition
        assert mult["main"] == 1.0

    def test_dot_flops_with_trip_count(self):
        cost = rf.analyze_hlo(HLO)
        # one 64x64x64 dot per iteration, 8 iterations
        assert cost.flops == pytest.approx(8 * 2 * 64 * 64 * 64)

    def test_collective_bytes_with_factor_and_trip(self):
        cost = rf.analyze_hlo(HLO)
        # all-reduce of f32[64,64] x8 iterations x2 (ring factor)
        assert cost.coll_bytes_weighted == pytest.approx(8 * 64 * 64 * 4 * 2)
        assert cost.coll_counts["all-reduce"] == 8

    def test_dus_fusion_charged_as_slice(self):
        comps = rf.parse_hlo(HLO)
        assert rf._dus_update_bytes(comps["fused_dus"]) == 1 * 128 * 128 * 4
        cost = rf.analyze_hlo(HLO)
        # the DUS fusion must NOT be charged the 10x full buffer twice
        assert cost.bytes < 3 * 10 * 128 * 128 * 4 + 8 * 6 * 64 * 64 * 4 + 1e5

    def test_promoted_allreduce_halved(self):
        hlo = HLO.replace("to_apply=%add_comp", "to_apply=%add_comp_promoted")
        cost = rf.analyze_hlo(hlo)
        assert cost.coll_bytes_weighted == pytest.approx(8 * 64 * 64 * 4)

    def test_shape_bytes(self):
        assert rf._shape_bytes("bf16[4,8]{1,0}") == 64
        assert rf._shape_bytes("(f32[2,2]{1,0}, s32[3]{0})") == 28
        assert rf._shape_bytes("pred[]") == 1


class TestRoofline:
    def test_terms_and_bottleneck(self):
        r = rf.Roofline(chips=256, flops=1.97e14, hbm_bytes=8.19e11,
                        coll_bytes=5e10, model_flops=1e16)
        assert r.t_compute == pytest.approx(1.0)
        assert r.t_memory == pytest.approx(1.0)
        assert r.t_collective == pytest.approx(1.0)
        r2 = rf.Roofline(chips=256, flops=1e12, hbm_bytes=8.19e12,
                         coll_bytes=1e9)
        assert r2.bottleneck == "memory"

    def test_mfu_bound(self):
        r = rf.Roofline(chips=1, flops=1.97e14, hbm_bytes=0, coll_bytes=0,
                        model_flops=0.5 * 1.97e14)
        assert r.mfu_bound == pytest.approx(0.5)
