"""Tests for the JAX block pool / hierarchical pool / paged KV cache."""

import jax
import jax.numpy as jnp
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:              # container image lacks hypothesis
    from _hypothesis_fallback import given, settings, st

from repro.core import block_pool, hier_pool, kv_cache
from repro.core.block_pool import NULL


class TestBlockPool:
    def test_alloc_free_roundtrip(self):
        pool = block_pool.create(16)
        pool, ids = block_pool.alloc(pool, jnp.array([True] * 4 + [False] * 4))
        assert int(pool.top) == 12
        assert np.all(np.asarray(ids[:4]) >= 0)
        assert np.all(np.asarray(ids[4:]) == -1)
        pool = block_pool.free(pool, ids)
        assert int(pool.top) == 16

    def test_exhaustion(self):
        pool = block_pool.create(3)
        pool, ids = block_pool.alloc(pool, jnp.ones(5, bool))
        got = np.asarray(ids)
        assert (got >= 0).sum() == 3 and (got == -1).sum() == 2
        assert int(pool.top) == 0

    def test_batch_ops(self):
        pool = block_pool.create(10)
        pool, batch = block_pool.alloc_batch(pool, 4)
        assert int(pool.top) == 6 and np.all(np.asarray(batch) >= 0)
        pool, batch2 = block_pool.alloc_batch(pool, 8)  # too big
        assert np.all(np.asarray(batch2) == -1) and int(pool.top) == 6
        pool = block_pool.free_batch(pool, batch)
        assert int(pool.top) == 10

    def test_jit_and_no_double_alloc(self):
        alloc_j = jax.jit(block_pool.alloc)
        free_j = jax.jit(block_pool.free)
        pool = block_pool.create(64)
        rng = np.random.RandomState(0)
        live = set()
        for step in range(50):
            mask = jnp.asarray(rng.rand(8) < 0.6)
            pool, ids = alloc_j(pool, mask)
            for i in np.asarray(ids):
                if i >= 0:
                    assert i not in live, "double allocation"
                    live.add(int(i))
            if live and rng.rand() < 0.5:
                drop = [live.pop() for _ in range(min(4, len(live)))]
                drop += [-1] * (8 - len(drop))
                pool = free_j(pool, jnp.asarray(drop, jnp.int32))
        assert int(pool.top) == 64 - len(live)

    def test_alloc_n_basic(self):
        pool = block_pool.create(16)
        pool, ids = block_pool.alloc_n(pool, jnp.asarray([2, 0, 3]), 4)
        got = np.asarray(ids)
        assert got.shape == (3, 4)
        assert (got[0] >= 0).sum() == 2 and (got[1] >= 0).sum() == 0
        assert (got[2] >= 0).sum() == 3
        assert int(pool.top) == 11
        live = got[got >= 0].tolist()
        assert len(set(live)) == 5, "duplicate grant"
        pool = block_pool.free(pool, ids.reshape(-1))
        assert int(pool.top) == 16

    def test_alloc_n_prefix_denial(self):
        """All-or-nothing per slot, in slot order: the first infeasible
        slot denies itself and every later slot (monotone cumulative
        demand), so one probe of the last needed id detects failure."""
        pool = block_pool.create(10)
        pool, ids = block_pool.alloc_n(pool, jnp.asarray([2, 0, 3, 6, 1]), 6)
        got = np.asarray(ids)
        assert (got[0] >= 0).sum() == 2 and (got[2] >= 0).sum() == 3
        assert (got[3] >= 0).sum() == 0, "infeasible slot must get nothing"
        assert (got[4] >= 0).sum() == 0, "slots after a denial get nothing"
        assert int(pool.top) == 5

    def test_alloc_n_matches_sequential_alloc(self):
        """alloc_n(counts) hands out the same blocks as repeated alloc."""
        p1 = p2 = block_pool.create(32)
        counts = jnp.asarray([3, 1, 0, 2])
        p1, ids1 = block_pool.alloc_n(p1, counts, 3)
        seq = []
        for s, c in enumerate(np.asarray(counts)):
            row = []
            for _ in range(int(c)):
                p2, one = block_pool.alloc(
                    p2, jnp.asarray([True]))
                row.append(int(one[0]))
            seq.append(row)
        assert int(p1.top) == int(p2.top)
        for s, row in enumerate(seq):
            assert np.asarray(ids1)[s, :len(row)].tolist() == row

    @settings(max_examples=20, deadline=None)
    @given(m=st.integers(8, 64), seed=st.integers(0, 999))
    def test_property_alloc_n_conservation(self, m, seed):
        rng = np.random.RandomState(seed)
        pool = block_pool.create(m)
        live = []
        for _ in range(12):
            if rng.rand() < 0.6:
                counts = jnp.asarray(rng.randint(0, 4, 5))
                pool, ids = block_pool.alloc_n(pool, counts, 3)
                live += [int(i) for i in np.asarray(ids).ravel() if i >= 0]
            elif live:
                k = rng.randint(1, len(live) + 1)
                back = [live.pop() for _ in range(k)]
                back += [-1] * ((-len(back)) % 6)
                pool = block_pool.free(pool, jnp.asarray(back, jnp.int32))
            assert int(pool.top) + len(live) == m
            assert len(set(live)) == len(live)

    @settings(max_examples=20, deadline=None)
    @given(m=st.integers(4, 64), seed=st.integers(0, 999))
    def test_property_conservation(self, m, seed):
        rng = np.random.RandomState(seed)
        pool = block_pool.create(m)
        live = []
        for _ in range(20):
            if rng.rand() < 0.5:
                pool, ids = block_pool.alloc(pool, jnp.asarray(rng.rand(6) < 0.7))
                live += [int(i) for i in np.asarray(ids) if i >= 0]
            elif live:
                k = rng.randint(1, len(live) + 1)
                back = [live.pop() for _ in range(k)] + [-1] * (6 - k)
                pool = block_pool.free(pool, jnp.asarray(back[:6], jnp.int32))
                live += [b for b in back[6:] if b >= 0]
            assert int(pool.top) + len(live) == m
            assert len(set(live)) == len(live)


class TestHierPool:
    def test_private_only_common_case(self):
        pool = hier_pool.create(num_blocks=256, num_lanes=4, ell=8)
        shared_top0 = int(pool.shared.top)
        # a few allocs per lane: shared pool untouched
        for _ in range(3):
            pool, ids = hier_pool.alloc(pool, jnp.ones(4, bool))
            assert np.all(np.asarray(ids) >= 0)
        assert int(pool.shared.top) == shared_top0

    def test_rebalance_refills_and_drains(self):
        pool = hier_pool.create(num_blocks=256, num_lanes=2, ell=8)
        # drain lane 0 below ell
        for _ in range(7):
            pool, _ = hier_pool.alloc(pool, jnp.asarray([True, False]))
        assert int(pool.private_top[0]) == 1
        pool = hier_pool.rebalance(pool)
        assert int(pool.private_top[0]) == 9   # refilled one batch
        # now free many into lane 1 to exceed 2*ell
        ids = []
        for _ in range(20):
            pool, got = hier_pool.alloc(pool, jnp.asarray([True, True]))
            ids.append(np.asarray(got))
        for got in ids:
            pool = hier_pool.free(pool, jnp.asarray([NULL, got[0]], jnp.int32))
            pool = hier_pool.free(pool, jnp.asarray([NULL, got[1]], jnp.int32))
        assert int(pool.private_top[1]) > 16
        before = int(pool.shared.top)
        total_before = int(hier_pool.total_free(pool))
        pool = hier_pool.rebalance(pool)
        # lane 1 drained one batch (+8 shared); lane 0 (empty after the
        # alloc storm) refilled one batch (-8 shared): net zero, but both
        # lanes are back inside [ell, 2*ell] and blocks are conserved.
        assert int(pool.private_top[1]) <= 2 * 8
        assert int(pool.private_top[0]) == 8
        assert int(pool.shared.top) == before
        assert int(hier_pool.total_free(pool)) == total_before

    def test_alloc_n_private_only(self):
        """Per-lane batched demand is served from private stacks alone."""
        pool = hier_pool.create(num_blocks=256, num_lanes=4, ell=8)
        shared_top0 = int(pool.shared.top)
        pool, ids = hier_pool.alloc_n(pool, jnp.asarray([3, 8, 0, 5]), 8)
        got = np.asarray(ids)
        assert [(r >= 0).sum() for r in got] == [3, 8, 0, 5]
        assert int(pool.shared.top) == shared_top0
        # a lane demanding more than its private stack is denied whole
        pool, ids = hier_pool.alloc_n(pool, jnp.asarray([0, 1, 0, 0]), 8)
        assert not (np.asarray(ids) >= 0).any()

    def test_adversarial_full_batch_drain_never_dry(self):
        """§4.2 invariant: lanes draining a FULL batch (ell blocks) every
        step, with one rebalance per step, never observe a dry private
        pool — the private stack always covers the next step's worst-case
        demand because refill restores >= ell blocks whenever the stack
        drops below ell."""
        L, ell, steps, hold = 4, 8, 60, 3
        pool = hier_pool.create(num_blocks=L * ell * (hold + 4),
                                num_lanes=L, ell=ell)
        total0 = int(hier_pool.total_free(pool))
        alloc_j = jax.jit(hier_pool.alloc_n, static_argnums=(2,))
        free_j = jax.jit(hier_pool.free)
        reb = jax.jit(hier_pool.rebalance)
        held = []          # FIFO of [L, ell] batches, freed after `hold`
        live = 0
        for step in range(steps):
            pool, ids = alloc_j(pool, jnp.full((L,), ell, jnp.int32), ell)
            got = np.asarray(ids)
            assert (got >= 0).all(), (
                f"step {step}: a lane ran dry (paper §4.2 violated)")
            held.append(got)
            live += L * ell
            if len(held) > hold:
                batch = held.pop(0)
                for k in range(ell):      # frees trickle back per lane
                    pool = free_j(pool, jnp.asarray(batch[:, k]))
                live -= L * ell
            pool = reb(pool)
            assert int(hier_pool.total_free(pool)) + live == total0, (
                f"step {step}: blocks lost or duplicated")
        # drain everything back and re-check conservation
        while held:
            batch = held.pop(0)
            for k in range(ell):
                pool = free_j(pool, jnp.asarray(batch[:, k]))
        pool = reb(pool)
        assert int(hier_pool.total_free(pool)) == total0

    def test_rebalance_conserves_under_random_storms(self):
        """No block is lost or duplicated across many rebalances under
        randomized alloc/free storms (conservation + uniqueness)."""
        rng = np.random.RandomState(7)
        L, ell = 6, 4
        pool = hier_pool.create(num_blocks=512, num_lanes=L, ell=ell)
        total0 = int(hier_pool.total_free(pool))
        live = set()
        for step in range(40):
            counts = jnp.asarray(rng.randint(0, ell + 1, L))
            pool, ids = hier_pool.alloc_n(pool, counts, ell)
            for i in np.asarray(ids).ravel():
                if i >= 0:
                    assert i not in live, "duplicate allocation"
                    live.add(int(i))
            if live and rng.rand() < 0.7:
                back = np.full(L, -1, np.int32)
                for lane in range(min(L, len(live))):
                    back[lane] = live.pop()
                pool = hier_pool.free(pool, jnp.asarray(back))
            pool = hier_pool.rebalance(pool)
            assert int(hier_pool.total_free(pool)) + len(live) == total0

    def test_conservation_under_jit(self):
        step_alloc = jax.jit(hier_pool.alloc)
        step_free = jax.jit(hier_pool.free)
        reb = jax.jit(hier_pool.rebalance)
        pool = hier_pool.create(num_blocks=512, num_lanes=8, ell=8)
        total = int(hier_pool.total_free(pool))
        rng = np.random.RandomState(1)
        live = []
        for step in range(60):
            pool, ids = step_alloc(pool, jnp.asarray(rng.rand(8) < 0.7))
            live += [int(i) for i in np.asarray(ids) if i >= 0]
            if live and rng.rand() < 0.5:
                back = np.full(8, -1, np.int32)
                for lane in range(min(4, len(live))):
                    back[lane] = live.pop()
                pool = step_free(pool, jnp.asarray(back))
                live += [int(b) for b in back if b >= 0 and False]
            if step % 4 == 0:
                pool = reb(pool)
            assert int(hier_pool.total_free(pool)) + len(live) == total
            assert len(set(live)) == len(live)


class TestHierPoolFreeN:
    def test_free_n_returns_to_lane_with_spill(self):
        pool = hier_pool.create(num_blocks=128, num_lanes=2, ell=2)  # cap 6
        total0 = int(hier_pool.total_free(pool))
        # grab 10 blocks for lane 0 via bulk (lane holds only 2)
        pool, ids = hier_pool.alloc_from_shared(
            pool, jnp.asarray([10, 0]), 10)
        assert (np.asarray(ids)[0] >= 0).all()
        top_before = int(pool.private_top[0])
        shared_before = int(pool.shared.top)
        pool = hier_pool.free_n(pool, ids)
        # lane takes what fits (cap 6), the rest spills to shared
        assert int(pool.private_top[0]) == 6
        assert int(pool.shared.top) == shared_before + 10 - (6 - top_before)
        assert int(hier_pool.total_free(pool)) == total0
        assert int(hier_pool.num_live(pool)) == 0

    def test_free_n_shared_page_released_once(self):
        """Two lanes releasing a shared block in ONE call: refcount 2
        drops to 0 and the block returns to exactly one stack."""
        pool = hier_pool.create(num_blocks=64, num_lanes=2, ell=2)
        total0 = int(hier_pool.total_free(pool))
        pool, got = hier_pool.alloc(pool, jnp.asarray([True, False]))
        b = int(got[0])
        pool = hier_pool.addref(pool, got)             # second reference
        assert int(hier_pool.total_free(pool)) == total0 - 1
        pool = hier_pool.free_n(pool, jnp.asarray([[b], [b]], jnp.int32))
        assert int(hier_pool.total_free(pool)) == total0
        assert int(hier_pool.num_live(pool)) == 0
        # and a partial release keeps the block off every stack
        pool, got = hier_pool.alloc(pool, jnp.asarray([True, False]))
        b = int(got[0])
        pool = hier_pool.addref(pool, got)
        pool = hier_pool.free_n(pool, jnp.asarray([[b], [NULL]], jnp.int32))
        assert int(hier_pool.total_free(pool)) == total0 - 1
        assert int(pool.shared.refcount[b]) == 1
        pool = hier_pool.free_n(pool, jnp.asarray([[b], [NULL]], jnp.int32))
        assert int(hier_pool.total_free(pool)) == total0

    def test_create_vectorized_matches_sequential_carve(self):
        """The one-shot warm-up hands lane i exactly the batch the old
        per-lane alloc_batch loop would have."""
        pool = hier_pool.create(num_blocks=32, num_lanes=3, ell=4)
        ref = block_pool.create(32)
        for lane in range(3):
            ref, batch = block_pool.alloc_batch(ref, 4)
            assert np.asarray(pool.private_ids)[lane, :4].tolist() == \
                np.asarray(batch).tolist()
        assert int(pool.shared.top) == int(ref.top)

    def test_dp_wrappers_shard_local(self):
        pool = hier_pool.create_dp(2, 64, 4, 2)
        pool, ids = hier_pool.alloc_n_dp(pool, jnp.full((2, 4), 2), 2)
        got = np.asarray(ids)
        assert (got >= 0).all()
        # shards carve identical (shard-local) id spaces independently
        assert np.array_equal(got[0], got[1])
        pool = hier_pool.free_n_dp(pool, ids)
        pool = hier_pool.rebalance_dp(pool)
        assert int(hier_pool.total_free(pool)) == 128
        assert np.asarray(pool.private_top).min() >= 2


class TestBatchHistoriesLinearize:
    """Satellite: adversarial scheduler runs over the device pool's
    batch ops, checked with the expanded-history linearizability test;
    a crash between the two rebalance phases must conserve blocks."""

    def _storm(self, seed, crash_rebalancer_at=None, crash_lane=None):
        import random
        from repro.core import (Scheduler, SimContext,
                                check_batch_alloc_history)
        L, ell, kmax = 3, 4, 4
        st = {"pool": hier_pool.create(num_blocks=96, num_lanes=L, ell=ell),
              "held": {lane: [] for lane in range(L)}}
        total0 = int(hier_pool.total_free(st["pool"]))
        ctx = SimContext(L + 1, seed=seed)
        sched = Scheduler(seed=seed)

        def lane_program(lane):
            # intervals use the scheduler's step clock and straddle a
            # yield, so ops genuinely overlap across lanes; the pool op
            # itself is one atomic point inside the interval
            rng = random.Random(seed * 31 + lane)
            held = st["held"][lane]
            for _ in range(25):
                yield                                     # scheduling point
                if not held or rng.random() < 0.55:
                    want = rng.randint(1, kmax)
                    counts = np.zeros(L, np.int32)
                    counts[lane] = want
                    rec = ctx.begin_op(lane, "alloc_n", arg=want)
                    rec.invoke_step = sched.steps
                    yield
                    pool, ids = hier_pool.alloc_n(
                        st["pool"], jnp.asarray(counts), kmax)
                    st["pool"] = pool
                    got = [int(i) for i in np.asarray(ids)[lane] if i >= 0]
                    held.extend(got)
                    yield
                    ctx.end_op(rec, result=got)
                    rec.response_step = sched.steps
                else:
                    k = rng.randint(1, min(len(held), kmax))
                    back = held[-k:]                # peek — pop only at the
                    ids = np.full((L, kmax), -1, np.int32)   # linearization
                    ids[lane, :k] = back            # point below, atomically
                    rec = ctx.begin_op(lane, "free_n", arg=back)
                    rec.invoke_step = sched.steps
                    yield
                    st["pool"] = hier_pool.free_n(st["pool"],
                                                  jnp.asarray(ids))
                    del held[-k:]                   # atomic with the op: a
                    yield                           # crash on either yield
                    ctx.end_op(rec)                 # leaves ledger == pool
                    rec.response_step = sched.steps

        def rebalancer(pid):
            for _ in range(40):
                yield
                st["pool"] = hier_pool.rebalance_drain(st["pool"])
                yield                      # <-- crash window: torn rebalance
                st["pool"] = hier_pool.rebalance_refill(st["pool"])

        for lane in range(L):
            sched.add(lane, lane_program(lane))
        sched.add(L, rebalancer(L))
        crash_at = {}
        if crash_rebalancer_at is not None:
            crash_at[L] = crash_rebalancer_at
        if crash_lane is not None:
            crash_at[crash_lane] = crash_rebalancer_at or 40
        sched.run("bursty", crash_at=crash_at)

        errs = check_batch_alloc_history(ctx.history)
        assert errs == [], errs
        live = sum(len(h) for h in st["held"].values())
        assert int(hier_pool.total_free(st["pool"])) + live == total0, (
            "blocks lost or duplicated (crashed holders counted live)")
        assert int(hier_pool.num_live(st["pool"])) == live

    def test_adversarial_batch_histories(self):
        for seed in (0, 1, 2):
            self._storm(seed)

    def test_crash_mid_rebalance_conserves(self):
        """The rebalancer dies between drain and refill: the drained
        batch sits in the shared pool, nothing is lost, lanes keep
        operating on their private stacks."""
        self._storm(seed=5, crash_rebalancer_at=37)

    def test_crash_lane_holding_blocks_conserves(self):
        """A user lane crashes while holding live blocks: they stay
        allocated (refcount 1) and conservation accounts for them."""
        self._storm(seed=7, crash_rebalancer_at=None, crash_lane=1)


class TestPagedKVCache:
    def _mk(self, **kw):
        d = dict(num_pages=32, page_size=4, kv_heads=2, head_dim=8,
                 max_seqs=3, max_pages_per_seq=8, dtype=jnp.float32)
        d.update(kw)
        return kv_cache.create(**d)

    def test_append_and_gather(self):
        cache = self._mk()
        T = 10
        ks = np.random.RandomState(0).randn(T, 3, 2, 8).astype(np.float32)
        vs = np.random.RandomState(1).randn(T, 3, 2, 8).astype(np.float32)
        for t in range(T):
            cache, ok = kv_cache.append(
                cache, jnp.asarray(ks[t]), jnp.asarray(vs[t]),
                jnp.ones(3, bool))
            assert bool(jnp.all(ok))
        assert np.all(np.asarray(cache.seq_lens) == T)
        for s in range(3):
            k, v, valid = kv_cache.gather_kv(cache, s, max_len=12)
            np.testing.assert_allclose(np.asarray(k)[:T], ks[:, s], rtol=1e-6)
            np.testing.assert_allclose(np.asarray(v)[:T], vs[:, s], rtol=1e-6)
            assert int(valid.sum()) == T

    def test_release_returns_pages(self):
        cache = self._mk()
        for t in range(8):
            cache, _ = kv_cache.append(
                cache, jnp.zeros((3, 2, 8)), jnp.zeros((3, 2, 8)),
                jnp.ones(3, bool))
        used = 32 - int(cache.pool.top)
        assert used == 3 * 2   # 8 tokens = 2 pages of 4, per seq
        cache = kv_cache.release(cache, jnp.asarray([True, False, True]))
        assert int(cache.pool.top) == 32 - 2
        assert int(cache.seq_lens[1]) == 8

    def test_page_exhaustion_reports_not_corrupts(self):
        cache = self._mk(num_pages=2, max_seqs=2, max_pages_per_seq=4)
        oks = []
        for t in range(6):
            cache, ok = kv_cache.append(
                cache, jnp.zeros((2, 2, 8)), jnp.zeros((2, 2, 8)),
                jnp.ones(2, bool))
            oks.append(np.asarray(ok))
        # 2 pages serve 1 page per seq (4 tokens); the 5th token needs a
        # second page and must fail cleanly for both seqs
        assert oks[3].all() and not oks[4].any()
        assert np.all(np.asarray(cache.seq_lens) == 4)

    def test_append_under_jit(self):
        cache = self._mk()
        app = jax.jit(kv_cache.append)
        for t in range(5):
            cache, ok = app(cache, jnp.ones((3, 2, 8)), jnp.ones((3, 2, 8)),
                            jnp.ones(3, bool))
        assert np.all(np.asarray(cache.seq_lens) == 5)

    def test_gather_kv_partial_page_not_truncated(self):
        """Regression: max_len not a multiple of page_size must include
        the trailing partial page (was silently dropped by floor div)."""
        cache = self._mk()          # psz=4
        T = 10
        ks = np.random.RandomState(2).randn(T, 3, 2, 8).astype(np.float32)
        for t in range(T):
            cache, _ = kv_cache.append(
                cache, jnp.asarray(ks[t]), jnp.asarray(ks[t]),
                jnp.ones(3, bool))
        k, _, valid = kv_cache.gather_kv(cache, 0, max_len=10)
        assert int(valid.sum()) == 10, "partial page tokens were dropped"
        np.testing.assert_allclose(
            np.asarray(k)[np.asarray(valid)], ks[:, 0], rtol=1e-6)
        # max_len below seq_len still trims to max_len
        _, _, valid7 = kv_cache.gather_kv(cache, 0, max_len=7)
        assert int(valid7.sum()) == 7

    def test_append_chunk_matches_sequential(self):
        """append_chunk(C tokens) == C x append, including ragged lens."""
        c1 = c2 = self._mk()
        rng = np.random.RandomState(3)
        ks = rng.randn(3, 7, 2, 8).astype(np.float32)
        vs = rng.randn(3, 7, 2, 8).astype(np.float32)
        lens = np.array([7, 5, 0], np.int32)
        c1, ok = kv_cache.append_chunk(
            c1, jnp.asarray(ks), jnp.asarray(vs), jnp.asarray(lens))
        assert np.asarray(ok).all()
        for t in range(7):
            c2, _ = kv_cache.append(
                c2, jnp.asarray(ks[:, t]), jnp.asarray(vs[:, t]),
                jnp.asarray(t < lens))
        assert np.array_equal(np.asarray(c1.seq_lens), np.asarray(c2.seq_lens))
        assert int(c1.pool.top) == int(c2.pool.top)
        for s in range(3):
            k1, v1, m1 = kv_cache.gather_kv(c1, s, max_len=8)
            k2, v2, m2 = kv_cache.gather_kv(c2, s, max_len=8)
            assert int(m1.sum()) == int(m2.sum()) == lens[s]
            np.testing.assert_allclose(np.asarray(k1)[np.asarray(m1)],
                                       np.asarray(k2)[np.asarray(m2)])
            np.testing.assert_allclose(np.asarray(v1)[np.asarray(m1)],
                                       np.asarray(v2)[np.asarray(m2)])

    def test_append_chunk_exhaustion_all_or_nothing(self):
        """A chunk that cannot get all its pages appends nothing."""
        cache = self._mk(num_pages=3, max_seqs=2, max_pages_per_seq=4)
        ks = jnp.zeros((2, 8, 2, 8))
        # seq 0 wants 2 pages, seq 1 wants 2 pages; only 3 pages exist
        cache, ok = kv_cache.append_chunk(
            cache, ks, ks, jnp.asarray([8, 8]))
        got = np.asarray(ok)
        assert got[0] and not got[1], "second chunk must fail whole"
        assert np.asarray(cache.seq_lens).tolist() == [8, 0]
        assert int(cache.pool.top) == 1

    def test_append_chunk_table_overflow_fails_clean(self):
        cache = self._mk(max_pages_per_seq=2)      # capacity 8 tokens
        ks = jnp.zeros((3, 6, 2, 8))
        cache, ok = kv_cache.append_chunk(
            cache, ks, ks, jnp.asarray([6, 6, 6]))
        assert np.asarray(ok).all()
        cache, ok = kv_cache.append_chunk(       # 6 more would need page 3
            cache, ks, ks, jnp.asarray([6, 0, 2]))
        got = np.asarray(ok)
        assert not got[0] and got[1] and got[2]
        assert np.asarray(cache.seq_lens).tolist() == [6, 6, 8]

    def test_append_chunk_under_jit_interleaved_with_append(self):
        cache = self._mk()
        appc = jax.jit(kv_cache.append_chunk)
        app = jax.jit(kv_cache.append)
        cache, ok = appc(cache, jnp.ones((3, 6, 2, 8)), jnp.ones((3, 6, 2, 8)),
                         jnp.asarray([6, 3, 1]))
        cache, ok2 = app(cache, jnp.ones((3, 2, 8)), jnp.ones((3, 2, 8)),
                         jnp.ones(3, bool))
        assert np.asarray(ok).all() and np.asarray(ok2).all()
        assert np.asarray(cache.seq_lens).tolist() == [7, 4, 2]
