"""Tests for the JAX block pool / hierarchical pool / paged KV cache."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import block_pool, hier_pool, kv_cache
from repro.core.block_pool import NULL


class TestBlockPool:
    def test_alloc_free_roundtrip(self):
        pool = block_pool.create(16)
        pool, ids = block_pool.alloc(pool, jnp.array([True] * 4 + [False] * 4))
        assert int(pool.top) == 12
        assert np.all(np.asarray(ids[:4]) >= 0)
        assert np.all(np.asarray(ids[4:]) == -1)
        pool = block_pool.free(pool, ids)
        assert int(pool.top) == 16

    def test_exhaustion(self):
        pool = block_pool.create(3)
        pool, ids = block_pool.alloc(pool, jnp.ones(5, bool))
        got = np.asarray(ids)
        assert (got >= 0).sum() == 3 and (got == -1).sum() == 2
        assert int(pool.top) == 0

    def test_batch_ops(self):
        pool = block_pool.create(10)
        pool, batch = block_pool.alloc_batch(pool, 4)
        assert int(pool.top) == 6 and np.all(np.asarray(batch) >= 0)
        pool, batch2 = block_pool.alloc_batch(pool, 8)  # too big
        assert np.all(np.asarray(batch2) == -1) and int(pool.top) == 6
        pool = block_pool.free_batch(pool, batch)
        assert int(pool.top) == 10

    def test_jit_and_no_double_alloc(self):
        alloc_j = jax.jit(block_pool.alloc)
        free_j = jax.jit(block_pool.free)
        pool = block_pool.create(64)
        rng = np.random.RandomState(0)
        live = set()
        for step in range(50):
            mask = jnp.asarray(rng.rand(8) < 0.6)
            pool, ids = alloc_j(pool, mask)
            for i in np.asarray(ids):
                if i >= 0:
                    assert i not in live, "double allocation"
                    live.add(int(i))
            if live and rng.rand() < 0.5:
                drop = [live.pop() for _ in range(min(4, len(live)))]
                drop += [-1] * (8 - len(drop))
                pool = free_j(pool, jnp.asarray(drop, jnp.int32))
        assert int(pool.top) == 64 - len(live)

    @settings(max_examples=20, deadline=None)
    @given(m=st.integers(4, 64), seed=st.integers(0, 999))
    def test_property_conservation(self, m, seed):
        rng = np.random.RandomState(seed)
        pool = block_pool.create(m)
        live = []
        for _ in range(20):
            if rng.rand() < 0.5:
                pool, ids = block_pool.alloc(pool, jnp.asarray(rng.rand(6) < 0.7))
                live += [int(i) for i in np.asarray(ids) if i >= 0]
            elif live:
                k = rng.randint(1, len(live) + 1)
                back = [live.pop() for _ in range(k)] + [-1] * (6 - k)
                pool = block_pool.free(pool, jnp.asarray(back[:6], jnp.int32))
                live += [b for b in back[6:] if b >= 0]
            assert int(pool.top) + len(live) == m
            assert len(set(live)) == len(live)


class TestHierPool:
    def test_private_only_common_case(self):
        pool = hier_pool.create(num_blocks=256, num_lanes=4, ell=8)
        shared_top0 = int(pool.shared.top)
        # a few allocs per lane: shared pool untouched
        for _ in range(3):
            pool, ids = hier_pool.alloc(pool, jnp.ones(4, bool))
            assert np.all(np.asarray(ids) >= 0)
        assert int(pool.shared.top) == shared_top0

    def test_rebalance_refills_and_drains(self):
        pool = hier_pool.create(num_blocks=256, num_lanes=2, ell=8)
        # drain lane 0 below ell
        for _ in range(7):
            pool, _ = hier_pool.alloc(pool, jnp.asarray([True, False]))
        assert int(pool.private_top[0]) == 1
        pool = hier_pool.rebalance(pool)
        assert int(pool.private_top[0]) == 9   # refilled one batch
        # now free many into lane 1 to exceed 2*ell
        ids = []
        for _ in range(20):
            pool, got = hier_pool.alloc(pool, jnp.asarray([True, True]))
            ids.append(np.asarray(got))
        for got in ids:
            pool = hier_pool.free(pool, jnp.asarray([NULL, got[0]], jnp.int32))
            pool = hier_pool.free(pool, jnp.asarray([NULL, got[1]], jnp.int32))
        assert int(pool.private_top[1]) > 16
        before = int(pool.shared.top)
        total_before = int(hier_pool.total_free(pool))
        pool = hier_pool.rebalance(pool)
        # lane 1 drained one batch (+8 shared); lane 0 (empty after the
        # alloc storm) refilled one batch (-8 shared): net zero, but both
        # lanes are back inside [ell, 2*ell] and blocks are conserved.
        assert int(pool.private_top[1]) <= 2 * 8
        assert int(pool.private_top[0]) == 8
        assert int(pool.shared.top) == before
        assert int(hier_pool.total_free(pool)) == total_before

    def test_conservation_under_jit(self):
        step_alloc = jax.jit(hier_pool.alloc)
        step_free = jax.jit(hier_pool.free)
        reb = jax.jit(hier_pool.rebalance)
        pool = hier_pool.create(num_blocks=512, num_lanes=8, ell=8)
        total = int(hier_pool.total_free(pool))
        rng = np.random.RandomState(1)
        live = []
        for step in range(60):
            pool, ids = step_alloc(pool, jnp.asarray(rng.rand(8) < 0.7))
            live += [int(i) for i in np.asarray(ids) if i >= 0]
            if live and rng.rand() < 0.5:
                back = np.full(8, -1, np.int32)
                for lane in range(min(4, len(live))):
                    back[lane] = live.pop()
                pool = step_free(pool, jnp.asarray(back))
                live += [int(b) for b in back if b >= 0 and False]
            if step % 4 == 0:
                pool = reb(pool)
            assert int(hier_pool.total_free(pool)) + len(live) == total
            assert len(set(live)) == len(live)


class TestPagedKVCache:
    def _mk(self, **kw):
        d = dict(num_pages=32, page_size=4, kv_heads=2, head_dim=8,
                 max_seqs=3, max_pages_per_seq=8, dtype=jnp.float32)
        d.update(kw)
        return kv_cache.create(**d)

    def test_append_and_gather(self):
        cache = self._mk()
        T = 10
        ks = np.random.RandomState(0).randn(T, 3, 2, 8).astype(np.float32)
        vs = np.random.RandomState(1).randn(T, 3, 2, 8).astype(np.float32)
        for t in range(T):
            cache, ok = kv_cache.append(
                cache, jnp.asarray(ks[t]), jnp.asarray(vs[t]),
                jnp.ones(3, bool))
            assert bool(jnp.all(ok))
        assert np.all(np.asarray(cache.seq_lens) == T)
        for s in range(3):
            k, v, valid = kv_cache.gather_kv(cache, s, max_len=12)
            np.testing.assert_allclose(np.asarray(k)[:T], ks[:, s], rtol=1e-6)
            np.testing.assert_allclose(np.asarray(v)[:T], vs[:, s], rtol=1e-6)
            assert int(valid.sum()) == T

    def test_release_returns_pages(self):
        cache = self._mk()
        for t in range(8):
            cache, _ = kv_cache.append(
                cache, jnp.zeros((3, 2, 8)), jnp.zeros((3, 2, 8)),
                jnp.ones(3, bool))
        used = 32 - int(cache.pool.top)
        assert used == 3 * 2   # 8 tokens = 2 pages of 4, per seq
        cache = kv_cache.release(cache, jnp.asarray([True, False, True]))
        assert int(cache.pool.top) == 32 - 2
        assert int(cache.seq_lens[1]) == 8

    def test_page_exhaustion_reports_not_corrupts(self):
        cache = self._mk(num_pages=2, max_seqs=2, max_pages_per_seq=4)
        oks = []
        for t in range(6):
            cache, ok = kv_cache.append(
                cache, jnp.zeros((2, 2, 8)), jnp.zeros((2, 2, 8)),
                jnp.ones(2, bool))
            oks.append(np.asarray(ok))
        # 2 pages serve 1 page per seq (4 tokens); the 5th token needs a
        # second page and must fail cleanly for both seqs
        assert oks[3].all() and not oks[4].any()
        assert np.all(np.asarray(cache.seq_lens) == 4)

    def test_append_under_jit(self):
        cache = self._mk()
        app = jax.jit(kv_cache.append)
        for t in range(5):
            cache, ok = app(cache, jnp.ones((3, 2, 8)), jnp.ones((3, 2, 8)),
                            jnp.ones(3, bool))
        assert np.all(np.asarray(cache.seq_lens) == 5)
