"""Observability plane tests (DESIGN.md §13).

The device counter block is checked EXACTLY against an independent
host-side recount: a harness wraps the engine's jitted-step dispatch to
snapshot the feed (lane widths, prompt flags, the ``_fed`` shadow) and
wraps the flight recorder to pair each completed step's packed status
with that snapshot, then recomputes what each counter row must be from
page arithmetic alone — under plain storms, preemption, speculative
rollback, and a torn drain/refill crash window.  The tracer's chrome
export is schema-validated with strict span nesting, and the one-sync /
one-collective discipline is re-asserted with telemetry fully enabled.
"""

import json
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import models
from repro.configs import get_config, smoke_config
from repro.serving import chaos
from repro.serving.engine import Request, ServingEngine
from repro.serving.telemetry import (CTR_ALLOC, CTR_DRAIN, CTR_FREED,
                                     CTR_MARGIN, CTR_REFILL, CTR_ROLLBACK,
                                     CTR_SHARED_FREE, CTR_SPILL, N_CTR,
                                     FlightRecorder, Telemetry, parse_prom)
from repro.serving.trace import Tracer, validate_chrome


@pytest.fixture(scope="module")
def engine_setup():
    cfg = smoke_config(get_config("olmo-1b"))
    params = models.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


# ===================================================== host-side recount
#
# Independent replay of the counter block from host state.  At dispatch
# the harness snapshots feed_lens/is_prompt and the _fed shadow (prompt
# slots are already advanced at dispatch, generating slots are not);
# when the engine records the step into the flight ring the harness
# reads the packed status and recomputes, per shard, from ceil-division
# page arithmetic alone:
#
#   alloc    = sum_slots  pages(fed_before + fed) - pages(fed_before)
#   rollback = sum_gen    pages(fed_before + fed) - pages(fed_before+ne)
#   freed    = rollback + sum_done pages(final_kept_tokens)
#
# Exact only when every page has refcount 1 — so these storms run with
# prefix sharing off (or with no overlapping same-prefix residency and
# the default pin budget of zero, which never creates a pin).


class Recount:
    def __init__(self, eng):
        self.eng = eng
        self.psz = eng.cfg.page_size
        self.expected = []          # one dict per completed step
        self.observed = []          # matching int ctr blocks [N_CTR, DP]
        self.margins = []           # device-read min(private_top)-ell
        self.preempt_freed = 0      # pages released outside the step
        self.host_freed = []        # per step: host-side free since prev
        self._host_freed = False
        self._pending = None
        self._post_shared = None
        self._wrap_variants()
        self._wrap_flight()
        self._wrap_preempt()

    def _wrap_variants(self):
        eng = self.eng
        for key, fn in list(eng._serve_variants.items()):
            eng._serve_variants[key] = self._make_wrapper(fn)

    def _make_wrapper(self, fn):
        eng = self.eng

        def wrapped(params, state, last_tok, out_count, budget, temps,
                    topks, seeds, prompt_toks, feed_lens, is_prompt, emit,
                    expert_mask):
            self._pending = {
                "feed": np.asarray(feed_lens).copy(),
                "is_prompt": np.asarray(is_prompt).copy(),
                "fed": dict(eng._fed),
            }
            return fn(params, state, last_tok, out_count, budget, temps,
                      topks, seeds, prompt_toks, feed_lens, is_prompt,
                      emit, expert_mask)
        return wrapped

    def _wrap_flight(self):
        flight = self.eng.flight
        orig = flight.record

        def record(**rec):
            self._on_step(rec)
            orig(**rec)
        flight.record = record

    def _wrap_preempt(self):
        eng = self.eng
        orig = eng.preempt

        def preempt(slot):
            # refcount-1 release outside the step's counter block
            self.preempt_freed += -(-eng._fed.get(slot, 0) // self.psz)
            self._host_freed = True
            return orig(slot)
        eng.preempt = preempt

    def _on_step(self, rec):
        eng, psz = self.eng, self.psz
        snap, self._pending = self._pending, None
        assert snap is not None, "flight.record without a dispatch"
        status = np.asarray(rec["status"])
        T = rec["T"]
        n_emit = status[T + 0]
        done = status[T + 1]
        ctr = status[T + 3:, :, 0]
        assert ctr.shape == (N_CTR, eng.dp)

        pages = lambda x: -(-x // psz)               # noqa: E731
        alloc = np.zeros(eng.dp, np.int64)
        roll = np.zeros(eng.dp, np.int64)
        freed = np.zeros(eng.dp, np.int64)
        for d in range(eng.dp):
            for b in range(eng.bl):
                fed = int(snap["feed"][d, b])
                if fed == 0:
                    continue
                slot = d * eng.bl + b
                if snap["is_prompt"][d, b]:
                    # _fed advanced at dispatch: before = after - fed
                    before = snap["fed"].get(slot, 0) - fed
                    kept = before + fed
                else:
                    before = snap["fed"].get(slot, 0)
                    ne = int(n_emit[d, b])
                    kept = before + ne
                    roll[d] += pages(before + fed) - pages(kept)
                alloc[d] += pages(before + fed) - pages(before)
                if done[d, b]:
                    freed[d] += pages(kept)
        freed += roll
        self.expected.append({"alloc": alloc, "roll": roll,
                              "freed": freed})
        self.observed.append(ctr.astype(np.int64))
        self.host_freed.append(self._host_freed)
        self._host_freed = False
        # device-read invariant gauges (test-only sync): the §4.2
        # margin and shared level the block must have reported (the
        # KV class — these storms run single-class engines)
        kv = eng.state.pool.classes[0]
        ell = kv.private_ids.shape[-1] // 3
        self.margins.append(
            np.asarray(jnp.min(kv.private_top, axis=-1)) - ell)
        self._post_shared = np.asarray(kv.shared.top).copy()

    def check(self):
        assert self.expected, "no steps recorded"
        ell = self.eng.state.pool.classes[0].private_ids.shape[-1] // 3
        for i, (exp, obs) in enumerate(zip(self.expected, self.observed)):
            np.testing.assert_array_equal(
                obs[CTR_ALLOC], exp["alloc"],
                err_msg=f"step {i}: alloc recount mismatch")
            np.testing.assert_array_equal(
                obs[CTR_ROLLBACK], exp["roll"],
                err_msg=f"step {i}: rollback recount mismatch")
            np.testing.assert_array_equal(
                obs[CTR_FREED], exp["freed"],
                err_msg=f"step {i}: freed recount mismatch")
            # §4.2 never-dry margin: non-negative at every step, and
            # exactly the device state the step left behind
            assert (obs[CTR_MARGIN] >= 0).all(), \
                f"step {i}: never-dry margin went negative"
            np.testing.assert_array_equal(
                obs[CTR_MARGIN], self.margins[i],
                err_msg=f"step {i}: margin gauge mismatch")
            # drain/refill move whole batches of ell per lane
            assert (obs[CTR_DRAIN] % ell == 0).all()
            assert (obs[CTR_REFILL] % ell == 0).all()
        # The shared free level moves by +drain -refill +spill each
        # step — the lane-cap overflow of every IN-STEP release is now
        # metered in CTR_SPILL, so the telescoping is an exact identity,
        # not the old drain/refill-only floor inequality.  Host-side
        # frees between steps (preempt's separate jitted release) spill
        # to shared OUTSIDE any counter block, so on steps following one
        # the identity relaxes back to a (tighter-than-before) floor.
        for i in range(1, len(self.observed)):
            prev, obs = self.observed[i - 1], self.observed[i]
            floor = (prev[CTR_SHARED_FREE] + obs[CTR_DRAIN]
                     - obs[CTR_REFILL] + obs[CTR_SPILL])
            if self.host_freed[i]:
                assert (obs[CTR_SHARED_FREE] >= floor).all(), \
                    f"step {i}: shared-free fell below the spill floor"
            else:
                np.testing.assert_array_equal(
                    obs[CTR_SHARED_FREE], floor,
                    err_msg=f"step {i}: shared-free telescoping is not "
                            f"exact (drain/refill/spill)")
        np.testing.assert_array_equal(
            self.observed[-1][CTR_SHARED_FREE], self._post_shared,
            err_msg="final shared-free gauge disagrees with device state")
        # host-side telemetry accumulated the same totals
        tel = self.eng.telemetry
        np.testing.assert_array_equal(
            tel.shard["alloc_pages"],
            sum(e["alloc"] for e in self.expected))
        np.testing.assert_array_equal(
            tel.shard["freed_pages"],
            sum(e["freed"] for e in self.expected))
        assert tel.never_dry_margin_min() is not None
        assert tel.never_dry_margin_min() >= 0


def _alloc_freed_balance(rc):
    total_alloc = int(sum(e["alloc"] for e in rc.expected).sum())
    total_freed = int(sum(e["freed"] for e in rc.expected).sum())
    assert total_alloc == total_freed + rc.preempt_freed, (
        f"page conservation broke: alloc={total_alloc} "
        f"freed={total_freed} preempt_freed={rc.preempt_freed}")


def test_counter_block_exact_on_storm(engine_setup):
    """Seeded storm: every counter row matches the host recount, step
    by step, and the invariant gauges match device state exactly."""
    cfg, params = engine_setup
    rng = np.random.RandomState(0)
    eng = ServingEngine(cfg, params, dp=2, b_local=2, max_len=64,
                        prefix_sharing=False)
    rc = Recount(eng)
    reqs = [Request(i, prompt=list(rng.randint(1, 255, rng.randint(3, 14))),
                    max_new_tokens=5) for i in range(10)]
    for r in reqs:
        eng.submit(r)
    eng.run(max_steps=500)
    assert all(r.done for r in reqs)
    rc.check()
    _alloc_freed_balance(rc)
    assert eng.page_occupancy() == 0.0


def test_counter_block_exact_under_forced_spill(engine_setup):
    """Spill-forcing trace: a request that completes holding more pages
    than a whole lane can hold (> 3*ell) MUST overflow the lane cap at
    its in-step release — CTR_SPILL meters the overflow and the shared-
    free telescoping stays an exact identity through it (the bug the
    spill row fixes: unmetered spill made the gauge drift off the
    drain/refill ledger)."""
    cfg, params = engine_setup
    rng = np.random.RandomState(8)
    eng = ServingEngine(cfg, params, dp=1, b_local=2, max_len=64,
                        prefix_sharing=False)
    kv = eng.state.pool.classes[0]
    ell = kv.private_ids.shape[-1] // 3
    cap_tokens = 3 * ell * cfg.page_size
    rc = Recount(eng)
    # each request retires > 3*ell pages in one release: guaranteed
    # lane-cap overflow no matter what the lane held beforehand
    reqs = [Request(i, prompt=list(rng.randint(1, 255, cap_tokens + 2)),
                    max_new_tokens=3) for i in range(4)]
    for r in reqs:
        eng.submit(r)
    eng.run(max_steps=500)
    assert all(r.done for r in reqs)
    rc.check()
    _alloc_freed_balance(rc)
    spilled = int(sum(obs[CTR_SPILL].sum() for obs in rc.observed))
    assert spilled > 0, "trace never forced a lane-cap spill"
    np.testing.assert_array_equal(
        eng.telemetry.shard["spill_pages"],
        sum(obs[CTR_SPILL] for obs in rc.observed))
    assert eng.page_occupancy() == 0.0


def test_counter_block_exact_under_preemption(engine_setup):
    """Interactive-class arrivals force preemptions mid-storm; the
    counter block stays exact (preempt-path frees happen in a separate
    jitted release call, accounted host-side by the harness)."""
    cfg, params = engine_setup
    rng = np.random.RandomState(1)
    eng = ServingEngine(cfg, params, dp=2, b_local=2, max_len=64,
                        prefix_sharing=False)
    rc = Recount(eng)
    batch = [Request(i, prompt=list(rng.randint(1, 255, 12)),
                     max_new_tokens=8, slo="batch") for i in range(4)]
    for r in batch:
        eng.submit(r)
    for _ in range(3):
        eng.step()
    hot = [Request(100 + i, prompt=list(rng.randint(1, 255, 10)),
                   max_new_tokens=4, slo="interactive") for i in range(4)]
    for r in hot:
        eng.submit(r)
    eng.run(max_steps=500)
    assert all(r.done for r in batch + hot)
    assert eng.stats["preemptions"] > 0, "storm never preempted"
    assert rc.preempt_freed > 0
    rc.check()
    _alloc_freed_balance(rc)
    assert eng.page_occupancy() == 0.0


def test_counter_block_exact_under_spec_rollback(engine_setup):
    """Speculative repeats with a poisoned draft history: rejected-draft
    whole-page rollback shows up in CTR_ROLLBACK exactly, and the device
    total equals the host-model ``spec_pages_rolled_back`` counter."""
    cfg, params = engine_setup
    rng = np.random.RandomState(2)
    # draft_len=7: a fully-rejected draft over-allocates a whole page at
    # the prompt-length alignment below, so rollback is provably > 0
    eng = ServingEngine(cfg, params, dp=1, b_local=2, max_len=64,
                        speculate=True, draft_len=7, spec_gate=False)
    assert eng.spec_store is not None
    psz = cfg.page_size
    rc = Recount(eng)
    prompt = list(rng.randint(1, 255, psz + 6))   # key = first whole page
    first = Request(0, prompt=list(prompt), max_new_tokens=8)
    eng.submit(first)
    eng.run(max_steps=200)
    assert first.done
    key = eng.spec_store.key_of(prompt)
    assert key is not None
    # sequential repeats (never co-resident, pin budget 0 → every page
    # stays refcount-1); poisoning the recorded continuation before each
    # forces a full-draft rejection on the repeat's first spec step
    # the drafting suffix includes the first generated token, so the
    # poisoned stream must match through it and diverge right after —
    # the repeat then drafts a full-width garbage lane and rejects it
    garbage = (int(first.out_tokens[0]),) \
        + tuple(int(t) + 1 for t in first.out_tokens[1:7]) + (3,) * 7
    for i in range(1, 4):
        # the store keeps several streams per key and drafts from the
        # first consistent one — drop the true history recorded at the
        # previous finish so only the poisoned stream can draft
        eng.spec_store.streams.pop(key, None)
        eng.spec_store.record(key, tuple(prompt[len(key):]) + garbage)
        rep = Request(i, prompt=list(prompt), max_new_tokens=8)
        eng.submit(rep)
        eng.run(max_steps=300)
        assert rep.done
        assert rep.out_tokens == first.out_tokens   # rollback is exact
    assert eng.stats["spec_lanes"] > 0, "no speculative lanes dispatched"
    rc.check()
    dev_roll = int(sum(e["roll"] for e in rc.expected).sum())
    assert dev_roll > 0, "poisoned drafts never rolled a page back"
    assert dev_roll == eng.stats["spec_pages_rolled_back"], (
        "device rollback row disagrees with the host rollback model")
    _alloc_freed_balance(rc)
    assert eng.page_occupancy() == 0.0


# ================================================= acceptance criterion:
# torn-window chaos run -> flight dump == host replay


def test_flight_dump_matches_host_replay_through_torn_crash(
        engine_setup, tmp_path):
    """A seeded chaos run (host crash inside the torn drain/refill
    window) leaves a flight-recorder dump whose recorded never-dry
    margins and per-shard alloc/free counter rows exactly match the
    harness's host-side replay of the same steps."""
    cfg, params = engine_setup
    rng = np.random.RandomState(3)
    fpath = str(tmp_path / "flight.json")
    journal = chaos.ServingJournal()
    injector = chaos.parse_faults("crash@4:post_sync:torn")
    recounts = []

    def build():
        eng = ServingEngine(
            cfg, params, dp=2, b_local=2, max_len=64,
            prefix_sharing=False, journal=journal, injector=injector,
            flight=FlightRecorder(capacity=64, path=fpath))
        recounts.append(Recount(eng))
        return eng

    eng = build()
    reqs = [Request(i, prompt=list(rng.randint(1, 255, 10)),
                    max_new_tokens=5) for i in range(6)]
    for r in reqs:
        eng.submit(r)
    crashes = 0
    for _ in range(200):
        if eng.idle():
            break
        try:
            eng.run(max_steps=1)
        except chaos.HostCrash:
            crashes += 1
            eng, report = chaos.recover_engine(build, eng, journal)
    assert eng.idle(), "run never drained"
    assert crashes == 1, "the torn-window crash never fired"
    assert not journal.in_flight()
    for rc in recounts:
        rc.check()

    # the crash-time dump (overwritten by recover_engine's) holds the
    # pre-crash window — the crashed dispatch itself never reached the
    # ring, exactly like the harness's pending-discard
    mid = FlightRecorder.load(fpath)
    assert mid["reason"] == "recover_engine"
    assert len(mid["records"]) == len(recounts[0].expected)

    # final dump: the adopted ring holds BOTH engines' steps in order —
    # pair them with the harness's per-step host replay, in order
    eng.flight.dump("test_final")
    dump = FlightRecorder.load(fpath)
    records = dump["records"]
    expected = [e for rc in recounts for e in rc.expected]
    margins = [m for rc in recounts for m in rc.margins]
    assert len(records) == len(expected)
    for i, (rec, exp) in enumerate(zip(records, expected)):
        ctr = np.asarray(rec["ctr"], np.int64)
        np.testing.assert_array_equal(
            ctr[CTR_ALLOC], exp["alloc"],
            err_msg=f"dump record {i}: alloc vs host replay")
        np.testing.assert_array_equal(
            ctr[CTR_FREED], exp["freed"],
            err_msg=f"dump record {i}: freed vs host replay")
        np.testing.assert_array_equal(
            ctr[CTR_MARGIN], margins[i],
            err_msg=f"dump record {i}: margin vs host replay")
        assert (ctr[CTR_MARGIN] >= 0).all()


# ====================================================== tracer / chrome


def test_chrome_trace_schema_and_nesting(engine_setup, tmp_path):
    cfg, params = engine_setup
    rng = np.random.RandomState(4)
    eng = ServingEngine(cfg, params, dp=2, b_local=2, max_len=64,
                        tracer=Tracer())
    reqs = [Request(i, prompt=list(rng.randint(1, 255, 8)),
                    max_new_tokens=4,
                    slo="interactive" if i % 3 == 0 else "standard")
            for i in range(6)]
    for r in reqs:
        eng.submit(r)
    eng.run(max_steps=300)
    assert all(r.done for r in reqs)

    doc = eng.tracer.to_chrome()
    validate_chrome(doc)                   # schema + strict B/E nesting
    names = {ev["name"] for ev in doc["traceEvents"]}
    for must in ("request", "active", "submit", "admit", "prefill_chunk",
                 "first_token", "finish"):
        assert must in names, f"span taxonomy missing {must!r}"
    # every request's lifecycle ordering holds on its own trace row
    for r in reqs:
        kinds = [e["name"] for e in doc["traceEvents"]
                 if e["tid"] == r.rid]
        assert kinds.index("submit") < kinds.index("admit") \
            < kinds.index("first_token") < kinds.index("finish")
    # file exports round-trip
    p = eng.tracer.write_chrome(str(tmp_path / "trace.json"))
    with open(p) as fh:
        validate_chrome(json.load(fh))
    pj = eng.tracer.write_jsonl(str(tmp_path / "trace.jsonl"))
    with open(pj) as fh:
        lines = [json.loads(ln) for ln in fh]
    assert len(lines) == len(doc["traceEvents"])


def test_trace_preemption_reopens_active_span(engine_setup):
    """Preempt closes the 'active' span, readmission reopens it —
    nesting stays valid across preemption cycles."""
    cfg, params = engine_setup
    rng = np.random.RandomState(5)
    eng = ServingEngine(cfg, params, dp=1, b_local=2, max_len=64,
                        tracer=Tracer())
    batch = [Request(i, prompt=list(rng.randint(1, 255, 10)),
                     max_new_tokens=8, slo="batch") for i in range(2)]
    for r in batch:
        eng.submit(r)
    for _ in range(2):
        eng.step()
    hot = [Request(10 + i, prompt=list(rng.randint(1, 255, 8)),
                   max_new_tokens=3, slo="interactive") for i in range(2)]
    for r in hot:
        eng.submit(r)
    eng.run(max_steps=300)
    assert eng.stats["preemptions"] > 0
    doc = eng.tracer.to_chrome()
    validate_chrome(doc)
    preempted = {e["tid"] for e in doc["traceEvents"]
                 if e["name"] == "preempt"}
    assert preempted, "no preempt instants traced"
    for tid in preempted:
        actives = [e for e in doc["traceEvents"]
                   if e["tid"] == tid and e["name"] == "active"]
        assert len(actives) >= 4, (
            "preempted request should close and reopen its active span")


# ============================================ one sync / one collective


def test_one_sync_per_step_with_telemetry_enabled(engine_setup, tmp_path):
    """Telemetry fully on — tracer, flight recorder with a path and
    periodic sync, device counters — the step still performs exactly
    ONE device->host sync."""
    cfg, params = engine_setup
    rng = np.random.RandomState(6)
    fpath = str(tmp_path / "fl.json")
    eng = ServingEngine(
        cfg, params, dp=1, b_local=2, max_len=64, tracer=Tracer(),
        flight=FlightRecorder(capacity=16, path=fpath, sync_every=2))
    for i in range(4):
        eng.submit(Request(i, prompt=list(rng.randint(1, 255, 6)),
                           max_new_tokens=8))
    eng.step()                             # admission + prefill chunk

    import repro.serving.engine as engine_mod
    syncs = []
    real_asarray = np.asarray

    class CountingNp:
        def __getattr__(self, name):
            return getattr(np, name)

        @staticmethod
        def asarray(x, *a, **kw):
            if isinstance(x, jax.Array):
                syncs.append(x.shape)
            return real_asarray(x, *a, **kw)

    orig = engine_mod.np
    engine_mod.np = CountingNp()
    try:
        for _ in range(3):
            eng.step()
    finally:
        engine_mod.np = orig
    assert len(syncs) == 3, f"expected 1 sync/step, saw {syncs}"
    assert all(s == syncs[0] for s in syncs), syncs
    assert syncs[0][0] >= 1 + 3 + N_CTR and syncs[0][1:] == (1, 2), syncs
    assert os.path.exists(fpath), "periodic flight sync never wrote"
    assert FlightRecorder.load(fpath)["records"]


@pytest.mark.skipif(len(jax.devices()) < 4, reason="mesh-8 CI job")
def test_one_collective_per_step_with_telemetry(engine_setup):
    """dp=4 shard_map plane: the default serve variant compiles exactly
    one collective (the status all_gather) with the counter block
    riding the status rows."""
    cfg, params = engine_setup
    eng = ServingEngine(cfg, params, dp=4, b_local=2, max_len=64)
    assert eng.mesh is not None
    hlo = eng._serve_variants[(False, False)].lower(
        eng.params, eng.state, eng.last_tok, eng.out_count, eng.budget,
        eng.temps, eng.topks, eng.seeds,
        jnp.zeros((4, 2, eng.chunk), jnp.int32),
        jnp.zeros((4, 2), jnp.int32),
        jnp.zeros((4, 2), bool), jnp.zeros((4, 2), bool),
        eng.expert_mask,
    ).compile().as_text()
    n_gather = hlo.count("all-gather(") + hlo.count("all-gather-start(")
    n_other = sum(hlo.count(c) for c in
                  ("all-reduce(", "all-reduce-start(", "all-to-all(",
                   "collective-permute(", "collective-permute-start("))
    assert n_gather == 1, f"expected exactly one all_gather: {n_gather}"
    assert n_other == 0, f"unexpected extra collectives: {n_other}"


# ======================================================= facade / prom


def test_stats_property_backward_compatible(engine_setup):
    cfg, params = engine_setup
    eng = ServingEngine(cfg, params, dp=1, b_local=2, max_len=48)
    assert eng.stats is eng.telemetry.counters       # one live ledger
    eng.stats["deadline_expired"] += 1               # external dict write
    assert eng.telemetry.counters["deadline_expired"] == 1
    eng.telemetry.inc("deadline_expired")
    assert eng.stats["deadline_expired"] == 2
    with pytest.raises(KeyError):
        eng.telemetry.inc("not_a_counter")
    with pytest.raises(KeyError):
        eng.telemetry.observe_hist("not_a_hist", 1)


def test_prom_render_parse_roundtrip():
    tel = Telemetry(dp=2)
    tel.inc("tokens_out", 42)
    tel.inc("sched_deferred", 3)
    tel.set_max("pages_peak", 17)
    tel.observe_hist("chunk_hist", 8, 5)
    blk = np.zeros((N_CTR, 2), np.int32)
    blk[CTR_ALLOC] = [4, 6]
    blk[CTR_FREED] = [1, 2]
    blk[CTR_SHARED_FREE] = [30, 20]
    blk[CTR_MARGIN] = [5, 3]
    tel.absorb_counter_block(blk)
    blk2 = blk.copy()
    blk2[CTR_SHARED_FREE] = [25, 26]
    blk2[CTR_MARGIN] = [7, 2]
    tel.absorb_counter_block(blk2)

    metrics = parse_prom(tel.render_prom())
    assert metrics["repro_tokens_out"][()] == 42
    assert metrics["repro_sched_deferred"][()] == 3
    assert metrics["repro_pages_peak"][()] == 17
    assert metrics["repro_chunk_hist"][(("bucket", "8"),)] == 5
    assert metrics["repro_alloc_pages"][(("shard", "0"),)] == 8
    assert metrics["repro_alloc_pages"][(("shard", "1"),)] == 12
    # gauges min-accumulate per shard
    assert metrics["repro_shared_free_min"][(("shard", "0"),)] == 25
    assert metrics["repro_shared_free_min"][(("shard", "1"),)] == 20
    assert metrics["repro_never_dry_margin_min"][(("shard", "1"),)] == 2
    assert metrics["repro_never_dry_margin_min_all"][()] == 2
    assert tel.never_dry_margin_min() == 2
    snap = tel.snapshot()
    assert snap["never_dry_margin_min"] == 2
    assert snap["per_shard"]["alloc_pages"] == [8, 12]
    json.dumps(snap)                        # bench-embeddable


def test_flight_recorder_ring_and_atomic_dump(tmp_path):
    p = str(tmp_path / "ring.json")
    fl = FlightRecorder(capacity=4, path=p)
    for i in range(10):
        fl.record(step=i, payload=np.arange(3, dtype=np.int32))
    assert len(fl.ring) == 4                # bounded
    out = fl.dump("unit_test", {"note": 1})
    assert out == p
    got = FlightRecorder.load(p)
    assert got["reason"] == "unit_test"
    assert got["extra"] == {"note": 1}
    assert [r["step"] for r in got["records"]] == [6, 7, 8, 9]
    assert got["records"][0]["payload"] == [0, 1, 2]   # np -> json
    assert not [f for f in os.listdir(tmp_path)
                if f.startswith("ring.json.") and f != "ring.json"], \
        "torn temp file left behind"
    # adoption carries the window into a successor recorder
    fl2 = FlightRecorder(capacity=8)
    fl2.adopt(fl)
    assert [r["step"] for r in fl2.ring] == [6, 7, 8, 9]
    assert fl2.path == p


def test_reconcile_report_traced_and_dumped(engine_setup, tmp_path):
    """In-place recovery emits the structured reconcile report through
    the tracer and dumps the flight ring with the report attached."""
    cfg, params = engine_setup
    rng = np.random.RandomState(7)
    fpath = str(tmp_path / "fl.json")
    eng = ServingEngine(cfg, params, dp=1, b_local=2, max_len=64,
                        tracer=Tracer(),
                        flight=FlightRecorder(capacity=8, path=fpath))
    eng.submit(Request(0, prompt=list(rng.randint(1, 255, 8)),
                       max_new_tokens=6))
    for _ in range(3):
        eng.step()
    report = eng._recover_inplace()
    assert report["conserved"]
    evs = list(eng.tracer.events)
    rec = [e for e in evs if e["name"] == "reconcile"]
    assert rec, "reconcile never traced"
    assert rec[0]["args"]["conserved"]
    assert any(e["name"] == "recover" and e["ph"] == "B" for e in evs)
    assert any(e["name"] == "recover" and e["ph"] == "E" for e in evs)
    dump = FlightRecorder.load(fpath)
    assert dump["reason"] == "audit_and_reconcile"
    assert dump["extra"]["report"]["conserved"]
    assert eng.stats["flight_dumps"] >= 1
    # the requeued request still completes after recovery
    eng.run(max_steps=300)
    assert not eng.active and eng.scheduler.backlog() == 0
    validate_chrome(eng.tracer.to_chrome())
