"""Serving engine tests: continuous batching + allocator integration."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import models
from repro.configs import get_config, smoke_config
from repro.serving.engine import Request, ServingEngine


@pytest.fixture(scope="module")
def engine_setup():
    cfg = smoke_config(get_config("olmo-1b"))
    params = models.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def test_continuous_batching_completes(engine_setup):
    cfg, params = engine_setup
    eng = ServingEngine(cfg, params, dp=2, b_local=2, max_len=64)
    rng = np.random.RandomState(0)
    reqs = [Request(i, prompt=list(rng.randint(1, 255, rng.randint(3, 10))),
                    max_new_tokens=5) for i in range(9)]
    for r in reqs:
        eng.submit(r)
    eng.run(max_steps=500)
    assert all(r.done for r in reqs)
    assert all(len(r.out_tokens) == 5 for r in reqs)
    # oversubscribed queue (9 reqs, 4 slots) => continuous batching worked
    assert eng.stats["admitted"] == 9


def test_no_page_leaks(engine_setup):
    cfg, params = engine_setup
    eng = ServingEngine(cfg, params, dp=1, b_local=2, max_len=48)
    for i in range(6):
        eng.submit(Request(i, prompt=[1, 2, 3, 4, 5], max_new_tokens=4))
    eng.run(max_steps=300)
    assert eng.page_occupancy() == 0.0, "pages leaked after drain"


def test_host_allocator_constant_time(engine_setup):
    """Admission cost through the paper's allocator is O(1) and the
    simulated allocator reports no safety violations."""
    cfg, params = engine_setup
    eng = ServingEngine(cfg, params, dp=1, b_local=2, max_len=48,
                        scheduler_lanes=3)
    for i in range(12):
        eng.submit(Request(i, prompt=[2, 3], max_new_tokens=3))
    eng.run(max_steps=300)
    assert eng.stats["alloc_steps_max"] <= 70       # O(1) bound (cf. tests/core)
    assert eng.lane_ctx.violations == []


@pytest.mark.parametrize("arch", [
    "olmo-1b",              # pure paged-global attention
    "recurrentgemma-2b",    # ring (sliding window) + rglru recurrent
    "mamba2-370m",          # ssd recurrent
])
def test_decode_step_chunk_matches_single_token(arch):
    """Model-level contract: decode_step_chunk over ragged chunks yields
    the same per-position logits as token-by-token decode_step — across
    the paged, ring-eviction, and recurrent-scan chunk paths."""
    cfg = smoke_config(get_config(arch))
    params = models.init_params(cfg, jax.random.PRNGKey(0))
    from repro.models.decode_init import empty_decode_state
    rng = np.random.RandomState(1)
    toks = rng.randint(1, 255, (1, 2, 11)).astype(np.int32)

    s1 = empty_decode_state(cfg, 1, 2, 64)
    outs1 = []
    for t in range(11):
        lg, s1 = models.decode_step(cfg, params, jnp.asarray(toks[:, :, t]),
                                    s1)
        outs1.append(np.asarray(lg))
    outs1 = np.stack(outs1, axis=2)

    s2 = empty_decode_state(cfg, 1, 2, 64)
    outs2 = []
    for c0 in range(0, 11, 4):           # 11 = 4 + 4 + 3, ragged tail
        n = min(4, 11 - c0)
        chunk = np.zeros((1, 2, 4), np.int32)
        chunk[:, :, :n] = toks[:, :, c0:c0 + n]
        lg, s2, ok = models.decode_step_chunk(
            cfg, params, jnp.asarray(chunk), s2,
            jnp.full((1, 2), n, jnp.int32))
        assert np.asarray(ok).all()
        outs2.append(np.asarray(lg)[:, :, :n])
    outs2 = np.concatenate(outs2, axis=2)

    np.testing.assert_allclose(outs1, outs2, atol=1e-5, rtol=1e-5)
    assert np.array_equal(np.asarray(s1.seq_lens), np.asarray(s2.seq_lens))
    kv1, kv2 = s1.pool.classes[0], s2.pool.classes[0]
    assert np.array_equal(np.asarray(kv1.private_top),
                          np.asarray(kv2.private_top))
    assert np.array_equal(np.asarray(kv1.shared.top),
                          np.asarray(kv2.shared.top))


def test_decode_step_loop_survives_lane_exhaustion(engine_setup):
    """Regression (review finding): raw decode_step loops have no
    per-step rebalance, so once a slot's lane (ell warm pages) is spent
    the allocator must fall back to the shared pool — not write -1 into
    the page table and silently corrupt KV.  30 tokens = 4 pages at
    psz=8, twice the ell=2 lane stock."""
    cfg, params = engine_setup
    from repro.core import classed_pool, hier_pool
    from repro.models.decode_init import empty_decode_state
    rng = np.random.RandomState(5)
    toks = rng.randint(1, 255, (1, 2, 30)).astype(np.int32)

    s1 = empty_decode_state(cfg, 1, 2, 64)          # never rebalanced
    s2 = empty_decode_state(cfg, 1, 2, 64)          # rebalanced per step
    outs1, outs2 = [], []
    for t in range(30):
        lg, s1 = models.decode_step(cfg, params, jnp.asarray(toks[:, :, t]),
                                    s1)
        outs1.append(np.asarray(lg))
        lg, s2 = models.decode_step(cfg, params, jnp.asarray(toks[:, :, t]),
                                    s2)
        s2 = s2._replace(pool=classed_pool.rebalance_dp(s2.pool))
        outs2.append(np.asarray(lg))
    np.testing.assert_allclose(np.stack(outs1), np.stack(outs2),
                               atol=1e-5, rtol=1e-5)
    # all written pages mapped, none through a clamped NULL entry
    assert np.all(np.asarray(s1.page_tables)[:, :, :4] >= 0)
    kv = s1.pool.classes[0]
    total = kv.shared.free_ids.shape[1]
    free = int(hier_pool.total_free(kv))
    assert free + int(hier_pool.num_live(kv)) == total


def test_decode_step_chunk_pool_denial_appends_nothing(engine_setup):
    """Pool exhaustion is all-or-nothing: a chunk whose pages cannot all
    be granted must not advance seq_lens (silently attending over
    never-written positions) and must report ok=False."""
    cfg, params = engine_setup
    from repro.models.decode_init import empty_decode_state
    state = empty_decode_state(cfg, 1, 1, 64)
    # drain the slot lanes AND the shared pool: a chunk must be denied
    kv = state.pool.classes[0]
    kv = kv._replace(
        private_top=jnp.zeros_like(kv.private_top),
        shared=kv.shared._replace(top=jnp.zeros_like(kv.shared.top)))
    state = state._replace(pool=state.pool._replace(
        classes=(kv,) + state.pool.classes[1:]))
    toks = jnp.ones((1, 1, 8), jnp.int32)
    _, state, ok = models.decode_step_chunk(
        cfg, params, toks, state, jnp.full((1, 1), 8, jnp.int32))
    assert not bool(ok[0, 0])
    assert int(state.seq_lens[0, 0]) == 0
    assert np.all(np.asarray(state.page_tables) == -1)


def test_capacity_cap_when_max_len_not_page_multiple(engine_setup):
    """max_len not a multiple of page_size: sequences must stop at the
    page-table capacity instead of overwriting live KV through the
    clamped page index (regression for the chunked path)."""
    cfg, params = engine_setup          # smoke page_size = 8
    eng = ServingEngine(cfg, params, dp=1, b_local=2, max_len=44,
                        chunk_size=8)
    assert eng.capacity == 40           # 5 pages of 8
    reqs = [Request(i, prompt=[2] * 30, max_new_tokens=64)
            for i in range(2)]
    for r in reqs:
        eng.submit(r)
    eng.run(max_steps=200)
    assert all(r.done for r in reqs)
    # 30 prompt + 10 generated hits capacity-1 done detection
    assert all(len(r.out_tokens) <= 10 for r in reqs)
    assert eng.page_occupancy() == 0.0


def test_token_identity_across_lane_widths(engine_setup):
    """The unified token-lane step must emit exactly the same tokens at
    every lane width — chunk_size=1 IS the pre-refactor single-token
    baseline (a width-1 lane per slot per step), so this is the
    legacy-deletion identity bar: greedy decode bit-identical across
    ragged prompt lengths, continuous batching, and chunk sizes."""
    cfg, params = engine_setup
    rng = np.random.RandomState(42)
    prompts = [list(rng.randint(1, 255, rng.randint(2, 29)))
               for _ in range(9)]

    def run(**kw):
        eng = ServingEngine(cfg, params, dp=2, b_local=2, max_len=64, **kw)
        reqs = [Request(i, prompt=list(p), max_new_tokens=4)
                for i, p in enumerate(prompts)]
        for r in reqs:
            eng.submit(r)
        eng.run(max_steps=500)
        assert all(r.done for r in reqs)
        return [r.out_tokens for r in reqs], eng

    width1_out, width1_eng = run(chunk_size=1)
    for chunk in (4, 16):
        out, eng = run(chunk_size=chunk)
        assert out == width1_out, f"chunk_size={chunk} diverged"
        assert eng.page_occupancy() == 0.0
    # chunked prefill takes fewer steps than one-token-per-step
    out16, eng16 = run(chunk_size=16)
    assert eng16.stats["steps"] < width1_eng.stats["steps"]


def test_steady_state_decode_single_sync(engine_setup):
    """Once prompts are consumed, each engine step performs exactly one
    device->host sync (the packed status array) and runs at T=1."""
    cfg, params = engine_setup
    eng = ServingEngine(cfg, params, dp=1, b_local=2, max_len=64,
                        chunk_size=8)
    for i in range(2):
        eng.submit(Request(i, prompt=[3, 5, 7], max_new_tokens=8))
    eng.step()                      # prefill chunk consumes the prompts
    assert all(not p for p in eng.pending_tokens.values())

    import repro.serving.engine as engine_mod
    syncs = []
    real_asarray = np.asarray

    class CountingNp:
        def __getattr__(self, name):
            return getattr(np, name)

        @staticmethod
        def asarray(x, *a, **kw):
            if isinstance(x, jax.Array):
                syncs.append(x.shape)
            return real_asarray(x, *a, **kw)

    orig = engine_mod.np
    engine_mod.np = CountingNp()
    try:
        steps0 = eng.stats["steps"]
        for _ in range(3):
            eng.step()
    finally:
        engine_mod.np = orig
    assert eng.stats["steps"] == steps0 + 3
    assert len(syncs) == 3, f"expected 1 sync/step, saw {syncs}"
    from repro.serving.telemetry import N_CTR
    assert all(s == (4 + N_CTR, 1, 2) for s in syncs), \
        "sync is the packed status (+ telemetry counter rows)"


def test_eos_stops_generation(engine_setup):
    """On-device EOS detection finishes a request mid-budget."""
    cfg, params = engine_setup
    probe = ServingEngine(cfg, params, dp=1, b_local=1, max_len=64)
    r0 = Request(0, prompt=[5, 9, 17, 3], max_new_tokens=6)
    probe.submit(r0)
    probe.run(max_steps=100)
    assert len(r0.out_tokens) == 6
    eos = r0.out_tokens[2]          # greedy decode is deterministic
    first = r0.out_tokens.index(eos)      # eos may repeat earlier

    eng = ServingEngine(cfg, params, dp=1, b_local=1, max_len=64,
                        eos_id=eos)
    r1 = Request(0, prompt=[5, 9, 17, 3], max_new_tokens=6)
    eng.submit(r1)
    eng.run(max_steps=100)
    assert r1.done
    assert r1.out_tokens == r0.out_tokens[:first + 1]
    assert eng.page_occupancy() == 0.0


def test_sampling_determinism(engine_setup):
    """Temperature/top-k sampling (the on-device sampler that replaced
    the hardcoded argmax) is keyed per request by (seed, position):
    same seed → identical streams, different seed → different streams,
    and the draw is invariant to chunk size / batch composition.
    temperature=0 (the default) stays greedy and seed-independent, so
    every token-identity test in this file is unaffected."""
    cfg, params = engine_setup

    def run(seed, temp=0.9, topk=8, chunk=8):
        eng = ServingEngine(cfg, params, dp=1, b_local=2, max_len=64,
                            chunk_size=chunk)
        reqs = [Request(i, prompt=[3 + i, 5, 7, 11], max_new_tokens=6,
                        temperature=temp, top_k=topk, seed=seed + i)
                for i in range(3)]
        for r in reqs:
            eng.submit(r)
        eng.run(max_steps=200)
        assert all(r.done for r in reqs)
        assert eng.page_occupancy() == 0.0
        return [r.out_tokens for r in reqs]

    a = run(42)
    assert a == run(42), "same seed must reproduce"
    assert a != run(43), "different seed must diverge"
    assert a == run(42, chunk=4), "sampling must be chunk-invariant"
    assert run(0, temp=0.0) == run(99, temp=0.0), \
        "greedy must ignore the seed"


def test_outputs_match_offline_decode(engine_setup):
    """Engine output == running the same prompt through raw decode."""
    cfg, params = engine_setup
    prompt = [5, 9, 17, 3]
    n_new = 4

    eng = ServingEngine(cfg, params, dp=1, b_local=1, max_len=64)
    req = Request(0, prompt=list(prompt), max_new_tokens=n_new)
    eng.submit(req)
    eng.run(max_steps=100)

    # offline: token-by-token greedy decode from an empty state
    from repro.models.decode_init import empty_decode_state
    state = empty_decode_state(cfg, 1, 1, 64)
    toks = list(prompt)
    out = []
    for t in range(len(prompt) + n_new - 1):
        tok = jnp.asarray([[toks[t] if t < len(toks) else out[-1]]],
                          jnp.int32)
        logits, state = models.decode_step(cfg, params, tok, state)
        if t >= len(prompt) - 1:
            nxt = int(jnp.argmax(logits[0, 0]))
            out.append(nxt)
            if t >= len(toks) - 1:
                toks.append(nxt)
    assert req.out_tokens == out[:n_new]
