"""Serving engine tests: continuous batching + allocator integration."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import models
from repro.configs import get_config, smoke_config
from repro.serving.engine import Request, ServingEngine


@pytest.fixture(scope="module")
def engine_setup():
    cfg = smoke_config(get_config("olmo-1b"))
    params = models.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def test_continuous_batching_completes(engine_setup):
    cfg, params = engine_setup
    eng = ServingEngine(cfg, params, dp=2, b_local=2, max_len=64)
    rng = np.random.RandomState(0)
    reqs = [Request(i, prompt=list(rng.randint(1, 255, rng.randint(3, 10))),
                    max_new_tokens=5) for i in range(9)]
    for r in reqs:
        eng.submit(r)
    eng.run(max_steps=500)
    assert all(r.done for r in reqs)
    assert all(len(r.out_tokens) == 5 for r in reqs)
    # oversubscribed queue (9 reqs, 4 slots) => continuous batching worked
    assert eng.stats["admitted"] == 9


def test_no_page_leaks(engine_setup):
    cfg, params = engine_setup
    eng = ServingEngine(cfg, params, dp=1, b_local=2, max_len=48)
    for i in range(6):
        eng.submit(Request(i, prompt=[1, 2, 3, 4, 5], max_new_tokens=4))
    eng.run(max_steps=300)
    assert eng.page_occupancy() == 0.0, "pages leaked after drain"


def test_host_allocator_constant_time(engine_setup):
    """Admission cost through the paper's allocator is O(1) and the
    simulated allocator reports no safety violations."""
    cfg, params = engine_setup
    eng = ServingEngine(cfg, params, dp=1, b_local=2, max_len=48,
                        scheduler_lanes=3)
    for i in range(12):
        eng.submit(Request(i, prompt=[2, 3], max_new_tokens=3))
    eng.run(max_steps=300)
    assert eng.stats["alloc_steps_max"] <= 70       # O(1) bound (cf. tests/core)
    assert eng.lane_ctx.violations == []


def test_outputs_match_offline_decode(engine_setup):
    """Engine output == running the same prompt through raw decode."""
    cfg, params = engine_setup
    prompt = [5, 9, 17, 3]
    n_new = 4

    eng = ServingEngine(cfg, params, dp=1, b_local=1, max_len=64)
    req = Request(0, prompt=list(prompt), max_new_tokens=n_new)
    eng.submit(req)
    eng.run(max_steps=100)

    # offline: token-by-token greedy decode from an empty state
    from repro.models.decode_init import empty_decode_state
    state = empty_decode_state(cfg, 1, 1, 64)
    toks = list(prompt)
    out = []
    for t in range(len(prompt) + n_new - 1):
        tok = jnp.asarray([[toks[t] if t < len(toks) else out[-1]]],
                          jnp.int32)
        logits, state = models.decode_step(cfg, params, tok, state)
        if t >= len(prompt) - 1:
            nxt = int(jnp.argmax(logits[0, 0]))
            out.append(nxt)
            if t >= len(toks) - 1:
                toks.append(nxt)
    assert req.out_tokens == out[:n_new]
