"""Minimal stand-in for ``hypothesis`` when it is not installed.

The container image used for tier-1 runs does not ship hypothesis, so
the property tests fall back to a tiny fixed-seed fuzzer: ``@given``
re-runs the test body N times with pseudo-random draws from the same
strategy surface the real library provides (only the subset this repo
uses).  When hypothesis *is* available the real library is used — see
the guarded imports in the test modules.
"""

from __future__ import annotations

import random

_EXAMPLES = 8          # fixed-seed draws per @given test


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def draw(self, rng: random.Random):
        return self._draw(rng)


class st:
    @staticmethod
    def integers(min_value=0, max_value=1 << 30, **_kw):
        return _Strategy(lambda r: r.randint(min_value, max_value))

    @staticmethod
    def sampled_from(elements):
        elements = list(elements)
        return _Strategy(lambda r: r.choice(elements))


def settings(*_a, **_kw):
    """No-op decorator factory (max_examples/deadline are ignored)."""
    def deco(fn):
        return fn
    return deco


def given(**strategies):
    def deco(fn):
        # NOT functools.wraps: pytest must see the wrapper's (*args)
        # signature, not the original's drawn parameters (it would try
        # to resolve them as fixtures).
        def wrapper(*args, **kwargs):
            rng = random.Random(0xC0FFEE)
            for _ in range(_EXAMPLES):
                drawn = {k: s.draw(rng) for k, s in strategies.items()}
                fn(*args, **kwargs, **drawn)
        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        return wrapper
    return deco
