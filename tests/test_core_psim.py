"""Tests for the P-SIM shared stack with memory management (Result 2)."""

import random

import pytest

from repro.core import SimContext, Scheduler, WGStackChecker, Event
from repro.core.memory import BlockMemory
from repro.core.psim import PSimStack
from repro.core.sim import NULL, LLSC, LLSCFromTaggedCAS


def make_stack(ctx, nodes_per_proc=64):
    """Standalone stack with trivial per-process node pools."""
    p = ctx.nprocs
    mem = BlockMemory(ctx, p * nodes_per_proc, k=2)
    free = [list(range(pid * nodes_per_proc, (pid + 1) * nodes_per_proc))
            for pid in range(p)]

    def alloc_node(pid):
        yield from ctx.local_step(pid)
        return free[pid].pop()

    def free_node(pid, nd):
        yield from ctx.local_step(pid)
        free[pid].append(nd)

    return PSimStack(ctx, mem, alloc_node, free_node), mem, free


@pytest.mark.parametrize("p", [2, 3, 4, 8])
@pytest.mark.parametrize("policy", ["random", "bursty", "round_robin"])
def test_stack_semantics(p, policy):
    """Concurrent pushes/pops: pops return exactly the pushed multiset
    minus what remains; no value delivered twice; LIFO per linearization
    (validated via snapshot + conservation)."""
    # Nodes for *all* pushed values can come from one winner's pool, so
    # size pools generously (the recursive allocator avoids this by
    # refilling from the shared pool; here pools are static).
    ctx = SimContext(p, seed=42)
    stack, mem, _ = make_stack(ctx, nodes_per_proc=24 * p + 16)
    sched = Scheduler(seed=42)
    pushed, popped = [], []

    def worker(pid):
        rng = random.Random(pid * 7)
        mine = [1000 * (pid + 1) + i for i in range(20)]
        for v in mine:
            ok = yield from stack.push(pid, v)
            assert ok is True
            pushed.append(v)
            if rng.random() < 0.5:
                r = yield from stack.pop(pid)
                if r != NULL:
                    popped.append(r)

    for pid in range(p):
        sched.add(pid, worker(pid))
    sched.run(policy)

    assert ctx.violations == []
    remaining = [d for _, d in stack.snapshot_stack()]
    assert sorted(popped + remaining) == sorted(pushed)
    assert len(set(popped)) == len(popped), "a value was delivered twice"


@pytest.mark.parametrize("p", [2, 4, 8, 16])
def test_shared_op_linear_time(p):
    """Result 2.1: each push/pop is O(p) instructions."""
    ctx = SimContext(p, seed=0)
    stack, _, _ = make_stack(ctx, nodes_per_proc=8 * p + 16)
    sched = Scheduler(seed=0)
    costs = []

    def worker(pid):
        for i in range(6):
            rec = ctx.begin_op(pid, "push")
            yield from stack.push(pid, pid * 100 + i)
            ctx.end_op(rec)
            costs.append(rec.steps)
            rec = ctx.begin_op(pid, "pop")
            yield from stack.pop(pid)
            ctx.end_op(rec)
            costs.append(rec.steps)

    for pid in range(p):
        sched.add(pid, worker(pid))
    sched.run("random")
    assert max(costs) <= 40 * p + 60, f"p={p}: op cost {max(costs)}"


@pytest.mark.parametrize("p", [2, 4, 8])
def test_internal_alloc_free_bound(p):
    """Result 2.2: <= 2p allocate and <= 2p free calls per shared op."""
    ctx = SimContext(p, seed=1)
    stack, _, _ = make_stack(ctx, nodes_per_proc=10 * p + 16)
    sched = Scheduler(seed=1)
    maxima = [0, 0]

    def worker(pid):
        for i in range(8):
            yield from stack.push(pid, pid * 100 + i)
            a, f = stack.last_op_internal_calls
            maxima[0] = max(maxima[0], a)
            maxima[1] = max(maxima[1], f)
            yield from stack.pop(pid)
            a, f = stack.last_op_internal_calls
            maxima[0] = max(maxima[0], a)
            maxima[1] = max(maxima[1], f)

    for pid in range(p):
        sched.add(pid, worker(pid))
    sched.run("bursty")
    assert maxima[0] <= 2 * p, f"allocs per op {maxima[0]} > 2p"
    assert maxima[1] <= 2 * p, f"frees per op {maxima[1]} > 2p"


def test_node_space_bound():
    """Result 2.3: <= M + O(p^2) nodes allocated-but-not-freed."""
    p = 4
    ctx = SimContext(p, seed=2)
    stack, _, free = make_stack(ctx, nodes_per_proc=152)
    sched = Scheduler(seed=2)

    def worker(pid):
        for i in range(24):
            yield from stack.push(pid, pid * 1000 + i)
        for _ in range(12):
            yield from stack.pop(pid)

    for pid in range(p):
        sched.add(pid, worker(pid))
    sched.run("random")
    assert ctx.violations == []
    M = len(stack.snapshot_stack())
    outstanding = p * 96 - sum(len(f) for f in free)
    assert outstanding <= M + 2 * p * p, (
        f"{outstanding} nodes un-freed with stack size {M}")


def test_small_history_linearizable():
    """Wing&Gong-checked linearizability on small concurrent histories."""
    for seed in range(8):
        p = 3
        ctx = SimContext(p, seed=seed)
        stack, _, _ = make_stack(ctx)
        sched = Scheduler(seed=seed)
        events = []

        def worker(pid):
            rng = random.Random(seed * 31 + pid)
            for i in range(2):
                v = (pid + 1) * 10 + i
                t0 = ctx.global_step
                yield from stack.push(pid, v)
                events.append(Event(pid, "push", v, True, t0, ctx.global_step))
                if rng.random() < 0.7:
                    t0 = ctx.global_step
                    r = yield from stack.pop(pid)
                    events.append(Event(
                        pid, "pop", None, None if r == NULL else r,
                        t0, ctx.global_step))

        for pid in range(p):
            sched.add(pid, worker(pid))
        sched.run("random")
        assert WGStackChecker(events).check(), f"seed {seed} not linearizable"


def test_llsc_semantics_match_tagged_cas():
    """The black-box LLSC behaves identically to the tagged-CAS build."""
    for seed in range(6):
        rng = random.Random(seed)
        ctx = SimContext(3, seed=seed)
        a = LLSC(ctx, init=0)
        b = LLSCFromTaggedCAS(ctx, init=0)

        def drive(obj):
            out = []
            rng2 = random.Random(seed)
            gens = {}
            for step in range(300):
                pid = rng2.randrange(3)
                op = rng2.choice(["ll", "vl", "sc"])
                if op == "ll":
                    g = obj.ll(pid)
                elif op == "vl":
                    g = obj.vl(pid)
                else:
                    g = obj.sc(pid, rng2.randrange(100))
                try:
                    while True:
                        next(g)
                except StopIteration as e:
                    out.append((op, pid, e.value))
            return out

        assert drive(a) == drive(b)
