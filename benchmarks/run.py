"""Benchmark harness — one function per claim/table (CSV to stdout).

The paper is theory-only (no experiment tables), so the benches validate
its RESULT statements empirically and measure the systems layers built
on them:

  result1_worst_case_steps   — O(1) allocate/free (Result 1.2)
  result1_vs_baselines       — worst-case steps vs lock / Treiber
  result1_space_overhead     — Theta(p^2) metadata (Result 1.4)
  result1_memory_blowup      — vs Hoard-style Theta(p*S) (section 3.1)
  result2_shared_op_cost     — O(p) shared stack ops (Result 2.1)
  jax_block_pool_o1          — device pool: alloc AND chunked alloc_n
                               cost independent of m
  jax_paged_kv_append        — paged KV append + append_chunk throughput
  serving_throughput         — continuous-batching engine tok/s:
                               width-1 token lanes (chunk=1, the
                               single-token baseline the deleted
                               legacy path degenerated to) vs full
                               chunked lanes, on a decode-heavy and a
                               prompt-heavy mix, in the same run
  serving_pool_churn         — many short requests with a hot ~90%-shared
                               prompt prefix: prefix sharing (refcounted
                               pages + COW, DESIGN.md §7) vs unshared,
                               pages-in-use reduction and token identity
  serving_overload           — bursty arrivals at 2x slot capacity with an
                               80% hot-prefix mix and an interactive SLO
                               class landing mid-burst (DESIGN.md §8):
                               p50/p99 latency, prefix hit rate,
                               preemption count, and the prefill work the
                               pinned prefix cache saves across
                               drain-to-idle gaps vs pinning disabled —
                               token-identical to an unconstrained run,
                               zero leaks after drain + pin flush
  serving_speculative        — 80%-hot-prefix greedy trace with repeated
                               full prompts (DESIGN.md §10): draft
                               accept rate, generated tok/s vs the
                               non-speculative run of the same trace,
                               whole-page rollback volume, token
                               identity, zero leaks
  serving_mesh_shards        — dp=4 engine on the shard_map allocation
                               plane (DESIGN.md §9; a real device mesh
                               when the process has >= 4 devices):
                               per-shard occupancy balance from the
                               status row, token identity vs the
                               single-device run, zero leaks

Output: ``name,us_per_call,derived`` CSV rows, plus machine-readable
``BENCH_serving.json`` (written next to the CWD) so the serving perf
trajectory is tracked across PRs.
"""

import json
import os
import random
import statistics
import sys
import time

# repo root on sys.path: result2_shared_op_cost borrows a helper from
# tests/, which `python benchmarks/run.py` would otherwise not resolve
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))


def _time_us(fn, n=5):
    ts = []
    for _ in range(n):
        t0 = time.perf_counter()
        fn()
        ts.append((time.perf_counter() - t0) * 1e6)
    return statistics.median(ts)


def result1_worst_case_steps():
    from repro.core import SimContext, WaitFreeAllocator, Scheduler
    worst = {}
    us = 0.0
    for p in (2, 4, 8, 16, 32):
        ctx = SimContext(p, seed=0)
        alloc = WaitFreeAllocator(ctx, shared_batches=4 * p)
        sched = Scheduler(seed=0)

        def phased(pid, alloc=alloc):
            # alloc/free bursts sized to force shared-pool transfers
            held = []
            for ph in range(4):
                if ph % 2 == 0:
                    for _ in range(alloc.ell * 3):
                        held.append((yield from alloc.allocate(pid)))
                else:
                    while held:
                        yield from alloc.free(pid, held.pop())

        for pid in range(p):
            sched.add(pid, phased(pid))
        t0 = time.perf_counter()
        sched.run("random")
        us = (time.perf_counter() - t0) * 1e6 / max(len(ctx.history), 1)
        worst[p] = max(op.steps for op in ctx.history if op.completed)
    derived = "worst_steps_by_p=" + "/".join(
        f"{p}:{w}" for p, w in worst.items())
    print(f"result1_worst_case_steps,{us:.2f},{derived}")
    return worst


def result1_vs_baselines():
    from repro.core import SimContext, Scheduler
    from repro.core.baselines import LockFreeListAllocator, TreiberAllocator
    p = 8
    rows = {}
    for name, cls in (("lock", LockFreeListAllocator),
                      ("treiber", TreiberAllocator)):
        ctx = SimContext(p, seed=0)
        alloc = cls(ctx, m=4096)
        sched = Scheduler(seed=0)

        def workload(pid, alloc=alloc):
            held = []
            rng = random.Random(pid)
            for _ in range(150):
                if not held or (len(held) < 16 and rng.random() < 0.6):
                    b = yield from alloc.allocate(pid)
                    if b >= 0:
                        held.append(b)
                else:
                    yield from alloc.free(pid, held.pop())

        for pid in range(p):
            sched.add(pid, workload(pid))
        sched.run("bursty")
        rows[name] = max(op.steps for op in ctx.history if op.completed)
    from repro.core import WaitFreeAllocator, closed_loop
    ctx = SimContext(p, seed=0)
    ours = WaitFreeAllocator(ctx, shared_batches=4 * p)
    sched = Scheduler(seed=0)
    for pid in range(p):
        sched.add(pid, closed_loop(pid, ours, 150, random.Random(pid),
                                   scribble=False))
    sched.run("bursty")
    rows["ours"] = max(op.steps for op in ctx.history if op.completed)
    print(f"result1_vs_baselines,0,"
          f"worst_steps ours={rows['ours']} lock={rows['lock']} "
          f"treiber={rows['treiber']}")
    return rows


def result1_space_overhead():
    from repro.core import SimContext, WaitFreeAllocator
    words = {}
    for p in (2, 4, 8, 16, 32, 64):
        ctx = SimContext(p, seed=0)
        alloc = WaitFreeAllocator(ctx, shared_batches=4 * p)
        words[p] = alloc.metadata_words()
    # quadratic fit sanity: words(2p)/words(p) -> 4 as p grows
    ratio = words[64] / words[32]
    derived = ("words_by_p=" + "/".join(f"{p}:{w}" for p, w in words.items())
               + f" growth_ratio_64v32={ratio:.2f}")
    print(f"result1_space_overhead,0,{derived}")
    return words


def result1_memory_blowup():
    from repro.core.baselines import HoardSpaceModel
    rows = []
    for p in (8, 64, 256):
        hoard = HoardSpaceModel(p, superblock_blocks=1024)  # 4KB/4-word blk
        ours = HoardSpaceModel.paper_blowup_blocks(p)
        rows.append(f"p{p}:ours={ours},hoard={hoard.additive_blowup_blocks()}")
    print(f"result1_memory_blowup,0,additive_blocks {' '.join(rows)}")


def result2_shared_op_cost():
    from repro.core import SimContext, Scheduler
    from tests.test_core_psim import make_stack
    costs = {}
    for p in (2, 4, 8, 16):
        ctx = SimContext(p, seed=0)
        stack, _, _ = make_stack(ctx, nodes_per_proc=8 * p + 16)
        sched = Scheduler(seed=0)
        worst = [0]

        def worker(pid, worst=worst, stack=stack, ctx=ctx):
            for i in range(5):
                rec = ctx.begin_op(pid, "push")
                yield from stack.push(pid, pid * 100 + i)
                ctx.end_op(rec)
                worst[0] = max(worst[0], rec.steps)

        for pid in range(p):
            sched.add(pid, worker(pid))
        sched.run("random")
        costs[p] = worst[0]
    derived = ("push_steps_by_p=" + "/".join(f"{p}:{c}" for p, c in costs.items())
               + " (linear in p)")
    print(f"result2_shared_op_cost,0,{derived}")


def jax_block_pool_o1():
    """alloc and chunked alloc_n cost vs pool size m (donated buffers so
    the free-stack is updated in place, as the serving step does — an
    un-donated jit would copy the m-sized stack and mask the O(R) op)."""
    import jax
    import jax.numpy as jnp
    from repro.core import block_pool

    def timed_pairs(m, step):
        pool = block_pool.create(m)
        pool = step(pool)                        # compile
        jax.block_until_ready(pool.top)
        ts = []
        for _ in range(20):
            t0 = time.perf_counter()
            pool = step(pool)
            jax.block_until_ready(pool.top)
            ts.append((time.perf_counter() - t0) * 1e6)
        return statistics.median(ts)

    counts = jnp.tile(jnp.asarray([2, 0, 3, 1], jnp.int32), 16)  # 64 slots
    mask = jnp.ones(64, bool)
    us_by_m, usn_by_m = {}, {}
    for m in (1 << 10, 1 << 14, 1 << 18):
        alloc = jax.jit(block_pool.alloc, donate_argnums=(0,))
        alloc_n = jax.jit(block_pool.alloc_n, static_argnums=(2,),
                          donate_argnums=(0,))
        freef = jax.jit(block_pool.free, donate_argnums=(0,))

        def pair(pool, alloc=alloc, freef=freef):
            pool, ids = alloc(pool, mask)
            return freef(pool, ids)

        def pair_n(pool, alloc_n=alloc_n, freef=freef):
            pool, ids = alloc_n(pool, counts, 4)
            return freef(pool, ids.reshape(-1))

        us_by_m[m] = timed_pairs(m, pair)
        usn_by_m[m] = timed_pairs(m, pair_n)
    derived = ("us_by_pool_size=" + "/".join(
        f"{m}:{u:.1f}" for m, u in us_by_m.items())
        + " alloc_n_us_by_pool_size=" + "/".join(
        f"{m}:{u:.1f}" for m, u in usn_by_m.items()))
    print(f"jax_block_pool_o1,{us_by_m[1 << 18]:.2f},{derived}")


def jax_paged_kv_append():
    import jax
    import jax.numpy as jnp
    from repro.core import kv_cache
    cache = kv_cache.create(num_pages=256, page_size=16, kv_heads=4,
                            head_dim=64, max_seqs=16, max_pages_per_seq=16)
    app = jax.jit(kv_cache.append)
    appc = jax.jit(kv_cache.append_chunk)
    k = jnp.ones((16, 4, 64))
    v = jnp.ones((16, 4, 64))
    act = jnp.ones(16, bool)
    C = 16
    kc = jnp.ones((16, C, 4, 64))
    vc = jnp.ones((16, C, 4, 64))
    lens = jnp.full((16,), C, jnp.int32)
    jax.block_until_ready(app(cache, k, v, act)[1])          # compile
    jax.block_until_ready(appc(cache, kc, vc, lens)[1])
    us = _time_us(lambda: jax.block_until_ready(app(cache, k, v, act)[1]),
                  n=20)
    usc = _time_us(lambda: jax.block_until_ready(
        appc(cache, kc, vc, lens)[1]), n=20)
    print(f"jax_paged_kv_append,{us:.2f},tokens_per_call=16 "
          f"chunk_us={usc:.2f} chunk_tokens_per_call={16 * C} "
          f"chunk_us_per_token={usc / (16 * C):.3f}")


def _run_serving_mix(cfg, params, prompts, max_new, chunk):
    from repro.serving.engine import Request, ServingEngine
    eng = ServingEngine(cfg, params, dp=2, b_local=2, max_len=96,
                        chunk_size=chunk)
    # warmup: compile every step shape (chunk prefill, T=1 decode,
    # release) off the clock
    w = Request(-1, prompt=list(range(2, 2 + chunk + 2)), max_new_tokens=2)
    eng.submit(w)
    eng.run(max_steps=100)
    eng.stats.update(steps=0, tokens_out=0, prompt_tokens=0)
    for i, p in enumerate(prompts):
        eng.submit(Request(i, prompt=list(p), max_new_tokens=max_new))
    t0 = time.perf_counter()
    eng.run(max_steps=4000)
    dt = time.perf_counter() - t0
    total = eng.stats["tokens_out"] + eng.stats["prompt_tokens"]
    return {
        "gen_tok_per_s": round(eng.stats["tokens_out"] / dt, 1),
        "total_tok_per_s": round(total / dt, 1),
        "steps": eng.stats["steps"],
        "us_per_step": round(dt * 1e6 / max(eng.stats["steps"], 1)),
        "wall_s": round(dt, 3),
        "alloc_O1_max": eng.stats["alloc_steps_max"],
        "leak_free": eng.page_occupancy() == 0.0,
    }


def serving_throughput():
    """Width-1 vs chunked token lanes on decode-heavy and prompt-heavy
    mixes (same params, same run) + BENCH_serving.json for trend
    tracking.  chunk=1 runs the SAME unified step one token per lane
    per step — the baseline the deleted legacy path degenerated to —
    so the A/B now isolates exactly the lane-width win."""
    import numpy as np
    import jax
    from repro import models
    from repro.configs import get_config, smoke_config
    cfg = smoke_config(get_config("olmo-1b"))
    params = models.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.RandomState(0)
    chunk = 16
    mixes = {
        # prompt len >= 4x generation len: chunked prefill dominates
        "prompt_heavy": ([list(rng.randint(1, 255, 48)) for _ in range(12)], 8),
        "decode_heavy": ([list(rng.randint(1, 255, 6)) for _ in range(12)], 24),
    }
    report = {"config": cfg.name, "chunk_size": chunk, "mixes": {}}
    for mix, (prompts, max_new) in mixes.items():
        width1 = _run_serving_mix(cfg, params, prompts, max_new, chunk=1)
        chunked = _run_serving_mix(cfg, params, prompts, max_new,
                                   chunk=chunk)
        speedup = (chunked["total_tok_per_s"] /
                   max(width1["total_tok_per_s"], 1e-9))
        report["mixes"][mix] = {"width1": width1, "chunked": chunked,
                                "speedup_total": round(speedup, 2)}
        print(f"serving_throughput,{chunked['us_per_step']},mix={mix} "
              f"chunked_tok_per_s={chunked['total_tok_per_s']} "
              f"width1_tok_per_s={width1['total_tok_per_s']} "
              f"speedup={speedup:.2f}x steps={chunked['steps']} "
              f"alloc_O1_max={chunked['alloc_O1_max']}")
    report["mixes"]["pool_churn"] = serving_pool_churn(cfg, params)
    report["mixes"]["overload"] = serving_overload(cfg, params)
    report["mixes"]["mesh_shards"] = serving_mesh_shards(cfg, params)
    report["mixes"]["speculative"] = serving_speculative(cfg, params)
    report["mixes"]["chaos"] = serving_chaos(cfg, params)
    with open("BENCH_serving.json", "w") as f:
        json.dump(report, f, indent=2)
        f.write("\n")
    return report


def serving_speculative(cfg, params):
    """Speculative decode on shared prefixes (DESIGN.md §10): an
    80%-hot-prefix greedy trace where hot traffic repeats full prompts
    (the production shape speculation wins on — retried/templated
    queries).  Reports the draft accept rate, generated-token
    throughput vs the non-speculative run of the same trace, the
    whole-page over-allocation rolled back by rejected drafts, and the
    usual identity/leak axes."""
    import numpy as np
    from repro.serving.engine import Request, ServingEngine

    rng = np.random.RandomState(0)
    hot = list(rng.randint(1, 255, 16))                  # 2 pages of 8
    uniq = [hot + list(rng.randint(1, 255, 4 + i)) for i in range(4)]
    spec = []
    for i in range(24):
        if rng.random_sample() < 0.8:
            spec.append(list(uniq[rng.randint(len(uniq))]))   # hot repeat
        else:
            spec.append(list(rng.randint(1, 255, 8 + i % 9)))

    def drive(eng, reqs, max_steps=2000):
        t0 = time.perf_counter()
        for r in reqs:
            eng.submit(r)
        eng.run(max_steps=max_steps)
        dt = time.perf_counter() - t0
        assert all(r.done for r in reqs)
        return dt

    def spec_stats(eng):
        s = eng.stats
        return {
            "steps": s["steps"],
            "spec_lanes": s["spec_lanes"],
            "drafted": s["spec_drafted"],
            "accepted": s["spec_accepted"],
            "accept_rate": round(s["spec_accepted"]
                                 / max(s["spec_drafted"], 1), 2),
            "accept_hist": {str(k): v
                            for k, v in sorted(s["accept_hist"].items())},
            "pages_rolled_back": s["spec_pages_rolled_back"],
            "lane_hist": {str(k): v
                          for k, v in sorted(s["chunk_hist"].items())},
        }

    def run(speculate):
        eng = ServingEngine(cfg, params, dp=1, b_local=4, max_len=96,
                            chunk_size=16, speculate=speculate,
                            draft_len=4)
        # warm twice: the first pass over the unique hot prompts records
        # their continuations, the second replays them so draft lanes
        # fire and the speculative step variant compiles off the clock
        for w in range(2):
            drive(eng, [Request(-1 - i - 100 * w, prompt=list(p),
                                max_new_tokens=8)
                        for i, p in enumerate(uniq)], max_steps=500)
        for k in ("steps", "tokens_out", "prompt_tokens", "spec_lanes",
                  "spec_drafted", "spec_accepted",
                  "spec_pages_rolled_back"):
            eng.stats[k] = 0
        eng.stats["accept_hist"] = {}
        eng.stats["chunk_hist"] = {}
        reqs = [Request(i, prompt=list(p), max_new_tokens=8)
                for i, p in enumerate(spec)]
        dt = drive(eng, reqs)
        row = spec_stats(eng)
        row["gen_tok_per_s"] = round(eng.stats["tokens_out"] / dt, 1)
        row["leak_free"] = eng.page_occupancy() == 0.0
        return [r.out_tokens for r in reqs], row, eng

    out_ns, base, _ = run(False)
    out_sp, specd, eng = run(True)

    # rollback probe: greedy exact-match drafting only rejects when the
    # recorded history is wrong, so force it — poison each hot prompt's
    # continuation with its real first token + garbage and replay.
    # Measures the cost of worst-case rejection: every draft rolled
    # back, §4.2 and conservation intact, still leak-free.
    for i, p in enumerate(uniq):
        key = eng.spec_store.key_of(p)
        real = out_sp[spec.index(p)] if p in spec else None
        first = (real[0],) if real else ()
        tail = tuple(p[len(key):])
        garbage = tuple((t + 101) % 250 + 1 for t in range(4))
        eng.spec_store.streams.pop(key, None)
        eng.spec_store.record(key, tail + first + garbage)
    s0 = dict(eng.stats)
    probe = [Request(1000 + i, prompt=list(p), max_new_tokens=8)
             for i, p in enumerate(uniq * 2)]
    drive(eng, probe, max_steps=500)
    rejected_probe = {
        "drafted": eng.stats["spec_drafted"] - s0["spec_drafted"],
        "accepted": eng.stats["spec_accepted"] - s0["spec_accepted"],
        "pages_rolled_back": (eng.stats["spec_pages_rolled_back"]
                              - s0["spec_pages_rolled_back"]),
        "leak_free": eng.page_occupancy() == 0.0,
    }

    row = {"baseline": base, "speculative": specd,
           "rejected_probe": rejected_probe,
           "token_identical": out_ns == out_sp,
           "steps_saved": base["steps"] - specd["steps"],
           "speedup_gen": round(specd["gen_tok_per_s"]
                                / max(base["gen_tok_per_s"], 1e-9), 2)}
    print(f"serving_speculative,0,accept_rate={specd['accept_rate']} "
          f"steps {base['steps']}->{specd['steps']} "
          f"gen_tok_per_s {base['gen_tok_per_s']}->"
          f"{specd['gen_tok_per_s']} "
          f"probe_rolled_back={rejected_probe['pages_rolled_back']} "
          f"token_identical={row['token_identical']} "
          f"leak_free={specd['leak_free'] and rejected_probe['leak_free']}")
    return row


def serving_mesh_shards(cfg, params):
    """Multi-host allocation plane smoke (DESIGN.md §9): a mixed
    hot-prefix workload on a dp=4 engine — shard_mapped over a real
    ("dp",) device mesh when the process has >= 4 devices (CI's mesh-8
    job forces 8 CPU devices), vmap semantics otherwise.  Reports the
    per-shard occupancy stats from the packed status row (the
    scheduler's placement balance across hosts) and the usual
    leak/identity axes vs a single-device run of the same trace."""
    import numpy as np
    from repro.serving.engine import Request, ServingEngine
    from repro.serving.sched import SchedConfig

    rng = np.random.RandomState(0)
    hot = list(rng.randint(1, 255, 24))                  # 3 pages of 8
    spec = []
    for i in range(20):
        if rng.random_sample() < 0.6:
            prompt = hot + list(rng.randint(1, 255, 2 + i % 5))
        else:
            prompt = list(rng.randint(1, 255, 8 + i % 9))
        spec.append(prompt)

    def run(dp, b_local):
        eng = ServingEngine(cfg, params, dp=dp, b_local=b_local,
                            max_len=64, chunk_size=16,
                            sched=SchedConfig(pin_pages=8))
        reqs = [Request(i, prompt=list(p), max_new_tokens=4)
                for i, p in enumerate(spec)]
        t0 = time.perf_counter()
        for r in reqs:
            eng.submit(r)
        eng.run(max_steps=1000)
        dt = time.perf_counter() - t0
        assert all(r.done for r in reqs)
        eng.flush_pins()
        return [r.out_tokens for r in reqs], eng, dt

    out4, eng4, dt = run(dp=4, b_local=2)
    out1, eng1, _ = run(dp=1, b_local=2)
    occ = eng4.shard_occupancy()
    row = {
        "mesh_devices": occ["mesh_devices"],
        "shard_map": eng4.mesh is not None,
        "gen_tok_per_s": round(eng4.stats["tokens_out"] / dt, 1),
        "steps": eng4.stats["steps"],
        "pages_mean_shard": occ["pages_mean_shard"],
        "pages_peak_shard": occ["pages_peak_shard"],
        "prefix_hit_rate": round(eng4.stats["prefix_shared_reqs"]
                                 / max(eng4.stats["admitted"], 1), 2),
        "token_identical_vs_single_device": out4 == out1,
        "leak_free": eng4.page_occupancy() == 0.0,
    }
    print(f"serving_mesh_shards,0,devices={row['mesh_devices']} "
          f"shard_map={row['shard_map']} "
          f"pages_mean_shard={row['pages_mean_shard']} "
          f"pages_peak_shard={row['pages_peak_shard']} "
          f"token_identical={row['token_identical_vs_single_device']} "
          f"leak_free={row['leak_free']}")
    return row


def serving_pool_churn(cfg, params):
    """Pool-churn scenario: a stream of short requests sharing a hot
    ~90% prompt prefix (the production shape: one system prompt, many
    users).  Measures prefix sharing's pages-in-use win at equal
    outputs — the refactor's acceptance bar is >= 2x fewer mean
    pages-in-use with token-identical generations."""
    import numpy as np
    from repro.serving.engine import Request, ServingEngine
    rng = np.random.RandomState(0)
    hot = list(rng.randint(1, 255, 68))                    # 8.5 pages of 8
    prompts = [hot + list(rng.randint(1, 255, 6)) for _ in range(16)]

    def run(share):
        eng = ServingEngine(cfg, params, dp=1, b_local=6, max_len=96,
                            chunk_size=16, prefix_sharing=share)
        # warm the hot prefix: the first request prefills it, then the
        # arrival stream overlaps lifetimes (continuous batching)
        reqs = [Request(0, prompt=list(prompts[0]), max_new_tokens=8)]
        eng.submit(reqs[0])
        for _ in range(5):
            eng.step()
        t0 = time.perf_counter()
        for i, p in enumerate(prompts[1:], 1):
            r = Request(i, prompt=list(p), max_new_tokens=8)
            reqs.append(r)
            eng.submit(r)
        eng.run(max_steps=2000)
        dt = time.perf_counter() - t0
        assert all(r.done for r in reqs)
        # shared prompt tokens were SERVED without being fed — count
        # them in delivered throughput or the shared run looks slower
        # for doing strictly less work per request
        total = (eng.stats["tokens_out"] + eng.stats["prompt_tokens"]
                 + eng.stats["prefix_shared_tokens"])
        return [r.out_tokens for r in reqs], {
            "delivered_tok_per_s": round(total / dt, 1),
            "steps": eng.stats["steps"],
            "pages_mean": round(eng.pages_mean(), 1),
            "pages_peak": eng.stats["pages_peak"],
            "prefix_shared_tokens": eng.stats["prefix_shared_tokens"],
            "prefix_shared_reqs": eng.stats["prefix_shared_reqs"],
            "leak_free": eng.page_occupancy() == 0.0,
        }

    out_u, unshared = run(False)
    out_s, shared = run(True)
    ratio = unshared["pages_mean"] / max(shared["pages_mean"], 1e-9)
    row = {"unshared": unshared, "shared": shared,
           "pages_mean_reduction": round(ratio, 2),
           "token_identical": out_u == out_s}
    print(f"serving_pool_churn,0,pages_mean unshared={unshared['pages_mean']} "
          f"shared={shared['pages_mean']} reduction={ratio:.2f}x "
          f"token_identical={out_u == out_s} "
          f"shared_tokens={shared['prefix_shared_tokens']} "
          f"delivered_tok_per_s shared={shared['delivered_tok_per_s']} "
          f"unshared={unshared['delivered_tok_per_s']}")
    return row


def serving_overload(cfg, params):
    """Bursty-overload scenario (DESIGN.md §8): each burst submits 2x
    the slot capacity, 80% of prompts share a hot 5-page prefix, and an
    interactive-class pair lands mid-burst (forcing preemption of
    standard work).  The engine fully drains between bursts, so without
    pinning the hot prefix dies with each burst's last request and the
    next burst re-prefills it from scratch — the pinned run re-shares
    it across the idle gap.  Acceptance axes: token identity with an
    unconstrained run, zero leaks after drain + pin flush, and a
    measured prefill-work reduction from pinning."""
    import numpy as np
    from repro.serving.engine import Request, ServingEngine
    from repro.serving.sched import SchedConfig

    rng = np.random.RandomState(0)
    hot = list(rng.randint(1, 255, 40))                  # 5 pages of 8
    spec = []
    for i in range(24):
        if rng.random_sample() < 0.8:
            prompt = hot + list(rng.randint(1, 255, 4 + i % 5))
        else:
            prompt = list(rng.randint(1, 255, 12 + i % 7))
        spec.append((prompt, "interactive" if i % 4 == 3 else "standard"))

    def run(b_local, pin_pages, bursts):
        eng = ServingEngine(cfg, params, dp=1, b_local=b_local, max_len=96,
                            chunk_size=16,
                            sched=SchedConfig(pin_pages=pin_pages))
        reqs = [Request(i, prompt=list(p), max_new_tokens=6, slo=slo)
                for i, (p, slo) in enumerate(spec)]
        per = -(-len(reqs) // bursts)
        t0 = time.perf_counter()
        for j in range(0, len(reqs), per):
            burst = reqs[j:j + per]
            # standard work first; interactive arrives mid-burst, after
            # the slots have filled — the preemption trigger
            for r in burst:
                if r.slo != "interactive":
                    eng.submit(r)
            for _ in range(2):
                eng.step()
            for r in burst:
                if r.slo == "interactive":
                    eng.submit(r)
            eng.run(max_steps=1000)              # drain to idle
        dt = time.perf_counter() - t0
        assert all(r.done for r in reqs)
        pinned_steady = eng.pinned_pages()
        eng.flush_pins()
        lat = eng.latency_quantiles()
        s = eng.stats
        return [r.out_tokens for r in reqs], {
            "gen_tok_per_s": round(s["tokens_out"] / dt, 1),
            "steps": s["steps"],
            "p50_ms": round(lat["p50_s"] * 1e3, 1),
            "p99_ms": round(lat["p99_s"] * 1e3, 1),
            "first_token_p50_ms": round(lat["first_token_p50_s"] * 1e3, 1),
            "prompt_tokens": s["prompt_tokens"],
            "prefix_shared_tokens": s["prefix_shared_tokens"],
            "prefix_hit_rate": round(
                s["prefix_shared_reqs"] / max(s["admitted"], 1), 2),
            "pin_hits": s["pin_hit_reqs"],
            "preemptions": s["preemptions"],
            "deferred": eng.scheduler.stats["deferred"],
            "pinned_pages_steady": pinned_steady,
            "leak_free": eng.page_occupancy() == 0.0,
        }

    out_ref, _ = run(b_local=8, pin_pages=0, bursts=1)   # unconstrained
    out_pin, pinned = run(b_local=4, pin_pages=12, bursts=3)
    out_raw, nopin = run(b_local=4, pin_pages=0, bursts=3)
    saved = nopin["prompt_tokens"] - pinned["prompt_tokens"]
    row = {"pinned": pinned, "unpinned": nopin,
           "token_identical": out_pin == out_ref and out_raw == out_ref,
           "prefill_tokens_saved_by_pinning": saved,
           "prefill_pages_saved_by_pinning": saved // cfg.page_size}
    print(f"serving_overload,0,2x-burst 80%-hot: p50={pinned['p50_ms']}ms "
          f"p99={pinned['p99_ms']}ms hit_rate={pinned['prefix_hit_rate']} "
          f"preemptions={pinned['preemptions']} "
          f"prefill_pages_saved={row['prefill_pages_saved_by_pinning']} "
          f"token_identical={row['token_identical']} "
          f"leak_free={pinned['leak_free'] and nopin['leak_free']}")
    return row


def serving_chaos(cfg, params):
    """Fault-tolerance axes (DESIGN.md §11): crash the host inside the
    torn drain/refill rebalance window, rebuild the engine, reconcile
    allocator state from the device arrays + admission journal, and
    measure (a) recovery wall time, (b) token identity of the recovered
    run vs an unfaulted reference (greedy AND sampled lanes), and
    (c) warm vs cold restart — a warm restart carries pinned prefixes
    and speculation streams through the checkpoint sidecar, so the hot
    prefix needs no re-prefill."""
    import tempfile

    import numpy as np
    from repro.checkpoint.ckpt import Checkpointer
    from repro.serving import chaos
    from repro.serving.engine import Request, ServingEngine
    from repro.serving.sched import SchedConfig

    rng = np.random.RandomState(0)
    hot = list(rng.randint(1, 255, 16))                  # 2 pages of 8
    spec = [hot + list(rng.randint(1, 255, 4 + i % 5)) for i in range(8)]

    def reqs():
        return [Request(i, prompt=list(p), max_new_tokens=6,
                        temperature=0.7 if i % 2 else 0.0, seed=40 + i)
                for i, p in enumerate(spec)]

    # ---- reference: no faults
    ref_reqs = reqs()
    eng = ServingEngine(cfg, params, dp=1, b_local=4, max_len=96,
                        chunk_size=16)
    for r in ref_reqs:
        eng.submit(r)
    eng.run(max_steps=1000)
    ref_out = {r.rid: list(r.out_tokens) for r in ref_reqs}

    # ---- crash mid-rebalance, recover, finish
    journal = chaos.ServingJournal()
    injector = chaos.parse_faults("crash@4:post_sync:torn")

    def build():
        return ServingEngine(cfg, params, dp=1, b_local=4, max_len=96,
                             chunk_size=16, journal=journal,
                             injector=injector)

    eng = build()
    for r in reqs():
        eng.submit(r)
    try:
        eng.run(max_steps=1000)
        raise AssertionError("injected crash never fired")
    except chaos.HostCrash:
        pass
    t0 = time.perf_counter()
    eng, report = chaos.recover_engine(build, eng, journal)
    recovery_s = time.perf_counter() - t0
    eng.run(max_steps=1000)
    out = journal.outputs()
    crash_identical = (journal.finished() == set(ref_out)
                       and all(out[rid] == ref_out[rid] for rid in ref_out))
    crash_row = {
        "recovery_ms": round(recovery_s * 1e3, 1),
        "reconciled_pages": report["reclaimed"],
        "requeued": report["requeued"],
        "never_dry": report["never_dry"],
        "token_identical": crash_identical,
        "leak_free": eng.leak_free(),
    }

    # ---- warm vs cold restart: do pins/speculation survive?
    def fresh():
        return ServingEngine(cfg, params, dp=1, b_local=4, max_len=96,
                             chunk_size=16, speculate=True, draft_len=4,
                             sched=SchedConfig(pin_pages=8))

    def drive(eng, batch):
        t0 = time.perf_counter()
        for r in batch:
            eng.submit(r)
        eng.run(max_steps=1000)
        dt = time.perf_counter() - t0
        lat = eng.latency_quantiles()
        return dt, lat["first_token_p50_s"]

    def restart_stats(eng, dt):
        s = eng.stats
        return {
            "wall_s": round(dt, 3),
            "prompt_tokens": s["prompt_tokens"],
            "pin_hit_reqs": s["pin_hit_reqs"],
            "pin_hit_tokens": s["pin_hit_tokens"],
            "spec_lanes": s["spec_lanes"],
        }

    with tempfile.TemporaryDirectory() as d:
        warmup = fresh()
        drive(warmup, reqs())                      # pins hot, records spec
        ckptr = Checkpointer(d, keep=1)
        warmup.save_warm(ckptr, step=1)

        warm = fresh()
        warm.restore_warm(ckptr)
        dt_w, ftl_w = drive(warm, reqs())
        warm_row = restart_stats(warm, dt_w)
        warm_row["first_token_p50_ms"] = round(ftl_w * 1e3, 1)
        warm_ok = warm.stats["pin_hit_reqs"] > 0

        cold = fresh()
        dt_c, ftl_c = drive(cold, reqs())
        cold_row = restart_stats(cold, dt_c)
        cold_row["first_token_p50_ms"] = round(ftl_c * 1e3, 1)

    row = {
        "crash_recovery": crash_row,
        "warm_restart": warm_row,
        "cold_restart": cold_row,
        "prefill_tokens_saved_by_warm_restart":
            cold_row["prompt_tokens"] - warm_row["prompt_tokens"],
        "warm_restart_carried_pins": warm_ok,
    }
    print(f"serving_chaos,{crash_row['recovery_ms'] * 1e3:.0f},"
          f"torn-crash recovery={crash_row['recovery_ms']}ms "
          f"reconciled={crash_row['reconciled_pages']}pg "
          f"token_identical={crash_row['token_identical']} "
          f"leak_free={crash_row['leak_free']} "
          f"warm_vs_cold_prefill_saved="
          f"{row['prefill_tokens_saved_by_warm_restart']}tok "
          f"warm_pin_hits={warm_row['pin_hit_reqs']}")
    return row


def main() -> None:
    print("name,us_per_call,derived")
    result1_worst_case_steps()
    result1_vs_baselines()
    result1_space_overhead()
    result1_memory_blowup()
    result2_shared_op_cost()
    jax_block_pool_o1()
    jax_paged_kv_append()
    serving_throughput()


if __name__ == "__main__":
    main()
