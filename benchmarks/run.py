"""Benchmark harness — one function per claim/table (CSV to stdout).

The paper is theory-only (no experiment tables), so the benches validate
its RESULT statements empirically and measure the systems layers built
on them:

  result1_worst_case_steps   — O(1) allocate/free (Result 1.2)
  result1_vs_baselines       — worst-case steps vs lock / Treiber
  result1_space_overhead     — Theta(p^2) metadata (Result 1.4)
  result1_memory_blowup      — vs Hoard-style Theta(p*S) (section 3.1)
  result2_shared_op_cost     — O(p) shared stack ops (Result 2.1)
  jax_block_pool_o1          — device pool: alloc AND chunked alloc_n
                               cost independent of m
  jax_paged_kv_append        — paged KV append + append_chunk throughput
  serving_throughput         — continuous-batching engine tok/s:
                               width-1 token lanes (chunk=1, the
                               single-token baseline the deleted
                               legacy path degenerated to) vs full
                               chunked lanes, on a decode-heavy and a
                               prompt-heavy mix, in the same run
  serving_pool_churn         — many short requests with a hot ~90%-shared
                               prompt prefix: prefix sharing (refcounted
                               pages + COW, DESIGN.md §7) vs unshared,
                               pages-in-use reduction and token identity
  serving_overload           — bursty arrivals at 2x slot capacity with an
                               80% hot-prefix mix and an interactive SLO
                               class landing mid-burst (DESIGN.md §8):
                               p50/p99 latency, prefix hit rate,
                               preemption count, and the prefill work the
                               pinned prefix cache saves across
                               drain-to-idle gaps vs pinning disabled —
                               token-identical to an unconstrained run,
                               zero leaks after drain + pin flush
  serving_speculative        — accept-regime sweep for speculative
                               decode (DESIGN.md §10, §12): the same
                               80%-hot-prefix greedy trace run under
                               full-accept / partial-accept /
                               adversarial all-reject draft streams at
                               each draft_len, reporting accept rate,
                               speedup_gen vs one shared non-spec
                               baseline, the measured break-even accept
                               rate per draft_len, and gated rows
                               showing the accept-rate gate recovering
                               throughput on hostile streams
  serving_mesh_shards        — dp=4 engine on the shard_map allocation
                               plane (DESIGN.md §9).  Needs >= 4
                               devices; a single-device process
                               re-execs itself under
                               ``--xla_force_host_platform_device_count=8``
                               (``--emit-json mesh_shards``) so the
                               numbers always measure the real mesh —
                               never the silent vmap fallback — or
                               records an explicit ``skipped_no_mesh``
                               marker if even the re-exec fails

CLI modes (besides the default full run):
  --emit-json NAME   run one serving bench and print its row as a
                     ``BENCH_JSON:{...}`` line (subprocess protocol for
                     the mesh re-exec)
  --spec-smoke       CI gate: assert speedup_gen >= 1.0 on the gated
                     partial-accept mix and write a jax.profiler trace
                     of a speculative step to spec_trace/

Output: ``name,us_per_call,derived`` CSV rows, plus machine-readable
``BENCH_serving.json`` (written next to the CWD) so the serving perf
trajectory is tracked across PRs.
"""

import json
import os
import random
import statistics
import sys
import time

# repo root on sys.path: result2_shared_op_cost borrows a helper from
# tests/, which `python benchmarks/run.py` would otherwise not resolve
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))


def _time_us(fn, n=5):
    ts = []
    for _ in range(n):
        t0 = time.perf_counter()
        fn()
        ts.append((time.perf_counter() - t0) * 1e6)
    return statistics.median(ts)


def result1_worst_case_steps():
    from repro.core import SimContext, WaitFreeAllocator, Scheduler
    worst = {}
    us = 0.0
    for p in (2, 4, 8, 16, 32):
        ctx = SimContext(p, seed=0)
        alloc = WaitFreeAllocator(ctx, shared_batches=4 * p)
        sched = Scheduler(seed=0)

        def phased(pid, alloc=alloc):
            # alloc/free bursts sized to force shared-pool transfers
            held = []
            for ph in range(4):
                if ph % 2 == 0:
                    for _ in range(alloc.ell * 3):
                        held.append((yield from alloc.allocate(pid)))
                else:
                    while held:
                        yield from alloc.free(pid, held.pop())

        for pid in range(p):
            sched.add(pid, phased(pid))
        t0 = time.perf_counter()
        sched.run("random")
        us = (time.perf_counter() - t0) * 1e6 / max(len(ctx.history), 1)
        worst[p] = max(op.steps for op in ctx.history if op.completed)
    derived = "worst_steps_by_p=" + "/".join(
        f"{p}:{w}" for p, w in worst.items())
    print(f"result1_worst_case_steps,{us:.2f},{derived}")
    return worst


def result1_vs_baselines():
    from repro.core import SimContext, Scheduler
    from repro.core.baselines import LockFreeListAllocator, TreiberAllocator
    p = 8
    rows = {}
    for name, cls in (("lock", LockFreeListAllocator),
                      ("treiber", TreiberAllocator)):
        ctx = SimContext(p, seed=0)
        alloc = cls(ctx, m=4096)
        sched = Scheduler(seed=0)

        def workload(pid, alloc=alloc):
            held = []
            rng = random.Random(pid)
            for _ in range(150):
                if not held or (len(held) < 16 and rng.random() < 0.6):
                    b = yield from alloc.allocate(pid)
                    if b >= 0:
                        held.append(b)
                else:
                    yield from alloc.free(pid, held.pop())

        for pid in range(p):
            sched.add(pid, workload(pid))
        sched.run("bursty")
        rows[name] = max(op.steps for op in ctx.history if op.completed)
    from repro.core import WaitFreeAllocator, closed_loop
    ctx = SimContext(p, seed=0)
    ours = WaitFreeAllocator(ctx, shared_batches=4 * p)
    sched = Scheduler(seed=0)
    for pid in range(p):
        sched.add(pid, closed_loop(pid, ours, 150, random.Random(pid),
                                   scribble=False))
    sched.run("bursty")
    rows["ours"] = max(op.steps for op in ctx.history if op.completed)
    print(f"result1_vs_baselines,0,"
          f"worst_steps ours={rows['ours']} lock={rows['lock']} "
          f"treiber={rows['treiber']}")
    return rows


def result1_space_overhead():
    from repro.core import SimContext, WaitFreeAllocator
    words = {}
    for p in (2, 4, 8, 16, 32, 64):
        ctx = SimContext(p, seed=0)
        alloc = WaitFreeAllocator(ctx, shared_batches=4 * p)
        words[p] = alloc.metadata_words()
    # quadratic fit sanity: words(2p)/words(p) -> 4 as p grows
    ratio = words[64] / words[32]
    derived = ("words_by_p=" + "/".join(f"{p}:{w}" for p, w in words.items())
               + f" growth_ratio_64v32={ratio:.2f}")
    print(f"result1_space_overhead,0,{derived}")
    return words


def result1_memory_blowup():
    from repro.core.baselines import HoardSpaceModel
    rows = []
    for p in (8, 64, 256):
        hoard = HoardSpaceModel(p, superblock_blocks=1024)  # 4KB/4-word blk
        ours = HoardSpaceModel.paper_blowup_blocks(p)
        rows.append(f"p{p}:ours={ours},hoard={hoard.additive_blowup_blocks()}")
    print(f"result1_memory_blowup,0,additive_blocks {' '.join(rows)}")


def result2_shared_op_cost():
    from repro.core import SimContext, Scheduler
    from tests.test_core_psim import make_stack
    costs = {}
    for p in (2, 4, 8, 16):
        ctx = SimContext(p, seed=0)
        stack, _, _ = make_stack(ctx, nodes_per_proc=8 * p + 16)
        sched = Scheduler(seed=0)
        worst = [0]

        def worker(pid, worst=worst, stack=stack, ctx=ctx):
            for i in range(5):
                rec = ctx.begin_op(pid, "push")
                yield from stack.push(pid, pid * 100 + i)
                ctx.end_op(rec)
                worst[0] = max(worst[0], rec.steps)

        for pid in range(p):
            sched.add(pid, worker(pid))
        sched.run("random")
        costs[p] = worst[0]
    derived = ("push_steps_by_p=" + "/".join(f"{p}:{c}" for p, c in costs.items())
               + " (linear in p)")
    print(f"result2_shared_op_cost,0,{derived}")


def jax_block_pool_o1():
    """alloc and chunked alloc_n cost vs pool size m (donated buffers so
    the free-stack is updated in place, as the serving step does — an
    un-donated jit would copy the m-sized stack and mask the O(R) op)."""
    import jax
    import jax.numpy as jnp
    from repro.core import block_pool

    def timed_pairs(m, step):
        pool = block_pool.create(m)
        pool = step(pool)                        # compile
        jax.block_until_ready(pool.top)
        ts = []
        for _ in range(20):
            t0 = time.perf_counter()
            pool = step(pool)
            jax.block_until_ready(pool.top)
            ts.append((time.perf_counter() - t0) * 1e6)
        return statistics.median(ts)

    counts = jnp.tile(jnp.asarray([2, 0, 3, 1], jnp.int32), 16)  # 64 slots
    mask = jnp.ones(64, bool)
    us_by_m, usn_by_m = {}, {}
    for m in (1 << 10, 1 << 14, 1 << 18):
        alloc = jax.jit(block_pool.alloc, donate_argnums=(0,))
        alloc_n = jax.jit(block_pool.alloc_n, static_argnums=(2,),
                          donate_argnums=(0,))
        freef = jax.jit(block_pool.free, donate_argnums=(0,))

        def pair(pool, alloc=alloc, freef=freef):
            pool, ids = alloc(pool, mask)
            return freef(pool, ids)

        def pair_n(pool, alloc_n=alloc_n, freef=freef):
            pool, ids = alloc_n(pool, counts, 4)
            return freef(pool, ids.reshape(-1))

        us_by_m[m] = timed_pairs(m, pair)
        usn_by_m[m] = timed_pairs(m, pair_n)
    derived = ("us_by_pool_size=" + "/".join(
        f"{m}:{u:.1f}" for m, u in us_by_m.items())
        + " alloc_n_us_by_pool_size=" + "/".join(
        f"{m}:{u:.1f}" for m, u in usn_by_m.items()))
    print(f"jax_block_pool_o1,{us_by_m[1 << 18]:.2f},{derived}")


def jax_paged_kv_append():
    import jax
    import jax.numpy as jnp
    from repro.core import kv_cache
    cache = kv_cache.create(num_pages=256, page_size=16, kv_heads=4,
                            head_dim=64, max_seqs=16, max_pages_per_seq=16)
    app = jax.jit(kv_cache.append)
    appc = jax.jit(kv_cache.append_chunk)
    k = jnp.ones((16, 4, 64))
    v = jnp.ones((16, 4, 64))
    act = jnp.ones(16, bool)
    C = 16
    kc = jnp.ones((16, C, 4, 64))
    vc = jnp.ones((16, C, 4, 64))
    lens = jnp.full((16,), C, jnp.int32)
    jax.block_until_ready(app(cache, k, v, act)[1])          # compile
    jax.block_until_ready(appc(cache, kc, vc, lens)[1])
    us = _time_us(lambda: jax.block_until_ready(app(cache, k, v, act)[1]),
                  n=20)
    usc = _time_us(lambda: jax.block_until_ready(
        appc(cache, kc, vc, lens)[1]), n=20)
    print(f"jax_paged_kv_append,{us:.2f},tokens_per_call=16 "
          f"chunk_us={usc:.2f} chunk_tokens_per_call={16 * C} "
          f"chunk_us_per_token={usc / (16 * C):.3f}")


def _run_serving_mix(cfg, params, prompts, max_new, chunk):
    from repro.serving.engine import Request, ServingEngine
    eng = ServingEngine(cfg, params, dp=2, b_local=2, max_len=96,
                        chunk_size=chunk)
    # warmup: compile every step shape (chunk prefill, T=1 decode,
    # release) off the clock
    w = Request(-1, prompt=list(range(2, 2 + chunk + 2)), max_new_tokens=2)
    eng.submit(w)
    eng.run(max_steps=100)
    eng.stats.update(steps=0, tokens_out=0, prompt_tokens=0)
    for i, p in enumerate(prompts):
        eng.submit(Request(i, prompt=list(p), max_new_tokens=max_new))
    t0 = time.perf_counter()
    eng.run(max_steps=4000)
    dt = time.perf_counter() - t0
    total = eng.stats["tokens_out"] + eng.stats["prompt_tokens"]
    return {
        "gen_tok_per_s": round(eng.stats["tokens_out"] / dt, 1),
        "total_tok_per_s": round(total / dt, 1),
        "steps": eng.stats["steps"],
        "us_per_step": round(dt * 1e6 / max(eng.stats["steps"], 1)),
        "wall_s": round(dt, 3),
        "alloc_O1_max": eng.stats["alloc_steps_max"],
        "leak_free": eng.page_occupancy() == 0.0,
        "telemetry": eng.telemetry.snapshot(),
    }


def serving_throughput():
    """Width-1 vs chunked token lanes on decode-heavy and prompt-heavy
    mixes (same params, same run) + BENCH_serving.json for trend
    tracking.  chunk=1 runs the SAME unified step one token per lane
    per step — the baseline the deleted legacy path degenerated to —
    so the A/B now isolates exactly the lane-width win."""
    import numpy as np
    import jax
    from repro import models
    from repro.configs import get_config, smoke_config
    cfg = smoke_config(get_config("olmo-1b"))
    params = models.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.RandomState(0)
    chunk = 16
    mixes = {
        # prompt len >= 4x generation len: chunked prefill dominates
        "prompt_heavy": ([list(rng.randint(1, 255, 48)) for _ in range(12)], 8),
        "decode_heavy": ([list(rng.randint(1, 255, 6)) for _ in range(12)], 24),
    }
    report = {"config": cfg.name, "chunk_size": chunk, "mixes": {}}
    for mix, (prompts, max_new) in mixes.items():
        width1 = _run_serving_mix(cfg, params, prompts, max_new, chunk=1)
        chunked = _run_serving_mix(cfg, params, prompts, max_new,
                                   chunk=chunk)
        speedup = (chunked["total_tok_per_s"] /
                   max(width1["total_tok_per_s"], 1e-9))
        report["mixes"][mix] = {"width1": width1, "chunked": chunked,
                                "speedup_total": round(speedup, 2)}
        print(f"serving_throughput,{chunked['us_per_step']},mix={mix} "
              f"chunked_tok_per_s={chunked['total_tok_per_s']} "
              f"width1_tok_per_s={width1['total_tok_per_s']} "
              f"speedup={speedup:.2f}x steps={chunked['steps']} "
              f"alloc_O1_max={chunked['alloc_O1_max']}")
    report["mixes"]["pool_churn"] = serving_pool_churn(cfg, params)
    report["mixes"]["overload"] = serving_overload(cfg, params)
    report["mixes"]["mesh_shards"] = serving_mesh_shards(cfg, params)
    report["mixes"]["speculative"] = serving_speculative(cfg, params)
    report["mixes"]["chaos"] = serving_chaos(cfg, params)
    report["mixes"]["size_classes"] = serving_size_classes(cfg, params)
    report["mixes"]["moe"] = serving_moe(cfg, params)
    with open("BENCH_serving.json", "w") as f:
        json.dump(report, f, indent=2)
        f.write("\n")
    return report


def _garble(t):
    """A token guaranteed != t for any t in [0, 255] (vocab stays 1..255,
    clear of pad/EOS ids): t == ((t+1) % 255) + 1 would need 2 ≡ 0
    (mod 255)."""
    return ((int(t) + 1) % 255) + 1


def _break_even_accept(eng, k):
    """Smallest accept rate a where 1 + a + ... + a^k clears the
    measured spec-step cost ratio cost(k+1, spec)/cost(1, decode) — the
    same break-even the engine's ``_gate_k`` applies (DESIGN.md §12).
    Falls back to the linear cost model when a width is unmeasured."""
    c1 = eng._step_cost.get((1, False))
    ck = eng._step_cost.get((k + 1, True))
    measured = bool(c1 and ck)
    ratio = (ck / c1) if measured else 1.0 + eng.spec_cost_slope * k
    if k + 1 < ratio:                 # even accept=1.0 can't pay
        return {"cost_ratio": round(ratio, 3), "measured": measured,
                "accept": None}
    lo, hi = 0.0, 1.0
    for _ in range(40):
        mid = 0.5 * (lo + hi)
        if sum(mid ** i for i in range(k + 1)) >= ratio:
            hi = mid
        else:
            lo = mid
    return {"cost_ratio": round(ratio, 3), "measured": measured,
            "accept": round(hi, 3)}


def serving_speculative(cfg, params, smoke=False):
    """Accept-regime sweep for speculative decode (DESIGN.md §10, §12).

    One shared non-speculative baseline engine runs an 80%-hot-prefix
    greedy trace (and is re-measured next to every regime row, so each
    speedup compares thermally-local machine states), then the same
    trace runs under three draft-stream regimes at each draft_len — ``full`` (recorded history is right: accept 1.0),
    ``partial`` (history right for the first ~3/4 of each request's
    continuation, then wrong — the boundary draft is partially
    accepted and rolls back its rejected tail, and the per-prefix
    accept EWMA stays clearly above the gate's break-even),
    ``adversarial`` (history wrong from the first draft token:
    all-reject, every draft page rolled back) — with the accept-rate
    gate OFF so the regime's raw cost/benefit is what's measured.  Each
    draft_len also reports its measured break-even accept rate (the
    gate's decision boundary, from the EWMA step-cost model).  Two
    gated rows close the loop: ``partial_gated`` must pay
    (speedup_gen >= 1.0 is the CI spec-perf-smoke gate) and
    ``adversarial_gated`` shows the gate switching hostile prefixes off
    and recovering ~baseline throughput.  Token identity vs the
    baseline holds in EVERY regime — verification guarantees output,
    regimes only move cost."""
    import numpy as np
    from repro.serving.engine import Request, ServingEngine

    rng = np.random.RandomState(0)
    hot = list(rng.randint(1, 255, 16))                  # 2 pages of 8
    uniq = [hot + list(rng.randint(1, 255, 4 + i)) for i in range(4)]
    trace = []
    for i in range(24):
        if rng.random_sample() < 0.8:
            trace.append(list(uniq[rng.randint(len(uniq))]))  # hot repeat
        else:
            trace.append(list(rng.randint(1, 255, 8 + i % 9)))
    # decode-heavy generations: at max_new=8 the trace wall time is
    # ~70% chunked prefill (where drafts can't help) and the real
    # decode-side win drowns in prefill-step timing variance; 24 new
    # tokens per request puts the measurement where speculation acts
    mn = 24

    def drive(eng, reqs, max_steps=2000):
        t0 = time.perf_counter()
        for r in reqs:
            eng.submit(r)
        eng.run(max_steps=max_steps)
        dt = time.perf_counter() - t0
        assert all(r.done for r in reqs)
        return dt

    def reset(eng):
        for k in ("steps", "tokens_out", "prompt_tokens", "spec_lanes",
                  "spec_drafted", "spec_accepted", "spec_pages_rolled_back",
                  "spec_gate_skips", "spec_mixed_steps"):
            eng.stats[k] = 0
        eng.stats["accept_hist"] = {}
        eng.stats["chunk_hist"] = {}

    def run_trace(eng, rid0, passes=3):
        """Best-of-N measured passes.  A single trace is only ~50 steps
        (~0.2s on the smoke config), so one stray jit compile or OS
        hiccup inside the window swings the ratio by 30%+; pass 1
        absorbs any residual compile, later passes are steady state,
        and the fastest pass is reported.  The regimes are stationary
        (recording is stubbed), so every pass must be token-identical —
        asserted — which also pins tokens_out across passes, keeping
        the last pass's stats consistent with the best pass's dt."""
        outs0, best_dt = None, None
        for p in range(passes):
            reset(eng)
            reqs = [Request(rid0 + 100 * p + i, prompt=list(pr),
                            max_new_tokens=mn)
                    for i, pr in enumerate(trace)]
            dt = drive(eng, reqs)
            outs = [r.out_tokens for r in reqs]
            assert outs0 is None or outs == outs0, \
                f"trace pass {p} diverged from pass 0"
            outs0 = outs
            best_dt = dt if best_dt is None else min(best_dt, dt)
        return outs0, best_dt

    def stagger_warm(eng):
        """Replay the hot prompts with OVERLAPPING lifetimes: a repeat
        admitted while its twin is live takes the prefix-share path,
        whose jitted admission step otherwise compiles (~0.5s) inside
        the first measured run — every engine warms through this so
        trace runs compare steady states, not compile schedules."""
        reqs = [Request(-101 - i, prompt=list(p), max_new_tokens=mn)
                for i, p in enumerate(uniq)]
        for r in reqs[:2]:
            eng.submit(r)
        for _ in range(3):
            eng.step()
        for r in reqs[2:]:
            eng.submit(r)
        eng.run(max_steps=500)
        assert all(r.done for r in reqs)

    def prep(draft_len, gate, regime):
        """Engine warmed off the clock (pass 1 records the true
        continuations and captures them for poisoning, pass 2 replays
        so the spec variant compiles and the step-cost EWMA gets real
        samples), then the hot streams are rewritten for the regime and
        recording is stubbed so trace completions can't heal them."""
        eng = ServingEngine(cfg, params, dp=1, b_local=4, max_len=96,
                            chunk_size=16, speculate=True,
                            draft_len=draft_len, spec_gate=gate)
        # exact-replay-only drafting for the regime probes: all hot
        # prompts share ONE key, and once a request crosses the
        # poisoned boundary the n-gram fallback would keep
        # extrapolating garbage from the same streams — its 0-accept
        # lanes collapse the per-key EWMA that also gates the still-
        # reliable exact replay of fresh requests, so the gate's
        # on/off state becomes an order-dependent coin flip instead of
        # a property of the regime.  The sweep isolates replay
        # economics; n-gram drafting has its own identity/rollback
        # tests.
        eng.spec_store.ngram = 0
        reqs = [Request(-1 - i, prompt=list(p), max_new_tokens=mn)
                for i, p in enumerate(uniq)]
        drive(eng, reqs, max_steps=500)
        real = {tuple(p): list(r.out_tokens) for p, r in zip(uniq, reqs)}
        # replay staggered: the first pair decodes (drafts live from the
        # streams pass 1 recorded) while the second pair's prompts are
        # still pending, so the MIXED prompt/decode spec width AND the
        # prefix-share admission path compile here, not on the trace
        stagger_warm(eng)
        if regime != "full":
            # prefill emits each prompt's first token before any draft
            # fires, so a poisoned stream needs >= 1 true token for the
            # exact-suffix replay to engage at all; after that the
            # adversarial stream is pure garbage (0 accepts/draft)
            # while the partial stream stays right for the first ~3/4
            # of the continuation and garbage after — replay goes
            # structurally dead at the first garbled position (the
            # engine's correction token diverges the request suffix
            # from the stream), so the boundary draft is the partially
            # accepted one that pays a rejected-tail rollback, and the
            # accept EWMA lands clearly above the gate's measured
            # break-even (~0.3-0.4 on the smoke config; a stream
            # that is only half-a-draft right sits ON that boundary
            # and the gate legitimately oscillates — noise, not
            # signal).  All hot prompts share ONE whole-page key
            # (their page-aligned prefix is the same 2 hot pages), so
            # clear that key's streams once, THEN record every
            # prompt's poisoned stream under it — the per-suffix
            # replay disambiguates.
            for key in {eng.spec_store.key_of(p) for p in uniq}:
                eng.spec_store.streams.pop(key, None)
            for p in uniq:
                key = eng.spec_store.key_of(p)
                tail = tuple(p[len(key):])
                r = real[tuple(p)]
                keep = (1 if regime == "adversarial"
                        else 1 + max(1, 3 * len(r) // 4))
                cont = tail + tuple(r[:keep]) + tuple(
                    _garble(r[j]) if j < len(r) else _garble(j + 31)
                    for j in range(keep, keep + 8))
                eng.spec_store.record(key, cont)
            eng.spec_store.record = lambda *a, **kw: None
        reset(eng)
        return eng

    def row_of(eng, dt, outs, base_now):
        s = eng.stats
        tps = round(s["tokens_out"] / dt, 1)
        return {
            "steps": s["steps"],
            "spec_lanes": s["spec_lanes"],
            "drafted": s["spec_drafted"],
            "accepted": s["spec_accepted"],
            "accept_rate": round(s["spec_accepted"]
                                 / max(s["spec_drafted"], 1), 2),
            "gate_skips": s["spec_gate_skips"],
            "mixed_steps": s["spec_mixed_steps"],
            "pages_rolled_back": s["spec_pages_rolled_back"],
            "gen_tok_per_s": tps,
            "baseline_tok_per_s": base_now,
            "speedup_gen": round(tps / max(base_now, 1e-9), 2),
            "token_identical": outs == base_outs,
            "leak_free": eng.page_occupancy() == 0.0,
            "telemetry": eng.telemetry.snapshot(),
        }

    # ---- shared baseline: one non-speculative engine, kept alive so
    # every regime row can re-measure it in the SAME machine state.
    # The sweep interleaves minutes of multi-core jit compilation with
    # its measured windows; a baseline captured once up front is 20-40%
    # stale (thermal/frequency drift) by the later rows, which showed
    # up as two behaviorally identical regime runs differing 0.75x vs
    # 1.04x purely by when they ran.
    base_eng = ServingEngine(cfg, params, dp=1, b_local=4, max_len=96,
                             chunk_size=16)
    drive(base_eng, [Request(-1 - i, prompt=list(p), max_new_tokens=mn)
                     for i, p in enumerate(uniq)], max_steps=500)  # compile
    stagger_warm(base_eng)
    base_outs, base_dt = run_trace(base_eng, 0)
    base_tps = round(base_eng.stats["tokens_out"] / base_dt, 1)
    base = {"steps": base_eng.stats["steps"], "gen_tok_per_s": base_tps,
            "leak_free": base_eng.page_occupancy() == 0.0}
    _base_rid = [10000]

    def base_tps_now():
        """Thermally-local baseline: best-of-2 fresh passes on the
        warmed baseline engine, taken right after the regime row it
        normalizes."""
        _base_rid[0] += 1000
        outs, dt = run_trace(base_eng, _base_rid[0], passes=2)
        assert outs == base_outs
        return round(base_eng.stats["tokens_out"] / dt, 1)

    def gated_run(regime, rid0):
        eng = prep(4, True, regime)
        outs, dt = run_trace(eng, rid0)
        r = row_of(eng, dt, outs, base_tps_now())
        r["break_even"] = _break_even_accept(eng, 4)
        print(f"serving_speculative,0,regime={regime}_gated draft_len=4 "
              f"accept_rate={r['accept_rate']} "
              f"speedup_gen={r['speedup_gen']} "
              f"gate_skips={r['gate_skips']} "
              f"token_identical={r['token_identical']} "
              f"leak_free={r['leak_free']}")
        return r

    if smoke:
        partial_gated = gated_run("partial", 3000)
        return {"baseline": base, "partial_gated": partial_gated}

    # ---- raw regimes, gate off: what each accept regime really costs
    sweep = {}
    for dl in (2, 4):
        regimes = {}
        for regime in ("full", "partial", "adversarial"):
            eng = prep(dl, False, regime)
            outs, dt = run_trace(eng, 1000 * dl)
            regimes[regime] = row_of(eng, dt, outs, base_tps_now())
            print(f"serving_speculative,0,regime={regime} draft_len={dl} "
                  f"accept_rate={regimes[regime]['accept_rate']} "
                  f"speedup_gen={regimes[regime]['speedup_gen']} "
                  f"rolled_back={regimes[regime]['pages_rolled_back']} "
                  f"token_identical={regimes[regime]['token_identical']} "
                  f"leak_free={regimes[regime]['leak_free']}")
        regimes["break_even"] = _break_even_accept(eng, dl)
        print(f"serving_speculative,0,break_even draft_len={dl} "
              f"cost_ratio={regimes['break_even']['cost_ratio']} "
              f"accept={regimes['break_even']['accept']} "
              f"measured={regimes['break_even']['measured']}")
        sweep[f"draft_len_{dl}"] = regimes

    # ---- gate on: partial must pay, adversarial must be defanged
    partial_gated = gated_run("partial", 3000)
    adversarial_gated = gated_run("adversarial", 4000)

    all_identical = (partial_gated["token_identical"]
                     and adversarial_gated["token_identical"]
                     and all(r["token_identical"]
                             for k, regs in sweep.items()
                             for n, r in regs.items() if n != "break_even"))
    row = {"baseline": base, "sweep": sweep,
           "partial_gated": partial_gated,
           "adversarial_gated": adversarial_gated,
           "token_identical": all_identical}
    print(f"serving_speculative,0,summary baseline={base_tps}tok/s "
          f"partial_gated_speedup={partial_gated['speedup_gen']} "
          f"adversarial_gated_speedup={adversarial_gated['speedup_gen']} "
          f"token_identical_all_regimes={all_identical}")
    return row


_JSON_TAG = "BENCH_JSON:"


def serving_mesh_shards(cfg, params):
    """Multi-host allocation plane smoke (DESIGN.md §9) — with the
    mesh actually present.  A dp=4 engine only shard_maps over a real
    ("dp",) device mesh when the process has >= 4 devices; below that
    it silently falls back to vmap semantics, and this bench used to
    report those single-device numbers as if they measured the mesh.
    Now a single-device process re-execs itself under
    ``--xla_force_host_platform_device_count=8`` (the ``--emit-json``
    subprocess protocol) so the row always comes from a real mesh, and
    if even the re-exec cannot produce one the row is an explicit
    ``skipped_no_mesh`` marker instead of misleading numbers."""
    import subprocess

    import jax
    if jax.local_device_count() >= 4:
        return _serving_mesh_shards_inline(cfg, params)
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=8").strip()
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(root, "src")]
        + ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else []))
    try:
        proc = subprocess.run(
            [sys.executable, os.path.abspath(__file__),
             "--emit-json", "mesh_shards"],
            env=env, capture_output=True, text=True, timeout=1200)
        row = None
        for line in reversed(proc.stdout.splitlines()):
            if line.startswith(_JSON_TAG):
                row = json.loads(line[len(_JSON_TAG):])
                break
        if row is None:
            raise RuntimeError(
                f"re-exec produced no {_JSON_TAG} row "
                f"(rc={proc.returncode}): {proc.stderr[-2000:]}")
        row["mesh_via_subprocess"] = True
        print(f"serving_mesh_shards,0,devices={row['mesh_devices']} "
              f"shard_map={row['shard_map']} (via subprocess re-exec) "
              f"pages_mean_shard={row['pages_mean_shard']} "
              f"token_identical={row['token_identical_vs_single_device']} "
              f"leak_free={row['leak_free']}")
        return row
    except Exception as e:  # noqa: BLE001 — any failure means "no mesh"
        row = {"skipped_no_mesh": True,
               "mesh_devices": jax.local_device_count(),
               "reason": str(e)[:500]}
        print(f"serving_mesh_shards,0,skipped_no_mesh=True "
              f"devices={jax.local_device_count()} (dp=4 would fall back "
              "to single-device vmap semantics; row suppressed)")
        return row


def _serving_mesh_shards_inline(cfg, params):
    """The actual dp=4 mesh bench body — caller guarantees >= 4 devices
    (directly, or via the forced-device re-exec above).  Reports the
    per-shard occupancy stats from the packed status row (the
    scheduler's placement balance across hosts) and the usual
    leak/identity axes vs a single-device run of the same trace."""
    import numpy as np
    from repro.serving.engine import Request, ServingEngine
    from repro.serving.sched import SchedConfig

    rng = np.random.RandomState(0)
    hot = list(rng.randint(1, 255, 24))                  # 3 pages of 8
    spec = []
    for i in range(20):
        if rng.random_sample() < 0.6:
            prompt = hot + list(rng.randint(1, 255, 2 + i % 5))
        else:
            prompt = list(rng.randint(1, 255, 8 + i % 9))
        spec.append(prompt)

    def run(dp, b_local):
        eng = ServingEngine(cfg, params, dp=dp, b_local=b_local,
                            max_len=64, chunk_size=16,
                            sched=SchedConfig(pin_pages=8))
        reqs = [Request(i, prompt=list(p), max_new_tokens=4)
                for i, p in enumerate(spec)]
        t0 = time.perf_counter()
        for r in reqs:
            eng.submit(r)
        eng.run(max_steps=1000)
        dt = time.perf_counter() - t0
        assert all(r.done for r in reqs)
        eng.flush_pins()
        return [r.out_tokens for r in reqs], eng, dt

    out4, eng4, dt = run(dp=4, b_local=2)
    out1, eng1, _ = run(dp=1, b_local=2)
    occ = eng4.shard_occupancy()
    row = {
        "mesh_devices": occ["mesh_devices"],
        "shard_map": eng4.mesh is not None,
        "gen_tok_per_s": round(eng4.stats["tokens_out"] / dt, 1),
        "steps": eng4.stats["steps"],
        "pages_mean_shard": occ["pages_mean_shard"],
        "pages_peak_shard": occ["pages_peak_shard"],
        "prefix_hit_rate": round(eng4.stats["prefix_shared_reqs"]
                                 / max(eng4.stats["admitted"], 1), 2),
        "token_identical_vs_single_device": out4 == out1,
        "leak_free": eng4.page_occupancy() == 0.0,
        "telemetry": eng4.telemetry.snapshot(),
    }
    print(f"serving_mesh_shards,0,devices={row['mesh_devices']} "
          f"shard_map={row['shard_map']} "
          f"pages_mean_shard={row['pages_mean_shard']} "
          f"pages_peak_shard={row['pages_peak_shard']} "
          f"token_identical={row['token_identical_vs_single_device']} "
          f"leak_free={row['leak_free']}")
    return row


def serving_pool_churn(cfg, params):
    """Pool-churn scenario: a stream of short requests sharing a hot
    ~90% prompt prefix (the production shape: one system prompt, many
    users).  Measures prefix sharing's pages-in-use win at equal
    outputs — the refactor's acceptance bar is >= 2x fewer mean
    pages-in-use with token-identical generations."""
    import numpy as np
    from repro.serving.engine import Request, ServingEngine
    rng = np.random.RandomState(0)
    hot = list(rng.randint(1, 255, 68))                    # 8.5 pages of 8
    prompts = [hot + list(rng.randint(1, 255, 6)) for _ in range(16)]

    def run(share):
        eng = ServingEngine(cfg, params, dp=1, b_local=6, max_len=96,
                            chunk_size=16, prefix_sharing=share)
        # warm the hot prefix: the first request prefills it, then the
        # arrival stream overlaps lifetimes (continuous batching)
        reqs = [Request(0, prompt=list(prompts[0]), max_new_tokens=8)]
        eng.submit(reqs[0])
        for _ in range(5):
            eng.step()
        t0 = time.perf_counter()
        for i, p in enumerate(prompts[1:], 1):
            r = Request(i, prompt=list(p), max_new_tokens=8)
            reqs.append(r)
            eng.submit(r)
        eng.run(max_steps=2000)
        dt = time.perf_counter() - t0
        assert all(r.done for r in reqs)
        # shared prompt tokens were SERVED without being fed — count
        # them in delivered throughput or the shared run looks slower
        # for doing strictly less work per request
        total = (eng.stats["tokens_out"] + eng.stats["prompt_tokens"]
                 + eng.stats["prefix_shared_tokens"])
        return [r.out_tokens for r in reqs], {
            "delivered_tok_per_s": round(total / dt, 1),
            "steps": eng.stats["steps"],
            "pages_mean": round(eng.pages_mean(), 1),
            "pages_peak": eng.stats["pages_peak"],
            "prefix_shared_tokens": eng.stats["prefix_shared_tokens"],
            "prefix_shared_reqs": eng.stats["prefix_shared_reqs"],
            "leak_free": eng.page_occupancy() == 0.0,
            "telemetry": eng.telemetry.snapshot(),
        }

    out_u, unshared = run(False)
    out_s, shared = run(True)
    ratio = unshared["pages_mean"] / max(shared["pages_mean"], 1e-9)
    row = {"unshared": unshared, "shared": shared,
           "pages_mean_reduction": round(ratio, 2),
           "token_identical": out_u == out_s}
    print(f"serving_pool_churn,0,pages_mean unshared={unshared['pages_mean']} "
          f"shared={shared['pages_mean']} reduction={ratio:.2f}x "
          f"token_identical={out_u == out_s} "
          f"shared_tokens={shared['prefix_shared_tokens']} "
          f"delivered_tok_per_s shared={shared['delivered_tok_per_s']} "
          f"unshared={unshared['delivered_tok_per_s']}")
    return row


def serving_overload(cfg, params):
    """Bursty-overload scenario (DESIGN.md §8): each burst submits 2x
    the slot capacity, 80% of prompts share a hot 5-page prefix, and an
    interactive-class pair lands mid-burst (forcing preemption of
    standard work).  The engine fully drains between bursts, so without
    pinning the hot prefix dies with each burst's last request and the
    next burst re-prefills it from scratch — the pinned run re-shares
    it across the idle gap.  Acceptance axes: token identity with an
    unconstrained run, zero leaks after drain + pin flush, and a
    measured prefill-work reduction from pinning."""
    import numpy as np
    from repro.serving.engine import Request, ServingEngine
    from repro.serving.sched import SchedConfig

    rng = np.random.RandomState(0)
    hot = list(rng.randint(1, 255, 40))                  # 5 pages of 8
    spec = []
    for i in range(24):
        if rng.random_sample() < 0.8:
            prompt = hot + list(rng.randint(1, 255, 4 + i % 5))
        else:
            prompt = list(rng.randint(1, 255, 12 + i % 7))
        spec.append((prompt, "interactive" if i % 4 == 3 else "standard"))

    def run(b_local, pin_pages, bursts):
        eng = ServingEngine(cfg, params, dp=1, b_local=b_local, max_len=96,
                            chunk_size=16,
                            sched=SchedConfig(pin_pages=pin_pages))
        reqs = [Request(i, prompt=list(p), max_new_tokens=6, slo=slo)
                for i, (p, slo) in enumerate(spec)]
        per = -(-len(reqs) // bursts)
        t0 = time.perf_counter()
        for j in range(0, len(reqs), per):
            burst = reqs[j:j + per]
            # standard work first; interactive arrives mid-burst, after
            # the slots have filled — the preemption trigger
            for r in burst:
                if r.slo != "interactive":
                    eng.submit(r)
            for _ in range(2):
                eng.step()
            for r in burst:
                if r.slo == "interactive":
                    eng.submit(r)
            eng.run(max_steps=1000)              # drain to idle
        dt = time.perf_counter() - t0
        assert all(r.done for r in reqs)
        pinned_steady = eng.pinned_pages()
        eng.flush_pins()
        lat = eng.latency_quantiles()
        s = eng.stats
        return [r.out_tokens for r in reqs], {
            "gen_tok_per_s": round(s["tokens_out"] / dt, 1),
            "steps": s["steps"],
            "p50_ms": round(lat["p50_s"] * 1e3, 1),
            "p99_ms": round(lat["p99_s"] * 1e3, 1),
            "first_token_p50_ms": round(lat["first_token_p50_s"] * 1e3, 1),
            "prompt_tokens": s["prompt_tokens"],
            "prefix_shared_tokens": s["prefix_shared_tokens"],
            "prefix_hit_rate": round(
                s["prefix_shared_reqs"] / max(s["admitted"], 1), 2),
            "pin_hits": s["pin_hit_reqs"],
            "preemptions": s["preemptions"],
            "deferred": eng.scheduler.stats["deferred"],
            "pinned_pages_steady": pinned_steady,
            "leak_free": eng.page_occupancy() == 0.0,
            "telemetry": eng.telemetry.snapshot(),
        }

    out_ref, _ = run(b_local=8, pin_pages=0, bursts=1)   # unconstrained
    out_pin, pinned = run(b_local=4, pin_pages=12, bursts=3)
    out_raw, nopin = run(b_local=4, pin_pages=0, bursts=3)
    saved = nopin["prompt_tokens"] - pinned["prompt_tokens"]
    row = {"pinned": pinned, "unpinned": nopin,
           "token_identical": out_pin == out_ref and out_raw == out_ref,
           "prefill_tokens_saved_by_pinning": saved,
           "prefill_pages_saved_by_pinning": saved // cfg.page_size}
    print(f"serving_overload,0,2x-burst 80%-hot: p50={pinned['p50_ms']}ms "
          f"p99={pinned['p99_ms']}ms hit_rate={pinned['prefix_hit_rate']} "
          f"preemptions={pinned['preemptions']} "
          f"prefill_pages_saved={row['prefill_pages_saved_by_pinning']} "
          f"token_identical={row['token_identical']} "
          f"leak_free={pinned['leak_free'] and nopin['leak_free']}")
    return row


def serving_chaos(cfg, params):
    """Fault-tolerance axes (DESIGN.md §11): crash the host inside the
    torn drain/refill rebalance window, rebuild the engine, reconcile
    allocator state from the device arrays + admission journal, and
    measure (a) recovery wall time, (b) token identity of the recovered
    run vs an unfaulted reference (greedy AND sampled lanes), and
    (c) warm vs cold restart — a warm restart carries pinned prefixes
    and speculation streams through the checkpoint sidecar, so the hot
    prefix needs no re-prefill."""
    import tempfile

    import numpy as np
    from repro.checkpoint.ckpt import Checkpointer
    from repro.serving import chaos
    from repro.serving.engine import Request, ServingEngine
    from repro.serving.sched import SchedConfig

    rng = np.random.RandomState(0)
    hot = list(rng.randint(1, 255, 16))                  # 2 pages of 8
    spec = [hot + list(rng.randint(1, 255, 4 + i % 5)) for i in range(8)]

    def reqs():
        return [Request(i, prompt=list(p), max_new_tokens=6,
                        temperature=0.7 if i % 2 else 0.0, seed=40 + i)
                for i, p in enumerate(spec)]

    # ---- reference: no faults
    ref_reqs = reqs()
    eng = ServingEngine(cfg, params, dp=1, b_local=4, max_len=96,
                        chunk_size=16)
    for r in ref_reqs:
        eng.submit(r)
    eng.run(max_steps=1000)
    ref_out = {r.rid: list(r.out_tokens) for r in ref_reqs}

    # ---- crash mid-rebalance, recover, finish
    journal = chaos.ServingJournal()
    injector = chaos.parse_faults("crash@4:post_sync:torn")

    def build():
        return ServingEngine(cfg, params, dp=1, b_local=4, max_len=96,
                             chunk_size=16, journal=journal,
                             injector=injector)

    eng = build()
    for r in reqs():
        eng.submit(r)
    try:
        eng.run(max_steps=1000)
        raise AssertionError("injected crash never fired")
    except chaos.HostCrash:
        pass
    t0 = time.perf_counter()
    eng, report = chaos.recover_engine(build, eng, journal)
    recovery_s = time.perf_counter() - t0
    eng.run(max_steps=1000)
    out = journal.outputs()
    crash_identical = (journal.finished() == set(ref_out)
                       and all(out[rid] == ref_out[rid] for rid in ref_out))
    crash_row = {
        "recovery_ms": round(recovery_s * 1e3, 1),
        "reconciled_pages": report["reclaimed"],
        "requeued": report["requeued"],
        "never_dry": report["never_dry"],
        "token_identical": crash_identical,
        "leak_free": eng.leak_free(),
        "telemetry": eng.telemetry.snapshot(),
    }

    # ---- warm vs cold restart: do pins/speculation survive?
    def fresh():
        return ServingEngine(cfg, params, dp=1, b_local=4, max_len=96,
                             chunk_size=16, speculate=True, draft_len=4,
                             sched=SchedConfig(pin_pages=8))

    def drive(eng, batch):
        t0 = time.perf_counter()
        for r in batch:
            eng.submit(r)
        eng.run(max_steps=1000)
        dt = time.perf_counter() - t0
        lat = eng.latency_quantiles()
        return dt, lat["first_token_p50_s"]

    def restart_stats(eng, dt):
        s = eng.stats
        return {
            "wall_s": round(dt, 3),
            "prompt_tokens": s["prompt_tokens"],
            "pin_hit_reqs": s["pin_hit_reqs"],
            "pin_hit_tokens": s["pin_hit_tokens"],
            "spec_lanes": s["spec_lanes"],
        }

    with tempfile.TemporaryDirectory() as d:
        warmup = fresh()
        drive(warmup, reqs())                      # pins hot, records spec
        ckptr = Checkpointer(d, keep=1)
        warmup.save_warm(ckptr, step=1)

        warm = fresh()
        warm.restore_warm(ckptr)
        dt_w, ftl_w = drive(warm, reqs())
        warm_row = restart_stats(warm, dt_w)
        warm_row["first_token_p50_ms"] = round(ftl_w * 1e3, 1)
        warm_ok = warm.stats["pin_hit_reqs"] > 0

        cold = fresh()
        dt_c, ftl_c = drive(cold, reqs())
        cold_row = restart_stats(cold, dt_c)
        cold_row["first_token_p50_ms"] = round(ftl_c * 1e3, 1)

    row = {
        "crash_recovery": crash_row,
        "warm_restart": warm_row,
        "cold_restart": cold_row,
        "prefill_tokens_saved_by_warm_restart":
            cold_row["prompt_tokens"] - warm_row["prompt_tokens"],
        "warm_restart_carried_pins": warm_ok,
    }
    print(f"serving_chaos,{crash_row['recovery_ms'] * 1e3:.0f},"
          f"torn-crash recovery={crash_row['recovery_ms']}ms "
          f"reconciled={crash_row['reconciled_pages']}pg "
          f"token_identical={crash_row['token_identical']} "
          f"leak_free={crash_row['leak_free']} "
          f"warm_vs_cold_prefill_saved="
          f"{row['prefill_tokens_saved_by_warm_restart']}tok "
          f"warm_pin_hits={warm_row['pin_hit_reqs']}")
    return row


def serving_size_classes(cfg, params):
    """Size-classed allocation plane (DESIGN.md §14): a bounded-state
    model (ring + recurrent layers) served with the two-class pool.

    Reports per-class blocks-in-use (peak and mean over steps) and the
    over-allocation the fine CLS_STATE granularity saves versus
    charging the same bounded state in whole KV pages — both in
    token-capacity units.  The paged-KV class is untouched (class-0
    counters match the single-class engine bit for bit, asserted in
    tests/test_classed_pool.py); the win is that admission accounts
    ring windows / recurrent blocks at quarter-page granularity."""
    import jax
    import numpy as np
    from repro import models
    from repro.configs import get_config, smoke_config
    from repro.core.classed_pool import CLS_KV, CLS_STATE
    from repro.models.transformer import (base_kind, state_blocks_per_slot,
                                          state_page_tokens)
    from repro.serving.engine import Request, ServingEngine

    scfg = smoke_config(get_config("recurrentgemma-2b"))
    sparams = models.init_params(scfg, jax.random.PRNGKey(0))
    rng = np.random.RandomState(0)
    max_len = 96
    # speculation on: the repeat share of the mix drafts from recorded
    # continuations, so draft-tail rollback traffic rides the run (on a
    # ring/recurrent arch it frees zero KV pages — state never moves,
    # which is the point of the accounting-plane routing)
    eng = ServingEngine(scfg, sparams, dp=2, b_local=2, max_len=max_len,
                        size_classes=2, speculate=True, draft_len=4)
    base = list(rng.randint(1, 255, 12))
    prompts = [list(base) if i % 3 == 0
               else list(rng.randint(1, 255, rng.randint(6, 16)))
               for i in range(10)]
    for i, p in enumerate(prompts):
        eng.submit(Request(i, prompt=p, max_new_tokens=8))
    in_use = {CLS_KV: [], CLS_STATE: []}
    t0 = time.perf_counter()
    for _ in range(2000):
        if eng.idle():
            break
        eng.step()
        for c in in_use:
            in_use[c].append(eng.blocks_in_use(c))
    dt = time.perf_counter() - t0
    assert eng.idle() and eng.leak_free()

    # over-allocation: the same bounded state charged at KV-page
    # granularity (each component rounds up to a whole coarse page)
    psz_kv, psz_s = scfg.page_size, state_page_tokens(scfg)
    W = min(scfg.window or max_len, max_len)
    coarse_pages = 0
    for k in scfg.pattern:
        bk = base_kind(k)
        if bk == "local":
            coarse_pages += scfg.n_groups * -(-W // psz_kv)
        elif bk != "global":
            coarse_pages += scfg.n_groups      # one page per rec block
    for k in scfg.remainder:
        bk = base_kind(k)
        if bk == "local":
            coarse_pages += -(-W // psz_kv)
        elif bk != "global":
            coarse_pages += 1
    sbs = state_blocks_per_slot(scfg, max_len)
    fine_tok = sbs * psz_s
    coarse_tok = coarse_pages * psz_kv
    admissions = eng.stats["admitted"]
    granted = eng.stats["state_blocks_granted"]
    assert granted == admissions * sbs, (granted, admissions, sbs)
    row = {
        "config": scfg.name,
        "size_classes": eng.n_classes,
        "state_blocks_per_slot": sbs,
        "state_page_tokens": psz_s,
        "kv_page_tokens": psz_kv,
        "blocks_in_use_peak": {c: int(max(v) if v else 0)
                               for c, v in in_use.items()},
        "blocks_in_use_mean": {c: round(float(np.mean(v)) if v else 0.0, 2)
                               for c, v in in_use.items()},
        "state_blocks_granted": granted,
        "per_slot_state_tokens_fine": fine_tok,
        "per_slot_state_tokens_coarse": coarse_tok,
        "over_alloc_saved_tokens_per_slot": coarse_tok - fine_tok,
        "over_alloc_saved_tokens_total": admissions * (coarse_tok - fine_tok),
        "saved_frac": round(1 - fine_tok / max(coarse_tok, 1), 4),
        "spec_drafted": eng.stats["spec_drafted"],
        "spec_pages_rolled_back": eng.stats["spec_pages_rolled_back"],
        "wall_s": round(dt, 3),
        "leak_free": True,
    }
    assert row["over_alloc_saved_tokens_per_slot"] > 0, (
        "fine class saved nothing — class boundary is mis-sized")
    print(f"serving_size_classes,0,arch={scfg.name} "
          f"state_blocks/slot={sbs} "
          f"saved_tok/slot={row['over_alloc_saved_tokens_per_slot']} "
          f"saved_frac={row['saved_frac']} "
          f"peak_state_blocks={row['blocks_in_use_peak'][CLS_STATE]}")
    return row


def serving_moe(cfg, params):
    """Expert-paged MoE serving (DESIGN.md §15): the expert FFN stack
    routed through the classed pool's CLS_EXPERT read-only pages vs the
    resident-weight engine, on three footprint mixes:

    * ``skewed``   — 80% of requests share one hot 2-expert footprint,
      the rest fan out to cold pairs (the production shape load-aware
      admission is built for);
    * ``uniform``  — footprints rotate round-robin over disjoint pairs
      (worst case for the LRU: every admission is a miss);
    * ``hot_repeat`` — every request reuses the same footprint (best
      case: one load, then pure hits).

    Reports expert hit rate, peak pages resident, and the weight-HBM
    savings vs full residency — and asserts the §15 soundness story:
    token-identical streams on every mix, zero in-step misses, zero
    dropped tokens, leak-free after drain + flush.  The paged engine
    runs under a budget HALF of full residency — a configuration the
    resident engine cannot express at all — and the skewed mix must
    clear the >= 30% peak weight-HBM reduction bar."""
    import dataclasses
    import jax
    import numpy as np
    from repro import models
    from repro.configs import get_config, smoke_config
    from repro.models.transformer import EXPERT_PPE, expert_layer_slots
    from repro.serving.engine import Request, ServingEngine

    scfg = smoke_config(get_config("mixtral-8x7b"))
    # serving capacity factor: dispatch capacity >= routed load, so the
    # zero-drop meter (satellite of §15) is a hard invariant here
    scfg = dataclasses.replace(
        scfg, moe=dataclasses.replace(scfg.moe, capacity_factor=64.0))
    sparams = models.init_params(scfg, jax.random.PRNGKey(0))
    E = scfg.moe.num_experts
    slots = expert_layer_slots(scfg)
    full_pages = slots * E * EXPERT_PPE          # resident-engine stack
    budget = full_pages // 2                      # inexpressible resident
    pairs = [tuple(sorted((i % E, (i + 1) % E))) for i in range(E)]
    rng = np.random.RandomState(0)
    n_req = 12
    mixes = {
        "skewed": [pairs[0] if rng.random() < 0.8
                   else pairs[1 + rng.randint(len(pairs) - 1)]
                   for _ in range(n_req)],
        "uniform": [pairs[i % len(pairs)] for i in range(n_req)],
        "hot_repeat": [pairs[0]] * n_req,
    }
    prompts = [list(rng.randint(1, scfg.vocab - 1, 8)) for _ in range(n_req)]

    def run(paged, fps):
        eng = ServingEngine(scfg, sparams, dp=1, b_local=2, max_len=64,
                            prefix_sharing=False, mesh=None,
                            expert_paging=paged,
                            expert_budget=budget if paged else None)
        reqs = [Request(i, prompt=list(prompts[i]), max_new_tokens=8,
                        experts=fps[i]) for i in range(n_req)]
        for r in reqs:
            eng.submit(r)
        t0 = time.perf_counter()
        eng.run(max_steps=3000)
        dt = time.perf_counter() - t0
        assert eng.idle(), "mix never drained"
        return eng, [r.out_tokens for r in reqs], dt

    row = {"config": scfg.name, "expert_pages_full": full_pages,
           "expert_budget": budget, "mixes": {}}
    for mix, fps in mixes.items():
        _, want, _ = run(False, fps)
        eng, got, dt = run(True, fps)
        assert got == want, f"{mix}: paged streams diverged from resident"
        assert int(eng.telemetry.shard["moe_dropped_tokens"].sum()) == 0
        assert int(eng.telemetry.shard["expert_miss_pages_c2"].sum()) == 0
        peak = eng.stats["expert_pages_resident_peak"]
        saved = 1 - peak / max(full_pages, 1)
        eng.flush_experts()
        assert eng.leak_free(), f"{mix}: expert pages leaked"
        hr = eng.telemetry.expert_hit_rate()
        row["mixes"][mix] = {
            "expert_hit_rate": None if hr is None else round(hr, 4),
            "expert_load_pages": eng.stats["expert_load_pages"],
            "expert_evictions": eng.stats["expert_evictions"],
            "pages_resident_peak": peak,
            "weight_hbm_saved_frac": round(saved, 4),
            "sched_defer_experts": eng.stats["sched_defer_experts"],
            "token_identical": True,
            "wall_s": round(dt, 3),
        }
        print(f"serving_moe,0,mix={mix} hit_rate={hr} "
              f"peak_pages={peak}/{full_pages} "
              f"hbm_saved={saved:.0%} budget={budget} "
              f"evictions={eng.stats['expert_evictions']}")
    assert row["mixes"]["skewed"]["weight_hbm_saved_frac"] >= 0.30, (
        "skewed mix must save >= 30% peak weight HBM vs residency")
    assert (row["mixes"]["hot_repeat"]["expert_hit_rate"] or 0) >= \
        row["mixes"]["uniform"]["expert_hit_rate"], (
        "hot-repeat must hit at least as often as round-robin")
    return row


def spec_perf_smoke(cfg, params):
    """CI gate (spec-perf-smoke job): speculation must PAY.  Runs the
    shared baseline plus the gated partial-accept mix and asserts
    ``speedup_gen >= 1.0`` — the regression this PR exists to fix (the
    prior state of this path was 0.22x, ROADMAP §perf) — then profiles
    a window containing one speculative verify step with jax.profiler
    into ``spec_trace/`` for the job's artifact upload."""
    import jax
    import numpy as np
    from repro.serving.engine import Request, ServingEngine

    row = serving_speculative(cfg, params, smoke=True)
    part = row["partial_gated"]
    assert part["token_identical"], part
    assert part["leak_free"], part
    assert part["spec_lanes"] > 0, part
    assert part["speedup_gen"] >= 1.0, (
        f"speculation lost throughput on the partial-accept mix: {part}")

    # profiler trace of a speculative step: record + compile off-trace,
    # then step a hot replay until a draft lane fires inside the trace
    rng = np.random.RandomState(0)
    hot = list(rng.randint(1, 255, 16))
    uniq = [hot + list(rng.randint(1, 255, 4 + i)) for i in range(4)]
    eng = ServingEngine(cfg, params, dp=1, b_local=4, max_len=96,
                        chunk_size=16, speculate=True, draft_len=4)
    for w in range(2):
        for i, p in enumerate(uniq):
            eng.submit(Request(-1 - i - 100 * w, prompt=list(p),
                               max_new_tokens=8))
        eng.run(max_steps=500)
    for i, p in enumerate(uniq):
        eng.submit(Request(i, prompt=list(p), max_new_tokens=8))
    s0 = eng.stats["spec_lanes"]
    trace_dir = os.path.abspath("spec_trace")
    os.makedirs(trace_dir, exist_ok=True)
    with jax.profiler.trace(trace_dir):
        for _ in range(50):
            eng.step()
            if eng.stats["spec_lanes"] > s0:
                break
    assert eng.stats["spec_lanes"] > s0, "no speculative step in trace"
    eng.run(max_steps=500)
    assert eng.page_occupancy() == 0.0
    print(f"spec_perf_smoke,0,speedup_gen={part['speedup_gen']} "
          f"accept_rate={part['accept_rate']} "
          f"gate_skips={part['gate_skips']} trace_dir=spec_trace")


# serving benches reachable via the --emit-json subprocess protocol
_EMIT_JSON_FNS = {
    "mesh_shards": _serving_mesh_shards_inline,
    "speculative": serving_speculative,
    "moe": serving_moe,
}


def main(argv=None) -> None:
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--emit-json", metavar="NAME", default=None,
                    choices=sorted(_EMIT_JSON_FNS),
                    help="run one serving bench and print its row as a "
                         f"'{_JSON_TAG}' line (subprocess protocol)")
    ap.add_argument("--spec-smoke", action="store_true",
                    help="CI gate: assert gated partial-accept "
                         "speedup_gen >= 1.0 and write a jax.profiler "
                         "trace of a speculative step to spec_trace/")
    args = ap.parse_args(argv)
    if args.emit_json or args.spec_smoke:
        import jax
        from repro import models
        from repro.configs import get_config, smoke_config
        cfg = smoke_config(get_config("olmo-1b"))
        params = models.init_params(cfg, jax.random.PRNGKey(0))
        if args.spec_smoke:
            spec_perf_smoke(cfg, params)
            return
        row = _EMIT_JSON_FNS[args.emit_json](cfg, params)
        print(_JSON_TAG + json.dumps(row))
        return
    print("name,us_per_call,derived")
    result1_worst_case_steps()
    result1_vs_baselines()
    result1_space_overhead()
    result1_memory_blowup()
    result2_shared_op_cost()
    jax_block_pool_o1()
    jax_paged_kv_append()
    serving_throughput()


if __name__ == "__main__":
    main()
