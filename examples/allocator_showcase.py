"""The paper, end to end: every Result demonstrated in one script.

  PYTHONPATH=src python examples/allocator_showcase.py

Walks through: O(1) worst-case bound under four adversarial schedulers
(Result 1.2), live-block capacity m - Theta(p^2) (1.3), Theta(p^2)
metadata (1.4), shared-stack O(p) ops with <= 2p internal allocations
(Result 2), wait-freedom under crash failures, and the comparison against
lock-based and Treiber-stack baselines.
"""

import random

from repro.core import (SimContext, WaitFreeAllocator, Scheduler,
                        check_alloc_history, PoolExhausted)
from repro.core.baselines import (HoardSpaceModel, LockFreeListAllocator,
                                  TreiberAllocator)

def phased_bursts(pid, alloc, phases=4):
    """Alloc/free bursts sized to force shared-pool batch transfers."""
    held = []
    for ph in range(phases):
        if ph % 2 == 0:
            for _ in range(alloc.ell * 3):
                held.append((yield from alloc.allocate(pid)))
        else:
            while held:
                yield from alloc.free(pid, held.pop())


print("=== Result 1.2: O(1) worst-case, any scheduler, any p ===")
for p in (2, 8, 32):
    worst = 0
    for policy in ("random", "bursty", "round_robin", "stall_one"):
        ctx = SimContext(p, seed=1)
        alloc = WaitFreeAllocator(ctx, shared_batches=4 * p)
        sched = Scheduler(seed=1)
        for pid in range(p):
            sched.add(pid, phased_bursts(pid, alloc))
        sched.run(policy)
        assert ctx.violations == [] and check_alloc_history(ctx.history) == []
        worst = max(worst, max(o.steps for o in ctx.history if o.completed))
    print(f"  p={p:3d}: worst-case steps/op = {worst}")

print("=== Result 1.3: live capacity m - Theta(p^2) ===")
for p in (2, 8):
    ctx = SimContext(p, seed=0)
    alloc = WaitFreeAllocator(ctx, shared_batches=6 * p)
    sched = Scheduler(seed=0)
    got = []

    def greedy(pid):
        try:
            while True:
                got.append((yield from alloc.allocate(pid)))
        except PoolExhausted:
            return

    sched.add(0, greedy(0))
    try:
        sched.run("round_robin")
    except PoolExhausted:
        pass
    m = alloc.mem.m
    print(f"  p={p}: allocated {len(got)}/{m} blocks "
          f"(unreachable: {m - len(got)} <= c*p^2 = {11 * p * p + 8 * p})")

print("=== Result 1.4: Theta(p^2) metadata ===")
for p in (4, 8, 16, 32):
    ctx = SimContext(p, seed=0)
    alloc = WaitFreeAllocator(ctx, shared_batches=4 * p)
    print(f"  p={p:3d}: {alloc.metadata_words():7d} words "
          f"({alloc.metadata_words() / p / p:.1f} * p^2)")

print("=== wait-freedom under crashes ===")
p = 6
ctx = SimContext(p, seed=9)
alloc = WaitFreeAllocator(ctx, shared_batches=4 * p)
sched = Scheduler(seed=9)
for pid in range(p):
    sched.add(pid, phased_bursts(pid, alloc))
sched.run("random", crash_at={0: 400, 1: 1200, 2: 2000})
alive = [pid for pid in range(p) if sched.done[pid]]
worst = max(o.steps for o in ctx.history
            if o.completed and o.pid in (3, 4, 5))
print(f"  crashed 3 of {p} processes mid-run; survivors {alive[-3:]} all "
      f"finished, worst op {worst} steps, violations: {len(ctx.violations)}")

print("=== baselines: worst-case op cost under contention ===")
p = 8
for name, cls in (("global lock", LockFreeListAllocator),
                  ("treiber stack", TreiberAllocator)):
    ctx = SimContext(p, seed=0)
    alloc = cls(ctx, m=4096)
    sched = Scheduler(seed=0)

    def wl(pid, alloc=alloc):
        held = []
        rng = random.Random(pid)
        for _ in range(200):
            if not held or rng.random() < 0.6:
                b = yield from alloc.allocate(pid)
                if b >= 0:
                    held.append(b)
            else:
                yield from alloc.free(pid, held.pop())

    for pid in range(p):
        sched.add(pid, wl(pid))
    sched.run("random")
    worst = max(o.steps for o in ctx.history if o.completed)
    print(f"  {name:14s}: worst {worst:5d} steps "
          f"(unbounded in theory; ours is provably constant)")

print("=== section 3.1: additive memory blowup ===")
for p in (8, 64, 512):
    ours = HoardSpaceModel.paper_blowup_blocks(p)
    hoard = HoardSpaceModel(p, superblock_blocks=1024).additive_blowup_blocks()
    print(f"  p={p:4d}: ours Theta(p^2) = {ours:9d} blocks, "
          f"Hoard Theta(p*S) = {hoard:9d} blocks")
print("showcase done.")
