"""Fault-tolerant training demo: checkpoint/restart + failure injection.

Trains a reduced mixtral (MoE + SWA) while injecting two node failures;
the loop restores from the last complete checkpoint and converges to the
exact same state a failure-free run reaches (pure step function + pure
data stream).

  PYTHONPATH=src python examples/train_fault_tolerant.py
"""

import shutil

import jax.numpy as jnp

from repro.launch.train import main as train_main


def main():
    for d in ("/tmp/ft_a", "/tmp/ft_b"):
        shutil.rmtree(d, ignore_errors=True)

    print("=== run A: no failures ===")
    state_a, losses_a = train_main([
        "--arch", "mixtral-8x7b", "--smoke", "--steps", "24",
        "--save-every", "6", "--ckpt-dir", "/tmp/ft_a"])

    print("=== run B: failure injected at step 15 ===")
    state_b, losses_b = train_main([
        "--arch", "mixtral-8x7b", "--smoke", "--steps", "24",
        "--save-every", "6", "--ckpt-dir", "/tmp/ft_b",
        "--inject-failure-at", "15"])

    pa, _, _ = state_a
    pb, _, _ = state_b
    diffs = [float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                   - b.astype(jnp.float32))))
             for a, b in zip(
                 jnp.tree_util.tree_leaves(pa), jnp.tree_util.tree_leaves(pb))]
    print(f"max param diff after recovery vs failure-free: {max(diffs):.2e}")
    assert max(diffs) < 1e-5, "recovery must be bit-faithful"
    print("fault-tolerant recovery is exact.")


if __name__ == "__main__":
    main()
