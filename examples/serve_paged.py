"""End-to-end serving driver: batched requests over paged KV cache.

The engine admits requests through the paper's wait-free allocator
(sequence slots = fixed-size blocks) behind a traffic-aware admission
scheduler (priority/SLO classes, per-shard page budgets, preemption,
pinned prefix retention — DESIGN.md §8), streams prompts through
chunked prefill (``--chunk`` tokens per step, each chunk's pages
allocated in one O(1)-per-request ``alloc_n`` batch), and decodes fully
on device — per-request temperature/top-k sampling, done-detection, and
page release all live inside the jitted step, so the host syncs once
per step on a packed status array.

  PYTHONPATH=src python examples/serve_paged.py [--arch recurrentgemma-2b]
  PYTHONPATH=src python examples/serve_paged.py \
      --hot-prefix 24 --pin-pages 12 --bursts 3 --interactive-frac 0.25
  PYTHONPATH=src python examples/serve_paged.py \
      --hot-prefix 24 --speculate --draft-len 4 --chunk-buckets 1,4,8

Fault-tolerant mode (DESIGN.md §11): ``--inject-fault`` takes a
comma-joined spec of deterministic faults (serving/chaos.py), e.g.
``crash@6:post_sync:torn,shard_loss@12:post_admission:1``.  Host
crashes are caught here, the engine is rebuilt, and allocator state is
reconciled from the surviving device arrays + the admission journal
(``chaos.recover_engine``); the driver then proves the run drained
with zero leaked pages.
"""

import argparse
import time

import jax
import numpy as np

from repro import models
from repro.configs import get_config, smoke_config
from repro.serving import chaos
from repro.serving.engine import Request, ServingEngine
from repro.serving.sched import SchedConfig
from repro.serving.telemetry import FlightRecorder, install_signal_dump
from repro.serving.trace import Tracer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=0,
                    help="fixed prompt length (0 = random 4..24)")
    ap.add_argument("--chunk", type=int, default=8,
                    help="prefill chunk size (tokens per step)")
    ap.add_argument("--chunk-buckets", default="",
                    help="comma-separated SLO-aware prefill widths, e.g. "
                         "1,4,8 (DESIGN §10; empty = fixed --chunk)")
    ap.add_argument("--speculate", action="store_true",
                    help="speculative decode on shared prefixes "
                         "(draft from hot-prefix continuation history, "
                         "verify+rollback inside the fused step)")
    ap.add_argument("--draft-len", type=int, default=4,
                    help="draft tokens per speculative lane")
    ap.add_argument("--no-spec-gate", action="store_true",
                    help="disable the per-prefix accept-rate break-even "
                         "gate (DESIGN §12): always draft at full "
                         "draft-len")
    ap.add_argument("--repeat-frac", type=float, default=0.0,
                    help="fraction of requests repeating a previous "
                         "full prompt (the traffic speculation wins on)")
    ap.add_argument("--hot-prefix", type=int, default=0, metavar="N",
                    help="prepend a common N-token prefix to every prompt "
                         "(exercises refcounted prefix sharing, DESIGN §7)")
    ap.add_argument("--pin-pages", type=int, default=0,
                    help="pinned prefix-cache budget per shard in pages "
                         "(0 = off; keeps the hot prefix alive across "
                         "request lifetimes, DESIGN §8)")
    ap.add_argument("--bursts", type=int, default=1,
                    help="submit the requests in N bursts, draining the "
                         "engine between bursts (shows pinned prefixes "
                         "surviving idle gaps)")
    ap.add_argument("--interactive-frac", type=float, default=0.0,
                    help="fraction of requests in the interactive SLO "
                         "class (may preempt standard/batch work)")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="sampling temperature (0 = greedy)")
    ap.add_argument("--top-k", type=int, default=0,
                    help="top-k cutoff when sampling (0 = full vocab)")
    ap.add_argument("--inject-fault", default="", metavar="SPEC",
                    help="deterministic fault spec, comma-joined "
                         "kind@step:phase[:extra] — kinds crash / "
                         "shard_loss / straggler / poison / error "
                         "(serving/chaos.py; DESIGN.md §11)")
    ap.add_argument("--deadline-s", type=float, default=0.0,
                    help="per-request deadline in seconds (0 = none)")
    ap.add_argument("--metrics-path", default="", metavar="FILE",
                    help="write a Prometheus text-format telemetry "
                         "snapshot at end of run (DESIGN.md §13)")
    ap.add_argument("--trace-path", default="", metavar="FILE",
                    help="write the request-lifecycle trace at end of "
                         "run (chrome trace_event JSON; .jsonl suffix "
                         "writes one event per line)")
    ap.add_argument("--flight-recorder", default="", metavar="FILE",
                    help="crash flight-recorder dump path (last-N-steps "
                         "ring; dumps on crash / watchdog / reconcile / "
                         "SIGTERM)")
    ap.add_argument("--flight-sync", type=int, default=0, metavar="N",
                    help="also dump the flight ring every N steps "
                         "(covers SIGKILL; 0 = crash paths only)")
    args = ap.parse_args()

    cfg = smoke_config(get_config(args.arch))
    params = models.init_params(cfg, jax.random.PRNGKey(0))
    buckets = tuple(int(b) for b in args.chunk_buckets.split(",") if b)
    faults = bool(args.inject_fault)
    journal = chaos.ServingJournal() if faults else None
    injector = chaos.parse_faults(args.inject_fault) if faults else None

    tracer = Tracer() if args.trace_path else None

    def build():
        # a fresh recorder per build: chaos.recover_engine adopts the
        # crashed ring into it, so the forensic window spans the crash
        flight = (FlightRecorder(path=args.flight_recorder,
                                 sync_every=args.flight_sync)
                  if args.flight_recorder or args.flight_sync else None)
        eng = ServingEngine(
            cfg, params, dp=2, b_local=4, max_len=96,
            scheduler_lanes=4, chunk_size=args.chunk,
            speculate=args.speculate, draft_len=args.draft_len,
            spec_gate=not args.no_spec_gate,
            sched=SchedConfig(pin_pages=args.pin_pages,
                              chunk_buckets=buckets),
            journal=journal, injector=injector, max_restarts=4,
            tracer=tracer, flight=flight)
        if args.flight_recorder:
            install_signal_dump(eng.flight)
        return eng

    engine = build()

    rng = np.random.RandomState(0)
    hot = list(rng.randint(1, cfg.vocab - 1, args.hot_prefix))
    reqs = []
    prompts = []
    for rid in range(args.requests):
        plen = args.prompt_len or rng.randint(4, 24)
        slo = ("interactive"
               if rng.random_sample() < args.interactive_frac
               else "standard")
        if prompts and rng.random_sample() < args.repeat_frac:
            prompt = list(prompts[rng.randint(len(prompts))])
        else:
            prompt = hot + list(rng.randint(1, cfg.vocab - 1, plen))
        prompts.append(prompt)
        reqs.append(Request(
            rid, prompt=prompt,
            max_new_tokens=args.max_new, slo=slo,
            temperature=args.temperature, top_k=args.top_k, seed=rid,
            deadline_s=args.deadline_s))

    t0 = time.time()
    peak_occ = 0.0
    crashes = 0
    per_burst = -(-len(reqs) // max(args.bursts, 1))
    for i in range(0, len(reqs), per_burst):
        for r in reqs[i:i + per_burst]:
            engine.submit(r)
        while not engine.idle():
            try:
                # one protected step: engine.run owns the §11 exception
                # discipline (poison -> bounded retry, step error ->
                # in-place recovery); only a host crash escapes
                engine.run(max_steps=1)
            except chaos.HostCrash:
                # the host process "died": rebuild from scratch and
                # reconcile allocator state against the device arrays
                # + journal — in-flight work requeues token-identically
                crashes += 1
                engine, report = chaos.recover_engine(
                    build, engine, journal)
                print(f"[chaos] host crash #{crashes} at "
                      f"step={injector.step}: reconciled "
                      f"{report['reclaimed']} leaked pages, "
                      f"requeued {report['requeued']} requests, "
                      f"restored {report['pins_restored']} pins "
                      f"(never_dry={report['never_dry']})")
            peak_occ = max(peak_occ, engine.page_occupancy())
    dt = time.time() - t0

    s = engine.stats
    lat = engine.latency_quantiles()
    total = s["tokens_out"] + s["prompt_tokens"]
    print(f"arch={cfg.name} chunk={args.chunk} "
          f"buckets={engine.scheduler.buckets(args.chunk)} "
          f"bursts={args.bursts} lane_hist={s['chunk_hist']}")
    print(f"requests={s['admitted']} gen_tokens={s['tokens_out']} "
          f"prompt_tokens={s['prompt_tokens']} steps={s['steps']} "
          f"wall={dt:.1f}s throughput={total/dt:.1f} tok/s "
          f"({s['tokens_out']/dt:.1f} gen tok/s)")
    print(f"p50 latency={lat['p50_s']*1e3:.0f}ms "
          f"p99={lat['p99_s']*1e3:.0f}ms "
          f"first-token p50={lat['first_token_p50_s']*1e3:.0f}ms")
    print(f"peak page occupancy={peak_occ:.2%}  "
          f"after drain={engine.page_occupancy():.2%} "
          f"({engine.pinned_pages()} pages cache-pinned)")
    if engine.prefix_cache is not None:
        print(f"prefix sharing: {s['prefix_shared_reqs']} requests reused "
              f"{s['prefix_shared_tokens']} prompt tokens from live pages "
              f"(pages-in-use mean={engine.pages_mean():.1f} "
              f"peak={s['pages_peak']})")
    ss = engine.scheduler.stats
    print(f"scheduler: preemptions={s['preemptions']} "
          f"deferred={ss['deferred']} rejected={ss['rejected']} "
          f"pins created={s['pins_created']} hits={s['pin_hit_reqs']} "
          f"({s['pin_hit_tokens']} tokens) evicted={ss['pins_evicted']}")
    if engine.speculate:
        rate = s["spec_accepted"] / max(s["spec_drafted"], 1)
        print(f"speculative: {s['spec_lanes']} draft lanes, "
              f"{s['spec_drafted']} drafted, {s['spec_accepted']} accepted "
              f"(rate={rate:.2f}), {s['spec_pages_rolled_back']} pages "
              f"rolled back, accept_hist={s['accept_hist']}, "
              f"gate_skips={s['spec_gate_skips']}, "
              f"mixed_steps={s['spec_mixed_steps']}")
    print(f"host admission worst-case steps={s['alloc_steps_max']} "
          f"(paper Result 1: O(1))")
    engine.flush_pins()
    if faults:
        print(f"[chaos] fired={injector.log} crashes={crashes} "
              f"shards_lost={sorted(engine.lost_shards)} "
              f"recoveries={s['recoveries']} retries={s['retries']} "
              f"failed={s['failed']} "
              f"deadline_expired={s['deadline_expired']}")
        assert not injector.pending(), (
            f"faults never reached: {injector.pending()}")
        assert engine.leak_free(), "pages leaked on surviving shards"
        assert not journal.in_flight(), "requests neither finished nor failed"
        print(f"[chaos] drained clean: {len(journal.finished())} finished, "
              f"zero leaked pages on surviving shards")
    else:
        assert engine.page_occupancy() == 0.0, \
            "pages leaked after drain+flush"
        assert all(r.done for r in reqs)
    m = engine.telemetry.never_dry_margin_min()
    print(f"never-dry margin (min over shards x steps): {m} "
          f"(>= 0 proves §4.2 held with slack)")
    if args.metrics_path:
        with open(args.metrics_path, "w") as fh:
            fh.write(engine.telemetry.render_prom())
        print(f"telemetry: prometheus snapshot -> {args.metrics_path}")
    if args.trace_path:
        if args.trace_path.endswith(".jsonl"):
            engine.tracer.write_jsonl(args.trace_path)
        else:
            engine.tracer.write_chrome(args.trace_path)
        print(f"telemetry: {len(engine.tracer.events)} trace events -> "
              f"{args.trace_path}")


if __name__ == "__main__":
    main()
