"""End-to-end serving driver: batched requests over paged KV cache.

The engine admits requests through the paper's wait-free allocator
(sequence slots = fixed-size blocks), streams prompts + generation
through the paged decode path, and reports allocator + paging metrics.

  PYTHONPATH=src python examples/serve_paged.py [--arch recurrentgemma-2b]
"""

import argparse
import time

import jax
import numpy as np

from repro import models
from repro.configs import get_config, smoke_config
from repro.serving.engine import Request, ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--max-new", type=int, default=8)
    args = ap.parse_args()

    cfg = smoke_config(get_config(args.arch))
    params = models.init_params(cfg, jax.random.PRNGKey(0))
    engine = ServingEngine(cfg, params, dp=2, b_local=4, max_len=96,
                           scheduler_lanes=4)

    rng = np.random.RandomState(0)
    reqs = []
    for rid in range(args.requests):
        r = Request(rid,
                    prompt=list(rng.randint(1, cfg.vocab - 1,
                                            rng.randint(4, 24))),
                    max_new_tokens=args.max_new)
        reqs.append(r)
        engine.submit(r)

    t0 = time.time()
    peak_occ = 0.0
    while engine.queue or engine.active:
        engine.step()
        peak_occ = max(peak_occ, engine.page_occupancy())
    dt = time.time() - t0

    lat = [r.finished_at - r.submitted_at for r in reqs]
    s = engine.stats
    print(f"arch={cfg.name}")
    print(f"requests={s['admitted']} tokens={s['tokens_out']} "
          f"steps={s['steps']} wall={dt:.1f}s "
          f"throughput={s['tokens_out']/dt:.1f} tok/s")
    print(f"p50 latency={sorted(lat)[len(lat)//2]*1e3:.0f}ms "
          f"p99={sorted(lat)[-1]*1e3:.0f}ms")
    print(f"peak page occupancy={peak_occ:.2%}  "
          f"after drain={engine.page_occupancy():.2%} (0% = no leaks)")
    print(f"host admission worst-case steps={s['alloc_steps_max']} "
          f"(paper Result 1: O(1))")
    assert all(r.done for r in reqs)


if __name__ == "__main__":
    main()
