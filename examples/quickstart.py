"""Quickstart: the paper's allocator + the framework around it, in 2 min.

  PYTHONPATH=src python examples/quickstart.py

1. runs the *faithful* wait-free allocator under an adversarial scheduler
   and shows the O(1) worst-case step bound (Result 1),
2. allocates/frees KV pages through the device-side block pool,
3. trains a reduced olmo-1b for a few steps,
4. serves a few requests through the paged-KV continuous-batching engine.
"""

import random

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (SimContext, WaitFreeAllocator, Scheduler,
                        closed_loop, check_alloc_history, block_pool)

# ---------------------------------------------------------- 1. the paper
print("=== 1. wait-free fixed-size allocate/free (Result 1) ===")
p = 8
ctx = SimContext(p, seed=0)
alloc = WaitFreeAllocator(ctx, shared_batches=4 * p)
sched = Scheduler(seed=0)
for pid in range(p):
    sched.add(pid, closed_loop(pid, alloc, 300, random.Random(pid)))
sched.run("random")
worst = max(op.steps for op in ctx.history if op.completed)
print(f"  {len(ctx.history)} ops on {p} async processes; "
      f"worst-case steps/op = {worst} (constant), "
      f"violations = {len(ctx.violations)}, "
      f"linearizability errors = {len(check_alloc_history(ctx.history))}")

# ------------------------------------------------- 2. device block pool
print("=== 2. TPU-native block pool (paged-KV pages) ===")
pool = block_pool.create(1024)
pool, ids = jax.jit(block_pool.alloc)(pool, jnp.ones(8, bool))
print(f"  allocated pages {np.asarray(ids)} in O(1) array ops; "
      f"free = {int(pool.top)}/1024")
pool = jax.jit(block_pool.free)(pool, ids)
print(f"  freed; free = {int(pool.top)}/1024")

# --------------------------------------------------------- 3. tiny train
print("=== 3. train a reduced olmo-1b ===")
from repro.launch.train import main as train_main
train_main(["--arch", "olmo-1b", "--smoke", "--steps", "10",
            "--ckpt-dir", "/tmp/quickstart_ckpt"])

# --------------------------------------------------------- 4. tiny serve
print("=== 4. serve through the paged-KV engine ===")
from repro.launch.serve import main as serve_main
serve_main(["--arch", "olmo-1b", "--smoke", "--requests", "6",
            "--max-new", "5"])
print("quickstart done.")
