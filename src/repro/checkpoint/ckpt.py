"""Sharded checkpointing with atomic index and async save.

Layout: ``<dir>/step_<N>/``
  * ``shard_<k>.npz``  — flat {path: array} for this host's shard
  * ``aux.json``       — optional JSON sidecar (host-side ledgers the
    serving plane's warm restart carries: pin entries, speculation
    streams, queued requests — serving/engine.py)
  * ``INDEX.json``     — written LAST (atomic rename); a checkpoint
    without INDEX is incomplete and ignored on restore

Fault-tolerance contract (runtime/fault.py, serving/chaos.py):
  * saves never corrupt the previous checkpoint: every file is written
    to a temp name and atomically renamed into place, so a crash
    mid-save — even one re-writing an existing step directory — leaves
    either the old complete snapshot or the new one, never a torn file;
  * ``latest_step`` only reports complete checkpoints;
  * async mode runs serialization in a worker thread — the train loop's
    deamortized "delayed work" slice, the same discipline as the paper's
    ``run_delayed_step``.
"""

from __future__ import annotations

import json
import os
import pathlib
import shutil
import threading
from typing import Any, Dict, Optional

import jax
import numpy as np


def _flatten(tree: Any, prefix: str = "") -> Dict[str, np.ndarray]:
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}/"))
    elif hasattr(tree, "_fields"):          # NamedTuple
        for k in tree._fields:
            out.update(_flatten(getattr(tree, k), f"{prefix}{k}/"))
    elif isinstance(tree, (tuple, list)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}/"))
    elif tree is None:
        pass
    else:
        out[prefix[:-1]] = np.asarray(tree)
    return out


def _unflatten_into(tree: Any, flat: Dict[str, np.ndarray], prefix: str = ""):
    if isinstance(tree, dict):
        return {k: _unflatten_into(v, flat, f"{prefix}{k}/")
                for k, v in tree.items()}
    if hasattr(tree, "_fields"):
        return type(tree)(*(
            _unflatten_into(getattr(tree, k), flat, f"{prefix}{k}/")
            for k in tree._fields))
    if isinstance(tree, (tuple, list)):
        return type(tree)(
            _unflatten_into(v, flat, f"{prefix}{i}/")
            for i, v in enumerate(tree))
    if tree is None:
        return None
    arr = flat[prefix[:-1]]
    return jax.numpy.asarray(arr, dtype=tree.dtype).reshape(tree.shape)


class Checkpointer:
    def __init__(self, directory: str, shard_id: int = 0, keep: int = 3):
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.shard_id = shard_id
        self.keep = keep
        self._thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------- save
    def save(self, step: int, state: Any, async_: bool = False,
             aux: Any = None) -> None:
        def np_safe(a):
            a = np.asarray(a)
            if a.dtype.kind == "V" or a.dtype.name == "bfloat16":
                return a.astype(np.float32)   # lossless; restore re-casts
            return a
        flat = {k: np_safe(v) for k, v in _flatten(state).items()}
        if async_:
            self.wait()
            self._thread = threading.Thread(
                target=self._write, args=(step, flat, aux), daemon=True)
            self._thread.start()
        else:
            self._write(step, flat, aux)

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, flat: Dict[str, np.ndarray],
               aux: Any = None) -> None:
        d = self.dir / f"step_{step:08d}"
        d.mkdir(parents=True, exist_ok=True)
        # write-temp-then-rename: a crash mid-serialization must never
        # tear the npz a restore would read (tested by the kill-mid-save
        # regression in tests/test_chaos.py)
        tmp_npz = d / f".shard_{self.shard_id}.npz.tmp"
        with open(tmp_npz, "wb") as f:
            np.savez(f, **flat)
        os.replace(tmp_npz, d / f"shard_{self.shard_id}.npz")
        if aux is not None:
            tmp_aux = d / ".aux.json.tmp"
            tmp_aux.write_text(json.dumps(aux, default=int))
            os.replace(tmp_aux, d / "aux.json")
        tmp = d / ".INDEX.tmp"
        tmp.write_text(json.dumps({
            "step": step,
            "shards": [self.shard_id],
            "keys": sorted(flat),
            "aux": aux is not None,
        }))
        os.replace(tmp, d / "INDEX.json")       # atomic completion marker
        self._gc()

    def _gc(self) -> None:
        done = sorted(p for p in self.dir.glob("step_*")
                      if (p / "INDEX.json").exists())
        for p in done[:-self.keep]:
            shutil.rmtree(p, ignore_errors=True)

    # ---------------------------------------------------------- restore
    def latest_step(self) -> Optional[int]:
        done = sorted(p for p in self.dir.glob("step_*")
                      if (p / "INDEX.json").exists())
        if not done:
            return None
        return int(done[-1].name.split("_")[1])

    def restore(self, step: int, like: Any) -> Any:
        d = self.dir / f"step_{step:08d}"
        assert (d / "INDEX.json").exists(), "incomplete checkpoint"
        flat = dict(np.load(d / f"shard_{self.shard_id}.npz"))
        return _unflatten_into(like, flat)

    def restore_aux(self, step: int) -> Any:
        """The JSON sidecar saved alongside ``step`` (None if absent)."""
        d = self.dir / f"step_{step:08d}"
        assert (d / "INDEX.json").exists(), "incomplete checkpoint"
        p = d / "aux.json"
        return json.loads(p.read_text()) if p.exists() else None
