"""Mixture-of-Experts FFN with sort-based (MegaBlocks-style) dispatch.

Two dispatch paths, both avoiding the quadratic one-hot dispatch tensor
of classic GShard:

* ``per-row`` (train/prefill): tokens are grouped per sequence row; each
  row sorts its (token, slot) pairs by expert id locally — with batch
  sharded over the data axis the sorts never cross devices.  Capacity
  per row C = ceil(top_k * S / E * cf); overflow tokens are dropped
  (standard capacity-factor semantics; see DESIGN.md).
* ``flat`` (decode, S == 1): all B tokens sorted globally; capacity
  C = ceil(top_k * B / E * cf).  Keeps decode FLOPs within ~cf of the
  useful expert compute instead of E/k times.

Expert weights are stacked [E, ...] and shard over the "experts"
logical axis (expert parallelism = model axis).
"""

from __future__ import annotations

import math
from typing import Dict

import jax
import jax.numpy as jnp

from .layers import ParamDef


def moe_defs(cfg) -> Dict[str, ParamDef]:
    d, f, E, dt = cfg.d_model, cfg.d_ff, cfg.moe.num_experts, cfg.jdtype
    return {
        "router": ParamDef((d, E), ("embed", None), jnp.float32),
        "w_gate": ParamDef((E, d, f), ("experts", "embed", "mlp"), dt),
        "w_up": ParamDef((E, d, f), ("experts", "embed", "mlp"), dt),
        "w_down": ParamDef((E, f, d), ("experts", "mlp", "embed"), dt),
    }


def _capacity(top_k: int, tokens: int, E: int, cf: float) -> int:
    c = math.ceil(top_k * tokens / E * cf)
    return max(8, min(c, top_k * tokens))   # clamp; pad to a useful floor


def _dispatch_indices(eids, E, C):
    """Sort-based routing for one token row: (keep, dest, t_s, order)."""
    N, k = eids.shape
    e_all = eids.reshape(N * k)
    t_all = jnp.repeat(jnp.arange(N), k)
    order = jnp.argsort(e_all)                       # stable
    e_s, t_s = e_all[order], t_all[order]
    idx = jnp.arange(N * k)
    start_of_expert = jnp.searchsorted(e_s, jnp.arange(E), side="left")
    pos = idx - start_of_expert[e_s]
    keep = pos < C
    dest = jnp.where(keep, e_s * C + pos, E * C)     # E*C = dropped bucket
    return keep, dest, t_s, order


#: router-logit fill for experts outside a row's admitted footprint.
#: Large but FINITE: an all-masked row still softmaxes to finite
#: (garbage) gates instead of NaN — its output is discarded anyway.
MASK_NEG = -1e30


def _dispatch_compute(params, x_flat, gates, eids, C):
    """Sort-based dispatch for one token group (flat / decode path).
    Returns (out, (keep, t_s, e_s)) — the routing meta feeds the
    capacity-drop / expert-touch meters."""
    N, d = x_flat.shape
    E = params["router"].shape[1]
    keep, dest, t_s, order = _dispatch_indices(eids, E, C)
    e_s = eids.reshape(-1)[order]
    g_s = gates.reshape(-1)[order]

    buf = jnp.zeros((E * C, d), x_flat.dtype)
    buf = buf.at[dest].set(x_flat[t_s], mode="drop")
    buf = buf.reshape(E, C, d)

    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, params["w_gate"])) * \
        jnp.einsum("ecd,edf->ecf", buf, params["w_up"])
    y = jnp.einsum("ecf,efd->ecd", h.astype(x_flat.dtype), params["w_down"])
    y = y.astype(x_flat.dtype).reshape(E * C, d)

    gathered = jnp.where(keep[:, None], y[jnp.minimum(dest, E * C - 1)], 0.0)
    out = jnp.zeros((N, d), x_flat.dtype)
    out = out.at[t_s].add(gathered * g_s[:, None].astype(x_flat.dtype))
    return out, (keep, t_s, e_s)


def moe_apply(cfg, params, x, *, expert_mask=None, token_valid=None,
              metered: bool = False):
    """x: [B, S, d] -> [B, S, d] (or ``(out, dropped, routed)`` when
    ``metered``: ``dropped`` int32[B] counts capacity-overflow-dropped
    (token, expert) assignments of VALID tokens per row, ``routed``
    int32[B, E] counts valid kept assignments per expert).

    ``expert_mask`` (bool [B, E]) restricts each row's routing to its
    admitted expert footprint (expert-paged serving, DESIGN.md §15):
    out-of-footprint logits take :data:`MASK_NEG` BEFORE top_k, so a
    footprint row never routes to a non-resident expert.  An all-True
    mask selects exactly the unmasked logits — value-identical to no
    mask.  ``token_valid`` (bool [B, S]) marks real token positions for
    the meters (padding tokens route and occupy capacity exactly as
    before, but never count as drops or touches).

    Train/prefill path: per-row sorted dispatch (vmapped scatter/gather —
    row-local, so sorts never cross shards) but **batched expert einsums
    outside the vmap** with explicit batch-sharding constraints on the
    dispatch buffer.  Without the constraints GSPMD resolved the mixed
    (batch-sharded activations x data-sharded expert weights) contraction
    by materializing full-batch expert activations and all-reducing them
    (~4.9e12 weighted bytes/device on mixtral train_4k — see
    EXPERIMENTS.md §Perf iteration A1); pinning the buffer forces the
    cheap weight-all-gather plan instead.
    """
    from ..parallel.partition import constrain_batch
    B, S, d = x.shape
    k, E, cf = cfg.moe.top_k, cfg.moe.num_experts, cfg.moe.capacity_factor

    # router in x.dtype with f32 accumulation: casting x itself to f32
    # would create an f32 [B,S,d] primal whose cotangent drags the whole
    # backward residual chain into f32 (2x collective/HBM bytes — §Perf A2)
    logits = jax.lax.dot_general(
        x, params["router"].astype(x.dtype), (((2,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)                   # [B,S,E]
    if expert_mask is not None:
        logits = jnp.where(expert_mask[:, None, :], logits,
                           jnp.float32(MASK_NEG))
    gates, eids = jax.lax.top_k(logits, k)
    gates = jax.nn.softmax(gates, axis=-1)

    valid = (jnp.ones((B, S), bool) if token_valid is None
             else token_valid.astype(bool))

    if S == 1:
        C = _capacity(k, B, E, cf)
        out, (keep, t_s, e_s) = _dispatch_compute(
            params, x.reshape(B, d), gates.reshape(B, k),
            eids.reshape(B, k), C)
        out = out.reshape(B, S, d)
        if not metered:
            return out
        # flat path: token index == row index (S == 1)
        v_s = valid.reshape(B)[t_s]
        dropped = jnp.zeros((B,), jnp.int32).at[t_s].add(
            (v_s & ~keep).astype(jnp.int32))
        routed = jnp.zeros((B, E), jnp.int32).at[t_s, e_s].add(
            (v_s & keep).astype(jnp.int32))
        return out, dropped, routed

    C = _capacity(k, S, E, cf)

    def row_scatter(xr, er):
        keep, dest, t_s, order = _dispatch_indices(er, E, C)
        buf = jnp.zeros((E * C, d), xr.dtype)
        buf = buf.at[dest].set(xr[t_s], mode="drop")
        return buf.reshape(E, C, d), (keep, dest, t_s, order,
                                      er.reshape(-1)[order])

    buf, meta = jax.vmap(row_scatter)(x, eids)       # [B, E, C, d]
    buf = constrain_batch(buf)

    h = jax.nn.silu(jnp.einsum("becd,edf->becf", buf, params["w_gate"])) * \
        jnp.einsum("becd,edf->becf", buf, params["w_up"])
    y = jnp.einsum("becf,efd->becd", h.astype(x.dtype), params["w_down"])
    y = constrain_batch(y.astype(x.dtype))

    def row_combine(yr, gr, m):
        keep, dest, t_s, order, e_s = m
        g_s = gr.reshape(-1)[order]
        yf = yr.reshape(E * C, d)
        gathered = jnp.where(keep[:, None],
                             yf[jnp.minimum(dest, E * C - 1)], 0.0)
        out = jnp.zeros((S, d), yr.dtype)
        return out.at[t_s].add(gathered * g_s[:, None].astype(yr.dtype))

    out = jax.vmap(row_combine)(y, gates, meta)
    if not metered:
        return out

    def row_meter(m, vr):
        keep, dest, t_s, order, e_s = m
        v_s = vr[t_s]
        dropped = jnp.sum(v_s & ~keep).astype(jnp.int32)
        routed = jnp.zeros((E,), jnp.int32).at[e_s].add(
            (v_s & keep).astype(jnp.int32))
        return dropped, routed

    dropped, routed = jax.vmap(row_meter)(meta, valid)
    return out, dropped, routed


def aux_load_balance_loss(cfg, logits_mean_prob, fraction_assigned):
    """Switch-style auxiliary loss (computed by the caller if desired)."""
    E = cfg.moe.num_experts
    return E * jnp.sum(logits_mean_prob * fraction_assigned)
