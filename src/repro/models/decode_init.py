"""Decode-state construction: empty state + prefill-cache loading.

Host-side engine utilities (not jitted): the serving engine allocates
prompt pages through the paper's allocator and scatters prefill K/V into
them.  Layouts match :class:`repro.models.transformer.DecodeState`.
"""

from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

from ..core import classed_pool
from ..core.classed_pool import CLS_KV, ClassSpec
from .transformer import DecodeState, decode_state_defs, _positions


def empty_decode_state(cfg, dp: int, b_local: int, max_len: int,
                       chunk: int | None = None,
                       size_classes: int = 1,
                       expert_budget: int | None = None) -> DecodeState:
    """Concrete zero state; pages live in a per-shard size-classed
    two-level pool vector with one private lane per slot per class
    (``chunk`` sizes the KV lane batch ``ell`` — see
    :func:`repro.models.transformer.pool_ell`; ``size_classes`` sets
    the class vector — see :func:`~repro.models.transformer.
    pool_class_specs`; ``expert_budget`` sizes the CLS_EXPERT class
    when ``size_classes >= 3``)."""
    defs = decode_state_defs(cfg, dp, b_local, max_len, chunk=chunk,
                             size_classes=size_classes,
                             expert_budget=expert_budget)

    def zeros(sds):
        return jnp.zeros(sds.shape, sds.dtype)

    kv_pages = jax.tree.map(zeros, defs.kv_pages)
    rings = jax.tree.map(zeros, defs.rings)
    rec = jax.tree.map(zeros, defs.rec)
    specs = tuple(
        ClassSpec(page_size=0,                    # granularity not stored
                  num_blocks=hp.shared.free_ids.shape[1],
                  num_lanes=hp.private_top.shape[1],
                  ell=hp.private_ids.shape[2] // 3)
        for hp in defs.pool.classes)
    pool = classed_pool.create_dp(dp, specs)
    page_tables = jnp.full(defs.page_tables.shape, -1, jnp.int32)
    seq_lens = jnp.zeros(defs.seq_lens.shape, jnp.int32)
    enc_kv = jax.tree.map(zeros, defs.enc_kv) if defs.enc_kv is not None else None
    state_tables = None
    if defs.state_tables is not None:
        state_tables = jnp.full(defs.state_tables.shape, -1, jnp.int32)
    expert_pages = expert_tables = None
    if defs.expert_pages is not None:
        expert_pages = zeros(defs.expert_pages)
        expert_tables = jax.tree.map(
            lambda s: jnp.full(s.shape, -1, jnp.int32), defs.expert_tables)
    return DecodeState(kv_pages, rings, rec, page_tables, seq_lens,
                       pool, enc_kv, state_tables, expert_pages,
                       expert_tables)


def empty_serve_arrays(dp: int, b_local: int):
    """Device-resident per-slot serving registers: (last_tok, out_count,
    budget), all int32[dp, b_local] zeros.

    last_tok feeds the next decode step without a host round-trip;
    out_count/budget drive on-device done-detection (see
    serving.engine._serve_step).  The engine writes budget/out_count at
    admission (host->device set, off the sync path) and the jitted step
    owns them afterwards.
    """
    z = jnp.zeros((dp, b_local), jnp.int32)
    return z, z, z


def load_prefill(cfg, state: DecodeState, caches: Dict[str, Any],
                 prompt_len: int) -> DecodeState:
    """Scatter dense prefill caches into the paged/ring/recurrent state.

    caches: output of ``forward_prefill`` — attention caches are dense
    (k, v) of [n_groups, B, S, KH, hd]; recurrent caches are final
    states.  All B sequences share prompt_len.  Pages come straight
    from each shard's shared pool in one batched
    :func:`hier_pool.alloc_from_shared` grant (bulk admission — a whole
    prompt never fits a 3*ell lane, and this path is off the per-token
    hot path by construction).
    """
    dp, b_local, max_pages = state.page_tables.shape
    psz = cfg.page_size
    n_pages = (prompt_len + psz - 1) // psz
    assert n_pages <= max_pages
    st_kinds = _positions(cfg)

    def split_cache(pos):
        c = caches[pos]
        if cfg.arch_kind == "encdec":
            return c[0]     # (self_cache, cross_kv)
        return c

    def cross_kv(pos):
        return caches[pos][1]

    # --- page allocation: one batched shared-pool grant per shard
    counts = jnp.full((dp, b_local), n_pages, jnp.int32)
    pool, ids = classed_pool.alloc_from_shared_dp(
        state.pool, CLS_KV, counts, max(n_pages, 1))
    assert bool(jnp.all(ids[..., :n_pages] >= 0)), "prefill pool exhausted"
    tables = np.full((dp, b_local, max_pages), -1, np.int32)
    tables[:, :, :n_pages] = np.asarray(ids)[:, :, :n_pages]

    new_kv_pages = {}
    for pos, (kp, vp) in state.kv_pages.items():
        kd, vd = split_cache(pos)                 # [n, B, S, KH, hd]
        n, B, S, KH, hd = kd.shape
        kd = np.asarray(kd).reshape(n, dp, b_local, S, KH, hd)
        vd = np.asarray(vd).reshape(n, dp, b_local, S, KH, hd)
        kp = np.asarray(kp).copy()
        vp = np.asarray(vp).copy()
        pad = n_pages * psz - prompt_len
        if pad:
            z = np.zeros((n, dp, b_local, pad, KH, hd), kd.dtype)
            kd = np.concatenate([kd, z], axis=3)
            vd = np.concatenate([vd, z], axis=3)
        kd = kd.reshape(n, dp, b_local, n_pages, psz, KH, hd)
        vd = vd.reshape(n, dp, b_local, n_pages, psz, KH, hd)
        for d in range(dp):
            for b in range(b_local):
                pids = tables[d, b, :n_pages]
                kp[:, d, pids] = kd[:, d, b]
                vp[:, d, pids] = vd[:, d, b]
        new_kv_pages[pos] = (jnp.asarray(kp), jnp.asarray(vp))

    new_rings = {}
    for pos, (kr, vr) in state.rings.items():
        kd, vd = split_cache(pos)
        n, B, S, KH, hd = kd.shape
        W = kr.shape[3]
        kd = np.asarray(kd).reshape(n, dp, b_local, S, KH, hd)
        vd = np.asarray(vd).reshape(n, dp, b_local, S, KH, hd)
        krn = np.asarray(kr).copy()
        vrn = np.asarray(vr).copy()
        take = min(W, prompt_len)
        src = np.arange(prompt_len - take, prompt_len)
        for s in src:
            krn[:, :, :, s % W] = kd[:, :, :, s]
            vrn[:, :, :, s % W] = vd[:, :, :, s]
        new_rings[pos] = (jnp.asarray(krn), jnp.asarray(vrn))

    new_rec = {}
    for pos, st in state.rec.items():
        c = split_cache(pos)                       # {"h": [n,B,...], "conv":}
        new_rec[pos] = {
            "h": jnp.asarray(np.asarray(c["h"]).reshape(st["h"].shape)),
            "conv": jnp.asarray(np.asarray(c["conv"]).reshape(st["conv"].shape)),
        }

    enc_kv = state.enc_kv
    if cfg.arch_kind == "encdec":
        ks, vs = [], []
        order = [f"pos{j}" for j in range(len(cfg.pattern))]
        rem = [f"rem{j}" for j in range(len(cfg.remainder))]
        for pos in order:
            k, v = cross_kv(pos)                  # [n_groups, B, L, KH, hd]
            ks.append(np.asarray(k))
            vs.append(np.asarray(v))
        # interleave pattern positions back into layer order
        n_layers_grp = len(order) * cfg.n_groups
        kcat = np.stack(ks, axis=1).reshape(n_layers_grp, *ks[0].shape[1:])
        vcat = np.stack(vs, axis=1).reshape(n_layers_grp, *vs[0].shape[1:])
        for pos in rem:
            k, v = cross_kv(pos)
            kcat = np.concatenate([kcat, np.asarray(k)], axis=0)
            vcat = np.concatenate([vcat, np.asarray(v)], axis=0)
        L = kcat.shape[0]
        kcat = kcat.reshape(L, dp, b_local, *kcat.shape[2:])
        vcat = vcat.reshape(L, dp, b_local, *vcat.shape[2:])
        enc_kv = (jnp.asarray(kcat), jnp.asarray(vcat))

    return DecodeState(
        kv_pages=new_kv_pages, rings=new_rings, rec=new_rec,
        page_tables=jnp.asarray(tables),
        seq_lens=jnp.full((dp, b_local), prompt_len, jnp.int32),
        pool=pool,
        enc_kv=enc_kv,
        state_tables=state.state_tables)
