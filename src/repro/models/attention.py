"""Attention: blockwise-streaming (flash semantics in pure jnp), sliding
window, and paged decode.

The blockwise forms never materialize the [S, S] score matrix, so 32k
prefill lowers with bounded memory; FLOPs/bytes in the compiled HLO are
what the roofline reads.  The Pallas kernels in ``repro.kernels`` are the
TPU execution path validated against these same semantics.
"""

from __future__ import annotations

import functools
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from .layers import ParamDef, apply_rope

NEG_INF = -1e30


def attn_defs(cfg) -> Dict[str, ParamDef]:
    d, H, KH, hd, dt = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd, cfg.jdtype
    return {
        "wq": ParamDef((d, H, hd), ("embed", "heads", "head_dim"), dt),
        "wk": ParamDef((d, KH, hd), ("embed", "kv_heads", "head_dim"), dt),
        "wv": ParamDef((d, KH, hd), ("embed", "kv_heads", "head_dim"), dt),
        "wo": ParamDef((H, hd, d), ("heads", "head_dim", "embed"), dt),
    }


def qkv(cfg, params, x, positions=None, rope: bool = True):
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"])
    if rope:
        if positions is None:
            positions = jnp.arange(x.shape[1])[None, :]
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _expand_kv(k, n_heads):
    """GQA: repeat kv heads to match q heads."""
    KH = k.shape[2]
    if KH == n_heads:
        return k
    return jnp.repeat(k, n_heads // KH, axis=2)


def blockwise_attention(
    q: jax.Array, k: jax.Array, v: jax.Array,
    causal: bool = True,
    window: Optional[int] = None,
    block_q: int = 512,
    block_k: int = 1024,
    q_offset: int = 0,
) -> jax.Array:
    """Streaming softmax attention; q,k,v: [B, S, H, hd] (kv pre-expanded).

    Scans over KV blocks with an online-softmax accumulator.  Memory is
    O(block_q * block_k) per step.  Causality/window applied via masks;
    the §Perf iteration adds block skipping (see kernels/ and
    EXPERIMENTS.md).
    """
    B, Sq, H, hd = q.shape
    Sk = k.shape[1]
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    # pad to block multiples; padded KV positions are masked out, padded
    # Q rows are sliced off the output
    pq = (-Sq) % block_q
    pk = (-Sk) % block_k
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
    Sq_p, Sk_p = Sq + pq, Sk + pk
    nq, nk = Sq_p // block_q, Sk_p // block_k
    scale = 1.0 / (hd ** 0.5)

    qb = q.reshape(B, nq, block_q, H, hd).swapaxes(0, 1)      # [nq, B, bq, H, hd]
    kb = k.reshape(B, nk, block_k, H, hd).swapaxes(0, 1)
    vb = v.reshape(B, nk, block_k, H, hd).swapaxes(0, 1)

    q_pos = (q_offset + jnp.arange(Sq_p)).reshape(nq, block_q)
    k_pos = jnp.arange(Sk_p).reshape(nk, block_k)

    @functools.partial(jax.checkpoint, prevent_cse=False)
    def q_block(qi_and_pos):
        # jax.checkpoint => the backward pass recomputes this block's
        # scores instead of saving [B,H,bq,Sk] residuals from the KV scan
        # — flash-attention memory behavior with plain-jnp gradients.
        qi, qpos = qi_and_pos                                  # [B,bq,H,hd], [bq]

        def kv_step(carry, kv):
            m, l, acc = carry
            ki, vi, kpos = kv
            s = jnp.einsum("bqhd,bkhd->bhqk", qi, ki) * scale
            mask = jnp.broadcast_to(kpos[None, :] < Sk, (block_q, block_k))
            if causal:
                mask &= qpos[:, None] >= kpos[None, :]
            if window is not None:
                mask &= qpos[:, None] - kpos[None, :] < window
            s = jnp.where(mask[None, None], s.astype(jnp.float32), NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhqk,bkhd->bhqd", p.astype(qi.dtype), vi).astype(jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, H, block_q), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, H, block_q), jnp.float32)
        a0 = jnp.zeros((B, H, block_q, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), (kb, vb, k_pos))
        out = acc / jnp.maximum(l, 1e-20)[..., None]
        return out.swapaxes(1, 2).astype(q.dtype)              # [B, bq, H, hd]

    out = jax.lax.map(q_block, (qb, q_pos))                    # [nq, B, bq, H, hd]
    out = out.swapaxes(0, 1).reshape(B, Sq_p, H, hd)
    return out[:, :Sq] if pq else out


def local_attention(
    q: jax.Array, k: jax.Array, v: jax.Array,
    window: int,
    block_q: int = 512,
    q_offset: int = 0,
) -> jax.Array:
    """Sliding-window causal attention with FLOPs ~ O(S * window).

    For each q block only the [start, start + window + block_q) KV slice
    is touched (dynamic_slice), so compute and bytes scale with the
    window, not the sequence — this is what makes long_500k affordable
    for SWA/local archs.
    """
    B, Sq, H, hd = q.shape
    Sk = k.shape[1]
    block_q = min(block_q, Sq)
    span = window + block_q
    if span >= Sk or Sq % block_q:
        return blockwise_attention(q, k, v, causal=True, window=window,
                                   q_offset=q_offset)
    nq = Sq // block_q
    scale = 1.0 / (hd ** 0.5)
    qb = q.reshape(B, nq, block_q, H, hd).swapaxes(0, 1)

    @functools.partial(jax.checkpoint, prevent_cse=False)
    def q_block(args):
        i, qi = args
        q0 = q_offset + i * block_q
        start = jnp.clip(q0 - window + 1, 0, Sk - span)
        ks = jax.lax.dynamic_slice(k, (0, start, 0, 0), (B, span, H, hd))
        vs = jax.lax.dynamic_slice(v, (0, start, 0, 0), (B, span, H, hd))
        qpos = q0 + jnp.arange(block_q)
        kpos = start + jnp.arange(span)
        s = jnp.einsum("bqhd,bkhd->bhqk", qi, ks) * scale
        mask = (qpos[:, None] >= kpos[None, :]) & (
            qpos[:, None] - kpos[None, :] < window)
        s = jnp.where(mask[None, None], s.astype(jnp.float32), NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        out = jnp.einsum("bhqk,bkhd->bqhd", p.astype(qi.dtype), vs)
        return out

    out = jax.lax.map(q_block, (jnp.arange(nq), qb))
    return out.swapaxes(0, 1).reshape(B, Sq, H, hd)


def attention_train(cfg, params, x, kind: str, positions=None,
                    causal: bool = True):
    """Full-sequence attention layer application (train/prefill).

    Returns (out, (k, v)) — k/v returned for prefill cache fill.
    """
    q, k, v = qkv(cfg, params, x, positions)
    ke = _expand_kv(k, cfg.n_heads)
    ve = _expand_kv(v, cfg.n_heads)
    if kind == "local" and cfg.window is not None:
        o = local_attention(q, ke, ve, cfg.window)
    else:
        o = blockwise_attention(q, ke, ve, causal=causal)
    out = jnp.einsum("bshk,hkd->bsd", o, params["wo"])
    return out, (k, v)


def cross_attention(cfg, params, x, enc_kv):
    """Decoder cross-attention over (precomputed) encoder K/V."""
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    k, v = enc_kv
    ke = _expand_kv(k, cfg.n_heads)
    ve = _expand_kv(v, cfg.n_heads)
    o = blockwise_attention(q, ke, ve, causal=False)
    return jnp.einsum("bshk,hkd->bsd", o, params["wo"])
