"""Unified decoder stack: dense / GQA / SWA / local-global / MoE / SSD /
RG-LRU / enc-dec / VLM — one scan-over-pattern-groups implementation.

Layer layout comes from ``cfg.pattern`` (see configs/base.py).  Parameters
for the ``n_groups`` full pattern repetitions are stacked on a leading
"layers" axis and consumed by ``jax.lax.scan`` — compile time and HLO
size are independent of depth.  Remainder layers (when n_layers is not a
multiple of the pattern length) are unrolled once.

Three entry points:
  * :func:`forward_train`        — full-sequence, no caches (training loss)
  * :func:`forward_prefill`      — full-sequence, fills the decode state
  * :func:`forward_decode_chunk` — a variable-width token lane (up to T
    tokens per sequence) against the decode state; single-token decode
    is simply a width-1 lane (the only decode entry point — the old
    ``forward_decode`` single-token path is gone)

Decode state (the paper's technique lives here):
  * global-attention layers use **paged KV** (block tables +
    fixed-size pages from the two-level :mod:`repro.core.hier_pool` —
    constant-time alloc/free from per-*slot* private lanes exactly like
    the paper's private pools, with the shared pool behind them and the
    deamortized ``rebalance`` once per engine step);
  * local/SWA layers use fixed-size **ring slabs** (bounded state needs
    no paging — it is a fixed-size block handed out at admission);
  * SSD / RG-LRU layers carry fixed-size recurrent state slabs.

Decode batch layout is [DP, B_local, ...] with DP sharded over the data
(and pod) mesh axes; every cache gather/scatter is vmapped over DP so
page ids stay shard-local (no cross-shard gathers — see DESIGN.md §5).
"""

from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import base_kind, is_moe_kind
from ..core import block_pool, classed_pool, hier_pool
from ..core.classed_pool import CLS_KV, ClassSpec
from ..kernels.paged_attention.ops import paged_attention_chunk
from ..kernels.verify_attention.ops import verify_attention
from ..parallel.partition import constrain_batch
from . import attention as attn
from . import moe as moe_mod
from . import rglru as rglru_mod
from . import ssm as ssm_mod
from .layers import (ParamDef, apply_norm, apply_rope, embed_apply,
                     embed_defs, ffn_apply, ffn_defs, norm_defs)


# ===================================================================== defs

def _stack(defs, n: int):
    return jax.tree.map(
        lambda d: ParamDef((n,) + d.shape, ("layers",) + d.axes, d.dtype, d.init),
        defs, is_leaf=lambda x: isinstance(x, ParamDef))


def layer_defs(cfg, kind: str, with_xattn: bool = False) -> Dict[str, Any]:
    bk = base_kind(kind)
    d: Dict[str, Any] = {"norm1": norm_defs(cfg)}
    if bk in ("global", "local"):
        d["attn"] = attn.attn_defs(cfg)
    elif bk == "ssd":
        d["ssd"] = ssm_mod.ssd_defs(cfg)
    elif bk == "rglru":
        d["rglru"] = rglru_mod.rglru_defs(cfg)
    else:
        raise ValueError(kind)
    if with_xattn:
        d["norm_x"] = norm_defs(cfg)
        d["xattn"] = attn.attn_defs(cfg)
    if bk != "ssd" and cfg.d_ff:
        d["norm2"] = norm_defs(cfg)
        d["ffn"] = moe_mod.moe_defs(cfg) if is_moe_kind(kind) else ffn_defs(cfg)
    return d


def model_defs(cfg) -> Dict[str, Any]:
    is_dec_of_encdec = cfg.arch_kind == "encdec"
    defs: Dict[str, Any] = {"embed": embed_defs(cfg)}
    fn = norm_defs(cfg)
    if fn:
        defs["final_norm"] = fn
    group = {f"pos{j}": layer_defs(cfg, k, with_xattn=is_dec_of_encdec)
             for j, k in enumerate(cfg.pattern)}
    if cfg.n_groups:
        defs["groups"] = _stack(group, cfg.n_groups)
    if cfg.remainder:
        defs["rem"] = {
            f"pos{j}": layer_defs(cfg, k, with_xattn=is_dec_of_encdec)
            for j, k in enumerate(cfg.remainder)}
    if is_dec_of_encdec:
        enc_layer = layer_defs(cfg, "global")
        defs["encoder"] = _stack(enc_layer, cfg.enc_layers)
        if fn:
            defs["enc_final_norm"] = norm_defs(cfg)
    return defs


# ================================================================ train path

def _mix_train(cfg, lp, x, kind, enc_out=None, causal=True):
    """One layer (full sequence).  Returns (x, cache) where cache is
    (k, v) for attention layers or the final recurrent state tree."""
    kind = base_kind(kind)
    h = apply_norm(cfg, lp["norm1"], x)
    if kind in ("global", "local"):
        o, cache = attn.attention_train(cfg, lp["attn"], h, kind, causal=causal)
    elif kind == "ssd":
        o, (hn, cn) = ssm_mod.ssd_block_apply(cfg, lp["ssd"], h)
        cache = {"h": hn, "conv": cn}
    else:
        o, (hn, cn) = rglru_mod.rglru_block_apply(cfg, lp["rglru"], h)
        cache = {"h": hn, "conv": cn}
    x = x + o
    if "xattn" in lp and enc_out is not None:
        hx = apply_norm(cfg, lp["norm_x"], x)
        k = jnp.einsum("bsd,dhk->bshk", enc_out, lp["xattn"]["wk"])
        v = jnp.einsum("bsd,dhk->bshk", enc_out, lp["xattn"]["wv"])
        x = x + attn.cross_attention(cfg, lp["xattn"], hx, (k, v))
        cache = (cache, (k, v))
    if "ffn" in lp:
        h2 = apply_norm(cfg, lp["norm2"], x)
        f = (moe_mod.moe_apply(cfg, lp["ffn"], h2) if "router" in lp["ffn"]
             else ffn_apply(cfg, lp["ffn"], h2))
        x = x + f
    return x, cache


def _encoder_apply(cfg, params, enc_embeds):
    """Whisper-style encoder over stubbed frame embeddings."""
    @functools.partial(jax.checkpoint, prevent_cse=False)
    def body(x, lp):
        x = constrain_batch(x)
        x, _ = _mix_train(cfg, lp, x, "global", causal=False)
        return x, None
    x, _ = jax.lax.scan(body, enc_embeds, params["encoder"])
    if "enc_final_norm" in params:
        x = apply_norm(cfg, params["enc_final_norm"], x)
    return x


def forward_train(cfg, params, tokens, extra: Optional[Dict] = None,
                  remat: bool = True):
    """tokens [B, S] (+ extra embeds for vlm/encdec) -> hidden [B, S', d]."""
    x = embed_apply(params["embed"], tokens).astype(cfg.jdtype)
    if cfg.arch_kind == "vlm" and extra and "img_embeds" in extra:
        x = jnp.concatenate(
            [extra["img_embeds"].astype(cfg.jdtype), x], axis=1)
    x = constrain_batch(x)
    enc_out = None
    if cfg.arch_kind == "encdec":
        enc_out = _encoder_apply(
            cfg, params, extra["enc_embeds"].astype(cfg.jdtype))
        enc_out = constrain_batch(enc_out)

    def group_body(x, gparams):
        x = constrain_batch(x)
        for j, kind in enumerate(cfg.pattern):
            x, _ = _mix_train(cfg, gparams[f"pos{j}"], x, kind, enc_out)
        return constrain_batch(x), None

    body = group_body
    if remat:
        body = jax.checkpoint(group_body, prevent_cse=False)
    if cfg.n_groups:
        x, _ = jax.lax.scan(body, x, params["groups"])
    for j, kind in enumerate(cfg.remainder):
        x, _ = _mix_train(cfg, params["rem"][f"pos{j}"], x, kind, enc_out)
    if "final_norm" in params:
        x = apply_norm(cfg, params["final_norm"], x)
    elif cfg.norm == "ln_nonparam":
        from .layers import ln_nonparam
        x = ln_nonparam(x)
    return x


def forward_prefill(cfg, params, tokens, extra: Optional[Dict] = None):
    """Full-sequence forward that also returns per-layer caches.

    Returns (hidden [B,S,d], caches) — caches is a dict:
      {"pos{j}": stacked-over-groups cache, "rem{j}": cache, "enc_out": ...}
    Attention caches are dense [n_groups, B, S, KH, hd] K/V (the serving
    engine paginates them into pages allocated by the block allocator).
    """
    x = embed_apply(params["embed"], tokens).astype(cfg.jdtype)
    if cfg.arch_kind == "vlm" and extra and "img_embeds" in extra:
        x = jnp.concatenate([extra["img_embeds"].astype(cfg.jdtype), x], axis=1)
    x = constrain_batch(x)
    enc_out = None
    if cfg.arch_kind == "encdec":
        enc_out = _encoder_apply(cfg, params,
                                 extra["enc_embeds"].astype(cfg.jdtype))
        enc_out = constrain_batch(enc_out)

    def group_body(x, gparams):
        x = constrain_batch(x)
        caches = {}
        for j, kind in enumerate(cfg.pattern):
            x, c = _mix_train(cfg, gparams[f"pos{j}"], x, kind, enc_out)
            caches[f"pos{j}"] = c
        return constrain_batch(x), caches

    caches = {}
    if cfg.n_groups:
        x, caches = jax.lax.scan(group_body, x, params["groups"])
    for j, kind in enumerate(cfg.remainder):
        x, c = _mix_train(cfg, params["rem"][f"pos{j}"], x, kind, enc_out)
        caches[f"rem{j}"] = jax.tree.map(lambda a: a[None], c)
    if enc_out is not None:
        caches["enc_out"] = enc_out
    if "final_norm" in params:
        x = apply_norm(cfg, params["final_norm"], x)
    elif cfg.norm == "ln_nonparam":
        from .layers import ln_nonparam
        x = ln_nonparam(x)
    return x, caches


# ============================================================== decode state

class DecodeState(NamedTuple):
    """All per-sequence serving state, [DP, B_local, ...] layouts.

    kv_pages:    dict pos -> (k, v) [n_stack, DP, pages_local, psz, KH, hd]
    rings:       dict pos -> (k, v) [n_stack, DP, Bl, W, KH, hd]
    rec:         dict pos -> pytree of recurrent states [n_stack, DP, Bl, ...]
    page_tables: int32 [DP, Bl, max_pages]   (shared by all paged layers)
    seq_lens:    int32 [DP, Bl]
    pool:        ClassedPool with leading-[DP] leaves per class — class
                 CLS_KV (always present) backs the paged KV with
                 per-slot private lanes of capacity 3*ell over a
                 per-shard shared stack; a two-class config adds the
                 fine CLS_STATE class accounting for bounded per-slot
                 state (ring windows, recurrent state, encoder KV) at
                 small-page granularity (ids shard-local AND
                 class-local; all mutation via classed_pool.*)
    enc_kv:      optional (k, v) [n_enc_stack?, ...] cross-attn KV (encdec)
    state_tables: optional int32 [DP, Bl, state_blocks_per_slot] — the
                 CLS_STATE block grants backing each slot's bounded
                 state (granted at admission, freed at release); None
                 in a single-class config
    expert_pages: optional [DP, NB2, d_model*d_ff] — CLS_EXPERT page
                 payloads (expert-paged MoE, DESIGN.md §15): each page
                 holds exactly one expert weight matrix, flat.  Written
                 only by the host-side expert loader; the jitted step
                 reads them via gathers (read-only shared pages).
    expert_tables: optional dict pos -> int32 [stack, DP, E, EXPERT_PPE]
                 — page ids of each MoE layer slot's experts (w_gate,
                 w_up, w_down), NULL for non-resident.  Mutated only by
                 the host-side ledger (load/evict); None when expert
                 paging is off
    """
    kv_pages: Dict[str, Tuple[jax.Array, jax.Array]]
    rings: Dict[str, Tuple[jax.Array, jax.Array]]
    rec: Dict[str, Any]
    page_tables: jax.Array
    seq_lens: jax.Array
    pool: classed_pool.ClassedPool
    enc_kv: Any
    state_tables: Any = None
    expert_pages: Any = None
    expert_tables: Any = None


#: pages per expert in the CLS_EXPERT class: one page per weight matrix
#: (w_gate, w_up, w_down), each exactly d_model*d_ff elements flat.
EXPERT_PPE = 3


def moe_positions(cfg) -> Tuple[list, list]:
    """(pattern MoE positions, remainder MoE positions) that carry an
    expert FFN — the layer slots the expert page tables index."""
    pat = [f"pos{j}" for j, k in enumerate(cfg.pattern)
           if is_moe_kind(k) and base_kind(k) != "ssd" and cfg.d_ff]
    rem = [f"rem{j}" for j, k in enumerate(cfg.remainder)
           if is_moe_kind(k) and base_kind(k) != "ssd" and cfg.d_ff]
    return pat, rem


def expert_layer_slots(cfg) -> int:
    """Total MoE layer slots = scanned groups x pattern MoE positions +
    remainder MoE positions (each slot owns E experts x EXPERT_PPE
    potential pages)."""
    pat, rem = moe_positions(cfg)
    return cfg.n_groups * len(pat) + len(rem)


def _positions(cfg) -> Dict[str, list]:
    """Map pattern position -> layer-state kind ('paged'|'ring'|'rec')."""
    kinds = {}
    for j, k in enumerate(cfg.pattern):
        bk = base_kind(k)
        if bk == "global":
            kinds[f"pos{j}"] = "paged"
        elif bk == "local":
            kinds[f"pos{j}"] = "ring"
        else:
            kinds[f"pos{j}"] = "rec"
    return kinds


def pool_ell(cfg, chunk: Optional[int] = None) -> int:
    """Lane batch size: ell >= the max pages one chunk can demand
    (ceil(chunk / page_size)), so the §4.2 never-dry invariant holds by
    construction — a slot's private lane always covers the next step's
    worst-case demand between rebalances."""
    chunk = chunk if chunk is not None else 2 * cfg.page_size
    return max(-(-int(chunk) // cfg.page_size), 2)


def state_page_tokens(cfg) -> int:
    """Granularity (token-capacity units) of the fine CLS_STATE class —
    a quarter KV page.  The class-boundary heuristic from the PAPERS.md
    reallocation analyses: small enough that bounded state (ring
    windows, recurrent blocks, encoder KV) stops rounding up to whole
    KV pages, large enough that the class's lane/table overhead stays
    negligible (DESIGN.md §14 routing table)."""
    return max(1, cfg.page_size // 4)


def state_blocks_per_slot(cfg, max_len: int) -> int:
    """CLS_STATE blocks one slot's bounded state occupies, at
    :func:`state_page_tokens` granularity.

    Rings charge their window per ring layer, recurrent layers one
    block each (fixed-size state), encoder KV its enc_len per decoder
    layer.  This is the accounting plane for state that is physically
    dense slot-indexed slabs: the grants are real allocator traffic
    (conservation-checked, §4.2-proven per class) so admission and
    occupancy meter bounded state at its own granularity instead of
    rounding up to KV pages — the §10 over-allocation the size-classed
    bench measures."""
    psz_s = state_page_tokens(cfg)
    blocks = 0
    kinds = _positions(cfg)
    W = min(cfg.window or max_len, max_len)
    for j, _ in enumerate(cfg.pattern):
        kind = kinds[f"pos{j}"]
        if kind == "ring":
            blocks += cfg.n_groups * -(-W // psz_s)
        elif kind == "rec":
            blocks += cfg.n_groups
    for k in cfg.remainder:
        bk = base_kind(k)
        if bk == "local":
            blocks += -(-W // psz_s)
        elif bk not in ("global",):
            blocks += 1
    if cfg.arch_kind == "encdec":
        stack = cfg.n_groups + len(cfg.remainder)
        blocks += stack * -(-cfg.enc_len // psz_s)
    return blocks


def pool_class_specs(cfg, b_local: int, max_len: int,
                     chunk: Optional[int] = None,
                     size_classes: int = 1,
                     expert_budget: Optional[int] = None
                     ) -> Tuple[ClassSpec, ...]:
    """The static class vector (DESIGN.md §14/§15), sized per class.

    Class 0 (CLS_KV) is the coarse paged-KV class: the pre-classed
    single-pool sizing verbatim — worst-case live pages for every local
    slot at max length PLUS fully-stocked lanes (3*ell per slot), the
    §4.2 slack.  With ``size_classes >= 2``, class 1 (CLS_STATE) is the
    fine bounded-state class with the same per-class slack rule at its
    own granularity and demand (``state_blocks_per_slot``).  With
    ``size_classes >= 3``, class 2 (CLS_EXPERT) is the read-only
    expert-weight class: ``expert_budget`` pages of ``d_model * d_ff``
    elements each (default: full residency — every expert of every MoE
    layer slot), plus the same 3*ell slack so the per-class §4.2
    argument holds verbatim.
    """
    psz = cfg.page_size
    max_pages = max(max_len // psz, 1)
    ell0 = pool_ell(cfg, chunk)
    specs = [ClassSpec(page_size=psz,
                       num_blocks=b_local * max_pages + 3 * ell0 * b_local,
                       num_lanes=b_local, ell=ell0)]
    if size_classes >= 2:
        sbs = state_blocks_per_slot(cfg, max_len)
        ell1 = 2       # in-step demand is frees only; keep the floor
        specs.append(ClassSpec(
            page_size=state_page_tokens(cfg),
            num_blocks=b_local * sbs + 3 * ell1 * b_local,
            num_lanes=b_local, ell=ell1))
    if size_classes >= 3:
        if expert_budget is None:
            expert_budget = (expert_layer_slots(cfg)
                             * cfg.moe.num_experts * EXPERT_PPE)
        ell2 = 2       # loads/evictions are host-paced, not in-step
        specs.append(ClassSpec(
            page_size=cfg.d_model * cfg.d_ff,
            num_blocks=int(expert_budget) + 3 * ell2 * b_local,
            num_lanes=b_local, ell=ell2))
    return tuple(specs)


def decode_state_defs(cfg, dp: int, b_local: int, max_len: int,
                      chunk: Optional[int] = None,
                      size_classes: int = 1,
                      expert_budget: Optional[int] = None):
    """ShapeDtypeStruct tree for the decode state (dry-run input).

    ``chunk`` is the serving engine's max tokens per step per sequence;
    it sizes the private-lane batch ``ell`` (see :func:`pool_ell`).
    ``size_classes`` sets the allocation-plane class vector
    (:func:`pool_class_specs`): 1 = the single coarse KV class
    (bit-identical to the pre-classed plane), 2 adds the fine
    bounded-state class and the ``state_tables`` register, 3 adds the
    read-only CLS_EXPERT class with its page payloads and per-MoE-layer
    expert tables (``expert_budget`` pages; DESIGN.md §15).
    """
    psz = cfg.page_size
    KH, hd = cfg.n_kv_heads, cfg.hd
    dt = cfg.jdtype
    ng = cfg.n_groups
    max_pages = max(max_len // psz, 1)
    specs = pool_class_specs(cfg, b_local, max_len, chunk, size_classes,
                             expert_budget)
    # per-shard KV page pool: enough for all local sequences at max
    # length PLUS fully-stocked lanes (3*ell per slot) — so rebalance
    # can keep every lane at >= ell free blocks even at peak occupancy
    pages_local = specs[CLS_KV].num_blocks
    kv_pages, rings, rec = {}, {}, {}

    def entry(pos, kind, stack):
        if kind == "paged":
            shp = (stack, dp, pages_local, psz, KH, hd)
            kv_pages[pos] = (jax.ShapeDtypeStruct(shp, dt),
                             jax.ShapeDtypeStruct(shp, dt))
        elif kind == "ring":
            W = min(cfg.window or max_len, max_len)
            shp = (stack, dp, b_local, W, KH, hd)
            rings[pos] = (jax.ShapeDtypeStruct(shp, dt),
                          jax.ShapeDtypeStruct(shp, dt))
        else:
            j = int(pos[3:])
            kind_name = (cfg.pattern + cfg.remainder)[j] if pos.startswith("pos") else None
            rec[pos] = _rec_state_defs(cfg, kind_name, stack, dp, b_local)

    for j, k in enumerate(cfg.pattern):
        entry(f"pos{j}", _positions(cfg)[f"pos{j}"], ng)
    for j, k in enumerate(cfg.remainder):
        pos = f"rem{j}"
        k = base_kind(k)
        if k == "global":
            shp = (1, dp, pages_local, psz, KH, hd)
            kv_pages[pos] = (jax.ShapeDtypeStruct(shp, dt),) * 2
        elif k == "local":
            W = min(cfg.window or max_len, max_len)
            shp = (1, dp, b_local, W, KH, hd)
            rings[pos] = (jax.ShapeDtypeStruct(shp, dt),) * 2
        else:
            rec[pos] = _rec_state_defs(cfg, k, 1, dp, b_local)

    enc_kv = None
    if cfg.arch_kind == "encdec":
        shp = (cfg.n_groups + len(cfg.remainder), dp, b_local,
               cfg.enc_len, cfg.n_kv_heads, cfg.hd)
        enc_kv = (jax.ShapeDtypeStruct(shp, dt), jax.ShapeDtypeStruct(shp, dt))

    def class_def(s: ClassSpec):
        return hier_pool.HierPool(
            shared=block_pool.BlockPool(
                free_ids=jax.ShapeDtypeStruct((dp, s.num_blocks), jnp.int32),
                top=jax.ShapeDtypeStruct((dp,), jnp.int32),
                refcount=jax.ShapeDtypeStruct((dp, s.num_blocks), jnp.int16)),
            private_ids=jax.ShapeDtypeStruct(
                (dp, s.num_lanes, 3 * s.ell), jnp.int32),
            private_top=jax.ShapeDtypeStruct((dp, s.num_lanes), jnp.int32))

    pool = classed_pool.ClassedPool(
        classes=tuple(class_def(s) for s in specs))
    state_tables = None
    if size_classes >= 2:
        sbs = max(state_blocks_per_slot(cfg, max_len), 1)
        state_tables = jax.ShapeDtypeStruct((dp, b_local, sbs), jnp.int32)
    expert_pages = expert_tables = None
    if size_classes >= 3:
        pe = cfg.d_model * cfg.d_ff
        E = cfg.moe.num_experts
        expert_pages = jax.ShapeDtypeStruct(
            (dp, specs[2].num_blocks, pe), dt)
        pat_moe, rem_moe = moe_positions(cfg)
        expert_tables = {}
        for pos in pat_moe:
            expert_tables[pos] = jax.ShapeDtypeStruct(
                (ng, dp, E, EXPERT_PPE), jnp.int32)
        for pos in rem_moe:
            expert_tables[pos] = jax.ShapeDtypeStruct(
                (1, dp, E, EXPERT_PPE), jnp.int32)

    return DecodeState(
        kv_pages=kv_pages, rings=rings, rec=rec,
        page_tables=jax.ShapeDtypeStruct((dp, b_local, max_pages), jnp.int32),
        seq_lens=jax.ShapeDtypeStruct((dp, b_local), jnp.int32),
        pool=pool,
        enc_kv=enc_kv,
        state_tables=state_tables,
        expert_pages=expert_pages,
        expert_tables=expert_tables,
    )


def _rec_state_defs(cfg, kind, stack, dp, b_local):
    if kind == "ssd":
        di = cfg.ssd_expand * cfg.d_model
        H = di // cfg.ssd_head_dim
        return {
            "h": jax.ShapeDtypeStruct(
                (stack, dp, b_local, H, cfg.ssd_head_dim, cfg.ssd_state),
                jnp.float32),
            "conv": jax.ShapeDtypeStruct(
                (stack, dp, b_local, 3, di + 2 * cfg.ssd_state), cfg.jdtype),
        }
    return {
        "h": jax.ShapeDtypeStruct(
            (stack, dp, b_local, cfg.d_model), jnp.float32),
        "conv": jax.ShapeDtypeStruct(
            (stack, dp, b_local, cfg.rglru_conv - 1, cfg.d_model), cfg.jdtype),
    }


# ======================================================= chunked decode path

def _paged_write_chunk(k_pages, v_pages, k_new, v_new, page_ids, pos_in_page,
                       write):
    """k_pages: [DP, P, psz, KH, hd]; k_new: [DP, Bl, T, KH, hd];
    page_ids/pos_in_page/write: [DP, Bl, T].  One scatter of Bl*T tokens
    per shard; masked tokens are dropped (out-of-range page index)."""
    P = k_pages.shape[1]
    pid = jnp.where(write, page_ids, P)

    def one(kp, vp, kn, vn, pid, pip):
        kp = kp.at[pid, pip].set(kn.astype(kp.dtype), mode="drop")
        vp = vp.at[pid, pip].set(vn.astype(vp.dtype), mode="drop")
        return kp, vp

    return jax.vmap(one)(k_pages, v_pages, k_new, v_new, pid, pos_in_page)


def _paged_attn_chunk(q, k_pages, v_pages, tables, base, verify=False):
    """q: [DP, Bl, T, H, hd]; pages: [DP, P, psz, KH, hd]; base: [DP, Bl].

    Folds DP into the kernel batch (shard-local page ids offset by d*P)
    so one pallas_call / ref call covers all shards — no vmap over the
    kernel.  Dispatches the Pallas chunk kernel on TPU, jnp ref elsewhere.
    verify=True routes through the page-grouped verify-attention
    schedule (kernels/verify_attention) — bit-identical math, but each
    hot shared page is streamed from HBM once for all draft lanes
    reading it instead of once per lane.
    """
    DP, Bl, T, H, hd = q.shape
    P = k_pages.shape[1]
    maxp = tables.shape[2]
    off = (jnp.arange(DP, dtype=jnp.int32) * P)[:, None, None]
    tg = jnp.where(tables >= 0, tables + off, -1).reshape(DP * Bl, maxp)
    kg = k_pages.reshape((DP * P,) + k_pages.shape[2:])
    vg = v_pages.reshape((DP * P,) + v_pages.shape[2:])
    op = verify_attention if verify else paged_attention_chunk
    o = op(q.reshape(DP * Bl, T, H, hd), kg, vg, tg, base.reshape(DP * Bl))
    return o.reshape(DP, Bl, T, H, hd)


def _ring_write_chunk(k_ring, v_ring, k_new, v_new, positions, tok_valid,
                      lens):
    """Write a chunk into the rings.  positions/tok_valid: [DP, Bl, T].

    Only the last W valid tokens of a chunk can survive in a ring of
    size W; masking the rest out also removes duplicate-slot scatters
    when T > W."""
    DP, Bl, W = k_ring.shape[:3]
    T = k_new.shape[2]
    t = jnp.arange(T)[None, None, :]
    write = tok_valid & (t >= lens[..., None] - W)
    slot = jnp.where(write, positions % W, W)
    dp_i = jnp.arange(DP)[:, None, None]
    bl_i = jnp.arange(Bl)[None, :, None]
    k_ring = k_ring.at[dp_i, bl_i, slot].set(
        k_new.astype(k_ring.dtype), mode="drop")
    v_ring = v_ring.at[dp_i, bl_i, slot].set(
        v_new.astype(v_ring.dtype), mode="drop")
    return k_ring, v_ring


def _ring_attn_chunk(cfg, q, k_ring, v_ring, k_chunk, v_chunk, base, lens):
    """Chunked sliding-window attention over ring + in-chunk K/V.

    q: [DP, Bl, T, H, hd]; ring: [DP, Bl, W, KH, hd] holding the
    PRE-chunk content; k/v_chunk: [DP, Bl, T, KH, hd]; base/lens:
    [DP, Bl].  Query t (absolute position base + t) attends to ring
    tokens (absolute <= base - 1) and chunk tokens t' <= t, both
    windowed.  Attention runs before the ring is overwritten so early
    queries still see tokens that later chunk tokens will evict.
    """
    DP, Bl, T, H, hd = q.shape
    W = k_ring.shape[2]
    win = cfg.window or W
    r = jnp.arange(W)
    last = base - 1
    # absolute position currently stored in ring slot r (<= base - 1)
    abs_ring = r[None, None] + W * ((last[..., None] - r[None, None]) // W)
    t_idx = jnp.arange(T)
    qpos = base[..., None] + t_idx                               # [DP,Bl,T]
    valid_ring = ((abs_ring[:, :, None, :] >= 0) &
                  (abs_ring[:, :, None, :] > qpos[..., None] - win))
    tp = t_idx[None, None, None, :]
    tq = t_idx[None, None, :, None]
    valid_chunk = ((tp <= tq) & (tp < lens[..., None, None]) &
                   (tq - tp < win))
    valid = jnp.concatenate(
        [valid_ring, jnp.broadcast_to(valid_chunk, (DP, Bl, T, T))], axis=3)
    k = jnp.concatenate([k_ring, k_chunk.astype(k_ring.dtype)], axis=2)
    v = jnp.concatenate([v_ring, v_chunk.astype(v_ring.dtype)], axis=2)
    ke = attn._expand_kv(k.reshape(DP * Bl, W + T, -1, hd), H)
    ve = attn._expand_kv(v.reshape(DP * Bl, W + T, -1, hd), H)
    qf = q.reshape(DP * Bl, T, H, hd)
    s = jnp.einsum("bthd,bkhd->bhtk", qf, ke) / (hd ** 0.5)
    vm = valid.reshape(DP * Bl, 1, T, W + T)
    s = jnp.where(vm, s.astype(jnp.float32), attn.NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.any(vm, axis=-1, keepdims=True), p, 0.0)
    o = jnp.einsum("bhtk,bkhd->bthd", p.astype(q.dtype), ve)
    return o.reshape(DP, Bl, T, H, hd)


def _xattn_decode_chunk(cfg, lp, x, enc_kv_layer):
    """Cross-attention for a chunk of decode tokens.

    x: [DP, Bl, T, d]; enc_kv: [DP, Bl, L, KH, hd] (not causal)."""
    DP, Bl, T, d = x.shape
    h = apply_norm(cfg, lp["norm_x"], x)
    q = jnp.einsum("xbtd,dhk->xbthk", h, lp["xattn"]["wq"])
    k, v = enc_kv_layer
    ke = attn._expand_kv(k.reshape(DP * Bl, cfg.enc_len, -1, cfg.hd),
                         cfg.n_heads)
    ve = attn._expand_kv(v.reshape(DP * Bl, cfg.enc_len, -1, cfg.hd),
                         cfg.n_heads)
    qf = q.reshape(DP * Bl, T, cfg.n_heads, cfg.hd)
    s = jnp.einsum("bthd,bkhd->bhtk", qf, ke) / (cfg.hd ** 0.5)
    p = jax.nn.softmax(s.astype(jnp.float32), axis=-1)
    o = jnp.einsum("bhtk,bkhd->bthd", p.astype(x.dtype), ve)
    o = o.reshape(DP, Bl, T, cfg.n_heads, cfg.hd)
    return x + jnp.einsum("xbthk,hkd->xbtd", o, lp["xattn"]["wo"])


def _mix_decode_chunk(cfg, lp, x, kind, st_kind, layer_state, state,
                      positions, tok_valid, base, lens, enc_kv_layer=None,
                      verify=False, expert_buf=None, expert_mask=None):
    """One layer over a chunk of up to T tokens per sequence.

    x: [DP, Bl, T, d].  Attention layers process the chunk in parallel
    (pages / ring written once, one chunk-attention call); recurrent
    layers scan the chunk sequentially with per-token state gating so
    ragged tails stay inert.  Returns (x, new_layer_state, moe_meta):
    ``moe_meta`` is None for non-MoE layers, else ``(dropped [DP],
    routed [DP, E])`` — capacity-dropped valid assignments per shard and
    valid kept assignments per expert (the §15 meters).

    ``expert_buf`` ([E, EXPERT_PPE, d*d_ff], shard-local, DP == 1) is
    the prefetched CLS_EXPERT page gather for this layer's experts; when
    given, the MoE FFN runs on weights reconstructed from it instead of
    resident ``lp["ffn"]`` matrices — the SAME compute path on the same
    values, so paged and resident serving are bit-identical.
    ``expert_mask`` (bool [DP, Bl, E]) is the admitted expert footprint.
    """
    DP, Bl, T, d = x.shape
    kind = base_kind(kind)
    h = apply_norm(cfg, lp["norm1"], x)
    if kind in ("global", "local"):
        hf = h.reshape(DP * Bl, T, d)
        pos_flat = positions.reshape(DP * Bl, T)
        q = jnp.einsum("bsd,dhk->bshk", hf, lp["attn"]["wq"])
        k = jnp.einsum("bsd,dhk->bshk", hf, lp["attn"]["wk"])
        v = jnp.einsum("bsd,dhk->bshk", hf, lp["attn"]["wv"])
        q = apply_rope(q, pos_flat, cfg.rope_theta)
        k = apply_rope(k, pos_flat, cfg.rope_theta)
        qd = q.reshape(DP, Bl, T, cfg.n_heads, cfg.hd)
        kd = k.reshape(DP, Bl, T, cfg.n_kv_heads, cfg.hd)
        vd = v.reshape(DP, Bl, T, cfg.n_kv_heads, cfg.hd)
        if st_kind == "paged":
            kp, vp = layer_state
            psz = cfg.page_size
            maxp = state.page_tables.shape[2]
            pid = jnp.take_along_axis(
                state.page_tables, jnp.minimum(positions // psz, maxp - 1),
                axis=2)
            write = tok_valid & (pid >= 0)
            kp, vp = _paged_write_chunk(kp, vp, kd, vd, pid,
                                        positions % psz, write)
            o = _paged_attn_chunk(qd, kp, vp, state.page_tables, base,
                                  verify=verify)
            new_state = (kp, vp)
        else:
            kr, vr = layer_state
            o = _ring_attn_chunk(cfg, qd, kr, vr, kd, vd, base, lens)
            kr, vr = _ring_write_chunk(kr, vr, kd, vd, positions, tok_valid,
                                       lens)
            new_state = (kr, vr)
        x = x + jnp.einsum("xbthk,hkd->xbtd", o, lp["attn"]["wo"])
    else:  # ssd / rglru — sequential recurrence, scanned over the chunk
        def tok_body(st, inp):
            ht, valid_t = inp                      # [DP,Bl,d], [DP,Bl]
            if kind == "ssd":
                o, (hn, cn) = ssm_mod.ssd_block_apply(
                    cfg, lp["ssd"], ht.reshape(DP * Bl, 1, d),
                    h0=st["h"].reshape(DP * Bl, *st["h"].shape[2:]),
                    conv0=st["conv"].reshape(DP * Bl, *st["conv"].shape[2:]),
                    decode=True)
            else:
                o, (hn, cn) = rglru_mod.rglru_block_apply(
                    cfg, lp["rglru"], ht.reshape(DP * Bl, 1, d),
                    h0=st["h"].reshape(DP * Bl, d),
                    conv0=st["conv"].reshape(DP * Bl, *st["conv"].shape[2:]),
                    decode=True)
            new_st = {"h": hn.reshape(DP, Bl, *hn.shape[1:]),
                      "conv": cn.reshape(DP, Bl, *cn.shape[1:])}

            def g(nw, old):
                m = valid_t.reshape((DP, Bl) + (1,) * (nw.ndim - 2))
                return jnp.where(m, nw, old)

            new_st = jax.tree.map(g, new_st, st)
            return new_st, o[:, 0].reshape(DP, Bl, d)

        new_state, o_seq = jax.lax.scan(
            tok_body, layer_state,
            (h.transpose(2, 0, 1, 3), tok_valid.transpose(2, 0, 1)))
        x = x + o_seq.transpose(1, 2, 0, 3)

    if "xattn" in lp and enc_kv_layer is not None:
        x = _xattn_decode_chunk(cfg, lp, x, enc_kv_layer)

    moe_meta = None
    if "ffn" in lp:
        h2 = apply_norm(cfg, lp["norm2"], x)
        h2f = h2.reshape(DP * Bl, T, d)
        if "router" in lp["ffn"]:
            E = cfg.moe.num_experts
            eff = lp["ffn"]
            if expert_buf is not None:
                # paged experts: rebuild the stacked [E, ...] weight
                # views from the gathered CLS_EXPERT pages and run the
                # IDENTICAL dispatch path.  Non-resident experts gather
                # page 0 (finite garbage) — the footprint mask keeps
                # every valid token off them, and dropped/invalid rows
                # contribute exactly 0 by the dispatch masking.
                ff = cfg.d_ff
                eff = {
                    "router": lp["ffn"]["router"],
                    "w_gate": expert_buf[:, 0].reshape(E, d, ff),
                    "w_up": expert_buf[:, 1].reshape(E, d, ff),
                    "w_down": expert_buf[:, 2].reshape(E, ff, d),
                }
            mask = (None if expert_mask is None
                    else expert_mask.reshape(DP * Bl, E))
            f, dropped, routed = moe_mod.moe_apply(
                cfg, eff, h2f, expert_mask=mask,
                token_valid=tok_valid.reshape(DP * Bl, T), metered=True)
            moe_meta = (dropped.reshape(DP, Bl).sum(axis=1),
                        routed.reshape(DP, Bl, E).sum(axis=1))
        else:
            f = ffn_apply(cfg, lp["ffn"], h2f)
        x = x + f.reshape(DP, Bl, T, d)
    return x, new_state, moe_meta


def _gather_expert_pages(pages, tab):
    """Gather one MoE layer slot's expert weights off the CLS_EXPERT
    pages: pages [DP, NB2, pe] (DP == 1), tab int32 [DP, E, EXPERT_PPE]
    -> [E, EXPERT_PPE, pe].  NULL entries clamp to page 0 — finite
    garbage the footprint mask keeps every valid token away from."""
    p = pages[0]
    return p[jnp.clip(tab[0], 0, p.shape[0] - 1)]


def forward_decode_chunk(cfg, params, tokens, state: DecodeState, lens,
                         active=None, verify=False, expert_mask=None):
    """Chunked decode/prefill: up to T tokens per sequence per call.

    tokens: int32 [DP, Bl, T]; lens: int32 [DP, Bl] — valid tokens per
    sequence this call (ragged tails are inert: not written to any
    cache, recurrent state gated per token).  Returns (hidden
    [DP, Bl, T, d], new DecodeState, fwd_meta) with seq_lens advanced
    by lens; ``fwd_meta`` is a dict of int32[DP] MoE meters
    (``moe_dropped``, ``expert_hit_pages``, ``expert_miss_pages``,
    ``expert_prefetch_pages``) — all zeros for non-MoE configs.

    ``expert_mask`` (bool [DP, Bl, E], optional) restricts each slot's
    routing to its admitted expert footprint (applied at every MoE
    router — paged OR resident, so the two modes stay token-identical).
    When ``state.expert_tables`` is set (expert-paged serving), each
    scan iteration g consumes the expert pages gathered during
    iteration g-1 and issues the gather for group g+1's tables — the
    prefetch has no data dependence on group g's FFN, so XLA overlaps
    the page DMA with compute; routing for layer L+1 never waits on its
    weight gather (DESIGN.md §15 prefetch window).

    Pages for the WHOLE chunk (up to ceil(T/psz) per sequence) come
    from each slot's private lane in one
    :func:`hier_pool.alloc_n_or_shared` call — the paper's
    batch-granularity transfer absorbing multi-page demand per step in
    O(Bl * T) lane-local work, independent of the pool size (the §4.2
    sizing rule ``ell >= ceil(T/psz)`` keeps the lanes never-dry
    between rebalances, so the shared-pool fallback is dead code on
    the serving path; a caller looping this step raw, with no
    rebalance, degrades to the shared pool instead of writing through
    NULL page ids).  T == 1 with lens == active is steady-state
    single-token decode — a width-1 lane, the serving engine's decode
    path.
    """
    DP, Bl, T = tokens.shape
    if active is None:
        active = jnp.ones((DP, Bl), bool)
    lens = jnp.where(active, jnp.clip(lens.astype(jnp.int32), 0, T), 0)
    base = state.seq_lens
    x = constrain_batch(embed_apply(params["embed"], tokens).astype(cfg.jdtype))

    # --- page allocation for the whole chunk (once, all paged layers)
    if state.kv_pages:
        # all-or-nothing per sequence (append_chunk's contract): a chunk
        # that would overflow the page table, or whose pages the pool
        # denies, appends NOTHING — without the gate the page-index
        # clamp below would overwrite live KV while seq_lens advanced
        psz = cfg.page_size
        maxp = state.page_tables.shape[2]
        kmax = -(-T // psz)
        lens, pages_before, counts = block_pool.chunk_page_plan(
            base, lens, psz, maxp)
        pool, got = classed_pool.alloc_n_or_shared_dp(
            state.pool, CLS_KV, counts, kmax)
        lens = jnp.where(block_pool.granted_mask(got, counts), lens, 0)
        dp_i = jnp.arange(DP)[:, None, None]
        bl_i = jnp.arange(Bl)[None, :, None]
        kk = jnp.arange(kmax)[None, None, :]
        slot = pages_before[..., None] + kk
        new_page = (kk < counts[..., None]) & (got >= 0)
        slot = jnp.where(new_page, slot, maxp)       # out-of-range => drop
        new_tables = state.page_tables.at[dp_i, bl_i, slot].set(
            got, mode="drop")
        state = state._replace(page_tables=new_tables, pool=pool)

    positions = base[..., None] + jnp.arange(T, dtype=jnp.int32)[None, None]
    tok_valid = jnp.arange(T)[None, None, :] < lens[..., None]

    st_kinds = _positions(cfg)
    has_x = cfg.arch_kind == "encdec"

    paged_moe = bool(state.expert_tables)
    if paged_moe:
        # the paged FFN squeezes the shard axis to rebuild [E, ...]
        # weights; under shard_map (or a dp=1 engine) DP is always 1
        assert DP == 1, "expert paging requires shard-local DP == 1"
    meters = {k: jnp.zeros((DP,), jnp.int32)
              for k in ("moe_dropped", "expert_hit_pages",
                        "expert_miss_pages", "expert_prefetch_pages")}

    def absorb(meters, meta, tab):
        """Fold one MoE layer's (dropped, routed) into the step meters;
        ``tab`` (int32 [DP, E, EXPERT_PPE] or None) supplies residency:
        an expert is resident iff all its pages are mapped."""
        if meta is None:
            return meters
        dropped, routed = meta
        meters = dict(meters)
        meters["moe_dropped"] = meters["moe_dropped"] + dropped
        if tab is not None:
            res = (tab >= 0).all(axis=-1)                  # [DP, E]
            touched = routed > 0
            meters["expert_hit_pages"] = (
                meters["expert_hit_pages"] + EXPERT_PPE * jnp.sum(
                    touched & res, axis=-1, dtype=jnp.int32))
            meters["expert_miss_pages"] = (
                meters["expert_miss_pages"] + EXPERT_PPE * jnp.sum(
                    touched & ~res, axis=-1, dtype=jnp.int32))
        return meters

    pat_moe, _rem_moe = moe_positions(cfg)
    etab_pat = ({pos: state.expert_tables[pos] for pos in pat_moe}
                if paged_moe else {})
    # next-group tables: group g prefetches g+1's experts (wraps to 0)
    etab_next = jax.tree.map(lambda a: jnp.roll(a, -1, axis=0), etab_pat)
    ebuf0 = {pos: _gather_expert_pages(state.expert_pages, tab[0])
             for pos, tab in etab_pat.items()}

    def group_body(carry, xs):
        x, ebuf, meters = carry
        gparams, gstate, enc_kv_g, etab_g, etab_n = xs
        new_gstate = {}
        for j, kind in enumerate(cfg.pattern):
            pos = f"pos{j}"
            x, ns, meta = _mix_decode_chunk(
                cfg, gparams[pos], x, kind, st_kinds[pos], gstate[pos],
                state, positions, tok_valid, base, lens,
                enc_kv_g if has_x else None, verify=verify,
                expert_buf=ebuf.get(pos), expert_mask=expert_mask)
            new_gstate[pos] = ns
            meters = absorb(meters, meta, etab_g.get(pos))
        # prefetch the NEXT group's expert pages: independent of this
        # group's FFN, so the gather DMA overlaps the compute above
        new_ebuf = {}
        for pos, tab_n in etab_n.items():
            new_ebuf[pos] = _gather_expert_pages(state.expert_pages, tab_n)
            meters = dict(meters)
            meters["expert_prefetch_pages"] = (
                meters["expert_prefetch_pages"] + jnp.sum(
                    tab_n >= 0, axis=(1, 2), dtype=jnp.int32))
        return (x, new_ebuf, meters), new_gstate

    if cfg.n_groups:
        gstates = {}
        for pos, kv in state.kv_pages.items():
            if pos.startswith("pos"):
                gstates[pos] = kv
        for pos, kv in state.rings.items():
            if pos.startswith("pos"):
                gstates[pos] = kv
        for pos, rc in state.rec.items():
            if pos.startswith("pos"):
                gstates[pos] = rc
        if has_x and state.enc_kv is not None:
            assert len(cfg.pattern) == 1, "encdec requires pattern length 1"
            enc_scan = (state.enc_kv[0][:cfg.n_groups],
                        state.enc_kv[1][:cfg.n_groups])
        else:
            enc_scan = (jnp.zeros((cfg.n_groups,)),) * 2  # placeholder
        (x, _, meters), new_gstates = jax.lax.scan(
            group_body, (x, ebuf0, meters),
            (params["groups"], gstates, enc_scan, etab_pat, etab_next))
    else:
        new_gstates = {}

    new_rem_states = {}
    for j, kind in enumerate(cfg.remainder):
        pos = f"rem{j}"
        bk = base_kind(kind)
        st_kind = ("paged" if bk == "global"
                   else "ring" if bk == "local" else "rec")
        ls = (state.kv_pages.get(pos) or state.rings.get(pos)
              or state.rec.get(pos))
        ls0 = jax.tree.map(lambda a: a[0], ls)
        lp = params["rem"][f"pos{j}"]
        enc_l = None
        if has_x and state.enc_kv is not None:
            idx = cfg.n_groups * len(cfg.pattern) + j
            enc_l = (state.enc_kv[0][idx], state.enc_kv[1][idx])
        tab_r = ebuf_r = None
        if paged_moe and pos in state.expert_tables:
            tab_r = state.expert_tables[pos][0]
            ebuf_r = _gather_expert_pages(state.expert_pages, tab_r)
        x, ns, meta = _mix_decode_chunk(cfg, lp, x, kind, st_kind, ls0,
                                        state, positions, tok_valid, base,
                                        lens, enc_l, verify=verify,
                                        expert_buf=ebuf_r,
                                        expert_mask=expert_mask)
        new_rem_states[pos] = jax.tree.map(lambda a: a[None], ns)
        meters = absorb(meters, meta, tab_r)

    kv_pages, rings, rec = {}, {}, {}
    for pos in state.kv_pages:
        src = new_gstates if pos.startswith("pos") else new_rem_states
        kv_pages[pos] = src[pos]
    for pos in state.rings:
        src = new_gstates if pos.startswith("pos") else new_rem_states
        rings[pos] = src[pos]
    for pos in state.rec:
        src = new_gstates if pos.startswith("pos") else new_rem_states
        rec[pos] = src[pos]
    # rec states were gated per token inside the chunk scan; no extra
    # active-gating needed here (lens == 0 leaves every leaf untouched).

    state = DecodeState(
        kv_pages=kv_pages, rings=rings, rec=rec,
        page_tables=state.page_tables,
        seq_lens=base + lens,
        pool=state.pool,
        enc_kv=state.enc_kv,
        state_tables=state.state_tables,
        expert_pages=state.expert_pages,
        expert_tables=state.expert_tables)

    if "final_norm" in params:
        x = apply_norm(cfg, params["final_norm"], x)
    elif cfg.norm == "ln_nonparam":
        from .layers import ln_nonparam
        x = ln_nonparam(x)
    return x, state, meters
