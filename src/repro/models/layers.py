"""Parameter definitions, norms, RoPE, embeddings, dense FFN.

Parameters live in nested dicts of arrays.  Shapes/axes are declared via
:class:`ParamDef` trees so the same declaration yields (a) initialized
arrays, (b) ``jax.ShapeDtypeStruct`` stand-ins for the dry-run, and
(c) ``PartitionSpec`` trees from logical-axis rules
(:mod:`repro.parallel.partition`).
"""

from __future__ import annotations

import math
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class ParamDef(NamedTuple):
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]    # logical axis names, len == ndim
    dtype: Any = jnp.float32
    init: str = "normal"               # normal | zeros | ones


def is_def(x) -> bool:
    return isinstance(x, ParamDef)


def init_tree(key: jax.Array, defs) -> Any:
    """Initialize a pytree of ParamDefs into arrays."""
    leaves, treedef = jax.tree.flatten(defs, is_leaf=is_def)
    keys = jax.random.split(key, len(leaves))
    arrs = []
    for k, d in zip(keys, leaves):
        if d.init == "zeros":
            arrs.append(jnp.zeros(d.shape, d.dtype))
        elif d.init == "ones":
            arrs.append(jnp.ones(d.shape, d.dtype))
        else:
            fan_in = d.shape[0] if d.shape else 1
            arrs.append(
                (jax.random.normal(k, d.shape, jnp.float32)
                 * (1.0 / math.sqrt(max(fan_in, 1)))).astype(d.dtype))
    return jax.tree.unflatten(treedef, arrs)


def shape_tree(defs) -> Any:
    """ShapeDtypeStruct stand-ins (dry-run path; no allocation)."""
    return jax.tree.map(
        lambda d: jax.ShapeDtypeStruct(d.shape, d.dtype), defs, is_leaf=is_def)


# ----------------------------------------------------------------- norms

def rmsnorm(x, scale=None, eps: float = 1e-6):
    """RMSNorm with a bf16 primal chain.

    Only the variance *reduction* runs in f32 (a per-row scalar); the
    elementwise normalize/scale stays in x.dtype.  This keeps the big
    [B,S,d] primals — and therefore their cotangents and any TP
    all-reduce placed on them — in bf16 instead of f32, halving both
    HBM traffic and collective bytes (EXPERIMENTS.md §Perf A2).
    """
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    rs = jax.lax.rsqrt(var + eps).astype(x.dtype)
    y = x * rs
    if scale is not None:
        y = y * (1.0 + scale).astype(x.dtype)
    return y


def ln_nonparam(x, eps: float = 1e-5):
    """OLMo-style non-parametric LayerNorm (bf16 primal chain)."""
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
    rs = jax.lax.rsqrt(var + eps).astype(x.dtype)
    return (x - mu.astype(x.dtype)) * rs


def norm_defs(cfg) -> Dict[str, ParamDef]:
    if cfg.norm == "ln_nonparam":
        return {}
    return {"scale": ParamDef((cfg.d_model,), ("embed",), jnp.float32, "zeros")}


def apply_norm(cfg, params, x):
    if cfg.norm == "ln_nonparam":
        return ln_nonparam(x)
    return rmsnorm(x, params["scale"])


# ----------------------------------------------------------------- rope

def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [..., seq, heads, head_dim]; positions: [..., seq]."""
    hd = x.shape[-1]
    freqs = rope_frequencies(hd, theta)                   # [hd/2]
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # [..., S, hd/2]
    cos = jnp.cos(angles)[..., :, None, :]
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ----------------------------------------------------------------- ffn

def ffn_defs(cfg) -> Dict[str, ParamDef]:
    d, f, dt = cfg.d_model, cfg.d_ff, cfg.jdtype
    if cfg.act == "swiglu":
        return {
            "w_gate": ParamDef((d, f), ("embed", "mlp"), dt),
            "w_up": ParamDef((d, f), ("embed", "mlp"), dt),
            "w_down": ParamDef((f, d), ("mlp", "embed"), dt),
        }
    return {
        "w_up": ParamDef((d, f), ("embed", "mlp"), dt),
        "w_down": ParamDef((f, d), ("mlp", "embed"), dt),
    }


def ffn_apply(cfg, params, x):
    if cfg.act == "swiglu":
        h = jax.nn.silu(x @ params["w_gate"]) * (x @ params["w_up"])
    else:
        h = jax.nn.gelu(x @ params["w_up"])
    return h @ params["w_down"]


# ----------------------------------------------------------------- embeds

def embed_defs(cfg) -> Dict[str, ParamDef]:
    dt = cfg.jdtype
    out = {"tok": ParamDef((cfg.vocab, cfg.d_model), ("vocab", "embed"), dt)}
    if not cfg.tie_embeddings:
        out["lm_head"] = ParamDef(
            (cfg.d_model, cfg.vocab), ("embed", "vocab"), dt)
    return out


def embed_apply(params, tokens):
    return params["tok"][tokens]


def logits_apply(cfg, params, x):
    w = params.get("lm_head")
    if w is None:
        w = params["tok"].T
    return x @ w


def logits_argmax_chunked(cfg, params, x, chunk: int = 1024):
    """``jnp.argmax(logits_apply(cfg, params, x), -1)`` without ever
    materializing the [..., V] logits tensor.

    Scans vocabulary chunks keeping a running (max, argmax).  Each
    candidate logit is the same dot product the full projection
    computes, and ascending chunk order with a strict ``>`` preserves
    ``jnp.argmax``'s first-max tie-break — so the winner is exactly the
    full projection's argmax.  The speculative verify path uses this so
    greedy draft verification never builds [T, V] (DESIGN.md §12).
    """
    w = params.get("lm_head")
    if w is None:
        w = params["tok"].T
    D, V = w.shape
    chunk = min(chunk, V)
    n = V // chunk

    def fold(carry, lg, off):
        best, arg = carry
        m = jnp.max(lg, axis=-1)
        a = jnp.argmax(lg, axis=-1).astype(jnp.int32) + off
        upd = m > best
        return jnp.where(upd, m, best), jnp.where(upd, a, arg)

    def body(carry, wc_off):
        wc, off = wc_off
        lg = (x @ wc).astype(jnp.float32)
        return fold(carry, lg, off), None

    ws = w[:, :n * chunk].reshape(D, n, chunk).transpose(1, 0, 2)
    offs = jnp.arange(n, dtype=jnp.int32) * chunk
    init = (jnp.full(x.shape[:-1], -jnp.inf, jnp.float32),
            jnp.zeros(x.shape[:-1], jnp.int32))
    (best, arg), _ = jax.lax.scan(body, init, (ws, offs))
    if V % chunk:
        lg = (x @ w[:, n * chunk:]).astype(jnp.float32)
        best, arg = fold((best, arg), lg, jnp.int32(n * chunk))
    return arg


def chunked_softmax_xent(cfg, embed_params, x, labels, chunk: int = 512):
    """Cross-entropy without materializing [B, S, V] logits.

    Scans over sequence chunks; with remat the chunk logits are
    recomputed in the backward pass.  Returns mean loss over tokens.
    """
    B, S, D = x.shape
    chunk = min(chunk, S)
    n = S // chunk
    xs = x[:, :n * chunk].reshape(B, n, chunk, D).swapaxes(0, 1)
    ys = labels[:, :n * chunk].reshape(B, n, chunk).swapaxes(0, 1)

    def body(acc, xy):
        xc, yc = xy
        logits = logits_apply(cfg, embed_params, xc).astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, yc[..., None], axis=-1)[..., 0]
        return acc + jnp.sum(logz - gold), None

    total, _ = jax.lax.scan(body, jnp.float32(0), (xs, ys))
    # remainder (S not divisible by chunk)
    if S % chunk:
        xc, yc = x[:, n * chunk:], labels[:, n * chunk:]
        logits = logits_apply(cfg, embed_params, xc).astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, yc[..., None], axis=-1)[..., 0]
        total = total + jnp.sum(logz - gold)
    return total / (B * S)
