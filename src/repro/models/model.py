"""Top-level model API: params, losses, serve steps, parameter counts."""

from __future__ import annotations

import math
from typing import Dict

import jax
import jax.numpy as jnp

from .layers import (chunked_softmax_xent, init_tree, is_def, logits_apply,
                     shape_tree)
from .transformer import (DecodeState, forward_decode_chunk,
                          forward_prefill, forward_train, model_defs)


def param_defs(cfg):
    return model_defs(cfg)


def init_params(cfg, key: jax.Array):
    return init_tree(key, model_defs(cfg))


def param_shapes(cfg):
    """ShapeDtypeStruct tree — dry-run stand-ins, no allocation."""
    return shape_tree(model_defs(cfg))


def count_params(cfg) -> int:
    leaves = jax.tree.leaves(model_defs(cfg), is_leaf=is_def)
    return sum(math.prod(d.shape) for d in leaves)


def count_active_params(cfg) -> int:
    """Active params per token: MoE expert weights scaled by top_k/E."""
    if cfg.moe is None:
        return count_params(cfg)
    total = 0

    def walk(tree):
        nonlocal total
        if is_def(tree):
            total += math.prod(tree.shape)
            return
        if isinstance(tree, dict) and "router" in tree:   # a MoE ffn subtree
            for k, v in tree.items():
                n = sum(math.prod(d.shape)
                        for d in jax.tree.leaves(v, is_leaf=is_def))
                if k.startswith("w_"):                    # expert weights
                    n = n * cfg.moe.top_k // cfg.moe.num_experts
                total += n
            return
        for v in tree.values():
            walk(v)

    walk(model_defs(cfg))
    return total


# ------------------------------------------------------------------- train

def loss_fn(cfg, params, batch: Dict[str, jax.Array], remat: bool = True):
    """Causal LM loss (chunked CE — never materializes [B,S,V] logits)."""
    x = forward_train(cfg, params, batch["tokens"], extra=batch, remat=remat)
    labels = batch["labels"]
    if cfg.arch_kind == "vlm" and "img_embeds" in batch:
        x = x[:, batch["img_embeds"].shape[1]:]     # loss on text tokens only
    return chunked_softmax_xent(cfg, params["embed"], x, labels)


# ------------------------------------------------------------------- serve

def prefill(cfg, params, batch):
    """Prompt processing: returns (last-token logits, caches)."""
    x, caches = forward_prefill(cfg, params, batch["tokens"], extra=batch)
    logits = logits_apply(cfg, params["embed"], x[:, -1])
    return logits, caches


def decode_step(cfg, params, tokens, state: DecodeState, active=None):
    """One decode step: (logits [DP, Bl, V], new state).

    A width-1 token lane through :func:`forward_decode_chunk` — the
    single-token path is not a separate implementation anymore (the
    pre-refactor ``forward_decode`` is deleted); inactive slots feed a
    zero-length lane and stay inert, and a slot whose private lane ran
    dry (a raw loop with no rebalance) degrades to the shard's shared
    pool inside the chunk allocator.
    """
    DP, Bl = tokens.shape
    if active is None:
        active = jnp.ones((DP, Bl), bool)
    x, state, _ = forward_decode_chunk(
        cfg, params, tokens[:, :, None], state,
        active.astype(jnp.int32), active=active)
    logits = logits_apply(cfg, params["embed"], x[:, :, 0])
    return logits, state


def decode_step_chunk(cfg, params, tokens, state: DecodeState, lens,
                      active=None):
    """Chunked decode/prefill step:
    (logits [DP, Bl, T, V], new state, ok bool[DP, Bl]).

    Processes up to T tokens per sequence (lens gives each sequence's
    live count); logits are returned for every chunk position so
    callers can sample at position lens - 1 or score whole prompts.
    ok is False where the chunk was denied whole (page-table overflow
    or pool exhaustion — nothing appended, logits meaningless); callers
    must not sample from a denied sequence.
    """
    T = tokens.shape[2]
    if active is None:
        active = jnp.ones(tokens.shape[:2], bool)
    asked = jnp.where(active, jnp.clip(lens.astype(jnp.int32), 0, T), 0)
    base = state.seq_lens
    x, state, _ = forward_decode_chunk(cfg, params, tokens, state, lens,
                                       active=active)
    logits = logits_apply(cfg, params["embed"], x)
    return logits, state, state.seq_lens - base == asked
