from .model import (param_defs, init_params, param_shapes, count_params,
                    count_active_params, loss_fn, prefill, decode_step,
                    decode_step_chunk)
from .transformer import (DecodeState, decode_state_defs, forward_train,
                          forward_prefill, forward_decode_chunk, model_defs)
