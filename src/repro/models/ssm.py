"""Mamba-2 SSD (state-space duality) block [arXiv:2405.21060].

Chunked SSD reference: within-chunk attention-like term + inter-chunk
state recurrence carried by ``lax.scan`` — O(S * Q) compute/memory per
head instead of O(S^2).  Decode is an O(1) state update.  The Pallas
kernel (kernels/ssd_scan) tiles the same computation for VMEM.
"""

from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from .layers import ParamDef, rmsnorm


def ssd_defs(cfg) -> Dict[str, ParamDef]:
    d = cfg.d_model
    di = cfg.ssd_expand * d
    N = cfg.ssd_state
    H = di // cfg.ssd_head_dim
    dt = cfg.jdtype
    return {
        "in_proj": ParamDef((d, 2 * di + 2 * N + H), ("embed", "mlp"), dt),
        "conv_w": ParamDef((4, di + 2 * N), (None, None), dt),
        "A_log": ParamDef((H,), (None,), jnp.float32, "zeros"),
        "D": ParamDef((H,), (None,), jnp.float32, "ones"),
        "dt_bias": ParamDef((H,), (None,), jnp.float32, "zeros"),
        "norm_scale": ParamDef((di,), ("mlp",), jnp.float32, "zeros"),
        "out_proj": ParamDef((di, d), ("mlp", "embed"), dt),
    }


def _split_proj(cfg, z):
    d = cfg.d_model
    di = cfg.ssd_expand * d
    N = cfg.ssd_state
    H = di // cfg.ssd_head_dim
    x, zgate, Bc, Cc, dt = jnp.split(
        z, [di, 2 * di, 2 * di + N, 2 * di + 2 * N], axis=-1)
    return x, zgate, Bc, Cc, dt


def _causal_conv(x, w, state=None):
    """Depthwise causal conv, kernel 4.  x: [B, S, C]; w: [4, C].

    Returns (y, new_state) where state is the last 3 inputs [B, 3, C].
    """
    B, S, C = x.shape
    K = w.shape[0]
    if state is None:
        state = jnp.zeros((B, K - 1, C), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)
    y = sum(xp[:, i:i + S] * w[i] for i in range(K))
    return y, xp[:, -(K - 1):]


def ssd_chunked(x, dt, A, Bm, Cm, D, chunk: int = 128, h0=None):
    """SSD scan. x: [B,S,H,P]; dt: [B,S,H]; A: [H]; Bm/Cm: [B,S,N].

    Returns (y [B,S,H,P], h_final [B,H,P,N]).
    """
    Bsz, S, H, P = x.shape
    N = Bm.shape[-1]
    Q = min(chunk, S)
    assert S % Q == 0
    nc = S // Q

    a = dt * A[None, None, :]                       # [B,S,H] (negative)
    xc = x.reshape(Bsz, nc, Q, H, P)
    dtc = dt.reshape(Bsz, nc, Q, H)
    ac = a.reshape(Bsz, nc, Q, H)
    Bc = Bm.reshape(Bsz, nc, Q, N)
    Cc = Cm.reshape(Bsz, nc, Q, N)

    cum = jnp.cumsum(ac, axis=2)                    # [B,nc,Q,H]
    a_total = cum[:, :, -1]                         # [B,nc,H]

    # within-chunk (diagonal) term.  For i < j (masked out) seg > 0 and
    # exp(seg) overflows; mask BEFORE the exp so the cotangent of the
    # masked branch is well-defined (inf * 0 = NaN otherwise).
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]   # [B,nc,Q,Q,H] = cum_i - cum_j
    tri = jnp.tril(jnp.ones((Q, Q), bool))[None, None, :, :, None]
    L = jnp.where(tri, jnp.exp(jnp.where(tri, seg, 0.0)), 0.0)
    cb = jnp.einsum("bcin,bcjn->bcij", Cc, Bc)      # [B,nc,Q,Q]
    w = cb[..., None] * L * dtc[:, :, None, :, :]   # [B,nc,Q,Q,H]
    y_diag = jnp.einsum("bcijh,bcjhp->bcihp", w.astype(x.dtype), xc)

    # chunk states: contribution of each chunk to the carried state
    decay_out = jnp.exp(a_total[:, :, None, :] - cum)         # [B,nc,Q,H]
    states = jnp.einsum("bcqh,bcqn,bcqhp->bchpn",
                        (decay_out * dtc).astype(x.dtype), Bc, xc)

    # inter-chunk recurrence
    def step(h, inp):
        st, atot = inp                              # [B,H,P,N], [B,H]
        h_out = h                                   # state entering this chunk
        h_new = h * jnp.exp(atot)[:, :, None, None] + st
        return h_new, h_out

    h_init = h0 if h0 is not None else jnp.zeros((Bsz, H, P, N), jnp.float32)
    h_final, h_prevs = jax.lax.scan(
        step, h_init,
        (states.swapaxes(0, 1).astype(jnp.float32),
         a_total.swapaxes(0, 1)))
    h_prevs = h_prevs.swapaxes(0, 1)                # [B,nc,H,P,N]

    # off-diagonal (carried state) term
    decay_in = jnp.exp(cum)                          # [B,nc,Q,H]
    y_off = jnp.einsum("bcqn,bchpn->bcqhp", Cc,
                       h_prevs.astype(x.dtype)) * decay_in[..., None].astype(x.dtype)

    y = (y_diag + y_off).reshape(Bsz, S, H, P)
    y = y + x * D[None, None, :, None]
    return y, h_final


def ssd_decode_step(x, dt, A, Bm, Cm, D, h):
    """One-token SSD update. x:[B,H,P]; dt:[B,H]; Bm/Cm:[B,N]; h:[B,H,P,N]."""
    a = jnp.exp(dt * A[None, :])                     # [B,H]
    upd = jnp.einsum("bh,bn,bhp->bhpn", dt, Bm, x)
    h_new = h * a[:, :, None, None] + upd
    y = jnp.einsum("bn,bhpn->bhp", Cm, h_new.astype(x.dtype))
    return y + x * D[None, :, None], h_new


def ssd_block_apply(cfg, params, x, h0=None, conv0=None, decode: bool = False):
    """Full Mamba-2 block: in_proj -> conv -> SSD -> gated norm -> out_proj.

    Train/prefill: x [B,S,d] -> (y, (h_final, conv_state)).
    Decode: x [B,1,d] with (h0, conv0) states.
    """
    d = cfg.d_model
    di = cfg.ssd_expand * d
    N = cfg.ssd_state
    P = cfg.ssd_head_dim
    H = di // P

    z = x @ params["in_proj"]
    xin, zgate, Bc, Cc, dtr = _split_proj(cfg, z)
    conv_in = jnp.concatenate([xin, Bc, Cc], axis=-1)
    conv_out, conv_state = _causal_conv(conv_in, params["conv_w"], conv0)
    conv_out = jax.nn.silu(conv_out)
    xin, Bc, Cc = jnp.split(conv_out, [di, di + N], axis=-1)

    Bsz, S, _ = x.shape
    xh = xin.reshape(Bsz, S, H, P)
    dt = jax.nn.softplus(dtr.astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["A_log"])

    if decode:
        y, h_new = ssd_decode_step(
            xh[:, 0], dt[:, 0], A, Bc[:, 0], Cc[:, 0], params["D"],
            h0 if h0 is not None else jnp.zeros((Bsz, H, P, N), jnp.float32))
        y = y[:, None]
    else:
        y, h_new = ssd_chunked(xh, dt, A, Bc, Cc, params["D"], h0=h0)

    y = y.reshape(Bsz, -1, di)
    y = rmsnorm(y * jax.nn.silu(zgate), params["norm_scale"])
    y = y.astype(x.dtype)
    return y @ params["out_proj"], (h_new, conv_state)
