"""Griffin RG-LRU recurrent block [arXiv:2402.19427].

Real-Gated Linear Recurrent Unit:
    r_t = sigmoid(W_r x_t);  i_t = sigmoid(W_i x_t)
    log a_t = -c * softplus(Lambda) * r_t          (c = 8)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

computed with ``jax.lax.associative_scan`` (O(log S) depth) for
train/prefill and an O(1) update for decode.  The enclosing Griffin
block: gated branch (GeLU) x (linear -> causal depthwise conv(4) ->
RG-LRU) -> output projection.
"""

from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from .layers import ParamDef

_C = 8.0


def rglru_defs(cfg) -> Dict[str, ParamDef]:
    d = cfg.d_model
    dt = cfg.jdtype
    return {
        "w_x": ParamDef((d, d), ("embed", "mlp"), dt),
        "w_gate": ParamDef((d, d), ("embed", "mlp"), dt),
        "conv_w": ParamDef((cfg.rglru_conv, d), (None, "mlp"), dt),
        "w_r": ParamDef((d, d), ("mlp", "mlp"), dt),
        "w_i": ParamDef((d, d), ("mlp", "mlp"), dt),
        "lam": ParamDef((d,), ("mlp",), jnp.float32, "ones"),
        "w_out": ParamDef((d, d), ("mlp", "embed"), dt),
    }


def _gates(params, x):
    r = jax.nn.sigmoid(x @ params["w_r"]).astype(jnp.float32)
    i = jax.nn.sigmoid(x @ params["w_i"]).astype(jnp.float32)
    log_a = -_C * jax.nn.softplus(params["lam"]) * r
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * \
        (i * x.astype(jnp.float32))
    return a, gated


def rglru_scan(params, x, h0=None):
    """x: [B, S, d] -> (y [B, S, d], h_final [B, d])."""
    a, b = _gates(params, x)                       # [B,S,d] fp32

    if h0 is not None:
        b = b.at[:, 0].add(a[:, 0] * h0)

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, ar * bl + br

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h.astype(x.dtype), h[:, -1]


def rglru_decode_step(params, x, h):
    """x: [B, 1, d]; h: [B, d] -> (y [B,1,d], h_new)."""
    a, b = _gates(params, x)
    h_new = a[:, 0] * h + b[:, 0]
    return h_new[:, None].astype(x.dtype), h_new


def _causal_conv(x, w, state=None):
    B, S, C = x.shape
    K = w.shape[0]
    if state is None:
        state = jnp.zeros((B, K - 1, C), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)
    y = sum(xp[:, i:i + S] * w[i] for i in range(K))
    return y, xp[:, -(K - 1):]


def rglru_block_apply(cfg, params, x, h0=None, conv0=None,
                      decode: bool = False):
    """Griffin recurrent block.  x: [B,S,d] -> (y, (h, conv_state))."""
    gate = jax.nn.gelu(x @ params["w_gate"])
    u = x @ params["w_x"]
    u, conv_state = _causal_conv(u, params["conv_w"], conv0)
    if decode:
        y, h = rglru_decode_step(params, u, h0 if h0 is not None else
                                 jnp.zeros(u.shape[::2], jnp.float32))
    else:
        y, h = rglru_scan(params, u, h0)
    return (y * gate) @ params["w_out"], (h, conv_state)
