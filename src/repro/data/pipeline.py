"""Data pipeline: deterministic sharded token streams.

Production shape: each host loads only its shard of the global batch
(``host_slice``), tokenizes/packs off the critical path, and double-
buffers ahead of the step loop.  For the reproduction the source is a
synthetic-but-deterministic token stream (seeded per shard and step), so
runs are reproducible across restarts and elastic re-sharding — the
stream is a pure function of (seed, step, position), not of worker
state, which is what makes checkpoint/restart and elastic scaling exact.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator

import numpy as np



@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_hosts: int = 1
    host_id: int = 0


class TokenStream:
    """Stateless synthetic LM stream: batch(step) is a pure function."""

    def __init__(self, cfg: DataConfig):
        assert cfg.global_batch % cfg.n_hosts == 0
        self.cfg = cfg
        self.local_batch = cfg.global_batch // cfg.n_hosts

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        out_tokens = np.empty((self.local_batch, cfg.seq_len + 1), np.int32)
        for i in range(self.local_batch):
            row = cfg.host_id * self.local_batch + i
            rng = np.random.Philox(key=cfg.seed + step * 1_000_003 + row)
            gen = np.random.Generator(rng)
            # Zipf-ish marginal like natural text; offset so 0 is padding
            toks = gen.zipf(1.3, size=cfg.seq_len + 1)
            out_tokens[i] = np.clip(toks, 1, cfg.vocab - 1)
        return {
            "tokens": out_tokens[:, :-1],
            "labels": out_tokens[:, 1:],
        }

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class Prefetcher:
    """One-deep host-side prefetch (double buffering)."""

    def __init__(self, stream: TokenStream, start_step: int = 0):
        self.stream = stream
        self.step = start_step
        self._next = self.stream.batch_at(self.step)

    def get(self) -> Dict[str, np.ndarray]:
        cur = self._next
        self.step += 1
        self._next = self.stream.batch_at(self.step)
        return cur
