"""Core of the reproduction: the paper's wait-free fixed-size allocator.

Faithful layer (simulated asynchronous shared memory):
  sim, memory, psim, allocator, scheduler, linearizability, baselines

TPU-native layer (JAX, SPMD):
  block_pool, hier_pool, kv_cache
"""

from .sim import NULL, SimContext, Register, RegisterArray, CASWord, LLSC
from .memory import BlockMemory
from .psim import PSimStack
from .allocator import WaitFreeAllocator, PoolExhausted, DEAMORT_C
from .scheduler import Scheduler, closed_loop
from .linearizability import (check_alloc_history, check_batch_alloc_history,
                              check_classed_batch_history,
                              check_cross_class_frees,
                              check_cross_shard_frees,
                              check_preemption_history,
                              check_sharded_batch_history,
                              expand_batch_history, split_history_by_class,
                              split_history_by_shard,
                              WGStackChecker, Event)
from . import block_pool, classed_pool, hier_pool, kv_cache, refpool

__all__ = [
    "NULL", "SimContext", "Register", "RegisterArray", "CASWord", "LLSC",
    "BlockMemory", "PSimStack", "WaitFreeAllocator", "PoolExhausted",
    "DEAMORT_C", "Scheduler", "closed_loop", "check_alloc_history",
    "check_batch_alloc_history", "check_classed_batch_history",
    "check_cross_class_frees", "check_cross_shard_frees",
    "check_preemption_history", "check_sharded_batch_history",
    "expand_batch_history", "split_history_by_class",
    "split_history_by_shard",
    "WGStackChecker", "Event", "block_pool", "classed_pool", "hier_pool",
    "kv_cache", "refpool",
]
