"""Paged KV cache built on the block pool — the allocator's main client.

vLLM-style paging adapted to TPU: the KV store is a pool of fixed-size
*pages* of ``page_size`` tokens; each sequence owns a page table (list of
page ids).  Appending a token is O(1) array ops; crossing a page
boundary allocates a page from the :mod:`hier_pool`/:mod:`block_pool`
(constant time, the paper's contribution).  Attention kernels read
through the page table (see ``repro.kernels.paged_attention``).

Layout choice for TPU: pages store K and V as
``[num_pages, page_size, kv_heads, head_dim]`` so that a page is a
(page_size x head_dim) VMEM tile per head — head_dim is kept a multiple
of 128 by configs, aligning gathers with the MXU/VPU lanes.
"""

from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from . import block_pool
from .block_pool import BlockPool, NULL


class PagedKVCache(NamedTuple):
    pool: BlockPool           # page allocator
    k_pages: jax.Array        # [num_pages, page_size, kv_heads, head_dim]
    v_pages: jax.Array        # [num_pages, page_size, kv_heads, head_dim]
    page_tables: jax.Array    # int32[max_seqs, max_pages_per_seq]
    seq_lens: jax.Array       # int32[max_seqs] — tokens currently stored


def create(num_pages: int, page_size: int, kv_heads: int, head_dim: int,
           max_seqs: int, max_pages_per_seq: int,
           dtype=jnp.bfloat16) -> PagedKVCache:
    return PagedKVCache(
        pool=block_pool.create(num_pages),
        k_pages=jnp.zeros((num_pages, page_size, kv_heads, head_dim), dtype),
        v_pages=jnp.zeros((num_pages, page_size, kv_heads, head_dim), dtype),
        page_tables=jnp.full((max_seqs, max_pages_per_seq), NULL, jnp.int32),
        seq_lens=jnp.zeros((max_seqs,), jnp.int32),
    )


def page_size(cache: PagedKVCache) -> int:
    return cache.k_pages.shape[1]


def append(cache: PagedKVCache, k: jax.Array, v: jax.Array,
           active: jax.Array) -> Tuple["PagedKVCache", jax.Array]:
    """Append one token of K/V per active sequence.

    k, v: [max_seqs, kv_heads, head_dim]; active: bool[max_seqs].
    Returns (cache, ok[max_seqs]) — ok False if a page allocation failed.
    O(max_seqs) work, independent of cache size (paper's discipline).
    """
    S = cache.seq_lens.shape[0]
    psz = page_size(cache)
    pos_in_page = cache.seq_lens % psz
    page_idx = cache.seq_lens // psz

    needs_page = active & (pos_in_page == 0)
    pool, new_ids = block_pool.alloc(cache.pool, needs_page)
    ok = jnp.where(needs_page, new_ids >= 0, True) & active

    rows = jnp.arange(S)
    page_tables = cache.page_tables.at[rows, page_idx].set(
        jnp.where(needs_page & ok, new_ids,
                  cache.page_tables[rows, page_idx]))

    page_ids = page_tables[rows, page_idx]
    write = ok & (page_ids >= 0)
    # masked slots scatter to an out-of-range page and are dropped; the
    # previous read-modify-write idiom (write old value back to page 0)
    # raced with a real write to page 0 at the same position
    tgt = jnp.where(write, page_ids, cache.k_pages.shape[0])
    k_pages = cache.k_pages.at[tgt, pos_in_page].set(
        k.astype(cache.k_pages.dtype), mode="drop")
    v_pages = cache.v_pages.at[tgt, pos_in_page].set(
        v.astype(cache.v_pages.dtype), mode="drop")

    seq_lens = cache.seq_lens + write.astype(jnp.int32)
    return PagedKVCache(pool, k_pages, v_pages, page_tables, seq_lens), ok


def append_chunk(cache: PagedKVCache, k: jax.Array, v: jax.Array,
                 lens: jax.Array,
                 active: jax.Array | None = None
                 ) -> Tuple["PagedKVCache", jax.Array]:
    """Append up to C tokens of K/V per sequence in one fixed-shape call.

    k, v: [max_seqs, C, kv_heads, head_dim]; lens: int32[max_seqs] —
    tokens to append per sequence (0 <= lens[s] <= C); active:
    bool[max_seqs] (default all).  Pages for the whole chunk
    (ceil(C/psz) worst case per sequence) are taken from the pool in ONE
    :func:`block_pool.alloc_n` call, so cost stays O(max_seqs * C),
    independent of the pool size m.  Returns (cache, ok[max_seqs]) — ok
    False where the allocation was denied or the chunk would overflow
    the page table; denied sequences append nothing (all-or-nothing).
    """
    S, C = k.shape[0], k.shape[1]
    psz = page_size(cache)
    maxp = cache.page_tables.shape[1]
    num_pages = cache.k_pages.shape[0]
    if active is None:
        active = jnp.ones((S,), bool)
    L = cache.seq_lens
    n = jnp.where(active, jnp.clip(lens.astype(jnp.int32), 0, C), 0)
    asked = n
    n, pages_before, counts = block_pool.chunk_page_plan(L, n, psz, maxp)

    kmax = -(-C // psz)                            # ceil(C / psz), static
    pool, ids = block_pool.alloc_n(cache.pool, counts, kmax)
    ok = active & (n == asked) & block_pool.granted_mask(ids, counts)
    n = jnp.where(ok, n, 0)

    rows = jnp.arange(S)[:, None]
    kk = jnp.arange(kmax)[None, :]
    slot = pages_before[:, None] + kk
    new_page = (kk < counts[:, None]) & ok[:, None] & (ids >= 0)
    slot = jnp.where(new_page, slot, maxp)         # out-of-range => dropped
    page_tables = cache.page_tables.at[rows, slot].set(ids, mode="drop")

    t = jnp.arange(C)[None, :]
    pos = L[:, None] + t                           # [S, C] absolute positions
    write = t < n[:, None]
    pid = page_tables[rows, jnp.minimum(pos // psz, maxp - 1)]
    write = write & (pid >= 0)
    pid = jnp.where(write, pid, num_pages)         # out-of-range => dropped
    pip = pos % psz
    k_pages = cache.k_pages.at[pid, pip].set(
        k.astype(cache.k_pages.dtype), mode="drop")
    v_pages = cache.v_pages.at[pid, pip].set(
        v.astype(cache.v_pages.dtype), mode="drop")

    seq_lens = L + n
    return PagedKVCache(pool, k_pages, v_pages, page_tables, seq_lens), ok


def rollback(cache: PagedKVCache, n_tokens: jax.Array) -> PagedKVCache:
    """Un-append the last ``n_tokens[s]`` tokens of each sequence.

    The cache-level form of the serving step's speculative rollback
    (DESIGN.md §10): a rejected draft keeps its accepted prefix and
    returns exactly the whole-page over-allocation — pages that hold no
    remaining token — to the pool, in one fixed-shape release
    (:func:`block_pool.free` refcount semantics: a page another
    sequence still maps merely loses one reference).  The partial page
    the surviving prefix ends in stays mapped; its stale tail positions
    sit beyond ``seq_lens`` and are overwritten by the next append
    before any read can see them.  O(max_seqs * max_pages_per_seq),
    independent of num_pages.
    """
    S, P = cache.page_tables.shape
    psz = page_size(cache)
    n = jnp.clip(n_tokens.astype(jnp.int32), 0, cache.seq_lens)
    new_len = cache.seq_lens - n
    keep_pages = (new_len + psz - 1) // psz
    have_pages = (cache.seq_lens + psz - 1) // psz
    k = jnp.arange(P, dtype=jnp.int32)[None, :]
    roll = (k >= keep_pages[:, None]) & (k < have_pages[:, None])
    to_free = jnp.where(roll, cache.page_tables, NULL)
    pool = block_pool.free(cache.pool, to_free.reshape(-1))
    page_tables = jnp.where(roll, NULL, cache.page_tables)
    return PagedKVCache(pool, cache.k_pages, cache.v_pages,
                        page_tables, new_len)


def release(cache: PagedKVCache, seq_mask: jax.Array) -> PagedKVCache:
    """Release all pages of the masked sequences (one batch call).

    Each page loses one reference; pages still mapped by a
    prefix-sharing sibling stay live (release decrements instead of
    frees — :func:`block_pool.free`'s refcount semantics).
    O(max_seqs * max_pages_per_seq) scatter — independent of num_pages.
    """
    S, P = cache.page_tables.shape
    to_free = jnp.where(seq_mask[:, None], cache.page_tables, NULL)
    pool = block_pool.free(cache.pool, to_free.reshape(-1))
    page_tables = jnp.where(seq_mask[:, None], NULL, cache.page_tables)
    seq_lens = jnp.where(seq_mask, 0, cache.seq_lens)
    return PagedKVCache(pool, cache.k_pages, cache.v_pages,
                        page_tables, seq_lens)


def share_prefix(cache: PagedKVCache, dst: int, src: int,
                 n_tokens: jax.Array) -> Tuple["PagedKVCache", jax.Array]:
    """Map ``n_tokens`` of seq ``src``'s prefix into seq ``dst`` (static
    dst/src, traced n_tokens) — the refcount/COW protocol at the cache
    level (the serving engine runs the same protocol over the
    DecodeState's layer stack, see serving/prefix_cache.py).

    Full pages are shared: dst's table points at src's pages and each
    gains a reference (:func:`block_pool.addref`).  A partial tail page
    is copied-on-write: one fresh page from the pool, src's page
    content copied, so dst's first divergent append never touches the
    shared page.  seq_lens[dst] = n_tokens.  Returns (cache, ok) — ok
    False (nothing changed) if the COW allocation was denied or src's
    prefix is not resident.
    """
    psz = page_size(cache)
    maxp = cache.page_tables.shape[1]
    num_pages = cache.k_pages.shape[0]
    n_tokens = jnp.asarray(n_tokens, jnp.int32)
    fp = n_tokens // psz
    partial = n_tokens % psz
    src_row = cache.page_tables[src]
    np_needed = (n_tokens + psz - 1) // psz
    donor_ok = ((cache.seq_lens[src] >= n_tokens) &
                (src_row[jnp.clip(np_needed - 1, 0, maxp - 1)] >= 0))

    want = jnp.zeros((cache.seq_lens.shape[0],), bool).at[dst].set(
        (partial > 0) & donor_ok)
    pool, fresh = block_pool.alloc(cache.pool, want)
    fresh_id = fresh[dst]
    ok = donor_ok & ((partial == 0) | (fresh_id >= 0))

    k = jnp.arange(maxp, dtype=jnp.int32)
    shared_ids = jnp.where((k < fp) & ok, src_row, NULL)
    pool = block_pool.addref(pool, shared_ids)
    row = jnp.where(k < fp, src_row, cache.page_tables[dst])
    row = jnp.where((k == fp) & (partial > 0) & (fresh_id >= 0),
                    fresh_id, row)
    page_tables = cache.page_tables.at[dst].set(
        jnp.where(ok, row, cache.page_tables[dst]))

    src_pid = jnp.maximum(src_row[jnp.clip(fp, 0, maxp - 1)], 0)
    tgt = jnp.where(ok & (partial > 0) & (fresh_id >= 0), fresh_id,
                    num_pages)                     # out-of-range => drop
    k_pages = cache.k_pages.at[tgt].set(cache.k_pages[src_pid], mode="drop")
    v_pages = cache.v_pages.at[tgt].set(cache.v_pages[src_pid], mode="drop")
    seq_lens = cache.seq_lens.at[dst].set(
        jnp.where(ok, n_tokens, cache.seq_lens[dst]))
    return PagedKVCache(pool, k_pages, v_pages, page_tables, seq_lens), ok


def gather_kv(cache: PagedKVCache, seq_id: int | jax.Array,
              max_len: int) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Materialize a sequence's K/V up to max_len (reference path / tests).

    Production attention reads pages directly via the kernel; this is the
    jnp oracle used by ref implementations and the CPU dry-run path.
    """
    psz = page_size(cache)
    n_pages = -(-max_len // psz)   # round UP: a partial page still holds
    table = jax.lax.dynamic_slice(  # live tokens; masking trims the tail
        cache.page_tables, (seq_id, 0), (1, n_pages))[0]
    safe = jnp.maximum(table, 0)
    k = cache.k_pages[safe].reshape(n_pages * psz, *cache.k_pages.shape[2:])
    v = cache.v_pages[safe].reshape(n_pages * psz, *cache.v_pages.shape[2:])
    pos = jnp.arange(n_pages * psz)
    valid = ((pos < cache.seq_lens[seq_id]) & (pos < max_len)
             & jnp.repeat(table >= 0, psz))
    return k, v, valid
