"""P-SIM universal construction + stack, with the paper's memory management.

Faithful implementation of Figures 1 and 2 of the paper (reproduced from
Fatourou & Kallimanis [10]) plus the modifications of Section 4.1 that
turn it into Result 2:

* the fetch-and-add on ``Toggles`` is replaced by an array of single-writer
  registers (the paper: "the array toggles can instead be implemented as an
  array of registers without affecting any theoretical bounds");
* the LL/SC object ``S`` is the constant-time pointer-width LL/SC-from-CAS
  of Blelloch & Wei DISC'20 (see :class:`repro.core.sim.LLSC`) instead of a
  timestamped CAS, so no unbounded sequence numbers are hidden in words;
* stack nodes are allocated from the *caller's private pool* via the
  ``alloc_node`` / ``free_node`` callbacks (``allocate_private`` /
  ``free_private`` of Figure 4) — the paper's recursion trick;
* each ``Attempt`` iteration tracks locally-pushed and locally-popped
  nodes: on SC failure (or a failed VL) the locally-pushed nodes are freed
  (they never became visible); on SC success the locally-popped nodes are
  freed (they are now popped from the global state);
* the dangerous dereference of ``pst->top`` in ``local_pop`` (the paper's
  line 61 read of ``top->next``, plus the ``top->data`` read that the
  stack-of-batches use needs — see DESIGN.md §2a clarification) is guarded
  by an immediate ``VL(S)``: if the VL fails the iteration is aborted, so a
  freed node's garbage words are never acted upon.

Return values: ``rvals[a]`` stores the popped node's *data* word (the
batch pointer), not the node pointer, because the node itself is freed by
the applier on a successful SC.  Values are carried forward by the state
record copies exactly as in P-SIM.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Generator, List, Optional, Tuple

from .memory import BlockMemory
from .sim import LLSC, NULL, RegisterArray, SimContext, Step

# Node layout inside a k>=2-word block (see memory.py):
NODE_DATA = 0   # word 0: data (pointer to the batch's first block)
NODE_NEXT = 1   # word 1: next node in the shared stack

PUSH = "push"
POP = "pop"


@dataclass
class Request:
    """An announced operation (a single word: pointer to this record)."""

    op: str                 # PUSH | POP
    arg: int = NULL         # batch pointer for PUSH
    seq: int = 0            # sim-internal id for the applied-exactly-once monitor


@dataclass
class StRec:
    """P-SIM state record: stack top + applied bits + return values.

    ``2p + 1`` words of shared memory; copied field-by-field (each field
    copy is one shared-memory instruction, interruptible between fields —
    torn copies are discarded by the VL that follows, as in P-SIM).
    """

    st_top: int
    applied: List[int]
    rvals: List[Any]
    owner: int = -1          # sim-internal (recycling monitor)
    slot: int = 0            # sim-internal


class PSimStack:
    """Shared stack of batches (Result 2)."""

    def __init__(
        self,
        ctx: SimContext,
        mem: BlockMemory,
        alloc_node: Callable[[int], Generator],
        free_node: Callable[[int, int], Generator],
        init_top: int = NULL,
    ):
        p = ctx.nprocs
        self.ctx = ctx
        self.mem = mem
        self.alloc_node = alloc_node
        self.free_node = free_node
        self.announce = RegisterArray(ctx, p, init=None, category="psim_announce")
        self.toggles = RegisterArray(ctx, p, init=0, category="psim_toggles")
        # Pool[1..p+1][1..2] of state records: 2(p+1) records of 2p+1 words.
        ctx.add_space("psim_pool", 2 * (p + 1) * (2 * p + 1))
        self.pool: List[List[StRec]] = [
            [
                StRec(NULL, [0] * p, [None] * p, owner=i, slot=s)
                for s in range(2)
            ]
            for i in range(p + 1)
        ]
        init_rec = self.pool[p][0]
        init_rec.st_top = init_top
        self.S = LLSC(ctx, init=init_rec, category="psim_S")
        # thread-local state.  The paper's `toggle = 2^i` + fetch-and-add
        # makes the first announce flip Toggles bit i from 0 to 1; with an
        # array of registers the equivalent is: start at 0, flip before
        # each announce write (so the first announced value is 1 != the
        # initial applied[] of 0).
        self._toggle = [0] * p
        self._index = [1] * p    # next slot to use (0/1); paper's `index`
        self._seq = 0
        # monitors / stats
        self.applied_seqs: set = set()
        self.installed_count = 0
        self.alloc_calls_by = [0] * p
        self.free_calls_by = [0] * p
        self.last_op_internal_calls: Optional[Tuple[int, int]] = None

    # -- public API ----------------------------------------------------------
    def push(self, pid: int, batch_ptr: int) -> Generator:
        """Linearizable push of a batch pointer.  O(p) instructions."""
        req = self._new_request(PUSH, batch_ptr)
        return (yield from self._apply_op(pid, req))

    def pop(self, pid: int) -> Generator:
        """Linearizable pop; returns a batch pointer or NULL.  O(p)."""
        req = self._new_request(POP)
        return (yield from self._apply_op(pid, req))

    def _new_request(self, op: str, arg: int = NULL) -> Request:
        self._seq += 1
        return Request(op, arg, self._seq)

    # -- P-SIM core (Figure 1 + Section 4.1 modifications) --------------------
    def _apply_op(self, pid: int, req: Request) -> Generator:
        """PSimApplyOp — announce, flip toggle, Attempt, read rvals."""
        a0, f0 = self.alloc_calls_by[pid], self.free_calls_by[pid]
        yield from self.announce.write(pid, pid, req)
        self._toggle[pid] ^= 1
        yield from self.toggles.write(pid, pid, self._toggle[pid])
        yield from self._attempt(pid)
        rec = yield from self.S.read(pid)
        result = yield from self._read_rval(pid, rec, pid)
        self.last_op_internal_calls = (
            self.alloc_calls_by[pid] - a0, self.free_calls_by[pid] - f0)
        return result

    def _read_rval(self, pid: int, rec: StRec, slot: int) -> Generator:
        yield Step
        self.ctx.global_step += 1
        self.ctx.charge(pid)
        return rec.rvals[slot]

    def _attempt(self, pid: int) -> Generator:
        p = self.ctx.nprocs
        for _j in range(2):
            ls = yield from self.S.ll(pid)                       # line 28
            rec = self.pool[pid][self._index[pid]]
            if rec is self.S.peek():                              # monitor only
                self.ctx.violation(
                    f"process {pid} overwrites the installed record")
            # Pool[i][index] = *ls_ptr  (field-by-field copy, line 29)
            yield from self._copy_rec(pid, ls, rec)
            ok = yield from self.S.vl(pid)                        # line 30
            if not ok:
                continue
            ltoggles = yield from self.toggles.read_all(pid)      # line 32
            locally_pushed: List[int] = []
            locally_popped: List[int] = []
            aborted = False
            for a in range(p):                                    # line 33
                yield from self.ctx.local_step(pid)
                if ltoggles[a] != rec.applied[a]:                 # line 35
                    request = yield from self.announce.read(pid, a)
                    ok = yield from self._apply_local(
                        pid, rec, a, request, locally_pushed, locally_popped)
                    if not ok:           # VL failed inside local_pop
                        aborted = True
                        break
                    rec.applied[a] = ltoggles[a]                  # line 39
            if aborted:
                # free nodes allocated by local_push ops this iteration
                yield from self._free_all(pid, locally_pushed)
                continue
            success = yield from self.S.sc(pid, rec)              # line 40
            if success:
                self.installed_count += 1
                for seqno in rec_applied_seqs(rec):
                    if seqno in self.applied_seqs:
                        self.ctx.violation(f"request {seqno} applied twice")
                    self.applied_seqs.add(seqno)
                rec.meta_applied = []                              # reset
                self._index[pid] ^= 1                             # line 41
                yield from self._free_all(pid, locally_popped)
            else:
                yield from self._free_all(pid, locally_pushed)

    def _copy_rec(self, pid: int, src: StRec, dst: StRec) -> Generator:
        """Copy a (2p+1)-word state record, one word per instruction."""
        p = self.ctx.nprocs
        yield Step
        self.ctx.global_step += 1
        self.ctx.charge(pid)
        dst.st_top = src.st_top
        dst.meta_applied = []   # sim-internal: only NEW applications tracked
        for i in range(p):
            yield Step
            self.ctx.global_step += 1
            self.ctx.charge(pid)
            dst.applied[i] = src.applied[i]
        for i in range(p):
            yield Step
            self.ctx.global_step += 1
            self.ctx.charge(pid)
            dst.rvals[i] = src.rvals[i]

    def _apply_local(
        self,
        pid: int,
        rec: StRec,
        a: int,
        request: Request,
        locally_pushed: List[int],
        locally_popped: List[int],
    ) -> Generator:
        """Apply one announced request to the local record.

        Returns False iff a VL guard failed (iteration must abort).
        """
        if request.op == PUSH:                                    # Figure 2, local_push
            nd = yield from self._alloc(pid)
            yield from self.mem.write(pid, nd, NODE_DATA, request.arg)
            yield from self.mem.write(pid, nd, NODE_NEXT, rec.st_top)
            yield from self.ctx.local_step(pid)
            rec.st_top = nd
            locally_pushed.append(nd)
            rec.rvals[a] = True
        else:                                                     # local_pop
            yield from self.ctx.local_step(pid)
            ret = rec.st_top
            if ret == NULL:
                rec.rvals[a] = NULL
            else:
                data = yield from self.mem.read(pid, ret, NODE_DATA)
                nxt = yield from self.mem.read(pid, ret, NODE_NEXT)
                ok = yield from self.S.vl(pid)   # paper's VL-after-line-61 guard
                if not ok:
                    return False
                rec.st_top = nxt
                rec.rvals[a] = data
                locally_popped.append(ret)
        if not hasattr(rec, "meta_applied"):
            rec.meta_applied = []
        rec.meta_applied.append(request.seq)
        return True

    # -- node allocation bookkeeping ------------------------------------------
    def _alloc(self, pid: int) -> Generator:
        self.alloc_calls_by[pid] += 1
        nd = yield from self.alloc_node(pid)
        return nd

    def _free(self, pid: int, nd: int) -> Generator:
        self.free_calls_by[pid] += 1
        yield from self.free_node(pid, nd)

    def _free_all(self, pid: int, nodes: List[int]) -> Generator:
        """Free a list of nodes with a loop-bookkeeping step between frees.

        The interleaved local step also guarantees a suspension point
        *outside* any private-pool critical section between consecutive
        frees, so deamortization slices stay O(1) (see allocator.py).
        """
        for nd in nodes:
            yield from self.ctx.local_step(pid)
            yield from self._free(pid, nd)

    # -- test helpers (no step charges) ----------------------------------------
    def snapshot_stack(self) -> List[Tuple[int, int]]:
        """[(node, data), ...] from top; sim-internal, for checkers."""
        out = []
        node = self.S.peek().st_top
        while node != NULL:
            out.append((node, self.mem.words[node][NODE_DATA]))
            node = self.mem.words[node][NODE_NEXT]
        return out


def rec_applied_seqs(rec: StRec) -> List[int]:
    return list(getattr(rec, "meta_applied", []))
