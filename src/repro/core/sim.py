"""Simulated asynchronous shared memory with instruction-level scheduling.

This module is the substrate for the *faithful* reproduction of
"Concurrent Fixed-Size Allocation and Free in Constant Time"
(Blelloch & Wei, 2020).  Every shared-memory instruction (read / write /
CAS / LL / VL / SC, and block-word accesses) is one atomic *step* of a
process coroutine.  Process code is written as Python generators; each
primitive is invoked as ``value = yield from obj.op(pid, ...)`` which

  1. yields once (a scheduling point *before* the instruction), then
  2. executes the instruction atomically (the simulator is single
     threaded, so everything between two yields is atomic), and
  3. charges one instruction to the process's current operation.

The paper's time complexity counts local and shared instructions; we
charge local O(1) bookkeeping via :meth:`SimContext.local_step` where it
corresponds to real work (loop iterations, stack pointer updates).

Space accounting: every shared object registers its word count with the
context under a category, so benchmarks can verify the Theta(p^2)
metadata bound of Result 1.
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Generator, List, Optional, Tuple

NULL = -1  # null pointer in simulated memory (block index / record id)

Step = None  # what primitives yield; the scheduler ignores the value


@dataclass
class OpRecord:
    """One high-level operation instance in the history."""

    opid: int
    pid: int
    name: str
    arg: Any
    invoke_step: int
    steps: int = 0                  # instructions charged to this op
    result: Any = None
    response_step: Optional[int] = None
    meta: Dict[str, Any] = field(default_factory=dict)

    @property
    def completed(self) -> bool:
        return self.response_step is not None


class SimContext:
    """Global simulation state: step counts, history, space accounting."""

    def __init__(self, nprocs: int, seed: int = 0):
        self.nprocs = nprocs
        self.global_step = 0
        self.current_op: List[Optional[OpRecord]] = [None] * nprocs
        self.history: List[OpRecord] = []
        self._opid = itertools.count()
        self.space_words: Dict[str, int] = {}
        self.monitors: List[Callable[[], None]] = []
        self.violations: List[str] = []

    # -- operation history -------------------------------------------------
    def begin_op(self, pid: int, name: str, arg: Any = None) -> OpRecord:
        rec = OpRecord(next(self._opid), pid, name, arg, self.global_step)
        self.current_op[pid] = rec
        self.history.append(rec)
        return rec

    def end_op(self, rec: OpRecord, result: Any = None) -> None:
        rec.result = result
        rec.response_step = self.global_step
        if self.current_op[rec.pid] is rec:
            self.current_op[rec.pid] = None

    # -- step accounting ---------------------------------------------------
    def charge(self, pid: int, n: int = 1) -> None:
        rec = self.current_op[pid]
        if rec is not None:
            rec.steps += n

    def local_step(self, pid: int) -> Generator:
        """One unit of local O(1) work (counted, schedulable)."""
        yield Step
        self.global_step += 1
        self.charge(pid)

    # -- space accounting ----------------------------------------------------
    def add_space(self, category: str, words: int) -> None:
        self.space_words[category] = self.space_words.get(category, 0) + words

    def total_space(self, exclude: Tuple[str, ...] = ()) -> int:
        return sum(v for k, v in self.space_words.items() if k not in exclude)

    # -- invariant monitors --------------------------------------------------
    def check_monitors(self) -> None:
        for m in self.monitors:
            m()

    def violation(self, msg: str) -> None:
        self.violations.append(msg)


class _Shared:
    def __init__(self, ctx: SimContext, category: str, words: int):
        self.ctx = ctx
        ctx.add_space(category, words)

    def _tick(self, pid: int) -> None:
        self.ctx.global_step += 1
        self.ctx.charge(pid)


class Register(_Shared):
    """Word-sized atomic register."""

    def __init__(self, ctx: SimContext, init: Any = 0, category: str = "register"):
        super().__init__(ctx, category, 1)
        self.value = init

    def read(self, pid: int) -> Generator:
        yield Step
        self._tick(pid)
        return self.value

    def write(self, pid: int, v: Any) -> Generator:
        yield Step
        self._tick(pid)
        self.value = v


class RegisterArray(_Shared):
    """Array of word-sized registers (one instruction per element access)."""

    def __init__(self, ctx: SimContext, n: int, init: Any = 0,
                 category: str = "register"):
        super().__init__(ctx, category, n)
        self.values = [init] * n

    def read(self, pid: int, idx: int) -> Generator:
        yield Step
        self._tick(pid)
        return self.values[idx]

    def write(self, pid: int, idx: int, v: Any) -> Generator:
        yield Step
        self._tick(pid)
        self.values[idx] = v

    def read_all(self, pid: int) -> Generator:
        """n instructions (used for the Toggles array: the paper notes the
        fetch-and-add is only an optimization and an array of registers
        preserves all bounds)."""
        out = []
        for i in range(len(self.values)):
            out.append((yield from self.read(pid, i)))
        return out


class CASWord(_Shared):
    """Word-sized CAS object supporting read and CAS."""

    def __init__(self, ctx: SimContext, init: Any = 0, category: str = "cas"):
        super().__init__(ctx, category, 1)
        self.value = init

    def read(self, pid: int) -> Generator:
        yield Step
        self._tick(pid)
        return self.value

    def cas(self, pid: int, expected: Any, new: Any) -> Generator:
        yield Step
        self._tick(pid)
        if self.value == expected:
            self.value = new
            return True
        return False


class LLSC(_Shared):
    """Pointer-width LL/SC object.

    The paper builds LL/SC from pointer-width CAS via Blelloch & Wei
    (DISC'20, "LL/SC and atomic copy"), which gives O(1)-time LL/VL/SC
    with O(c p^2) space and *no* unbounded sequence numbers.  The paper
    uses that construction as a black box, and so do we: this class
    provides exact LL/SC semantics at O(1) simulated instructions per
    call, and registers the cited O(p^2) words (c = 1) so the space
    benchmarks account for it honestly.  A tag-based from-CAS backend
    (:class:`LLSCFromTaggedCAS`) is provided for cross-checking
    semantics; it would need unbounded tags in a real word, which is
    exactly what the DISC'20 construction removes.
    """

    def __init__(self, ctx: SimContext, init: Any = None, nprocs: Optional[int] = None,
                 category: str = "llsc"):
        p = ctx.nprocs if nprocs is None else nprocs
        # Cited bound: O(c p^2) words with c = 1 outstanding LL per process.
        super().__init__(ctx, category, p * p)
        self.value = init
        self._version = 0                      # sim-internal, not algorithm state
        self._link: Dict[int, int] = {}        # pid -> version at last LL

    def ll(self, pid: int) -> Generator:
        yield Step
        self._tick(pid)
        self._link[pid] = self._version
        return self.value

    def read(self, pid: int) -> Generator:
        """Plain read (no link established)."""
        yield Step
        self._tick(pid)
        return self.value

    def vl(self, pid: int) -> Generator:
        yield Step
        self._tick(pid)
        return self._link.get(pid) == self._version

    def sc(self, pid: int, new: Any) -> Generator:
        yield Step
        self._tick(pid)
        if self._link.get(pid) == self._version:
            self.value = new
            self._version += 1
            return True
        return False

    # non-linearizable peek for monitors/tests only (no step charge)
    def peek(self) -> Any:
        return self.value


class LLSCFromTaggedCAS(_Shared):
    """LL/SC simulated from CAS with (value, tag) pairs.

    This is the classic construction the paper *avoids* (it needs an
    unbounded tag packed into the word).  Provided to cross-validate the
    semantics of :class:`LLSC` in tests.
    """

    def __init__(self, ctx: SimContext, init: Any = None, category: str = "llsc_tagged"):
        super().__init__(ctx, category, 1)
        self._cell: Tuple[Any, int] = (init, 0)
        self._link: Dict[int, Tuple[Any, int]] = {}

    def ll(self, pid: int) -> Generator:
        yield Step
        self._tick(pid)
        self._link[pid] = self._cell
        return self._cell[0]

    def read(self, pid: int) -> Generator:
        yield Step
        self._tick(pid)
        return self._cell[0]

    def vl(self, pid: int) -> Generator:
        yield Step
        self._tick(pid)
        return self._link.get(pid) == self._cell

    def sc(self, pid: int, new: Any) -> Generator:
        yield Step
        self._tick(pid)
        if self._link.get(pid) == self._cell:
            self._cell = (new, self._cell[1] + 1)
            return True
        return False

    def peek(self) -> Any:
        return self._cell[0]
