"""Two-level (private / shared) block pool — the paper's structure in SPMD.

Each *lane* (a serving request slot or a data-parallel shard) owns a
private stack of block ids with capacity ``3 * ell``; a shared pool
(:mod:`block_pool`) holds the rest.  Exactly as in the paper:

* ``alloc`` / ``free`` touch **only the lane's private stack** — O(1)
  array ops per lane, fully vectorized across lanes, no cross-lane
  coordination (the common case);
* ``rebalance`` is the deamortized shared-pool traffic: lanes whose
  private pool dropped below ``ell`` pull a batch of ``ell`` blocks from
  the shared pool, lanes that exceed ``3*ell - ell`` push a batch back.
  It is called once per engine step, off the per-token critical path —
  the moral equivalent of ``run_delayed_step``.

Invariant (paper section 4.2): with ell >= max per-step demand, a lane's
private pool never runs dry between rebalances, so ``alloc`` never needs
the shared pool synchronously.
"""

from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from . import block_pool
from .block_pool import BlockPool, NULL


class HierPool(NamedTuple):
    shared: BlockPool
    private_ids: jax.Array    # int32[L, 3*ell] — per-lane stacks
    private_top: jax.Array    # int32[L]
    ell: jax.Array            # int32 scalar — batch size (static-ish)


def create(num_blocks: int, num_lanes: int, ell: int) -> HierPool:
    """All blocks start in the shared pool except one warm batch per lane."""
    cap = 3 * ell
    assert num_blocks >= num_lanes * ell, "need >= one batch per lane"
    shared = block_pool.create(num_blocks)
    private_ids = jnp.full((num_lanes, cap), NULL, dtype=jnp.int32)
    private_top = jnp.zeros((num_lanes,), dtype=jnp.int32)
    pool = HierPool(shared, private_ids, private_top, jnp.int32(ell))
    # warm every lane with one batch (sequential init, not on hot path)
    def warm(i, pool):
        shared, ids = block_pool.alloc_batch(pool.shared, ell)
        private_ids = jax.lax.dynamic_update_slice(
            pool.private_ids, ids[None, :], (i, 0))
        private_top = pool.private_top.at[i].set(ell)
        return HierPool(shared, private_ids, private_top, pool.ell)
    return jax.lax.fori_loop(0, num_lanes, warm, pool)


def alloc(pool: HierPool, want: jax.Array) -> Tuple[HierPool, jax.Array]:
    """Per-lane allocate: want bool[L] -> ids int32[L] (NULL if denied).

    Touches only private state: one gather + one subtract per lane.
    """
    want = want.astype(jnp.int32)
    have = pool.private_top > 0
    take = (want == 1) & have
    idx = jnp.maximum(pool.private_top - 1, 0)
    ids = jnp.take_along_axis(pool.private_ids, idx[:, None], axis=1)[:, 0]
    ids = jnp.where(take, ids, NULL)
    new_top = pool.private_top - take.astype(jnp.int32)
    return pool._replace(private_top=new_top), ids


def alloc_n(pool: HierPool, counts: jax.Array,
            max_per_lane: int) -> Tuple[HierPool, jax.Array]:
    """Per-lane batched allocate: counts int32[L] -> ids int32[L, K].

    The chunked-demand fast path: a lane appending C tokens per step
    needs up to ceil(C / page_size) blocks at once.  All-or-nothing per
    lane, private-stack only — with the §4.2 invariant ``ell >= max
    per-step demand`` a lane's private pool never runs dry between
    rebalances, so this never touches the shared pool.  O(L * K) work.
    """
    counts = jnp.clip(counts.astype(jnp.int32), 0, max_per_lane)
    ok = counts <= pool.private_top
    n = jnp.where(ok, counts, 0)
    k = jnp.arange(max_per_lane, dtype=jnp.int32)[None, :]
    want = k < n[:, None]
    idx = jnp.maximum(pool.private_top[:, None] - 1 - k, 0)
    ids = jnp.take_along_axis(pool.private_ids, idx, axis=1)
    ids = jnp.where(want, ids, NULL)
    return pool._replace(private_top=pool.private_top - n), ids


def free(pool: HierPool, ids: jax.Array) -> HierPool:
    """Per-lane free: ids int32[L] (NULL = no-op for that lane).

    Frees go to the lane's own private pool, as in the paper.  If a
    private stack is at capacity the block spills directly to the shared
    pool (bounded leak path; rebalance keeps this rare).
    """
    valid = ids >= 0
    cap = pool.private_ids.shape[1]
    fits = pool.private_top < cap
    local = valid & fits
    pos = jnp.where(local, pool.private_top, 0)
    rows = jnp.arange(ids.shape[0])
    private_ids = pool.private_ids.at[rows, pos].set(
        jnp.where(local, ids, pool.private_ids[rows, pos]))
    private_top = pool.private_top + local.astype(jnp.int32)
    spill = jnp.where(valid & ~fits, ids, NULL)
    shared = block_pool.free(pool.shared, spill)
    return HierPool(shared, private_ids, private_top, pool.ell)


def rebalance(pool: HierPool) -> HierPool:
    """Deamortized shared-pool traffic (one call per engine step).

    Each lane moves at most one batch of ``ell`` blocks per call:
      * refill if private_top <  ell      (paper: pop a batch)
      * drain  if private_top > 2*ell     (paper: push a batch at 3*ell;
        2*ell keeps headroom for a full step of frees, mirroring the
        paper's ell >= 3p slack)
    Work is O(L * ell) per call, independent of pool size m.
    """
    L, cap = pool.private_ids.shape

    def lane_step(i, pool):
        ell = pool.ell
        top = pool.private_top[i]

        def refill(pool):
            shared, ids = block_pool.alloc_batch(
                pool.shared, int(pool.private_ids.shape[1]) // 3)
            got = ids[0] >= 0
            top = pool.private_top[i]
            # place batch above current top
            updated = jax.lax.dynamic_update_slice(
                pool.private_ids[i], ids, (top,))
            private_ids = pool.private_ids.at[i].set(
                jnp.where(got, updated, pool.private_ids[i]))
            private_top = pool.private_top.at[i].add(
                jnp.where(got, ids.shape[0], 0))
            return HierPool(shared, private_ids, private_top, pool.ell)

        def drain(pool):
            n = int(pool.private_ids.shape[1]) // 3
            top = pool.private_top[i]
            start = top - n
            ids = jax.lax.dynamic_slice(pool.private_ids[i], (start,), (n,))
            shared = block_pool.free_batch(pool.shared, ids)
            private_top = pool.private_top.at[i].add(-n)
            return HierPool(shared, pool.private_ids, private_top, pool.ell)

        pool = jax.lax.cond(top < ell, refill, lambda p: p, pool)
        top2 = pool.private_top[i]
        pool = jax.lax.cond(top2 > 2 * ell, drain, lambda p: p, pool)
        return pool

    return jax.lax.fori_loop(0, L, lane_step, pool)


def total_free(pool: HierPool) -> jax.Array:
    return pool.shared.top + jnp.sum(pool.private_top)
