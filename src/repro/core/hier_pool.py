"""Two-level (private / shared) block pool — the paper's structure in SPMD.

Each *lane* (a serving request slot) owns a private stack of block ids
with capacity ``3 * ell``; a shared pool (:mod:`block_pool`) holds the
rest.  Exactly as in the paper:

* ``alloc`` / ``alloc_n`` / ``free`` / ``free_n`` touch **only the
  lane's private stack** — O(1)/O(K) array ops per lane, fully
  vectorized across lanes, no cross-lane coordination (the common
  case);
* ``rebalance`` is the deamortized shared-pool traffic: lanes whose
  private pool dropped below ``ell`` pull a batch of ``ell`` blocks from
  the shared pool, lanes that exceed ``2*ell`` push a batch back.  It is
  called once per engine step, off the per-token critical path — the
  moral equivalent of ``run_delayed_step``.  Both phases are one
  fixed-shape gather/scatter across all lanes (no per-lane loop);
  drains run first so their batches can serve the same call's refills.

Invariant (paper section 4.2): with ell >= max per-step demand, a lane's
private pool never runs dry between rebalances, so ``alloc`` never needs
the shared pool synchronously.

Reference counting rides on the shared :class:`BlockPool`'s per-block
``refcount`` (blocks parked in private lanes are free, refcount 0):
user grants stamp refcount 1, :func:`addref` registers prefix sharers,
and ``free_n`` only returns a block to a stack when its count reaches
zero — release decrements instead of frees.

Serving state carries one HierPool per DP shard (leaves get a leading
``[DP, ...]`` axis); the ``*_dp`` wrappers vmap every operation over
that axis so page ids stay shard-local.
"""

from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import block_pool
from .block_pool import BlockPool, NULL


class HierPool(NamedTuple):
    shared: BlockPool         # shared stack + the pool-wide refcounts
    private_ids: jax.Array    # int32[L, 3*ell] — per-lane stacks
    private_top: jax.Array    # int32[L]

    # ell is not stored: the lane capacity encodes it (3*ell), and every
    # consumer derives it statically via ``lane_ell`` — no redundant
    # state to disagree with the shapes.


def create(num_blocks: int, num_lanes: int, ell: int) -> HierPool:
    """All blocks start in the shared pool except one warm batch per lane.

    The warm-up is ONE batched carve of ``num_lanes * ell`` ids off the
    shared stack (lane i receives exactly the batch the old sequential
    ``alloc_batch`` loop handed it) — O(1) compiled ops, not O(lanes)
    loop iterations.
    """
    cap = 3 * ell
    assert num_blocks >= num_lanes * ell, "need >= one batch per lane"
    shared = block_pool.create(num_blocks)
    n = num_lanes * ell
    carve = shared.free_ids[num_blocks - n:]
    private_ids = jnp.full((num_lanes, cap), NULL, dtype=jnp.int32)
    # lane i gets carve slice [n - (i+1)*ell : n - i*ell] == reversed rows
    private_ids = private_ids.at[:, :ell].set(
        carve.reshape(num_lanes, ell)[::-1])
    private_top = jnp.full((num_lanes,), ell, dtype=jnp.int32)
    shared = shared._replace(top=shared.top - n)
    return HierPool(shared, private_ids, private_top)


def lane_ell(pool: HierPool) -> int:
    """The lane batch size, derived from the (static) lane capacity."""
    return pool.private_ids.shape[-1] // 3


def validate_plan(num_blocks: int, num_lanes: int, ell: int,
                  max_live: int, *, degraded_ok: bool = False,
                  what: str = "pool") -> bool:
    """Plan-time §4.2 never-dry validation (engine/sizing layer).

    :func:`create` only asserts ``num_blocks >= num_lanes * ell`` — one
    warm batch per lane — which is enough to *construct* the pool but
    NOT enough for the paper's §4.2 never-dry-by-construction argument:
    ``rebalance`` guarantees every lane leaves with >= ell blocks only
    when the pool-wide slack over the worst-case live demand is at
    least ``3 * ell * num_lanes`` (each lane may hold up to its full
    3*ell capacity while another sits empty).  A config that passes the
    create assert but lacks that slack compiles and runs — and its
    lanes can run dry mid-step (negative never-dry margin, NULL grants
    on the hot path).

    Raises ``ValueError`` when the slack is insufficient, unless
    ``degraded_ok`` — the documented degraded mode: the pool still
    conserves blocks and ``alloc_n_or_shared`` falls back to the shared
    stack synchronously, but the O(1)-per-lane hot-path guarantee is
    forfeit.  Returns True when fully provisioned, False when admitted
    degraded.
    """
    slack = num_blocks - max_live
    need = 3 * ell * num_lanes
    if slack >= need:
        return True
    msg = (f"{what}: num_blocks={num_blocks} leaves slack {slack} over "
           f"max_live={max_live}, but the §4.2 never-dry argument needs "
           f"3*ell*L = {need} (ell={ell}, lanes={num_lanes}); lanes can "
           f"run dry between rebalances. Provision num_blocks >= "
           f"{max_live + need}, or pass degraded_ok to accept "
           f"synchronous shared-pool fallback on the hot path.")
    if not degraded_ok:
        raise ValueError(msg)
    return False


def alloc(pool: HierPool, want: jax.Array) -> Tuple[HierPool, jax.Array]:
    """Per-lane allocate: want bool[L] -> ids int32[L] (NULL if denied).

    Touches only private state: one gather + one subtract per lane.
    Granted blocks are stamped refcount 1.
    """
    want = want.astype(jnp.int32)
    have = pool.private_top > 0
    take = (want == 1) & have
    idx = jnp.maximum(pool.private_top - 1, 0)
    ids = jnp.take_along_axis(pool.private_ids, idx[:, None], axis=1)[:, 0]
    ids = jnp.where(take, ids, NULL)
    new_top = pool.private_top - take.astype(jnp.int32)
    shared = pool.shared._replace(
        refcount=block_pool._set_ref(pool.shared.refcount, ids, 1))
    return pool._replace(shared=shared, private_top=new_top), ids


def alloc_n(pool: HierPool, counts: jax.Array,
            max_per_lane: int) -> Tuple[HierPool, jax.Array]:
    """Per-lane batched allocate: counts int32[L] -> ids int32[L, K].

    The chunked-demand fast path: a lane appending C tokens per step
    needs up to ceil(C / page_size) blocks at once.  All-or-nothing per
    lane, private-stack only — with the §4.2 invariant ``ell >= max
    per-step demand`` a lane's private pool never runs dry between
    rebalances, so this never touches the shared pool.  Granted blocks
    are stamped refcount 1.  O(L * K) work.
    """
    counts = jnp.clip(counts.astype(jnp.int32), 0, max_per_lane)
    ok = counts <= pool.private_top
    n = jnp.where(ok, counts, 0)
    k = jnp.arange(max_per_lane, dtype=jnp.int32)[None, :]
    want = k < n[:, None]
    idx = jnp.maximum(pool.private_top[:, None] - 1 - k, 0)
    ids = jnp.take_along_axis(pool.private_ids, idx, axis=1)
    ids = jnp.where(want, ids, NULL)
    shared = pool.shared._replace(
        refcount=block_pool._set_ref(pool.shared.refcount, ids, 1))
    return pool._replace(shared=shared,
                         private_top=pool.private_top - n), ids


def alloc_or_shared(pool: HierPool, want: jax.Array
                    ) -> Tuple[HierPool, jax.Array]:
    """Lane-first allocate with a synchronous shared-pool fallback.

    The paper's general algorithm: an empty private pool pulls from the
    shared pool.  The serving hot path never needs the fallback (§4.2
    sizing + the per-step rebalance keep lanes stocked), but callers
    looping raw ``decode_step`` without a rebalance must degrade to the
    shared pool rather than silently corrupt KV once a lane's warm
    stock is gone."""
    pool, ids = alloc(pool, want)
    miss = want & (ids < 0)
    shared, got = block_pool.alloc(pool.shared, miss)
    ids = jnp.where(miss, got, ids)
    return pool._replace(shared=shared), ids


def alloc_n_or_shared(pool: HierPool, counts: jax.Array,
                      max_per_lane: int) -> Tuple[HierPool, jax.Array]:
    """Batched lane-first allocate with a shared-pool fallback.

    The chunked analogue of :func:`alloc_or_shared`: lanes whose
    private stack covers their whole demand are served exactly as
    :func:`alloc_n` serves them (identical grants — the serving hot
    path, where §4.2 sizing plus the per-step rebalance make the
    fallback dead code); a lane whose private stack cannot cover the
    demand takes its WHOLE batch from the shared pool instead
    (all-or-nothing per lane either way — a chunk is never granted
    half from each level).  Callers looping raw ``decode_step_chunk``
    without a rebalance degrade to the shared pool rather than
    silently write through NULL page ids once the warm stock is gone.
    """
    counts = jnp.clip(counts.astype(jnp.int32), 0, max_per_lane)
    pool, ids = alloc_n(pool, counts, max_per_lane)
    miss = (counts > 0) & ~block_pool.granted_mask(ids, counts)
    shared, got = block_pool.alloc_n(
        pool.shared, jnp.where(miss, counts, 0), max_per_lane)
    ids = jnp.where(miss[:, None], got, ids)
    return pool._replace(shared=shared), ids


def alloc_from_shared(pool: HierPool, counts: jax.Array,
                      max_per_lane: int) -> Tuple[HierPool, jax.Array]:
    """Bulk user grants straight from the shared pool — the admission /
    prefill-loading path, off the per-token hot path (a lane's 3*ell
    stack cannot hold a whole prompt).  Prefix-grant semantics and
    refcount stamping as :func:`block_pool.alloc_n`."""
    shared, ids = block_pool.alloc_n(pool.shared, counts, max_per_lane)
    return pool._replace(shared=shared), ids


def addref(pool: HierPool, ids: jax.Array) -> HierPool:
    """Register one extra reference per valid id (prefix sharing)."""
    return pool._replace(shared=block_pool.addref(pool.shared, ids))


def free_n_metered(pool: HierPool, ids: jax.Array
                   ) -> Tuple[HierPool, jax.Array]:
    """:func:`free_n` that also reports the lane-cap spill.

    Returns ``(pool, n_spilled)`` where ``n_spilled`` (int32 scalar) is
    the number of released blocks that overflowed their lane's 3*ell
    stack and landed on the SHARED stack instead.  The §13 counter
    block meters this row explicitly: without it the shared-free
    telescoping ``shared_top' - shared_top == drain - refill`` is only
    an inequality whenever a release overflows a lane (DESIGN.md §13).
    """
    L, K = ids.shape
    cap = pool.private_ids.shape[1]
    refcount, released = block_pool.release_plan(
        pool.shared.refcount, ids.reshape(-1))
    released = released.reshape(L, K)
    rel_ids = jnp.where(released, ids, NULL)
    # push to the lane: rank the released entries within each lane
    rank = jnp.cumsum(released.astype(jnp.int32), axis=1)       # 1-based
    pos = pool.private_top[:, None] + rank - 1
    to_lane = released & (pos < cap)
    lane_pos = jnp.where(to_lane, pos, cap)                     # cap => drop
    rows = jnp.arange(L)[:, None]
    private_ids = pool.private_ids.at[rows, lane_pos].set(
        rel_ids, mode="drop")
    private_top = pool.private_top + jnp.sum(
        to_lane.astype(jnp.int32), axis=1)
    spilled = released & ~to_lane
    spill = jnp.where(spilled, rel_ids, NULL).reshape(-1)
    shared = block_pool._push(pool.shared._replace(refcount=refcount), spill)
    return (HierPool(shared, private_ids, private_top),
            jnp.sum(spilled.astype(jnp.int32)))


def free_n(pool: HierPool, ids: jax.Array) -> HierPool:
    """Per-lane batched free: ids int32[L, K] (NULL entries = no-op).

    Drops one reference per valid id; blocks whose refcount reaches
    zero return to the owning lane's private stack (up to capacity),
    the overflow spilling to the shared stack — so a whole sequence's
    pages release in one fixed-shape call with nothing lost: every
    block released in this call lands on exactly one stack, duplicate
    ids (two lanes releasing a shared page together) release once, and
    still-referenced blocks stay off both stacks.
    """
    pool, _ = free_n_metered(pool, ids)
    return pool


def free(pool: HierPool, ids: jax.Array) -> HierPool:
    """Per-lane free: ids int32[L] (NULL = no-op for that lane).

    Frees go to the lane's own private pool, as in the paper, spilling
    to the shared pool when the lane stack is full.  One-column case of
    :func:`free_n` (same refcount semantics).
    """
    return free_n(pool, ids[:, None])


def free_shared(pool: HierPool, ids: jax.Array) -> HierPool:
    """Release lane-less references straight to the SHARED stack.

    ids: int32[K] (NULL = no-op).  The cache-owner release path (pin
    eviction, DESIGN.md §8): pinned pages belong to no serving lane, so
    a dropped reference whose count reaches zero returns to the shared
    free stack — the next rebalance redistributes it to whichever lane
    runs low.  Same refcount semantics as :func:`free_n` (duplicates in
    one call release once, still-referenced blocks stay off the stack).
    """
    return pool._replace(shared=block_pool.free(pool.shared, ids))


def free_per_shard(pool: HierPool) -> jax.Array:
    """Free blocks available to each shard (shared stack + lane stocks)
    — the scheduler's low-water query.  On a DP-sharded pool the result
    is int32[DP]; on a single-shard pool it is a scalar."""
    return pool.shared.top + jnp.sum(pool.private_top, axis=-1)


def live_per_shard(pool: HierPool) -> jax.Array:
    """Referenced blocks per shard (each counted once) — int32[DP] on a
    DP-sharded pool, scalar otherwise.  Per-shard conservation is
    ``free_per_shard + live_per_shard == pages_local`` on EVERY shard
    independently: block ids are shard-local, so the invariant must be
    checked shard-resolved (the multi-host test plane's §4.1 form)."""
    return block_pool.num_live_rows(pool.shared.refcount)


def rebalance_drain(pool: HierPool) -> HierPool:
    """Phase 1 of the deamortized shared-pool traffic: every lane above
    ``2*ell`` pushes its top ``ell`` blocks to the shared pool in one
    fixed-shape scatter (2*ell keeps headroom for a full step of frees,
    mirroring the paper's ell >= 3p slack)."""
    L, cap = pool.private_ids.shape
    ell = cap // 3
    k = jnp.arange(ell, dtype=jnp.int32)[None, :]
    drain = pool.private_top > 2 * ell
    idx = jnp.maximum(pool.private_top[:, None] - 1 - k, 0)
    dids = jnp.take_along_axis(pool.private_ids, idx, axis=1)
    dids = jnp.where(drain[:, None], dids, NULL)
    shared = block_pool._push(pool.shared, dids.reshape(-1))
    private_top = pool.private_top - jnp.where(drain, ell, 0)
    return pool._replace(shared=shared, private_top=private_top)


def rebalance_refill(pool: HierPool) -> HierPool:
    """Phase 2: every lane below ``ell`` pulls one batch of ``ell``
    blocks from the shared pool — one prefix-granting
    :func:`block_pool._take_n` across all lanes (all-or-nothing per
    lane in lane order when the shared pool cannot serve everyone)."""
    L, cap = pool.private_ids.shape
    ell = cap // 3
    k = jnp.arange(ell, dtype=jnp.int32)[None, :]
    refill = pool.private_top < ell
    counts = jnp.where(refill, ell, 0)
    shared, got = block_pool._take_n(pool.shared, counts, ell)
    granted = block_pool.granted_mask(got, counts) & refill
    place = jnp.where(granted[:, None],
                      pool.private_top[:, None] + k, cap)   # cap => drop
    rows = jnp.arange(L)[:, None]
    private_ids = pool.private_ids.at[rows, place].set(got, mode="drop")
    private_top = pool.private_top + jnp.where(granted, ell, 0)
    return HierPool(shared, private_ids, private_top)


def rebalance(pool: HierPool) -> HierPool:
    """Deamortized shared-pool traffic (one call per engine step).

    Each lane moves at most one batch of ``ell`` blocks per call:
    drains first (lanes above 2*ell push a batch), then refills (lanes
    below ell pull a batch) — ordering that lets this call's drains
    supply this call's refills, so whenever the pool-wide slack is at
    least ``3*ell*L`` every lane leaves with >= ell blocks (§4.2 holds
    by construction).  Work is O(L * ell) per call in two fixed-shape
    scatters, independent of the pool size m — no per-lane loop.
    """
    return rebalance_refill(rebalance_drain(pool))


def total_free(pool: HierPool) -> jax.Array:
    return jnp.sum(pool.shared.top) + jnp.sum(pool.private_top)


def num_live(pool: HierPool) -> jax.Array:
    """Blocks with at least one reference (each counted once)."""
    return jnp.sum((pool.shared.refcount > 0).astype(jnp.int32))


# ---------------------------------------------------------- DP-sharded ops
#
# The serving DecodeState holds one HierPool per DP shard: every leaf
# carries a leading [DP, ...] axis and block ids are shard-local.  The
# wrappers below vmap the single-shard ops over that axis (no
# cross-shard gathers ever appear in the HLO — DESIGN.md §5).
#
# On a real multi-device mesh the engine shard_maps its jitted steps
# over a ("dp",) axis (launch.mesh.make_dp_mesh): each device then sees
# a local DP slice of 1 and these same wrappers run entirely
# device-local — drain and refill move blocks only between a shard's
# own lanes and its own shared stack, never across the mesh axis
# (DESIGN.md §9 ownership rules).  The vmap form and the shard_map form
# compute identical results by construction; the conformance suite
# (tests/test_multihost_pool.py) replays one trace through both and
# through the host-side reference model (core/refpool.py).

DP_AXES = HierPool(
    shared=BlockPool(free_ids=0, top=0, refcount=0),
    private_ids=0, private_top=0)


def create_dp(dp: int, num_blocks: int, num_lanes: int, ell: int) -> HierPool:
    """One identical HierPool per DP shard (ids are shard-local)."""
    pool = create(num_blocks, num_lanes, ell)
    return jax.tree.map(
        lambda a: jnp.broadcast_to(a[None], (dp,) + a.shape), pool)


def alloc_dp(pool: HierPool, want: jax.Array
             ) -> Tuple[HierPool, jax.Array]:
    """want bool[DP, L] -> ids int32[DP, L]."""
    return jax.vmap(alloc, in_axes=(DP_AXES, 0))(pool, want)


def alloc_or_shared_dp(pool: HierPool, want: jax.Array
                       ) -> Tuple[HierPool, jax.Array]:
    """want bool[DP, L] -> ids int32[DP, L] (lane-first, shared fallback)."""
    return jax.vmap(alloc_or_shared, in_axes=(DP_AXES, 0))(pool, want)


def alloc_n_dp(pool: HierPool, counts: jax.Array,
               max_per_lane: int) -> Tuple[HierPool, jax.Array]:
    """counts int32[DP, L] -> ids int32[DP, L, K]."""
    return jax.vmap(lambda p, c: alloc_n(p, c, max_per_lane),
                    in_axes=(DP_AXES, 0))(pool, counts)


def alloc_n_or_shared_dp(pool: HierPool, counts: jax.Array,
                         max_per_lane: int) -> Tuple[HierPool, jax.Array]:
    """counts int32[DP, L] -> ids int32[DP, L, K] (lane-first batched
    allocate, whole-batch shared fallback per denied lane)."""
    return jax.vmap(lambda p, c: alloc_n_or_shared(p, c, max_per_lane),
                    in_axes=(DP_AXES, 0))(pool, counts)


def alloc_from_shared_dp(pool: HierPool, counts: jax.Array,
                         max_per_lane: int) -> Tuple[HierPool, jax.Array]:
    """counts int32[DP, L] -> ids int32[DP, L, K] (bulk, off hot path)."""
    return jax.vmap(lambda p, c: alloc_from_shared(p, c, max_per_lane),
                    in_axes=(DP_AXES, 0))(pool, counts)


def addref_dp(pool: HierPool, ids: jax.Array) -> HierPool:
    """ids int32[DP, ...] — shard-local extra references."""
    return jax.vmap(addref, in_axes=(DP_AXES, 0))(pool, ids)


def free_n_dp(pool: HierPool, ids: jax.Array) -> HierPool:
    """ids int32[DP, L, K] — per-lane batched release per shard."""
    return jax.vmap(free_n, in_axes=(DP_AXES, 0))(pool, ids)


def free_n_metered_dp(pool: HierPool, ids: jax.Array
                      ) -> Tuple[HierPool, jax.Array]:
    """ids int32[DP, L, K] -> (pool, spilled int32[DP]) — batched
    release that meters each shard's lane-cap spill to the shared
    stack (the §13 spill counter row)."""
    return jax.vmap(free_n_metered, in_axes=(DP_AXES, 0))(pool, ids)


def free_shared_dp(pool: HierPool, ids: jax.Array) -> HierPool:
    """ids int32[DP, K] — shard-local cache-owner release (pin
    eviction); zero-refcount blocks land on the shard's shared stack."""
    return jax.vmap(free_shared, in_axes=(DP_AXES, 0))(pool, ids)


def rebalance_dp(pool: HierPool) -> HierPool:
    return jax.vmap(rebalance, in_axes=(DP_AXES,))(pool)


def rebalance_drain_dp(pool: HierPool) -> HierPool:
    """Drain phase only — the torn mid-rebalance state fault injection
    plants before a simulated host crash (DESIGN.md §11)."""
    return jax.vmap(rebalance_drain, in_axes=(DP_AXES,))(pool)


def rebalance_refill_dp(pool: HierPool) -> HierPool:
    """Refill phase only.  ``rebalance_refill_dp(rebalance_drain_dp(p))
    == rebalance_dp(p)``; the serve step calls the phases separately so
    the telemetry counter block can meter drain and refill traffic from
    the ``sum(private_top)`` deltas between them (DESIGN.md §13)."""
    return jax.vmap(rebalance_refill, in_axes=(DP_AXES,))(pool)


# ----------------------------------------------------------- crash recovery
#
# After a host crash the free stacks and the host's shadow of lane
# occupancy are untrusted: the crash may have landed anywhere, including
# inside the rebalance's torn drain/refill window.  What remains
# trustworthy is *reachability* — the device-resident page-table rows
# recovery keeps and the journaled pin rows.  Reconciliation recounts
# references from those rows alone and rebuilds the whole pool; it is
# host-side numpy, strictly off the hot path.


def _reconcile_shard(shared: BlockPool, private_ids: np.ndarray,
                     keep_rows: Optional[np.ndarray],
                     pin_rows: Optional[np.ndarray]
                     ) -> Tuple[HierPool, dict]:
    old_ref = np.asarray(shared.refcount)
    m = old_ref.shape[0]
    lanes = np.asarray(private_ids)
    L, cap = lanes.shape
    ell = cap // 3

    refs = np.zeros(m, np.int64)
    for rows in (keep_rows, pin_rows):
        if rows is None:
            continue
        ids = np.asarray(rows).reshape(-1)
        ids = ids[(ids >= 0) & (ids < m)]
        np.add.at(refs, ids, 1)

    # pages a dead episode held (were referenced) that no keeping row
    # reaches any more — exactly what reconcile returns to the free set
    reclaimed = np.nonzero((old_ref > 0) & (refs == 0))[0]
    # referenced pages the torn state thought free (counter corruption)
    resurrected = int(np.sum((old_ref <= 0) & (refs > 0)))

    # the recount runs in int64 but the pool stores int16 refcounts: a
    # pathologically shared page (> 32767 keeping rows) would silently
    # wrap negative on the narrow, turning a live page "free".  Clamp
    # to the dtype max and report — the page stays live (releases
    # decrement, so a clamped count errs toward never freeing early).
    ref_cap = np.iinfo(old_ref.dtype).max
    clamped = np.nonzero(refs > ref_cap)[0]
    refs = np.minimum(refs, ref_cap)

    free_list = np.nonzero(refs == 0)[0]           # ascending ids
    # lanes first: exactly ell ids each while supply lasts, so the §4.2
    # never-dry floor holds by construction whenever slack allows
    new_lanes = np.full((L, cap), NULL, np.int32)
    new_tops = np.zeros(L, np.int32)
    pos = 0
    for i in range(L):
        take = min(ell, len(free_list) - pos)
        if take <= 0:
            break
        new_lanes[i, :take] = free_list[pos:pos + take]
        new_tops[i] = take
        pos += take
    rest = free_list[pos:]
    new_free = np.full(m, NULL, np.int32)
    new_free[:len(rest)] = rest[::-1]              # pops come off the end

    shard_pool = HierPool(
        shared=BlockPool(free_ids=jnp.asarray(new_free),
                         top=jnp.asarray(np.int32(len(rest))),
                         refcount=jnp.asarray(refs.astype(old_ref.dtype))),
        private_ids=jnp.asarray(new_lanes),
        private_top=jnp.asarray(new_tops))
    report = {
        "reclaimed": [int(b) for b in reclaimed],
        "resurrected": resurrected,
        "clamped": [int(b) for b in clamped],
        "free": int(len(rest)) + int(new_tops.sum()),
        "live": int(np.sum(refs > 0)),
        "capacity": int(m),
        "never_dry": bool(new_tops.min() >= ell) if L else True,
    }
    assert report["free"] + report["live"] == m, "reconcile broke conservation"
    return shard_pool, report


def audit_and_reconcile(pool: HierPool, keep_tables=None, pin_tables=None
                        ) -> Tuple[HierPool, dict]:
    """Rebuild a (possibly torn) pool from device-resident references.

    ``keep_tables`` are the page-table rows recovery keeps (``[B, maxp]``
    per shard; usually none — in-flight requests requeue through the
    preemption-resume path) and ``pin_tables`` the journal-trusted pin
    rows; both use NULL (-1) for empty entries.  Every block referenced
    by a keeping row stays live with a freshly recounted refcount; every
    other block becomes free — each lane refilled to exactly ``ell``,
    the remainder restacked on the shared pool in deterministic order.

    Accepts a single-shard pool or a DP-stacked one (leading ``[DP,...]``
    leaf axes).  Returns ``(pool, report)``; conservation (free + live ==
    capacity, per shard) is asserted, never-dry is reported per shard.
    """
    dp_form = np.asarray(pool.private_top).ndim == 2
    if not dp_form:
        shard_pool, rep = _reconcile_shard(
            pool.shared, pool.private_ids, keep_tables, pin_tables)
        return shard_pool, {
            "shards": [rep], "reclaimed": len(rep["reclaimed"]),
            "resurrected": rep["resurrected"],
            "clamped": len(rep["clamped"]),
            "never_dry": rep["never_dry"], "conserved": True}
    host = jax.tree.map(np.asarray, pool)
    dp = host.private_top.shape[0]
    shards, reps = [], []
    for s in range(dp):
        shard = jax.tree.map(lambda a: a[s], host)
        sp, rep = _reconcile_shard(
            shard.shared, shard.private_ids,
            None if keep_tables is None else np.asarray(keep_tables)[s],
            None if pin_tables is None else np.asarray(pin_tables)[s])
        shards.append(sp)
        reps.append(rep)
    pool_out = jax.tree.map(lambda *xs: jnp.stack(xs), *shards)
    return pool_out, {
        "shards": reps,
        "reclaimed": sum(len(r["reclaimed"]) for r in reps),
        "resurrected": sum(r["resurrected"] for r in reps),
        "clamped": sum(len(r["clamped"]) for r in reps),
        "never_dry": all(r["never_dry"] for r in reps),
        "conserved": True}
