"""Host-side sequential reference model of the two-level device pool.

The differential-conformance half of the multi-host test plane
(tests/test_multihost_pool.py): every operation of
:mod:`repro.core.hier_pool` has an executable sequential specification
here, in plain Python lists — the *sequential witness* that the P-SIM
construction guarantees exists for the shared pool's history (DESIGN.md
§2a: P-SIM linearizes every shared-pool op, so a conforming
implementation must behave like SOME sequential stack; the device pool
is stronger — it is deterministic, so it must behave like THIS one).

Fidelity contract: given the same op trace, :class:`RefShardPool`
returns *bit-identical grant ids* and reaches *identical final state*
(shared stack contents, lane stacks, refcounts) as the jax
implementation — whether the jax ops run single-device, vmapped over a
[DP, ...] axis, or shard_mapped over a real device mesh.  The
conformance test replays one randomized trace through all of them and
asserts the grant/free multisets match per shard, so any divergence in
stack discipline (pop order, spill order, prefix-grant feasibility,
refcount-zero release marking) fails loudly.

Ordering rules mirrored exactly from block_pool/hier_pool:

* stacks pop from the top (``free_ids[top-1]`` == end of the list);
* ``create`` carves one warm batch per lane off the shared top, lane i
  receiving reversed-row i of the carve;
* batch takes (``_take_n``) are prefix-feasible in slot order —
  the first infeasible slot denies itself and every later slot;
* ``free_n`` applies ALL refcount decrements first, then releases the
  first occurrence of each block whose count reached zero — lane rows
  keep what fits (column order) up to capacity, the rest spills to the
  shared stack in row-major order;
* drain pushes each draining lane's top ``ell`` blocks in pop order,
  lanes in lane order; refill places a granted batch bottom-up.
"""

from __future__ import annotations

from typing import List, Optional, Sequence


class RefShardPool:
    """Sequential spec of ONE shard's HierPool (see module docstring)."""

    def __init__(self, num_blocks: int, num_lanes: int, ell: int):
        assert num_blocks >= num_lanes * ell
        self.m = num_blocks
        self.ell = ell
        self.cap = 3 * ell
        # shared free stack: list end == stack top (free_ids[top-1])
        self.shared: List[int] = list(range(num_blocks - 1, -1, -1))
        self.refcount = [0] * num_blocks
        # warm-up carve: top num_lanes*ell entries, reversed rows
        n = num_lanes * ell
        carve = self.shared[self.m - n:]
        del self.shared[self.m - n:]
        rows = [carve[j * ell:(j + 1) * ell] for j in range(num_lanes)]
        self.lanes: List[List[int]] = [rows[num_lanes - 1 - i]
                                       for i in range(num_lanes)]

    # -- queries --------------------------------------------------------
    def free_total(self) -> int:
        return len(self.shared) + sum(len(x) for x in self.lanes)

    def num_live(self) -> int:
        return sum(1 for r in self.refcount if r > 0)

    def lane_tops(self) -> List[int]:
        return [len(x) for x in self.lanes]

    # -- user ops -------------------------------------------------------
    def alloc(self, want: Sequence[bool]) -> List[int]:
        """hier_pool.alloc: one lane-local pop per wanting lane."""
        ids = []
        for lane, w in zip(self.lanes, want):
            if w and lane:
                b = lane.pop()
                self.refcount[b] = 1
                ids.append(b)
            else:
                ids.append(-1)
        return ids

    def alloc_n(self, counts: Sequence[int],
                max_per_lane: int) -> List[List[int]]:
        """hier_pool.alloc_n: all-or-nothing per lane, lane-local."""
        out = []
        for lane, c in zip(self.lanes, counts):
            c = min(max(int(c), 0), max_per_lane)
            if c <= len(lane):
                got = [lane.pop() for _ in range(c)]
                for b in got:
                    self.refcount[b] = 1
            else:
                got = []
            out.append(got)
        return out

    def alloc_from_shared(self, counts: Sequence[int],
                          max_per_lane: int) -> List[List[int]]:
        """block_pool.alloc_n on the shared stack: prefix-feasible
        all-or-nothing grants in slot order."""
        out, cum = [], 0
        avail = len(self.shared)
        for c in counts:
            c = min(max(int(c), 0), max_per_lane)
            cum += c
            if cum <= avail:
                got = [self.shared.pop() for _ in range(c)]
                for b in got:
                    self.refcount[b] = 1
            else:
                got = []
                avail = -1          # a denied slot denies all later ones
            out.append(got)
        return out

    def addref(self, ids: Sequence[int]) -> None:
        for b in ids:
            if b >= 0:
                self.refcount[b] += 1

    def free_n(self, ids: Sequence[Sequence[int]]) -> int:
        """hier_pool.free_n: decrement everything first, release each
        zero-count block once (first occurrence, row-major), lane rows
        keep what fits in column order, the rest spills row-major.
        Returns the spill count — the sequential spec of
        :func:`hier_pool.free_n_metered`'s second output."""
        flat = [b for row in ids for b in row if b >= 0]
        for b in flat:
            self.refcount[b] -= 1
        seen = set()
        spill = []
        for lane, row in zip(self.lanes, ids):
            for b in row:
                if b < 0 or self.refcount[b] != 0 or b in seen:
                    continue
                seen.add(b)
                if len(lane) < self.cap:
                    lane.append(b)
                else:
                    spill.append(b)
        self.shared.extend(spill)
        return len(spill)

    def free_shared(self, ids: Sequence[int]) -> None:
        """hier_pool.free_shared: lane-less release to the SHARED stack."""
        valid = [b for b in ids if b >= 0]
        for b in valid:
            self.refcount[b] -= 1
        seen = set()
        for b in valid:
            if self.refcount[b] == 0 and b not in seen:
                seen.add(b)
                self.shared.append(b)

    # -- rebalance ------------------------------------------------------
    def rebalance_drain(self) -> None:
        for lane in self.lanes:
            if len(lane) > 2 * self.ell:
                for _ in range(self.ell):
                    self.shared.append(lane.pop())

    def rebalance_refill(self) -> None:
        need = [len(x) < self.ell for x in self.lanes]
        cum = 0
        avail = len(self.shared)
        for lane, n in zip(self.lanes, need):
            if not n:
                continue
            cum += self.ell
            if cum <= avail:
                lane.extend(self.shared.pop() for _ in range(self.ell))
            else:
                avail = -1          # prefix-feasible, like _take_n
        # (drained entries above were already on the shared stack and
        # may serve refills in the same rebalance call — same as jax)

    def rebalance(self) -> None:
        self.rebalance_drain()
        self.rebalance_refill()


def create_dp(dp: int, num_blocks: int, num_lanes: int,
              ell: int) -> List[RefShardPool]:
    """One reference shard pool per DP shard — the host mirror of
    :func:`repro.core.hier_pool.create_dp` (ids shard-local)."""
    return [RefShardPool(num_blocks, num_lanes, ell) for _ in range(dp)]


class RefClassedPool:
    """Sequential spec of ONE shard's size-classed pool vector
    (:mod:`repro.core.classed_pool`): an independent
    :class:`RefShardPool` per class.  Classes never exchange blocks, so
    the witness is exactly the per-class witnesses side by side —
    every op takes the class index first and delegates; ids are
    class-local AND shard-local, mirroring the device plane.

    ``specs``: sequence of ``(num_blocks, num_lanes, ell)`` triples
    (or anything exposing those attributes, e.g.
    :class:`~repro.core.classed_pool.ClassSpec`).
    """

    def __init__(self, specs):
        def triple(s):
            if hasattr(s, "num_blocks"):
                return (s.num_blocks, s.num_lanes, s.ell)
            return tuple(s)[-3:] if len(tuple(s)) == 4 else tuple(s)
        self.classes = [RefShardPool(*triple(s)) for s in specs]

    # -- queries (summed over classes, like classed_pool) ---------------
    def free_total(self) -> int:
        return sum(c.free_total() for c in self.classes)

    def num_live(self) -> int:
        return sum(c.num_live() for c in self.classes)

    # -- class-indexed ops ----------------------------------------------
    def alloc(self, cls: int, want):
        return self.classes[cls].alloc(want)

    def alloc_n(self, cls: int, counts, max_per_lane: int):
        return self.classes[cls].alloc_n(counts, max_per_lane)

    def alloc_from_shared(self, cls: int, counts, max_per_lane: int):
        return self.classes[cls].alloc_from_shared(counts, max_per_lane)

    def addref(self, cls: int, ids) -> None:
        self.classes[cls].addref(ids)

    def free_n(self, cls: int, ids) -> int:
        return self.classes[cls].free_n(ids)

    def free_shared(self, cls: int, ids) -> None:
        self.classes[cls].free_shared(ids)

    # -- rebalance: all classes (the serve step's fused form) or one
    # (the torn per-class windows the chaos plane injects) -------------
    def rebalance_drain(self, cls: Optional[int] = None) -> None:
        for c in self._sel(cls):
            c.rebalance_drain()

    def rebalance_refill(self, cls: Optional[int] = None) -> None:
        for c in self._sel(cls):
            c.rebalance_refill()

    def rebalance(self, cls: Optional[int] = None) -> None:
        self.rebalance_drain(cls)
        self.rebalance_refill(cls)

    def _sel(self, cls: Optional[int]):
        return self.classes if cls is None else [self.classes[cls]]


def create_classed_dp(dp: int, specs) -> List[RefClassedPool]:
    """One reference classed pool per DP shard — the host mirror of
    :func:`repro.core.classed_pool.create_dp`."""
    return [RefClassedPool(specs) for _ in range(dp)]


def conforms_classed(ref: RefClassedPool, pool, shard: int
                     ) -> Optional[str]:
    """Compare a reference classed shard against shard ``shard`` of a
    jax :class:`~repro.core.classed_pool.ClassedPool` (class by class,
    raw leaves).  Returns None on match, else a message naming the
    diverging class."""
    import numpy as np
    for c, (rc, hp) in enumerate(zip(ref.classes, pool.classes)):
        msg = conforms(rc,
                       np.asarray(hp.shared.free_ids[shard]),
                       np.asarray(hp.shared.top[shard]),
                       np.asarray(hp.private_ids[shard]),
                       np.asarray(hp.private_top[shard]),
                       np.asarray(hp.shared.refcount[shard]))
        if msg is not None:
            return f"class {c}: {msg}"
    return None


def conforms(ref: RefShardPool, shared_free_ids, shared_top,
             private_ids, private_top, refcount) -> Optional[str]:
    """Compare a reference shard against the jax shard's raw leaves
    (host-side numpy views).  Returns None on match, else a message."""
    top = int(shared_top)
    if top != len(ref.shared):
        return f"shared top {top} != ref {len(ref.shared)}"
    got = [int(x) for x in shared_free_ids[:top]]
    if got != ref.shared:
        return f"shared stack {got} != ref {ref.shared}"
    for i, lane in enumerate(ref.lanes):
        t = int(private_top[i])
        if t != len(lane):
            return f"lane {i} top {t} != ref {len(lane)}"
        if [int(x) for x in private_ids[i][:t]] != lane:
            return (f"lane {i} stack {[int(x) for x in private_ids[i][:t]]}"
                    f" != ref {lane}")
    rc = [int(x) for x in refcount]
    if rc != ref.refcount:
        return f"refcounts diverge: {rc} != {ref.refcount}"
    return None
