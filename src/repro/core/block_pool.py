"""Fixed-size block pool as a pure-functional JAX state machine.

TPU-native translation of the paper's constant-time discipline: the pool
is a free-*stack* of block ids plus a stack pointer; ``alloc``/``free``
are fixed-shape gathers/scatters whose HLO cost is O(R) for R requests
and — the paper's key property — **independent of the pool size m** (no
scans over the pool, no compaction).  All functions are jit-compatible
and differentiable-free (integer state).

Request batching: callers pass a fixed-width request vector with a mask
(SPMD programs need static shapes); each masked-off slot costs nothing
semantically.  NULL = -1 ids mark failed/masked allocations.
"""

from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

NULL = jnp.int32(-1)


class BlockPool(NamedTuple):
    """free_ids[0:top] are the available block ids (a stack)."""

    free_ids: jax.Array     # int32[m]
    top: jax.Array          # int32 scalar — number of free blocks


def create(num_blocks: int) -> BlockPool:
    return BlockPool(
        free_ids=jnp.arange(num_blocks - 1, -1, -1, dtype=jnp.int32),
        top=jnp.int32(num_blocks),
    )


def num_free(pool: BlockPool) -> jax.Array:
    return pool.top


def alloc(pool: BlockPool, mask: jax.Array) -> Tuple[BlockPool, jax.Array]:
    """Allocate one block per True slot of ``mask`` (bool[R]).

    Returns (new_pool, ids[R]) with ids = NULL where mask is False or the
    pool had too few blocks (allocation is all-or-nothing per slot, in
    slot order).  O(R) work, independent of m.
    """
    mask = mask.astype(jnp.int32)
    # slot i takes the (rank_i)-th block from the top of the stack
    rank = jnp.cumsum(mask) * mask            # 1-based rank among granted
    have = rank <= pool.top                   # enough blocks for this slot?
    take = (mask == 1) & have
    idx = pool.top - rank                     # stack position (top-1 .. )
    idx = jnp.where(take, idx, 0)
    ids = jnp.where(take, pool.free_ids[idx], NULL)
    n_taken = jnp.sum(take.astype(jnp.int32))
    return BlockPool(pool.free_ids, pool.top - n_taken), ids.astype(jnp.int32)


def alloc_n(pool: BlockPool, counts: jax.Array,
            max_per_slot: int) -> Tuple[BlockPool, jax.Array]:
    """Allocate ``counts[i]`` blocks for slot i in ONE fixed-shape gather.

    counts: int32[R] with 0 <= counts[i] <= max_per_slot (static).
    Returns (new_pool, ids[R, max_per_slot]) — row i holds counts[i]
    valid ids followed by NULL padding.  Grants are all-or-nothing per
    slot in slot order: because the cumulative demand is monotone, a
    denied slot denies every later slot too (prefix grants), so callers
    can detect failure from the last needed id alone.  O(R *
    max_per_slot) work, independent of the pool size m — the chunked
    analogue of :func:`alloc` (multi-page demand per step absorbed in
    one batch, the paper's batch-granularity transfer).
    """
    R = counts.shape[0]
    counts = jnp.clip(counts.astype(jnp.int32), 0, max_per_slot)
    k = jnp.arange(max_per_slot, dtype=jnp.int32)[None, :]
    want = k < counts[:, None]                     # [R, K]
    have = jnp.cumsum(counts) <= pool.top          # prefix-feasible slots
    take = want & have[:, None]
    flat = take.reshape(-1).astype(jnp.int32)
    rank = (jnp.cumsum(flat) * flat).reshape(R, max_per_slot)  # 1-based
    idx = jnp.where(take, pool.top - rank, 0)
    ids = jnp.where(take, pool.free_ids[idx], NULL)
    n_taken = jnp.sum(flat)
    return BlockPool(pool.free_ids, pool.top - n_taken), ids.astype(jnp.int32)


def chunk_page_plan(seq_lens: jax.Array, lens: jax.Array, psz: int,
                    maxp: int) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Page demand for appending ``lens`` tokens per sequence (elementwise
    over any leading shape).  Returns (lens, pages_before, counts) with
    lens zeroed where the chunk would overflow a ``maxp``-page table —
    the all-or-nothing contract shared by kv_cache.append_chunk and the
    model's chunked decode path."""
    lens = jnp.where((seq_lens + lens + psz - 1) // psz <= maxp, lens, 0)
    pages_before = (seq_lens + psz - 1) // psz
    counts = (seq_lens + lens + psz - 1) // psz - pages_before
    return lens, pages_before, counts


def granted_mask(ids: jax.Array, counts: jax.Array) -> jax.Array:
    """Did :func:`alloc_n` grant a request in full?  Prefix-grant
    semantics make one probe of the last needed id sufficient.
    ids: [..., K]; counts: [...] -> bool[...]."""
    last = jnp.take_along_axis(
        ids, jnp.maximum(counts - 1, 0)[..., None], axis=-1)[..., 0]
    return (counts == 0) | (last >= 0)


def free(pool: BlockPool, ids: jax.Array) -> BlockPool:
    """Return blocks to the pool; slots with id == NULL are ignored.

    O(R) scatter, independent of m.  Double-free protection is the
    caller's contract (as in the paper: free requires a live block).
    """
    valid = ids >= 0
    rank = jnp.cumsum(valid.astype(jnp.int32)) * valid  # 1-based
    pos = pool.top + rank - 1
    pos = jnp.where(valid, pos, jnp.int32(pool.free_ids.shape[0]))  # drop
    free_ids = pool.free_ids.at[pos].set(ids, mode="drop")
    n = jnp.sum(valid.astype(jnp.int32))
    return BlockPool(free_ids, pool.top + n)


def alloc_batch(pool: BlockPool, n: int) -> Tuple[BlockPool, jax.Array]:
    """Allocate a contiguous batch of exactly ``n`` ids (static n) —
    the paper's batch-granularity transfer.  Returns ids[n] (all NULL if
    the pool holds fewer than n)."""
    ok = pool.top >= n
    start = jnp.maximum(pool.top - n, 0)
    ids = jax.lax.dynamic_slice(pool.free_ids, (start,), (n,))
    ids = jnp.where(ok, ids, NULL)
    new_top = jnp.where(ok, pool.top - n, pool.top)
    return BlockPool(pool.free_ids, new_top), ids.astype(jnp.int32)


def free_batch(pool: BlockPool, ids: jax.Array) -> BlockPool:
    """Return a full batch (static length; all ids valid or all NULL)."""
    n = ids.shape[0]
    ok = ids[0] >= 0
    updated = jax.lax.dynamic_update_slice(pool.free_ids, ids, (pool.top,))
    free_ids = jnp.where(ok, updated, pool.free_ids)
    new_top = jnp.where(ok, pool.top + n, pool.top)
    return BlockPool(free_ids, new_top)
