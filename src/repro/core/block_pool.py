"""Fixed-size block pool as a pure-functional JAX state machine.

TPU-native translation of the paper's constant-time discipline: the pool
is a free-*stack* of block ids plus a stack pointer; ``alloc``/``free``
are fixed-shape gathers/scatters whose HLO cost is O(R) for R requests
and — the paper's key property — **independent of the pool size m** (no
scans over the pool, no compaction).  All functions are jit-compatible
and differentiable-free (integer state).

Request batching: callers pass a fixed-width request vector with a mask
(SPMD programs need static shapes); each masked-off slot costs nothing
semantically.  NULL = -1 ids mark failed/masked allocations.

Reference counting (prefix sharing): every block carries an int16
refcount.  ``alloc``/``alloc_n`` hand out blocks with refcount 1;
``addref`` registers an extra reference (a second sequence mapping the
same physical page); ``free`` drops one reference and only blocks whose
count reaches zero return to the free stack.  Pool-internal batch
transfers (``alloc_batch``/``free_batch``, the shared<->private lane
traffic) move *free* blocks and never touch refcounts.
"""

from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

NULL = jnp.int32(-1)


class BlockPool(NamedTuple):
    """free_ids[0:top] are the available block ids (a stack)."""

    free_ids: jax.Array     # int32[m]
    top: jax.Array          # int32 scalar — number of free blocks
    refcount: jax.Array     # int16[m] — live references per block (0 = free)


def create(num_blocks: int) -> BlockPool:
    return BlockPool(
        free_ids=jnp.arange(num_blocks - 1, -1, -1, dtype=jnp.int32),
        top=jnp.int32(num_blocks),
        refcount=jnp.zeros((num_blocks,), dtype=jnp.int16),
    )


def num_free(pool: BlockPool) -> jax.Array:
    return pool.top


def num_live(pool: BlockPool) -> jax.Array:
    """Blocks with at least one reference (each counted once)."""
    return jnp.sum((pool.refcount > 0).astype(jnp.int32))


def num_live_rows(refcount: jax.Array) -> jax.Array:
    """Per-row live-block counts: int16[..., m] -> int32[...].

    The shard-resolved companion of :func:`num_live` for DP-stacked
    refcounts ([DP, m]) — each shard's conservation check
    (``free_per_shard + num_live_rows == pages_local``) runs on its own
    row, never summing across shards (block ids are shard-local, so a
    cross-shard sum could mask a leak on one shard cancelled by a
    double-free on another).
    """
    return jnp.sum((refcount > 0).astype(jnp.int32), axis=-1)


def refcounts_of(pool: BlockPool, ids: jax.Array) -> jax.Array:
    """Gather per-block refcounts for valid ids (NULL -> 0).

    Diagnostic/low-water helper for cache-owner accounting: a prefix
    cache that pins pages holds one reference per pinned page, so
    ``refcounts_of(pool, pin_row)`` tells a test (or an eviction
    policy auditing its ledger) exactly how many owners each pinned
    page still has.  O(R) gather, independent of m.
    """
    safe = jnp.where(ids >= 0, ids, 0)
    return jnp.where(ids >= 0, pool.refcount[safe],
                     jnp.int16(0)).astype(jnp.int16)


def _set_ref(refcount: jax.Array, ids: jax.Array, value) -> jax.Array:
    """refcount[id] = value for valid ids (NULL / out-of-range dropped)."""
    m = refcount.shape[0]
    safe = jnp.where(ids >= 0, ids, m)
    return refcount.at[safe].set(jnp.int16(value), mode="drop")


def addref(pool: BlockPool, ids: jax.Array) -> BlockPool:
    """Register one extra reference per valid id (NULL = no-op).

    Duplicate ids in one call add one reference each (scatter-add).
    The blocks must be live (refcount >= 1) — sharing a free block is a
    caller bug, exactly like freeing one.
    """
    m = pool.refcount.shape[0]
    flat = ids.reshape(-1)
    safe = jnp.where(flat >= 0, flat, m)
    ones = jnp.ones_like(flat, dtype=jnp.int16)
    return pool._replace(
        refcount=pool.refcount.at[safe].add(ones, mode="drop"))


def alloc(pool: BlockPool, mask: jax.Array) -> Tuple[BlockPool, jax.Array]:
    """Allocate one block per True slot of ``mask`` (bool[R]).

    Returns (new_pool, ids[R]) with ids = NULL where mask is False or the
    pool had too few blocks (allocation is all-or-nothing per slot, in
    slot order).  Granted blocks start with refcount 1.  O(R) work,
    independent of m.
    """
    mask = mask.astype(jnp.int32)
    # slot i takes the (rank_i)-th block from the top of the stack
    rank = jnp.cumsum(mask) * mask            # 1-based rank among granted
    have = rank <= pool.top                   # enough blocks for this slot?
    take = (mask == 1) & have
    idx = pool.top - rank                     # stack position (top-1 .. )
    idx = jnp.where(take, idx, 0)
    ids = jnp.where(take, pool.free_ids[idx], NULL).astype(jnp.int32)
    n_taken = jnp.sum(take.astype(jnp.int32))
    refcount = _set_ref(pool.refcount, ids, 1)
    return BlockPool(pool.free_ids, pool.top - n_taken, refcount), ids


def _take_n(pool: BlockPool, counts: jax.Array,
            max_per_slot: int) -> Tuple[BlockPool, jax.Array]:
    """alloc_n without the refcount stamp — the pool-internal transfer
    used by lane refills (blocks stay free, just change stacks)."""
    R = counts.shape[0]
    counts = jnp.clip(counts.astype(jnp.int32), 0, max_per_slot)
    k = jnp.arange(max_per_slot, dtype=jnp.int32)[None, :]
    want = k < counts[:, None]                     # [R, K]
    have = jnp.cumsum(counts) <= pool.top          # prefix-feasible slots
    take = want & have[:, None]
    flat = take.reshape(-1).astype(jnp.int32)
    rank = (jnp.cumsum(flat) * flat).reshape(R, max_per_slot)  # 1-based
    idx = jnp.where(take, pool.top - rank, 0)
    ids = jnp.where(take, pool.free_ids[idx], NULL).astype(jnp.int32)
    n_taken = jnp.sum(flat)
    return pool._replace(top=pool.top - n_taken), ids


def alloc_n(pool: BlockPool, counts: jax.Array,
            max_per_slot: int) -> Tuple[BlockPool, jax.Array]:
    """Allocate ``counts[i]`` blocks for slot i in ONE fixed-shape gather.

    counts: int32[R] with 0 <= counts[i] <= max_per_slot (static).
    Returns (new_pool, ids[R, max_per_slot]) — row i holds counts[i]
    valid ids followed by NULL padding.  Grants are all-or-nothing per
    slot in slot order: because the cumulative demand is monotone, a
    denied slot denies every later slot too (prefix grants), so callers
    can detect failure from the last needed id alone.  Granted blocks
    start with refcount 1.  O(R * max_per_slot) work, independent of the
    pool size m — the chunked analogue of :func:`alloc` (multi-page
    demand per step absorbed in one batch, the paper's batch-granularity
    transfer).
    """
    pool, ids = _take_n(pool, counts, max_per_slot)
    return pool._replace(refcount=_set_ref(pool.refcount, ids, 1)), ids


def chunk_page_plan(seq_lens: jax.Array, lens: jax.Array, psz: int,
                    maxp: int) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Page demand for appending ``lens`` tokens per sequence (elementwise
    over any leading shape).  Returns (lens, pages_before, counts) with
    lens zeroed where the chunk would overflow a ``maxp``-page table —
    the all-or-nothing contract shared by kv_cache.append_chunk and the
    model's chunked decode path."""
    lens = jnp.where((seq_lens + lens + psz - 1) // psz <= maxp, lens, 0)
    pages_before = (seq_lens + psz - 1) // psz
    counts = (seq_lens + lens + psz - 1) // psz - pages_before
    return lens, pages_before, counts


def granted_mask(ids: jax.Array, counts: jax.Array) -> jax.Array:
    """Did :func:`alloc_n` grant a request in full?  Prefix-grant
    semantics make one probe of the last needed id sufficient.
    ids: [..., K]; counts: [...] -> bool[...]."""
    last = jnp.take_along_axis(
        ids, jnp.maximum(counts - 1, 0)[..., None], axis=-1)[..., 0]
    return (counts == 0) | (last >= 0)


def _first_occurrence(ids: jax.Array) -> jax.Array:
    """bool[R]: True where ids[r] is the first occurrence of its value
    among the valid entries.  Stable sort + adjacent compare — O(R log
    R), independent of m (release runs every serve step with R =
    slots * max_pages, so no all-pairs R^2 blowup here)."""
    R = ids.shape[0]
    valid = ids >= 0
    key = jnp.where(valid, ids, jnp.iinfo(jnp.int32).max)
    order = jnp.argsort(key)             # stable: ties keep index order
    sorted_key = key[order]
    lead = jnp.concatenate(
        [jnp.ones((1,), bool), sorted_key[1:] != sorted_key[:-1]])
    first = jnp.zeros((R,), bool).at[order].set(lead)
    return first & valid


def release_plan(refcount: jax.Array, ids: jax.Array
                 ) -> Tuple[jax.Array, jax.Array]:
    """Drop one reference per valid id; return (new_refcount,
    released bool) where released marks, exactly once per block, the
    entries whose block reached refcount zero in this call.  Duplicate
    ids in one call drop one reference each (two sequences releasing a
    shared page in the same step)."""
    m = refcount.shape[0]
    valid = ids >= 0
    safe = jnp.where(valid, ids, m)
    dec = jnp.ones_like(ids, dtype=jnp.int16)
    refcount = refcount.at[safe].add(-dec, mode="drop")
    now_zero = refcount[jnp.where(valid, ids, 0)] == 0
    released = valid & now_zero & _first_occurrence(ids)
    return refcount, released


def _push(pool: BlockPool, ids: jax.Array) -> BlockPool:
    """Push valid ids onto the free stack (no refcount bookkeeping —
    callers guarantee the blocks are free)."""
    valid = ids >= 0
    rank = jnp.cumsum(valid.astype(jnp.int32)) * valid  # 1-based
    pos = pool.top + rank - 1
    pos = jnp.where(valid, pos, jnp.int32(pool.free_ids.shape[0]))  # drop
    free_ids = pool.free_ids.at[pos].set(ids, mode="drop")
    n = jnp.sum(valid.astype(jnp.int32))
    return pool._replace(free_ids=free_ids, top=pool.top + n)


def free(pool: BlockPool, ids: jax.Array) -> BlockPool:
    """Drop one reference per valid id; slots with id == NULL are ignored.

    Blocks whose refcount reaches zero return to the free stack (each
    exactly once, even if listed twice in one call by two sequences
    releasing a shared page together).  O(R log R) sort + O(R) scatter,
    independent of m.  Freeing more references than a block holds is the
    caller's contract violation (as in the paper: free requires a live
    block).
    """
    flat = ids.reshape(-1)
    refcount, released = release_plan(pool.refcount, flat)
    return _push(pool._replace(refcount=refcount),
                 jnp.where(released, flat, NULL))


def alloc_batch(pool: BlockPool, n: int) -> Tuple[BlockPool, jax.Array]:
    """Take a contiguous batch of exactly ``n`` free ids (static n) —
    the paper's batch-granularity shared-pool transfer.  Returns ids[n]
    (all NULL if the pool holds fewer than n).  Pool-internal: the
    blocks stay free (refcount untouched)."""
    ok = pool.top >= n
    start = jnp.maximum(pool.top - n, 0)
    ids = jax.lax.dynamic_slice(pool.free_ids, (start,), (n,))
    ids = jnp.where(ok, ids, NULL)
    new_top = jnp.where(ok, pool.top - n, pool.top)
    return pool._replace(top=new_top), ids.astype(jnp.int32)


def free_batch(pool: BlockPool, ids: jax.Array) -> BlockPool:
    """Return a full batch of free blocks (static length; all ids valid
    or all NULL).  Pool-internal: refcounts untouched."""
    n = ids.shape[0]
    ok = ids[0] >= 0
    updated = jax.lax.dynamic_update_slice(pool.free_ids, ids, (pool.top,))
    free_ids = jnp.where(ok, updated, pool.free_ids)
    new_top = jnp.where(ok, pool.top + n, pool.top)
    return pool._replace(free_ids=free_ids, top=new_top)
