"""Wait-free fixed-size allocate/free (Result 1; Figures 3 and 4).

Each process owns a *private pool*:

* ``current_batch`` — a partially-filled stack of blocks (chained through
  word 0 of each free block),
* ``local_batches`` — a stack of zero..two *full* batches of ``ell``
  blocks each (chained through word 1 of each batch's first block),
* ``num_batches``   — number of full batches, plus one if a shared-pool
  pop is in flight (the paper's invariant: always 1 or 2).

The *shared pool* is the P-SIM stack of batches (:class:`~repro.core.psim.
PSimStack`, Result 2).  Shared pushes/pops cost O(p) instructions and are
**deamortized**: every user-level ``allocate``/``free`` advances the
in-flight shared operation by ``DEAMORT_C`` instructions
(``run_delayed_step``), so each user operation is O(1) worst-case and the
shared operation completes within p user operations.

The shared stack allocates its nodes from the *same* private pools via
``allocate_private``/``free_private`` (Figure 4) — the paper's recursion
trick.  A shared op makes at most 2p such calls (Result 2, property 2),
which the batch-size choice ``ell >= 3p`` absorbs.  We default to
``ell = 4p`` — still Theta(p) as the paper requires — because our
instruction-count constants for the deamortization slices are concrete
(see DESIGN.md); the paper's ``3p`` bound assumes idealized unit costs.

Implementation clarifications vs. the paper's schematic pseudocode (both
noted in DESIGN.md):

* In Figure 3 the final ``current_batch.pop()``/``push(b)`` happen *after*
  ``run_delayed_step()``, whose internal ``allocate_private``/
  ``free_private`` calls may have emptied/filled ``current_batch`` in the
  meantime.  The take/put helpers therefore re-apply the Figure-4
  refill/overflow logic if needed; the paper's accounting (at most 2p
  internal calls per shared op) bounds this.
* ``rvals`` of a shared pop carries the popped node's *data* word (batch
  pointer) because the node is freed by the applier (see psim.py).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Generator, List, Optional

from .memory import BlockMemory
from .psim import PSimStack
from .sim import NULL, SimContext, Step

# Words borrowed from blocks (paper section 4.2).
BLK_NEXT = 0    # next block within a batch (and user data word 0 when live)
BAT_NEXT = 1    # next batch in local_batches (only on a batch's first block)

# Instructions of the in-flight shared op executed per user op.  A shared
# push/pop costs <= ~34p + O(1) simulated instructions (P-SIM: two
# attempt iterations, each copying a (2p+1)-word record, reading p
# toggles, applying <= p requests, plus <= 2p internal allocate/free
# calls).  DEAMORT_C = 48 completes it within ~0.75p user ops < p.
DEAMORT_C = 48


class PoolExhausted(RuntimeError):
    pass


@dataclass
class DelayedOp:
    kind: str                      # 'push' | 'pop'
    gen: Generator
    slices: int = 0                # user ops that advanced it (monitor: <= p)


class PrivatePool:
    """Thread-local pool state (O(1) words per process)."""

    def __init__(self, ctx: SimContext):
        # current_batch: top pointer + size counter (thread-local words)
        self.cur_top: int = NULL
        self.cur_size: int = 0
        # local_batches: top pointer + (monitor-only) count
        self.lb_top: int = NULL
        self.lb_count: int = 0
        self.num_batches: int = 0
        self.delayed: Optional[DelayedOp] = None
        ctx.add_space("private_pool_meta", 6)


class WaitFreeAllocator:
    """Result 1: O(1) wait-free allocate/free with Theta(p^2) overhead."""

    def __init__(
        self,
        ctx: SimContext,
        k: int = 2,
        ell: Optional[int] = None,
        shared_batches: int = 8,
        allow_os_growth: bool = False,
        deamort_c: int = DEAMORT_C,
    ):
        p = ctx.nprocs
        self.ctx = ctx
        self.ell = ell if ell is not None else max(4 * p, 4)
        assert self.ell >= 3 * p, "the paper requires ell >= 3p"
        self.allow_os_growth = allow_os_growth
        self.deamort_c = deamort_c

        cur_init = self.ell // 2
        m = p * (2 * self.ell + cur_init) + shared_batches * (self.ell + 1)
        self.mem = BlockMemory(ctx, m, k)
        self.pools = [PrivatePool(ctx) for _ in range(p)]

        # --- sequential initialization (not part of any measured op) ---
        blocks = iter(range(m))
        for pool in self.pools:
            for _ in range(2):
                self._init_push_full_batch(pool, [next(blocks) for _ in range(self.ell)])
            pool.num_batches = 2
            for _ in range(cur_init):
                b = next(blocks)
                self.mem.words[b][BLK_NEXT] = pool.cur_top
                pool.cur_top = b
                pool.cur_size += 1

        top_node = NULL
        for _ in range(shared_batches):
            node = next(blocks)
            batch = [next(blocks) for _ in range(self.ell)]
            first = self._link_batch(batch)
            self.mem.words[node][0] = first     # NODE_DATA
            self.mem.words[node][1] = top_node  # NODE_NEXT
            top_node = node
        assert next(blocks, None) is None

        self.shared = PSimStack(
            ctx, self.mem,
            alloc_node=self._allocate_private,
            free_node=self._free_private,
            init_top=top_node,
        )

        # monitors / stats
        self.live: set = set()
        self.os_requests = 0
        self.max_delayed_slices = 0
        self.delayed_started = 0
        self.delayed_completed = 0
        # Critical-section depth per process: >0 while inside a private-
        # pool operation.  Deamortization slices must not suspend the
        # delayed generator mid private-pool op (the paper's sequential-
        # process model makes thread-local ops atomic w.r.t. the process's
        # own instruction stream); _run_delayed_step drains to a safe
        # boundary, adding at most O(1) instructions per slice.
        self._crit = [0] * p

    # ------------------------------------------------------------------ init
    def _link_batch(self, blocks: List[int]) -> int:
        top = NULL
        for b in blocks:
            self.mem.words[b][BLK_NEXT] = top
            top = b
        return top

    def _init_push_full_batch(self, pool: PrivatePool, blocks: List[int]) -> None:
        first = self._link_batch(blocks)
        self.mem.words[first][BAT_NEXT] = pool.lb_top
        pool.lb_top = first
        pool.lb_count += 1

    # ----------------------------------------------------- low-level stacks
    def _cur_push(self, pid: int, b: int) -> Generator:
        pool = self.pools[pid]
        self._crit[pid] += 1
        try:
            yield from self.mem.write(pid, b, BLK_NEXT, pool.cur_top)
            yield from self.ctx.local_step(pid)
            pool.cur_top = b
            pool.cur_size += 1
        finally:
            self._crit[pid] -= 1

    def _cur_pop(self, pid: int) -> Generator:
        pool = self.pools[pid]
        assert pool.cur_size > 0
        self._crit[pid] += 1
        try:
            b = pool.cur_top
            nxt = yield from self.mem.read(pid, b, BLK_NEXT)
            yield from self.ctx.local_step(pid)
            pool.cur_top = nxt
            pool.cur_size -= 1
        finally:
            self._crit[pid] -= 1
        return b

    def _lb_push(self, pid: int, batch_first: int) -> Generator:
        pool = self.pools[pid]
        self._crit[pid] += 1
        try:
            yield from self.mem.write(pid, batch_first, BAT_NEXT, pool.lb_top)
            yield from self.ctx.local_step(pid)
            pool.lb_top = batch_first
            pool.lb_count += 1
        finally:
            self._crit[pid] -= 1

    def _lb_pop(self, pid: int) -> Generator:
        pool = self.pools[pid]
        if pool.lb_top == NULL:
            raise PoolExhausted(
                f"process {pid}: local_batches empty (invariant violation)")
        self._crit[pid] += 1
        try:
            first = pool.lb_top
            nxt = yield from self.mem.read(pid, first, BAT_NEXT)
            yield from self.ctx.local_step(pid)
            pool.lb_top = nxt
            pool.lb_count -= 1
        finally:
            self._crit[pid] -= 1
        return first

    # ------------------------------------------------ Figure 4 (private ops)
    def _allocate_private(self, pid: int) -> Generator:
        pool = self.pools[pid]
        self._crit[pid] += 1
        try:
            yield from self.ctx.local_step(pid)         # is_empty check
            if pool.cur_size == 0:
                first = yield from self._lb_pop(pid)
                pool.cur_top = first
                pool.cur_size = self.ell
                pool.num_batches -= 1                    # Fig 4 line 4
            b = yield from self._cur_pop(pid)
        finally:
            self._crit[pid] -= 1
        return b

    def _free_private(self, pid: int, b: int) -> Generator:
        pool = self.pools[pid]
        self._crit[pid] += 1
        try:
            yield from self.ctx.local_step(pid)          # full() check
            if pool.cur_size == self.ell:
                pool.num_batches += 1                    # Fig 4 lines 9-10
                yield from self._lb_push(pid, pool.cur_top)
                pool.cur_top = NULL
                pool.cur_size = 0
            yield from self._cur_push(pid, b)
        finally:
            self._crit[pid] -= 1

    # ---------------------------------------------- deamortized shared ops
    def _start_delayed(self, pid: int, kind: str, batch_first: int = NULL) -> None:
        pool = self.pools[pid]
        if pool.delayed is not None:
            self.ctx.violation(
                f"process {pid}: second delayed {kind} while "
                f"{pool.delayed.kind} in flight")
            # Safety valve (never hit in a correct configuration): finish
            # the in-flight op synchronously.  Monitored via violations.
            self._drain_delayed(pid)
        gen = self._delayed_pop_gen(pid) if kind == "pop" else \
            self._delayed_push_gen(pid, batch_first)
        pool.delayed = DelayedOp(kind, gen)
        self.delayed_started += 1

    def _delayed_pop_gen(self, pid: int) -> Generator:
        batch = yield from self.shared.pop(pid)
        if batch == NULL:
            batch = yield from self._os_refill(pid)
        yield from self._lb_push(pid, batch)
        # num_batches unchanged: the in-flight pop it counted is now a
        # full batch in local_batches.

    def _delayed_push_gen(self, pid: int, batch_first: int) -> Generator:
        yield from self.shared.push(pid, batch_first)

    def _os_refill(self, pid: int) -> Generator:
        """Model requesting a fresh batch from the OS (m grows)."""
        if not self.allow_os_growth:
            raise PoolExhausted("shared pool empty and OS growth disabled")
        self.os_requests += 1
        self._crit[pid] += 1
        try:
            blocks = self.mem.grow(self.ell)
            top = NULL
            for b in blocks:
                yield from self.mem.write(pid, b, BLK_NEXT, top)
                top = b
        finally:
            self._crit[pid] -= 1
        return top

    def _run_delayed_step(self, pid: int) -> Generator:
        pool = self.pools[pid]
        yield from self.ctx.local_step(pid)
        op = pool.delayed
        if op is None:
            return
        op.slices += 1
        budget = self.deamort_c
        while budget > 0 or self._crit[pid] > 0:
            # Never suspend inside a private-pool operation: drain to a
            # safe boundary (private ops are O(1) instructions, so the
            # overage per slice is constant).  Other processes may still
            # interleave (the outer yield); only *this* process's user
            # operation must not resume mid-private-op.
            budget -= 1
            try:
                next(op.gen)
            except StopIteration:
                pool.delayed = None
                self.delayed_completed += 1
                self.max_delayed_slices = max(self.max_delayed_slices, op.slices)
                return
            yield Step

    def _drain_delayed(self, pid: int) -> None:
        """Safety valve: run the in-flight op to completion (sequentially)."""
        pool = self.pools[pid]
        op = pool.delayed
        for _ in op.gen:
            pass
        pool.delayed = None
        self.delayed_completed += 1

    # --------------------------------------------------- Figure 3 (user ops)
    def allocate(self, pid: int) -> Generator:
        rec = self.ctx.begin_op(pid, "allocate")
        pool = self.pools[pid]
        yield from self.ctx.local_step(pid)          # is_empty check
        if pool.cur_size == 0:
            yield from self._refill_user(pid)
        yield from self._run_delayed_step(pid)
        yield from self.ctx.local_step(pid)
        if pool.cur_size == 0:                        # drained by delayed step
            yield from self._refill_user(pid)
        b = yield from self._cur_pop(pid)
        if b in self.live:
            self.ctx.violation(f"block {b} allocated while live")
        self.live.add(b)
        self.ctx.end_op(rec, b)
        return b

    def _refill_user(self, pid: int) -> Generator:
        """Figure 3 lines 9-12."""
        pool = self.pools[pid]
        first = yield from self._lb_pop(pid)
        pool.cur_top = first
        pool.cur_size = self.ell
        yield from self.ctx.local_step(pid)
        if pool.num_batches == 1:
            self._start_delayed(pid, "pop")
        else:
            pool.num_batches -= 1

    def free(self, pid: int, b: int) -> Generator:
        rec = self.ctx.begin_op(pid, "free", b)
        if b not in self.live:
            self.ctx.violation(f"free of non-live block {b}")
        self.live.discard(b)
        pool = self.pools[pid]
        yield from self.ctx.local_step(pid)           # full() check
        if pool.cur_size == self.ell:
            yield from self._overflow_user(pid)
        yield from self._run_delayed_step(pid)
        yield from self.ctx.local_step(pid)
        if pool.cur_size == self.ell:                 # filled by delayed step
            yield from self._overflow_user(pid)
        yield from self._cur_push(pid, b)
        self.ctx.end_op(rec)
        return None

    def _overflow_user(self, pid: int) -> Generator:
        """Figure 3 lines 17-23."""
        pool = self.pools[pid]
        yield from self.ctx.local_step(pid)
        if pool.num_batches == 2:
            self._start_delayed(pid, "push", pool.cur_top)
        else:
            pool.num_batches += 1
            yield from self._lb_push(pid, pool.cur_top)
        pool.cur_top = NULL
        pool.cur_size = 0

    # -------------------------------------------------------- introspection
    def private_pool_blocks(self, pid: int) -> int:
        """Blocks held in pid's private pool (monitor; no step charges)."""
        pool = self.pools[pid]
        total = pool.cur_size
        bat = pool.lb_top
        while bat != NULL:
            total += self.ell
            bat = self.mem.words[bat][BAT_NEXT]
        return total

    def metadata_words(self) -> int:
        """All words of internal metadata (excludes the block pool itself)."""
        return self.ctx.total_space(exclude=("pool_blocks",))

    def check_num_batches_invariant(self) -> None:
        for pid, pool in enumerate(self.pools):
            inflight = 1 if (pool.delayed and pool.delayed.kind == "pop") else 0
            if pool.num_batches != pool.lb_count + inflight:
                self.ctx.violation(
                    f"process {pid}: num_batches={pool.num_batches} != "
                    f"full({pool.lb_count}) + inflight_pop({inflight})")
            if not (0 <= pool.num_batches <= 3):
                self.ctx.violation(
                    f"process {pid}: num_batches={pool.num_batches} out of range")
