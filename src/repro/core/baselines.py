"""Baseline allocators for the paper's comparisons (Section 3.1).

All run under the same instruction-level simulation so step counts and
space are directly comparable with :class:`~repro.core.allocator.
WaitFreeAllocator`:

* :class:`LockFreeListAllocator` — a single global free list guarded by a
  test-and-CAS lock.  Blocking: a stalled lock holder stalls everyone
  (worst-case op time unbounded under adversarial scheduling).
* :class:`TreiberAllocator` — lock-free Treiber stack of free blocks with
  (pointer, tag) CAS (the tag models the unbounded sequence numbers the
  paper avoids).  Lock-free but not wait-free: an op can fail its CAS an
  unbounded number of times under contention.
* :class:`HoardSpaceModel` — no execution; models the Theta(p * S)
  additive blowup of Hoard-style superblock allocators for the space
  benchmark (Berger et al. [3]).
"""

from __future__ import annotations

from typing import Generator, Optional

from .memory import BlockMemory
from .sim import CASWord, NULL, SimContext


class LockFreeListAllocator:
    """Global free list + CAS spin lock (blocking baseline)."""

    def __init__(self, ctx: SimContext, m: int, k: int = 2):
        self.ctx = ctx
        self.mem = BlockMemory(ctx, m, k)
        self.lock = CASWord(ctx, 0, category="baseline_lock")
        self.head = CASWord(ctx, NULL, category="baseline_head")
        for b in range(m - 1, -1, -1):
            self.mem.words[b][0] = self.head.value
            self.head.value = b
        self.live: set = set()

    def _acquire(self, pid: int) -> Generator:
        while True:
            ok = yield from self.lock.cas(pid, 0, 1 + pid)
            if ok:
                return

    def _release(self, pid: int) -> Generator:
        yield from self.lock.cas(pid, 1 + pid, 0)

    def allocate(self, pid: int) -> Generator:
        rec = self.ctx.begin_op(pid, "allocate")
        yield from self._acquire(pid)
        b = yield from self.head.read(pid)
        if b == NULL:
            yield from self._release(pid)
            self.ctx.end_op(rec, NULL)
            return NULL
        nxt = yield from self.mem.read(pid, b, 0)
        yield from self.head.cas(pid, b, nxt)   # plain write would do
        yield from self._release(pid)
        self.live.add(b)
        self.ctx.end_op(rec, b)
        return b

    def free(self, pid: int, b: int) -> Generator:
        rec = self.ctx.begin_op(pid, "free", b)
        self.live.discard(b)
        yield from self._acquire(pid)
        h = yield from self.head.read(pid)
        yield from self.mem.write(pid, b, 0, h)
        yield from self.head.cas(pid, h, b)
        yield from self._release(pid)
        self.ctx.end_op(rec)


class TreiberAllocator:
    """Treiber-stack free list; lock-free, unbounded retries possible."""

    def __init__(self, ctx: SimContext, m: int, k: int = 2):
        self.ctx = ctx
        self.mem = BlockMemory(ctx, m, k)
        # (head pointer, tag) packed into one CAS object; the tag is the
        # unbounded sequence number the paper's algorithm avoids.
        self.head = CASWord(ctx, (NULL, 0), category="baseline_head")
        top = NULL
        for b in range(m):
            self.mem.words[b][0] = top
            top = b
        self.head.value = (top, 0)
        self.live: set = set()

    def allocate(self, pid: int) -> Generator:
        rec = self.ctx.begin_op(pid, "allocate")
        while True:
            h, tag = yield from self.head.read(pid)
            if h == NULL:
                self.ctx.end_op(rec, NULL)
                return NULL
            nxt = yield from self.mem.read(pid, h, 0)
            ok = yield from self.head.cas(pid, (h, tag), (nxt, tag + 1))
            if ok:
                self.live.add(h)
                self.ctx.end_op(rec, h)
                return h

    def free(self, pid: int, b: int) -> Generator:
        rec = self.ctx.begin_op(pid, "free", b)
        self.live.discard(b)
        while True:
            h, tag = yield from self.head.read(pid)
            yield from self.mem.write(pid, b, 0, h)
            ok = yield from self.head.cas(pid, (h, tag), (b, tag + 1))
            if ok:
                self.ctx.end_op(rec)
                return


class HoardSpaceModel:
    """Additive memory blowup model for superblock allocators.

    Hoard-style allocators move blocks between private heaps and the
    global heap in contiguous *superblocks* of S blocks; each private
    heap can hold up to a constant number of partially-empty superblocks,
    giving Theta(p * S) additive blowup (S is typically a multiple of the
    page size, so S >> p).  The paper's allocator achieves Theta(p^2)
    additive blowup with batches of ell = Theta(p) non-contiguous blocks.
    """

    def __init__(self, p: int, superblock_blocks: int, per_heap_superblocks: int = 2):
        self.p = p
        self.S = superblock_blocks
        self.c = per_heap_superblocks

    def additive_blowup_blocks(self) -> int:
        return self.p * self.S * self.c

    @staticmethod
    def paper_blowup_blocks(p: int, ell: Optional[int] = None) -> int:
        ell = ell if ell is not None else 4 * p
        return p * 3 * ell   # <= 3 ell blocks per private pool
