"""Adversarial instruction-level schedulers for the concurrency simulation.

A *process program* is a generator (built from the allocator / stack
operations); the scheduler interleaves them one instruction at a time.
Policies:

* ``random``      — uniformly random runnable process each step.
* ``round_robin`` — cyclic.
* ``bursty``      — random process runs a geometric burst of steps
  (models cache-friendly co-runs and long stalls of everyone else).
* ``stall_one``   — one chosen victim process is scheduled only once
  every ``stall`` steps (models a straggler).
* callable        — any ``(step, runnable_pids, rng) -> pid``.

Crash failures: ``crash(pid)`` stops a process forever (it is never
scheduled again); the paper's wait-freedom means everyone else still
completes in bounded own-steps.
"""

from __future__ import annotations

import random
from typing import Callable, Dict, Generator, List, Optional


class Scheduler:
    def __init__(self, seed: int = 0):
        self.rng = random.Random(seed)
        self.programs: Dict[int, Generator] = {}
        self.done: Dict[int, bool] = {}
        self.crashed: set = set()
        self.steps = 0

    def add(self, pid: int, program: Generator) -> None:
        self.programs[pid] = program
        self.done[pid] = False

    def crash(self, pid: int) -> None:
        self.crashed.add(pid)

    def runnable(self) -> List[int]:
        return [p for p, d in self.done.items()
                if not d and p not in self.crashed]

    def step_one(self, pid: int) -> None:
        try:
            next(self.programs[pid])
        except StopIteration:
            self.done[pid] = True
        self.steps += 1

    def run(
        self,
        policy: str | Callable = "random",
        max_steps: int = 10_000_000,
        crash_at: Optional[Dict[int, int]] = None,
    ) -> int:
        """Run until all non-crashed programs finish; returns steps taken."""
        crash_at = crash_at or {}
        burst_pid, burst_left = None, 0
        victim = None
        if policy == "stall_one":
            victim = self.rng.choice(list(self.programs))
        while self.steps < max_steps:
            for pid, at in list(crash_at.items()):
                if self.steps >= at:
                    self.crash(pid)
                    del crash_at[pid]
            runnable = self.runnable()
            if not runnable:
                break
            if callable(policy):
                pid = policy(self.steps, runnable, self.rng)
            elif policy == "round_robin":
                pid = runnable[self.steps % len(runnable)]
            elif policy == "bursty":
                if burst_pid not in runnable or burst_left <= 0:
                    burst_pid = self.rng.choice(runnable)
                    burst_left = self.rng.randint(1, 64)
                pid = burst_pid
                burst_left -= 1
            elif policy == "stall_one":
                others = [p for p in runnable if p != victim]
                if others and (self.steps % 200 != 0 or victim not in runnable):
                    pid = self.rng.choice(others)
                else:
                    pid = victim if victim in runnable else self.rng.choice(runnable)
            else:  # random
                pid = self.rng.choice(runnable)
            self.step_one(pid)
        return self.steps


def closed_loop(pid: int, allocator, n_ops: int, rng: random.Random,
                held: Optional[List[int]] = None,
                max_held: int = 32,
                scribble: bool = True) -> Generator:
    """A user workload: random mix of allocate/free, holding <= max_held.

    ``scribble`` writes garbage into every word of allocated (live) blocks
    to validate the paper's claim that the allocator "works correctly
    regardless of what the user writes into the memory blocks".
    """
    held = held if held is not None else []
    for _ in range(n_ops):
        do_alloc = (not held) or (len(held) < max_held and rng.random() < 0.55)
        if do_alloc:
            b = yield from allocator.allocate(pid)
            if scribble:
                for w in range(allocator.mem.k):
                    allocator.mem.words[b][w] = 0xDEAD0000 | (pid << 8) | (w & 0xFF)
            held.append(b)
        else:
            b = held.pop(rng.randrange(len(held)))
            yield from allocator.free(pid, b)
    while held:
        yield from allocator.free(pid, held.pop())
