"""Linearizability / safety checkers.

Three checkers:

1. :func:`check_alloc_history` — allocator-specific safety on a recorded
   history: a linearizable fixed-size allocator must admit a sequential
   witness where every ``allocate`` returns an *available* block and every
   ``free`` targets a *live* block.  For allocate/free this reduces to
   interval conditions on each block's alternating alloc/free timeline
   (allocations of a block must strictly interleave with its frees), which
   we verify directly — no exponential search needed.

2. :func:`check_batch_alloc_history` — the batch-granular variant for
   the device pool's ``alloc_n`` / ``free_n`` (and rebalance) histories:
   a batch grant linearizes iff the per-block expansion does — an
   ``alloc_n`` returning K blocks is K allocations sharing one
   invocation/response interval, a ``free_n`` is the symmetric batch of
   frees (:func:`expand_batch_history` performs the expansion).
   ``preempt`` ops (the serving scheduler force-releasing a victim
   lane's blocks, arg = victim pid, result = released ids) expand
   exactly like ``free_n``.

3. :func:`check_preemption_history` — batch safety plus preemption
   *completeness*: when a ``preempt`` of victim v responds, every block
   granted to v and not yet freed must be in the preempt's released set
   — a preempted lane may not retain pages (the scheduler's page-budget
   accounting depends on it).

4. :func:`check_speculative_history` — speculative-episode completeness
   (DESIGN.md §10): a rejected draft must free exactly its whole-page
   over-allocation (granted − kept), on its own shard — leak and theft
   detection over ``spec``-tagged alloc_n / spec_rollback ops, on top
   of the sharded batch checks.

5. :class:`WGStackChecker` — a small Wing & Gong style exhaustive
   linearizability checker for stack histories (used on the P-SIM shared
   stack with small histories).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, List, Sequence, Tuple

from .sim import OpRecord


def check_alloc_history(history: Sequence[OpRecord]) -> List[str]:
    """Safety check for allocate/free histories.

    Uses invocation/response *steps* as the real-time order.  Returns a
    list of violation strings (empty == pass).

    Conditions (each implies no sequential witness exists if violated):
      * a block returned by two allocations with no free of that block
        whose interval could linearize between them;
      * a free of a block that was never allocated, or whose latest
        possible allocation responds after the free's invocation window
        closes without overlap.
    """
    errs: List[str] = []
    per_block: Dict[Any, List[OpRecord]] = {}
    for op in history:
        if not op.completed:
            continue
        if op.name == "allocate":
            per_block.setdefault(op.result, []).append(op)
        elif op.name == "free":
            per_block.setdefault(op.arg, []).append(op)

    for block, ops in per_block.items():
        # Sort by response step: a valid linearization must alternate
        # alloc, free, alloc, free ... when ops on one block are totally
        # ordered in real time.  With overlap we only flag definite
        # violations: two allocs both *responding* before any free of the
        # block *invokes* in between.
        ops_sorted = sorted(ops, key=lambda o: (o.response_step, o.invoke_step))
        live = False
        prev = None
        for op in ops_sorted:
            if op.name == "allocate":
                if live and prev is not None and prev.response_step < op.invoke_step:
                    # prev alloc strictly precedes this alloc; no free of
                    # this block linearized in between.
                    errs.append(
                        f"block {block}: double allocation "
                        f"(ops {prev.opid} then {op.opid})")
                live = True
                prev = op
            else:  # free
                if not live and prev is not None and prev.response_step < op.invoke_step:
                    errs.append(
                        f"block {block}: free while available (op {op.opid})")
                live = False
                prev = op
    return errs


# ------------------------------------------------------------- batch ops

def expand_batch_history(history: Sequence[OpRecord]) -> List[OpRecord]:
    """Expand batch operations into per-block ops for the safety check.

    * ``alloc_n`` (result = iterable of granted block ids) becomes one
      ``allocate`` per id;
    * ``free_n`` (arg = iterable of released block ids) becomes one
      ``free`` per id;
    * ``preempt`` (arg = victim pid, result = iterable of released
      block ids) becomes one ``free`` per released id — a preemption IS
      a batch free performed on the victim's behalf, so the interval
      reasoning is identical;
    * ``spec_rollback`` (arg = iterable of released block ids; the
      serving step returning a rejected draft's whole-page
      over-allocation) becomes one ``free`` per id — a rollback IS a
      batch free of same-step grants, so it must linearize like one
      (the episode-completeness conditions are a separate check,
      :func:`check_speculative_history`);
    * ``reconcile`` (arg = iterable of reclaimed block ids; crash
      recovery's :func:`hier_pool.audit_and_reconcile` returning a dead
      episode's pages to the free set) becomes one ``free`` per id —
      reclamation IS a batch free performed on the crashed processes'
      behalf, so post-recovery re-grants of those pages must not look
      like double allocation (the exactly-the-orphans condition is a
      separate check, :func:`check_recovery_history`);
    * ``crash`` (arg = iterable of crashed pids) passes through — it
      moves no blocks; only the recovery checker interprets it;
    * ``allocate`` / ``free`` pass through unchanged.

    Every expanded op inherits the batch op's invocation/response
    interval (the grant is one atomic step of the lane), so the
    interval reasoning of :func:`check_alloc_history` applies verbatim:
    batch grants must linearize exactly like their sequential
    expansion.  Rebalance moves *free* blocks between stacks and is
    invisible to the allocate/free history — conservation checks cover
    it (see tests).
    """
    out: List[OpRecord] = []
    serial = 10 ** 6      # expanded opids stay unique and ordered
    for op in history:
        if op.name == "alloc_n":
            ids = [b for b in (op.result or []) if b is not None and b >= 0]
            for j, b in enumerate(ids):
                out.append(OpRecord(
                    opid=op.opid * serial + j, pid=op.pid, name="allocate",
                    arg=None, invoke_step=op.invoke_step, steps=op.steps,
                    result=b, response_step=op.response_step))
        elif op.name in ("free_n", "spec_rollback"):
            ids = [b for b in (op.arg or []) if b is not None and b >= 0]
            for j, b in enumerate(ids):
                out.append(OpRecord(
                    opid=op.opid * serial + j, pid=op.pid, name="free",
                    arg=b, invoke_step=op.invoke_step, steps=op.steps,
                    result=None, response_step=op.response_step))
        elif op.name == "preempt":
            ids = [b for b in (op.result or []) if b is not None and b >= 0]
            for j, b in enumerate(ids):
                out.append(OpRecord(
                    opid=op.opid * serial + j, pid=op.pid, name="free",
                    arg=b, invoke_step=op.invoke_step, steps=op.steps,
                    result=None, response_step=op.response_step))
        elif op.name == "reconcile":
            ids = [b for b in (op.arg or []) if b is not None and b >= 0]
            for j, b in enumerate(ids):
                out.append(OpRecord(
                    opid=op.opid * serial + j, pid=op.pid, name="free",
                    arg=b, invoke_step=op.invoke_step, steps=op.steps,
                    result=None, response_step=op.response_step))
        else:
            out.append(op)
    return out


def check_batch_alloc_history(history: Sequence[OpRecord]) -> List[str]:
    """Safety check for histories containing batch ``alloc_n``/``free_n``
    ops (the two-level device pool's operations): expand batches to
    per-block ops, then run :func:`check_alloc_history`."""
    return check_alloc_history(expand_batch_history(history))


def check_preemption_history(history: Sequence[OpRecord]) -> List[str]:
    """Batch safety plus preemption completeness.

    On top of :func:`check_batch_alloc_history` (double-grant /
    free-while-available on the per-block expansion, with ``preempt``
    expanding to frees), replays the completed ops in response order
    and tracks each pid's held blocks: when a ``preempt`` op (arg =
    victim pid, result = released ids) responds, the victim must hold
    exactly the released set — a block the victim still held that the
    preempt did not release is a *leak* (the slot's pages survived its
    eviction), and a released block the victim never held is a
    *theft* (another lane's live page was force-freed).  Both break the
    serving scheduler's page-budget accounting even when the pure
    alloc/free history linearizes, which is why this is a separate
    check.
    """
    errs = check_batch_alloc_history(history)
    held: Dict[int, set] = {}
    owner: Dict[Any, int] = {}
    done = [op for op in history if op.completed]
    for op in sorted(done, key=lambda o: (o.response_step, o.invoke_step)):
        if op.name == "allocate":
            if op.result is not None and op.result >= 0:
                held.setdefault(op.pid, set()).add(op.result)
                owner[op.result] = op.pid
        elif op.name == "alloc_n":
            for b in (op.result or []):
                if b is not None and b >= 0:
                    held.setdefault(op.pid, set()).add(b)
                    owner[b] = op.pid
        elif op.name == "free":
            held.get(owner.pop(op.arg, op.pid), set()).discard(op.arg)
        elif op.name == "free_n":
            for b in (op.arg or []):
                if b is not None and b >= 0:
                    held.get(owner.pop(b, op.pid), set()).discard(b)
        elif op.name == "preempt":
            victim = op.arg
            released = {b for b in (op.result or [])
                        if b is not None and b >= 0}
            holding = held.get(victim, set())
            leaked = holding - released
            stolen = released - holding
            if leaked:
                errs.append(f"preempt op {op.opid}: victim {victim} "
                            f"retained blocks {sorted(leaked)}")
            if stolen:
                errs.append(f"preempt op {op.opid}: released blocks "
                            f"{sorted(stolen)} not held by victim {victim}")
            for b in released:
                owner.pop(b, None)
            held[victim] = set()
    return errs


def check_recovery_history(history: Sequence[OpRecord]) -> List[str]:
    """Batch safety plus crash-recovery completeness.

    On top of :func:`check_batch_alloc_history` (with ``reconcile``
    expanding to frees), replays the completed ops in response order
    tracking each pid's held blocks:

    * a ``crash`` op (arg = iterable of crashed pids) orphans every
      block those pids hold — the dead episodes can never free them;
    * the next ``reconcile`` op (arg = iterable of reclaimed block ids)
      must reclaim *exactly* the orphaned set: an orphan it misses is a
      **leak** (a dead request's page never returns to the free
      stacks), a reclaimed block nobody orphaned is a **double free**
      (a surviving holder's live page was pushed back while still
      mapped — the next grant hands one physical page to two lanes);
    * orphans still outstanding when the history ends are leaks too.

    Single id space — shard-split a multi-shard history with
    :func:`split_history_by_shard` first, as for the other checkers.
    Mirrors :func:`check_preemption_history`: both verify that a batch
    release performed *on behalf of* a lane (eviction there, reconcile
    here) matches exactly what the lane held.
    """
    errs = check_batch_alloc_history(history)
    held: Dict[int, set] = {}
    owner: Dict[Any, int] = {}
    orphaned: Dict[Any, int] = {}          # block -> crashed pid
    done = [op for op in history if op.completed]
    for op in sorted(done, key=lambda o: (o.response_step, o.invoke_step)):
        if op.name == "allocate":
            if op.result is not None and op.result >= 0:
                held.setdefault(op.pid, set()).add(op.result)
                owner[op.result] = op.pid
        elif op.name == "alloc_n":
            for b in (op.result or []):
                if b is not None and b >= 0:
                    held.setdefault(op.pid, set()).add(b)
                    owner[b] = op.pid
        elif op.name == "free":
            held.get(owner.pop(op.arg, op.pid), set()).discard(op.arg)
        elif op.name == "free_n":
            for b in (op.arg or []):
                if b is not None and b >= 0:
                    held.get(owner.pop(b, op.pid), set()).discard(b)
        elif op.name == "crash":
            for pid in (op.arg or []):
                for b in held.get(pid, set()):
                    orphaned[b] = pid
                held[pid] = set()
        elif op.name == "reconcile":
            reclaimed = {b for b in (op.arg or [])
                         if b is not None and b >= 0}
            leaked = set(orphaned) - reclaimed
            double = reclaimed - set(orphaned)
            if leaked:
                errs.append(
                    f"reconcile op {op.opid}: leaked blocks "
                    f"{sorted(leaked)} (orphaned by crashed pids "
                    f"{sorted({orphaned[b] for b in leaked})}, "
                    f"never reclaimed)")
            if double:
                errs.append(
                    f"reconcile op {op.opid}: blocks {sorted(double)} "
                    f"reclaimed but not orphaned (double free of a "
                    f"live holder's pages)")
            for b in reclaimed:
                owner.pop(b, None)
            orphaned.clear()
    if orphaned:
        errs.append(
            f"end of history: blocks {sorted(orphaned)} orphaned by "
            f"crashed pids {sorted(set(orphaned.values()))} were never "
            f"reclaimed (leak)")
    return errs


# ------------------------------------------------------------- sharded ops

def split_history_by_shard(history: Sequence[OpRecord]
                           ) -> Dict[int, List[OpRecord]]:
    """Partition a multi-shard history by ``op.meta["shard"]``.

    The multi-host pool keeps one id space PER SHARD (block ids are
    shard-local — shard 0's block 7 and shard 1's block 7 are different
    physical pages), so the per-block interval checks are only sound on
    a single shard's sub-history: running them on the merged history
    would flag legitimate concurrent grants of the same id on two
    shards as double allocation.  Ops missing the shard tag default to
    shard 0 (single-shard histories pass through unchanged).
    """
    out: Dict[int, List[OpRecord]] = {}
    for op in history:
        out.setdefault(op.meta.get("shard", 0), []).append(op)
    return out


def check_cross_shard_frees(history: Sequence[OpRecord]) -> List[str]:
    """Cross-shard theft check: a grant observed on shard i must be
    freed on shard i.

    Replays the completed ops in response order, tracking per-(shard,
    block) live-grant counts.  A ``free``/``free_n``/``preempt``
    release naming block b on shard j while b has no live grant on j
    but does on some i != j is a *cross-shard theft*: somebody freed a
    foreign shard's page through their own shard's allocator — the
    exact failure mode shard_map is supposed to make impossible
    (shard-local id spaces mean the free would corrupt an unrelated
    page on shard j while leaking the real one on shard i).
    """
    errs: List[str] = []
    live: Dict[Tuple[int, Any], int] = {}

    def grant(shard, b):
        live[(shard, b)] = live.get((shard, b), 0) + 1

    def release(shard, b, op):
        if live.get((shard, b), 0) > 0:
            live[(shard, b)] -= 1
            return
        holders = [s for (s, blk), n in live.items() if blk == b and n > 0]
        if holders:
            errs.append(
                f"op {op.opid} ({op.name}): block {b} freed on shard "
                f"{shard} but granted on shard(s) {sorted(holders)} — "
                f"cross-shard theft")

    done = [op for op in history if op.completed]
    for op in sorted(done, key=lambda o: (o.response_step, o.invoke_step)):
        shard = op.meta.get("shard", 0)
        if op.name == "allocate":
            if op.result is not None and op.result >= 0:
                grant(shard, op.result)
        elif op.name == "alloc_n":
            for b in (op.result or []):
                if b is not None and b >= 0:
                    grant(shard, b)
        elif op.name == "free":
            release(shard, op.arg, op)
        elif op.name in ("free_n", "spec_rollback"):
            for b in (op.arg or []):
                if b is not None and b >= 0:
                    release(shard, b, op)
        elif op.name == "preempt":
            for b in (op.result or []):
                if b is not None and b >= 0:
                    release(shard, b, op)
    return errs


def check_speculative_history(history: Sequence[OpRecord]) -> List[str]:
    """Speculative-episode completeness on top of the sharded batch
    checks (DESIGN.md §10 draft-page ownership).

    A speculative *episode* is one slot's draft lane in one serving
    step: an ``alloc_n`` grant of the lane's whole-page over-allocation
    (``meta["spec"] = episode id``), the verify decision recording the
    pages the accepted prefix keeps (``meta["kept"]`` on the rollback
    op), and a ``spec_rollback`` releasing the rejected tail
    (``meta["spec"]`` matching, arg = released ids).  On top of
    :func:`check_sharded_batch_history` (double-grant /
    free-while-available per shard, cross-shard theft, with rollbacks
    expanding to frees) this enforces, per episode:

    * **same shard** — every op of an episode carries one shard tag
      (a draft's pages come from its own slot's lane and must return
      there; crossing shards would corrupt a foreign id space);
    * **kept ⊆ granted** — the verify step cannot keep a page the
      grant never handed out (kept-set theft);
    * **released == granted − kept**, exactly:
        - a granted, unkept page missing from the release is a *leak*
          (the rejected draft retained its over-allocation — the §4.2
          slack and the scheduler's budget both silently shrink);
        - a released page outside granted − kept is a *theft* (the
          rollback freed a kept page, or another lane's live page).
      A missing rollback op is fine only for a full accept
      (granted == kept).
    """
    errs = check_sharded_batch_history(history)
    episodes: Dict[Any, dict] = {}
    for op in history:
        if not op.completed or "spec" not in op.meta:
            continue
        ep = episodes.setdefault(
            op.meta["spec"],
            {"granted": set(), "kept": set(), "freed": set(),
             "shards": set(), "ops": []})
        ep["shards"].add(op.meta.get("shard", 0))
        ep["ops"].append(op.opid)
        if op.name in ("alloc_n", "allocate"):
            ids = op.result if op.name == "alloc_n" else [op.result]
            ep["granted"] |= {b for b in (ids or [])
                              if b is not None and b >= 0}
            ep["kept"] |= {b for b in op.meta.get("kept", [])
                           if b is not None and b >= 0}
        elif op.name in ("spec_rollback", "free_n", "free"):
            ids = op.arg if op.name != "free" else [op.arg]
            ep["freed"] |= {b for b in (ids or [])
                            if b is not None and b >= 0}
            ep["kept"] |= {b for b in op.meta.get("kept", [])
                           if b is not None and b >= 0}
    for eid, ep in sorted(episodes.items(), key=lambda kv: str(kv[0])):
        if len(ep["shards"]) > 1:
            errs.append(f"spec episode {eid}: ops span shards "
                        f"{sorted(ep['shards'])} — a draft's pages must "
                        f"live and die on its own shard")
        stolen_kept = ep["kept"] - ep["granted"]
        if stolen_kept:
            errs.append(f"spec episode {eid}: kept blocks "
                        f"{sorted(stolen_kept)} never granted to the "
                        f"draft lane")
        expect = ep["granted"] - ep["kept"]
        leaked = expect - ep["freed"]
        theft = ep["freed"] - expect
        if leaked:
            errs.append(f"spec episode {eid}: rejected draft retained "
                        f"blocks {sorted(leaked)} (leak)")
        if theft:
            errs.append(f"spec episode {eid}: rollback released blocks "
                        f"{sorted(theft)} outside its over-allocation "
                        f"(theft)")
    return errs


def check_sharded_batch_history(history: Sequence[OpRecord]) -> List[str]:
    """Multi-shard safety: the cross-shard theft check on the whole
    history, plus the per-block batch checks on every shard's
    sub-history independently (:func:`split_history_by_shard`)."""
    errs = check_cross_shard_frees(history)
    for shard, ops in sorted(split_history_by_shard(history).items()):
        errs += [f"shard {shard}: {e}"
                 for e in check_batch_alloc_history(ops)]
    return errs


# ---------------------------------------------------------- classed ops

def split_history_by_class(history: Sequence[OpRecord]
                           ) -> Dict[int, List[OpRecord]]:
    """Partition a size-classed history by ``op.meta["cls"]``.

    The classed pool keeps one id space PER CLASS per shard (class 0's
    block 7 and class 1's block 7 are different physical blocks on the
    same shard — DESIGN.md §14), so per-block interval checks are only
    sound on a single class's sub-history, exactly as for shards.  Ops
    missing the class tag default to class 0 (single-class histories
    pass through unchanged)."""
    out: Dict[int, List[OpRecord]] = {}
    for op in history:
        out.setdefault(op.meta.get("cls", 0), []).append(op)
    return out


def check_cross_class_frees(history: Sequence[OpRecord]) -> List[str]:
    """Cross-class theft check: a grant observed in class i must be
    freed in class i (on its own shard).

    The class-axis mirror of :func:`check_cross_shard_frees`: id spaces
    are class-local, so a release naming block b under (class j, shard
    s) while b has no live grant there but does under (class i != j,
    shard s) freed a foreign class's block through its own class's
    allocator — corrupting class j's stack while leaking class i's
    block.  The classes never exchange blocks, so this can never be
    legitimate."""
    errs: List[str] = []
    live: Dict[Tuple[int, int, Any], int] = {}   # (cls, shard, block)

    def key(op, b):
        return (op.meta.get("cls", 0), op.meta.get("shard", 0), b)

    def grant(op, b):
        k = key(op, b)
        live[k] = live.get(k, 0) + 1

    def release(op, b):
        k = key(op, b)
        if live.get(k, 0) > 0:
            live[k] -= 1
            return
        cls, shard, _ = k
        holders = [c for (c, s, blk), n in live.items()
                   if blk == b and s == shard and n > 0 and c != cls]
        if holders:
            errs.append(
                f"op {op.opid} ({op.name}): block {b} freed in class "
                f"{cls} (shard {shard}) but granted in class(es) "
                f"{sorted(holders)} — cross-class theft")

    done = [op for op in history if op.completed]
    for op in sorted(done, key=lambda o: (o.response_step, o.invoke_step)):
        if op.name == "allocate":
            if op.result is not None and op.result >= 0:
                grant(op, op.result)
        elif op.name == "alloc_n":
            for b in (op.result or []):
                if b is not None and b >= 0:
                    grant(op, b)
        elif op.name == "free":
            release(op, op.arg)
        elif op.name in ("free_n", "spec_rollback"):
            for b in (op.arg or []):
                if b is not None and b >= 0:
                    release(op, b)
        elif op.name == "preempt":
            for b in (op.result or []):
                if b is not None and b >= 0:
                    release(op, b)
    return errs


def check_classed_batch_history(history: Sequence[OpRecord]) -> List[str]:
    """Size-classed multi-shard safety (DESIGN.md §14): the cross-class
    theft check on the whole history, then every class's sub-history
    through the full sharded batch checks independently — conservation
    and interval safety are per class per shard, because both the id
    spaces and the §4.2 argument are."""
    errs = check_cross_class_frees(history)
    for cls, ops in sorted(split_history_by_class(history).items()):
        errs += [f"class {cls}: {e}"
                 for e in check_sharded_batch_history(ops)]
    return errs


# ---------------------------------------------------------------- WG checker

@dataclass
class Event:
    pid: int
    op: str          # 'push' | 'pop'
    arg: Any
    result: Any
    invoke: int
    response: int


class WGStackChecker:
    """Exhaustive linearizability check for small stack histories."""

    def __init__(self, events: Sequence[Event]):
        self.events = list(events)

    def check(self) -> bool:
        events = sorted(self.events, key=lambda e: e.invoke)
        n = len(events)
        if n > 14:
            raise ValueError("exhaustive checker limited to small histories")

        def search(done: frozenset, stack: Tuple, memo: set) -> bool:
            if (done, stack) in memo:
                return False
            if len(done) == n:
                return True
            # an op may linearize now if it hasn't, and every op whose
            # response precedes its invocation has already linearized
            min_resp = min(
                (events[i].response for i in range(n) if i not in done),
                default=float("inf"))
            for i in range(n):
                if i in done:
                    continue
                e = events[i]
                if e.invoke > min_resp:
                    continue   # must linearize someone responding earlier
                new_stack = None
                if e.op == "push":
                    new_stack = stack + (e.arg,)
                else:
                    if stack:
                        if e.result == stack[-1]:
                            new_stack = stack[:-1]
                    else:
                        if e.result is None or e.result == -1:
                            new_stack = stack
                if new_stack is not None:
                    if search(done | {i}, new_stack, memo):
                        return True
            memo.add((done, stack))
            return False

        return search(frozenset(), tuple(), set())
