"""Simulated pool memory: ``m`` blocks of ``k >= 2`` words each.

Pointers are block indices (ints); :data:`~repro.core.sim.NULL` is the
null pointer.  Block word reads/writes are shared-memory instructions.

Word-borrowing layout used by the allocator (paper section 4.2):

* word 0 of a free block — ``next`` pointer chaining the blocks of a
  batch (``batch = stack<block>``),
* word 1 of the *first* block of a batch — ``next`` pointer for the
  thread-local ``local_batches`` stack,
* shared-stack nodes are ordinary blocks obtained from
  ``allocate_private``: word 0 = ``data`` (pointer to the batch's first
  block), word 1 = ``next`` (next node in the shared stack).

Live blocks belong entirely to the user; the allocator never relies on
their contents (the test harness scribbles over them to prove it).
"""

from __future__ import annotations

from typing import Generator, List

from .sim import SimContext, Step


class BlockMemory:
    """``m`` blocks x ``k`` words of simulated shared memory."""

    def __init__(self, ctx: SimContext, m: int, k: int = 2):
        assert k >= 2, "the paper requires blocks of k >= 2 words"
        self.ctx = ctx
        self.k = k
        self.words: List[List[int]] = [[0] * k for _ in range(m)]
        ctx.add_space("pool_blocks", m * k)

    @property
    def m(self) -> int:
        return len(self.words)

    def grow(self, nblocks: int) -> List[int]:
        """Model requesting more memory from the OS; returns new block ids."""
        start = len(self.words)
        self.words.extend([0] * self.k for _ in range(nblocks))
        self.ctx.add_space("pool_blocks", nblocks * self.k)
        return list(range(start, start + nblocks))

    def read(self, pid: int, block: int, word: int) -> Generator:
        yield Step
        self.ctx.global_step += 1
        self.ctx.charge(pid)
        return self.words[block][word]

    def write(self, pid: int, block: int, word: int, value: int) -> Generator:
        yield Step
        self.ctx.global_step += 1
        self.ctx.charge(pid)
        self.words[block][word] = value
