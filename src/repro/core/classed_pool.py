"""Size-classed allocation plane: one allocator, many fixed sizes.

The paper's O(1) allocate/free argument is *per fixed block size*, so
it generalizes verbatim to a small static vector of size classes: each
class is an independent :class:`~repro.core.hier_pool.HierPool`
(per-class private lanes over a per-class shared stack, per-class
drain/refill rebalance), and the §4.2 never-dry invariant is proven
independently per class — the classes never exchange blocks, so no
cross-class interaction can invalidate a class's slack argument
(DESIGN.md §14).  This is the bucketed ``pool_allocator`` shape
(SNIPPETS.md Snippet 1), with class boundaries chosen per the
reallocation analyses in PAPERS.md (Farach-Colton et al. 2405.12152,
Jin 2602.15417): a coarse class for paged KV (large pages amortize
page-table walks) and a fine class for small bounded state (ring
windows, recurrent state, encoder KV, draft-tail accounting) where a
whole KV-sized page would be mostly over-allocation.

Every op takes the class index ``cls`` as a *static* Python int — the
class vector is fixed at trace time, so a class-indexed call lowers to
exactly the single-class HLO on that class's leaves (single-class
configs are bit-identical to the pre-classed plane by construction).
``rebalance_*`` runs over ALL classes in one call so the jitted serve
step keeps its one-rebalance-per-step shape; passing ``cls`` rebalances
one class only (the torn per-class crash windows the chaos plane
injects).
"""

from __future__ import annotations

from typing import NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from . import hier_pool
from .hier_pool import HierPool


#: class index of the coarse paged-KV class — always present, always 0.
CLS_KV = 0
#: class index of the fine bounded-state class in a two-class config.
CLS_STATE = 1
#: class index of the read-only shared expert-weight class in a
#: three-class (expert-paged MoE) config.  Expert pages are never
#: written after load: residency is managed host-side through the same
#: addref/free_shared protocol pins use, with refcount = one ledger ref
#: + one ref per active batch routed through the expert (DESIGN.md §15).
CLS_EXPERT = 2


class ClassSpec(NamedTuple):
    """Static description of one size class."""
    page_size: int       # granularity, in token-capacity units
    num_blocks: int      # per-shard blocks in this class
    num_lanes: int       # private lanes (serving slots)
    ell: int             # lane batch size (lane capacity = 3*ell)


class ClassedPool(NamedTuple):
    """A static tuple of independent per-class HierPools (a pytree:
    tuples of NamedTuples of arrays — shard_map/vmap/jit transparent)."""
    classes: Tuple[HierPool, ...]


def n_classes(pool: ClassedPool) -> int:
    return len(pool.classes)


def cls_pool(pool: ClassedPool, cls: int) -> HierPool:
    """The class's underlying HierPool (read-only view)."""
    return pool.classes[cls]


def _put(pool: ClassedPool, cls: int, hp: HierPool) -> ClassedPool:
    cs = list(pool.classes)
    cs[cls] = hp
    return ClassedPool(classes=tuple(cs))


def validate_specs(specs: Sequence[ClassSpec],
                   max_live: Sequence[int], *,
                   degraded_ok: bool = False) -> Tuple[bool, ...]:
    """Plan-time §4.2 validation, per class (hier_pool.validate_plan).

    ``max_live[c]`` is class c's worst-case simultaneously-live blocks
    (the admission budget).  Raises ``ValueError`` naming the failing
    class unless ``degraded_ok``; returns the per-class fully-
    provisioned flags."""
    assert len(specs) == len(max_live)
    return tuple(
        hier_pool.validate_plan(
            s.num_blocks, s.num_lanes, s.ell, int(max_live[c]),
            degraded_ok=degraded_ok,
            what=f"class {c} (page_size={s.page_size})")
        for c, s in enumerate(specs))


def create(specs: Sequence[ClassSpec]) -> ClassedPool:
    """One single-shard HierPool per class."""
    return ClassedPool(classes=tuple(
        hier_pool.create(s.num_blocks, s.num_lanes, s.ell)
        for s in specs))


def create_dp(dp: int, specs: Sequence[ClassSpec]) -> ClassedPool:
    """One identical per-class pool vector per DP shard."""
    return ClassedPool(classes=tuple(
        hier_pool.create_dp(dp, s.num_blocks, s.num_lanes, s.ell)
        for s in specs))


# --------------------------------------------------- class-indexed ops
#
# Thin static-dispatch wrappers: extract class ``cls``, run the
# single-class op, put the result back.  Only the touched class's
# leaves appear in the lowered HLO.

def alloc_n_dp(pool: ClassedPool, cls: int, counts: jax.Array,
               max_per_lane: int) -> Tuple[ClassedPool, jax.Array]:
    hp, ids = hier_pool.alloc_n_dp(pool.classes[cls], counts, max_per_lane)
    return _put(pool, cls, hp), ids


def alloc_n_or_shared_dp(pool: ClassedPool, cls: int, counts: jax.Array,
                         max_per_lane: int
                         ) -> Tuple[ClassedPool, jax.Array]:
    hp, ids = hier_pool.alloc_n_or_shared_dp(
        pool.classes[cls], counts, max_per_lane)
    return _put(pool, cls, hp), ids


def alloc_from_shared_dp(pool: ClassedPool, cls: int, counts: jax.Array,
                         max_per_lane: int
                         ) -> Tuple[ClassedPool, jax.Array]:
    hp, ids = hier_pool.alloc_from_shared_dp(
        pool.classes[cls], counts, max_per_lane)
    return _put(pool, cls, hp), ids


def free_n_dp(pool: ClassedPool, cls: int, ids: jax.Array) -> ClassedPool:
    return _put(pool, cls, hier_pool.free_n_dp(pool.classes[cls], ids))


def free_n_metered_dp(pool: ClassedPool, cls: int, ids: jax.Array
                      ) -> Tuple[ClassedPool, jax.Array]:
    hp, spilled = hier_pool.free_n_metered_dp(pool.classes[cls], ids)
    return _put(pool, cls, hp), spilled


def free_shared_dp(pool: ClassedPool, cls: int,
                   ids: jax.Array) -> ClassedPool:
    return _put(pool, cls, hier_pool.free_shared_dp(pool.classes[cls], ids))


def addref_dp(pool: ClassedPool, cls: int, ids: jax.Array) -> ClassedPool:
    return _put(pool, cls, hier_pool.addref_dp(pool.classes[cls], ids))


def rebalance_dp(pool: ClassedPool,
                 cls: Optional[int] = None) -> ClassedPool:
    """Deamortized rebalance — all classes (default) in one call, so
    the serve step keeps one fused rebalance per step; ``cls`` limits
    to one class (torn per-class windows in chaos tests)."""
    if cls is not None:
        return _put(pool, cls, hier_pool.rebalance_dp(pool.classes[cls]))
    return ClassedPool(classes=tuple(
        hier_pool.rebalance_dp(hp) for hp in pool.classes))


def rebalance_drain_dp(pool: ClassedPool,
                       cls: Optional[int] = None) -> ClassedPool:
    if cls is not None:
        return _put(pool, cls,
                    hier_pool.rebalance_drain_dp(pool.classes[cls]))
    return ClassedPool(classes=tuple(
        hier_pool.rebalance_drain_dp(hp) for hp in pool.classes))


def rebalance_refill_dp(pool: ClassedPool,
                        cls: Optional[int] = None) -> ClassedPool:
    if cls is not None:
        return _put(pool, cls,
                    hier_pool.rebalance_refill_dp(pool.classes[cls]))
    return ClassedPool(classes=tuple(
        hier_pool.rebalance_refill_dp(hp) for hp in pool.classes))


# ------------------------------------------------------------- queries

def free_per_shard(pool: ClassedPool, cls: int) -> jax.Array:
    return hier_pool.free_per_shard(pool.classes[cls])


def live_per_shard(pool: ClassedPool, cls: int) -> jax.Array:
    return hier_pool.live_per_shard(pool.classes[cls])


def lane_ell(pool: ClassedPool, cls: int) -> int:
    return hier_pool.lane_ell(pool.classes[cls])


def pages_local(pool: ClassedPool, cls: int) -> int:
    """Per-shard block capacity of class ``cls`` (static)."""
    return pool.classes[cls].shared.free_ids.shape[-1]


def total_free(pool: ClassedPool) -> jax.Array:
    """Free blocks summed over ALL classes (and shards)."""
    return sum(hier_pool.total_free(hp) for hp in pool.classes)


def num_live(pool: ClassedPool) -> jax.Array:
    """Live blocks summed over ALL classes (and shards)."""
    return sum(hier_pool.num_live(hp) for hp in pool.classes)


# ------------------------------------------------------ crash recovery

def audit_and_reconcile(pool: ClassedPool, keep_tables=None,
                        pin_tables=None) -> Tuple[ClassedPool, dict]:
    """Per-class :func:`hier_pool.audit_and_reconcile`, merged report.

    ``keep_tables`` / ``pin_tables`` are per-class sequences (or None
    for none anywhere); entry c holds class c's keeping rows (None
    allowed per class — e.g. pins exist only in the KV class).  The
    merged report carries per-class sub-reports under ``"classes"``
    plus the same top-level keys the single-pool form exposes
    (conservation and never-dry are ANDed over classes — the §4.2
    argument is per class, so recovery must prove it per class).
    """
    C = len(pool.classes)

    def per(tabs, c):
        return None if tabs is None else tabs[c]

    new_classes, reports = [], []
    for c in range(C):
        hp, rep = hier_pool.audit_and_reconcile(
            pool.classes[c], keep_tables=per(keep_tables, c),
            pin_tables=per(pin_tables, c))
        new_classes.append(hp)
        reports.append(rep)
    merged = {
        "classes": reports,
        "reclaimed": sum(r["reclaimed"] for r in reports),
        "resurrected": sum(r["resurrected"] for r in reports),
        "clamped": sum(r["clamped"] for r in reports),
        "never_dry": all(r["never_dry"] for r in reports),
        "conserved": all(r["conserved"] for r in reports),
    }
    return ClassedPool(classes=tuple(new_classes)), merged
