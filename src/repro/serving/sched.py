"""Traffic-aware serving frontend: admission, preemption, pin policy.

The paper gives the layers *below* this one constant-time alloc/free —
the allocator never stalls under load.  This module is the layer that
decides **who gets the pages**: a scheduler subsystem that treats
pages-in-use as the contended resource, in the spirit of production
allocators that pair fast alloc/free with an explicit reclamation
policy under a memory budget (DESIGN.md §8).

Three responsibilities, all host-side policy over the engine's O(1)
mechanisms (nothing here touches the per-token hot path):

* **Admission** — per-SLO-class priority queues (FIFO within a class,
  strict priority across classes), continuous batching, and per-shard
  page-budget accounting: a request is admitted only onto a shard
  whose worst-case committed pages (every active request at its full
  ``prompt + max_new`` demand) plus cache-pinned pages leave room for
  its own worst case.  The budget defaults to ``b_local * max_pages``
  — exactly the table capacity the pool was sized for — so the §4.2
  never-dry invariant stays intact even with pinned pages subtracting
  from the pool's slack.  Backpressure is explicit: ``submit`` rejects
  with a reason (``queue_full``, ``too_large``) instead of queueing
  unservable work, and a blocked head-of-line defers with a recorded
  reason (``slots`` / ``pages``).

* **Preemption** — when the head of a higher-priority queue cannot be
  placed, the scheduler evicts pinned pages first (cheapest — only
  cache state), then preempts a lower-priority victim: the engine
  releases the victim's pages through the normal refcounted path
  (``hier_pool.free_n_dp`` inside ``_release_slots``) and the request
  is requeued at the *front* of its class carrying prompt + generated
  tokens, so readmission re-prefills through the prefix cache (often
  nearly free: the victim's whole-page state is pinned before release
  when the pin budget allows).  Output identity is preserved: greedy
  decode is position-deterministic, and the sampler keys noise by
  ``(seed, out_count)`` (serving/sampling.py), so a resumed request
  draws exactly the tokens it would have drawn unpreempted.

* **Hardening** (DESIGN.md §11) — per-request deadlines (queued or
  running, a request past ``deadline_at`` fails with the typed reason
  ``"deadline"``), bounded-backoff retry parking for fault-failed
  requests, and graceful shard-loss degradation: a dead shard leaves
  the placement set, its evacuated work requeues at the front, and
  when the recovery backlog's worst case exceeds the surviving
  capacity (``runtime.elastic.plan_serving_for``) the lowest class
  sheds from the tail with reason ``"shed"``.

* **Pin policy** — which finished-or-finishing prefixes stay pinned
  (`serving/prefix_cache.py` holds the mechanism): pin at prompt
  completion and at preemption, deduplicated by exact token key, LRU
  eviction per shard when the pinned-pages budget is exceeded, on
  admission pressure, or when a shard's pool occupancy crosses the
  high-water mark (read from the packed per-step status row — no extra
  device sync).
"""

from __future__ import annotations

import dataclasses
import itertools
from collections import deque
from typing import Deque, Dict, List, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class SLOClass:
    """One service class.  Higher ``priority`` admits first and may
    preempt strictly-lower-priority work (if ``preemptible``)."""
    name: str
    priority: int
    preemptible: bool = True


#: interactive preempts standard preempts batch; batch is the
#: background class that soaks up leftover capacity.
DEFAULT_CLASSES: Tuple[SLOClass, ...] = (
    SLOClass("interactive", 2),
    SLOClass("standard", 1),
    SLOClass("batch", 0),
)

#: every typed terminal failure a request can carry in ``req.rejected``
#: (DESIGN.md §11): admission backpressure (``too_large`` /
#: ``queue_full``), deadline expiry, a poisoned request out of retries,
#: and load shed under degraded capacity.
FAILURE_REASONS: Tuple[str, ...] = (
    "too_large", "queue_full", "deadline", "poisoned", "shed",
)


@dataclasses.dataclass(frozen=True)
class SchedConfig:
    classes: Tuple[SLOClass, ...] = DEFAULT_CLASSES
    #: reject new submissions beyond this backlog (0 = unbounded)
    max_queue: int = 0
    #: admissible worst-case pages per shard (0 = the engine default,
    #: b_local * max_pages — the capacity the pool is provisioned for)
    page_budget: int = 0
    #: admissible worst-case CLS_STATE blocks per shard in a size-
    #: classed config (0 = the engine default, b_local *
    #: state_blocks_per_slot — what class 1 is provisioned for).  The
    #: second budget dimension of admission: a shard must have headroom
    #: in BOTH classes, since the classes never exchange blocks
    #: (DESIGN.md §14)
    state_budget: int = 0
    #: admissible CLS_EXPERT pages per shard in an expert-paged config
    #: (0 = the engine default, full residency).  The third budget
    #: dimension — but load-aware, not worst-case-static: a request
    #: whose expert footprint is already resident on a shard costs 0
    #: pages there, a cold fan-out costs EXPERT_PPE pages per expert
    #: per MoE layer slot, and the engine nets out what LRU eviction of
    #: cold experts can reclaim (engine.expert_headroom; DESIGN.md §15)
    expert_budget: int = 0
    preemption: bool = True
    max_preemptions_per_tick: int = 2
    #: pinned-prefix pages budget per shard (0 disables pinning)
    pin_pages: int = 0
    #: device pin-table rows per shard
    pin_rows: int = 4
    #: shed pins when a shard's pool occupancy crosses this fraction
    high_water: float = 0.9
    #: retries granted to a request that fails mid-flight for a
    #: retryable reason (poisoned step, injected fault) before it is
    #: terminally rejected
    retry_limit: int = 1
    #: scheduler ticks a retrying request parks before re-queueing;
    #: the wait grows linearly with the retry count (bounded backoff)
    retry_backoff: int = 2
    #: SLO-aware chunk sizing (DESIGN.md §10): the static set of prefill
    #: lane widths the engine may dispatch (each is one compiled step
    #: variant).  () disables adaptation — every prefill step runs the
    #: engine's full ``chunk_size``.  With buckets configured the
    #: scheduler shrinks the prefill lane to the smallest bucket
    #: whenever latency-class work is waiting on lower-priority prefill
    #: (prefill/decode interference control); the engine's full chunk
    #: is always a member, so an idle queue always runs full-width.
    chunk_buckets: Tuple[int, ...] = ()


@dataclasses.dataclass
class Admission:
    """submit() decision; ``reason`` is empty when accepted."""
    accepted: bool
    reason: str = ""


class AdmissionScheduler:
    """Queues + accounting.  The engine owns the mechanisms (slot
    alloc, share, pin, release); ``tick`` drives them once per engine
    step, before the feed build — entirely host-side, no device sync.
    """

    def __init__(self, config: SchedConfig, n_shards: int,
                 page_budget: int, state_budget: int = 0):
        self.config = config
        self.classes = sorted(config.classes, key=lambda c: -c.priority)
        self.by_name = {c.name: c for c in self.classes}
        # unknown slo names fall into the lowest class rather than jump
        # the queue
        self.default_class = self.classes[-1]
        self.queues: Dict[str, Deque] = {c.name: deque()
                                         for c in self.classes}
        self.n_shards = n_shards
        self.page_budget = (config.page_budget or page_budget)
        #: fine-class (CLS_STATE) block budget per shard; 0 when the
        #: engine runs a single class — the dimension then never binds
        self.state_budget = (config.state_budget or state_budget)
        self.committed = [0] * n_shards             # worst-case pages
        self.committed_state = [0] * n_shards       # worst-case blocks
        # slot -> (shard, est_pages, est_state_blocks)
        self.est_of: Dict[int, Tuple[int, int, int]] = {}
        self._seq = itertools.count()
        #: shards lost to failure (engine.lose_shard): excluded from
        #: placement; their budget leaves ``plan_serving_for`` capacity
        self.dead_shards: set = set()
        #: (ready_tick, req) retry parking — bounded-backoff staging
        #: area for fault-failed requests (engine.fail_active)
        self.parked: List[Tuple[int, object]] = []
        self._ticks = 0
        # preemptions are counted by the mechanism (engine.preempt /
        # engine.stats) — one ledger, not two that can drift
        self.stats = {"deferred": 0, "rejected": 0, "pins_evicted": 0,
                      "defer_slots": 0, "defer_pages": 0,
                      "defer_experts": 0, "shed": 0, "retried": 0}
        #: set by the engine: the §13 Telemetry facade; every counter
        #: below mirrors into its typed ``sched_*`` namespace
        self.telemetry = None

    def _count(self, name: str, n: int = 1) -> None:
        self.stats[name] = self.stats.get(name, 0) + n
        if self.telemetry is not None:
            self.telemetry.inc("sched_" + name, n)

    # ---------------------------------------------------------- intake
    def class_of(self, req) -> SLOClass:
        return self.by_name.get(getattr(req, "slo", ""),
                                self.default_class)

    def submit(self, req, est_pages: int) -> Admission:
        if est_pages > self.page_budget:
            self._count("rejected")
            req.rejected = "too_large"
            return Admission(False, "too_large")
        if self.config.max_queue and self.backlog() >= self.config.max_queue:
            self._count("rejected")
            req.rejected = "queue_full"
            return Admission(False, "queue_full")
        self.queues[self.class_of(req).name].append(req)
        return Admission(True)

    def backlog(self) -> int:
        # parked retries count: the engine's run/idle loops key
        # liveness on backlog, and a parked request is still owed work
        return sum(len(q) for q in self.queues.values()) + len(self.parked)

    def pending(self) -> List:
        """Queued + parked requests, admission order (priority then
        FIFO; parked retries last)."""
        return ([r for c in self.classes for r in self.queues[c.name]]
                + [r for _, r in self.parked])

    def requeue_front(self, req) -> None:
        """A preempted request resumes before its class peers."""
        self.queues[self.class_of(req).name].appendleft(req)

    def park(self, req, delay: int) -> None:
        """Stage a retrying request for ``delay`` scheduler ticks
        before it rejoins its class queue (bounded backoff)."""
        self._count("retried")
        self.parked.append((self._ticks + max(0, int(delay)), req))

    def _unpark(self) -> None:
        still = []
        for ready, req in self.parked:
            if ready <= self._ticks:
                # back of the class queue: a retry yields to peers that
                # have not failed, unlike a preempted request
                self.queues[self.class_of(req).name].append(req)
            else:
                still.append((ready, req))
        self.parked = still

    # ------------------------------------------------------ accounting
    def on_admitted(self, slot: int, shard: int, est: int,
                    est_state: int = 0) -> None:
        self.committed[shard] += est
        self.committed_state[shard] += est_state
        self.est_of[slot] = (shard, est, est_state)

    def on_released(self, slot: int) -> None:
        """Slot finished or was preempted: uncommit its worst case."""
        shard, est, est_state = self.est_of.pop(slot)
        self.committed[shard] -= est
        self.committed_state[shard] -= est_state

    def headroom(self, shard: int, pinned_on) -> int:
        return self.page_budget - self.committed[shard] - pinned_on(shard)

    def state_headroom(self, shard: int) -> int:
        """Fine-class admission headroom (no pinning in CLS_STATE —
        bounded state dies with its request)."""
        return self.state_budget - self.committed_state[shard]

    # ------------------------------------------------------------ tick
    def tick(self, engine) -> None:
        """One admission pass: shed pins above high water, then admit
        heads in priority order, evicting pins / preempting victims for
        a blocked head before deferring it (strict priority — a blocked
        head blocks lower classes; admitting around it would consume
        the very pages it is waiting for)."""
        self._ticks += 1
        self._unpark()
        self._expire_deadlines(engine)
        if self.dead_shards:
            self._shed_backlog(engine)
        self._shed_high_water(engine)
        preempted = 0
        while True:
            head = self._head()
            if head is None:
                return
            cls, req = head
            est = engine.est_pages(req)
            est_state = engine.est_state_blocks(req)
            match, shard, blocked = self._place(engine, req, est,
                                                est_state)
            if blocked is None:
                self.queues[cls.name].popleft()
                slot = engine.admit(req, match, shard)
                req._seq = next(self._seq)
                self.on_admitted(slot, slot // engine.bl, est, est_state)
                continue
            if blocked == "pages" and self._evict_pins_for(engine, est):
                continue
            if (self.config.preemption
                    and preempted < self.config.max_preemptions_per_tick):
                victim = self._pick_victim(engine, cls.priority)
                if victim is not None:
                    vreq = engine.preempt(victim)
                    self.requeue_front(vreq)
                    preempted += 1
                    continue
            self._count("deferred")
            self._count(f"defer_{blocked}")
            return

    def _head(self):
        for cls in self.classes:
            if self.queues[cls.name]:
                return cls, self.queues[cls.name][0]
        return None

    # ------------------------------------------------------- hardening
    def _reject(self, engine, req, reason: str) -> None:
        req.rejected = reason
        self._count("rejected")
        engine._jrec("reject", rid=req.rid, reason=reason)
        engine._trace_terminal(req, reason)

    def _expire_deadlines(self, engine) -> None:
        """Fail every request past its absolute deadline — queued,
        parked, or running.  ``deadline_at`` is stamped at first submit
        and survives preemption/recovery, so a request cannot reset its
        own clock by failing (DESIGN.md §11)."""
        now = engine._clock()

        def expired(r):
            return 0.0 < getattr(r, "deadline_at", 0.0) < now

        for q in self.queues.values():
            for r in [r for r in q if expired(r)]:
                q.remove(r)
                engine.telemetry.inc("deadline_expired")
                self._reject(engine, r, "deadline")
        still = []
        for ready, r in self.parked:
            if expired(r):
                engine.telemetry.inc("deadline_expired")
                self._reject(engine, r, "deadline")
            else:
                still.append((ready, r))
        self.parked = still
        for slot in [s for s, r in engine.active.items() if expired(r)]:
            engine.fail_active(slot, "deadline")

    def lose_shard(self, shard: int) -> None:
        """Remove a shard from the placement set (engine.lose_shard
        owns the evacuation mechanics)."""
        self.dead_shards.add(shard)

    def _shed_backlog(self, engine) -> None:
        """Degraded-capacity load shedding: when the queued backlog's
        worst-case pages exceed the surviving shards' budget
        (``plan_serving_for``), drop from the lowest class's tail with
        the typed reason ``"shed"`` rather than queue unservable work."""
        from ..runtime.elastic import plan_serving_for
        backlog_pages = sum(engine.est_pages(r) for r in self.pending())
        plan = plan_serving_for(self.n_shards, self.dead_shards,
                                self.page_budget, backlog_pages)
        to_shed = plan.shed_pages
        for cls in reversed(self.classes):          # lowest class first
            q = self.queues[cls.name]
            while to_shed > 0 and q:
                victim = q.pop()                    # tail: newest work
                to_shed -= engine.est_pages(victim)
                victim.rejected = "shed"
                self._count("shed")
                engine._jrec("reject", rid=victim.rid, reason="shed")
                engine._trace_terminal(victim, "shed")
            if to_shed <= 0:
                break

    # ------------------------------------------------- lane-width policy
    def buckets(self, full_chunk: int) -> Tuple[int, ...]:
        """The static compile set: configured buckets clipped to the
        engine's full chunk, plus the full chunk itself (ascending)."""
        bs = {b for b in self.config.chunk_buckets
              if 1 <= b <= full_chunk}
        bs.add(int(full_chunk))
        return tuple(sorted(bs))

    def pick_chunk(self, engine, full_chunk: int) -> int:
        """Prefill lane width for this step (DESIGN.md §10).

        The engine dispatches exactly one step shape per step, so a
        long prefill chunk holds every decode lane in the batch hostage
        for its whole wall-clock — the prefill/decode interference the
        ROADMAP item names.  Policy: when work of the top latency class
        is *waiting* on strictly-lower-priority prefill — queued for a
        slot, or already decoding in a batch whose prompt feeds belong
        to lower classes — shrink to the smallest bucket; otherwise run
        the full chunk.  Width never affects output tokens (chunking is
        token-invariant), only step latency, so the policy is free to
        flip per step; each bucket is one compiled variant, chosen from
        the static :meth:`buckets` set.
        """
        bs = self.buckets(full_chunk)
        if len(bs) == 1:
            return bs[-1]
        top = self.classes[0]
        waiting = bool(self.queues[top.name])
        decoding_top = prefill_lower = False
        for slot, req in engine.active.items():
            cls = self.class_of(req)
            if engine.pending_tokens.get(slot):
                if cls.priority < top.priority:
                    prefill_lower = True
            elif cls.priority >= top.priority:
                decoding_top = True
        if (waiting or decoding_top) and prefill_lower:
            return bs[0]
        return bs[-1]

    def _place(self, engine, req, est, est_state: int = 0):
        """(match, shard, blocked): a shard-local prefix match, an
        admissible shard holding a free slot, or why not.

        Cross-host placement policy (DESIGN.md §9): page ids never
        alias across shards, so the trie is queried PER admissible
        shard and the request lands where its longest shard-local
        donor lives — a donor on an inadmissible (or foreign) shard is
        worthless even on an exact key match, and the returned match is
        always on the returned shard by construction.  With no donor
        anywhere, the shard with the most committed/pinned headroom
        takes the request (spread the worst case across hosts)."""
        slots = engine.free_slot_shards()
        if not slots:
            return None, None, "slots"
        pinned = engine.pinned_pages_on
        fits = [s for s in sorted(slots)
                if s not in self.dead_shards
                and est <= self.headroom(s, pinned)
                and (est_state <= 0
                     or est_state <= self.state_headroom(s))]
        if not fits:
            return None, None, "pages"
        # load-aware expert admission (DESIGN.md §15): the cost of a
        # request's expert footprint is per-shard — 0 where the experts
        # are hot (resident), EXPERT_PPE pages per cold (pos, group,
        # expert) slot — and headroom counts LRU-evictable cold experts
        # as reclaimable.  Skew in the footprint mix is therefore what
        # the scheduler learns: hot-expert traffic admits freely while
        # cold fan-outs wait for (or migrate to) a shard with paging
        # room, keeping every bulk load inside the class budget §4.2
        # is provisioned for.
        est_exp = getattr(engine, "est_expert_pages", None)
        if est_exp is not None:
            fits = [s for s in fits
                    if est_exp(req, s) <= engine.expert_headroom(s)]
            if not fits:
                return None, None, "experts"
        best = None                       # (n_tokens, shard, match)
        for s in fits:
            m = engine.prefix_match(req, shard=s)
            if m is not None and (best is None or m.n_tokens > best[0]):
                best = (m.n_tokens, s, m)
        if best is not None:
            return best[2], best[1], None
        # most headroom first: spread the worst case
        shard = max(fits, key=lambda s: self.headroom(s, pinned))
        return None, shard, None

    # ------------------------------------------------------ preemption
    def _pick_victim(self, engine, admit_priority: int) -> Optional[int]:
        """Lowest-priority, most-recently-admitted active slot strictly
        below the admitting priority (least progress lost), from a
        preemptible class."""
        cands = []
        for slot, vreq in engine.active.items():
            vcls = self.class_of(vreq)
            if vcls.priority < admit_priority and vcls.preemptible:
                cands.append((vcls.priority, -getattr(vreq, "_seq", 0),
                              slot))
        if not cands:
            return None
        return min(cands)[2]

    # ------------------------------------------------------ pin policy
    def _evict_pins_for(self, engine, est: int) -> bool:
        """Evict LRU pins until some free-slot shard can commit ``est``
        more worst-case pages.  Returns True on success."""
        if engine.pins is None:
            return False
        progressed = False
        for shard in sorted(engine.free_slot_shards()):
            while (self.headroom(shard, engine.pinned_pages_on) < est
                   and engine.pins.pages_on(shard) > 0):
                pin_id = engine.pins.lru(shard)
                engine.evict_pin(pin_id)
                self._count("pins_evicted")
                progressed = True
            if self.headroom(shard, engine.pinned_pages_on) >= est:
                return True
        return progressed and any(
            self.headroom(s, engine.pinned_pages_on) >= est
            for s in engine.free_slot_shards())

    def _shed_high_water(self, engine) -> None:
        """Pool-pressure eviction: the per-step status row carries each
        shard's pages-in-use; above ``high_water`` occupancy the cache
        gives pages back before they are forced out."""
        if engine.pins is None:
            return
        hw = self.config.high_water * engine.pages_local
        for shard in range(self.n_shards):
            while (engine.pages_used_shard[shard] > hw
                   and engine.pins.pages_on(shard) > 0):
                pin_id = engine.pins.lru(shard)
                pages = engine.pins.entries[pin_id]["pages"]
                engine.evict_pin(pin_id)
                self._count("pins_evicted")
                # the status row is one step stale — account the evicted
                # pages here so the loop terminates without a sync
                engine.pages_used_shard[shard] -= pages

    def may_pin(self, engine, shard: int, pages: int) -> bool:
        """Pin admission control: respect the pin budget (evicting LRU
        to make room) and never let pins squeeze committed work."""
        if engine.pins is None or pages <= 0:
            return False
        if pages > engine.pins.budget:
            return False
        while not engine.pins.fits(shard, pages):
            pin_id = engine.pins.lru(shard)
            if pin_id is None:
                return False
            engine.evict_pin(pin_id)
            self._count("pins_evicted")
        if not engine.pins.has_free_row(shard):
            pin_id = engine.pins.lru(shard)
            if pin_id is None:
                return False
            engine.evict_pin(pin_id)
            self._count("pins_evicted")
        return (self.committed[shard] + engine.pinned_pages_on(shard)
                + pages <= self.page_budget)
