"""Expert-paged MoE serving: the CLS_EXPERT plane (DESIGN.md §15).

Expert FFN weights are read-only pages in the classed pool's third
size class.  Residency is managed with the SAME addref/free_shared
protocol pinned prefixes use (serving/prefix_cache.py): an expert is a
read-only shared object whose refcount counts its owners —

* **one cache-owned reference** held by the host :class:`ExpertLedger`
  while the expert is resident (the pin analogue: the ledger's
  reference keeps the pages off the free stacks between requests);
* **one reference per active slot** whose admitted expert footprint
  contains the expert (registered in bulk at admission by
  :func:`expert_ref_step`, dropped host-side after the step's status
  sync when the slot releases — never inside ``_serve_step``, which
  stays one sync + one collective).

Eviction (:func:`expert_evict_step`) is exactly ``unpin_step`` shaped:
drop the cache's references, NULL the table row.  Pages some active
slot still references only decrement — the conservation invariants of
the refcount protocol carry over unchanged.  The ledger runs LRU over
*cold* experts (zero batch references), mirroring
:class:`~repro.serving.prefix_cache.PinnedPrefixes`.

Weight layout: one expert = ``EXPERT_PPE`` pages (w_gate, w_up,
w_down), each ``d_model * d_ff`` elements flat.  Loads pull the pages
from the class's shared stack in one bulk
:func:`~repro.core.classed_pool.alloc_from_shared_dp` grant —
admission-time traffic, off the per-token hot path, covered by the
class's §4.2 slack as long as admission respects the page budget
(``ServingEngine.expert_headroom``).
"""

from __future__ import annotations

import itertools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core import classed_pool
from ..core.block_pool import NULL
from ..core.classed_pool import CLS_EXPERT
from ..models.transformer import EXPERT_PPE, moe_positions


# ------------------------------------------------------------ host weights

def build_host_experts(cfg, params) -> Dict[str, np.ndarray]:
    """Host-side numpy copy of every MoE layer slot's expert weights,
    keyed by expert-table position: ``pos -> float[S, E, EXPERT_PPE,
    d_model*d_ff]`` (S = n_groups for pattern positions, 1 for
    remainder).  This is the backing store expert pages load from —
    kept on host exactly so the device copy can be paged."""
    pat, rem = moe_positions(cfg)
    pe = cfg.d_model * cfg.d_ff
    E = cfg.moe.num_experts
    out: Dict[str, np.ndarray] = {}
    for pos in pat:
        ffn = params["groups"][pos]["ffn"]
        mats = [np.asarray(ffn[k]) for k in ("w_gate", "w_up", "w_down")]
        G = mats[0].shape[0]
        out[pos] = np.stack([m.reshape(G, E, pe) for m in mats], axis=2)
    for pos in rem:
        j = pos[len("rem"):]
        ffn = params["rem"][f"pos{j}"]["ffn"]
        mats = [np.asarray(ffn[k]) for k in ("w_gate", "w_up", "w_down")]
        out[pos] = np.stack([m.reshape(E, pe) for m in mats], axis=1)[None]
    return out


def stub_expert_params(params):
    """Replace every MoE position's expert-weight leaves with [.., 1, 1]
    placeholders (leading stack dims kept so the group scan still
    slices them).  The paged FFN reconstructs its weights from
    CLS_EXPERT pages and never reads these leaves — keeping the dense
    [E, d, f] stacks resident would forfeit the HBM the paging buys."""
    def stub_ffn(ffn):
        out = dict(ffn)
        for k in ("w_gate", "w_up", "w_down"):
            w = ffn[k]
            out[k] = jnp.zeros(w.shape[:-2] + (1, 1), w.dtype)
        return out

    def stub_layers(layers):
        out = dict(layers)
        for pos, lp in layers.items():
            if (isinstance(lp, dict) and isinstance(lp.get("ffn"), dict)
                    and "router" in lp["ffn"]):
                lp = dict(lp)
                lp["ffn"] = stub_ffn(lp["ffn"])
                out[pos] = lp
        return out

    new = dict(params)
    if isinstance(params.get("groups"), dict):
        new["groups"] = stub_layers(params["groups"])
    if isinstance(params.get("rem"), dict):
        new["rem"] = stub_layers(params["rem"])
    return new


# ------------------------------------------------------------ device steps

def expert_load_step(pos, state, counts, w, shard_oh, g, e):
    """Jit-able: load one expert's pages on one shard.

    Pulls ``EXPERT_PPE`` pages from CLS_EXPERT's shared stack (bulk
    grant, like prefill loading), writes the expert's flat weight pages
    into them, and maps the ``(group g, expert e)`` row of ``pos``'s
    expert table.  counts: int32[DP, Bl] (EXPERT_PPE at (shard, 0),
    else 0); w: [EXPERT_PPE, page_elems] replicated; shard_oh:
    bool[DP]; g/e: int32 scalars (dynamic — one compile per table
    position, not per expert).  The caller (ExpertLedger-driven
    admission) has verified budget headroom, so the grant cannot dry
    the shared stack below its §4.2 slack (DESIGN.md §15).
    """
    pool, ids = classed_pool.alloc_from_shared_dp(
        state.pool, CLS_EXPERT, counts, EXPERT_PPE)
    pids = ids[:, 0, :]                                    # [DP, PPE]
    pages = state.expert_pages                             # [DP, NB, pe]
    nb = pages.shape[1]
    tgt = jnp.where(shard_oh[:, None] & (pids >= 0), pids, nb)

    def write(pg, t):                                      # [NB, pe], [PPE]
        return pg.at[t].set(w.astype(pg.dtype), mode="drop")

    pages = jax.vmap(write)(pages, tgt)
    tab = state.expert_tables[pos]                         # [S, DP, E, PPE]
    S, _, E, _ = tab.shape
    sel = ((jnp.arange(S, dtype=jnp.int32)[:, None, None] == g)
           & shard_oh[None, :, None]
           & (jnp.arange(E, dtype=jnp.int32)[None, None, :] == e))
    tab = jnp.where(sel[..., None], pids[None, :, None, :], tab)
    tables = dict(state.expert_tables)
    tables[pos] = tab
    return state._replace(pool=pool, expert_pages=pages,
                          expert_tables=tables)


def expert_evict_step(pos, state, shard_oh, g, e):
    """Jit-able eviction (the ``unpin_step`` analogue): drop the
    cache-owned references on one expert's pages and NULL its table
    row.  Pages an active slot still references only decrement
    (``free_shared`` on a refcount >= 2 page); a page reaching zero
    returns to the shard's shared stack."""
    tab = state.expert_tables[pos]                         # [S, DP, E, PPE]
    S, DP, E, _ = tab.shape
    sel = ((jnp.arange(S, dtype=jnp.int32)[:, None, None] == g)
           & shard_oh[None, :, None]
           & (jnp.arange(E, dtype=jnp.int32)[None, None, :] == e))
    ids = jnp.where(sel[..., None], tab, NULL)
    pool = classed_pool.free_shared_dp(
        state.pool, CLS_EXPERT, jnp.moveaxis(ids, 1, 0).reshape(DP, -1))
    tab = jnp.where(sel[..., None], NULL, tab)
    tables = dict(state.expert_tables)
    tables[pos] = tab
    return state._replace(pool=pool, expert_tables=tables)


def expert_ref_step(free, state, masks, shard_oh):
    """Jit-able bulk reference traffic for one slot's whole expert
    footprint: addref (admission) or free_shared (release) every page
    of every selected expert across every table position, in ONE call.

    masks: dict pos -> bool[S, E] (the slot's footprint, broadcast over
    groups); shard_oh: bool[DP].  ``free`` is static (two compiles).
    NULL table entries pass through both paths as no-ops, so a
    footprint larger than the resident set is harmless — but admission
    loads every footprint expert first, so that never happens outside
    fault paths."""
    cols = []
    for pos in sorted(state.expert_tables):
        tab = state.expert_tables[pos]                     # [S, DP, E, PPE]
        DP = tab.shape[1]
        m = masks[pos][:, None, :] & shard_oh[None, :, None]  # [S, DP, E]
        ids = jnp.where(m[..., None], tab, NULL)
        cols.append(jnp.moveaxis(ids, 1, 0).reshape(DP, -1))
    ids = jnp.concatenate(cols, axis=1)
    op = classed_pool.free_shared_dp if free else classed_pool.addref_dp
    pool = op(state.pool, CLS_EXPERT, ids)
    return state._replace(pool=pool)


# ------------------------------------------------------------- host ledger

class ExpertLedger:
    """Host-side ledger of CLS_EXPERT residency (the
    :class:`~repro.serving.prefix_cache.PinnedPrefixes` analogue).

    Pure bookkeeping — pages live behind the expert tables and the
    pool refcounts; this class answers the policy questions admission
    asks: is (shard, pos, group, expert) resident, how many pages does
    the cache hold on shard d, how many of those are *evictable* (zero
    batch references — no active slot routes through them), and who is
    the LRU cold expert.  ``batch`` mirrors the per-slot references the
    pool carries; an expert with ``batch > 0`` is never an eviction
    candidate (its pages are live working set, not cache)."""

    def __init__(self, n_shards: int, budget_pages: int):
        self.n_shards = int(n_shards)
        self.budget = int(budget_pages)
        #: (shard, pos, g, e) -> {"batch": int, "used": clock}
        self.entries: Dict[Tuple[int, str, int, int], dict] = {}
        self._clock = itertools.count()

    @staticmethod
    def key(shard: int, pos: str, g: int, e: int):
        return (int(shard), pos, int(g), int(e))

    # -- queries --------------------------------------------------------
    def resident(self, shard: int, pos: str, g: int, e: int) -> bool:
        return self.key(shard, pos, g, e) in self.entries

    def pages_on(self, shard: int) -> int:
        return EXPERT_PPE * sum(1 for k in self.entries if k[0] == shard)

    def evictable_pages(self, shard: int) -> int:
        return EXPERT_PPE * sum(1 for k, e in self.entries.items()
                                if k[0] == shard and e["batch"] == 0)

    def lru(self, shard: int):
        """LRU *cold* expert on a shard (None if every resident expert
        has active batch references)."""
        cands = [(e["used"], k) for k, e in self.entries.items()
                 if k[0] == shard and e["batch"] == 0]
        return min(cands)[1] if cands else None

    def resident_count(self) -> int:
        return len(self.entries)

    # -- mutation -------------------------------------------------------
    def add(self, shard: int, pos: str, g: int, e: int) -> None:
        self.entries[self.key(shard, pos, g, e)] = {
            "batch": 0, "used": next(self._clock)}

    def remove(self, key) -> None:
        ent = self.entries.pop(key)
        assert ent["batch"] == 0, "evicting an expert with active refs"

    def addref(self, key) -> None:
        ent = self.entries[key]
        ent["batch"] += 1
        ent["used"] = next(self._clock)

    def deref(self, key) -> None:
        ent = self.entries.get(key)
        if ent is not None and ent["batch"] > 0:
            ent["batch"] -= 1

    def touch(self, key) -> None:
        if key in self.entries:
            self.entries[key]["used"] = next(self._clock)

    def drop_shard(self, shard: int) -> None:
        """A dead shard's expert pages are unreachable — they leave the
        ledger with the shard (engine.lose_shard)."""
        for k in [k for k in self.entries if k[0] == shard]:
            del self.entries[k]

    def clear(self) -> None:
        """Crash recovery: the pool reconcile reclaimed every
        CLS_EXPERT page (no keep rows survive a recovery), so the
        ledger starts empty and experts reload on the next admission."""
        self.entries.clear()
