"""Fault injection and crash recovery for the serving plane.

The paper's allocator is built so that a process can die anywhere —
mid-allocate, mid-free, even mid-rebalance — and the pool stays sound.
This module makes the serving engine honor the same contract at host
granularity (DESIGN.md §11):

* :class:`ServingFailureInjector` deterministically injects host
  crashes, shard loss, step stragglers, generic step errors, and
  poisoned requests at named ``engine.step()`` phase boundaries
  (:data:`PHASES`), including a *torn* crash that lands inside the
  rebalance's drain/refill window;
* :class:`ServingJournal` is the host-side admission/completion log —
  the only host state recovery trusts.  Everything else is rebuilt from
  the device-resident arrays (kv pages, pin rows, pool refcounts);
* :func:`recover_engine` performs the recovery: reconcile the pool via
  :func:`hier_pool.audit_and_reconcile`, restore journaled pins with
  their KV content, and requeue every in-flight request through the
  existing preemption-resume path.  Because the sampler keys its noise
  by ``fold_in(seed, out_count)``, replay regenerates exactly the
  tokens the crash lost — recovery is token-identical for greedy and
  sampled decode alike.
"""

from __future__ import annotations

import dataclasses
import json
import time
from typing import Any, Dict, IO, List, Optional, Tuple

import numpy as np

from ..core import NULL, classed_pool

#: ``engine.step()`` phase boundaries, in execution order.  ``pre_tick``
#: and ``post_admission`` fire every step; the rest only when the step
#: dispatches work.  ``feed`` fires BEFORE any per-slot feed mutation,
#: so a fault there leaves host and device consistent; ``post_sync``
#: fires after the device round-trip but BEFORE bookkeeping/journaling —
#: a crash there loses the step's tokens and replay must regenerate
#: them.
PHASES = ("pre_tick", "post_admission", "feed", "dispatched",
          "post_sync", "post_step")


class HostCrash(RuntimeError):
    """The serving host died: all host state is lost; the device-resident
    arrays and the journal survive.  Recover with :func:`recover_engine`."""


class StepError(RuntimeError):
    """A step failed without killing the host (driver bug, transient
    device error).  ``ServingEngine.run`` recovers in place."""


class PoisonedRequest(RuntimeError):
    """A specific request deterministically fails the step that feeds it."""

    def __init__(self, rid: int, slot: int):
        super().__init__(f"poisoned request rid={rid} (slot {slot})")
        self.rid = rid
        self.slot = slot


@dataclasses.dataclass
class Fault:
    """One scheduled fault.

    ``step`` is a floor, not an exact match: the fault fires at the
    first step >= ``step`` whose execution reaches ``phase`` (idle steps
    never reach the dispatch phases).  ``poison`` faults instead fire at
    the first reached ``feed`` whose batch contains ``rid``.
    """

    step: int
    phase: str
    kind: str                    # crash | shard_loss | straggler | poison | error
    shard: int = 0               # shard_loss: which shard dies
    rid: Optional[int] = None    # poison: which request
    delay: float = 0.0           # straggler: injected seconds
    torn: bool = False           # crash: plant a torn mid-rebalance pool first
    fired: bool = False

    def __post_init__(self):
        assert self.phase in PHASES, f"unknown phase {self.phase!r}"
        assert self.kind in ("crash", "shard_loss", "straggler", "poison",
                             "error"), f"unknown fault kind {self.kind!r}"


class ServingFailureInjector:
    """Deterministic fault schedule keyed on (step, phase).

    The engine calls :meth:`fire` at every phase boundary; the injector
    counts steps itself (``pre_tick`` opens a new step) so the schedule
    survives engine recovery — the recovered engine keeps the same
    injector object and later faults still fire.
    """

    def __init__(self, faults: List[Fault]):
        self.faults = list(faults)
        self.step = -1
        self.log: List[Tuple[int, str, str]] = []

    def pending(self) -> int:
        return sum(1 for f in self.faults if not f.fired)

    def fire(self, engine: Any, phase: str,
             rids: Optional[Dict[int, int]] = None) -> None:
        if phase == "pre_tick":
            self.step += 1
        for f in self.faults:
            if f.fired or f.phase != phase:
                continue
            if f.kind == "poison":
                if rids and f.rid in rids and self.step >= f.step:
                    f.fired = True
                    self.log.append((self.step, phase, "poison"))
                    raise PoisonedRequest(f.rid, rids[f.rid])
                continue
            if self.step < f.step:
                continue
            f.fired = True
            self.log.append((self.step, phase, f.kind))
            if f.kind == "straggler":
                time.sleep(f.delay)
            elif f.kind == "shard_loss":
                engine.lose_shard(f.shard)
            elif f.kind == "error":
                raise StepError(
                    f"injected step error @ step {self.step}:{phase}")
            elif f.kind == "crash":
                if f.torn:
                    # leave the pool mid-rebalance: drain ran, refill
                    # did not — the torn window reconcile must handle
                    engine.state = engine.state._replace(
                        pool=classed_pool.rebalance_drain_dp(engine.state.pool))
                raise HostCrash(
                    f"injected host crash @ step {self.step}:{phase}"
                    + (" (torn rebalance)" if f.torn else ""))


def parse_faults(spec: str) -> ServingFailureInjector:
    """Parse a CLI fault schedule: ``kind@step:phase[:extra],...``

    ``extra`` is the shard for ``shard_loss``, the rid for ``poison``,
    the seconds for ``straggler``, and the literal ``torn`` for a
    mid-rebalance ``crash``.  Example::

        crash@3:post_sync,shard_loss@5:post_admission:1,
        straggler@2:pre_tick:0.05,poison@1:feed:7,crash@9:dispatched:torn
    """
    faults = []
    for part in spec.split(","):
        part = part.strip()
        if not part:
            continue
        kind, rest = part.split("@", 1)
        pieces = rest.split(":")
        step, phase = int(pieces[0]), pieces[1]
        extra = pieces[2] if len(pieces) > 2 else None
        f = Fault(step=step, phase=phase, kind=kind)
        if kind == "shard_loss":
            f.shard = int(extra or 0)
        elif kind == "poison":
            assert extra is not None, "poison needs a rid"
            f.rid = int(extra)
        elif kind == "straggler":
            f.delay = float(extra or 0.05)
        elif kind == "crash":
            f.torn = extra == "torn"
        faults.append(f)
    return ServingFailureInjector(faults)


# ------------------------------------------------------------------ journal


class ServingJournal:
    """Host-side admission/completion log — recovery's source of truth.

    The engine appends one event per state transition it performs
    *after* the corresponding device work completed (write-ahead for
    admission, write-behind for emission), so after a crash:

    * a journaled pin whose device row survived keeps its pages;
      a device pin the journal never saw is reclaimed (the crash landed
      between the device op and the journal write — the pages would
      otherwise leak);
    * an in-flight request replays from its journaled token prefix; the
      tokens of a step whose ``post_sync`` bookkeeping never ran are
      regenerated deterministically by the ``fold_in(seed, out_count)``
      sampler keying.

    With ``path`` set, events are additionally appended to a JSONL file
    (``load`` replays it), modeling a durable log.
    """

    def __init__(self, path: Optional[str] = None):
        self.events: List[dict] = []
        self.path = path
        self._fh: Optional[IO[str]] = open(path, "a") if path else None

    def record(self, kind: str, **fields: Any) -> None:
        ev = {"kind": kind, **fields}
        self.events.append(ev)
        if self._fh is not None:
            self._fh.write(json.dumps(ev, default=int) + "\n")
            self._fh.flush()

    def close(self) -> None:
        if self._fh is not None:
            self._fh.close()
            self._fh = None

    @classmethod
    def load(cls, path: str) -> "ServingJournal":
        j = cls()
        with open(path) as fh:
            for line in fh:
                line = line.strip()
                if line:
                    j.events.append(json.loads(line))
        return j

    # --------------------------------------------------------- replays
    def in_flight(self) -> List[dict]:
        """Submitted-but-unfinished request specs, in submit order, with
        the accumulated journaled token stream."""
        flight: Dict[int, dict] = {}
        order: List[int] = []
        for ev in self.events:
            k = ev["kind"]
            if k == "submit":
                rid = ev["rid"]
                spec = dict(ev)
                spec["out_tokens"] = list(ev.get("out_tokens", []))
                flight[rid] = spec
                if rid not in order:
                    order.append(rid)
            elif k == "tokens" and ev["rid"] in flight:
                flight[ev["rid"]]["out_tokens"].extend(ev["toks"])
            elif k in ("finish", "reject"):
                flight.pop(ev["rid"], None)
        return [flight[r] for r in order if r in flight]

    def outputs(self) -> Dict[int, List[int]]:
        """Latest known emitted stream per rid (finished or not)."""
        outs: Dict[int, List[int]] = {}
        for ev in self.events:
            if ev["kind"] == "submit":
                outs[ev["rid"]] = list(ev.get("out_tokens", []))
            elif ev["kind"] == "tokens" and ev["rid"] in outs:
                outs[ev["rid"]].extend(ev["toks"])
        return outs

    def finished(self) -> set:
        return {ev["rid"] for ev in self.events if ev["kind"] == "finish"}

    def live_pins(self) -> List[dict]:
        """Pin entries still live at the end of the log (pins on lost
        shards are dropped — their pages died with the shard)."""
        pins: Dict[int, dict] = {}
        for ev in self.events:
            if ev["kind"] == "pin":
                pins[ev["pin_id"]] = dict(ev)
            elif ev["kind"] == "unpin":
                pins.pop(ev["pin_id"], None)
            elif ev["kind"] == "shard_lost":
                pins = {p: e for p, e in pins.items()
                        if e["shard"] != ev["shard"]}
        return list(pins.values())

    def lost_shards(self) -> set:
        return {ev["shard"] for ev in self.events
                if ev["kind"] == "shard_lost"}


# ----------------------------------------------------------------- recovery


def recover_engine(factory, crashed, journal: ServingJournal):
    """Rebuild a serving engine after a :class:`HostCrash`.

    ``factory`` constructs a fresh engine with the same topology
    (normally closing over the same journal and injector, so the
    recovered engine keeps journaling and later scheduled faults still
    fire).  ``crashed`` is the dead engine: its host state is
    untrusted, but its device-resident arrays (DecodeState, pin tables)
    survive and are the ground truth together with the journal.

    Returns ``(engine, report)`` where ``report`` extends the
    :func:`hier_pool.audit_and_reconcile` report with ``requeued``,
    ``pins_restored``, ``finished_at_crash`` and the requeued
    ``requests`` (for token-identity checks).
    """
    from .engine import Request

    eng = factory()
    assert eng.dp == crashed.dp and eng.bl == crashed.bl, \
        "recovery requires an identical topology"

    # observability survives the crash (DESIGN.md §13): the dead
    # engine's flight ring and trace buffer carry into the recovered
    # engine, so the pre-crash window stays in the next dump and spans
    # opened before the crash close correctly after it
    eng.flight.adopt(crashed.flight)
    eng.telemetry.tracer = eng.tracer = crashed.telemetry.tracer
    eng.tracer.begin("recover", kind="host_crash")

    # journal-trusted pin rows: mask the device pin tables down to rows
    # the journal confirms; everything else is reclaimed by reconcile
    pins_live = journal.live_pins() if eng.pins is not None else []
    pin_np = None
    if crashed.pin_tables is not None:
        pin_np = np.asarray(crashed.pin_tables).copy()
        ok = np.zeros(pin_np.shape[:2], bool)
        for e in pins_live:
            ok[e["shard"], e["row"]] = True
        pin_np[~ok] = NULL

    report = eng.adopt_crashed_state(crashed.state, pin_np)

    if eng.pins is not None and pins_live:
        eng.pins.load_state(pins_live)
        if eng.prefix_cache is not None:
            for pid, e in eng.pins.entries.items():
                eng.prefix_cache.pin_insert(pid, e["shard"],
                                            list(e["tokens"]))

    for s in sorted(journal.lost_shards()):
        eng.lose_shard(s)            # fresh engine: just retires the shard

    # requeue every journaled in-flight request through the admission
    # path — the preemption-resume contract (out_count = len(out_tokens)
    # keys both the budget check and the sampler stream) makes replay
    # token-identical
    requeued: List[Request] = []
    finished_now = 0
    for spec in journal.in_flight():
        req = Request(rid=spec["rid"], prompt=list(spec["prompt"]),
                      max_new_tokens=int(spec["max_new_tokens"]),
                      temperature=float(spec.get("temperature", 0.0)),
                      top_k=int(spec.get("top_k", 0)),
                      seed=int(spec.get("seed", 0)),
                      slo=spec.get("slo", "standard"),
                      out_tokens=list(spec["out_tokens"]))
        req.preemptions = int(spec.get("preemptions", 0)) + 1
        req.deadline_at = float(spec.get("deadline_at", 0.0))
        if (len(req.out_tokens) >= req.max_new_tokens
                or (eng.eos_id is not None and req.out_tokens
                    and req.out_tokens[-1] == eng.eos_id)):
            # finished on device; only the journal's finish record was
            # lost in the crash — close it out instead of replaying
            req.done = True
            journal.record("finish", rid=req.rid)
            finished_now += 1
            continue
        eng.submit(req)
        requeued.append(req)

    report["requeued"] = len(requeued)
    report["finished_at_crash"] = finished_now
    report["pins_restored"] = len(pins_live)
    report["requests"] = requeued
    # structured reconcile report through the tracer — pages rebuilt,
    # refcount deltas, journal replay length — then one flight dump
    # that records the recovery outcome next to the pre-crash window
    eng.tracer.instant(
        "reconcile", kind="host_crash",
        reclaimed=int(report.get("reclaimed", 0)),
        resurrected=int(report.get("resurrected", 0)),
        never_dry=bool(report.get("never_dry", True)),
        conserved=bool(report.get("conserved", True)),
        requeued=len(requeued), finished_at_crash=finished_now,
        pins_restored=len(pins_live),
        journal_events=len(journal.events))
    eng.tracer.end("recover")
    if eng.flight.dump("recover_engine", {
            "report": {k: v for k, v in report.items()
                       if k != "requests"},
            "journal_events": len(journal.events)}):
        eng.telemetry.inc("flight_dumps")
    return eng, report
