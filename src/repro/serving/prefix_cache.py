"""Prefix sharing: host-side radix trie + device-side share/COW step.

Identical prompt prefixes from concurrent requests map onto the same
physical KV pages (the dominant memory win under production request
rates — a hot system prompt is stored once, not once per slot).

Split of responsibilities (DESIGN.md §7):

* :class:`PrefixCache` — a **host-side** radix trie over the prompts of
  *live* slots, at page granularity (one trie level per ``page_size``
  tokens).  It matches an incoming prompt against live prefixes and
  answers with a donor slot and a token count — never a page id: page
  ids stay device-resident (the host performs one sync per serving
  step and never reads tables back).
* :func:`share_prefix_step` — a **jitted device step**, called once per
  admission-with-match (off the per-token hot path).  It copies the
  donor's full-page table entries into the new slot's table and
  registers the extra references (``hier_pool.addref`` on the pool's
  int16 refcounts), and performs the copy-on-write for the one partial
  page the new slot will append into: a fresh page from the slot's
  private lane, the donor's page content copied across all paged
  layers.  The per-token step then needs no sharing logic at all —
  appends only ever write at positions >= seq_lens (never into a
  shared page), and release decrements refcounts instead of freeing
  (:func:`hier_pool.free_n`).

Matches are shard-local by construction (page ids are private to a DP
shard), so the trie is kept per shard and the engine prefers placing a
request on its donor's shard.

Only models whose whole decode state is paged can share (ring /
recurrent layers would need their donor's state *at the match point*,
which no longer exists); the engine auto-disables sharing otherwise.

**Pinned prefixes (DESIGN.md §8).**  Without pinning, the trie only
tracks *live* slots, so a hot system prompt is re-prefilled from
scratch the moment its last request finishes.  Pinning keeps a
finished prefix's pages alive by giving the cache its *own* reference
on each page: :func:`pin_prefix_step` copies the first ``n_pages`` of
a live slot's table into a device-resident **pin table** row and
``addref``\\ s them, so when the slot later releases inside the jitted
step the pages drop to refcount 1 (cache-owned) instead of 0 — the
conservation invariants from the refcount protocol carry over
unchanged (a pinned page is simply a page with one more owner).
Pinned rows are donors like any live slot: the trie stores them under
negative pseudo-slot ids and :func:`share_pinned_step` maps them into
a new slot exactly as :func:`share_prefix_step` maps a live donor —
including the COW copy of a mid-page tail, whose source content is
still resident because a refcount ≥ 1 page is never restacked.
:func:`unpin_step` releases the cache's references (eviction);
:class:`PinnedPrefixes` is the host ledger (LRU order, per-shard pages
budget, row assignment) the scheduler drives the policy through.
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from ..core import classed_pool
from ..core.block_pool import NULL
from ..core.classed_pool import CLS_KV


# ------------------------------------------------------------- host trie

#: Pinned entries live in the same trie as live slots, keyed by
#: negative pseudo-slot ids: pin_id 0, 1, 2, ... <-> -2, -3, -4, ...
#: (-1 is reserved — it reads as NULL in device land).
PIN_BASE = -2


def pin_pseudo_slot(pin_id: int) -> int:
    return PIN_BASE - pin_id


def pin_id_of(pseudo_slot: int) -> int:
    return PIN_BASE - pseudo_slot


@dataclasses.dataclass
class Match:
    slot: int        # donor slot (engine-global index; < 0 = pinned row)
    shard: int       # DP shard both slots must live on
    n_tokens: int    # shareable prefix length (tokens, host-verified)

    @property
    def pinned(self) -> bool:
        return self.slot < 0


class _Node:
    __slots__ = ("children", "slots")

    def __init__(self):
        self.children: Dict[tuple, _Node] = {}
        self.slots: set = set()


class PrefixCache:
    """Radix trie of live prompt prefixes, one level per page.

    ``completed[slot]`` tracks how many prompt tokens of a slot are
    actually in device KV (fed through completed steps); matches never
    exceed it, so a donor mid-prefill only donates what it has written.
    Entries leave the trie when their request finishes — pages a sharer
    still maps stay alive through their refcount, and the sharer itself
    remains a donor for the common prefix.
    """

    def __init__(self, page_size: int):
        self.psz = int(page_size)
        self.roots: Dict[int, _Node] = {}
        self.tokens: Dict[int, List[int]] = {}
        self.shard_of: Dict[int, int] = {}
        self.completed: Dict[int, int] = {}
        #: set by the engine: the §13 Telemetry facade (trie hit/miss
        #: counters); None keeps the cache usable standalone
        self.telemetry = None

    # -- bookkeeping ----------------------------------------------------
    def _pages(self, tokens: Sequence[int]):
        for i in range(len(tokens) // self.psz):
            yield tuple(tokens[i * self.psz:(i + 1) * self.psz])

    def insert(self, slot: int, shard: int, tokens: Sequence[int]) -> None:
        self.tokens[slot] = list(tokens)
        self.shard_of[slot] = shard
        self.completed[slot] = 0
        node = self.roots.setdefault(shard, _Node())
        for key in self._pages(tokens):
            node = node.children.setdefault(key, _Node())
            node.slots.add(slot)

    def update_progress(self, slot: int, n_in_kv: int) -> None:
        if slot in self.completed:
            n = min(int(n_in_kv), len(self.tokens[slot]))
            self.completed[slot] = max(self.completed[slot], n)

    def remove(self, slot: int) -> None:
        tokens = self.tokens.pop(slot, None)
        if tokens is None:
            return
        shard = self.shard_of.pop(slot)
        self.completed.pop(slot, None)
        node = self.roots.get(shard)
        path = []
        for key in self._pages(tokens):
            child = node.children.get(key)
            if child is None:
                break
            child.slots.discard(slot)
            path.append((node, key, child))
            node = child
        for parent, key, child in reversed(path):   # prune empty branches
            if not child.slots and not child.children:
                del parent.children[key]

    def live_slots(self) -> int:
        return len(self.tokens)

    # -- pinned pseudo-slots --------------------------------------------
    def pin_insert(self, pin_id: int, shard: int,
                   tokens: Sequence[int]) -> None:
        """Register a pinned prefix as a donor.  ``tokens`` must be the
        exact whole-page prefix held by the pin row (the engine passes
        ``prompt[:n_pages * psz]``); it is fully resident by
        construction, so completion equals its length."""
        pseudo = pin_pseudo_slot(pin_id)
        self.insert(pseudo, shard, tokens)
        self.completed[pseudo] = len(tokens)

    def pin_remove(self, pin_id: int) -> None:
        self.remove(pin_pseudo_slot(pin_id))

    # -- matching -------------------------------------------------------
    def match(self, tokens: Sequence[int],
              shard: Optional[int] = None) -> Optional[Match]:
        """Longest shareable prefix of ``tokens`` among live prompts.

        Walks the trie page-by-page, then extends into the donor's
        partial page token-by-token.  The result is capped at the
        donor's completed (in-KV) length and at ``len(tokens) - 1`` —
        the final prompt token is always fed normally so the new slot
        has a live position to sample its first output from.  Returns
        None below one full page (a COW copy wouldn't pay for itself).

        ``shard`` restricts the search to that shard's sub-trie — the
        cross-host placement contract (DESIGN.md §9): page ids are
        private to a DP shard, so a donor on shard i is unusable by a
        request placed on shard j != i EVEN WHEN THE TOKEN KEY MATCHES
        EXACTLY.  The scheduler queries each admissible shard
        separately and places the request where its longest shard-local
        match lives; an unrestricted match (shard=None) is only a
        diagnostic (best across shards), never a sharing decision.
        """
        limit = len(tokens) - 1
        if limit < self.psz:
            return None
        best: Optional[Match] = None
        if shard is None:
            roots = self.roots.items()
        else:
            root = self.roots.get(shard)
            roots = [] if root is None else [(shard, root)]
        for shard, root in roots:
            depth_of: Dict[int, int] = {}       # slot -> deepest page match
            node = root
            for i, key in enumerate(self._pages(tokens)):
                node = node.children.get(key)
                if node is None:
                    break
                for s in node.slots:
                    depth_of[s] = i + 1
            for s, d in depth_of.items():
                ent = self.tokens[s]
                n = d * self.psz
                while n < len(tokens) and n < len(ent) and tokens[n] == ent[n]:
                    n += 1
                n = min(n, self.completed[s], limit)
                if (best is None or n > best.n_tokens
                        or (n == best.n_tokens and best.slot < 0 <= s)):
                    # ties prefer a live donor over a pinned row (same
                    # pages either way; live donors keep LRU honest)
                    best = Match(slot=s, shard=shard, n_tokens=n)
        if best is None or best.n_tokens < self.psz:
            if self.telemetry is not None:
                self.telemetry.inc("trie_misses")
            return None
        if self.telemetry is not None:
            self.telemetry.inc("trie_hits")
        return best


# ---------------------------------------------- speculative continuations

class SpeculationStore:
    """Continuation history of hot prefixes — the host-side draft model
    for speculative decode (DESIGN.md §10).

    A *key* is a whole-page prompt prefix (the same page-granular token
    keys the trie and the pin ledger use, so a pinned prefix and its
    recorded continuations age together).  Each completed request whose
    prompt starts with key pages records its **continuation** — prompt
    tail beyond the key plus every generated token — and a later slot
    sitting at context ``key + suffix`` drafts the next ``k`` tokens
    from the first recorded stream consistent with its suffix.  A
    prefix is "hot" exactly when some stream is recorded under it:
    drafting needs history, and history only exists for repeated
    traffic.

    Pure host bookkeeping: drafting never reads device state (the step
    keeps its single sync), and a wrong draft costs only the rejected
    lane's rolled-back pages.  Bounded: ``keep`` streams per key
    (newest win), ``max_keys`` keys (LRU).
    """

    def __init__(self, page_size: int, keep: int = 4, max_keys: int = 64,
                 ngram: int = 3, ewma_alpha: float = 0.3):
        self.psz = int(page_size)
        self.keep = int(keep)
        self.max_keys = int(max_keys)
        self.ngram = int(ngram)
        self.ewma_alpha = float(ewma_alpha)
        self.streams: Dict[tuple, List[tuple]] = {}
        self._accept: Dict[tuple, float] = {}   # per-key accept-rate EWMA
        self._lru: Dict[tuple, int] = {}
        self._clock = itertools.count()

    def key_of(self, prompt: Sequence[int]) -> Optional[tuple]:
        """The whole-page prefix key of a prompt (None below one page)."""
        n = (len(prompt) // self.psz) * self.psz
        return tuple(prompt[:n]) if n >= self.psz else None

    def record(self, key: tuple, continuation: Sequence[int]) -> None:
        rows = self.streams.setdefault(key, [])
        cont = tuple(continuation)
        if cont in rows:
            rows.remove(cont)
        rows.append(cont)                       # newest last (wins lookup)
        del rows[:-self.keep]
        self._lru[key] = next(self._clock)
        while len(self.streams) > self.max_keys:
            cold = min(self._lru, key=self._lru.get)
            del self.streams[cold], self._lru[cold]
            self._accept.pop(cold, None)

    # -- accept-rate EWMA (the engine's break-even gate reads this) -----
    def observe(self, key: tuple, drafted: int, accepted: int) -> None:
        """Fold one verified lane's accept fraction into the key's EWMA.

        Called by the engine on the step's status read; the gate in
        ``ServingEngine._gate_k`` compares this against the measured
        cost ratio before drafting the key again (DESIGN.md §12)."""
        if drafted <= 0:
            return
        r = min(max(accepted / drafted, 0.0), 1.0)
        prev = self._accept.get(key)
        self._accept[key] = r if prev is None else (
            (1.0 - self.ewma_alpha) * prev + self.ewma_alpha * r)

    def accept_rate(self, key: tuple) -> Optional[float]:
        """EWMA accept rate for a key, or None before any observation
        (the gate drafts unmeasured prefixes optimistically — the first
        verified lane seeds the EWMA)."""
        return self._accept.get(key)

    def draft(self, key: tuple, suffix: Sequence[int],
              k: int) -> List[int]:
        """Up to ``k`` draft tokens for a slot at context key+suffix.

        Exact-suffix replay first: the newest stream whose recorded
        continuation starts with the slot's whole suffix wins (recent
        traffic predicts recent traffic).  When no stream matches
        exactly, an n-gram fallback matches the suffix's last g tokens
        (g = ngram down to 1) ANYWHERE in a recorded stream and drafts
        what followed there — drafting extends beyond exact replay
        while the verify/rollback plane stays unchanged (a wrong draft
        still costs only the rejected lane's rolled-back pages).  An
        absent history drafts nothing — the slot simply decodes a
        width-1 lane that step.
        """
        if k <= 0:
            return []
        rows = self.streams.get(key)
        if not rows:
            return []
        suffix = tuple(suffix)
        n = len(suffix)
        for cont in reversed(rows):
            if cont[:n] == suffix and len(cont) > n:
                self._lru[key] = next(self._clock)
                return list(cont[n:n + k])
        # n-gram fallback: longest recent-gram match, newest stream
        # first, rightmost occurrence within a stream (most context)
        for g in range(min(self.ngram, n), 0, -1):
            tail = suffix[-g:]
            for cont in reversed(rows):
                for i in range(len(cont) - g, -1, -1):
                    if cont[i:i + g] == tail and i + g < len(cont):
                        self._lru[key] = next(self._clock)
                        return list(cont[i + g:i + g + k])
        return []

    # -- warm restart (serving/engine.py save_warm/restore_warm) --------
    def to_state(self) -> list:
        """JSON-able snapshot, LRU-coldest key first so ``load_state``'s
        re-recording reproduces the eviction order.  Carries the
        accept-rate EWMA so the break-even gate stays warm across
        restarts."""
        keys = sorted(self.streams, key=lambda k: self._lru[k])
        return [[[int(t) for t in k],
                 [[int(t) for t in c] for c in self.streams[k]],
                 self._accept.get(k)]
                for k in keys]

    def load_state(self, rows: list) -> None:
        self.streams.clear()
        self._lru.clear()
        self._accept.clear()
        for row in rows:
            key, conts = row[0], row[1]
            kt = tuple(int(t) for t in key)
            for c in conts:
                self.record(kt, tuple(int(t) for t in c))
            if len(row) > 2 and row[2] is not None:
                self._accept[kt] = float(row[2])


# --------------------------------------------------- pinned host ledger

class PinnedPrefixes:
    """Host-side ledger of cache-owned (pinned) prefixes.

    Pure bookkeeping — the pages themselves live behind the device pin
    table and the pool refcounts; this class answers the policy
    questions (which row is free, who is LRU, how many pages does the
    cache hold on shard d) the scheduler asks when it pins, evicts
    under the per-shard ``budget_pages``, or sheds pins on pool
    pressure.  pin_id = shard * rows_per_shard + row, globally unique
    and stable for a pin's lifetime.
    """

    def __init__(self, n_shards: int, rows_per_shard: int,
                 budget_pages: int):
        self.n_shards = n_shards
        self.npin = int(rows_per_shard)
        self.budget = int(budget_pages)
        self.entries: Dict[int, dict] = {}          # pin_id -> entry
        self.free_rows = {s: set(range(self.npin)) for s in range(n_shards)}
        self.by_key: Dict[Tuple[int, tuple], int] = {}
        self._clock = itertools.count()

    # -- queries --------------------------------------------------------
    def pages_on(self, shard: int) -> int:
        return sum(e["pages"] for e in self.entries.values()
                   if e["shard"] == shard)

    def total_pages(self) -> int:
        return sum(e["pages"] for e in self.entries.values())

    def lookup(self, shard: int, tokens: Sequence[int]) -> Optional[int]:
        return self.by_key.get((shard, tuple(tokens)))

    def lru(self, shard: int) -> Optional[int]:
        cands = [(e["used"], pid) for pid, e in self.entries.items()
                 if e["shard"] == shard]
        return min(cands)[1] if cands else None

    def fits(self, shard: int, pages: int) -> bool:
        return self.pages_on(shard) + pages <= self.budget

    # -- mutation -------------------------------------------------------
    def add(self, shard: int, tokens: Sequence[int], pages: int) -> int:
        row = min(self.free_rows[shard])            # caller checked free
        self.free_rows[shard].discard(row)
        pin_id = shard * self.npin + row
        self.entries[pin_id] = {"shard": shard, "row": row,
                                "tokens": tuple(tokens), "pages": pages,
                                "used": next(self._clock)}
        self.by_key[(shard, tuple(tokens))] = pin_id
        return pin_id

    def has_free_row(self, shard: int) -> bool:
        return bool(self.free_rows[shard])

    def remove(self, pin_id: int) -> Tuple[int, int]:
        e = self.entries.pop(pin_id)
        self.free_rows[e["shard"]].add(e["row"])
        self.by_key.pop((e["shard"], e["tokens"]), None)
        return e["shard"], e["row"]

    def touch(self, pin_id: int) -> None:
        if pin_id in self.entries:
            self.entries[pin_id]["used"] = next(self._clock)

    # -- warm restart / crash recovery ----------------------------------
    def to_state(self) -> list:
        """JSON-able ledger snapshot, LRU-coldest entry first (the
        journal's pin events and a warm save share this shape)."""
        ents = sorted(self.entries.items(), key=lambda kv: kv[1]["used"])
        return [{"pin_id": int(pid), "shard": int(e["shard"]),
                 "row": int(e["row"]),
                 "tokens": [int(t) for t in e["tokens"]],
                 "pages": int(e["pages"])} for pid, e in ents]

    def load_state(self, entries: list) -> None:
        """Rebuild the ledger at its exact rows — the device pin table
        being restored alongside references those rows, so a pin must
        come back where its pages already are."""
        self.entries.clear()
        self.by_key.clear()
        self.free_rows = {s: set(range(self.npin))
                          for s in range(self.n_shards)}
        for e in entries:                       # LRU-coldest first
            shard, row = int(e["shard"]), int(e["row"])
            toks = tuple(int(t) for t in e["tokens"])
            pid = shard * self.npin + row
            self.free_rows[shard].discard(row)
            self.entries[pid] = {"shard": shard, "row": row,
                                 "tokens": toks, "pages": int(e["pages"]),
                                 "used": next(self._clock)}
            self.by_key[(shard, toks)] = pid


# --------------------------------------------------------- device steps

def share_prefix_step(psz: int, state, dst_oh, src_oh, n_tokens,
                      axis_name=None):
    """Map ``n_tokens`` of the src slot's prefix into the dst slot.

    dst_oh / src_oh: bool[DP, Bl] one-hots on the SAME shard;
    n_tokens: int32 scalar (>= 1, host-capped at the donor's completed
    length and the page-table capacity).  Jitted once; called per
    admission-with-match, off the per-token path.

    ``axis_name`` names the mesh axis when the call runs under
    shard_map (DESIGN.md §9): all state mutation is dst-shard-local
    either way (the one-hots are False everywhere else), but the
    returned ``ok`` flag must be the dst shard's verdict on every host
    — one tiny psum replicates it (the call's only collective).

    Protocol (all-or-nothing, ``ok`` reports the outcome):
      1. full pages [0, n_tokens // psz) of the donor's table are
         copied into the dst row and each gains a reference;
      2. if the prefix ends mid-page, a fresh page is allocated from
         the SHARED pool (admission-time bulk, like prefill loading —
         never from the slot's private lane, whose >= ell stock is the
         §4.2 never-dry budget for the next chunk) and the donor's
         partial page is copied into it across every paged layer
         (copy-on-write at the first divergent append — the dst slot
         appends into its private copy, never into the shared page);
      3. seq_lens[dst] = n_tokens, so the engine feeds only the
         remaining prompt suffix.
    """
    src_row = jnp.sum(jnp.where(src_oh[..., None], state.page_tables, 0),
                      axis=(0, 1))                                 # [maxp]
    return _share_from_row(psz, state, dst_oh, src_row, n_tokens,
                           axis_name)


def share_pinned_step(psz: int, state, pin_tables, dst_oh, pin_oh,
                      n_tokens, axis_name=None):
    """:func:`share_prefix_step` with a pinned row as the donor.

    pin_oh: bool[DP, Npin] one-hot on the dst shard.  The pin row's
    pages are live (cache-owned refcount >= 1), their KV content is
    still resident, and the row is NULL beyond its pinned pages — so
    the shared-row protocol applies verbatim, including the COW copy
    when the match ends mid-page.
    """
    src_row = jnp.sum(jnp.where(pin_oh[..., None], pin_tables, 0),
                      axis=(0, 1))                                 # [maxp]
    return _share_from_row(psz, state, dst_oh, src_row, n_tokens,
                           axis_name)


def _share_from_row(psz: int, state, dst_oh, src_row, n_tokens,
                    axis_name=None):
    """Shared body: map a donor table row into the dst slot (see
    :func:`share_prefix_step` for the protocol)."""
    DP, Bl, maxp = state.page_tables.shape
    n_tokens = jnp.asarray(n_tokens, jnp.int32)
    fp = n_tokens // psz                          # full pages shared
    partial = n_tokens % psz                      # tokens in the COW page
    k = jnp.arange(maxp, dtype=jnp.int32)
    np_needed = (n_tokens + psz - 1) // psz
    donor_ok = src_row[jnp.clip(np_needed - 1, 0, maxp - 1)] >= 0
    shard_mask = jnp.any(dst_oh, axis=1)                           # [DP]

    # COW page for the partial tail, from the SHARED pool (off the hot
    # path; taking it from the slot's lane would eat into the lane's
    # never-dry stock and silently deny the slot's next chunk)
    want = dst_oh & (partial > 0) & donor_ok
    pool, fresh = classed_pool.alloc_from_shared_dp(
        state.pool, CLS_KV, want.astype(jnp.int32), 1)
    fresh = fresh[..., 0]                                          # [DP, Bl]
    ok = donor_ok & ((partial == 0) | jnp.any(fresh >= 0))

    # register the extra references on the donor's full pages
    shared_ids = jnp.where((k < fp) & ok, src_row, NULL)
    ids_dp = jnp.where(shard_mask[:, None], shared_ids[None, :], NULL)
    pool = classed_pool.addref_dp(pool, CLS_KV, ids_dp)

    # dst table row: donor's full pages, then the fresh partial copy
    row = jnp.where(k[None, None, :] < fp, src_row[None, None, :],
                    state.page_tables)
    row = jnp.where((k[None, None, :] == fp) & (partial > 0) &
                    (fresh[..., None] >= 0), fresh[..., None], row)
    page_tables = jnp.where(dst_oh[..., None] & ok, row, state.page_tables)

    # copy the donor's partial page into the fresh page (every layer)
    src_pid = jnp.maximum(src_row[jnp.clip(fp, 0, maxp - 1)], 0)
    fresh_shard = jnp.max(jnp.where(want, fresh, NULL), axis=1)    # [DP]
    any_pages = next(iter(state.kv_pages.values()))[0]
    P = any_pages.shape[2]
    tgt = jnp.where(shard_mask & ok & (partial > 0) & (fresh_shard >= 0),
                    fresh_shard, P)                                # P => drop

    def copy_pages(pages):                        # [S, DP, P, psz, KH, hd]
        def per_shard(pg, t):
            return pg.at[:, t].set(pg[:, src_pid], mode="drop")
        return jax.vmap(per_shard, in_axes=(1, 0), out_axes=1)(pages, tgt)

    kv_pages = {pos: (copy_pages(kp), copy_pages(vp))
                for pos, (kp, vp) in state.kv_pages.items()}
    seq_lens = jnp.where(dst_oh & ok, n_tokens, state.seq_lens)
    state = state._replace(kv_pages=kv_pages, page_tables=page_tables,
                           seq_lens=seq_lens, pool=pool)
    if axis_name is not None:
        # under shard_map each shard computed its own (meaningless off
        # the dst shard) ok; replicate the dst shard's verdict so the
        # host reads one truth — the call's only cross-shard traffic
        ok = jax.lax.psum(
            jnp.where(jnp.any(dst_oh), ok, False).astype(jnp.int32),
            axis_name) > 0
    return state, ok


def pin_prefix_step(pool, pin_tables, page_tables, pin_oh, src_oh,
                    n_pages):
    """Pin the first ``n_pages`` of a live slot's table into a pin row.

    pin_oh: bool[DP, Npin] one-hot naming the (free) destination row;
    src_oh: bool[DP, Bl] one-hot naming the live source slot, SAME
    shard; n_pages: int32 scalar >= 1 (whole pages only — a partial
    page is still being appended into and cannot be cache-owned).

    The cache takes ONE reference per pinned page
    (:func:`hier_pool.addref`): when the source slot later releases
    inside the jitted step, the pages drop to refcount 1 instead of 0
    and stay off the free stacks — alive, content intact, donatable.
    Jitted once; called per pin (prefill completion or preemption),
    off the per-token path.
    """
    DP, Npin, maxp = pin_tables.shape
    k = jnp.arange(maxp, dtype=jnp.int32)
    src_row = jnp.sum(jnp.where(src_oh[..., None], page_tables, 0),
                      axis=(0, 1))                                 # [maxp]
    row = jnp.where(k < jnp.asarray(n_pages, jnp.int32), src_row, NULL)
    shard_mask = jnp.any(pin_oh, axis=1)                           # [DP]
    ids_dp = jnp.where(shard_mask[:, None], row[None, :], NULL)
    pool = classed_pool.addref_dp(pool, CLS_KV, ids_dp)
    pin_tables = jnp.where(pin_oh[..., None], row[None, None, :],
                           pin_tables)
    return pool, pin_tables


def unpin_step(pool, pin_tables, pin_oh):
    """Evict pinned rows: drop the cache's references, clear the rows.

    pin_oh: bool[DP, Npin] (any number of rows, any shards).  Pages
    whose refcount reaches zero return to the shard's SHARED stack
    (:func:`hier_pool.free_shared` — pin rows belong to no lane; the
    per-step rebalance redistributes).  Pages a live sharer still maps
    just lose the cache's reference.
    """
    DP = pin_tables.shape[0]
    ids = jnp.where(pin_oh[..., None], pin_tables, NULL)
    pool = classed_pool.free_shared_dp(pool, CLS_KV, ids.reshape(DP, -1))
    pin_tables = jnp.where(pin_oh[..., None], NULL, pin_tables)
    return pool, pin_tables
