"""Continuous-batching serving engine on the paper's allocator.

Two allocator integrations (DESIGN.md §2b):

* **host (faithful)**: admission runs through the wait-free
  :class:`~repro.core.allocator.WaitFreeAllocator` — sequence *slots*
  are the fixed-size blocks, scheduler lanes are the processes.  Each
  admission/release is O(1) regardless of fleet size, so request
  scheduling never stalls behind a global lock (the paper's claim,
  live in the control plane).
* **device (SPMD)**: KV pages come from the two-level
  :mod:`~repro.core.hier_pool` threaded through ``DecodeState`` — one
  private lane of capacity ``3*ell`` per serving slot, a shared pool
  per DP shard behind them, and one deamortized ``rebalance`` fused
  into the jitted step (off the per-token path), so per-step alloc and
  free touch only lane-local state: exactly the paper's structure at
  batch granularity.

Prefix sharing (DESIGN.md §7): a host-side radix trie over live
prompts (:mod:`.prefix_cache`) maps identical prompt prefixes from
concurrent requests onto the same physical pages.  Shared pages carry
an int16 refcount in the pool; a copy-on-write step at admission gives
each slot a private copy of the one partial page it will append into,
and release inside the jitted step decrements instead of frees.

The token hot path is fully device-resident (DESIGN.md §6): one jitted
``_serve_step`` embeds the forward pass, chunked prefill, greedy
sampling, EOS/length done-detection, and page release for finished
slots, and returns a small packed status array — the host performs
EXACTLY ONE device→host sync per step (``np.asarray(status)``).  Prompts
are processed ``chunk_size`` tokens per step; steady-state decode runs
the same step at T=1 with the previous token read from a device-resident
register, never from the host.

The pre-refactor single-token path is kept behind ``legacy=True`` for
A/B benchmarking (benchmarks/run.py measures both in the same run).
"""

from __future__ import annotations

import dataclasses
import functools
import itertools
import time
from collections import deque
from typing import Deque, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .. import models
from ..core import NULL, SimContext, WaitFreeAllocator, hier_pool
from ..models.decode_init import empty_decode_state, empty_serve_arrays
from ..models.layers import logits_apply
from ..models.transformer import DecodeState, forward_decode_chunk
from .prefix_cache import PrefixCache, share_prefix_step


@dataclasses.dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new_tokens: int = 16
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    slot: Optional[int] = None
    done: bool = False
    submitted_at: float = 0.0
    finished_at: float = 0.0


def _release_slots(state: DecodeState, mask):
    """Jit-able: release all pages of masked slots, zero their state.

    mask: bool[DP, Bl].  One :func:`hier_pool.free_n` per shard — each
    page loses one reference; pages still mapped by a prefix-sharing
    sibling stay live (release decrements instead of frees), the rest
    return to the slot's lane / the shared pool.
    """
    dp, bl, maxp = state.page_tables.shape
    to_free = jnp.where(mask[:, :, None], state.page_tables, NULL)
    pool = hier_pool.free_n_dp(state.pool, to_free)
    page_tables = jnp.where(mask[:, :, None], NULL, state.page_tables)
    seq_lens = jnp.where(mask, 0, state.seq_lens)

    def zero_masked(tree):
        def f(a):
            if a.ndim >= 3 and a.shape[1] == dp and a.shape[2] == bl:
                m = mask.reshape((1, dp, bl) + (1,) * (a.ndim - 3))
                return jnp.where(m, jnp.zeros_like(a), a)
            return a
        return jax.tree.map(f, tree)

    rings = zero_masked(state.rings)
    rec = zero_masked(state.rec)
    return state._replace(page_tables=page_tables, seq_lens=seq_lens,
                          pool=pool, rings=rings, rec=rec)


# Packed per-step status rows (the step's single device->host transfer).
STATUS_TOKEN = 0     # sampled token id (-1 where nothing was emitted)
STATUS_EMITTED = 1   # 1 iff the slot produced an output token this step
STATUS_DONE = 2      # 1 iff the slot finished (pages already released)
STATUS_PAGES = 3     # pages-in-use on the slot's DP shard (broadcast row)


def _serve_step(cfg, max_len, eos_id, params, state, last_tok, out_count,
                budget, prompt_toks, feed_lens, is_prompt, emit):
    """One fully device-resident engine step (jitted once per chunk T).

    prompt_toks: int32[DP, Bl, T] host-provided prompt chunks (ignored
    for generating slots — their input token is the device-resident
    ``last_tok`` register); feed_lens: tokens fed per slot this step
    (0 = idle); is_prompt: slot consumes prompt tokens; emit: slot
    produces an output token this step (host knows this statically —
    it's "prompt exhausted by this chunk" or "generating").

    Folds greedy sampling, EOS/length done-detection, page release for
    finished slots, and the once-per-step :func:`hier_pool.rebalance`
    (the paper's deamortized shared-pool traffic, off the per-token
    path) into the step so the host syncs exactly once, on the returned
    packed status int32[4, DP, Bl] (see STATUS_* row indices; the PAGES
    row carries per-shard pages-in-use so occupancy tracking costs no
    extra transfer).
    """
    DP, Bl, T = prompt_toks.shape
    gen_col = jnp.zeros((DP, Bl, T), jnp.int32).at[:, :, 0].set(last_tok)
    toks = jnp.where(is_prompt[..., None], prompt_toks, gen_col)
    active = feed_lens > 0

    hidden, state = forward_decode_chunk(cfg, params, toks, state,
                                         feed_lens, active=active)
    idx = jnp.maximum(feed_lens - 1, 0)
    h_last = jnp.take_along_axis(hidden, idx[..., None, None],
                                 axis=2)[:, :, 0]         # [DP, Bl, d]
    logits = logits_apply(cfg, params["embed"], h_last)
    nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)

    emit = emit & active
    out_count = out_count + emit.astype(jnp.int32)
    seq_full = state.seq_lens >= max_len - 1
    done = active & ((out_count >= budget) | seq_full |
                     (emit & (nxt == eos_id)))
    last_tok = jnp.where(emit, nxt, last_tok)
    state = _release_slots(state, done)
    # deamortized shared<->lane traffic: once per step, off the
    # per-token path (the paper's run_delayed_step)
    state = state._replace(pool=hier_pool.rebalance_dp(state.pool))

    pages_local = state.pool.shared.free_ids.shape[1]
    free_now = state.pool.shared.top + jnp.sum(state.pool.private_top, axis=1)
    pages_used = (pages_local - free_now).astype(jnp.int32)      # [DP]
    status = jnp.stack([jnp.where(emit, nxt, -1),
                        emit.astype(jnp.int32),
                        done.astype(jnp.int32),
                        jnp.broadcast_to(pages_used[:, None], (DP, Bl))])
    return state, last_tok, out_count, status


class ServingEngine:
    def __init__(self, cfg, params, dp: int = 1, b_local: int = 4,
                 max_len: int = 512, scheduler_lanes: int = 2,
                 greedy: bool = True, chunk_size: int = 8,
                 eos_id: Optional[int] = None, legacy: bool = False,
                 prefix_sharing: bool = True):
        self.cfg = cfg
        self.params = params
        self.dp, self.bl = dp, b_local
        self.max_len = max_len
        self.chunk = max(int(chunk_size), 1)
        self.legacy = legacy
        self.state = empty_decode_state(cfg, dp, b_local, max_len,
                                        chunk=self.chunk)
        self.last_tok, self.out_count, self.budget = \
            empty_serve_arrays(dp, b_local)
        self.greedy = greedy
        # sequences can never outgrow the page table (maxp * psz tokens,
        # < max_len when max_len is not a page multiple); done-detection
        # and feed capping use the effective capacity so a chunk is never
        # submitted that forward_decode_chunk would have to reject
        maxp = self.state.page_tables.shape[2]
        self.capacity = (min(max_len, maxp * cfg.page_size)
                         if self.state.kv_pages else max_len)
        self._fed: Dict[int, int] = {}       # host shadow of seq_lens

        # fused device-resident step (compiled once for T=chunk and,
        # lazily, once for the T=1 steady-state decode shape)
        self._serve = jax.jit(
            functools.partial(_serve_step, cfg, self.capacity,
                              -1 if eos_id is None else int(eos_id)),
            donate_argnums=(1, 2, 3))
        # pre-refactor single-token path (A/B benchmarking); the
        # once-per-step lane rebalance rides inside its jit as well
        def _legacy_step(p, t, s, a):
            logits, s = models.decode_step(cfg, p, t, s, active=a)
            return logits, s._replace(pool=hier_pool.rebalance_dp(s.pool))

        self._decode = jax.jit(_legacy_step, donate_argnums=(2,))
        self._release = jax.jit(_release_slots, donate_argnums=(0,))

        # prefix sharing: only sound when the whole decode state is
        # paged (ring / recurrent layers would need donor state at the
        # match point); page ids are shard-local, so matches are too
        self.prefix_cache: Optional[PrefixCache] = None
        if (prefix_sharing and not legacy and self.state.kv_pages
                and not self.state.rings and not self.state.rec
                and self.state.enc_kv is None):
            self.prefix_cache = PrefixCache(cfg.page_size)
            self._share = jax.jit(
                functools.partial(share_prefix_step, cfg.page_size),
                donate_argnums=(0,))

        # host-side wait-free slot allocator: slots are fixed-size blocks.
        n_slots = dp * b_local
        self.lane_ctx = SimContext(scheduler_lanes, seed=0)
        self.slot_alloc = WaitFreeAllocator(
            self.lane_ctx, ell=max(3 * scheduler_lanes, 4),
            shared_batches=max(2, n_slots), allow_os_growth=True)
        # bind allocator block ids <-> engine slots (first n_slots blocks)
        self._slot_of_block: Dict[int, int] = {}
        self._block_of_slot: Dict[int, int] = {}
        self._free_slots = deque(range(n_slots))
        self.lanes = itertools.cycle(range(scheduler_lanes))

        self.queue: Deque[Request] = deque()
        self.active: Dict[int, Request] = {}     # slot -> request
        self.pending_tokens: Dict[int, List[int]] = {}
        self.stats = {"steps": 0, "tokens_out": 0, "admitted": 0,
                      "prompt_tokens": 0, "alloc_steps_max": 0,
                      "prefix_shared_tokens": 0, "prefix_shared_reqs": 0,
                      "pages_peak": 0, "pages_sum": 0}

    # ------------------------------------------------------------ control
    def _host_alloc_slot(self, preferred_shard: Optional[int] = None
                         ) -> Optional[int]:
        """O(1) wait-free admission through the paper's allocator.

        ``preferred_shard`` steers placement next to a prefix-sharing
        donor (page ids are shard-local, so only same-shard slots can
        map the donor's pages)."""
        if not self._free_slots:
            return None
        if preferred_shard is not None:
            for s in self._free_slots:
                if s // self.bl == preferred_shard:
                    self._free_slots.remove(s)
                    self._free_slots.appendleft(s)
                    break
        lane = next(self.lanes)
        gen = self.slot_alloc.allocate(lane)
        try:
            while True:
                next(gen)
        except StopIteration as e:
            block = e.value
        op = self.lane_ctx.history[-1]
        self.stats["alloc_steps_max"] = max(
            self.stats["alloc_steps_max"], op.steps)
        slot = self._free_slots.popleft()
        self._slot_of_block[block] = slot
        self._block_of_slot[slot] = block
        return slot

    def _host_free_slot(self, slot: int) -> None:
        lane = next(self.lanes)
        block = self._block_of_slot.pop(slot)
        self._slot_of_block.pop(block)
        gen = self.slot_alloc.free(lane, block)
        try:
            while True:
                next(gen)
        except StopIteration:
            pass
        self._free_slots.append(slot)

    def submit(self, req: Request) -> None:
        req.submitted_at = time.time()
        self.queue.append(req)

    def _admit(self) -> None:
        while self.queue and self._free_slots:
            # empty prompts degrade to the legacy BOS=1 convention
            prompt = list(self.queue[0].prompt) or [1]
            match = (self.prefix_cache.match(prompt)
                     if self.prefix_cache is not None else None)
            slot = self._host_alloc_slot(match.shard if match else None)
            if slot is None:
                break
            req = self.queue.popleft()
            req.slot = slot
            self.active[slot] = req
            d, b = divmod(slot, self.bl)
            shared_n = 0
            if match is not None and d == match.shard:
                shared_n = self._try_share(slot, match, len(prompt))
            self.pending_tokens[slot] = prompt[shared_n:]
            self._fed[slot] = shared_n
            if self.prefix_cache is not None:
                self.prefix_cache.insert(slot, d, prompt)
                self.prefix_cache.update_progress(slot, shared_n)
            if not self.legacy:
                self.budget = self.budget.at[d, b].set(req.max_new_tokens)
                self.out_count = self.out_count.at[d, b].set(0)
            self.stats["admitted"] += 1

    def _try_share(self, slot: int, match, prompt_len: int) -> int:
        """Map the matched prefix onto the donor's pages (device-side,
        one jitted call, off the per-token path).  Returns the number of
        tokens now resident in the slot's KV (0 = no sharing)."""
        n = min(match.n_tokens, prompt_len - 1, self.capacity - 1)
        if n < self.cfg.page_size:
            return 0
        dst = np.zeros((self.dp, self.bl), bool)
        src = np.zeros((self.dp, self.bl), bool)
        dst[slot // self.bl, slot % self.bl] = True
        src[match.slot // self.bl, match.slot % self.bl] = True
        self.state, ok = self._share(self.state, jnp.asarray(dst),
                                     jnp.asarray(src), jnp.int32(n))
        if not bool(ok):       # lane dry for the COW page — admit unshared
            return 0
        self.stats["prefix_shared_tokens"] += n
        self.stats["prefix_shared_reqs"] += 1
        return n

    # -------------------------------------------------------------- step
    def step(self) -> None:
        if self.legacy:
            return self._step_legacy()
        self._admit()
        if not self.active:
            return

        # schedule this step's feeds (host-side bookkeeping only — no
        # device sync; prompt chunks come from host queues, generation
        # tokens from the device-resident last_tok register)
        any_prompt = any(self.pending_tokens[s] for s in self.active)
        T = self.chunk if any_prompt else 1
        prompt_toks = np.zeros((self.dp, self.bl, T), np.int32)
        feed_lens = np.zeros((self.dp, self.bl), np.int32)
        is_prompt = np.zeros((self.dp, self.bl), bool)
        emit = np.zeros((self.dp, self.bl), bool)
        for slot in self.active:
            d, b = divmod(slot, self.bl)
            pend = self.pending_tokens[slot]
            if pend:
                # never feed past the page-table capacity — a slot that
                # reaches it finishes via the on-device length check
                n = min(len(pend), T, self.capacity - self._fed[slot])
                prompt_toks[d, b, :n] = pend[:n]
                del pend[:n]
                feed_lens[d, b] = n
                is_prompt[d, b] = True
                emit[d, b] = not pend
                self.stats["prompt_tokens"] += n
            else:
                feed_lens[d, b] = 1
                emit[d, b] = True
            self._fed[slot] += int(feed_lens[d, b])

        self.state, self.last_tok, self.out_count, status = self._serve(
            self.params, self.state, self.last_tok, self.out_count,
            self.budget, jnp.asarray(prompt_toks), jnp.asarray(feed_lens),
            jnp.asarray(is_prompt), jnp.asarray(emit))
        self.stats["steps"] += 1
        status = np.asarray(status)      # the step's ONE device->host sync

        pages_now = int(status[STATUS_PAGES, :, 0].sum())
        self.stats["pages_peak"] = max(self.stats["pages_peak"], pages_now)
        self.stats["pages_sum"] += pages_now

        for slot, req in list(self.active.items()):
            d, b = divmod(slot, self.bl)
            if status[STATUS_EMITTED, d, b]:
                req.out_tokens.append(int(status[STATUS_TOKEN, d, b]))
                self.stats["tokens_out"] += 1
            if status[STATUS_DONE, d, b]:
                # pages were already released inside the jitted step
                req.done = True
                req.finished_at = time.time()
                self.active.pop(slot)
                self.pending_tokens.pop(slot, None)
                if self.prefix_cache is not None:
                    self.prefix_cache.remove(slot)
                self._host_free_slot(slot)
            elif self.prefix_cache is not None:
                # this step's feed is now in device KV: the slot can
                # donate that much of its prompt to future admissions
                self.prefix_cache.update_progress(slot, self._fed[slot])

    def _step_legacy(self) -> None:
        """Pre-refactor path: one token per step, host-side argmax."""
        self._admit()

        tokens = np.zeros((self.dp, self.bl), np.int32)
        active = np.zeros((self.dp, self.bl), bool)
        feeding = {}
        for slot, req in self.active.items():
            d, b = divmod(slot, self.bl)
            pend = self.pending_tokens[slot]
            if pend:
                tok = pend.pop(0)
                feeding[slot] = ("prompt", tok)
                self.stats["prompt_tokens"] += 1
            else:
                tok = req.out_tokens[-1] if req.out_tokens else 1
                feeding[slot] = ("gen", tok)
            tokens[d, b] = tok
            active[d, b] = True
        if not feeding:
            return
        logits, self.state = self._decode(
            self.params, jnp.asarray(tokens), self.state, jnp.asarray(active))
        self.stats["steps"] += 1
        nxt = np.asarray(jnp.argmax(logits, axis=-1))
        # one seq_lens transfer per step, not one per active slot
        seq_lens = np.asarray(self.state.seq_lens)

        finished = []
        for slot, req in list(self.active.items()):
            d, b = divmod(slot, self.bl)
            kind, _ = feeding[slot]
            if kind == "gen" or not self.pending_tokens[slot]:
                req.out_tokens.append(int(nxt[d, b]))
                self.stats["tokens_out"] += 1
            full = seq_lens[d, b] >= self.max_len - 1
            if len(req.out_tokens) >= req.max_new_tokens or full:
                finished.append(slot)
        if finished:
            mask = np.zeros((self.dp, self.bl), bool)
            for slot in finished:
                d, b = divmod(slot, self.bl)
                mask[d, b] = True
                req = self.active.pop(slot)
                req.done = True
                req.finished_at = time.time()
                self.pending_tokens.pop(slot, None)
                self._host_free_slot(slot)
            self.state = self._release(self.state, jnp.asarray(mask))

    def run(self, max_steps: int = 10_000) -> None:
        for _ in range(max_steps):
            if not self.queue and not self.active:
                break
            self.step()

    # ------------------------------------------------------------ metrics
    def pages_in_use(self) -> int:
        """Physical pages currently referenced (shared pages count once)."""
        total = self.state.pool.shared.free_ids.shape[1] * self.dp
        return total - int(hier_pool.total_free(self.state.pool))

    def page_occupancy(self) -> float:
        total = self.state.pool.shared.free_ids.shape[1] * self.dp
        return self.pages_in_use() / total

    def pages_mean(self) -> float:
        """Mean pages-in-use per step (from the packed status row)."""
        return self.stats["pages_sum"] / max(self.stats["steps"], 1)
