"""Continuous-batching serving engine on the paper's allocator.

Two allocator integrations (DESIGN.md §2b):

* **host (faithful)**: admission runs through the wait-free
  :class:`~repro.core.allocator.WaitFreeAllocator` — sequence *slots*
  are the fixed-size blocks, scheduler lanes are the processes.  Each
  admission/release is O(1) regardless of fleet size, so request
  scheduling never stalls behind a global lock (the paper's claim,
  live in the control plane).
* **device (SPMD)**: KV pages come from the two-level
  :mod:`~repro.core.hier_pool` threaded through ``DecodeState`` — one
  private lane of capacity ``3*ell`` per serving slot, a shared pool
  per DP shard behind them, and one deamortized ``rebalance`` fused
  into the jitted step (off the per-token path), so per-step alloc and
  free touch only lane-local state: exactly the paper's structure at
  batch granularity.

Prefix sharing (DESIGN.md §7): a host-side radix trie over live
prompts (:mod:`.prefix_cache`) maps identical prompt prefixes from
concurrent requests onto the same physical pages.  Shared pages carry
an int16 refcount in the pool; a copy-on-write step at admission gives
each slot a private copy of the one partial page it will append into,
and release inside the jitted step decrements instead of frees.

Traffic-aware frontend (DESIGN.md §8): admission order, page-budget
backpressure, preemption, and pinned-prefix retention are policy owned
by :class:`~repro.serving.sched.AdmissionScheduler`; this engine owns
the mechanisms it drives — :meth:`admit`, :meth:`preempt`,
:meth:`evict_pin` and the pin table (whole pages of hot prefixes kept
alive after their request finishes, via cache-owned refcounts).

The token hot path is fully device-resident and runs ONE shape of
work: the variable-width **token-lane step** (DESIGN.md §10).  Each
active slot contributes a lane of tokens per step — a prefill chunk,
exactly one decode token (a width-1 lane), or one decode token plus
``k`` host-drafted speculative tokens — through the single jitted
``_serve_step``, which embeds the forward pass, per-request
temperature/top-k sampling (greedy by default, :mod:`.sampling`),
draft verification and whole-page rollback of rejected speculation
(``hier_pool.free_n_dp`` inside the jit), EOS/length done-detection,
and page release for finished slots, and returns a small packed status
array — the host performs EXACTLY ONE device→host sync per step
(``np.asarray(status)``).  Prefill lane widths come from the admission
scheduler's static chunk-bucket set (SLO-aware sizing: prefill shrinks
when latency-class work waits — :meth:`sched.AdmissionScheduler.
pick_chunk`); steady-state decode runs the same step at T=1 with the
previous token read from a device-resident register, never from the
host.  A step with nothing to feed skips the device entirely (idle
fast-path) and ``run`` exits as soon as both the batch and the
scheduler backlog are empty.  The pre-refactor single-token engine
path is gone — width-1 lanes ARE single-token decode.

Multi-host allocation plane (DESIGN.md §9): with >= dp devices the
engine builds a ``("dp",)`` mesh (``launch.mesh.make_dp_mesh``) and
shard_maps every jitted step — serve, release, share, pin, unpin —
over it, so each device owns exactly its shard's HierPool
leaves, lanes, refcounts, pin table, and KV pages; rebalance
drain/refill run entirely shard-local and the packed status row is the
only data crossing shards (one all_gather per step).  Admission is the
cross-host policy layer: the scheduler's per-shard committed/pinned
budgets are the mesh-visible state and prefix-trie donors are matched
strictly within a shard.  Without enough devices the same code runs
single-device vmap semantics, bit-identically.

Speculative decode on shared prefixes (DESIGN.md §10): the prefix
plane's :class:`~repro.serving.prefix_cache.SpeculationStore` records
the continuation history of hot (whole-page, often pinned) prompt
prefixes; the host drafts ``k`` tokens once per hot prefix per step
and the unified step scores each draft lane, accepts the matching
prefix, emits up to ``k + 1`` tokens, and rolls the rejected tail's
whole-page over-allocation back into the slot's private lane — still
one host sync and one collective per step.
"""

from __future__ import annotations

import dataclasses
import functools
import itertools
import time
from collections import deque
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..core import NULL, SimContext, WaitFreeAllocator, classed_pool, hier_pool
from ..core.classed_pool import CLS_EXPERT, CLS_KV, CLS_STATE
from ..launch.mesh import SERVE_DP_AXIS, make_dp_mesh
from ..launch.steps import (serve_register_pspec, serve_shardings,
                            serve_state_pspecs)
from ..models.decode_init import empty_decode_state, empty_serve_arrays
from ..models.layers import logits_apply, logits_argmax_chunked
from ..models.transformer import (EXPERT_PPE, DecodeState, expert_layer_slots,
                                  forward_decode_chunk, state_blocks_per_slot,
                                  state_page_tokens)
from ..runtime.fault import StepWatchdog
from .chaos import HostCrash, PoisonedRequest
from .expert_pages import (ExpertLedger, build_host_experts, expert_evict_step,
                           expert_load_step, expert_ref_step,
                           stub_expert_params)
from .prefix_cache import (PinnedPrefixes, PrefixCache, SpeculationStore,
                           pin_id_of, pin_prefix_step, share_pinned_step,
                           share_prefix_step, unpin_step)
from .sampling import sample_lane, sample_tokens
from .sched import Admission, AdmissionScheduler, SchedConfig
from .telemetry import (CTR_ALLOC, CTR_DRAIN, CTR_EDROP, CTR_EHIT, CTR_EMISS,
                        CTR_EPREF, CTR_FREED, CTR_MARGIN, CTR_REFILL,
                        CTR_ROLLBACK, CTR_SHARED_FREE, CTR_SPILL, N_CTR,
                        FlightRecorder, Telemetry)
from .trace import Tracer


@dataclasses.dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new_tokens: int = 16
    # sampling (defaults reproduce the pre-sampler greedy engine)
    temperature: float = 0.0
    top_k: int = 0
    seed: int = 0
    # scheduling
    slo: str = "standard"
    # admitted expert footprint (MoE serving, DESIGN.md §15): the
    # experts this request may route to, enforced BEFORE top_k by the
    # router mask in both resident and expert-paged engines (so the two
    # are token-identical by construction).  None = all experts.
    experts: Optional[Tuple[int, ...]] = None
    # deadline: relative seconds from submit (0 = none); the engine
    # stamps the absolute ``deadline_at`` at first submission so the
    # deadline survives preemption, crash requeue, and warm restart
    deadline_s: float = 0.0
    deadline_at: float = 0.0
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    slot: Optional[int] = None
    done: bool = False
    rejected: Optional[str] = None     # typed failure reason, terminal
    preemptions: int = 0
    retries: int = 0                   # fault-retry attempts consumed
    submitted_at: float = 0.0
    first_token_at: float = 0.0
    finished_at: float = 0.0
    _seq: int = 0                      # admission order (victim choice)
    _spec_key: Optional[tuple] = None  # whole-page prefix (speculation)


def _release_slots(state: DecodeState, mask):
    """Jit-able: release all blocks of masked slots, zero their state.

    mask: bool[DP, Bl].  One :func:`hier_pool.free_n` per class per
    shard — each page loses one reference; pages still mapped by a
    prefix-sharing sibling or pinned by the prefix cache stay live
    (release decrements instead of frees), the rest return to the
    slot's lane / the shared pool.  In a two-class config the slot's
    CLS_STATE grants (``state_tables`` row) release the same way.

    Returns ``(state, spill)`` with spill int32[C, DP]: pages a full
    lane spilled straight to the shared stack during the frees — the
    term that keeps the §13 shared-free telescoping an equality
    (metered via :func:`hier_pool.free_n_metered`).
    """
    dp, bl, maxp = state.page_tables.shape
    C = len(state.pool.classes)
    to_free = jnp.where(mask[:, :, None], state.page_tables, NULL)
    pool, spill_kv = classed_pool.free_n_metered_dp(state.pool, CLS_KV,
                                                    to_free)
    spills = [spill_kv] + [jnp.zeros_like(spill_kv)] * (C - 1)
    if state.state_tables is not None and C > CLS_STATE:
        st_free = jnp.where(mask[:, :, None], state.state_tables, NULL)
        pool, spills[CLS_STATE] = classed_pool.free_n_metered_dp(
            pool, CLS_STATE, st_free)
        state = state._replace(state_tables=jnp.where(
            mask[:, :, None], NULL, state.state_tables))
    page_tables = jnp.where(mask[:, :, None], NULL, state.page_tables)
    seq_lens = jnp.where(mask, 0, state.seq_lens)

    def zero_masked(tree):
        def f(a):
            if a.ndim >= 3 and a.shape[1] == dp and a.shape[2] == bl:
                m = mask.reshape((1, dp, bl) + (1,) * (a.ndim - 3))
                return jnp.where(m, jnp.zeros_like(a), a)
            return a
        return jax.tree.map(f, tree)

    rings = zero_masked(state.rings)
    rec = zero_masked(state.rec)
    state = state._replace(page_tables=page_tables, seq_lens=seq_lens,
                           pool=pool, rings=rings, rec=rec)
    return state, jnp.stack(spills)


def _alloc_state_step(state: DecodeState, counts):
    """Jit-able admission-time CLS_STATE grant: pull ``counts[d, b]``
    fine blocks per masked slot from class 1's shared stack (bulk
    admission, like prefill loading — off the serve step's hot path)
    and write them into the slot's ``state_tables`` row.  §4.2 for this
    class: lanes hold at most their 3*ell*L slack, so while admission
    respects the class budget the shared stack covers every grant."""
    kmax = state.state_tables.shape[2]
    pool, ids = classed_pool.alloc_from_shared_dp(
        state.pool, CLS_STATE, counts, kmax)
    tables = jnp.where(counts[:, :, None] > 0, ids, state.state_tables)
    return state._replace(pool=pool, state_tables=tables)


# Packed per-step status (the step's single device->host transfer),
# int32[T + 3 + C*N_CTR, DP, Bl] for a width-T step over C size
# classes: rows [0, T) carry each slot's emitted tokens this step in
# order (-1 padding — one row per lane position, so a fully-accepted
# draft lane reports k + 1 tokens in the same single sync), then three
# bookkeeping rows addressed relative to T:
STATUS_EMITTED = 0   # + T: emitted-token count this step
STATUS_DONE = 1      # + T: 1 iff the slot finished (pages released)
STATUS_PAGES = 2     # + T: KV pages-in-use on the slot's DP shard
# followed by C class-major blocks of the N_CTR telemetry counter rows
# (telemetry.CTR_* order within a block, class c's block at offset
# T + 3 + c*N_CTR; per-shard values broadcast over Bl like the PAGES
# row): allocator events metered INSIDE the jit from pool free-level
# deltas the step already computes, harvested through the same single
# sync and the same single all_gather — the DESIGN.md §13
# zero-extra-sync argument, unchanged by the class axis.


def _serve_step(cfg, max_len, eos_id, use_sampler, spec, n_verify, axis_name,
                params, state, last_tok, out_count, budget, temps, topks,
                seeds, prompt_toks, feed_lens, is_prompt, emit, expert_mask):
    """One fully device-resident token-lane step (jitted per lane width
    T x the two static feature flags).

    prompt_toks: int32[DP, Bl, T] host-provided lane tokens.  A prompt
    lane is a prompt chunk; a generating lane reads its first token
    from the device-resident ``last_tok`` register and — when ``spec``
    — carries host-drafted speculative tokens at positions 1..k.
    feed_lens: tokens fed per slot this step (0 = idle; 1 = plain
    decode, the width-1 lane; >1 with is_prompt False = draft+verify
    lane); is_prompt: slot consumes prompt tokens; emit: slot may emit
    output this step (host knows this statically — "prompt exhausted by
    this chunk" or "generating").  temps/topks/seeds: per-slot sampling
    registers, written at admission like ``budget`` (temp <= 0 →
    greedy; see sampling.py for the (seed, out_count) keying that makes
    preemption — and speculation — invisible in sampled output).

    ``use_sampler`` and ``spec`` are STATIC: the host knows at dispatch
    whether any active request samples and whether any lane carries
    drafts, so the default all-greedy non-speculative variant compiles
    without the sampler's full-vocab sort + Gumbel draw and without the
    per-position logits of draft verification — plain serving pays
    nothing for either feature.

    Speculative verify+rollback (``spec``; DESIGN.md §10, §12): the
    ``n_verify`` (static: draft_len + 1) verify positions of each lane
    are gathered and scored — never the full lane width — attention
    runs through the page-grouped verify kernel, position
    i's candidate is sampled with key index ``out_count + i``, and a
    draft is accepted iff it equals the previous position's candidate —
    so an accepted stream is exactly the stream sequential decode would
    have produced, key-for-key.  The slot emits its accepted prefix
    plus one verify token (1..k+1 tokens), keeps exactly that many KV
    positions, and returns the whole-page over-allocation of the
    rejected tail to its own private lane via :func:`hier_pool.
    free_n_dp` — inside this jit, before the rebalance, so §4.2 sees a
    lane at least as stocked as a non-speculative step would leave it.

    Folds sampling, verification/rollback, EOS/budget/length
    done-detection, page release for finished slots, and the
    once-per-step :func:`hier_pool.rebalance` (the paper's deamortized
    shared-pool traffic, off the per-token path) into the step so the
    host syncs exactly once, on the returned packed status int32[T+3,
    DP, Bl] (see STATUS_* row offsets; the PAGES row carries per-shard
    pages-in-use so occupancy tracking — and the scheduler's high-water
    pin eviction — costs no extra transfer).

    ``axis_name`` is STATIC: set (to the mesh axis) when the step runs
    under shard_map on the multi-device allocation plane (DESIGN.md
    §9).  Everything above — forward pass, page alloc/free, draft
    verification and rollback, rebalance drain/refill, sampling,
    done-detection — is then device-local by construction (each device
    owns its shard's HierPool leaves, lanes, refcounts, and KV pages);
    the ONE collective per step is the all_gather that replicates the
    packed status row so every host drives admission from the same
    global view.
    """
    DP, Bl, T = prompt_toks.shape
    C = len(state.pool.classes)
    gen_lane = prompt_toks.at[:, :, 0].set(last_tok)
    toks = jnp.where(is_prompt[..., None], prompt_toks, gen_lane)
    active = feed_lens > 0
    base = state.seq_lens
    # telemetry counter block (DESIGN.md §13, class axis §14):
    # allocator events are metered from per-class per-shard pool
    # free-level deltas between the step's existing phases — pure
    # arithmetic on values the step already holds, no extra device work
    # beyond a few scalar subtractions per class
    def free_all(pool):
        return [classed_pool.free_per_shard(pool, c)      # C x int32[DP]
                for c in range(C)]

    free_in = free_all(state.pool)

    # ``expert_mask`` (bool[DP, Bl, E]) is a per-slot register like
    # temps/seeds: each slot's admitted expert footprint, applied
    # before top_k at every MoE layer.  All-True rows are bit-identical
    # to no mask, so non-MoE and unrestricted slots pay nothing.  The
    # forward's ``fwd_meta`` meters (capacity drops, expert page
    # hit/miss/prefetch) ride the counter block below — same single
    # sync, same single collective (DESIGN.md §15).
    emask = expert_mask if cfg.moe is not None else None
    hidden, state, fwd_meta = forward_decode_chunk(
        cfg, params, toks, state, feed_lens, active=active, verify=spec,
        expert_mask=emask)
    free_fwd = free_all(state.pool)
    # forward only allocates, and only in the KV class
    ctr_alloc = [free_in[c] - free_fwd[c] for c in range(C)]
    idx = jnp.maximum(feed_lens - 1, 0)
    emit = emit & active
    if spec:
        # --- projection slimming (DESIGN.md §12): only the k + 1
        # verify positions of a draft lane need logits.  Gather those
        # hidden rows FIRST — a generating lane's verify positions are
        # lane positions 0..Tv-1, a prompt lane needs only its single
        # emitting position idx (broadcast over the gathered rows) —
        # then project the [DP, Bl, Tv, d] gather instead of the whole
        # [DP, Bl, T, d] lane, so a draft riding a chunk-width step
        # pays k + 1 vocab columns per slot, never T
        Tv = min(T, n_verify) if n_verify > 0 else T
        j = jnp.arange(Tv, dtype=jnp.int32)
        vpos = jnp.where(is_prompt[..., None], idx[..., None],
                         jnp.minimum(j, T - 1)[None, None])  # [DP,Bl,Tv]
        hidden_v = jnp.take_along_axis(hidden, vpos[..., None], axis=2)
        # output-key index per position: generating lanes emit from
        # position 0 on (key out_count + i); a prompt lane's single
        # emitting position is output index 0 (key out_count) — the
        # gather changes WHICH rows are scored, never the key a given
        # output index draws with, so the fold_in(seed, out_count + i)
        # stream stays bit-exact
        cnt = out_count[..., None] + jnp.where(is_prompt[..., None], 0,
                                               j[None, None])
        if use_sampler:
            logits = logits_apply(cfg, params["embed"],
                                  hidden_v)            # [DP,Bl,Tv,V]
            nxt_all = sample_lane(logits, temps, topks, seeds, cnt)
        else:
            # chunked-vocab argmax: greedy verification never builds
            # the [Tv, V] tensor either
            nxt_all = logits_argmax_chunked(cfg, params["embed"], hidden_v)
        # a prompt lane's gathered rows all hold position idx, so row 0
        # is its emitting candidate; a generating lane's last fed
        # position is row feed_lens - 1 (feed <= Tv by dispatch)
        vidx = jnp.where(is_prompt, 0, jnp.minimum(idx, Tv - 1))
        last_pos = jnp.take_along_axis(nxt_all, vidx[..., None],
                                       axis=2)[..., 0]
        # emission stream: generating lanes emit candidates in lane
        # order; prompt lanes emit (at most) their last position's
        etoks = jnp.where(is_prompt[..., None], last_pos[..., None],
                          nxt_all)
        # draft i (lane position i >= 1) is accepted iff position i-1's
        # candidate equals it and every earlier draft was accepted
        dmatch = ((nxt_all[..., :-1] == toks[..., 1:Tv]) &
                  (j[None, None, 1:] < feed_lens[..., None]))
        accepted = jnp.sum(jnp.cumprod(dmatch.astype(jnp.int32), axis=-1),
                           axis=-1)
        n_cand = (jnp.where(is_prompt, 1, accepted + 1)
                  * emit.astype(jnp.int32))
        # EOS / budget truncate the emission stream (an emitted EOS is
        # included, then the slot finishes)
        is_e = (etoks == eos_id) & (j[None, None] < n_cand[..., None])
        eos_cut = jnp.where(jnp.any(is_e, axis=-1),
                            jnp.argmax(is_e, axis=-1) + 1, Tv + 1)
        room = jnp.maximum(budget - out_count, 0)
        n_emit = jnp.minimum(n_cand, jnp.minimum(room, eos_cut))
        hit_eos = jnp.any(is_e & (j[None, None] < n_emit[..., None]),
                          axis=-1)
        # --- rollback: keep last_tok + accepted drafts, free the
        # rejected tail's whole-page over-allocation back to the slot's
        # OWN lane (same-shard by construction; refcount 1 pages —
        # granted this very step — so free_n restacks them)
        adv = state.seq_lens - base
        n_keep = jnp.where(is_prompt, adv, jnp.minimum(n_emit, adv))
        psz = cfg.page_size
        maxp = state.page_tables.shape[2]
        keep_pages = (base + n_keep + psz - 1) // psz
        have_pages = (base + adv + psz - 1) // psz
        kidx = jnp.arange(maxp, dtype=jnp.int32)[None, None, :]
        roll = ((kidx >= keep_pages[..., None]) &
                (kidx < have_pages[..., None]))
        pool, spill_roll = classed_pool.free_n_metered_dp(
            state.pool, CLS_KV, jnp.where(roll, state.page_tables, NULL))
        state = state._replace(
            pool=pool,
            page_tables=jnp.where(roll, NULL, state.page_tables),
            seq_lens=base + n_keep)
        # rollback pages are refcount-1 by construction (granted this
        # very step), so the free-level delta counts them exactly;
        # rollback is KV-class traffic only
        ctr_roll = [classed_pool.free_per_shard(state.pool, CLS_KV)
                    - free_fwd[CLS_KV]]
        ctr_roll += [jnp.zeros_like(ctr_roll[0]) for _ in range(C - 1)]
        out_count = out_count + n_emit
        seq_full = state.seq_lens >= max_len - 1
        done = active & ((out_count >= budget) | seq_full | hit_eos)
        last_emitted = jnp.take_along_axis(
            etoks, jnp.maximum(n_emit - 1, 0)[..., None], axis=2)[..., 0]
        last_tok = jnp.where(n_emit > 0, last_emitted, last_tok)
        tok_rows = jnp.where(j[None, None] < n_emit[..., None], etoks, -1)
        if Tv < T:      # pad the gathered rows back to the lane width
            tok_rows = jnp.concatenate(
                [tok_rows, jnp.full((DP, Bl, T - Tv), -1, jnp.int32)],
                axis=-1)
    else:
        # no drafts, no rollback
        ctr_roll = [jnp.zeros_like(f) for f in free_in]
        spill_roll = jnp.zeros_like(free_in[CLS_KV])
        h_last = jnp.take_along_axis(hidden, idx[..., None, None],
                                     axis=2)[:, :, 0]     # [DP, Bl, d]
        logits = logits_apply(cfg, params["embed"], h_last)
        if use_sampler:
            nxt = sample_tokens(logits, temps, topks, seeds, out_count)
        else:
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        out_count = out_count + emit.astype(jnp.int32)
        seq_full = state.seq_lens >= max_len - 1
        done = active & ((out_count >= budget) | seq_full |
                         (emit & (nxt == eos_id)))
        last_tok = jnp.where(emit, nxt, last_tok)
        n_emit = emit.astype(jnp.int32)
        tok_rows = jnp.concatenate(
            [jnp.where(emit, nxt, -1)[..., None],
             jnp.full((DP, Bl, T - 1), -1, jnp.int32)], axis=-1)
    state, spill_rel = _release_slots(state, done)
    # everything freed since the forward pass actually returned free —
    # spec rollback plus finished-slot release (shared/pinned pages a
    # sibling still maps only decrement, and correctly don't count)
    ctr_freed = [classed_pool.free_per_shard(state.pool, c) - free_fwd[c]
                 for c in range(C)]
    # pages a full lane spilled straight to the shared stack across
    # ALL of this step's frees — the explicit term that keeps the §13
    # shared-free telescoping an equality instead of an inequality:
    # shared_top' - shared_top == spill - drain_net per step
    ctr_spill = [spill_rel[c] + (spill_roll if c == CLS_KV else 0)
                 for c in range(C)]
    # deamortized shared<->lane traffic: once per step, off the
    # per-token path (the paper's run_delayed_step), fused over all
    # classes.  Phases run separately (== rebalance_dp by definition)
    # so the counter block meters per-class drain and refill traffic
    # from the lane-stock deltas.
    lane0 = [jnp.sum(state.pool.classes[c].private_top, axis=-1)
             for c in range(C)]
    pool = classed_pool.rebalance_drain_dp(state.pool)
    lane_drained = [jnp.sum(pool.classes[c].private_top, axis=-1)
                    for c in range(C)]
    pool = classed_pool.rebalance_refill_dp(pool)
    state = state._replace(pool=pool)
    ctr_drain = [lane0[c] - lane_drained[c] for c in range(C)]
    ctr_refill = [jnp.sum(pool.classes[c].private_top, axis=-1)
                  - lane_drained[c] for c in range(C)]

    # the PAGES status row stays the coarse KV class — the scheduler's
    # page budget and high-water pin eviction are KV-page quantities
    kv = state.pool.classes[CLS_KV]
    pages_local = kv.shared.free_ids.shape[1]
    free_now = kv.shared.top + jnp.sum(kv.private_top, axis=1)
    pages_used = (pages_local - free_now).astype(jnp.int32)      # [DP]
    # per-class post-rebalance invariant gauges: each class's shared
    # free level (host min-accumulates the low-water mark) and its
    # §4.2 never-dry margin min(private_top) - ell (>= 0 iff held)
    ctrs = []
    zero_dp = jnp.zeros((DP,), jnp.int32)
    for c in range(C):
        hp = state.pool.classes[c]
        margin = jnp.min(hp.private_top, axis=-1) - hier_pool.lane_ell(hp)
        ctr = jnp.empty((N_CTR, DP), jnp.int32)
        ctr = ctr.at[CTR_ALLOC].set(ctr_alloc[c])
        ctr = ctr.at[CTR_FREED].set(ctr_freed[c])
        ctr = ctr.at[CTR_ROLLBACK].set(ctr_roll[c])
        ctr = ctr.at[CTR_DRAIN].set(ctr_drain[c])
        ctr = ctr.at[CTR_REFILL].set(ctr_refill[c])
        ctr = ctr.at[CTR_SPILL].set(ctr_spill[c])
        ctr = ctr.at[CTR_SHARED_FREE].set(hp.shared.top)
        ctr = ctr.at[CTR_MARGIN].set(margin)
        # §15 expert-paging meters: page traffic rides the expert
        # class's block (``_c2`` keys), capacity drops ride class 0 so
        # resident-weight MoE engines meter them too
        ctr = ctr.at[CTR_EHIT].set(
            fwd_meta["expert_hit_pages"] if c == CLS_EXPERT else zero_dp)
        ctr = ctr.at[CTR_EMISS].set(
            fwd_meta["expert_miss_pages"] if c == CLS_EXPERT else zero_dp)
        ctr = ctr.at[CTR_EPREF].set(
            fwd_meta["expert_prefetch_pages"] if c == CLS_EXPERT
            else zero_dp)
        ctr = ctr.at[CTR_EDROP].set(
            fwd_meta["moe_dropped"] if c == 0 else zero_dp)
        ctrs.append(ctr)
    ctr = jnp.concatenate(ctrs)                  # [C * N_CTR, DP]
    status = jnp.concatenate(
        [tok_rows.transpose(2, 0, 1),
         n_emit[None],
         done.astype(jnp.int32)[None],
         jnp.broadcast_to(pages_used[:, None], (DP, Bl))[None],
         jnp.broadcast_to(ctr[:, :, None], (C * N_CTR, DP, Bl))])
    if axis_name is not None:
        # the step's single collective: only the packed status row
        # crosses shards (DESIGN.md §9 one-sync argument)
        status = jax.lax.all_gather(status, axis_name, axis=1, tiled=True)
    return state, last_tok, out_count, status


class ServingEngine:
    def __init__(self, cfg, params, dp: int = 1, b_local: int = 4,
                 max_len: int = 512, scheduler_lanes: int = 2,
                 greedy: bool = True, chunk_size: int = 8,
                 eos_id: Optional[int] = None,
                 prefix_sharing: bool = True,
                 speculate: bool = False, draft_len: int = 4,
                 spec_gate: bool = True,
                 sched: Optional[SchedConfig] = None,
                 mesh="auto",
                 size_classes: int = 1, degraded_pool_ok: bool = False,
                 expert_paging: bool = False,
                 expert_budget: Optional[int] = None,
                 journal=None, injector=None,
                 watchdog: Optional[StepWatchdog] = None,
                 clock=None, max_restarts: int = 0,
                 telemetry: Optional[Telemetry] = None,
                 tracer: Optional[Tracer] = None,
                 flight: Optional[FlightRecorder] = None):
        self.cfg = cfg
        self.params = params
        self.dp, self.bl = dp, b_local
        # expert-paged MoE serving (DESIGN.md §15): route expert FFN
        # weights through the classed pool's third size class.  Gated
        # on an actual MoE config with paged layer slots; the class
        # vector widens to three BEFORE telemetry/pool construction so
        # every downstream n_classes consumer (counter blocks, specs,
        # max_live) sees the expert class.
        self._elayer_n = expert_layer_slots(cfg)
        self.expert_paging = bool(
            (expert_paging or int(size_classes) > CLS_EXPERT)
            and cfg.moe is not None and self._elayer_n > 0)
        if self.expert_paging:
            size_classes = max(int(size_classes), CLS_EXPERT + 1)
        else:
            # the expert class is meaningless without paged MoE slots
            size_classes = min(int(size_classes), CLS_EXPERT)
        # observability plane (DESIGN.md §13): ONE facade every
        # subsystem emits through.  engine.stats stays a live property
        # view of telemetry.counters, so pre-§13 callers (and the
        # benches) read the same ledger the typed counters write.
        if telemetry is None:
            telemetry = Telemetry(dp, tracer=tracer, flight=flight,
                                  n_classes=max(int(size_classes), 1))
        self.telemetry = telemetry
        self.tracer = telemetry.tracer
        if telemetry.flight is None:
            telemetry.flight = FlightRecorder()
        self.flight = telemetry.flight
        self.max_len = max_len
        self.chunk = max(int(chunk_size), 1)
        self.draft_len = max(int(draft_len), 0)
        # lanes must cover the widest lane the engine will ever
        # dispatch: the prefill chunk or a draft+verify lane (§4.2's
        # ell >= max per-step demand, by construction)
        lane_tokens = self.chunk
        if speculate:
            lane_tokens = max(lane_tokens, self.draft_len + 1)
        # multi-host allocation plane (DESIGN.md §9): with >= dp devices
        # the engine owns a ("dp",) mesh, shards every DecodeState leaf
        # and per-slot register over it, and shard_maps the jitted steps
        # so each device holds exactly its shard's pool/lanes/refcounts/
        # pin-table/KV pages.  mesh=None (or too few devices) keeps the
        # single-device vmap semantics — bit-identical outputs.
        if mesh == "auto":
            mesh = make_dp_mesh(dp)
        self.mesh: Optional[Mesh] = mesh
        self._axis = SERVE_DP_AXIS if mesh is not None else None
        # CLS_EXPERT page budget: the cache capacity the host ledger
        # enforces per shard (full residency when unset — paging then
        # costs nothing and misses only on first touch).  The pool
        # itself is provisioned at budget + lane stock (pool_class_
        # specs), so admission respecting the budget keeps every bulk
        # grant inside the §4.2 slack.
        self.expert_budget = 0
        if self.expert_paging:
            assert dp == 1 or self.mesh is not None, (
                "expert paging needs shard-local DP == 1: run dp=1 or "
                "give each shard its own device (mesh='auto')")
            full = self._elayer_n * cfg.moe.num_experts * EXPERT_PPE
            self.expert_budget = int(
                expert_budget
                or (sched.expert_budget if sched is not None else 0)
                or full)
        self.state = empty_decode_state(
            cfg, dp, b_local, max_len, chunk=lane_tokens,
            size_classes=size_classes,
            expert_budget=(self.expert_budget if self.expert_paging
                           else None))
        self.n_classes = len(self.state.pool.classes)
        assert self.telemetry.n_classes == self.n_classes, (
            "telemetry n_classes must match the engine's size-class "
            "vector (pass Telemetry(dp, n_classes=...))")
        self._pspecs = serve_state_pspecs(self.state)
        self._rspec = serve_register_pspec()
        if self.mesh is not None:
            self.state = jax.device_put(
                self.state, serve_shardings(self.mesh, self._pspecs))
        self.last_tok, self.out_count, self.budget = \
            empty_serve_arrays(dp, b_local)
        # per-slot sampling registers (written at admission, read by the
        # jitted step; all-zeros == greedy everywhere)
        self.temps = jnp.zeros((dp, b_local), jnp.float32)
        self.topks = jnp.zeros((dp, b_local), jnp.int32)
        self.seeds = jnp.zeros((dp, b_local), jnp.int32)
        # per-slot admitted expert footprint (bool[DP, Bl, E]) — a
        # register like temps/seeds, applied pre-top_k in the jitted
        # step.  All-True (the reset value) is bit-identical to no mask,
        # so non-MoE and unrestricted requests pay nothing.
        E_reg = cfg.moe.num_experts if cfg.moe is not None else 1
        self.expert_mask = jnp.ones((dp, b_local, E_reg), bool)
        if self.mesh is not None:
            reg_ns = NamedSharding(self.mesh, self._rspec)
            (self.last_tok, self.out_count, self.budget, self.temps,
             self.topks, self.seeds, self.expert_mask) = jax.device_put(
                (self.last_tok, self.out_count, self.budget, self.temps,
                 self.topks, self.seeds, self.expert_mask), reg_ns)
        self.greedy = greedy
        # sequences can never outgrow the page table (maxp * psz tokens,
        # < max_len when max_len is not a page multiple); done-detection
        # and feed capping use the effective capacity so a chunk is never
        # submitted that forward_decode_chunk would have to reject
        maxp = self.state.page_tables.shape[2]
        self.capacity = (min(max_len, maxp * cfg.page_size)
                         if self.state.kv_pages else max_len)
        self.pages_local = classed_pool.pages_local(self.state.pool, CLS_KV)
        # CLS_STATE blocks one slot's bounded state occupies (0 in a
        # single-class config, and 0 for a fully-paged model even when
        # the class exists): granted at admission, freed at release
        self._state_blocks = (state_blocks_per_slot(cfg, max_len)
                              if self.state.state_tables is not None else 0)
        # plan-time §4.2 validation (DESIGN.md §14): every class must
        # carry pool-wide slack 3*ell*L over its worst-case live blocks
        # — hier_pool.create's own per-lane assert is NOT sufficient
        # (a config can satisfy it and still run a lane dry between
        # rebalances).  ``degraded_pool_ok`` documents the fallback:
        # under-provisioned classes keep serving correctly through the
        # synchronous alloc_n_or_shared shared-pool path, but the O(1)
        # lane-local guarantee (and the never-dry margin gauge) is
        # forfeit for that class.
        max_live = [b_local * maxp] + [b_local * self._state_blocks] * (
            self.n_classes - 1)
        if self.n_classes > CLS_EXPERT:
            # worst-case live CLS_EXPERT pages == the admission budget
            # (the host ledger never loads past it; DESIGN.md §15), so
            # the class's §4.2 slack is exactly its lane stock
            max_live[CLS_EXPERT] = self.expert_budget
        specs = tuple(
            classed_pool.ClassSpec(
                page_size=(cfg.page_size if c == CLS_KV
                           else state_page_tokens(cfg)),
                num_blocks=hp.shared.free_ids.shape[-1],
                num_lanes=hp.private_top.shape[-1],
                ell=hp.private_ids.shape[-1] // 3)
            for c, hp in enumerate(self.state.pool.classes))
        self.pool_provisioned = classed_pool.validate_specs(
            specs, max_live, degraded_ok=degraded_pool_ok)
        self._fed: Dict[int, int] = {}       # host shadow of seq_lens

        # fused device-resident token-lane step, compiled once per lane
        # width T (the scheduler's static chunk buckets, the draft lane
        # width, and T=1) times the two static feature flags
        # (use_sampler, spec) — all-greedy non-speculative batches, the
        # default, never compile or pay for either feature.  On the
        # mesh plane every jitted step is shard_mapped over the ("dp",)
        # axis — shard-locality is enforced structurally, not just by
        # the vmap convention (DESIGN.md §9).
        S, R = self._pspecs, self._rspec

        def wrap(fn, in_specs, out_specs, donate=()):
            if self.mesh is None:
                return jax.jit(fn, donate_argnums=donate)
            return jax.jit(
                shard_map(fn, mesh=self.mesh, in_specs=in_specs,
                          out_specs=out_specs, check_rep=False),
                donate_argnums=donate)

        eos = -1 if eos_id is None else int(eos_id)
        self.eos_id = eos_id
        self._spec_T = self.draft_len + 1
        self._serve_variants = {
            (sampler, spec): wrap(
                functools.partial(_serve_step, cfg, self.capacity, eos,
                                  sampler, spec, self._spec_T, self._axis),
                in_specs=(P(), S) + (R,) * 11,
                out_specs=(S, R, R, P()),
                donate=(1, 2, 3))
            for sampler in (False, True) for spec in (False, True)}
        self._sampling_slots: set = set()
        self._release = wrap(_release_slots, in_specs=(S, R),
                             out_specs=(S, P(None, "dp")), donate=(0,))
        # admission-time CLS_STATE grant (two-class configs): a jitted
        # bulk shared-pool pull, same off-hot-path shape as prefill
        # loading and the share/pin steps
        self._alloc_state = None
        if self.state.state_tables is not None:
            self._alloc_state = wrap(_alloc_state_step, in_specs=(S, R),
                                     out_specs=S, donate=(0,))

        # expert-paged weight plane (DESIGN.md §15): keep the full
        # expert stacks on HOST, stub the device param leaves to [..,1,1]
        # placeholders (the HBM the paging buys), and manage device
        # residency through CLS_EXPERT pages + the host ledger.  Load /
        # evict / refcount steps are admission-time traffic — jitted,
        # but never inside _serve_step, so the one-sync/one-collective
        # step shape is untouched.
        self.expert_ledger: Optional[ExpertLedger] = None
        self._host_experts = None
        self._slot_experts: Dict[int, tuple] = {}
        self._elayer_slots: List[Tuple[str, int]] = []
        self._fp_masks: Dict[tuple, Any] = {}
        if self.expert_paging:
            self._host_experts = build_host_experts(cfg, params)
            self.params = params = stub_expert_params(params)
            self.expert_ledger = ExpertLedger(dp, self.expert_budget)
            self._elayer_slots = [
                (pos, g)
                for pos in sorted(self.state.expert_tables)
                for g in range(self.state.expert_tables[pos].shape[0])]
            self._eload = {
                pos: wrap(functools.partial(expert_load_step, pos),
                          in_specs=(S, R, P(), P("dp"), P(), P()),
                          out_specs=S, donate=(0,))
                for pos in self.state.expert_tables}
            self._eevict = {
                pos: wrap(functools.partial(expert_evict_step, pos),
                          in_specs=(S, P("dp"), P(), P()),
                          out_specs=S, donate=(0,))
                for pos in self.state.expert_tables}
            self._eref = {
                free: wrap(functools.partial(expert_ref_step, free),
                           in_specs=(S, P(), P("dp")),
                           out_specs=S, donate=(0,))
                for free in (False, True)}

        # prefix sharing: only sound when the whole decode state is
        # paged (ring / recurrent layers would need donor state at the
        # match point); page ids are shard-local, so matches are too
        self.prefix_cache: Optional[PrefixCache] = None
        if (prefix_sharing and self.state.kv_pages
                and not self.state.rings and not self.state.rec
                and self.state.enc_kv is None):
            self.prefix_cache = PrefixCache(cfg.page_size)
            self._share = wrap(
                functools.partial(share_prefix_step, cfg.page_size,
                                  axis_name=self._axis),
                in_specs=(S, R, R, P()), out_specs=(S, P()),
                donate=(0,))

        # speculative decode on shared prefixes (DESIGN.md §10): sound
        # under the same fully-paged condition — rejected drafts roll
        # back pages and seq_lens, but ring/recurrent state cannot be
        # un-evolved, so those models never dispatch the spec variant
        self.spec_store: Optional[SpeculationStore] = None
        if speculate and self.draft_len > 0 and self.prefix_cache is not None:
            self.spec_store = SpeculationStore(cfg.page_size)
        self.speculate = self.spec_store is not None
        # accept-rate-gated drafting (DESIGN.md §12): per-prefix EWMA
        # accept rate (lives in the SpeculationStore, so it survives
        # warm restarts with the streams) against a measured per-step
        # cost model — an EWMA of step wall time keyed by (lane width,
        # spec).  Before both sides are measured, the break-even test
        # falls back to a linear cost model: a width-(k+1) verify step
        # costs ~ (1 + slope * k) plain decode steps (the slope is what
        # the verify kernel + projection slimming shrink).
        self.spec_gate = bool(spec_gate)
        self.spec_cost_slope = 0.25
        self._step_cost: Dict[Tuple[int, bool], float] = {}
        self._cost_seen: set = set()

        # traffic-aware frontend: admission order / page budgets /
        # preemption / pin policy (DESIGN.md §8).  The default budget is
        # b_local * maxp — the table capacity the pool is provisioned
        # for — so with pinning off the scheduler admits exactly what
        # the pre-scheduler engine admitted.
        self.sched_config = sched or SchedConfig()
        self.scheduler = AdmissionScheduler(
            self.sched_config, n_shards=dp, page_budget=b_local * maxp,
            state_budget=b_local * self._state_blocks)

        # pinned prefixes: device pin table (rows of cache-owned page
        # ids per shard) + host LRU ledger; disabled unless the sched
        # config grants a pin budget AND the model can share at all
        self.pins: Optional[PinnedPrefixes] = None
        self.pin_tables: Optional[jax.Array] = None
        if self.prefix_cache is not None and self.sched_config.pin_pages > 0:
            self.pins = PinnedPrefixes(dp, self.sched_config.pin_rows,
                                       self.sched_config.pin_pages)
            self.pin_tables = jnp.full(
                (dp, self.sched_config.pin_rows, maxp), -1, jnp.int32)
            if self.mesh is not None:
                # pin rows are shard-owned like everything else: a pin
                # on shard d references only shard-d pages, and its
                # addref/free traffic stays on shard d's device
                self.pin_tables = jax.device_put(
                    self.pin_tables, NamedSharding(self.mesh, R))
            PS = self._pspecs.pool
            self._pin = wrap(pin_prefix_step,
                             in_specs=(PS, R, R, R, R, P()),
                             out_specs=(PS, R), donate=(0, 1))
            self._unpin = wrap(unpin_step, in_specs=(PS, R, R),
                               out_specs=(PS, R), donate=(0, 1))
            self._share_pinned = wrap(
                functools.partial(share_pinned_step, cfg.page_size,
                                  axis_name=self._axis),
                in_specs=(S, R, R, R, P()), out_specs=(S, P()),
                donate=(0,))
        self._pinned_slots: set = set()
        # host copy of the status PAGES row (per-shard pages-in-use,
        # refreshed by the step's single sync; drives high-water pin
        # eviction without any extra transfer) + per-shard occupancy
        # accumulators for the mesh bench (shard_occupancy())
        self.pages_used_shard: List[int] = [0] * dp
        self._pages_shard_sum = np.zeros(dp, np.int64)
        self._pages_shard_peak = np.zeros(dp, np.int64)

        # host-side wait-free slot allocator: slots are fixed-size blocks.
        n_slots = dp * b_local
        self.lane_ctx = SimContext(scheduler_lanes, seed=0)
        self.slot_alloc = WaitFreeAllocator(
            self.lane_ctx, ell=max(3 * scheduler_lanes, 4),
            shared_batches=max(2, n_slots), allow_os_growth=True)
        # bind allocator block ids <-> engine slots (first n_slots blocks)
        self._slot_of_block: Dict[int, int] = {}
        self._block_of_slot: Dict[int, int] = {}
        self._free_slots = deque(range(n_slots))
        self.lanes = itertools.cycle(range(scheduler_lanes))

        # fault tolerance (DESIGN.md §11): optional admission/completion
        # journal + phase-boundary failure injector (serving/chaos.py),
        # the shared step watchdog, an injectable clock for deadline
        # tests, and the in-place recovery budget for run()
        self._journal = journal
        self._injector = injector
        self.watchdog = watchdog or StepWatchdog()
        self._clock = clock or time.time
        self.max_restarts = max_restarts
        self.lost_shards: set = set()

        self.active: Dict[int, Request] = {}     # slot -> request
        self.pending_tokens: Dict[int, List[int]] = {}
        self._latencies: List[float] = []
        self._ft_latencies: List[float] = []
        # wire the facade through the subsystems that emit (DESIGN §13)
        self.scheduler.telemetry = self.telemetry
        if self.prefix_cache is not None:
            self.prefix_cache.telemetry = self.telemetry
        self.flight.meta.update(
            dp=dp, b_local=b_local, page_size=int(cfg.page_size),
            pages_local=int(self.pages_local),
            lane_ell=classed_pool.lane_ell(self.state.pool, CLS_KV),
            size_classes=self.n_classes,
            expert_paging=self.expert_paging,
            expert_budget=self.expert_budget,
            speculate=self.speculate, arch=getattr(cfg, "name", "?"))

    @property
    def stats(self):
        """Backward-compatible live view of the typed telemetry
        counters (one ledger — external ``engine.stats[...]`` reads and
        writes land on the same dict :class:`Telemetry` maintains)."""
        return self.telemetry.counters

    # ---------------------------------------------------------- tracing
    def _tr_begin(self, name: str, tid: int, **args) -> None:
        """Idempotent span open: a resubmitted request (crash requeue,
        warm restart) must not double-open its span."""
        if not self.tracer.is_open(name, tid):
            self.tracer.begin(name, tid, **args)

    def _tr_end(self, name: str, tid: int, **args) -> None:
        if self.tracer.is_open(name, tid):
            self.tracer.end(name, tid, **args)

    def _trace_terminal(self, req, reason: str) -> None:
        """Close a request's spans on any terminal rejection path —
        also called by the scheduler's deadline/shed paths."""
        name = ("deadline_expired" if reason == "deadline"
                else "shed" if reason == "shed" else "reject")
        self.tracer.instant(name, tid=req.rid, reason=reason)
        self._tr_end("active", req.rid)
        self._tr_end("request", req.rid)

    # ------------------------------------------------------------ control
    @property
    def queue(self) -> List[Request]:
        """Backward-compatible view of the scheduler backlog (admission
        order: priority classes, FIFO within a class)."""
        return self.scheduler.pending()

    def _host_alloc_slot(self, shard: Optional[int] = None
                         ) -> Optional[int]:
        """O(1) wait-free admission through the paper's allocator.

        ``shard`` restricts placement: the scheduler's page-budget
        accounting is per shard, and a prefix-sharing donor's pages are
        only mappable from its own shard."""
        if not self._free_slots:
            return None
        if shard is not None:
            for s in self._free_slots:
                if s // self.bl == shard:
                    self._free_slots.remove(s)
                    self._free_slots.appendleft(s)
                    break
            else:
                return None
        lane = next(self.lanes)
        gen = self.slot_alloc.allocate(lane)
        try:
            while True:
                next(gen)
        except StopIteration as e:
            block = e.value
        op = self.lane_ctx.history[-1]
        self.telemetry.set_max("alloc_steps_max", op.steps)
        slot = self._free_slots.popleft()
        self._slot_of_block[block] = slot
        self._block_of_slot[slot] = block
        return slot

    def _host_free_slot(self, slot: int) -> None:
        lane = next(self.lanes)
        block = self._block_of_slot.pop(slot)
        self._slot_of_block.pop(block)
        gen = self.slot_alloc.free(lane, block)
        try:
            while True:
                next(gen)
        except StopIteration:
            pass
        self._free_slots.append(slot)

    # --------------------------------------------------- fault tolerance
    def _jrec(self, kind: str, **fields) -> None:
        if self._journal is not None:
            self._journal.record(kind, **fields)

    def _fire(self, phase: str, **ctx) -> None:
        if self._injector is not None:
            self._injector.fire(self, phase, **ctx)

    # ------------------------------------------------ scheduler interface
    def submit(self, req: Request) -> Admission:
        """Enqueue (or reject, with a reason) through the admission
        scheduler.  The return value is the backpressure signal."""
        now = self._clock()
        req.submitted_at = now
        if req.deadline_s > 0 and req.deadline_at == 0.0:
            req.deadline_at = now + req.deadline_s
        # write-ahead: the journal sees every request before the
        # scheduler does, carrying any resumed token prefix — recovery's
        # in_flight() replay is complete even if we crash mid-submit
        self._jrec("submit", rid=req.rid,
                   prompt=[int(t) for t in req.prompt],
                   max_new_tokens=int(req.max_new_tokens),
                   temperature=float(req.temperature),
                   top_k=int(req.top_k), seed=int(req.seed), slo=req.slo,
                   out_tokens=[int(t) for t in req.out_tokens],
                   preemptions=int(req.preemptions),
                   deadline_at=float(req.deadline_at))
        self._tr_begin("request", req.rid, slo=req.slo,
                       prompt_len=len(req.prompt))
        self.tracer.instant("submit", tid=req.rid, slo=req.slo)
        if self.expert_paging:
            # a footprint that cannot fit the expert budget even on an
            # empty shard is unservable — typed rejection, not a wedge
            need = (EXPERT_PPE * len(self._elayer_slots)
                    * len(self._footprint_of(req)))
            if need > self.expert_budget:
                self.scheduler._count("rejected")
                req.rejected = "too_large"
                self._jrec("reject", rid=req.rid, reason="too_large")
                self._trace_terminal(req, "too_large")
                return Admission(False, "too_large")
        adm = self.scheduler.submit(req, self.est_pages(req))
        if not adm.accepted:
            self._jrec("reject", rid=req.rid, reason=adm.reason)
            self._trace_terminal(req, adm.reason)
        return adm

    def est_pages(self, req: Request) -> int:
        """Worst-case page demand of a request: its full prompt plus
        its whole output budget, capped at the per-slot capacity.
        ``max_new_tokens`` is the TOTAL generation budget, so tokens
        already generated before a preemption are not added on top —
        the estimate is stable across preempt/readmit cycles (a grown
        estimate could outgrow a tight page budget and wedge the
        readmission)."""
        toks = len(req.prompt) + int(req.max_new_tokens)
        toks = min(max(toks, 1), self.capacity)
        return -(-toks // self.cfg.page_size)

    def est_state_blocks(self, req: Request) -> int:
        """Fine-class (CLS_STATE) block demand of a request: the fixed
        per-slot bounded-state footprint (rings/recurrent/encoder KV
        are sized by the model, not the request).  0 in a single-class
        config — the scheduler's state dimension then never binds."""
        return self._state_blocks

    def free_slot_shards(self) -> set:
        return {s // self.bl for s in self._free_slots}

    def prefix_match(self, req: Request, shard: Optional[int] = None):
        """Trie lookup, restricted to ``shard`` when given — the
        scheduler always restricts (donor pages are shard-local;
        DESIGN.md §9), the unrestricted form is diagnostic only."""
        if self.prefix_cache is None:
            return None
        toks = (list(req.prompt) + list(req.out_tokens)) or [1]
        return self.prefix_cache.match(toks, shard=shard)

    def pinned_pages_on(self, shard: int) -> int:
        return self.pins.pages_on(shard) if self.pins is not None else 0

    def pinned_pages(self) -> int:
        return self.pins.total_pages() if self.pins is not None else 0

    # ------------------------------------------- expert paging (§15)
    def _footprint_of(self, req: Request) -> tuple:
        """A request's admitted expert footprint (sorted, deduplicated;
        all experts when unrestricted)."""
        E = self.cfg.moe.num_experts
        if req.experts is None:
            return tuple(range(E))
        return tuple(sorted({int(e) for e in req.experts
                             if 0 <= int(e) < E}))

    def _fp_entry(self, fp: tuple):
        """(masks, row) for a footprint: the per-table bool[S, E] masks
        the bulk ref step consumes and the bool[E] register row.
        Cached — footprints repeat (that is the skew admission
        learns)."""
        ent = self._fp_masks.get(fp)
        if ent is None:
            E = self.cfg.moe.num_experts
            row = np.zeros(E, bool)
            row[list(fp)] = True
            masks = {
                pos: jnp.asarray(
                    np.broadcast_to(row, (tab.shape[0], E)).copy())
                for pos, tab in self.state.expert_tables.items()}
            ent = self._fp_masks[fp] = (masks, row)
        return ent

    def est_expert_pages(self, req: Request, shard: int) -> int:
        """Load-aware CLS_EXPERT page demand of a request ON A SHARD:
        0 for every (layer slot, expert) already resident there,
        EXPERT_PPE per cold one.  This is the per-shard skew signal the
        scheduler's third admission dimension consumes."""
        if self.expert_ledger is None:
            return 0
        led = self.expert_ledger
        fp = self._footprint_of(req)
        cold = sum(1 for pos, g in self._elayer_slots for e in fp
                   if not led.resident(shard, pos, g, e))
        return EXPERT_PPE * cold

    def expert_headroom(self, shard: int) -> int:
        """Admissible CLS_EXPERT pages on a shard: budget minus
        resident pages, plus what LRU eviction of COLD experts (zero
        active references) can reclaim.  Hot experts are working set,
        not cache — they never count as reclaimable."""
        if self.expert_ledger is None:
            return 1 << 30
        led = self.expert_ledger
        return (self.expert_budget - led.pages_on(shard)
                + led.evictable_pages(shard))

    def expert_pages_resident(self, shard: int) -> int:
        return (0 if self.expert_ledger is None
                else self.expert_ledger.pages_on(shard))

    def _load_expert(self, d: int, pos: str, g: int, e: int) -> None:
        w = self._host_experts[pos][g, e]
        counts = np.zeros((self.dp, self.bl), np.int32)
        counts[d, 0] = EXPERT_PPE
        oh = np.zeros(self.dp, bool)
        oh[d] = True
        self.state = self._eload[pos](
            self.state, jnp.asarray(counts), jnp.asarray(w),
            jnp.asarray(oh), jnp.int32(g), jnp.int32(e))
        self.expert_ledger.add(d, pos, g, e)
        self.telemetry.inc("expert_load_pages", EXPERT_PPE)

    def _evict_expert(self, key) -> None:
        d, pos, g, e = key
        self.expert_ledger.remove(key)
        oh = np.zeros(self.dp, bool)
        oh[d] = True
        self.state = self._eevict[pos](
            self.state, jnp.asarray(oh), jnp.int32(g), jnp.int32(e))
        self.telemetry.inc("expert_evictions")
        self.telemetry.inc("expert_evict_pages", EXPERT_PPE)

    def _admit_experts(self, slot: int, req: Request) -> None:
        """Bind the slot's expert footprint: set the router-mask
        register (both engines — token identity is by construction),
        and in paged mode make every footprint expert resident (LRU
        eviction for room; the scheduler's placement already verified
        headroom) and take one batch reference per expert."""
        if self.cfg.moe is None:
            return
        d, b = divmod(slot, self.bl)
        fp = self._footprint_of(req)
        masks, row = (self._fp_entry(fp) if self.expert_ledger is not None
                      else (None, None))
        if row is None:
            E = self.cfg.moe.num_experts
            row = np.zeros(E, bool)
            row[list(fp)] = True
        self.expert_mask = self.expert_mask.at[d, b].set(
            jnp.asarray(row))
        if self.expert_ledger is None:
            return
        led = self.expert_ledger
        keys = [(d, pos, g, e) for pos, g in self._elayer_slots
                for e in fp]
        for key in keys:
            if led.resident(*key):
                led.touch(key)
                self.telemetry.inc("expert_admit_hits")
                continue
            self.telemetry.inc("expert_admit_misses")
            while (led.pages_on(d) + EXPERT_PPE > self.expert_budget):
                victim = led.lru(d)
                assert victim is not None, (
                    "expert admission over budget with nothing "
                    "evictable — scheduler headroom check violated")
                self._evict_expert(victim)
            self._load_expert(*key)
        # ONE bulk addref over the whole footprint (admission-time
        # traffic, off the serve step)
        oh = np.zeros(self.dp, bool)
        oh[d] = True
        self.state = self._eref[False](self.state, masks,
                                       jnp.asarray(oh))
        for key in keys:
            led.addref(key)
        self._slot_experts[slot] = (d, fp)
        peak = max(led.pages_on(s) for s in range(self.dp))
        self.telemetry.set_max("expert_pages_resident_peak", peak)

    def _release_experts(self, slot: int, device: bool = True) -> None:
        """Drop the slot's expert references and reset its router-mask
        row to all-True (BOTH modes — the reset keeps resident and
        paged engines consistent, preserving token identity across a
        slot's whole lifecycle).  ``device=False`` on shard loss: the
        pages died with the shard, only host bookkeeping remains."""
        if self.cfg.moe is None:
            return
        d, b = divmod(slot, self.bl)
        self.expert_mask = self.expert_mask.at[d, b].set(True)
        ent = self._slot_experts.pop(slot, None)
        if ent is None or self.expert_ledger is None:
            return
        d, fp = ent
        if device:
            masks, _ = self._fp_entry(fp)
            oh = np.zeros(self.dp, bool)
            oh[d] = True
            self.state = self._eref[True](self.state, masks,
                                          jnp.asarray(oh))
        for pos, g in self._elayer_slots:
            for e in fp:
                self.expert_ledger.deref((d, pos, g, e))

    def flush_experts(self) -> int:
        """Evict every COLD resident expert (drained-engine teardown /
        leak audits — with active references nothing moves).  Returns
        the number of experts evicted."""
        if self.expert_ledger is None:
            return 0
        n = 0
        for key in [k for k, ent in self.expert_ledger.entries.items()
                    if ent["batch"] == 0]:
            self._evict_expert(key)
            n += 1
        return n

    def admit(self, req: Request, match, shard: int) -> int:
        """Place a request on ``shard`` (mechanism only — the scheduler
        chose the order, the shard, and verified budget/slot
        availability).  A preempted request re-enters here carrying its
        generated tokens: they are re-prefilled (often via the prefix
        cache) and generation resumes at ``out_count ==
        len(out_tokens)``, which both the budget check and the
        sampler's noise keying are relative to — so the resumed stream
        is the one the request would have produced unpreempted."""
        # empty prompts degrade to the BOS=1 convention
        toks = (list(req.prompt) + list(req.out_tokens)) or [1]
        slot = self._host_alloc_slot(shard)
        assert slot is not None, "scheduler admitted without a free slot"
        d, b = divmod(slot, self.bl)
        req.slot = slot
        self.active[slot] = req
        shared_n = 0
        if match is not None:
            # the scheduler guarantees shard-local matches; _try_share
            # asserts it, loudly — a cross-shard donor must never be
            # silently dropped (DESIGN.md §9)
            shared_n = self._try_share(slot, match, len(toks))
        self.pending_tokens[slot] = toks[shared_n:]
        self._fed[slot] = shared_n
        if self._alloc_state is not None and self._state_blocks > 0:
            # grant the slot's bounded-state blocks from the fine class
            # (CLS_STATE) in one bulk shared-pool pull — the class's
            # §4.2 slack plus the scheduler's state-budget accounting
            # guarantee the grant succeeds (DESIGN.md §14)
            counts = np.zeros((self.dp, self.bl), np.int32)
            counts[d, b] = self._state_blocks
            self.state = self._alloc_state(self.state, jnp.asarray(counts))
            self.telemetry.inc("state_blocks_granted", self._state_blocks)
        if self.prefix_cache is not None:
            self.prefix_cache.insert(slot, d, toks)
            self.prefix_cache.update_progress(slot, shared_n)
        if self.spec_store is not None:
            req._spec_key = self.spec_store.key_of(req.prompt)
        self.budget = self.budget.at[d, b].set(req.max_new_tokens)
        self.out_count = self.out_count.at[d, b].set(
            len(req.out_tokens))
        self.temps = self.temps.at[d, b].set(float(req.temperature))
        self.topks = self.topks.at[d, b].set(int(req.top_k))
        self.seeds = self.seeds.at[d, b].set(int(req.seed))
        self._admit_experts(slot, req)
        if req.temperature > 0:
            self._sampling_slots.add(slot)
        self.telemetry.inc("admitted")
        self._tr_begin("active", req.rid, slot=slot, shard=d)
        self.tracer.instant("admit", tid=req.rid, slot=slot, shard=d,
                            shared_tokens=shared_n)
        if req.out_tokens or req.preemptions:
            self.tracer.instant("resume", tid=req.rid,
                                tokens_done=len(req.out_tokens))
        self._jrec("admit", rid=req.rid, slot=slot, shard=d)
        return slot

    def preempt(self, slot: int) -> Request:
        """Evict a running request: pin its whole-page state when the
        budget allows (so readmission re-prefills through the cache),
        release its pages through the normal refcounted path, free the
        slot, and hand the request back to the scheduler carrying
        prompt + generated tokens."""
        req = self.active.pop(slot)
        toks = list(req.prompt) + list(req.out_tokens)
        self._maybe_pin(slot, toks)
        d, b = divmod(slot, self.bl)
        mask = np.zeros((self.dp, self.bl), bool)
        mask[d, b] = True
        self.state, _ = self._release(self.state, jnp.asarray(mask))
        self._release_experts(slot)
        self.pending_tokens.pop(slot, None)
        self._fed.pop(slot, None)
        self._pinned_slots.discard(slot)
        self._sampling_slots.discard(slot)
        if self.prefix_cache is not None:
            self.prefix_cache.remove(slot)
        self._host_free_slot(slot)
        self.scheduler.on_released(slot)
        req.slot = None
        req.preemptions += 1
        self.telemetry.inc("preemptions")
        self.tracer.instant("preempt", tid=req.rid, slot=slot)
        self._tr_end("active", req.rid)
        self._jrec("preempt", rid=req.rid)
        return req

    def fail_active(self, slot: int, reason: str, retry: bool = False
                    ) -> Request:
        """Terminate (or retry) a running request that hit a fault or an
        expired deadline: release its pages through the normal
        refcounted path, free the slot, then either park the request
        for a bounded-backoff retry or mark it terminally rejected with
        a typed reason (sched.FAILURE_REASONS)."""
        req = self.active.pop(slot)
        d, b = divmod(slot, self.bl)
        mask = np.zeros((self.dp, self.bl), bool)
        mask[d, b] = True
        self.state, _ = self._release(self.state, jnp.asarray(mask))
        self._release_experts(slot)
        self.pending_tokens.pop(slot, None)
        self._fed.pop(slot, None)
        self._pinned_slots.discard(slot)
        self._sampling_slots.discard(slot)
        if self.prefix_cache is not None:
            self.prefix_cache.remove(slot)
        self._host_free_slot(slot)
        self.scheduler.on_released(slot)
        req.slot = None
        self._tr_end("active", req.rid)
        if retry and req.retries < self.sched_config.retry_limit:
            req.retries += 1
            self.telemetry.inc("retries")
            self.tracer.instant("retry", tid=req.rid, reason=reason,
                                attempt=req.retries)
            self._jrec("preempt", rid=req.rid)
            self.scheduler.park(
                req, self.sched_config.retry_backoff * req.retries)
        else:
            req.rejected = reason
            self.telemetry.inc("failed")
            if reason == "deadline":
                self.telemetry.inc("deadline_expired")
            self._jrec("reject", rid=req.rid, reason=reason)
            self._trace_terminal(req, reason)
        return req

    def lose_shard(self, shard: int) -> None:
        """Graceful degradation on shard loss (DESIGN.md §11): the dead
        shard's device state — pages, pins, KV — is unreachable and
        leaves the accounting with the shard.  Its running requests are
        evacuated host-side through the requeue path (they re-prefill
        on a surviving shard, token-identically, since generation is a
        pure function of prompt + out_count); its slots are retired
        from service and admission shrinks to the survivors
        (runtime.elastic.plan_serving_for drives backlog shedding)."""
        if shard in self.lost_shards:
            return
        self.lost_shards.add(shard)
        self.telemetry.inc("shards_lost")
        self.tracer.instant("shard_loss", shard=shard)
        self._jrec("shard_lost", shard=shard)
        self.scheduler.lose_shard(shard)
        for slot in [s for s in self.active if s // self.bl == shard]:
            req = self.active.pop(slot)
            # host bookkeeping only: no device release — the shard that
            # owned the pages is gone
            self._release_experts(slot, device=False)
            self.pending_tokens.pop(slot, None)
            self._fed.pop(slot, None)
            self._pinned_slots.discard(slot)
            self._sampling_slots.discard(slot)
            if self.prefix_cache is not None:
                self.prefix_cache.remove(slot)
            self._host_free_slot(slot)
            self.scheduler.on_released(slot)
            req.slot = None
            req.preemptions += 1
            self.telemetry.inc("preemptions")
            self.tracer.instant("preempt", tid=req.rid, slot=slot,
                                reason="shard_loss")
            self._tr_end("active", req.rid)
            self._jrec("preempt", rid=req.rid)
            self.scheduler.requeue_front(req)
        if self.expert_ledger is not None:
            self.expert_ledger.drop_shard(shard)
        # retire the dead shard's slots from service entirely
        self._free_slots = deque(
            s for s in self._free_slots if s // self.bl != shard)
        if self.pins is not None:
            for pid in [p for p, e in self.pins.entries.items()
                        if e["shard"] == shard]:
                self.pins.remove(pid)
                if self.prefix_cache is not None:
                    self.prefix_cache.pin_remove(pid)
                self._jrec("unpin", pin_id=pid)
        if self.prefix_cache is not None:
            self.prefix_cache.roots.pop(shard, None)

    # ------------------------------------------------------------ pinning
    def _maybe_pin(self, slot: int, tokens: List[int]) -> None:
        """Pin the slot's resident whole pages for ``tokens`` (a prompt
        at prefill completion, prompt+generated at preemption) under
        the scheduler's pin policy.  Exact-key deduplicated: re-pinning
        a prefix that is already cache-held just refreshes its LRU."""
        if self.pins is None:
            return
        d, b = divmod(slot, self.bl)
        psz = self.cfg.page_size
        n_pages = min(len(tokens), self._fed.get(slot, 0)) // psz
        if n_pages < 1:
            return
        key_toks = tokens[:n_pages * psz]
        hit = self.pins.lookup(d, key_toks)
        if hit is not None:
            self.pins.touch(hit)
            return
        if not self.scheduler.may_pin(self, d, n_pages):
            return
        pin_id = self.pins.add(d, key_toks, n_pages)
        row = pin_id % self.pins.npin
        pin_oh = np.zeros((self.dp, self.pins.npin), bool)
        pin_oh[d, row] = True
        src = np.zeros((self.dp, self.bl), bool)
        src[d, b] = True
        pool, self.pin_tables = self._pin(
            self.state.pool, self.pin_tables, self.state.page_tables,
            jnp.asarray(pin_oh), jnp.asarray(src), jnp.int32(n_pages))
        self.state = self.state._replace(pool=pool)
        self.prefix_cache.pin_insert(pin_id, d, key_toks)
        self.telemetry.inc("pins_created")
        self.tracer.instant("pin", pin_id=pin_id, shard=d,
                            pages=int(n_pages))
        # write-behind: journaled only after the device op — a crash in
        # between leaves device refs the journal never saw, which
        # recovery reclaims (leak-fix, not leak)
        self._jrec("pin", pin_id=pin_id, shard=d, row=row,
                   tokens=[int(t) for t in key_toks], pages=int(n_pages))

    def evict_pin(self, pin_id: int) -> None:
        """Drop the cache's references on one pinned row (mechanism;
        the scheduler picks who and when)."""
        shard, row = self.pins.remove(pin_id)
        oh = np.zeros((self.dp, self.pins.npin), bool)
        oh[shard, row] = True
        pool, self.pin_tables = self._unpin(
            self.state.pool, self.pin_tables, jnp.asarray(oh))
        self.state = self.state._replace(pool=pool)
        self.prefix_cache.pin_remove(pin_id)
        self.tracer.instant("unpin", pin_id=pin_id, shard=shard)
        self._jrec("unpin", pin_id=pin_id)

    def flush_pins(self) -> int:
        """Evict every pinned prefix; returns how many.  After a full
        drain plus a flush, page occupancy must be exactly zero — the
        conservation check the overload bench and tests close with."""
        if self.pins is None:
            return 0
        ids = list(self.pins.entries)
        for pin_id in ids:
            self.evict_pin(pin_id)
        return len(ids)

    def _try_share(self, slot: int, match, prompt_len: int) -> int:
        """Map the matched prefix onto the donor's pages (device-side,
        one jitted call, off the per-token path).  The donor is either
        a live slot or a pinned cache row.  Returns the number of
        tokens now resident in the slot's KV (0 = no sharing)."""
        assert match.shard == slot // self.bl, (
            "cross-shard donor: page ids never alias across shards "
            "(DESIGN.md §9); the scheduler must match shard-locally")
        n = min(match.n_tokens, prompt_len - 1, self.capacity - 1)
        if n < self.cfg.page_size:
            return 0
        dst = np.zeros((self.dp, self.bl), bool)
        dst[slot // self.bl, slot % self.bl] = True
        if match.pinned:
            pin_id = pin_id_of(match.slot)
            pin_oh = np.zeros((self.dp, self.pins.npin), bool)
            pin_oh[match.shard, self.pins.entries[pin_id]["row"]] = True
            self.state, ok = self._share_pinned(
                self.state, self.pin_tables, jnp.asarray(dst),
                jnp.asarray(pin_oh), jnp.int32(n))
            if not bool(ok):   # shared pool dry for the COW page
                return 0
            self.pins.touch(pin_id)
            self.telemetry.inc("pin_hit_reqs")
            self.telemetry.inc("pin_hit_tokens", n)
            self.tracer.instant("pin_hit", tid=self.active[slot].rid,
                                tokens=n)
        else:
            src = np.zeros((self.dp, self.bl), bool)
            src[match.slot // self.bl, match.slot % self.bl] = True
            self.state, ok = self._share(self.state, jnp.asarray(dst),
                                         jnp.asarray(src), jnp.int32(n))
            if not bool(ok):   # lane dry for the COW page — admit unshared
                return 0
        self.telemetry.inc("prefix_shared_tokens", n)
        self.telemetry.inc("prefix_shared_reqs")
        rid = self.active[slot].rid
        self.tracer.instant("share", tid=rid, tokens=n,
                            shard=match.shard, pinned=bool(match.pinned))
        if n % self.cfg.page_size != 0:
            # the share step gave the slot a private copy of the donor's
            # partial tail page — the COW copy counter lives here, at
            # the share-step boundary, because COW never happens inside
            # _serve_step (DESIGN.md §13: counted host-side, zero extra
            # transfer — ``ok`` already crossed in the share's own sync)
            self.telemetry.inc("cow_copies")
            self.tracer.instant("cow_copy", tid=rid)
        return n

    # -------------------------------------------------------------- step
    def _gate_k(self, key, k_max: int) -> int:
        """Break-even draft length for this prefix (DESIGN.md §12).

        Expected tokens per step at accept rate ``a`` with k drafts is
        ``1 + a + a^2 + ... + a^k`` (draft i lands only if every earlier
        draft did); a width-(k+1) verify step costs ``cost(k+1, spec) /
        cost(1, decode)`` plain steps, from the measured per-step EWMA
        when both widths have run, else the linear fallback model.
        Returns the largest k <= k_max whose expected tokens clear its
        cost — so draft_len SHRINKS before speculation disables — or 0
        to skip drafting this prefix.  An unmeasured prefix drafts at
        k_max: optimism is how the EWMA gets its first sample.
        """
        if not self.spec_gate or k_max <= 0:
            return k_max
        a = self.spec_store.accept_rate(key)
        if a is None:
            return k_max
        c1 = self._step_cost.get((1, False))
        exp_tokens = 1.0
        gain = 1.0
        best = 0
        for k in range(1, k_max + 1):
            gain *= a
            exp_tokens += gain
            ck = self._step_cost.get((k + 1, True))
            ratio = (ck / c1 if c1 and ck
                     else 1.0 + self.spec_cost_slope * k)
            if exp_tokens >= ratio:
                best = k
        return best

    def _build_drafts(self, limit: int) -> Dict[int, List[int]]:
        """Host-side draft proposals for this step's generating slots,
        from the hot-prefix continuation store.  Drafted ONCE per hot
        prefix per step: slots at the same (prefix, context) reuse one
        lookup.  Never reads device state — the step keeps its single
        sync.  Caps keep drafts within the slot's page-table capacity
        and output budget (a draft past either is guaranteed waste);
        the accept-rate gate then shrinks or zeroes the draft length
        for prefixes whose measured accept rate can't pay for the wider
        verify lane."""
        out: Dict[int, List[int]] = {}
        if limit <= 0:
            return out
        memo: Dict[tuple, List[int]] = {}
        for slot, req in self.active.items():
            if self.pending_tokens[slot] or req._spec_key is None:
                continue
            key = req._spec_key
            k = min(limit, self.draft_len,
                    self.capacity - 1 - self._fed[slot],
                    req.max_new_tokens - len(req.out_tokens) - 1)
            if k <= 0:
                continue
            k_gated = self._gate_k(key, k)
            if k_gated <= 0:
                self.telemetry.inc("spec_gate_skips")
                continue
            suffix = tuple(req.prompt[len(key):]) + tuple(req.out_tokens)
            mk = (key, suffix, k_gated)
            if mk not in memo:
                memo[mk] = self.spec_store.draft(key, suffix, k_gated)
            if memo[mk]:
                out[slot] = memo[mk]
        return out

    def step(self) -> bool:
        """One engine step.  Returns True iff device work was
        dispatched (False = idle fast-path: admission ran but nothing
        is active, so the jitted step — and its sync — are skipped).

        The named ``_fire`` points are the chaos-injection phase
        boundaries (serving/chaos.py PHASES): ``feed`` fires BEFORE any
        per-slot feed mutation so a fault there leaves host and device
        consistent; ``post_sync`` fires after the device round-trip but
        before bookkeeping/journaling — a crash there loses this step's
        tokens and recovery must regenerate them."""
        t0 = time.perf_counter()
        self._fire("pre_tick")
        self.scheduler.tick(self)
        self._fire("post_admission")
        if not self.active:
            self.telemetry.inc("idle_steps")
            return False

        # schedule this step's lane widths (host-side bookkeeping only —
        # no device sync; prompt chunks come from host queues, decode
        # tokens from the device-resident last_tok register, draft
        # tokens from the continuation store).  The prefill width is the
        # scheduler's SLO-aware bucket choice; an all-decode step runs
        # width 1, widened to draft_len + 1 when drafts exist.
        any_prompt = any(self.pending_tokens[s] for s in self.active)
        T = self.scheduler.pick_chunk(self, self.chunk) if any_prompt else 1
        drafts: Dict[int, List[int]] = {}
        if self.spec_store is not None:
            if not any_prompt:
                drafts = self._build_drafts(self._spec_T - 1)
                if drafts:
                    T = self._spec_T
            elif T > 1:
                # drafts ride mixed prompt/decode steps too: projection
                # slimming made the spec variant's extra cost k + 1
                # gathered vocab rows per slot instead of a T-wide
                # projection, so a decode slot sharing a step with
                # prefill chunks no longer starves of speculation
                # (DESIGN.md §12; PR 5 restricted drafts to all-decode
                # steps precisely because of that T-wide cost)
                drafts = self._build_drafts(min(self._spec_T, T) - 1)
                if drafts:
                    self.telemetry.inc("spec_mixed_steps")
        self._fire("feed", rids={req.rid: slot
                                 for slot, req in self.active.items()})
        prompt_toks = np.zeros((self.dp, self.bl, T), np.int32)
        feed_lens = np.zeros((self.dp, self.bl), np.int32)
        is_prompt = np.zeros((self.dp, self.bl), bool)
        emit = np.zeros((self.dp, self.bl), bool)
        gen_slots: Dict[int, int] = {}       # slot -> drafts fed
        for slot, req in self.active.items():
            d, b = divmod(slot, self.bl)
            pend = self.pending_tokens[slot]
            if pend:
                # never feed past the page-table capacity — a slot that
                # reaches it finishes via the on-device length check
                n = min(len(pend), T, self.capacity - self._fed[slot])
                prompt_toks[d, b, :n] = pend[:n]
                del pend[:n]
                feed_lens[d, b] = n
                is_prompt[d, b] = True
                emit[d, b] = not pend
                self.telemetry.inc("prompt_tokens", n)
                self.tracer.instant("prefill_chunk", tid=req.rid,
                                    tokens=n, fed=self._fed[slot] + n)
                if (emit[d, b] and self.pins is not None
                        and slot not in self._pinned_slots
                        and self._fed[slot]
                        >= len(req.prompt) // self.cfg.page_size
                        * self.cfg.page_size):
                    # the prompt completes THIS step and its whole pages
                    # are ALREADY resident — `_fed` is read before this
                    # chunk is added, so the gate only passes when the
                    # chunk covers nothing but the partial tail: pin
                    # now, before dispatch — a request that finishes on
                    # this very step (max_new=1, instant EOS) releases
                    # in-device and could never pin after.  A prompt
                    # whose final whole page rides in THIS chunk pins on
                    # the post-status path below instead.
                    self._pinned_slots.add(slot)
                    self._maybe_pin(slot, list(req.prompt))
                self._fed[slot] += n
            else:
                dr = drafts.get(slot, [])
                if dr:
                    prompt_toks[d, b, 1:1 + len(dr)] = dr
                feed_lens[d, b] = 1 + len(dr)
                emit[d, b] = True
                # the KV the lane keeps (== tokens emitted) is only
                # known after verification: _fed advances on status read
                gen_slots[slot] = len(dr)

        spec = any(gen_slots.values())
        serve = self._serve_variants[(bool(self._sampling_slots), spec)]
        self.state, self.last_tok, self.out_count, status = serve(
            self.params, self.state, self.last_tok, self.out_count,
            self.budget, self.temps, self.topks, self.seeds,
            jnp.asarray(prompt_toks), jnp.asarray(feed_lens),
            jnp.asarray(is_prompt), jnp.asarray(emit), self.expert_mask)
        self.telemetry.inc("steps")
        self.telemetry.observe_hist("chunk_hist", T)
        self._fire("dispatched")
        status = np.asarray(status)      # the step's ONE device->host sync
        self._fire("post_sync")
        n_emit = status[T + STATUS_EMITTED]
        done_row = status[T + STATUS_DONE]
        pages_row = status[T + STATUS_PAGES]
        # device counter block: the N_CTR trailing rows (per-shard
        # values broadcast over Bl — column 0 is the value)
        ctr_block = status[T + 3:, :, 0]
        self.telemetry.absorb_counter_block(ctr_block)

        self.pages_used_shard = [int(x) for x in pages_row[:, 0]]
        pages_now = int(pages_row[:, 0].sum())
        self.telemetry.set_max("pages_peak", pages_now)
        self.telemetry.inc("pages_sum", pages_now)
        row = pages_row[:, 0].astype(np.int64)
        self._pages_shard_sum += row
        np.maximum(self._pages_shard_peak, row, out=self._pages_shard_peak)

        now = self._clock()
        psz = self.cfg.page_size
        for slot, req in list(self.active.items()):
            d, b = divmod(slot, self.bl)
            ne = int(n_emit[d, b])
            if ne:
                toks = [int(status[j, d, b]) for j in range(ne)]
                req.out_tokens.extend(toks)
                self._jrec("tokens", rid=req.rid, toks=toks)
                self.telemetry.inc("tokens_out", ne)
                if req.first_token_at == 0.0:
                    req.first_token_at = now
                    self._ft_latencies.append(now - req.submitted_at)
                    self.tracer.instant("first_token", tid=req.rid)
            if slot in gen_slots:
                k = gen_slots[slot]
                if k:
                    acc = max(ne - 1, 0)
                    self.telemetry.inc("spec_lanes")
                    self.telemetry.inc("spec_drafted", k)
                    self.telemetry.inc("spec_accepted", acc)
                    self.telemetry.observe_hist("accept_hist", acc)
                    self.tracer.instant("spec_accept", tid=req.rid,
                                        drafted=k, accepted=acc)
                    if req._spec_key is not None:
                        # feed the per-prefix accept-rate EWMA the gate
                        # reads (n_emit may be budget/EOS-truncated
                        # below the true accept count — a conservative
                        # under-estimate on the request's last step)
                        self.spec_store.observe(req._spec_key, k, acc)
                    # whole-page rollback accounting (host math on the
                    # _fed shadow — no extra sync): the lane fed 1 + k
                    # tokens but kept only ne
                    fed0 = self._fed[slot]
                    over = (-(-(fed0 + 1 + k) // psz)
                            - (-(-(fed0 + ne) // psz)))
                    self.telemetry.inc("spec_pages_rolled_back", over)
                    if over:
                        self.tracer.instant("spec_rollback",
                                            tid=req.rid, pages=over)
                self._fed[slot] += ne
            if done_row[d, b]:
                # pages were already released inside the jitted step
                req.done = True
                req.finished_at = now
                self._latencies.append(now - req.submitted_at)
                self.active.pop(slot)
                self._release_experts(slot)
                self.pending_tokens.pop(slot, None)
                self._pinned_slots.discard(slot)
                self._sampling_slots.discard(slot)
                if self.prefix_cache is not None:
                    self.prefix_cache.remove(slot)
                if self.spec_store is not None and req._spec_key:
                    # feed the continuation history: this finished
                    # stream is the next draft for its hot prefix
                    self.spec_store.record(
                        req._spec_key,
                        tuple(req.prompt[len(req._spec_key):])
                        + tuple(req.out_tokens))
                self._host_free_slot(slot)
                self.scheduler.on_released(slot)
                self.tracer.instant("finish", tid=req.rid,
                                    tokens=len(req.out_tokens))
                self._tr_end("active", req.rid)
                self._tr_end("request", req.rid)
                self._jrec("finish", rid=req.rid)
            else:
                if self.prefix_cache is not None:
                    # this step's feed is now in device KV: the slot can
                    # donate that much of its prompt to future admissions
                    self.prefix_cache.update_progress(slot, self._fed[slot])
                if (self.pins is not None
                        and slot not in self._pinned_slots
                        and not self.pending_tokens[slot]):
                    # prompt fully resident (this step's pages included —
                    # the pin runs AFTER the step that allocated them):
                    # retain its whole pages past the request's lifetime
                    self._pinned_slots.add(slot)
                    self._maybe_pin(slot, list(req.prompt))
        self._fire("post_step")
        # measured per-step cost model for the break-even gate: EWMA of
        # wall time keyed (lane width, spec).  The first dispatch at a
        # key pays jit compilation, so it is discarded — the second
        # sample seeds the EWMA.
        dt = time.perf_counter() - t0
        ck = (T, spec)
        if ck in self._cost_seen:
            prev = self._step_cost.get(ck)
            self._step_cost[ck] = dt if prev is None else (
                0.8 * prev + 0.2 * dt)
        else:
            self._cost_seen.add(ck)
        verdict = self.watchdog.observe(self.stats["steps"],
                                        time.perf_counter() - t0)
        if verdict == "straggler":
            self.telemetry.inc("stragglers")
        elif verdict == "timeout":
            self.telemetry.inc("step_timeouts")
        if verdict is not None:
            self.tracer.instant("watchdog", verdict=verdict,
                                step=self.stats["steps"])
        # flight recorder (DESIGN.md §13): ring-buffer this step's full
        # forensic record — the packed status (tokens + bookkeeping +
        # counter block), the gate decisions that shaped the dispatch,
        # and the watchdog verdict
        self.flight.record(
            step=self.stats["steps"], t=now, T=T, spec=spec,
            status=status.tolist(),
            ctr=ctr_block.tolist(),
            drafts={int(s): len(d) for s, d in drafts.items()},
            rids={int(s): int(r.rid) for s, r in self.active.items()},
            watchdog=verdict, dt_ms=round(dt * 1e3, 3))
        if verdict == "timeout" and self.flight.dump(
                "watchdog_timeout", {"step": self.stats["steps"]}):
            self.telemetry.inc("flight_dumps")
            self.tracer.instant("flight_dump", reason="watchdog_timeout")
        return True

    def idle(self) -> bool:
        """Nothing running and nothing admissible: the batch is empty
        and so is the scheduler backlog (rejected requests are terminal
        — they never hold ``run`` open; parked retries do)."""
        return not self.active and self.scheduler.backlog() == 0

    def run(self, max_steps: int = 10_000,
            max_restarts: Optional[int] = None) -> None:
        """Exception-safe driver (DESIGN.md §11).

        * :class:`~repro.serving.chaos.PoisonedRequest` fails exactly
          the offending request (bounded retry, then terminal
          ``rejected="poisoned"``) — everyone else keeps running;
        * :class:`~repro.serving.chaos.HostCrash` re-raises — host
          state is gone by definition and only
          :func:`~repro.serving.chaos.recover_engine` may rebuild it;
        * any other exception triggers an in-place recovery (requeue
          all active work, reconcile the pool) and, past the restart
          budget, re-raises AFTER recovering — so pool conservation
          holds even on the propagating path.
        """
        budget = self.max_restarts if max_restarts is None else max_restarts
        restarts = 0
        for _ in range(max_steps):
            if self.idle():
                break
            try:
                self.step()
            except PoisonedRequest as e:
                if e.slot in self.active:
                    self.fail_active(e.slot, "poisoned", retry=True)
            except HostCrash:
                # host state dies here by definition: the flight ring is
                # the crash's forensic record — dump it on the way out
                # (recover_engine re-dumps with the reconcile report)
                self.tracer.instant("crash", step=self.stats["steps"])
                if self.flight.dump("host_crash",
                                    {"step": self.stats["steps"]}):
                    self.telemetry.inc("flight_dumps")
                raise
            except Exception as e:
                restarts += 1
                if self.flight.dump("step_error",
                                    {"step": self.stats["steps"],
                                     "error": repr(e)}):
                    self.telemetry.inc("flight_dumps")
                    self.tracer.instant("flight_dump",
                                        reason="step_error")
                self._recover_inplace()
                if restarts > budget:
                    raise

    # ----------------------------------------------------- crash recovery
    def adopt_crashed_state(self, dead_state: DecodeState,
                            pin_np: Optional[np.ndarray]) -> dict:
        """Install a crashed engine's surviving device state (also the
        tail of :meth:`_recover_inplace`): keep the KV page content —
        pinned pages' data lives there — reconcile the pool against the
        trusted pin rows via :func:`hier_pool.audit_and_reconcile`, and
        clear every per-slot mapping and register (all in-flight work
        re-enters through the preemption-resume path).  Returns the
        reconcile report."""
        assert not self.active, "adopt with active slots"
        dp, bl, maxp = self.state.page_tables.shape
        C = len(dead_state.pool.classes)
        # pins live only in the KV class; every other class reconciles
        # against no keep rows (all grants belonged to requeued slots)
        pins = None if pin_np is None else tuple(
            [pin_np] + [None] * (C - 1))
        pool, report = classed_pool.audit_and_reconcile(
            dead_state.pool, keep_tables=None, pin_tables=pins)

        def zero(t):
            return jax.tree.map(jnp.zeros_like, t)

        state = dead_state._replace(
            pool=pool,
            page_tables=jnp.full((dp, bl, maxp), NULL, jnp.int32),
            seq_lens=jnp.zeros((dp, bl), jnp.int32),
            rings=zero(dead_state.rings), rec=zero(dead_state.rec))
        if dead_state.state_tables is not None:
            state = state._replace(state_tables=jnp.full_like(
                dead_state.state_tables, NULL))
        if dead_state.expert_tables is not None:
            # the reconcile passed no keep/pin rows for CLS_EXPERT, so
            # every expert page was reclaimed — NULL the tables, clear
            # the host ledger, and let the next admissions reload
            # (read-only weights re-materialize from the host store)
            state = state._replace(expert_tables={
                pos: jnp.full_like(tab, NULL)
                for pos, tab in dead_state.expert_tables.items()})
            if self.expert_ledger is not None:
                self.expert_ledger.clear()
            self._slot_experts.clear()
        if self.mesh is not None:
            state = jax.device_put(
                state, serve_shardings(self.mesh, self._pspecs))
        self.state = state
        self.last_tok, self.out_count, self.budget = \
            empty_serve_arrays(self.dp, self.bl)
        self.temps = jnp.zeros((self.dp, self.bl), jnp.float32)
        self.topks = jnp.zeros((self.dp, self.bl), jnp.int32)
        self.seeds = jnp.zeros((self.dp, self.bl), jnp.int32)
        self.expert_mask = jnp.ones_like(self.expert_mask)
        if self.pin_tables is not None:
            self.pin_tables = (jnp.asarray(pin_np) if pin_np is not None
                               else jnp.full_like(self.pin_tables, NULL))
        if self.mesh is not None:
            reg_ns = NamedSharding(self.mesh, self._rspec)
            (self.last_tok, self.out_count, self.budget, self.temps,
             self.topks, self.seeds, self.expert_mask) = jax.device_put(
                (self.last_tok, self.out_count, self.budget, self.temps,
                 self.topks, self.seeds, self.expert_mask), reg_ns)
            if self.pin_tables is not None:
                self.pin_tables = jax.device_put(self.pin_tables, reg_ns)
        self.pending_tokens.clear()
        self._fed.clear()
        self._pinned_slots.clear()
        self._sampling_slots.clear()
        # structured reconcile report through the tracer (DESIGN §13) —
        # recovery is never silent reconstruction
        self.tracer.instant(
            "reconcile",
            reclaimed=int(report.get("reclaimed", 0)),
            resurrected=int(report.get("resurrected", 0)),
            clamped=int(report.get("clamped", 0)),
            never_dry=bool(report.get("never_dry", True)),
            conserved=bool(report.get("conserved", True)))
        if self.flight.dump("audit_and_reconcile", {"report": report}):
            self.telemetry.inc("flight_dumps")
        return report

    def _recover_inplace(self) -> dict:
        """Restore a consistent engine after a failed step without
        losing the process: requeue every active request through the
        preemption path (host bookkeeping only — the device may be
        mid-operation, so per-slot release cannot be trusted) and
        rebuild the pool from the ledger-trusted pin rows.  The host
        survived, so the pin LEDGER is current; a device pin op whose
        ledger insert never ran is reclaimed, exactly as in the
        post-crash path."""
        self.telemetry.inc("recoveries")
        self.tracer.begin("recover", kind="inplace")
        for slot in list(self.active):
            req = self.active.pop(slot)
            # host bookkeeping only — the device is mid-operation, so
            # per-slot expert deref cannot be trusted; the reconcile
            # below reclaims every expert page regardless
            self._release_experts(slot, device=False)
            self.pending_tokens.pop(slot, None)
            self._fed.pop(slot, None)
            self._pinned_slots.discard(slot)
            self._sampling_slots.discard(slot)
            if self.prefix_cache is not None:
                self.prefix_cache.remove(slot)
            self._host_free_slot(slot)
            self.scheduler.on_released(slot)
            req.slot = None
            req.preemptions += 1
            self.telemetry.inc("preemptions")
            self.tracer.instant("preempt", tid=req.rid, slot=slot,
                                reason="recovery")
            self._tr_end("active", req.rid)
            self._jrec("preempt", rid=req.rid)
            self.scheduler.requeue_front(req)
        pin_np = None
        if self.pin_tables is not None:
            pin_np = np.asarray(self.pin_tables).copy()
            ok = np.zeros(pin_np.shape[:2], bool)
            for e in self.pins.entries.values():
                ok[e["shard"], e["row"]] = True
            pin_np[~ok] = NULL
        report = self.adopt_crashed_state(self.state, pin_np)
        self.tracer.end("recover")
        return report

    def leak_free(self) -> bool:
        """Zero live pages on every surviving shard (a dead shard's
        pages are unreachable by definition — they leave the accounting
        with the shard).  The post-drain + flush_pins invariant every
        chaos run closes with."""
        live = sum(np.asarray(classed_pool.live_per_shard(self.state.pool, c))
                   for c in range(self.n_classes))
        return all(int(live[s]) == 0 for s in range(self.dp)
                   if s not in self.lost_shards)

    # ------------------------------------------------------- warm restart
    def save_warm(self, ckptr, step: int = 0) -> None:
        """Persist the serving plane's warm state through the sharded
        checkpointer: the DecodeState (pool + pinned KV content), the
        device pin table, and a JSON sidecar with the host ledgers —
        pin entries, speculation streams, and still-queued requests.
        Must be called drained (no active slots): queued work requeues
        exactly, but a running slot's device KV is not snapshot-
        consistent with a host mid-step."""
        assert not self.active, "drain the engine before a warm save"
        ckptr.wait()
        payload = {"state": self.state}
        if self.pin_tables is not None:
            payload["pin_tables"] = self.pin_tables
        aux = {
            "pins": (self.pins.to_state() if self.pins is not None else []),
            "spec": (self.spec_store.to_state()
                     if self.spec_store is not None else None),
            "queued": [{
                "rid": int(r.rid),
                "prompt": [int(t) for t in r.prompt],
                "max_new_tokens": int(r.max_new_tokens),
                "temperature": float(r.temperature),
                "top_k": int(r.top_k), "seed": int(r.seed), "slo": r.slo,
                "out_tokens": [int(t) for t in r.out_tokens],
                "preemptions": int(r.preemptions),
                "deadline_at": float(r.deadline_at),
            } for r in self.scheduler.pending()],
        }
        ckptr.save(step, payload, aux=aux)

    def restore_warm(self, ckptr, step: Optional[int] = None) -> int:
        """Rebuild a freshly constructed engine from a warm save: adopt
        the device arrays (pool, pinned KV pages, pin table), reload
        the pin ledger + prefix-trie pin entries and the speculation
        store, and resubmit the queued requests.  The first post-
        restart hot-prefix request shares pinned pages and drafts
        without any re-prefill — the ROADMAP's warm-restart contract."""
        if step is None:
            step = ckptr.latest_step()
        assert step is not None, "no complete warm checkpoint to restore"
        like = {"state": self.state}
        if self.pin_tables is not None:
            like["pin_tables"] = self.pin_tables
        got = ckptr.restore(step, like)
        state = got["state"]
        if self.mesh is not None:
            state = jax.device_put(
                state, serve_shardings(self.mesh, self._pspecs))
        self.state = state
        if self.pin_tables is not None and "pin_tables" in got:
            self.pin_tables = got["pin_tables"]
            if self.mesh is not None:
                self.pin_tables = jax.device_put(
                    self.pin_tables, NamedSharding(self.mesh, self._rspec))
        aux = ckptr.restore_aux(step) or {}
        if self.pins is not None and aux.get("pins"):
            self.pins.load_state(aux["pins"])
            if self.prefix_cache is not None:
                for pid, e in self.pins.entries.items():
                    self.prefix_cache.pin_insert(pid, e["shard"],
                                                 list(e["tokens"]))
        if self.spec_store is not None and aux.get("spec"):
            self.spec_store.load_state(aux["spec"])
        for spec in aux.get("queued", []):
            req = Request(rid=int(spec["rid"]),
                          prompt=list(spec["prompt"]),
                          max_new_tokens=int(spec["max_new_tokens"]),
                          temperature=float(spec["temperature"]),
                          top_k=int(spec["top_k"]),
                          seed=int(spec["seed"]), slo=spec["slo"],
                          out_tokens=list(spec["out_tokens"]))
            req.preemptions = int(spec.get("preemptions", 0))
            req.deadline_at = float(spec.get("deadline_at", 0.0))
            self.submit(req)
        return step

    # ------------------------------------------------------------ metrics
    def blocks_in_use(self, cls: int = CLS_KV) -> int:
        """Blocks of one size class currently referenced across shards
        (shared pages count once)."""
        total = classed_pool.pages_local(self.state.pool, cls) * self.dp
        return total - int(hier_pool.total_free(
            classed_pool.cls_pool(self.state.pool, cls)))

    def pages_in_use(self) -> int:
        """Physical KV pages currently referenced (shared pages count
        once; includes cache-pinned pages — see :meth:`pinned_pages`).
        Coarse-class quantity; see :meth:`blocks_in_use` for the fine
        classes."""
        return self.blocks_in_use(CLS_KV)

    def page_occupancy(self) -> float:
        return self.pages_in_use() / (self.pages_local * self.dp)

    def pages_mean(self) -> float:
        """Mean pages-in-use per step (from the packed status row)."""
        return self.stats["pages_sum"] / max(self.stats["steps"], 1)

    def shard_occupancy(self) -> Dict[str, list]:
        """Per-shard pages-in-use statistics over the run (from the
        status row's PAGES entries — no extra sync): the mesh bench's
        load-balance axes, and the admission scheduler's placement
        quality in one place."""
        steps = max(self.stats["steps"], 1)
        return {
            "pages_mean_shard": [round(float(x) / steps, 1)
                                 for x in self._pages_shard_sum],
            "pages_peak_shard": [int(x) for x in self._pages_shard_peak],
            "mesh_devices": 0 if self.mesh is None else self.mesh.size,
        }

    def latency_quantiles(self) -> Dict[str, float]:
        """p50/p99 end-to-end and first-token latency (seconds) over
        finished requests — the overload bench's measured axes."""
        def q(xs, f):
            if not xs:
                return 0.0
            s = sorted(xs)
            return s[min(len(s) - 1, int(round(f * (len(s) - 1))))]
        return {"p50_s": q(self._latencies, 0.50),
                "p99_s": q(self._latencies, 0.99),
                "first_token_p50_s": q(self._ft_latencies, 0.50),
                "first_token_p99_s": q(self._ft_latencies, 0.99)}
