"""Continuous-batching serving engine on the paper's allocator.

Two allocator integrations (DESIGN.md §2b):

* **host (faithful)**: admission runs through the wait-free
  :class:`~repro.core.allocator.WaitFreeAllocator` — sequence *slots*
  are the fixed-size blocks, scheduler lanes are the processes.  Each
  admission/release is O(1) regardless of fleet size, so request
  scheduling never stalls behind a global lock (the paper's claim,
  live in the control plane).
* **device (SPMD)**: KV pages come from per-DP-shard private pools
  (block_pool inside serve_step) — one O(1) alloc per crossing
  sequence per step, exactly the private-pool fast path.

The engine is a continuous batcher: new requests are admitted into free
slots every step; prompts are streamed through the decode path (chunked
prefill would batch this further; see examples/serve_paged.py).
"""

from __future__ import annotations

import dataclasses
import itertools
import time
from collections import deque
from typing import Any, Deque, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .. import models
from ..core import NULL, SimContext, WaitFreeAllocator
from ..models.decode_init import empty_decode_state
from ..models.transformer import DecodeState


@dataclasses.dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new_tokens: int = 16
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    slot: Optional[int] = None
    done: bool = False
    submitted_at: float = 0.0
    finished_at: float = 0.0


def _release_slots(state: DecodeState, mask):
    """Jit-able: free all pages of masked slots, zero their state.

    mask: bool[DP, Bl].
    """
    dp, bl, maxp = state.page_tables.shape

    def free_shard(ids, top, table, m):
        # push freed page ids back onto the shard stack
        flat = jnp.where(m[:, None], table, NULL).reshape(-1)
        valid = flat >= 0
        rank = jnp.cumsum(valid.astype(jnp.int32)) * valid
        pos = jnp.where(valid, top + rank - 1, ids.shape[0])
        ids = ids.at[pos].set(flat, mode="drop")
        return ids, top + jnp.sum(valid.astype(jnp.int32))

    pool_ids, pool_top = jax.vmap(free_shard)(
        state.pool_ids, state.pool_top, state.page_tables, mask)
    page_tables = jnp.where(mask[:, :, None], NULL, state.page_tables)
    seq_lens = jnp.where(mask, 0, state.seq_lens)

    def zero_masked(tree):
        def f(a):
            if a.ndim >= 3 and a.shape[1] == dp and a.shape[2] == bl:
                m = mask.reshape((1, dp, bl) + (1,) * (a.ndim - 3))
                return jnp.where(m, jnp.zeros_like(a), a)
            return a
        return jax.tree.map(f, tree)

    rings = zero_masked(state.rings)
    rec = zero_masked(state.rec)
    return state._replace(page_tables=page_tables, seq_lens=seq_lens,
                          pool_ids=pool_ids, pool_top=pool_top,
                          rings=rings, rec=rec)


class ServingEngine:
    def __init__(self, cfg, params, dp: int = 1, b_local: int = 4,
                 max_len: int = 512, scheduler_lanes: int = 2,
                 greedy: bool = True):
        self.cfg = cfg
        self.params = params
        self.dp, self.bl = dp, b_local
        self.max_len = max_len
        self.state = empty_decode_state(cfg, dp, b_local, max_len)
        self.greedy = greedy

        self._decode = jax.jit(
            lambda p, t, s, a: models.decode_step(cfg, p, t, s, active=a),
            donate_argnums=(2,))
        self._release = jax.jit(_release_slots, donate_argnums=(0,))

        # host-side wait-free slot allocator: slots are fixed-size blocks.
        n_slots = dp * b_local
        self.lane_ctx = SimContext(scheduler_lanes, seed=0)
        self.slot_alloc = WaitFreeAllocator(
            self.lane_ctx, ell=max(3 * scheduler_lanes, 4),
            shared_batches=max(2, n_slots), allow_os_growth=True)
        # bind allocator block ids <-> engine slots (first n_slots blocks)
        self._slot_of_block: Dict[int, int] = {}
        self._block_of_slot: Dict[int, int] = {}
        self._free_slots = deque(range(n_slots))
        self.lanes = itertools.cycle(range(scheduler_lanes))

        self.queue: Deque[Request] = deque()
        self.active: Dict[int, Request] = {}     # slot -> request
        self.pending_tokens: Dict[int, List[int]] = {}
        self.stats = {"steps": 0, "tokens_out": 0, "admitted": 0,
                      "alloc_steps_max": 0}

    # ------------------------------------------------------------ control
    def _host_alloc_slot(self) -> Optional[int]:
        """O(1) wait-free admission through the paper's allocator."""
        if not self._free_slots:
            return None
        lane = next(self.lanes)
        gen = self.slot_alloc.allocate(lane)
        try:
            while True:
                next(gen)
        except StopIteration as e:
            block = e.value
        op = self.lane_ctx.history[-1]
        self.stats["alloc_steps_max"] = max(
            self.stats["alloc_steps_max"], op.steps)
        slot = self._free_slots.popleft()
        self._slot_of_block[block] = slot
        self._block_of_slot[slot] = block
        return slot

    def _host_free_slot(self, slot: int) -> None:
        lane = next(self.lanes)
        block = self._block_of_slot.pop(slot)
        self._slot_of_block.pop(block)
        gen = self.slot_alloc.free(lane, block)
        try:
            while True:
                next(gen)
        except StopIteration:
            pass
        self._free_slots.append(slot)

    def submit(self, req: Request) -> None:
        req.submitted_at = time.time()
        self.queue.append(req)

    # -------------------------------------------------------------- step
    def step(self) -> None:
        # 1. admission
        while self.queue and self._free_slots:
            slot = self._host_alloc_slot()
            if slot is None:
                break
            req = self.queue.popleft()
            req.slot = slot
            self.active[slot] = req
            self.pending_tokens[slot] = list(req.prompt)
            self.stats["admitted"] += 1

        # 2. one decode step for every active slot
        tokens = np.zeros((self.dp, self.bl), np.int32)
        active = np.zeros((self.dp, self.bl), bool)
        feeding = {}
        for slot, req in self.active.items():
            d, b = divmod(slot, self.bl)
            pend = self.pending_tokens[slot]
            if pend:
                tok = pend.pop(0)
                feeding[slot] = ("prompt", tok)
            else:
                tok = req.out_tokens[-1] if req.out_tokens else 1
                feeding[slot] = ("gen", tok)
            tokens[d, b] = tok
            active[d, b] = True
        if not feeding:
            return
        logits, self.state = self._decode(
            self.params, jnp.asarray(tokens), self.state, jnp.asarray(active))
        self.stats["steps"] += 1
        nxt = np.asarray(jnp.argmax(logits, axis=-1))

        # 3. collect outputs / completions
        finished = []
        for slot, req in list(self.active.items()):
            d, b = divmod(slot, self.bl)
            kind, _ = feeding[slot]
            if kind == "gen" or not self.pending_tokens[slot]:
                req.out_tokens.append(int(nxt[d, b]))
                self.stats["tokens_out"] += 1
            full = int(np.asarray(self.state.seq_lens)[d, b]) >= self.max_len - 1
            if len(req.out_tokens) >= req.max_new_tokens or full:
                finished.append(slot)
        if finished:
            mask = np.zeros((self.dp, self.bl), bool)
            for slot in finished:
                d, b = divmod(slot, self.bl)
                mask[d, b] = True
                req = self.active.pop(slot)
                req.done = True
                req.finished_at = time.time()
                self.pending_tokens.pop(slot, None)
                self._host_free_slot(slot)
            self.state = self._release(self.state, jnp.asarray(mask))

    def run(self, max_steps: int = 10_000) -> None:
        for _ in range(max_steps):
            if not self.queue and not self.active:
                break
            self.step()

    # ------------------------------------------------------------ metrics
    def page_occupancy(self) -> float:
        total = self.state.pool_ids.shape[1] * self.dp
        free = int(jnp.sum(self.state.pool_top))
        return 1.0 - free / total
