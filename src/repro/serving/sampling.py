"""On-device sampling: temperature + top-k, keyed per request.

Replaces the engine's hardcoded greedy ``jnp.argmax`` inside the jitted
serve step (DESIGN.md §6 step 4) without adding a device→host sync: the
sampler reads three small per-slot registers (temperature, top-k,
seed) that the host writes once at admission, exactly like
``budget``/``out_count``.

Determinism contract: the noise for a slot's i-th output token is a
pure function of ``(seed, i)`` — ``fold_in(fold_in(key0, seed), i)`` —
never of the slot index or the step number.  Two consequences the
scheduler relies on (DESIGN.md §8):

* the same request replayed on any slot, any batch composition, any
  chunk size draws the same tokens;
* a request preempted after k tokens and re-prefilled elsewhere resumes
  at ``out_count == k`` and therefore draws token k+1 from the same key
  it would have used unpreempted — preemption is invisible in sampled
  output, not just greedy output;
* a speculative draft lane (:func:`sample_lane`) scores position i with
  key ``out_count + i``, and acceptance/rollback consume key indices in
  order without skips — speculation is invisible in sampled output too
  (DESIGN.md §10; all-rejected lanes draw exactly the one key the plain
  step would).

``temperature <= 0`` short-circuits to plain argmax, bit-identical to
the pre-sampler engine (the default: every existing token-identity test
runs through this path unchanged).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def sample_tokens(logits: jax.Array, temp: jax.Array, top_k: jax.Array,
                  seeds: jax.Array, counts: jax.Array) -> jax.Array:
    """Sample one token per slot from [DP, Bl, V] logits.

    temp: f32[DP, Bl] (<= 0 → greedy argmax for that slot);
    top_k: int32[DP, Bl] (0 → full vocabulary);
    seeds: int32[DP, Bl] per-request RNG seeds;
    counts: int32[DP, Bl] tokens emitted so far (the fold-in position).
    Returns int32[DP, Bl].  Fixed-shape throughout — jit-safe inside
    the serve step; O(Bl·V log V) for the top-k sort, independent of
    the pool and page-table sizes.
    """
    logits = logits.astype(jnp.float32)
    V = logits.shape[-1]
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)

    # top-k: threshold at the k-th largest logit per slot (k = 0 or
    # >= V disables the mask; clip keeps the gather in range)
    k = jnp.clip(top_k, 1, V)
    srt = jnp.sort(logits, axis=-1)                   # ascending
    kth = jnp.take_along_axis(srt, (V - k)[..., None], axis=-1)
    cut = (top_k > 0)[..., None] & (logits < kth)
    masked = jnp.where(cut, -jnp.inf, logits)

    # Gumbel-max: argmax(logits/T + g) ~ softmax(logits/T), one key per
    # (request seed, output position)
    def draw(seed, cnt):
        key = jax.random.fold_in(
            jax.random.fold_in(jax.random.PRNGKey(0), seed), cnt)
        return jax.random.gumbel(key, (V,), dtype=jnp.float32)

    g = jax.vmap(jax.vmap(draw))(seeds, counts)
    t = jnp.maximum(temp, 1e-6)[..., None]
    sampled = jnp.argmax(masked / t + g, axis=-1).astype(jnp.int32)
    return jnp.where(temp > 0, sampled, greedy)


def sample_lane(logits: jax.Array, temp: jax.Array, top_k: jax.Array,
                seeds: jax.Array, counts: jax.Array) -> jax.Array:
    """Sample one token per lane position from [DP, Bl, T, V] logits.

    The speculative token-lane form of :func:`sample_tokens`: position
    i of a slot's lane is that slot's candidate i-th *output* token, so
    it draws with key ``fold_in(fold_in(key0, seed), counts[..., i])``
    where the caller passes ``counts[..., i] = out_count + i`` for
    draft/verify lanes (and a constant ``out_count`` for prefill lanes,
    whose single emitting position is output index 0).  The key stream
    is therefore EXACTLY the stream one-token-at-a-time decode draws
    from — acceptance/rollback never skips or reuses an index, which is
    what makes speculative sampling bit-identical to the
    non-speculative run (DESIGN.md §10).
    """
    return jax.vmap(sample_tokens, in_axes=(2, None, None, None, 2),
                    out_axes=2)(logits, temp, top_k, seeds, counts)
