"""Request-lifecycle tracer: chrome-trace / JSONL span export.

Every externally visible event in a request's life — submit, admit,
prefill chunk, first token, preempt, resume, pin, share, spec-accept,
finish — is recorded as a span or instant with a monotonic host
timestamp.  The buffer is bounded (drops are counted, never blocking),
and exports either as chrome-trace JSON (``trace_event`` format —
loadable in Perfetto / chrome://tracing) or as JSONL for ad-hoc
analysis.  The span taxonomy is documented in DESIGN.md §13.

Span model: ``pid`` is constant 0 (one engine process), ``tid`` is the
request id, so Perfetto renders one row per request with its "request"
(queued+active) and nested "active" (slot residency) spans; scheduler-
and recovery-level events use the reserved ``tid`` = -1 engine row.
"""

from __future__ import annotations

import json
import time
from collections import deque
from typing import Optional

#: tid for engine-level (not per-request) events
ENGINE_TID = -1

#: the span/instant taxonomy (DESIGN.md §13) — names outside this set
#: raise, keeping the trace vocabulary closed and greppable.
SPAN_NAMES = frozenset({
    "request",        # B submit .. E finish/terminal-fail
    "active",         # B admit .. E preempt/finish/fail (slot residency)
    "step",           # engine step span (engine row, sampled)
    "recover",        # recovery / reconcile window (engine row)
})
INSTANT_NAMES = frozenset({
    "submit", "admit", "resume", "prefill_chunk", "first_token",
    "preempt", "pin", "unpin", "pin_hit", "share", "cow_copy",
    "spec_accept", "spec_rollback", "finish", "reject", "defer",
    "fail", "retry", "deadline_expired", "shed",
    "watchdog", "crash", "reconcile", "flight_dump", "shard_loss",
})


class Tracer:
    """Bounded in-memory trace buffer with chrome-trace export."""

    def __init__(self, capacity: int = 200_000, enabled: bool = True,
                 clock=time.perf_counter):
        self.enabled = enabled
        self.capacity = int(capacity)
        self.events: deque = deque(maxlen=self.capacity)
        self.dropped = 0
        self._clock = clock
        self._t0 = clock()
        self._open: dict = {}       # (name, tid) -> open-span depth

    def _ts_us(self) -> float:
        return (self._clock() - self._t0) * 1e6

    def _push(self, ev: dict) -> None:
        if len(self.events) == self.capacity:
            self.dropped += 1
        self.events.append(ev)

    # ------------------------------------------------------------- emits
    def is_open(self, name: str, tid: int = ENGINE_TID) -> bool:
        """Whether a ``begin(name, tid)`` has no matching end yet — the
        engine's idempotence guard for spans that may re-enter through
        requeue/resubmit paths (crash recovery, warm restart)."""
        return self._open.get((name, int(tid)), 0) > 0

    def begin(self, name: str, tid: int = ENGINE_TID, **args) -> None:
        if not self.enabled:
            return
        assert name in SPAN_NAMES, f"unknown span {name!r}"
        key = (name, int(tid))
        self._open[key] = self._open.get(key, 0) + 1
        self._push({"name": name, "ph": "B", "ts": self._ts_us(),
                    "pid": 0, "tid": int(tid), "args": args})

    def end(self, name: str, tid: int = ENGINE_TID, **args) -> None:
        if not self.enabled:
            return
        assert name in SPAN_NAMES, f"unknown span {name!r}"
        key = (name, int(tid))
        self._open[key] = max(self._open.get(key, 0) - 1, 0)
        self._push({"name": name, "ph": "E", "ts": self._ts_us(),
                    "pid": 0, "tid": int(tid), "args": args})

    def instant(self, name: str, tid: int = ENGINE_TID, **args) -> None:
        if not self.enabled:
            return
        assert name in INSTANT_NAMES, f"unknown instant {name!r}"
        self._push({"name": name, "ph": "i", "ts": self._ts_us(),
                    "pid": 0, "tid": int(tid), "s": "t", "args": args})

    # ----------------------------------------------------------- exports
    def to_chrome(self) -> dict:
        """The chrome-trace JSON object (trace_event format)."""
        return {"traceEvents": list(self.events),
                "displayTimeUnit": "ms",
                "otherData": {"dropped": self.dropped}}

    def write_chrome(self, path: str) -> str:
        with open(path, "w") as fh:
            json.dump(self.to_chrome(), fh)
        return path

    def write_jsonl(self, path: str) -> str:
        with open(path, "w") as fh:
            for ev in self.events:
                fh.write(json.dumps(ev) + "\n")
        return path


def validate_chrome(doc: dict) -> None:
    """Assert a chrome-trace document is schema-valid and that B/E
    spans nest correctly per (pid, tid) row.  Used by the tests and the
    CI obs-smoke check; raises AssertionError with a specific message
    on the first violation."""
    assert isinstance(doc, dict) and "traceEvents" in doc
    stacks: dict = {}
    last_ts: Optional[float] = None
    for ev in doc["traceEvents"]:
        for key in ("name", "ph", "ts", "pid", "tid"):
            assert key in ev, f"event missing {key!r}: {ev}"
        assert ev["ph"] in ("B", "E", "i", "X"), ev["ph"]
        assert isinstance(ev["ts"], (int, float)) and ev["ts"] >= 0
        if last_ts is not None:
            assert ev["ts"] >= last_ts, "timestamps not monotonic"
        last_ts = ev["ts"]
        key = (ev["pid"], ev["tid"])
        stack = stacks.setdefault(key, [])
        if ev["ph"] == "B":
            stack.append(ev["name"])
        elif ev["ph"] == "E":
            assert stack, f"E {ev['name']!r} with empty stack on {key}"
            top = stack.pop()
            assert top == ev["name"], (
                f"mis-nested span on {key}: E {ev['name']!r} closes "
                f"B {top!r}")
    for key, stack in stacks.items():
        assert not stack, f"unclosed spans on {key}: {stack}"
