"""One-sync observability plane: typed counters, device counter block,
Prometheus exposition, and the crash flight recorder (DESIGN.md §13).

The paper's guarantees are quantitative — O(1) worst-case per op and
the §4.2 never-dry invariant ``min(private_top) >= ell`` — so the
serving plane treats the *margin* on those invariants as first-class
observable state, the way production allocators expose occupancy and
fragmentation.  Three pieces:

* :class:`Telemetry` — the single facade every host-side subsystem
  (engine, scheduler, prefix cache, chaos/recovery) emits through.
  Scalar counters live in a typed schema (:data:`COUNTER_SCHEMA`;
  unknown names raise), histograms in :data:`HIST_SCHEMA`, and the
  per-shard device counters in numpy accumulators.  ``counters`` is a
  plain dict so ``engine.stats`` can remain a live, backward-compatible
  view of it.

* the **device counter block** — a small int32 ``[N_CTR, DP]`` block
  computed *inside* the jitted serve step from allocator state the
  step already holds (pool free levels before/after the forward pass,
  the rollback mask, the drain/refill deltas, the post-rebalance lane
  floors) and harvested by widening the packed status rows the host
  already syncs on.  Zero extra transfers, zero extra collectives: the
  block rides the same status all_gather (DESIGN.md §13 zero-sync
  argument).  :meth:`Telemetry.absorb_counter_block` accumulates it
  host-side after the step's one ``np.asarray``.

* :class:`FlightRecorder` — a bounded ring of the last N step records
  (status rows, counter block, gate decisions, watchdog verdicts) that
  dumps to disk on crash / watchdog timeout / ``audit_and_reconcile``,
  giving the §11 recovery path a forensic artifact.  Dumps are atomic
  (temp + rename) and optionally periodic, so even a SIGKILLed process
  leaves a readable record behind.
"""

from __future__ import annotations

import json
import os
import signal
import time
from collections import deque
from typing import Dict, Optional

import numpy as np

# --------------------------------------------------- device counter block
#
# Row layout of the int32[N_CTR, DP] block the jitted serve step appends
# to the packed status (after the T token rows and the emitted/done/
# pages bookkeeping rows).  Each row holds one per-shard value,
# broadcast over the Bl axis exactly like the PAGES row, so the block
# crosses shards inside the step's single status all_gather.
#
# Counters (host sums across steps):
CTR_ALLOC = 0        # pages granted by this step's forward pass
CTR_FREED = 1        # pages returned free this step (release + rollback)
CTR_ROLLBACK = 2     # spec whole-page rollback (subset of CTR_FREED)
CTR_DRAIN = 3        # pages drained lane -> shared by this rebalance
CTR_REFILL = 4       # pages refilled shared -> lane by this rebalance
CTR_SPILL = 5        # released pages that overflowed a full lane stack
#                      and landed on the SHARED stack (free_n_metered) —
#                      the row that makes the shared-free telescoping
#                      shared' - shared == drain - refill + spill EXACT
# Gauges (host min-accumulates across steps):
CTR_SHARED_FREE = 6  # shared free-stack size after the step (low-water)
CTR_MARGIN = 7       # §4.2 never-dry margin min(private_top) - ell
# Expert-paged MoE rows (DESIGN.md §15).  The rows exist in every
# class's block (the block stays class-major rectangular); the engine
# emits the page rows on the expert class (`_c2` keys) and the drop row
# on class 0 (unsuffixed key) so non-paged MoE engines meter drops too:
CTR_EHIT = 8         # expert pages routed-to AND resident this step
CTR_EMISS = 9        # expert pages routed-to but NOT resident — the
#                      admission contract makes this an invariant 0;
#                      any nonzero is a residency bug detector
CTR_EPREF = 10       # expert pages prefetched one layer ahead
CTR_EDROP = 11       # MoE capacity-overflow dropped valid token slots
N_CTR = 12

#: counter-block row names, index-aligned with the CTR_* constants
CTR_NAMES = ("alloc_pages", "freed_pages", "spec_rollback_pages",
             "rebalance_drain_pages", "rebalance_refill_pages",
             "spill_pages", "shared_free", "never_dry_margin",
             "expert_hit_pages", "expert_miss_pages",
             "expert_prefetch_pages", "moe_dropped_tokens")
#: which rows accumulate by summation (the rest are min-gauges)
CTR_SUM_ROWS = (CTR_ALLOC, CTR_FREED, CTR_ROLLBACK, CTR_DRAIN, CTR_REFILL,
                CTR_SPILL, CTR_EHIT, CTR_EMISS, CTR_EPREF, CTR_EDROP)
CTR_MIN_ROWS = (CTR_SHARED_FREE, CTR_MARGIN)


def ctr_key(row: int, cls: int = 0) -> str:
    """Accumulator key for counter-block row ``row`` of size class
    ``cls``.  Class 0 keeps the historical un-suffixed names (single-
    class snapshots stay bit-identical); class c >= 1 suffixes ``_c<c>``
    — the telemetry class axis of DESIGN.md §14."""
    name = CTR_NAMES[row]
    return name if cls == 0 else f"{name}_c{cls}"


# -------------------------------------------------------- counter schema
#
# Every scalar counter any subsystem may emit.  The engine's historical
# ``stats`` keys are all here (engine.stats is a live view of
# Telemetry.counters), plus the observability plane's own counters and
# the scheduler/prefix-cache mirrors.  `max`-kind counters keep a
# high-water instead of a running sum.

COUNTER_SCHEMA: Dict[str, str] = {
    # engine serving counters (pre-existing stats keys)
    "steps": "dispatched engine steps",
    "tokens_out": "generated tokens emitted",
    "admitted": "requests admitted to a slot",
    "prompt_tokens": "prompt tokens prefilled",
    "alloc_steps_max": "worst-case host allocator op steps (O(1) bound)",
    "prefix_shared_tokens": "prompt tokens mapped onto donor pages",
    "prefix_shared_reqs": "requests admitted with a shared prefix",
    "pages_peak": "peak pages-in-use across shards",
    "pages_sum": "sum of per-step pages-in-use (mean = /steps)",
    "idle_steps": "steps skipped on the idle fast-path",
    "preemptions": "requests preempted",
    "pins_created": "prefix pins created",
    "pin_hit_reqs": "admissions served from a pinned prefix",
    "pin_hit_tokens": "prompt tokens served from pinned pages",
    "spec_drafted": "speculative tokens drafted",
    "spec_accepted": "speculative tokens accepted",
    "spec_lanes": "draft+verify lanes dispatched",
    "spec_pages_rolled_back": "whole pages rolled back off rejected drafts",
    "spec_gate_skips": "draft proposals zeroed by the accept-rate gate",
    "spec_mixed_steps": "mixed prompt/decode steps carrying drafts",
    "stragglers": "steps classified straggler by the watchdog",
    "step_timeouts": "steps past the watchdog hard timeout",
    "recoveries": "in-place engine recoveries",
    "deadline_expired": "requests failed on an expired deadline",
    "failed": "requests terminally failed (typed reason)",
    "retries": "bounded-backoff retries granted",
    "shards_lost": "shards retired from service",
    # observability plane
    "cow_copies": "copy-on-write page copies at share admission",
    "flight_dumps": "flight-recorder dumps written",
    "trace_drops": "trace events dropped by the bounded buffer",
    # scheduler mirrors (AdmissionScheduler emits through the facade)
    "sched_deferred": "head-of-line admissions deferred",
    "sched_defer_slots": "deferrals blocked on a free slot",
    "sched_defer_pages": "deferrals blocked on the page budget",
    "sched_rejected": "submissions rejected with backpressure",
    "sched_retried": "parked retries re-queued",
    "sched_shed": "requests shed under degraded capacity",
    "sched_pins_evicted": "pins evicted by scheduler policy",
    # prefix-cache mirrors
    "trie_hits": "prefix-trie lookups that found a donor",
    "trie_misses": "prefix-trie lookups that found nothing",
    # size-classed allocation plane (DESIGN.md §14)
    "state_blocks_granted": "bounded-state blocks granted at admission",
    # expert-paged MoE serving (DESIGN.md §15)
    "expert_admit_hits": "footprint experts already resident at admission",
    "expert_admit_misses": "footprint experts loaded cold at admission",
    "expert_load_pages": "expert pages loaded into the pool (3/expert)",
    "expert_evictions": "experts evicted from the ledger LRU",
    "expert_evict_pages": "expert pages freed by ledger eviction",
    "expert_pages_resident_peak": "peak expert pages resident (ledger)",
    "sched_defer_experts": "deferrals blocked on the expert-page budget",
}

#: counters that keep a running max instead of a sum
MAX_COUNTERS = ("alloc_steps_max", "pages_peak",
                "expert_pages_resident_peak")

HIST_SCHEMA = ("chunk_hist", "accept_hist")


def _jsonable(x):
    if isinstance(x, np.ndarray):
        return x.tolist()
    if isinstance(x, (np.integer,)):
        return int(x)
    if isinstance(x, (np.floating,)):
        return float(x)
    if isinstance(x, dict):
        return {str(k): _jsonable(v) for k, v in x.items()}
    if isinstance(x, (list, tuple)):
        return [_jsonable(v) for v in x]
    return x


class Telemetry:
    """The one facade host subsystems emit through.

    ``counters`` is a plain dict (typed: :meth:`inc` validates names
    against :data:`COUNTER_SCHEMA`) — the engine exposes it verbatim as
    the backward-compatible ``engine.stats`` view, histograms included.
    Per-shard device counters accumulate in numpy from the counter
    block the jitted step appends to the status rows.
    """

    def __init__(self, dp: int = 1, tracer=None,
                 flight: Optional["FlightRecorder"] = None,
                 n_classes: int = 1):
        self.dp = int(dp)
        self.n_classes = int(n_classes)
        self.counters: Dict = {name: 0 for name in COUNTER_SCHEMA}
        for h in HIST_SCHEMA:
            self.counters[h] = {}
        # per-shard sums from the device counter block, one set of rows
        # per size class (class 0 keeps the historical key names)
        self.shard = {ctr_key(r, c): np.zeros(self.dp, np.int64)
                      for c in range(self.n_classes) for r in CTR_SUM_ROWS}
        # per-shard min-gauges (low-water marks); None until first step
        self.low: Dict[str, Optional[np.ndarray]] = {
            ctr_key(r, c): None
            for c in range(self.n_classes) for r in CTR_MIN_ROWS}
        self.last_block: Optional[np.ndarray] = None
        if tracer is None:
            from .trace import Tracer
            tracer = Tracer(enabled=False)
        self.tracer = tracer
        self.flight = flight

    # ------------------------------------------------------ typed emits
    def inc(self, name: str, n: int = 1) -> None:
        if name not in COUNTER_SCHEMA:
            raise KeyError(f"unknown telemetry counter {name!r}")
        self.counters[name] += n

    def set_max(self, name: str, v: int) -> None:
        if name not in COUNTER_SCHEMA:
            raise KeyError(f"unknown telemetry counter {name!r}")
        if v > self.counters[name]:
            self.counters[name] = v

    def observe_hist(self, name: str, key, n: int = 1) -> None:
        if name not in HIST_SCHEMA:
            raise KeyError(f"unknown telemetry histogram {name!r}")
        h = self.counters[name]
        h[key] = h.get(key, 0) + n

    # ------------------------------------------------ device counter block
    def absorb_counter_block(self, block) -> None:
        """Accumulate one step's int32[n_classes*N_CTR, DP] counter
        block (already host-side — sliced off the packed status after
        the step's one sync).  Rows are class-major: class c's N_CTR
        rows start at ``c * N_CTR``."""
        blk = np.asarray(block, np.int64)
        assert blk.shape == (self.n_classes * N_CTR, self.dp), blk.shape
        for c in range(self.n_classes):
            base = c * N_CTR
            for r in CTR_SUM_ROWS:
                self.shard[ctr_key(r, c)] += blk[base + r]
            for r in CTR_MIN_ROWS:
                name = ctr_key(r, c)
                cur = self.low[name]
                self.low[name] = (blk[base + r].copy() if cur is None
                                  else np.minimum(cur, blk[base + r]))
        self.last_block = blk

    def never_dry_margin_min(self, cls: Optional[int] = None
                             ) -> Optional[int]:
        """Worst §4.2 margin seen on any shard at any step (>= 0 means
        the never-dry invariant held with that much slack to spare).
        Default: min over ALL classes — the invariant is per class, so
        the worst class bounds the pool vector; pass ``cls`` for one."""
        classes = range(self.n_classes) if cls is None else (cls,)
        vals = [self.low[ctr_key(CTR_MARGIN, c)] for c in classes]
        vals = [v for v in vals if v is not None]
        return None if not vals else int(min(v.min() for v in vals))

    def shared_low_water(self, cls: int = 0) -> Optional[int]:
        m = self.low[ctr_key(CTR_SHARED_FREE, cls)]
        return None if m is None else int(m.min())

    def expert_hit_rate(self) -> Optional[float]:
        """Admission-time expert residency hit rate (None before any
        MoE admission).  Derived from the host admission counters, not
        the in-step CTR_EMISS row — that row is an invariant detector
        (residency is guaranteed by admission, so it must stay 0)."""
        h = self.counters["expert_admit_hits"]
        m = self.counters["expert_admit_misses"]
        return None if h + m == 0 else h / (h + m)

    # ------------------------------------------------------------ exports
    def snapshot(self) -> dict:
        """JSON-ready snapshot: scalar counters, histograms, per-shard
        device-counter sums, and the invariant low-water gauges.  What
        the benches embed in BENCH_serving.json."""
        scalars = {k: v for k, v in self.counters.items()
                   if k not in HIST_SCHEMA}
        hists = {k: {str(b): c for b, c in sorted(self.counters[k].items())}
                 for k in HIST_SCHEMA}
        return {
            "counters": scalars,
            "hists": hists,
            "per_shard": {k: v.tolist() for k, v in self.shard.items()},
            "low_water": {k: (None if v is None else v.tolist())
                          for k, v in self.low.items()},
            "never_dry_margin_min": self.never_dry_margin_min(),
            "shared_free_low_water": self.shared_low_water(),
            "expert_hit_rate": self.expert_hit_rate(),
        }

    def render_prom(self, prefix: str = "repro") -> str:
        """Prometheus text exposition (one scrape-shaped snapshot)."""
        lines = []

        def emit(name, help_, kind, samples):
            lines.append(f"# HELP {prefix}_{name} {help_}")
            lines.append(f"# TYPE {prefix}_{name} {kind}")
            for labels, val in samples:
                lab = ("{" + ",".join(f'{k}="{v}"' for k, v in labels)
                       + "}") if labels else ""
                lines.append(f"{prefix}_{name}{lab} {val}")

        for name, help_ in COUNTER_SCHEMA.items():
            kind = "gauge" if name in MAX_COUNTERS else "counter"
            emit(name, help_, kind, [((), self.counters[name])])
        for h in HIST_SCHEMA:
            emit(h, f"{h} buckets", "counter",
                 [((("bucket", b),), c)
                  for b, c in sorted(self.counters[h].items())])
        for c in range(self.n_classes):
            for r in CTR_SUM_ROWS:
                name = ctr_key(r, c)
                emit(name, f"device counter block: {name}", "counter",
                     [((("shard", s),), int(v))
                      for s, v in enumerate(self.shard[name])])
            for r in CTR_MIN_ROWS:
                name = ctr_key(r, c) + "_min"
                vals = self.low[ctr_key(r, c)]
                if vals is not None:
                    emit(name, f"low-water gauge: {name}", "gauge",
                         [((("shard", s),), int(v))
                          for s, v in enumerate(vals)])
        m = self.never_dry_margin_min()
        if m is not None:
            emit("never_dry_margin_min_all", "worst §4.2 margin, any "
                 "shard any step", "gauge", [((), m)])
        r = self.expert_hit_rate()
        if r is not None:
            emit("expert_hit_rate", "fraction of footprint experts "
                 "already resident at admission", "gauge",
                 [((), round(r, 6))])
        return "\n".join(lines) + "\n"


def parse_prom(text: str) -> Dict[str, Dict[tuple, float]]:
    """Minimal Prometheus text-format parser (the CI smoke check and
    the tests round-trip :meth:`Telemetry.render_prom` through it).
    Returns {metric: {labels_tuple: value}}."""
    out: Dict[str, Dict[tuple, float]] = {}
    for line in text.splitlines():
        line = line.strip()
        if not line or line.startswith("#"):
            continue
        body, val = line.rsplit(" ", 1)
        if "{" in body:
            name, rest = body.split("{", 1)
            assert rest.endswith("}"), f"malformed sample: {line!r}"
            labels = []
            for pair in filter(None, rest[:-1].split(",")):
                k, v = pair.split("=", 1)
                assert v.startswith('"') and v.endswith('"'), line
                labels.append((k, v[1:-1]))
            key = tuple(labels)
        else:
            name, key = body, ()
        out.setdefault(name, {})[key] = float(val)
    return out


# --------------------------------------------------------- flight recorder


class FlightRecorder:
    """Bounded ring of the last ``capacity`` step records, dumped to
    disk when something goes wrong.

    Each record is whatever the engine hands :meth:`record` — by
    convention the packed status rows, the counter block, the step's
    gate decisions, and the watchdog verdict.  ``dump`` writes the ring
    atomically (temp + rename, the checkpointer's discipline) with a
    typed reason; with ``sync_every`` set the recorder also dumps
    periodically, so a force-killed process (SIGKILL — no handler runs)
    still leaves its most recent window on disk.
    """

    def __init__(self, capacity: int = 64, path: Optional[str] = None,
                 sync_every: int = 0):
        self.capacity = int(capacity)
        self.ring: deque = deque(maxlen=self.capacity)
        self.path = path
        self.sync_every = int(sync_every)
        self.dumps = 0
        self._since_sync = 0
        self.meta: dict = {}

    def record(self, **rec) -> None:
        self.ring.append(rec)
        if self.sync_every and self.path:
            self._since_sync += 1
            if self._since_sync >= self.sync_every:
                self.dump("periodic")

    def adopt(self, other: "FlightRecorder") -> None:
        """Carry a crashed engine's ring (and path) into the recovered
        engine — the forensic window survives the recovery."""
        for rec in other.ring:
            self.ring.append(rec)
        if self.path is None:
            self.path = other.path
        if self.sync_every == 0:
            self.sync_every = other.sync_every

    def dump(self, reason: str, extra: Optional[dict] = None,
             path: Optional[str] = None) -> Optional[str]:
        p = path or self.path
        if p is None:
            return None
        payload = {
            "reason": reason,
            "dumped_at": time.time(),
            "n_records": len(self.ring),
            "meta": _jsonable(self.meta),
            "extra": _jsonable(extra) if extra is not None else None,
            "records": [_jsonable(r) for r in self.ring],
        }
        tmp = f"{p}.tmp.{os.getpid()}"
        with open(tmp, "w") as fh:
            json.dump(payload, fh)
        os.replace(tmp, p)          # atomic: readers never see a torn file
        self.dumps += 1
        self._since_sync = 0
        return p

    @staticmethod
    def load(path: str) -> dict:
        with open(path) as fh:
            return json.load(fh)


def install_signal_dump(flight: FlightRecorder,
                        signals=(signal.SIGTERM,)) -> None:
    """Dump the flight ring on SIGTERM before dying — ``timeout``-style
    supervisors send TERM first, so an orderly force-kill still yields
    a forensic record (SIGKILL is covered by ``sync_every`` instead)."""
    def _handler(signum, frame):
        flight.dump(f"signal_{signum}")
        raise SystemExit(128 + signum)
    for s in signals:
        signal.signal(s, _handler)
