"""llama4_maverick_400b_a17b config (see configs/archs.py for the full assignment table)."""

from .base import ModelConfig, MoEConfig, register

CONFIG = register(ModelConfig(
    # [hf:meta-llama/Llama-4-Scout-17B-16E; unverified] — MoE 128e top-1
    name="llama4-maverick-400b-a17b", n_layers=48, d_model=5120, n_heads=40,
    n_kv_heads=8, d_ff=8192, vocab=202048, pattern=("global_moe", "global"),
    moe=MoEConfig(num_experts=128, top_k=1),
))
