"""Model/config system.

Every assigned architecture is a :class:`ModelConfig`; layer layout is a
repeating ``pattern`` of layer kinds (cycled over ``n_layers``):

  * ``global`` — full causal (or bidirectional for encoders) attention
  * ``local``  — sliding-window attention (``window`` tokens)
  * ``rglru``  — Griffin RG-LRU recurrent block (+ temporal conv)
  * ``ssd``    — Mamba-2 state-space duality block

Each layer is followed by its FFN (dense SwiGLU/GELU or MoE per
``moe``), except ``rglru``/``ssd`` blocks which carry their own mixing
and still get the FFN (Griffin/Mamba block structure handled in
models/transformer.py).
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    capacity_factor: float = 1.25


@dataclass(frozen=True)
class ModelConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None            # default d_model // n_heads
    pattern: Tuple[str, ...] = ("global",)
    window: Optional[int] = None               # for "local" layers
    moe: Optional[MoEConfig] = None
    arch_kind: str = "decoder"                 # decoder | encdec | vlm
    norm: str = "rms"                          # rms | ln_nonparam
    act: str = "swiglu"                        # swiglu | gelu
    rope_theta: float = 10_000.0
    # recurrent blocks
    ssd_state: int = 128                       # mamba2 N
    ssd_head_dim: int = 64                     # mamba2 P
    ssd_expand: int = 2
    rglru_conv: int = 4
    # enc-dec / vlm stubs
    enc_layers: int = 0
    enc_len: int = 1536                        # stub frame/patch count
    img_tokens: int = 0                        # vlm: prepended patch embeds
    # serving
    page_size: int = 64
    # numerics
    dtype: str = "bfloat16"
    # which shapes are runnable (sub-quadratic rule; see DESIGN.md)
    supports_long: bool = False
    tie_embeddings: bool = False

    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.n_heads

    @property
    def jdtype(self):
        return jnp.bfloat16 if self.dtype == "bfloat16" else jnp.float32

    @property
    def layer_kinds(self) -> Tuple[str, ...]:
        """Kind of each of the n_layers layers (pattern cycled)."""
        pat = self.pattern
        return tuple(pat[i % len(pat)] for i in range(self.n_layers))

    @property
    def n_attn_layers(self) -> int:
        return sum(1 for k in self.layer_kinds
                   if base_kind(k) in ("global", "local"))

    @property
    def n_groups(self) -> int:
        """Full pattern repetitions (the scan length)."""
        return self.n_layers // len(self.pattern)

    @property
    def remainder(self) -> Tuple[str, ...]:
        """Layer kinds after the last full pattern group (unrolled)."""
        return self.layer_kinds[self.n_groups * len(self.pattern):]

    # Exact parameter counts are computed from the real parameter tree in
    # ``repro.models.model.count_params`` (eval_shape, no allocation).


def base_kind(kind: str) -> str:
    """Strip the ffn marker: "global_moe" -> "global"."""
    return kind[:-4] if kind.endswith("_moe") else kind


def is_moe_kind(kind: str) -> bool:
    return kind.endswith("_moe")


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    mode: str           # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


_REGISTRY = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    from . import archs  # noqa: F401  (populates the registry)
    return _REGISTRY[name]


def list_archs():
    from . import archs  # noqa: F401
    return sorted(_REGISTRY)


def smoke_config(cfg: ModelConfig) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests."""
    kw = dict(
        name=cfg.name + "-smoke",
        n_layers=max(2, 2 * len(cfg.pattern)),
        d_model=64,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads > 1 else 1,
        d_ff=128 if cfg.d_ff else 0,
        vocab=256,
        head_dim=16,
        window=min(cfg.window, 32) if cfg.window else None,
        moe=MoEConfig(4, cfg.moe.top_k, cfg.moe.capacity_factor) if cfg.moe else None,
        ssd_state=16,
        ssd_head_dim=16,
        enc_layers=min(cfg.enc_layers, 2),
        enc_len=24,
        img_tokens=min(cfg.img_tokens, 8),
        page_size=8,
        dtype="float32",
    )
    base = dataclasses.asdict(cfg)
    base.update(kw)
    base["moe"] = kw["moe"]
    return ModelConfig(**base)
