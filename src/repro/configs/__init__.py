from .base import (ModelConfig, MoEConfig, ShapeConfig, SHAPES,
                   get_config, list_archs, register, smoke_config)
