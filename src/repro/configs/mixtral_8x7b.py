"""mixtral_8x7b config (see configs/archs.py for the full assignment table)."""

from .base import ModelConfig, MoEConfig, register

CONFIG = register(ModelConfig(
    # [arXiv:2401.04088; hf] — 8 experts top-2, SWA
    name="mixtral-8x7b", n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab=32000, pattern=("local_moe",), window=4096,
    moe=MoEConfig(num_experts=8, top_k=2), supports_long=True,  # SWA bounds KV
))
