"""olmo_1b config (see configs/archs.py for the full assignment table)."""

from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    # [arXiv:2402.00838; hf] — non-parametric LN
    name="olmo-1b", n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=8192, vocab=50304, norm="ln_nonparam", act="swiglu",
))
