"""phi_3_vision_4_2b config (see configs/archs.py for the full assignment table)."""

from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    # [hf:microsoft/Phi-3-vision-128k-instruct; hf] — phi3-mini + CLIP stub
    name="phi-3-vision-4.2b", n_layers=32, d_model=3072, n_heads=32,
    n_kv_heads=32, d_ff=8192, vocab=32064, arch_kind="vlm",
    img_tokens=576,   # stubbed CLIP patch embeddings, provided as input
))
