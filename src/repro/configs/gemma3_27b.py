"""gemma3_27b config (see configs/archs.py for the full assignment table)."""

from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    # [hf:google/gemma-3-1b-pt; unverified] — 5:1 local:global, 128k ctx
    name="gemma3-27b", n_layers=62, d_model=5376, n_heads=32, n_kv_heads=16,
    d_ff=21504, vocab=262144, head_dim=128,
    pattern=("local", "local", "local", "local", "local", "global"),
    window=1024, supports_long=True,   # 52/62 layers are window-1024
))
