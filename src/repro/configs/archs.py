"""The 10 assigned architectures — aggregator.

``supports_long`` implements the sub-quadratic rule for long_500k
(see DESIGN.md): SSM/hybrid/windowed archs run it; pure full-attention
archs skip it.
"""

from .olmo_1b import CONFIG as OLMO_1B
from .phi4_mini_3_8b import CONFIG as PHI4_MINI
from .llama3_2_1b import CONFIG as LLAMA32_1B
from .gemma3_27b import CONFIG as GEMMA3_27B
from .mixtral_8x7b import CONFIG as MIXTRAL_8X7B
from .llama4_maverick_400b_a17b import CONFIG as LLAMA4_MAVERICK
from .phi_3_vision_4_2b import CONFIG as PHI3_VISION
from .whisper_tiny import CONFIG as WHISPER_TINY
from .recurrentgemma_2b import CONFIG as RECURRENTGEMMA_2B
from .mamba2_370m import CONFIG as MAMBA2_370M

ALL = [OLMO_1B, PHI4_MINI, LLAMA32_1B, GEMMA3_27B, MIXTRAL_8X7B, LLAMA4_MAVERICK, PHI3_VISION, WHISPER_TINY, RECURRENTGEMMA_2B, MAMBA2_370M]
