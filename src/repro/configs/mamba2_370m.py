"""mamba2_370m config (see configs/archs.py for the full assignment table)."""

from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    # [arXiv:2405.21060; unverified] — SSD, attention-free
    name="mamba2-370m", n_layers=48, d_model=1024, n_heads=1, n_kv_heads=1,
    d_ff=0, vocab=50280, pattern=("ssd",), ssd_state=128, ssd_head_dim=64,
    supports_long=True,
))
