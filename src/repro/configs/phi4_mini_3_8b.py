"""phi4_mini_3_8b config (see configs/archs.py for the full assignment table)."""

from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    # [arXiv:2412.08905; hf] — RoPE SwiGLU GQA
    name="phi4-mini-3.8b", n_layers=32, d_model=3072, n_heads=24,
    n_kv_heads=8, d_ff=8192, vocab=200064,
))
