"""llama3_2_1b config (see configs/archs.py for the full assignment table)."""

from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    # [hf:meta-llama/Llama-3.2-1B; unverified]
    name="llama3.2-1b", n_layers=16, d_model=2048, n_heads=32, n_kv_heads=8,
    d_ff=8192, vocab=128256, rope_theta=500_000.0,
))
