"""whisper_tiny config (see configs/archs.py for the full assignment table)."""

from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    # [arXiv:2212.04356; unverified] — enc-dec, conv frontend stubbed
    name="whisper-tiny", n_layers=4, d_model=384, n_heads=6, n_kv_heads=6,
    d_ff=1536, vocab=51865, arch_kind="encdec", enc_layers=4, enc_len=1500,
    act="gelu",
))
