"""recurrentgemma_2b config (see configs/archs.py for the full assignment table)."""

from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    # [arXiv:2402.19427; hf] — RG-LRU + local attn, pattern 2 rec : 1 attn
    name="recurrentgemma-2b", n_layers=26, d_model=2560, n_heads=10,
    n_kv_heads=1, d_ff=7680, vocab=256000,
    pattern=("rglru", "rglru", "local"), window=2048, act="gelu",
    supports_long=True,
))
