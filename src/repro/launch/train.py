"""Training driver: end-to-end fault-tolerant train loop.

Runs anywhere: on CPU it uses a 1x1 mesh and a smoke config; on a pod
the same code takes the production mesh (``--production``).  Wraps the
jitted train step in :class:`repro.runtime.fault.FaultTolerantLoop`
(periodic async checkpoints, restart on failure, straggler watchdog).

  PYTHONPATH=src python -m repro.launch.train --arch olmo-1b --steps 100 \
      --smoke --ckpt-dir /tmp/ckpt
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from .. import models
from ..configs import get_config, smoke_config
from ..checkpoint.ckpt import Checkpointer
from ..data.pipeline import DataConfig, TokenStream
from ..optim import adamw
from ..parallel import compress
from ..runtime.fault import FailureInjector, FaultTolerantLoop


def build_step(cfg, opt_cfg, use_compression: bool = False):
    def train_step(state, batch):
        params, opt_state, ef = state
        loss, grads = jax.value_and_grad(
            lambda p: models.loss_fn(cfg, p, batch))(params)
        if use_compression:
            grads, ef = compress.compressed_grads(grads, ef)
        params, opt_state, metrics = adamw.apply(
            opt_cfg, opt_state, grads, params)
        metrics["loss"] = loss
        return (params, opt_state, ef), metrics

    return jax.jit(train_step, donate_argnums=(0,))


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--save-every", type=int, default=20)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--inject-failure-at", type=int, default=None)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_config(cfg)
    opt_cfg = adamw.AdamWConfig(warmup_steps=10, decay_steps=args.steps)

    params = models.init_params(cfg, jax.random.PRNGKey(0))
    opt_state = adamw.init(params)
    ef = compress.init_error_feedback(params) if args.compress_grads else None

    stream = TokenStream(DataConfig(
        vocab=cfg.vocab, seq_len=args.seq, global_batch=args.batch))
    step_fn = build_step(cfg, opt_cfg, args.compress_grads)
    losses = []

    def wrapped_step(state, batch):
        new_state, metrics = step_fn(state, {
            "tokens": jnp.asarray(batch["tokens"]),
            "labels": jnp.asarray(batch["labels"])})
        losses.append(float(metrics["loss"]))
        return new_state

    injector = None
    if args.inject_failure_at is not None:
        injector = FailureInjector(
            fail_at={args.inject_failure_at: RuntimeError("injected")})

    loop = FaultTolerantLoop(
        wrapped_step, stream.batch_at,
        Checkpointer(args.ckpt_dir), save_every=args.save_every,
        injector=injector)
    t0 = time.time()
    state = loop.run((params, opt_state, ef), args.steps)
    dt = time.time() - t0
    print(f"trained {loop.stats.completed_steps} steps in {dt:.1f}s "
          f"({loop.stats.restarts} restarts, "
          f"{loop.stats.straggler_steps} straggler steps)")
    print(f"loss: {losses[0]:.4f} -> {losses[-1]:.4f}")
    return state, losses


if __name__ == "__main__":
    main()
