import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST stay the first statements in this module (jax
locks the device count at first init).  Do not set that flag globally —
smoke tests and benches should see one device.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch olmo-1b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] \
      --out results/dryrun

Per cell: jit(step).lower(*ShapeDtypeStructs).compile() on the
production mesh, then record memory_analysis(), cost_analysis(), and the
parsed roofline terms (see roofline.py) to JSON.
"""

import argparse
import json
import math
import pathlib
import time
import traceback

import jax

from .. import models
from ..configs import SHAPES, get_config, list_archs
from . import roofline as rf
from . import steps as steps_mod
from .mesh import make_production_mesh


def cell_is_runnable(arch: str, shape_name: str) -> bool:
    cfg = get_config(arch)
    if shape_name == "long_500k" and not cfg.supports_long:
        return False   # sub-quadratic rule — see DESIGN.md
    return True


def model_flops_for(cfg, shape) -> float:
    """MODEL_FLOPS = 6*N*D (dense) / 6*N_active*D; x3 for train (fwd+bwd)."""
    n_active = models.count_active_params(cfg)
    if shape.mode == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.mode == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * shape.global_batch


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             rules: str = None, save_hlo: str = None) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = math.prod(mesh.devices.shape)
    spec = steps_mod.cell_specs(cfg, shape, mesh, rules=rules)

    from ..parallel import partition
    partition.set_activation_mesh(mesh, seq_shard=(rules == "sp"))
    t0 = time.time()
    with mesh:
        jitted = jax.jit(
            spec["fn"],
            in_shardings=spec["in_shardings"],
            donate_argnums=spec["donate_argnums"])
        lowered = jitted.lower(*spec["args"])
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    partition.set_activation_mesh(None)
    mem = compiled.memory_analysis()
    mem_info = {}
    for k in ("temp_size_in_bytes", "argument_size_in_bytes",
              "output_size_in_bytes", "alias_size_in_bytes",
              "generated_code_size_in_bytes"):
        v = getattr(mem, k, None)
        if v is not None:
            mem_info[k] = int(v)
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]

    roof = rf.from_compiled(compiled, chips,
                            model_flops=model_flops_for(cfg, shape))
    hbm_per_dev = (mem_info.get("argument_size_in_bytes", 0)
                   + mem_info.get("temp_size_in_bytes", 0)
                   - mem_info.get("alias_size_in_bytes", 0))
    out = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": chips,
        "rules": rules or ("fsdp" if shape.mode == "train" else "tp"),
        "mode": shape.mode,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "memory": mem_info,
        "hbm_per_device_gb": round(hbm_per_dev / 2**30, 3),
        "xla_cost_analysis": {k: float(ca.get(k, 0.0))
                              for k in ("flops", "bytes accessed")},
        "roofline": roof.as_dict(),
    }
    if save_hlo:
        pathlib.Path(save_hlo).write_text(compiled.as_text())
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--rules", default=None)
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--save-hlo", default=None)
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    outdir = pathlib.Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)

    cells = []
    archs = list_archs() if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = [args.multi_pod] if not args.both_meshes else [False, True]
    for arch in archs:
        for shape in shapes:
            if not cell_is_runnable(arch, shape):
                print(f"SKIP {arch} x {shape} (sub-quadratic rule)")
                continue
            for mp in meshes:
                cells.append((arch, shape, mp))

    failures = 0
    for arch, shape, mp in cells:
        tag = f"{arch}__{shape}__{'mp' if mp else 'sp'}"
        if args.rules:
            tag += f"__{args.rules}"
        path = outdir / (tag + ".json")
        if args.skip_existing and path.exists():
            print(f"SKIP (exists) {tag}")
            continue
        print(f"=== {tag} ===", flush=True)
        try:
            res = run_cell(arch, shape, mp, rules=args.rules,
                           save_hlo=args.save_hlo)
            path.write_text(json.dumps(res, indent=2))
            r = res["roofline"]
            print(f"  ok: compile={res['compile_s']}s "
                  f"hbm/dev={res['hbm_per_device_gb']}GB "
                  f"t_comp={r['t_compute_s']:.2e} t_mem={r['t_memory_s']:.2e} "
                  f"t_coll={r['t_collective_s']:.2e} "
                  f"bottleneck={r['bottleneck']} mfu<={r['mfu_bound']:.2f}",
                  flush=True)
        except Exception as e:
            failures += 1
            path.with_suffix(".err").write_text(traceback.format_exc())
            print(f"  FAIL: {type(e).__name__}: {e}", flush=True)
    print(f"done, {failures} failures")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
