"""Roofline-term extraction from compiled dry-run artifacts.

Three terms per (arch x shape x mesh), in seconds (TPU v5e targets):

  compute    = HLO_FLOPs_per_device / 197e12      (bf16 peak per chip)
  memory     = HLO_bytes_per_device / 819e9       (HBM bandwidth)
  collective = collective_bytes_per_device / 50e9 (ICI per link)

``compiled.cost_analysis()`` is per-device after SPMD partitioning but
counts ``while`` bodies (our layer scans) exactly once, so it badly
undercounts deep models.  We therefore walk the optimized HLO text with
a mini cost model:

  * computations are parsed into per-computation symbol tables
    (name -> shape), and a call-graph multiplier is propagated:
    while bodies multiply by their trip count (recovered from the
    loop-condition constant), fusions/calls inherit the caller's count;
  * FLOPs: ``dot``/``convolution`` ops (2 x result x contracted dims) —
    the MXU work.  VPU elementwise FLOPs are excluded (<2% for these
    models; noted in EXPERIMENTS.md);
  * bytes: per top-level instruction, result + operand bytes (fusion
    internals excluded — fusion boundaries are exactly where HBM traffic
    happens).  gather/dynamic-slice/dynamic-update-slice are charged for
    the data actually moved, not the full operand;
  * collectives: all-gather / all-reduce / reduce-scatter / all-to-all /
    collective-permute result bytes x transfer factor (ring all-reduce
    moves ~2x), times the call-graph multiplier.
"""

from __future__ import annotations

import math
import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

PEAK_FLOPS = 197e12          # bf16 / chip, TPU v5e
HBM_BW = 819e9               # bytes/s / chip
ICI_BW = 50e9                # bytes/s / link

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLL_FACTOR = {
    "all-reduce": 2.0,
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}

_COLL_KINDS = tuple(_COLL_FACTOR)

_SKIP_BYTES_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "while", "after-all", "iota", "rng",
}

_NAME_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*")
_OPCODE_RE = re.compile(r"([a-z][a-zA-Z\d\-]*)\(")
_ONE_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _ONE_SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(shape_str: str) -> List[int]:
    m = _ONE_SHAPE_RE.search(shape_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclass
class Instr:
    name: str
    shape: str            # result shape string
    op: str
    operands: List[str]
    line: str


def parse_hlo(text: str) -> Dict[str, List[Instr]]:
    comps: Dict[str, List[Instr]] = {}
    cur: Optional[str] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        s = line.strip()
        if s.endswith("{") and (") -> " in s or s.startswith("ENTRY")):
            name = s.split("(")[0].strip().split()[-1].lstrip("%")
            cur = name
            comps[cur] = []
            continue
        if s == "}":
            cur = None
            continue
        if cur is None or "=" not in s:
            continue
        nm = _NAME_RE.match(s)
        if not nm:
            continue
        name = nm.group(1)
        rest = s[nm.end():]
        om = _OPCODE_RE.search(rest)
        if not om:
            continue
        op = om.group(1)
        shape = rest[:om.start()].strip()   # result type (may be a tuple)
        # operand names: %refs inside the opcode's balanced (...)
        after = rest[om.end():]
        depth, args = 1, ""
        for ch in after:
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
            args += ch
        operands = re.findall(r"%([\w\.\-]+)", args)
        comps[cur].append(Instr(name, shape, op, operands, s))
    return comps


def _call_multipliers(comps: Dict[str, List[Instr]]) -> Dict[str, float]:
    """computation -> number of executions of one entry invocation."""
    entry = None
    for name in comps:
        if name.startswith("main") or entry is None:
            if entry is None:
                entry = name
    # the ENTRY computation is the first parsed with ENTRY marker; fall
    # back to a root heuristic: computation never called by others.
    called = set()
    calls: Dict[str, List[Tuple[str, float]]] = {}
    for cname, instrs in comps.items():
        for ins in instrs:
            line = ins.line
            mult = 1.0
            if ins.op == "while":
                mb = re.search(r"body=%?([\w\.\-]+)", line)
                mc = re.search(r"condition=%?([\w\.\-]+)", line)
                trip = _trip_count(comps, mc.group(1)) if mc else 1
                if mb:
                    calls.setdefault(cname, []).append((mb.group(1), trip))
                    called.add(mb.group(1))
                if mc:
                    calls.setdefault(cname, []).append((mc.group(1), trip + 1))
                    called.add(mc.group(1))
                continue
            for attr in ("calls", "to_apply", "body", "branch_computations",
                         "true_computation", "false_computation"):
                for mm in re.finditer(attr + r"=\{?%?([\w\.\-, %]+)\}?", line):
                    for target in re.findall(r"[\w\.\-]+", mm.group(1)):
                        if target in comps:
                            calls.setdefault(cname, []).append((target, 1.0))
                            called.add(target)
    roots = [c for c in comps if c not in called]
    mult: Dict[str, float] = {c: (1.0 if c in roots else 0.0) for c in comps}
    # propagate (call graph is a DAG; sweep to fixpoint)
    for _ in range(len(comps) + 1):
        new = {c: (1.0 if c in roots else 0.0) for c in comps}
        for cname, targets in calls.items():
            for tgt, k in targets:
                new[tgt] += mult.get(cname, 0.0) * k
        if new == mult:
            break
        mult = new
    return mult


def _trip_count(comps: Dict[str, List[Instr]], cond: str) -> float:
    best = 1
    for ins in comps.get(cond, []):
        for mm in re.finditer(r"constant\((\d+)\)", ins.line):
            best = max(best, int(mm.group(1)))
    return float(best)


def _fusion_bodies(comps: Dict[str, List[Instr]]) -> set:
    out = set()
    for instrs in comps.values():
        for ins in instrs:
            if ins.op == "fusion":
                m = re.search(r"calls=%?([\w\.\-]+)", ins.line)
                if m:
                    out.add(m.group(1))
    return out


def _dot_flops(ins: Instr, symbols: Dict[str, str]) -> float:
    out_elems = max(1, math.prod(_shape_dims(ins.shape)))
    lhs = symbols.get(ins.operands[0]) if ins.operands else None
    cdims = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.line)
    contracted = 1
    if lhs and cdims:
        dims = _shape_dims(lhs)
        for d in cdims.group(1).split(","):
            if d and int(d) < len(dims):
                contracted *= dims[int(d)]
    return 2.0 * out_elems * contracted


def _conv_flops(ins: Instr, symbols: Dict[str, str]) -> float:
    out_elems = max(1, math.prod(_shape_dims(ins.shape)))
    rhs = symbols.get(ins.operands[1]) if len(ins.operands) > 1 else None
    kernel = math.prod(_shape_dims(rhs)) if rhs else 1
    # rough: 2 * out * (kernel/out_channels)
    return 2.0 * out_elems * max(kernel, 1) ** 0.5


@dataclass
class HloCost:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes_weighted: float = 0.0
    coll_bytes_raw: float = 0.0
    coll_counts: Dict[str, int] = field(default_factory=dict)
    largest_collective: Tuple[str, float] = ("", 0.0)


def _dus_update_bytes(comp_instrs: List[Instr]) -> Optional[int]:
    """If a fused computation performs dynamic-update-slice(s), the real
    traffic is the update slices (XLA aliases the big buffer in place)."""
    total = 0
    symbols = {i.name: i.shape for i in comp_instrs}
    found = False
    for ins in comp_instrs:
        if ins.op == "dynamic-update-slice":
            found = True
            if len(ins.operands) > 1:
                total += _shape_bytes(symbols.get(ins.operands[1], ""))
    return total if found else None


def analyze_hlo(text: str) -> HloCost:
    comps = parse_hlo(text)
    mult = _call_multipliers(comps)
    fused = _fusion_bodies(comps)
    cost = HloCost()

    for cname, instrs in comps.items():
        m = mult.get(cname, 1.0)
        if m == 0.0:
            m = 1.0
        symbols = {i.name: i.shape for i in instrs}
        in_fusion = cname in fused
        for ins in instrs:
            if ins.op == "dot":
                cost.flops += m * _dot_flops(ins, symbols)
            elif ins.op == "convolution":
                cost.flops += m * _conv_flops(ins, symbols)
            if in_fusion:
                continue  # bytes counted at the fusion call site
            opk = ins.op
            if opk in _SKIP_BYTES_OPS:
                continue
            if opk.rstrip("-start").rstrip("-done") in _COLL_KINDS or \
               any(opk.startswith(k) for k in _COLL_KINDS):
                kind = next(k for k in _COLL_KINDS if opk.startswith(k))
                b = _shape_bytes(ins.shape)
                # XLA-CPU promotes bf16 all-reduce accumulation to f32
                # (to_apply=%add..._promoted); TPU reduces in bf16 on the
                # wire — charge the pre-promotion payload.
                if "promoted" in ins.line and "f32" in ins.shape:
                    b //= 2
                cost.coll_bytes_weighted += m * b * _COLL_FACTOR[kind]
                cost.coll_bytes_raw += m * b
                cost.coll_counts[kind] = cost.coll_counts.get(kind, 0) + int(m)
                if m * b > cost.largest_collective[1]:
                    cost.largest_collective = (f"{kind} {ins.shape}", m * b)
                cost.bytes += m * 2 * b
                continue
            res_b = _shape_bytes(ins.shape)
            if opk in ("gather", "dynamic-slice"):
                cost.bytes += m * (2 * res_b)
                continue
            if opk in ("scatter", "dynamic-update-slice"):
                upd = (_shape_bytes(symbols.get(ins.operands[1], ""))
                       if len(ins.operands) > 1 else res_b)
                cost.bytes += m * (2 * upd)
                continue
            if opk == "fusion":
                mm = re.search(r"calls=%?([\w\.\-]+)", ins.line)
                callee = comps.get(mm.group(1), []) if mm else []
                dus = _dus_update_bytes(callee)
                if dus is not None:
                    # in-place update: slice write+read + non-aliased reads
                    others = sorted(
                        (_shape_bytes(symbols.get(o, ""))
                         for o in ins.operands), reverse=True)
                    # drop the largest operand (the aliased buffer)
                    extra = sum(others[1:]) if others else 0
                    cost.bytes += m * (2 * dus + min(extra, res_b))
                    continue
            op_b = sum(_shape_bytes(symbols.get(o, ""))
                       for o in ins.operands)
            cost.bytes += m * (res_b + op_b)
    return cost


@dataclass
class Roofline:
    chips: int
    flops: float                  # per-device MXU flops
    hbm_bytes: float              # per-device bytes accessed
    coll_bytes: float             # per-device weighted collective bytes
    model_flops: float = 0.0      # 6*N*D useful flops, whole step, global
    coll_counts: Dict[str, int] = field(default_factory=dict)
    largest_collective: str = ""

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_fraction(self) -> float:
        """MODEL_FLOPS / (global HLO flops)."""
        total = self.flops * self.chips
        return self.model_flops / total if total else 0.0

    @property
    def mfu_bound(self) -> float:
        """Roofline-implied MFU upper bound: useful flops / (peak x time)."""
        t = max(self.t_compute, self.t_memory, self.t_collective)
        if t <= 0:
            return 0.0
        return self.model_flops / (self.chips * PEAK_FLOPS * t)

    def as_dict(self) -> dict:
        return {
            "chips": self.chips,
            "hlo_flops_per_device": self.flops,
            "hbm_bytes_per_device": self.hbm_bytes,
            "collective_bytes_per_device": self.coll_bytes,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "model_flops": self.model_flops,
            "useful_flop_fraction": self.useful_fraction,
            "mfu_bound": self.mfu_bound,
            "coll_counts": self.coll_counts,
            "largest_collective": self.largest_collective,
        }


def from_compiled(compiled, chips: int, model_flops: float = 0.0) -> Roofline:
    cost = analyze_hlo(compiled.as_text())
    return Roofline(
        chips=chips, flops=cost.flops, hbm_bytes=cost.bytes,
        coll_bytes=cost.coll_bytes_weighted, model_flops=model_flops,
        coll_counts=cost.coll_counts,
        largest_collective=cost.largest_collective[0])
