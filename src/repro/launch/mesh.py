"""Production mesh construction.

Defined as functions (never module-level constants) so importing this
module never touches jax device state.  The dry-run launcher sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* any
jax import to get placeholder devices (see dryrun.py).
"""

from __future__ import annotations

from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


#: The serving allocation plane's mesh axis: one device per DP shard.
SERVE_DP_AXIS = "dp"


def make_dp_mesh(dp: int) -> Optional[Mesh]:
    """One-axis ``("dp",)`` mesh of the first ``dp`` devices — the
    serving engine's multi-host allocation plane (DESIGN.md §9).

    Each device on the axis owns exactly one DP shard's allocator state
    (HierPool leaves, refcounts, pin table, KV pages); the engine wraps
    its jitted steps in ``shard_map`` over this mesh so shard-locality
    is enforced by construction, not just by vmap convention.

    Returns None when the process has fewer than ``dp`` devices (or
    dp < 2): the engine then falls back to the single-device vmap
    semantics, which compute the same thing on one device.  CI's mesh-8
    job forces 8 host devices via
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` so tier-1
    exercises the shard_map plane on CPU.
    """
    if dp < 2 or len(jax.devices()) < dp:
        return None
    return Mesh(np.asarray(jax.devices()[:dp]), (SERVE_DP_AXIS,))


def make_smoke_mesh():
    """1-device mesh with the production axis names (CPU tests)."""
    return jax.make_mesh((1, 1), ("data", "model"))


def nearest_mesh_for(n_devices: int, model_parallel: int = 16):
    """Elastic fallback: best (data, model) factorization for a device set.

    Used by runtime/elastic.py when membership changes: keep the model
    axis if divisible, shrink data parallelism to what remains.
    """
    while model_parallel > 1 and n_devices % model_parallel:
        model_parallel //= 2
    data = n_devices // model_parallel
    return (data, model_parallel), ("data", "model")
