"""Production mesh construction.

Defined as functions (never module-level constants) so importing this
module never touches jax device state.  The dry-run launcher sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* any
jax import to get placeholder devices (see dryrun.py).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_smoke_mesh():
    """1-device mesh with the production axis names (CPU tests)."""
    return jax.make_mesh((1, 1), ("data", "model"))


def nearest_mesh_for(n_devices: int, model_parallel: int = 16):
    """Elastic fallback: best (data, model) factorization for a device set.

    Used by runtime/elastic.py when membership changes: keep the model
    axis if divisible, shrink data parallelism to what remains.
    """
    while model_parallel > 1 and n_devices % model_parallel:
        model_parallel //= 2
    data = n_devices // model_parallel
    return (data, model_parallel), ("data", "model")
