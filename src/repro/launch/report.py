"""Generate the EXPERIMENTS.md roofline tables from dry-run JSONs.

  PYTHONPATH=src python -m repro.launch.report results/dryrun_v2 [fallback_dir]
"""

from __future__ import annotations

import json
import pathlib
import sys


def load(dirs):
    cells = {}
    for d in reversed(dirs):                      # earlier dirs = fallback
        for p in sorted(pathlib.Path(d).glob("*.json")):
            r = json.loads(p.read_text())
            key = (r["arch"], r["shape"], r["mesh"], r.get("rules", ""))
            base_key = (r["arch"], r["shape"], r["mesh"])
            cells[base_key] = r
    return cells


def fmt(x):
    if x == 0:
        return "0"
    if x < 1e-3 or x >= 1e4:
        return f"{x:.2e}"
    return f"{x:.3f}" if x < 10 else f"{x:.1f}"


def table(cells, mesh):
    rows = []
    hdr = ("| arch | shape | HBM/dev GB | t_compute s | t_memory s | "
           "t_coll s | bottleneck | useful FLOP frac | MFU bound |")
    sep = "|" + "---|" * 9
    rows.append(hdr)
    rows.append(sep)
    for (arch, shape, m), r in sorted(cells.items()):
        if m != mesh:
            continue
        rf = r["roofline"]
        rows.append(
            f"| {arch} | {shape} | {r['hbm_per_device_gb']} | "
            f"{fmt(rf['t_compute_s'])} | {fmt(rf['t_memory_s'])} | "
            f"{fmt(rf['t_collective_s'])} | {rf['bottleneck']} | "
            f"{rf['useful_flop_fraction']:.3f} | {rf['mfu_bound']:.3f} |")
    return "\n".join(rows)


def dryrun_section(cells):
    ok_sp = sum(1 for k in cells if k[2] == "16x16")
    ok_mp = sum(1 for k in cells if k[2] == "2x16x16")
    lines = [f"Single-pod (16x16 = 256 chips) cells compiled: {ok_sp}",
             f"Multi-pod (2x16x16 = 512 chips) cells compiled: {ok_mp}", ""]
    lines.append("| arch | shape | mesh | compile s | HBM/dev GB | "
                 "largest collective |")
    lines.append("|" + "---|" * 6)
    for (arch, shape, m), r in sorted(cells.items()):
        rf = r["roofline"]
        lines.append(f"| {arch} | {shape} | {m} | {r['compile_s']} | "
                     f"{r['hbm_per_device_gb']} | "
                     f"{rf.get('largest_collective', '')[:60]} |")
    return "\n".join(lines)


def main():
    dirs = sys.argv[1:] or ["results/dryrun_v2", "results/dryrun"]
    cells = load(dirs)
    print("## Dry-run summary\n")
    print(dryrun_section(cells))
    print("\n## Roofline (single-pod 16x16, per §Roofline)\n")
    print(table(cells, "16x16"))
    print("\n## Roofline (multi-pod 2x16x16)\n")
    print(table(cells, "2x16x16"))


if __name__ == "__main__":
    main()
