"""Step-function builders + input specs for every (arch x shape) cell.

Used by the dry-run (ShapeDtypeStruct inputs, .lower().compile()), the
trainer, and the serving engine.  All specs are mesh-aware:

* train/prefill: tokens [B, S] sharded over the batch axes;
* decode: [DP, B_local] layout with DP = min(#batch-shards, B); paged KV
  pages live in a per-DP-shard two-level HierPool with per-slot private
  lanes (see DESIGN.md §7 / transformer.py).
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .. import models
from ..configs.base import ModelConfig, ShapeConfig
from ..models import transformer as tfm
from ..optim import adamw
from ..parallel import partition


# ----------------------------------------------------------------- helpers

def _ns(mesh, spec):
    return NamedSharding(mesh, spec)


def _maybe(mesh, dim, axis):
    """Shard dim over axis only if divisible (see partition.py)."""
    return axis if dim % partition._axis_size(mesh, axis) == 0 else None


def decode_layout(shape: ShapeConfig, mesh: Mesh) -> Tuple[int, int]:
    dp = min(partition.dp_size(mesh), shape.global_batch)
    return dp, shape.global_batch // dp


# ------------------------------------------------------------- input specs

def batch_specs(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh):
    """(ShapeDtypeStruct tree, sharding tree) for the data batch."""
    ba = partition.batch_axes(mesh)
    ba = ba if len(ba) > 1 else ba[0]
    B, S = shape.global_batch, shape.seq_len
    if shape.mode in ("train", "prefill"):
        specs = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}
        shard = {"tokens": _ns(mesh, P(ba, None))}
        if shape.mode == "train":
            specs["labels"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
            shard["labels"] = _ns(mesh, P(ba, None))
        if cfg.arch_kind == "vlm":
            specs["img_embeds"] = jax.ShapeDtypeStruct(
                (B, cfg.img_tokens, cfg.d_model), cfg.jdtype)
            shard["img_embeds"] = _ns(mesh, P(ba, None, None))
        if cfg.arch_kind == "encdec":
            specs["enc_embeds"] = jax.ShapeDtypeStruct(
                (B, cfg.enc_len, cfg.d_model), cfg.jdtype)
            shard["enc_embeds"] = _ns(mesh, P(ba, None, None))
        return specs, shard
    # decode
    dp, bl = decode_layout(shape, mesh)
    specs = jax.ShapeDtypeStruct((dp, bl), jnp.int32)
    shard = _ns(mesh, P(ba if dp > 1 else None, None))
    return specs, shard


def decode_state_shardings(cfg: ModelConfig, state_defs: tfm.DecodeState,
                           mesh: Mesh) -> tfm.DecodeState:
    ba = partition.batch_axes(mesh)
    ba = ba if len(ba) > 1 else ba[0]
    dp = state_defs.seq_lens.shape[0]
    dpa = ba if dp > 1 else None
    KH = cfg.n_kv_heads
    kh_ax = _maybe(mesh, KH, "model")
    # GQA with KH < model-axis: instead of replicating the KV pool
    # model-axis-wide (16x memory), shard the head_dim (128 % 16 == 0
    # for every assigned config).  The QK^T contraction over hd becomes
    # a partial sum + a tiny [B,H,L] all-reduce.  §Perf B2.
    hd_ax = None
    if kh_ax is None:
        hd_ax = _maybe(mesh, cfg.hd, "model")

    def kv_spec(sds):
        # [stack, DP, pages|Bl, (psz|W), KH, hd]
        nd = len(sds.shape)
        parts = [None] * nd
        parts[1] = dpa
        parts[-2] = kh_ax
        parts[-1] = hd_ax
        return _ns(mesh, P(*parts))

    def rec_spec(sds):
        # shard the widest trailing dim over model if divisible
        parts = [None] * len(sds.shape)
        parts[1] = dpa
        # heads dim for ssd h [stack, DP, Bl, H, P, N]; channels for conv
        if len(sds.shape) >= 4:
            cand = 3
            parts[cand] = _maybe(mesh, sds.shape[cand], "model")
        return _ns(mesh, P(*parts))

    kv_pages = jax.tree.map(kv_spec, state_defs.kv_pages)
    rings = jax.tree.map(kv_spec, state_defs.rings)
    rec = jax.tree.map(rec_spec, state_defs.rec)
    enc_kv = None
    if state_defs.enc_kv is not None:
        enc_kv = jax.tree.map(kv_spec, state_defs.enc_kv)
    # every pool leaf (any class, any depth) carries DP at axis 0
    def pool_spec(sds):
        return _ns(mesh, P(*([dpa] + [None] * (len(sds.shape) - 1))))

    return tfm.DecodeState(
        kv_pages=kv_pages, rings=rings, rec=rec,
        page_tables=_ns(mesh, P(dpa, None, None)),
        seq_lens=_ns(mesh, P(dpa, None)),
        pool=jax.tree.map(pool_spec, state_defs.pool),
        enc_kv=enc_kv,
        state_tables=(None if state_defs.state_tables is None
                      else _ns(mesh, P(dpa, None, None))),
        expert_pages=(None if state_defs.expert_pages is None
                      else _ns(mesh, P(dpa, None, None))),
        expert_tables=(None if state_defs.expert_tables is None
                       else jax.tree.map(
                           lambda s: _ns(mesh, P(None, dpa, None, None)),
                           state_defs.expert_tables)))


# --------------------------------------------- serving dp-mesh partitioning
#
# The serving engine's allocation plane runs over the one-axis ("dp",)
# mesh of launch.mesh.make_dp_mesh: every DecodeState leaf is sharded on
# its DP axis so each device owns exactly its shard's HierPool (shared
# stack, refcounts, lanes), page tables, pin table, and KV pages, and
# the engine's jitted steps are shard_mapped over these specs
# (DESIGN.md §9).  Leaf layouts: kv_pages/rings/rec/enc_kv carry DP at
# axis 1 ([stack, DP, ...]); page_tables/seq_lens/pool leaves and the
# per-slot serving registers carry it at axis 0.  The §13 telemetry
# counter block widens the packed status array ([T+3+N_CTR, DP, Bl],
# DP at axis 1) — the extra rows ride the *existing* status out-spec
# and all_gather, so enabling telemetry changes no sharding and adds
# no collective.

def serve_register_pspec() -> P:
    """[DP, Bl(, ...)] per-slot register / mask / pin-table spec."""
    return P("dp")


def serve_state_pspecs(state: tfm.DecodeState) -> tfm.DecodeState:
    """PartitionSpec tree (axis name "dp") for a serving DecodeState."""
    ax1 = lambda tree: jax.tree.map(lambda _: P(None, "dp"), tree)
    return tfm.DecodeState(
        kv_pages=ax1(state.kv_pages),
        rings=ax1(state.rings),
        rec=ax1(state.rec),
        page_tables=P("dp"),
        seq_lens=P("dp"),
        pool=jax.tree.map(lambda _: P("dp"), state.pool),
        enc_kv=None if state.enc_kv is None else ax1(state.enc_kv),
        state_tables=None if state.state_tables is None else P("dp"),
        expert_pages=None if state.expert_pages is None else P("dp"),
        expert_tables=(None if state.expert_tables is None
                       else ax1(state.expert_tables)))


def serve_shardings(mesh: Mesh, pspecs):
    """NamedSharding tree for ``jax.device_put`` of serving state."""
    return jax.tree.map(lambda s: _ns(mesh, s), pspecs)


# ------------------------------------------------------------ step builders

def build_train_step(cfg: ModelConfig, opt_cfg: Optional[adamw.AdamWConfig] = None):
    opt_cfg = opt_cfg or adamw.AdamWConfig()

    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: models.loss_fn(cfg, p, batch))(params)
        new_params, new_opt, metrics = adamw.apply(
            opt_cfg, opt_state, grads, params)
        metrics["loss"] = loss
        return new_params, new_opt, metrics

    return train_step


def build_prefill_step(cfg: ModelConfig):
    def prefill_step(params, batch):
        return models.prefill(cfg, params, batch)
    return prefill_step


def build_serve_step(cfg: ModelConfig):
    """Dry-run / roofline decode cell: one width-1 token lane per slot
    (``models.decode_step`` wraps ``forward_decode_chunk`` at T=1 —
    the only decode entry point since the single-token path was
    deleted; DESIGN.md §10)."""
    def serve_step(params, tokens, state):
        return models.decode_step(cfg, params, tokens, state)
    return serve_step


# ---------------------------------------------------------------- assembly

def cell_specs(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
               rules: Optional[str] = None):
    """Everything needed to jit-lower one (arch x shape) cell.

    Returns dict with: fn, args (ShapeDtypeStructs), in_shardings,
    donate_argnums.
    """
    if rules is None:
        if shape.mode == "train":
            rules = "fsdp"
        elif cfg.moe is not None:
            rules = "ep_serve"   # §Perf B1: don't replicate experts
        else:
            rules = "tp"
    defs = models.param_defs(cfg)
    pshapes = models.param_shapes(cfg)
    pshard = partition.param_shardings(defs, mesh, rules)

    if shape.mode == "train":
        bspecs, bshard = batch_specs(cfg, shape, mesh)
        opt_shapes = _opt_shapes(pshapes)
        opt_shard = _opt_shardings(pshard, mesh)
        fn = build_train_step(cfg)
        return dict(fn=fn, args=(pshapes, opt_shapes, bspecs),
                    in_shardings=(pshard, opt_shard, bshard),
                    donate_argnums=(0, 1))
    if shape.mode == "prefill":
        bspecs, bshard = batch_specs(cfg, shape, mesh)
        fn = build_prefill_step(cfg)
        return dict(fn=fn, args=(pshapes, bspecs),
                    in_shardings=(pshard, bshard), donate_argnums=())
    # decode
    dp, bl = decode_layout(shape, mesh)
    sdefs = tfm.decode_state_defs(cfg, dp, bl, max_len=shape.seq_len)
    sshard = decode_state_shardings(cfg, sdefs, mesh)
    tspec, tshard = batch_specs(cfg, shape, mesh)
    fn = build_serve_step(cfg)
    return dict(fn=fn, args=(pshapes, tspec, sdefs),
                in_shardings=(pshard, tshard, sshard), donate_argnums=(2,))


def _opt_shapes(pshapes):
    f32 = lambda t: jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32), t)
    return adamw.AdamWState(
        step=jax.ShapeDtypeStruct((), jnp.int32),
        mu=f32(pshapes), nu=f32(pshapes), master=f32(pshapes))


def _opt_shardings(pshard, mesh):
    return adamw.AdamWState(
        step=_ns(mesh, P()),
        mu=pshard, nu=pshard, master=pshard)
