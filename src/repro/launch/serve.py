"""Serving driver: continuous batching over the paged-KV engine.

  PYTHONPATH=src python -m repro.launch.serve --arch olmo-1b --smoke \
      --requests 16
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from .. import models
from ..configs import get_config, smoke_config
from ..serving.engine import Request, ServingEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--dp", type=int, default=2)
    ap.add_argument("--b-local", type=int, default=2)
    ap.add_argument("--max-len", type=int, default=128)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_config(cfg)
    params = models.init_params(cfg, jax.random.PRNGKey(0))
    engine = ServingEngine(cfg, params, dp=args.dp, b_local=args.b_local,
                           max_len=args.max_len)
    rng = np.random.RandomState(0)
    for rid in range(args.requests):
        engine.submit(Request(
            rid, prompt=list(rng.randint(1, cfg.vocab - 1,
                                         rng.randint(4, 12))),
            max_new_tokens=args.max_new))
    t0 = time.time()
    engine.run()
    dt = time.time() - t0
    s = engine.stats
    print(f"served {s['admitted']} requests, {s['tokens_out']} tokens in "
          f"{s['steps']} engine steps ({dt:.1f}s, "
          f"{s['tokens_out']/max(dt,1e-9):.1f} tok/s)")
    print(f"host allocator worst-case op steps: {s['alloc_steps_max']} "
          f"(O(1) — paper Result 1)")
    print(f"page occupancy after drain: {engine.page_occupancy():.4f}")
    return engine


if __name__ == "__main__":
    main()
